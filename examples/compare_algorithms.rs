//! Regenerate one of the paper's tables from the public API: all seven
//! algorithms across the 10⁻³…10³ bandwidth sweep, with verified error
//! and the X/∞ conventions — plus an eighth `Auto` row showing what
//! the session's cost model picks at each bandwidth.
//!
//! The whole table runs on one prepared session inside
//! `coordinator::run_sweep`: one kd-tree build, shared per-bandwidth
//! truth/moment/clustering memos, exhaustive truth computed inside the
//! worker pool.
//!
//! Run: `cargo run --release --example compare_algorithms [dataset] [n] [kernel]`
//! Datasets: astro2d galaxy3d bio5 pall7 covtype10 texture16
//! Kernels: gaussian (default) laplace matern32 matern52 imq — the
//! non-Gaussian ones route every cell through the sum-of-Gaussians
//! layer and verify against the weight-scaled guarantee.

use fastgauss::api::{Precision, SimdMode};
use fastgauss::coordinator::{report, run_sweep, AlgoSpec, SweepConfig};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::Kernel;

fn main() -> fastgauss::util::error::Result<()> {
    let mut args = std::env::args().skip(1);
    let dataset = args.next().unwrap_or_else(|| "astro2d".to_string());
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let kernel = match args.next() {
        Some(name) => Kernel::parse(&name).ok_or_else(|| {
            fastgauss::anyhow!("unknown kernel {name} (valid: {})", Kernel::VALID_NAMES)
        })?,
        None => Kernel::Gaussian,
    };
    let ds = data::by_name(&dataset, n, 42)
        .ok_or_else(|| fastgauss::anyhow!("unknown dataset {dataset}"))?;
    let h_star = silverman(&ds.points);
    let mut algorithms = if kernel.is_gaussian() {
        AlgoSpec::paper_order()
    } else {
        // SoG cells fan one Gaussian request per component; keep the
        // table to the tree methods that stay fast at every component
        // bandwidth
        vec![AlgoSpec::Dfdo, AlgoSpec::Dito]
    };
    algorithms.push(AlgoSpec::Auto); // the session's per-cell pick
    let cfg = SweepConfig {
        dataset: ds,
        epsilon: 0.01,
        h_star,
        multipliers: vec![1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3],
        algorithms,
        workers: 1,
        leaf_size: 32,
        fast_exp: true,
        simd: SimdMode::Auto,
        precision: Precision::F64,
        kernel,
    };
    let res = run_sweep(&cfg);
    print!("{}", report::render_table(&res));
    eprintln!("(times in seconds; X = memory exhausted, inf = tolerance unreachable)");
    Ok(())
}
