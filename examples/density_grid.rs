//! Evaluate a 2-D KDE on a regular grid (bichromatic summation) and
//! write `density_grid.csv` (x, y, f̂) — ready for plotting.
//! Demonstrates the session's bichromatic path: the reference tree and
//! per-bandwidth state are prepared once, and the query grid rides on
//! top with only a query-tree build.
//!
//! Run: `cargo run --release --example density_grid [n] [grid]`

use fastgauss::api::{EvalRequest, Session};
use fastgauss::data;
use fastgauss::geometry::Matrix;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kde::density_at_session;

fn main() -> fastgauss::util::error::Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4000);
    let g: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(64);

    let ds = data::by_name("astro2d", n, 7).unwrap();
    let h = silverman(&ds.points);

    // g × g grid over the unit square
    let mut rows = Vec::with_capacity(g * g);
    for i in 0..g {
        for j in 0..g {
            rows.push(vec![i as f64 / (g - 1) as f64, j as f64 / (g - 1) as f64]);
        }
    }
    let grid = Matrix::from_rows(&rows);

    let session = Session::kde(&ds.points);
    let resolved = session.resolve(&EvalRequest::kde(h, 0.01).with_queries(&grid));
    let dens = density_at_session(&session, &grid, h, 0.01, resolved)
        .map_err(|e| fastgauss::anyhow!("{e}"))?;

    let out = "density_grid.csv";
    let mut csv_rows = Vec::with_capacity(g * g);
    for (i, d) in dens.iter().enumerate() {
        let mut r = grid.row(i).to_vec();
        r.push(*d);
        csv_rows.push(r);
    }
    data::csv::save(std::path::Path::new(out), &Matrix::from_rows(&csv_rows))?;

    let peak = dens.iter().cloned().fold(0.0f64, f64::max);
    let mean = fastgauss::util::stats::mean(&dens);
    println!(
        "wrote {out}: {g}×{g} grid, n={n}, h={h:.5}, method={resolved}; peak density {peak:.3}, mean {mean:.3}"
    );
    Ok(())
}
