//! Quickstart: guaranteed-accuracy Gaussian summation / KDE through
//! the `Session` front door — prepare once, evaluate many, automatic
//! method selection.
//!
//! Run: `cargo run --release --example quickstart`

use fastgauss::api::{EvalRequest, Method, Session};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kde::density_at_points_session;
use fastgauss::kernel::Kernel;

fn main() -> fastgauss::util::error::Result<()> {
    // 1. a dataset (any Matrix works; this is the 2-D astronomy-like set)
    let ds = data::by_name("astro2d", 2000, 42).unwrap();

    // 2. a bandwidth (Silverman pilot; see bandwidth_selection for LSCV)
    let h = silverman(&ds.points);
    println!("dataset={} n={} D={} h={h:.5}", ds.name, ds.len(), ds.dim());

    // 3. prepare the session once — one kd-tree build serves every
    //    request below
    let session = Session::kde(&ds.points);

    // 4. Gaussian summation with a guaranteed 1% relative tolerance;
    //    Method::Auto (the default) picks the algorithm from the
    //    problem's dimension, size and bandwidth
    let auto = session.evaluate(&EvalRequest::kde(h, 0.01))?;
    println!(
        "G(x_0) = {:.6}  via {} (prunes: {})",
        auto.sums[0],
        auto.method,
        auto.stats.total_prunes()
    );

    // 5. or pin the paper's algorithm explicitly
    let dito = session.evaluate(&EvalRequest::kde(h, 0.01).with_method(Method::Dito))?;

    // 6. verified against the exhaustive sum (also served — and
    //    memoized — by the session)
    let exact = session.evaluate(&EvalRequest::kde(h, 0.01).with_method(Method::Naive))?;
    let rel = fastgauss::algo::max_relative_error(&dito.sums, &exact.sums);
    println!("verified max relative error = {rel:.2e} (ε = 0.01)");

    // 7. or as a normalized density estimate
    let dens = density_at_points_session(&session, h, 0.01, Method::Auto)?;
    println!("f̂(x_0) = {:.6}", dens[0]);

    assert_eq!(session.tree_builds(), 1); // everything shared one build

    // 8. kernels beyond the Gaussian: pin one per request and the
    //    session answers through a certified sum-of-Gaussians
    //    decomposition — the decomposition's sup-norm error is charged
    //    out of ε, each Gaussian component is routed through the cost
    //    model, and the answer satisfies max_q|K̃−K| ≤ ε·W
    let matern =
        session.evaluate(&EvalRequest::kde(h, 0.01).with_kernel(Kernel::Matern32))?;
    let report = matern.sog.as_ref().expect("non-Gaussian answers carry a SoG report");
    println!(
        "Matérn-3/2 sum(x_0) = {:.6}  ({} Gaussian components, decomposition error {:.1e})",
        matern.sums[0],
        report.components.len(),
        report.decomp_err
    );
    Ok(())
}
