//! Quickstart: compute a guaranteed-accuracy Gaussian summation / KDE
//! with DITO, the paper's algorithm, in a dozen lines.
//!
//! Run: `cargo run --release --example quickstart`

use fastgauss::algo::{dito::Dito, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kde::density_at_points;

fn main() -> fastgauss::util::error::Result<()> {
    // 1. a dataset (any Matrix works; this is the 2-D astronomy-like set)
    let ds = data::by_name("astro2d", 2000, 42).unwrap();

    // 2. a bandwidth (Silverman pilot; see bandwidth_selection for LSCV)
    let h = silverman(&ds.points);
    println!("dataset={} n={} D={} h={h:.5}", ds.name, ds.len(), ds.dim());

    // 3. Gaussian summation with a guaranteed 1% relative tolerance
    let problem = GaussSumProblem::kde(&ds.points, h, 0.01);
    let engine = Dito::default();
    let result = engine.run(&problem)?;
    println!("G(x_0) = {:.6}  (prunes: {})", result.sums[0], result.stats.total_prunes());

    // 4. verified against the exhaustive sum
    let exact = Naive::new().run(&problem)?;
    let rel = fastgauss::algo::max_relative_error(&result.sums, &exact.sums);
    println!("verified max relative error = {rel:.2e} (ε = 0.01)");

    // 5. or as a normalized density estimate
    let dens = density_at_points(&ds.points, h, 0.01, &engine)?;
    println!("f̂(x_0) = {:.6}", dens[0]);
    Ok(())
}
