//! End-to-end driver (DESIGN.md §End-to-end validation): **optimal
//! bandwidth selection for KDE by least-squares cross-validation** — the
//! paper's motivating workload — on the astronomy-like dataset.
//!
//! The full pipeline composes every layer: synthetic data generation →
//! Silverman pilot → a session LSCV sweep over a 10⁻³…10³ log grid
//! (2×13 guaranteed summations through one `Session::evaluate_batch`,
//! parallel across requests, one kd-tree build total) → verification of
//! the chosen-h density against exhaustive truth and, when artifacts
//! are present, the PJRT Pallas path — and reports the paper's headline
//! metric: guaranteed-ε speedup of the whole cross-validation sweep
//! over exhaustive summation.
//!
//! Run: `cargo run --release --example bandwidth_selection [n]`
//! (default n = 5000; the result is recorded in EXPERIMENTS.md)

use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::algo::GaussSum;
use fastgauss::data;
use fastgauss::kde::bandwidth::{log_grid, silverman};
use fastgauss::kde::lscv::{lscv_score, select_bandwidth_session};
use fastgauss::util::timer::time_it;

fn main() -> fastgauss::util::error::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5000);
    let eps = 0.01;
    let ds = data::by_name("astro2d", n, 42).unwrap();
    let pilot = silverman(&ds.points);
    let grid = log_grid(pilot, 1e-3, 1e3, 13);
    println!(
        "== bandwidth selection: {} n={} D={} ε={eps} ==\npilot h = {pilot:.6}, grid = 13 log-spaced in [1e-3, 1e3]·pilot",
        ds.name,
        ds.len(),
        ds.dim(),
    );

    // ---- the fast path: LSCV sweep on a prepared session (one tree
    // build for the whole grid, parallel across the 26 requests) ----
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let ((h_star, scores), fast_secs) = time_it(|| {
        let session = Session::prepare(
            &ds.points,
            PrepareOptions { threads, ..Default::default() },
        );
        let out = select_bandwidth_session(&session, &grid, eps, Method::Dito).unwrap();
        assert_eq!(session.tree_builds(), 1);
        out
    });
    println!("\n  h                LSCV score");
    for (h, s) in grid.iter().zip(&scores) {
        let mark = if *h == h_star { "  <-- h*" } else { "" };
        println!("  {h:<16.8} {s:>14.6e}{mark}");
    }
    println!("\nDITO sweep time: {fast_secs:.2}s  →  h* = {h_star:.6}");

    // ---- the baseline: the same sweep exhaustively (the one-shot
    // engine shim, rebuilt per score — exactly what the session killed) ----
    let (_, slow_secs) = time_it(|| {
        let mut best = (grid[0], f64::INFINITY);
        for &h in &grid {
            let s =
                lscv_score(&ds.points, h, eps, &fastgauss::algo::naive::Naive::new()).unwrap();
            if s < best.1 {
                best = (h, s);
            }
        }
        best
    });
    println!("Naive sweep time: {slow_secs:.2}s");
    println!("headline: {:.1}× speedup at guaranteed ε = {eps}", slow_secs / fast_secs);

    // ---- verify the chosen-h density, vs exhaustive truth AND the
    // PJRT path — all through one fresh session ----
    let session = Session::kde(&ds.points);
    let fast = session
        .evaluate(&EvalRequest::kde(h_star, eps).with_method(Method::Dito))
        .map_err(|e| fastgauss::anyhow!("{e}"))?;
    let exact = session
        .evaluate(&EvalRequest::kde(h_star, eps).with_method(Method::Naive))
        .map_err(|e| fastgauss::anyhow!("{e}"))?;
    let rel = fastgauss::algo::max_relative_error(&fast.sums, &exact.sums);
    println!("verified max relative error at h*: {rel:.2e} (≤ {eps})");
    assert!(rel <= eps * (1.0 + 1e-9));

    if cfg!(feature = "pjrt")
        && fastgauss::runtime::artifacts_dir().join("manifest.json").exists()
    {
        let problem = fastgauss::algo::GaussSumProblem::kde(&ds.points, h_star, eps);
        let tiled = fastgauss::runtime::TiledNaive::load(ds.dim())?;
        let (pjrt, pjrt_secs) = time_it(|| tiled.run(&problem).unwrap());
        let rel_pjrt = fastgauss::algo::max_relative_error(&pjrt.sums, &exact.sums);
        println!(
            "PJRT artifact cross-check (L1 pallas kernel): rel {rel_pjrt:.1e} in {pjrt_secs:.2}s"
        );
        assert!(rel_pjrt < 1e-9);
    } else {
        println!("(artifacts not built; skipping PJRT cross-check — run `make artifacts`)");
    }
    println!("bandwidth_selection OK");
    Ok(())
}
