//! End-to-end driver (DESIGN.md §End-to-end validation): **optimal
//! bandwidth selection for KDE by least-squares cross-validation** — the
//! paper's motivating workload — on the astronomy-like dataset.
//!
//! The full pipeline composes every layer: synthetic data generation →
//! Silverman pilot → LSCV sweep over a 10⁻³…10³ log grid where each
//! score is two guaranteed Gaussian summations by DITO (L3 trees +
//! expansions + token error control) → verification of the chosen-h
//! density against the exhaustive PJRT artifact path (L1 Pallas kernel
//! via the L2 AOT graph) when artifacts are present — and reports the
//! paper's headline metric: guaranteed-ε speedup of the whole
//! cross-validation sweep over exhaustive summation.
//!
//! Run: `cargo run --release --example bandwidth_selection [n]`
//! (default n = 5000; the result is recorded in EXPERIMENTS.md)

use fastgauss::algo::dualtree::{DualTreeConfig, SweepEngine};
use fastgauss::algo::{dito::Dito, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::kde::bandwidth::{log_grid, silverman};
use fastgauss::kde::lscv::{lscv_score, select_bandwidth_engine};
use fastgauss::util::timer::time_it;

fn main() -> fastgauss::util::error::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5000);
    let eps = 0.01;
    let ds = data::by_name("astro2d", n, 42).unwrap();
    let pilot = silverman(&ds.points);
    let grid = log_grid(pilot, 1e-3, 1e3, 13);
    println!(
        "== bandwidth selection: {} n={} D={} ε={eps} ==\npilot h = {pilot:.6}, grid = 13 log-spaced in [1e-3, 1e3]·pilot",
        ds.name,
        ds.len(),
        ds.dim(),
    );

    // ---- the fast path: LSCV sweep on a prepared SweepEngine (one
    // tree build for the whole grid, parallel across bandwidths) ----
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let ((h_star, scores), fast_secs) = time_it(|| {
        let sweep = SweepEngine::for_kde(&ds.points, 32).with_threads(threads);
        let out =
            select_bandwidth_engine(&sweep, &grid, eps, &DualTreeConfig::default()).unwrap();
        assert_eq!(sweep.tree_builds(), 1);
        out
    });
    println!("\n  h                LSCV score");
    for (h, s) in grid.iter().zip(&scores) {
        let mark = if *h == h_star { "  <-- h*" } else { "" };
        println!("  {h:<16.8} {s:>14.6e}{mark}");
    }
    println!("\nDITO sweep time: {fast_secs:.2}s  →  h* = {h_star:.6}");

    // ---- the baseline: the same sweep exhaustively ----
    let (_, slow_secs) = time_it(|| {
        let mut best = (grid[0], f64::INFINITY);
        for &h in &grid {
            let s = lscv_score(&ds.points, h, eps, &Naive::new()).unwrap();
            if s < best.1 {
                best = (h, s);
            }
        }
        best
    });
    println!("Naive sweep time: {slow_secs:.2}s");
    println!("headline: {:.1}× speedup at guaranteed ε = {eps}", slow_secs / fast_secs);

    // ---- verify the chosen-h density, vs rust naive AND the PJRT path ----
    let engine = Dito::default();
    let problem = GaussSumProblem::kde(&ds.points, h_star, eps);
    let fast = engine.run(&problem)?;
    let exact = Naive::new().run(&problem)?;
    let rel = fastgauss::algo::max_relative_error(&fast.sums, &exact.sums);
    println!("verified max relative error at h*: {rel:.2e} (≤ {eps})");
    assert!(rel <= eps * (1.0 + 1e-9));

    if cfg!(feature = "pjrt")
        && fastgauss::runtime::artifacts_dir().join("manifest.json").exists()
    {
        let tiled = fastgauss::runtime::TiledNaive::load(ds.dim())?;
        let (pjrt, pjrt_secs) = time_it(|| tiled.run(&problem).unwrap());
        let rel_pjrt = fastgauss::algo::max_relative_error(&pjrt.sums, &exact.sums);
        println!(
            "PJRT artifact cross-check (L1 pallas kernel): rel {rel_pjrt:.1e} in {pjrt_secs:.2}s"
        );
        assert!(rel_pjrt < 1e-9);
    } else {
        println!("(artifacts not built; skipping PJRT cross-check — run `make artifacts`)");
    }
    println!("bandwidth_selection OK");
    Ok(())
}
