//! Property-based invariants over randomized inputs (see `prop`):
//! translation-operator exactness, bound validity, token-ledger
//! soundness, tree invariants, and end-to-end error-guarantee fuzzing.

use fastgauss::algo::dualtree::{run_dualtree, DualTreeConfig, SeriesKind};
use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::bounds::odp::OdpBounds;
use fastgauss::bounds::NodeGeometry;
use fastgauss::compute::simd::{Precision, SimdMode};
use fastgauss::geometry::{linf_dist, Matrix};
use fastgauss::hermite::{
    accumulate_farfield, eval_farfield, eval_local, h2h, l2l, HermiteTable, PairTable,
};
use fastgauss::kernel::GaussianKernel;
use fastgauss::multiindex::{Layout, MultiIndexSet};
use fastgauss::prop::{forall, Gen};
use fastgauss::tree::{BuildParams, KdTree, RefMoments};

fn random_matrix(g: &mut Gen, n: usize, d: usize) -> Matrix {
    Matrix::from_rows(&g.clustered_points(n, d))
}

/// H2H translation is exact on downward-closed sets — for random trees,
/// dims, layouts, orders and bandwidths.
#[test]
fn prop_h2h_moments_equal_direct() {
    forall("h2h == direct moments", 20, |g| {
        let d = g.usize_in(1, 4);
        let layout = if g.bool() { Layout::Grid } else { Layout::Graded };
        let p = g.usize_in(1, 4);
        let n = g.usize_in(20, 120);
        let pts = random_matrix(g, n, d);
        let w = g.vec_f64(n, 0.1, 2.0);
        let tree = KdTree::build(&pts, &w, BuildParams { leaf_size: g.usize_in(4, 24) });
        let kernel = GaussianKernel::new(g.log_uniform(0.05, 5.0));
        let m = RefMoments::compute(&tree, &kernel, layout, p);
        let set = m.set();
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        // spot-check a few nodes including the root
        for i in [0, tree.num_nodes() / 2, tree.num_nodes() - 1] {
            let node = tree.node(i);
            let rows: Vec<usize> = (node.begin..node.end).collect();
            let mut direct = vec![0.0; set.len()];
            accumulate_farfield(
                set,
                tree.points(),
                &rows,
                tree.weights(),
                &node.centroid,
                m.scale(),
                &mut direct,
                &mut mono,
                &mut off,
            );
            for j in 0..set.len() {
                let got = m.node_coeffs(i)[j];
                if (got - direct[j]).abs() > 1e-8 * direct[j].abs().max(1.0) {
                    return Err(format!("node {i} coeff {j}: {got} vs {}", direct[j]));
                }
            }
        }
        Ok(())
    });
}

/// L2L exactly re-centers a truncated polynomial.
#[test]
fn prop_l2l_recenters_exactly() {
    forall("l2l recenters", 30, |g| {
        let d = g.usize_in(1, 3);
        let layout = if g.bool() { Layout::Grid } else { Layout::Graded };
        let p = g.usize_in(1, 5);
        let set = MultiIndexSet::new(layout, d, p);
        let pairs = PairTable::new(&set);
        let coeffs = g.vec_f64(set.len(), -1.0, 1.0);
        let old_c = g.vec_f64(d, -0.5, 0.5);
        let new_c = g.vec_f64(d, -0.5, 0.5);
        let scale = g.log_uniform(0.2, 3.0);
        let mut shifted = vec![0.0; set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        l2l(&set, &pairs, &coeffs, &old_c, &new_c, scale, &mut shifted, &mut mono, &mut off);
        for _ in 0..5 {
            let xq = g.vec_f64(d, -1.0, 1.0);
            let a = eval_local(&set, &coeffs, &old_c, scale, &xq, &mut mono, &mut off);
            let b = eval_local(&set, &shifted, &new_c, scale, &xq, &mut mono, &mut off);
            if (a - b).abs() > 1e-8 * a.abs().max(1.0) {
                return Err(format!("{a} vs {b} at {xq:?}"));
            }
        }
        Ok(())
    });
}

/// Lemma 4 dominates the measured far-field truncation error for any
/// random geometry (the O(Dᵖ) bound has no node-size restriction, so we
/// fuzz radii beyond 1 too).
#[test]
fn prop_lemma4_dominates_measured_error() {
    forall("lemma4 valid", 25, |g| {
        let d = g.usize_in(1, 3);
        let h = g.log_uniform(0.1, 2.0);
        let kernel = GaussianKernel::new(h);
        let n = g.usize_in(5, 20);
        let spread = g.log_uniform(0.01, 1.5) * h; // radii up to 1.5·h
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| spread * g.f64_in(-1.0, 1.0)).collect())
            .collect();
        let pts = Matrix::from_rows(&rows);
        let w = vec![1.0; n];
        let all: Vec<usize> = (0..n).collect();
        let center = pts.col_mean();
        let r_ref =
            all.iter().map(|&r| linf_dist(pts.row(r), &center) / h).fold(0.0f64, f64::max);
        let mut xq = vec![0.0; d];
        xq[0] = spread + g.log_uniform(0.05, 2.0);
        // min distance from xq to the point-cloud bbox
        let lo = pts.col_min();
        let hi = pts.col_max();
        let mut dmin2 = 0.0;
        for j in 0..d {
            let del = if xq[j] < lo[j] {
                lo[j] - xq[j]
            } else {
                (xq[j] - hi[j]).max(0.0)
            };
            dmin2 += del * del;
        }
        let geo = NodeGeometry { dim: d, min_sqdist: dmin2, r_ref, r_query: 0.0, h };
        let exact: f64 = all
            .iter()
            .map(|&r| kernel.eval_sq(fastgauss::geometry::sqdist(pts.row(r), &xq)))
            .sum();
        let p = g.usize_in(1, 6);
        let set = MultiIndexSet::new(Layout::Graded, d, p);
        let mut coeffs = vec![0.0; set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        accumulate_farfield(
            &set, &pts, &all, &w, &center, kernel.series_scale(), &mut coeffs, &mut mono,
            &mut off,
        );
        let mut table = HermiteTable::new(d, p);
        let est =
            eval_farfield(&set, &coeffs, &center, kernel.series_scale(), &xq, &mut table, &mut off);
        let err = (est - exact).abs();
        let bound = n as f64 * OdpBounds::e_dh(&geo, p);
        if err <= bound * (1.0 + 1e-9) + 1e-12 {
            Ok(())
        } else {
            Err(format!("d={d} p={p} r={r_ref:.2}: err {err:.3e} > bound {bound:.3e}"))
        }
    });
}

/// End-to-end fuzz of the paper's guarantee: random data shape, dim,
/// bandwidth, tolerance, engine configuration — error never exceeds ε.
#[test]
fn prop_error_guarantee_fuzz() {
    forall("dual-tree error guarantee", 15, |g| {
        let d = g.usize_in(1, 6);
        let n = g.usize_in(50, 300);
        let pts = random_matrix(g, n, d);
        let h = g.log_uniform(1e-3, 1e2);
        let eps = g.log_uniform(1e-4, 0.2);
        let cfg = DualTreeConfig {
            leaf_size: g.usize_in(4, 64),
            use_tokens: g.bool(),
            series: match g.usize_in(0, 2) {
                0 => None,
                1 => Some(SeriesKind::OdpGraded),
                _ => Some(SeriesKind::OpdGrid),
            },
            plimit: if g.bool() { None } else { Some(g.usize_in(1, 6)) },
            // fuzz both base-case kernels: the guarantee must hold with
            // the certified fast path and the bit-exact one alike
            fast_exp: g.bool(),
            simd: if g.bool() { SimdMode::Auto } else { SimdMode::Off },
            // f32 requests must demote themselves whenever the derived
            // certificate does not fit the ε/4 admission gate
            precision: if g.bool() { Precision::F32 } else { Precision::F64 },
        };
        let problem = GaussSumProblem::kde(&pts, h, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let out = run_dualtree(&problem, &cfg).map_err(|e| e.to_string())?;
        let rel = max_relative_error(&out.sums, &exact);
        if rel <= eps * (1.0 + 1e-9) {
            Ok(())
        } else {
            Err(format!("cfg={cfg:?} d={d} n={n} h={h:.3e} eps={eps:.3e}: rel={rel:.3e}"))
        }
    });
}

/// Tree structural invariants over random builds.
#[test]
fn prop_tree_invariants() {
    forall("tree invariants", 25, |g| {
        let d = g.usize_in(1, 8);
        let n = g.usize_in(1, 400);
        let rows: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| g.f64_in(0.0, 1.0)).collect()).collect();
        let pts = Matrix::from_rows(&rows);
        let w = g.vec_f64(n, 0.01, 3.0);
        let tree = KdTree::build(&pts, &w, BuildParams { leaf_size: g.usize_in(1, 40) });
        // weights conserve, children partition, bboxes contain
        let total: f64 = w.iter().sum();
        if (tree.total_weight() - total).abs() > 1e-9 * total {
            return Err("weight not conserved".into());
        }
        for i in 0..tree.num_nodes() {
            let nd = tree.node(i);
            for pos in nd.begin..nd.end {
                if !nd.bbox.contains(tree.points().row(pos)) {
                    return Err(format!("node {i} bbox misses point {pos}"));
                }
            }
            if let Some((l, r)) = tree.children(i) {
                let (ln, rn) = (tree.node(l), tree.node(r));
                if ln.begin != nd.begin || ln.end != rn.begin || rn.end != nd.end {
                    return Err(format!("node {i} children don't partition"));
                }
                // sibling min/max distance bounds must bracket truth
                for _ in 0..3 {
                    let a = ln.begin + g.usize_in(0, ln.count() - 1);
                    let b = rn.begin + g.usize_in(0, rn.count() - 1);
                    let dd = fastgauss::geometry::dist(
                        tree.points().row(a),
                        tree.points().row(b),
                    );
                    if dd < ln.min_dist(rn) - 1e-9 || dd > ln.max_dist(rn) + 1e-9 {
                        return Err(format!(
                            "node {i}: dist {dd} outside [{}, {}]",
                            ln.min_dist(rn),
                            ln.max_dist(rn)
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Tokens never push the verified error past ε AND genuinely help:
/// across random instances DFDO's base-case work ≤ DFD's.
#[test]
fn prop_tokens_sound_and_useful() {
    forall("tokens sound & useful", 10, |g| {
        let d = g.usize_in(1, 4);
        let n = g.usize_in(100, 400);
        let pts = random_matrix(g, n, d);
        let h = g.log_uniform(1e-2, 10.0);
        let problem = GaussSumProblem::kde(&pts, h, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let base = DualTreeConfig { use_tokens: false, series: None, ..Default::default() };
        let tok = DualTreeConfig { use_tokens: true, series: None, ..Default::default() };
        let a = run_dualtree(&problem, &base).map_err(|e| e.to_string())?;
        let b = run_dualtree(&problem, &tok).map_err(|e| e.to_string())?;
        let rel = max_relative_error(&b.sums, &exact);
        if rel > 0.01 * (1.0 + 1e-9) {
            return Err(format!("tokens broke guarantee: {rel:.2e}"));
        }
        if b.stats.base_point_pairs > a.stats.base_point_pairs {
            return Err(format!(
                "tokens increased work: {} > {}",
                b.stats.base_point_pairs, a.stats.base_point_pairs
            ));
        }
        Ok(())
    });
}

/// Dataset generators: deterministic, unit-cube, right shapes.
#[test]
fn prop_dataset_contracts() {
    forall("dataset contracts", 12, |g| {
        let names = ["astro2d", "galaxy3d", "bio5", "pall7", "covtype10", "texture16"];
        let name = names[g.usize_in(0, names.len() - 1)];
        let n = g.usize_in(10, 500);
        let seed = g.rng().next_u64();
        let a = fastgauss::data::by_name(name, n, seed).unwrap();
        let b = fastgauss::data::by_name(name, n, seed).unwrap();
        if a.points != b.points {
            return Err(format!("{name} not deterministic"));
        }
        if a.len() != n {
            return Err(format!("{name}: wrong n"));
        }
        for j in 0..a.dim() {
            if a.points.col_min()[j] < -1e-12 || a.points.col_max()[j] > 1.0 + 1e-12 {
                return Err(format!("{name}: outside unit cube"));
            }
        }
        Ok(())
    });
}
