//! The compute layer's contract: the blocked SoA microkernel must
//! reproduce the scalar triple loop it replaced — bit-for-bit when a
//! range fits one block, within ulps otherwise — across dimensions,
//! block widths, gathers and scratch reuse.

use fastgauss::compute::{self, reference, Scratch, BLOCK};
use fastgauss::geometry::{sqdist, Matrix};
use fastgauss::kernel::GaussianKernel;
use fastgauss::util::Pcg32;

fn random(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_rows(
        &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
    )
}

fn random_weights(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::new(seed);
    (0..n).map(|_| rng.uniform_in(0.1, 3.0)).collect()
}

#[test]
fn blocked_microkernel_matches_scalar_triple_loop() {
    let shapes = [(50, 200, 1, 0.1), (40, 333, 2, 0.3), (30, 128, 5, 1.0), (25, 64, 10, 0.7)];
    for (n_q, n_r, d, h) in shapes {
        let q = random(n_q, d, 100 + d as u64);
        let r = random(n_r, d, 200 + d as u64);
        let w = random_weights(n_r, 300 + d as u64);
        let kernel = GaussianKernel::new(h);
        let mut want = vec![0.0; n_q];
        reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut want);
        for block in [0, 1, 13, BLOCK, 4 * BLOCK] {
            let mut scratch = Scratch::new(d);
            let mut got = vec![0.0; n_q];
            compute::gauss_sum_all(&q, &r, &w, &kernel, block, &mut scratch, &mut got);
            for i in 0..n_q {
                let tol = if block == 0 || block >= n_r { 0.0 } else { 1e-12 * want[i].max(1.0) };
                assert!(
                    (got[i] - want[i]).abs() <= tol,
                    "d={d} block={block} i={i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn single_block_ranges_are_bitwise_identical() {
    // leaf-sized ranges (the dual-tree base case) fit one block: the
    // microkernel must produce the exact bits of the scalar loop
    let q = random(20, 3, 7);
    let r = random(32, 3, 8);
    let w = random_weights(32, 9);
    let kernel = GaussianKernel::new(0.2);
    let mut scratch = Scratch::new(3);
    scratch.load(&r, 0, 32);
    scratch.load_weights(&w, 0, 32);
    for qi in 0..20 {
        let got = scratch.gauss_dot(&kernel, q.row(qi));
        let mut want = 0.0;
        for ri in 0..32 {
            want += w[ri] * kernel.eval_sq(sqdist(q.row(qi), r.row(ri)));
        }
        assert_eq!(got, want, "query {qi}");
    }
}

#[test]
fn indexed_gather_matches_scalar_subset() {
    let r = random(100, 4, 10);
    let w = random_weights(100, 11);
    let kernel = GaussianKernel::new(0.4);
    let mut rng = Pcg32::new(12);
    let idx: Vec<usize> = (0..37).map(|_| rng.below(100)).collect();
    let q: Vec<f64> = (0..4).map(|_| rng.uniform()).collect();
    let mut scratch = Scratch::new(4);
    let got = compute::gauss_sum_indexed(&q, &r, &idx, &w, &kernel, &mut scratch);
    let mut want = 0.0;
    for &i in &idx {
        want += w[i] * kernel.eval_sq(sqdist(&q, r.row(i)));
    }
    assert_eq!(got, want);
}

#[test]
fn sqdist_lane_matches_geometry() {
    let pts = random(77, 6, 13);
    let q = random(1, 6, 14);
    let mut scratch = Scratch::with_block(6, 16); // force multi-block growth
    scratch.load(&pts, 10, 60);
    let sq = scratch.sqdist_into(q.row(0));
    assert_eq!(sq.len(), 50);
    for (j, &v) in sq.iter().enumerate() {
        assert_eq!(v, sqdist(q.row(0), pts.row(10 + j)), "lane {j}");
    }
}

#[test]
fn scratch_survives_interleaved_workloads() {
    // alternating shapes and ranges must never leak state between calls
    let kernel = GaussianKernel::new(0.5);
    let r1 = random(300, 2, 15);
    let r2 = random(17, 2, 16);
    let w1 = random_weights(300, 17);
    let w2 = random_weights(17, 18);
    let q = random(5, 2, 19);
    let mut scratch = Scratch::new(2);
    for _round in 0..3 {
        for (r, w) in [(&r1, &w1), (&r2, &w2)] {
            let mut got = vec![0.0; 5];
            compute::gauss_sum_all(&q, r, w, &kernel, BLOCK, &mut scratch, &mut got);
            let mut want = vec![0.0; 5];
            reference::scalar_gauss_sums(&q, r, w, &kernel, &mut want);
            for i in 0..5 {
                assert!((got[i] - want[i]).abs() <= 1e-12 * want[i].max(1.0));
            }
        }
    }
}
