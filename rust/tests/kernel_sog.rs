//! Integration suite for the kernel layer: the Gaussian default stays
//! bit-for-bit untouched, non-Gaussian answers are pool-width
//! invariant, `Auto` routes individual SoG components through the cost
//! model, and the weight-scaled guarantee holds in the bichromatic and
//! weighted settings.

use fastgauss::algo::max_weight_scaled_error;
use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::data;
use fastgauss::geometry::Matrix;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::Kernel;
use fastgauss::util::Pcg32;

/// `kernel = gaussian` must be indistinguishable from a session that
/// never heard of the kernel layer: same sums bitwise, no SoG report,
/// no SoG stats — whether the default is implicit, set per session, or
/// pinned per request.
#[test]
fn gaussian_default_is_bit_identical() {
    let ds = data::by_name("astro2d", 300, 41).unwrap();
    let h = silverman(&ds.points);
    let plain = Session::prepare(&ds.points, PrepareOptions::default());
    let explicit = Session::prepare(
        &ds.points,
        PrepareOptions { kernel: Kernel::Gaussian, ..Default::default() },
    );
    for m in [Method::Naive, Method::Dfdo, Method::Dito, Method::Auto] {
        let req = EvalRequest::kde(h, 1e-4).with_method(m);
        let pinned = EvalRequest::kde(h, 1e-4).with_method(m).with_kernel(Kernel::Gaussian);
        let a = plain.evaluate(&req).unwrap();
        let b = explicit.evaluate(&req).unwrap();
        let c = plain.evaluate(&pinned).unwrap();
        assert_eq!(a.sums, b.sums, "{m}: explicit gaussian session diverged");
        assert_eq!(a.sums, c.sums, "{m}: per-request gaussian pin diverged");
        for ev in [&a, &b, &c] {
            assert_eq!(ev.kernel, Kernel::Gaussian);
            assert!(ev.sog.is_none(), "{m}: gaussian answer must not carry a SoG report");
            assert_eq!(ev.stats.sog_components, 0, "{m}");
            assert_eq!(ev.stats.sog_routed, [0u64; 7], "{m}");
        }
    }
}

/// Non-Gaussian answers ride the same fixed task decomposition and
/// indexed reduction as everything else: bitwise identical across pool
/// widths.
#[test]
fn sog_answers_are_pool_width_invariant() {
    let ds = data::by_name("astro2d", 300, 43).unwrap();
    let h = silverman(&ds.points);
    let run = |threads: usize| {
        let session = Session::prepare(
            &ds.points,
            PrepareOptions { kernel: Kernel::Laplace, threads, ..Default::default() },
        );
        session.evaluate(&EvalRequest::kde(h, 1e-2).with_method(Method::Dfdo)).unwrap()
    };
    let base = run(1);
    assert!(base.stats.sog_components > 0);
    for threads in [2, 4] {
        let other = run(threads);
        assert_eq!(base.sums, other.sums, "threads={threads}: SoG sums diverged bitwise");
        assert_eq!(
            base.stats.sog_components, other.stats.sog_components,
            "threads={threads}"
        );
    }
}

/// The SoG component bandwidths span the near-field and far-field
/// regimes of the cost model, so `Auto` must route the components of
/// one request to at least two distinct concrete methods — per-request
/// selection would collapse them to one.
#[test]
fn auto_routes_components_through_the_cost_model() {
    let ds = data::by_name("astro2d", 400, 47).unwrap();
    let h = silverman(&ds.points);
    let session = Session::prepare(
        &ds.points,
        PrepareOptions { kernel: Kernel::Laplace, ..Default::default() },
    );
    let ev = session.evaluate(&EvalRequest::kde(h, 1e-2).with_method(Method::Auto)).unwrap();
    let report = ev.sog.as_ref().expect("laplace answer must carry a SoG report");
    let distinct: std::collections::BTreeSet<&str> =
        report.components.iter().map(|c| c.method.name()).collect();
    assert!(
        distinct.len() >= 2,
        "Auto routed every component identically ({distinct:?}) — per-component \
         selection is not engaging"
    );
    assert_eq!(
        ev.stats.sog_routed.iter().sum::<u64>(),
        ev.stats.sog_components,
        "every component must land in a paper-method bucket"
    );
    assert!(ev.stats.sog_routed.iter().filter(|&&c| c > 0).count() >= 2);
}

/// Bichromatic + weighted: the guarantee max_q|K̃(q)−K(q)| ≤ ε·W holds
/// against the exhaustive true-kernel reference with W = Σ request
/// weights.
#[test]
fn bichromatic_weighted_sog_matches_direct_sums() {
    let ds = data::by_name("galaxy3d", 250, 53).unwrap();
    let mut rng = Pcg32::new(54);
    let weights: Vec<f64> = (0..250).map(|_| rng.uniform_in(0.2, 3.0)).collect();
    let queries = Matrix::from_rows(
        &(0..60)
            .map(|_| vec![rng.uniform(), rng.uniform(), rng.uniform()])
            .collect::<Vec<_>>(),
    );
    let scale = silverman(&ds.points);
    let w: f64 = weights.iter().sum();
    let session = Session::prepare(
        &ds.points,
        PrepareOptions { kernel: Kernel::Matern52, ..Default::default() },
    );
    for eps in [1e-2, 1e-4] {
        let exact =
            Kernel::Matern52.direct_sums(scale, &queries, &ds.points, Some(&weights));
        let req = EvalRequest::kde(scale, eps)
            .with_queries(&queries)
            .with_weights(&weights)
            .with_method(Method::Dfdo);
        let ev = session.evaluate(&req).unwrap();
        assert_eq!(ev.sums.len(), 60);
        let err = max_weight_scaled_error(&ev.sums, &exact, w);
        assert!(err <= eps * (1.0 + 1e-9), "eps={eps}: scaled err {err:.2e}");
        let report = ev.sog.as_ref().unwrap();
        assert!((report.total_weight - w).abs() <= 1e-9 * w);
        assert!(report.decomp_err <= 0.25 * eps);
    }
}
