//! Coordinator integration: the full table protocol (all seven
//! algorithms including the FGT τ-halving and IFGT K-doubling loops) on
//! a small dataset, with verified cells and paper-style rendering.

use fastgauss::api::{Precision, SimdMode};
use fastgauss::coordinator::{report, run_sweep, AlgoSpec, CellOutcome, SweepConfig};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::Kernel;

fn base_cfg(name: &str, n: usize, mult: Vec<f64>, algos: Vec<AlgoSpec>) -> SweepConfig {
    let ds = data::by_name(name, n, 3).unwrap();
    let h_star = silverman(&ds.points);
    SweepConfig {
        dataset: ds,
        epsilon: 0.01,
        h_star,
        multipliers: mult,
        algorithms: algos,
        workers: 2,
        leaf_size: 24,
        fast_exp: true,
        simd: SimdMode::Auto,
        precision: Precision::F64,
        kernel: Kernel::Gaussian,
    }
}

#[test]
fn full_seven_algorithm_protocol_2d() {
    let cfg = base_cfg(
        "astro2d",
        400,
        vec![1.0, 100.0],
        AlgoSpec::paper_order(), // Naive, FGT, IFGT, DFD, DFDO, DFTO, DITO
    );
    let res = run_sweep(&cfg);
    assert_eq!(res.cells.len(), 14);
    // guaranteed algorithms must all succeed and verify
    for (a, spec) in res.algorithms.iter().enumerate() {
        for b in 0..2 {
            let cell = res.cell(a, b);
            match spec {
                AlgoSpec::Naive | AlgoSpec::Dfd | AlgoSpec::Dfdo | AlgoSpec::Dfto
                | AlgoSpec::Dito => {
                    assert!(
                        matches!(cell.outcome, CellOutcome::Time(_)),
                        "{} failed: {:?}",
                        spec.name(),
                        cell.outcome
                    );
                    assert!(cell.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
                }
                // FGT/IFGT may succeed or fail; outcome must be recorded
                _ => {}
            }
        }
    }
    let table = report::render_table(&res);
    for name in ["Naive", "FGT", "IFGT", "DFD", "DFDO", "DFTO", "DITO"] {
        assert!(table.contains(name), "missing row {name} in\n{table}");
    }
}

#[test]
fn fgt_small_bandwidth_is_x_large_is_ok_2d() {
    let cfg = base_cfg("astro2d", 300, vec![1e-3, 1e2], vec![AlgoSpec::Fgt]);
    let res = run_sweep(&cfg);
    assert_eq!(res.cell(0, 0).outcome, CellOutcome::RamExhausted, "tiny h must be X");
    assert!(
        matches!(res.cell(0, 1).outcome, CellOutcome::Time(_)),
        "large h should succeed: {:?}",
        res.cell(0, 1).outcome
    );
}

#[test]
fn fgt_is_x_everywhere_in_high_d() {
    // paper: FGT exhausts RAM for D ≥ 5 at every bandwidth
    let cfg = base_cfg("bio5", 200, vec![1.0], vec![AlgoSpec::Fgt]);
    let res = run_sweep(&cfg);
    assert_eq!(res.cell(0, 0).outcome, CellOutcome::RamExhausted);
}

#[test]
fn ifgt_fails_at_small_bandwidth() {
    // paper: IFGT is ∞ across almost the entire sweep
    let cfg = base_cfg("astro2d", 300, vec![1e-3], vec![AlgoSpec::Ifgt]);
    let res = run_sweep(&cfg);
    assert_eq!(res.cell(0, 0).outcome, CellOutcome::ToleranceUnreachable);
}

#[test]
fn csv_export_matches_cells() {
    let cfg = base_cfg("galaxy3d", 200, vec![0.1, 1.0], vec![AlgoSpec::Dito, AlgoSpec::Dfd]);
    let res = run_sweep(&cfg);
    let csv = report::render_csv(&res);
    assert_eq!(csv.lines().count(), 1 + 4);
    assert!(csv.lines().skip(1).all(|l| l.starts_with("galaxy3d,3,200,")));
}

#[test]
fn workers_do_not_change_results() {
    let mk = |workers| {
        let mut cfg =
            base_cfg("astro2d", 250, vec![0.1, 1.0, 10.0], vec![AlgoSpec::Dito, AlgoSpec::Dfdo]);
        cfg.workers = workers;
        run_sweep(&cfg)
    };
    let a = mk(1);
    let b = mk(4);
    // outcomes (not timings) must be identical and ordered identically
    assert_eq!(a.cells.len(), b.cells.len());
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!((x.algo_index, x.bandwidth_index), (y.algo_index, y.bandwidth_index));
        assert_eq!(
            matches!(x.outcome, CellOutcome::Time(_)),
            matches!(y.outcome, CellOutcome::Time(_))
        );
        // deterministic algorithms → identical verified errors
        assert_eq!(x.rel_err, y.rel_err);
    }
}
