//! Sliced Fourier engine integration: ε-verification on high-dim
//! datasets at both tolerance regimes, the P-doubling protocol's
//! convergent and hopeless paths, pool-width bit-identity, bichromatic
//! and weighted requests, and the Method/config parity surface.
//!
//! Bandwidth choices are deliberate, not arbitrary: the slicing
//! Monte-Carlo variance per projection scales like the squared
//! pair-distance-to-bandwidth ratio, so ε = 1e-2 is reachable at
//! moderate bandwidths (h of the order of the data diameter) while
//! ε = 1e-4 needs the large-bandwidth regime (h a few times the
//! diameter) to verify within the doubling budget. Outside those
//! regimes the engine reports the paper's ∞ rather than answering
//! wrong — the hopeless-path test pins exactly that.

use fastgauss::algo::{max_relative_error, naive::Naive, AlgoError, GaussSum, GaussSumProblem};
use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::config::RunConfig;
use fastgauss::data::synthetic;
use fastgauss::geometry::Matrix;

fn eval_sliced(session: &Session<'_>, h: f64, eps: f64) -> fastgauss::api::Evaluation {
    session
        .evaluate(&EvalRequest::kde(h, eps).with_method(Method::Sliced))
        .unwrap_or_else(|e| panic!("Sliced h={h} eps={eps}: {e}"))
}

/// Sliced answers verify against the exhaustive truth on uniform noise
/// and both hyper sets, at the moderate-bandwidth ε = 1e-2 regime and
/// the large-bandwidth ε = 1e-4 regime.
#[test]
fn sliced_meets_epsilon_on_uniform_and_hyper_sets() {
    let cases: [(&str, Matrix, f64, f64); 6] = [
        ("uniform20", synthetic::uniform(130, 20, 11), 2.0, 1e-2),
        ("hyper20", synthetic::hyper20(130, 11), 2.0, 1e-2),
        ("hyper50", synthetic::hyper50(130, 11), 3.0, 1e-2),
        ("uniform20", synthetic::uniform(130, 20, 12), 20.0, 1e-4),
        ("hyper20", synthetic::hyper20(130, 12), 20.0, 1e-4),
        ("hyper50", synthetic::hyper50(130, 12), 25.0, 1e-4),
    ];
    for (name, data, h, eps) in &cases {
        let session = Session::kde(data);
        let ev = eval_sliced(&session, *h, *eps);
        assert_eq!(ev.method, Method::Sliced);
        // the session's own verification verdict...
        let reported = ev.rel_err.expect("Sliced answers carry the verified rel_err");
        assert!(
            reported <= eps * (1.0 + 1e-9),
            "{name} h={h} eps={eps}: reported rel {reported:.2e}"
        );
        // ...and an independent re-measurement against fresh truth
        let problem = GaussSumProblem::kde(data, *h, *eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let rel = max_relative_error(&ev.sums, &exact);
        assert!(rel <= eps * (1.0 + 1e-9), "{name} h={h} eps={eps}: measured rel {rel:.2e}");
    }
}

/// The P-doubling protocol converges where slicing is viable and
/// reports the paper's ∞ (never a wrong answer) where it is not: a
/// bandwidth orders of magnitude under the data spread pushes the
/// Fourier truncation past its cap on every slice.
#[test]
fn p_doubling_converges_and_reports_hopeless_bandwidths() {
    let data = synthetic::hyper20(300, 3);
    let session = Session::kde(&data);
    let ev = eval_sliced(&session, 2.5, 1e-2);
    assert!(ev.rel_err.unwrap() <= 1e-2 * (1.0 + 1e-9));
    match session.evaluate(&EvalRequest::kde(1e-3, 1e-2).with_method(Method::Sliced)) {
        Err(AlgoError::ToleranceUnreachable(msg)) => {
            assert!(msg.contains("slice"), "∞ must name the failing slice plan: {msg}");
        }
        other => panic!("expected ∞ at h=1e-3, got {other:?}"),
    }
}

/// One seed, one dataset: the accepted Sliced answer is bit-identical
/// across pool widths 1, 2 and 8, and across repeated evaluates on one
/// session — the slice blocks are absolute-indexed and folded in block
/// order, so the schedule never touches the arithmetic.
#[test]
fn sliced_is_bit_identical_across_pool_widths_and_repeats() {
    let data = synthetic::hyper20(140, 7);
    let mut answers = Vec::new();
    for threads in [1usize, 2, 8] {
        let session =
            Session::prepare(&data, PrepareOptions { threads, ..Default::default() });
        let first = eval_sliced(&session, 2.5, 1e-2);
        let second = eval_sliced(&session, 2.5, 1e-2);
        assert_eq!(first.sums, second.sums, "threads={threads}: repeat evaluate diverged");
        assert_eq!(first.rel_err, second.rel_err);
        answers.push(first.sums);
    }
    assert_eq!(answers[0], answers[1], "widths 1 vs 2 diverged");
    assert_eq!(answers[0], answers[2], "widths 1 vs 8 diverged");
}

/// Bichromatic (explicit query set) and weighted requests go through
/// the same verified path: the answer must meet ε against a fresh
/// exhaustive run of the same problem.
#[test]
fn bichromatic_and_weighted_requests_verify() {
    let refs = synthetic::hyper20(150, 21);
    let queries = synthetic::uniform(60, 20, 22);
    let weights: Vec<f64> = (0..150).map(|i| 0.5 + (i as f64) / 150.0).collect();
    let (h, eps) = (2.5, 1e-2);

    let session = Session::kde(&refs);
    let ev = session
        .evaluate(&EvalRequest::kde(h, eps).with_method(Method::Sliced).with_queries(&queries))
        .unwrap();
    assert_eq!(ev.sums.len(), 60);
    let problem = GaussSumProblem::new(&queries, &refs, None, h, eps);
    let exact = Naive::new().run(&problem).unwrap().sums;
    assert!(max_relative_error(&ev.sums, &exact) <= eps * (1.0 + 1e-9), "bichromatic");

    let wsession = Session::prepare(
        &refs,
        PrepareOptions { weights: Some(weights.clone()), ..Default::default() },
    );
    let ev = eval_sliced(&wsession, h, eps);
    let mut problem = GaussSumProblem::new(&refs, &refs, Some(&weights), h, eps);
    problem.monochromatic = true;
    let exact = Naive::new().run(&problem).unwrap().sums;
    assert!(max_relative_error(&ev.sums, &exact) <= eps * (1.0 + 1e-9), "weighted");
}

/// `Method::Auto` routes the high-dimensional, non-near-diagonal
/// regime to Sliced — and keeps its pre-existing low-D and small-N
/// choices (the full low-D golden table lives in session_api.rs).
#[test]
fn auto_selects_sliced_in_high_dimensions() {
    for (data, h) in [(synthetic::hyper20(400, 5), 0.5), (synthetic::hyper50(400, 5), 0.8)] {
        let session = Session::kde(&data);
        assert_eq!(session.resolve(&EvalRequest::kde(h, 1e-2)), Method::Sliced);
        // near-diagonal stays with the FD-only dual tree in any dim
        let tiny = session.data_scale() * 1e-3;
        assert_eq!(session.resolve(&EvalRequest::kde(tiny, 1e-2)), Method::Dfdo);
    }
    // small N: preparation can't amortize, Naive wins regardless of dim
    let small = synthetic::hyper20(100, 5);
    let session = Session::kde(&small);
    assert_eq!(session.resolve(&EvalRequest::kde(0.5, 1e-2)), Method::Naive);
    // low-D stays on the paper's engines
    let low = synthetic::astro2d(400, 5);
    let session = Session::kde(&low);
    let picked = session.resolve(&EvalRequest::kde(session.data_scale(), 1e-2));
    assert_ne!(picked, Method::Sliced, "low-D must not route to Sliced");
}

/// The parity surface: Method::parse round-trips the new name, the
/// paper table order stays the paper's seven rows, and the config keys
/// (`method = sliced`, `slices = P`) reach their PrepareOptions fields.
#[test]
fn method_and_config_round_trips() {
    assert_eq!(Method::parse("sliced"), Some(Method::Sliced));
    assert_eq!(Method::parse("SLICED"), Some(Method::Sliced));
    assert_eq!(Method::Sliced.name(), "Sliced");
    for m in Method::ALL {
        assert_eq!(Method::parse(m.name()), Some(m), "{} must round-trip", m.name());
    }
    assert_eq!(Method::paper_order().len(), 7, "the paper table keeps its seven rows");
    assert!(!Method::paper_order().contains(&Method::Sliced));
    assert!(!Method::Sliced.guarantees_tolerance(), "verified by the session, not a priori");
    assert!(Method::Sliced.dual_tree_config(32, None).is_none());

    let mut cfg = RunConfig::default();
    cfg.set("method", "sliced").unwrap();
    assert_eq!(cfg.method, Method::Sliced);
    cfg.set("slices", "256").unwrap();
    assert_eq!(cfg.slices, 256);
    assert_eq!(PrepareOptions::default().slices, 0, "0 = engine-chosen start");
}

/// A caller-pinned slice start threads through PrepareOptions into the
/// doubling loop and still comes back ε-verified.
#[test]
fn explicit_slice_start_still_verifies() {
    let data = synthetic::hyper20(130, 9);
    let session =
        Session::prepare(&data, PrepareOptions { slices: 256, ..Default::default() });
    let ev = eval_sliced(&session, 2.5, 1e-2);
    assert!(ev.rel_err.unwrap() <= 1e-2 * (1.0 + 1e-9));
}
