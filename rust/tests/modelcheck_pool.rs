//! Schedule-exploration suite for the work-stealing core: the real
//! pool's invariants hold across every explored interleaving, and the
//! deliberately broken pools in [`Mutation`] are caught within a
//! bounded schedule budget, with the failing schedule reproducible
//! bitwise from its printed seed and decision sequence.
//!
//! Runs only with `--features modelcheck` (see `[[test]]` in
//! Cargo.toml): without the feature the sync shim routes nothing and
//! `explore` would observe a single uncontrolled schedule.

#![cfg(feature = "modelcheck")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use fastgauss::runtime::modelcheck::{self, McConfig};
use fastgauss::runtime::pool::{Mutation, WorkStealPool};

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

// ---- the real pool, exhaustively ----

#[test]
fn run_indexed_delivers_every_slot_across_all_schedules() {
    let cfg = McConfig::dfs();
    let report = modelcheck::explore(&cfg, || {
        let pool = WorkStealPool::new(2);
        let out = pool.run_indexed(3, |k| 10 * k + 1);
        // a lost task panics inside run_indexed; a torn slot shows here
        assert_eq!(out, vec![1, 11, 21]);
        drop(pool); // join the workers inside the scenario
    });
    eprintln!(
        "run_indexed: {} schedules explored (exhausted: {}), seed {:#x}",
        report.schedules, report.exhausted, report.seed
    );
    if let Some(failure) = &report.failure {
        panic!("{failure}");
    }
    assert!(report.schedules > 1, "the explorer never branched");
}

#[test]
fn run_indexed_results_are_bit_identical_across_schedules() {
    // the keystone determinism claim, under adversarial scheduling:
    // the in-order fold of run_indexed results may not depend on how
    // tasks interleave, were stolen, or raced to their slots
    let reference: Mutex<Option<Vec<u64>>> = Mutex::new(None);
    let cfg = McConfig::dfs();
    let report = modelcheck::explore(&cfg, || {
        let pool = WorkStealPool::new(2);
        let parts = pool.run_indexed(3, |k| {
            let x = 0.1f64 + k as f64;
            (x * x).exp().sqrt()
        });
        drop(pool);
        let folded: f64 = parts.iter().sum();
        let mut bits: Vec<u64> = parts.iter().map(|v| v.to_bits()).collect();
        bits.push(folded.to_bits());
        let mut slot = reference.lock().unwrap();
        match slot.as_ref() {
            None => *slot = Some(bits),
            Some(first) => assert_eq!(first, &bits, "schedule-dependent float results"),
        }
    });
    if let Some(failure) = &report.failure {
        panic!("{failure}");
    }
    assert!(report.schedules > 1, "the explorer never branched");
}

#[test]
fn nested_scopes_help_instead_of_deadlocking() {
    // batch → traversal nesting: a worker waiting on an inner scope
    // must execute pending tasks, never park the pool into a deadlock.
    // A deadlock here surfaces as a forced condvar timeout, which the
    // config treats as a failure. The tree is too wide to enumerate,
    // so sample random schedules (seed overridable via
    // FASTGAUSS_MC_SEED for CI reproduction).
    let cfg = McConfig::random(150).from_env();
    let report = modelcheck::explore(&cfg, || {
        let pool = WorkStealPool::new(2);
        let outer = pool.run_indexed(2, |i| {
            let inner = pool.run_indexed(2, |j| 10 * i + j);
            inner.iter().sum::<usize>()
        });
        assert_eq!(outer, vec![1, 21]);
        drop(pool);
    });
    if let Some(failure) = &report.failure {
        panic!("{failure}");
    }
    assert_eq!(report.forced_timeouts, 0, "a nested wait needed its timeout safety net");
}

#[test]
fn first_panic_is_captured_and_pool_survives_under_all_schedules() {
    let cfg = McConfig::dfs();
    let report = modelcheck::explore(&cfg, || {
        let pool = WorkStealPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_indexed(2, |k| {
                if k == 1 {
                    panic!("injected task failure");
                }
                k
            })
        }));
        let payload = result.expect_err("task panic must reach the caller on every schedule");
        assert!(
            panic_message(payload.as_ref()).contains("injected task failure"),
            "panic payload was lost or replaced"
        );
        // the latch completed exactly once despite the panic: the pool
        // keeps scheduling fine afterwards
        assert_eq!(pool.run_indexed(2, |k| k + 1), vec![1, 2]);
        drop(pool);
    });
    if let Some(failure) = &report.failure {
        panic!("{failure}");
    }
    assert!(report.schedules > 1, "the explorer never branched");
}

// ---- the broken pools, caught and replayed ----

/// Explore a mutated pool, demand a failure within the budget, then
/// replay the recorded decision sequence twice and demand the same
/// failure both times — the reproducibility contract end to end.
fn assert_caught_and_replayable(mutation: Mutation, cfg: &McConfig) {
    let scenario = move || {
        let pool = WorkStealPool::new_mutated(2, mutation);
        let out = pool.run_indexed(2, |k| k + 7);
        assert_eq!(out, vec![7, 8]);
        drop(pool);
    };
    let report = modelcheck::explore(cfg, scenario);
    let failure = report.failure.unwrap_or_else(|| {
        panic!(
            "{mutation:?} escaped detection: {} schedules (exhausted: {}), seed {:#x}",
            report.schedules, report.exhausted, report.seed
        )
    });
    // the printed seed + choices are the reproduction recipe
    eprintln!("{mutation:?} caught:\n{failure}");
    for round in 0..2 {
        let replayed = modelcheck::replay(cfg, &failure.choices, scenario);
        let again = replayed
            .failure
            .unwrap_or_else(|| panic!("round {round}: replay of {mutation:?} did not fail"));
        assert_eq!(again.message, failure.message, "round {round}: replay diverged");
        assert_eq!(again.trace, failure.trace, "round {round}: replayed trace diverged");
    }
}

#[test]
fn relaxed_latch_decrement_is_caught_and_replays_bitwise() {
    // dropping the release edge on the latch decrement lets the scope
    // waiter observe completion without the finished task's writes;
    // the scope-token clock assertion catches the first such schedule
    assert_caught_and_replayable(Mutation::RelaxedLatchDecrement, &McConfig::dfs());
}

#[test]
fn skipped_completion_wake_is_caught_and_replays_bitwise() {
    // losing the completion notify strands the parked scope waiter;
    // with `fail_on_forced_timeout` the lost wakeup is an error, not a
    // silent 50ms stall
    assert_caught_and_replayable(Mutation::SkipCompletionWake, &McConfig::dfs());
}

// ---- configuration plumbing ----

#[test]
fn env_overrides_parse_decimal_and_hex() {
    std::env::set_var("FASTGAUSS_MC_SEED", "0xdead_beef".replace('_', ""));
    std::env::set_var("FASTGAUSS_MC_SCHEDULES", "12345");
    let cfg = McConfig::random(10).from_env();
    std::env::remove_var("FASTGAUSS_MC_SEED");
    std::env::remove_var("FASTGAUSS_MC_SCHEDULES");
    assert_eq!(cfg.seed, 0xdead_beef);
    assert_eq!(cfg.max_schedules, 12345);
}
