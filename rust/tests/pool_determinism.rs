//! Determinism suite for the shared work-stealing pool (PR 5): the
//! batch ≡ sequential bitwise guarantee and sweep-table bit-identity
//! across pool widths {1, 2, 8}, plus a stats-based check that nested
//! parallelism actually engages more workers than requests.
//!
//! Why these hold at all: the traversal cuts the query tree into a
//! *fixed* task set (a function of the tree, never of the pool width)
//! and every fan-out reduces its partial results by task index — so
//! scheduling and stealing can change wall-clock time but not a single
//! bit of any result.
//!
//! Scope: the suite covers the deterministic engines (Naive, the
//! dual-tree family, Auto which only resolves to those, and FGT's
//! τ-halving). IFGT is deliberately excluded — its K-doubling tuning
//! stops on a wall-clock budget, so its answers are ε-verified but
//! inherently timing-dependent at any pool width (documented in
//! DESIGN.md and `SweepConfig::workers`).

use fastgauss::api::{EvalRequest, Method, Precision, PrepareOptions, Session, SimdMode};
use fastgauss::coordinator::{run_sweep, AlgoSpec, SweepConfig};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kde::lscv::select_bandwidth_session;
use fastgauss::kernel::Kernel;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn prepared(data: &fastgauss::geometry::Matrix, threads: usize) -> Session<'_> {
    Session::prepare(data, PrepareOptions { threads, ..Default::default() })
}

/// evaluate_batch on a session of ANY pool width {1, 2, 8} must equal
/// sequential evaluation on an inline-pool session bit-for-bit — for
/// dual-tree methods (pool-width-invariant traversal), Naive (truth
/// memo) and Auto (deterministic resolution) alike.
#[test]
fn batch_bitwise_equals_sequential_across_widths_1_2_8() {
    let data = data::by_name("astro2d", 400, 17).unwrap().points;
    let h_star = silverman(&data);
    let requests: Vec<EvalRequest<'static>> = [0.1, 1.0, 10.0]
        .iter()
        .flat_map(|&m| {
            [Method::Dito, Method::Dfdo, Method::Dfd, Method::Naive, Method::Auto]
                .into_iter()
                .map(move |method| EvalRequest::kde(m * h_star, 0.01).with_method(method))
        })
        .collect();

    let sequential = prepared(&data, 1);
    let want: Vec<_> = requests.iter().map(|r| sequential.evaluate(r).unwrap()).collect();

    for threads in THREAD_COUNTS {
        let session = prepared(&data, threads);
        assert_eq!(session.pool().workers(), threads.max(1));
        let batch = session.evaluate_batch(&requests);
        assert_eq!(batch.len(), requests.len(), "threads={threads}: lost requests");
        for ((req, got), want) in requests.iter().zip(batch).zip(&want) {
            let got = got.unwrap();
            assert_eq!(
                got.sums, want.sums,
                "threads={threads} h={} {}: batch diverged from sequential",
                req.h, req.method
            );
            assert_eq!(got.method, want.method);
            // merged traversal counters are part of the guarantee too
            assert_eq!(got.stats.node_pairs, want.stats.node_pairs);
            assert_eq!(got.stats.base_point_pairs, want.stats.base_point_pairs);
            assert_eq!(
                got.stats.tokens_banked.to_bits(),
                want.stats.tokens_banked.to_bits(),
                "threads={threads}: stats reduction must be order-fixed"
            );
        }
    }
}

/// Whole sweep tables — outcomes and verified errors, the bits the
/// paper table is rendered from — are identical across worker counts
/// {1, 2, 8}.
#[test]
fn sweep_tables_bit_identical_across_workers_1_2_8() {
    let run = |workers: usize| {
        let ds = data::by_name("astro2d", 300, 23).unwrap();
        let h_star = silverman(&ds.points);
        run_sweep(&SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star,
            multipliers: vec![0.1, 1.0, 10.0],
            algorithms: vec![AlgoSpec::Naive, AlgoSpec::Dfd, AlgoSpec::Dito, AlgoSpec::Auto],
            workers,
            leaf_size: 16,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
        })
    };
    let base = run(1);
    assert_eq!(base.cells.len(), 12);
    for workers in [2, 8] {
        let table = run(workers);
        assert_eq!(table.cells.len(), base.cells.len(), "workers={workers}");
        for (a, b) in base.cells.iter().zip(&table.cells) {
            assert_eq!(
                (a.algo_index, a.bandwidth_index),
                (b.algo_index, b.bandwidth_index),
                "workers={workers}: cell order must be fixed"
            );
            // verified errors bitwise (f64), outcomes same kind
            // (timings legitimately differ)
            assert_eq!(
                a.rel_err.map(f64::to_bits),
                b.rel_err.map(f64::to_bits),
                "workers={workers} cell ({}, {})",
                a.algo_index,
                a.bandwidth_index
            );
            assert_eq!(
                std::mem::discriminant(&a.outcome),
                std::mem::discriminant(&b.outcome),
                "workers={workers}: outcome kind changed"
            );
        }
    }
}

/// LSCV bandwidth selection — the paper's end-to-end workload — picks
/// the same h* with the same scores on every pool width.
#[test]
fn lscv_selection_identical_across_widths() {
    let data = data::by_name("galaxy3d", 250, 29).unwrap().points;
    let pilot = silverman(&data);
    let grid: Vec<f64> = (0..5).map(|i| pilot * 0.25 * (i + 1) as f64).collect();
    let base_session = prepared(&data, 1);
    let (h_base, scores_base) =
        select_bandwidth_session(&base_session, &grid, 1e-4, Method::Dito).unwrap();
    for threads in [2, 8] {
        let session = prepared(&data, threads);
        let (h, scores) = select_bandwidth_session(&session, &grid, 1e-4, Method::Dito).unwrap();
        assert_eq!(h, h_base, "threads={threads}");
        assert_eq!(scores, scores_base, "threads={threads}: scores diverged");
    }
}

/// The undersubscription fix, observed through pool telemetry: a
/// 2-request batch on an 8-worker session engages MORE than 2 workers,
/// because each request fans its traversal tasks into the shared pool
/// (the old model pinned each request to one inner thread, so exactly
/// min(workers, requests) = 2 threads ever did work). Stats-based: we
/// union engaged workers over a few repetitions to be robust to
/// scheduling noise.
#[test]
fn two_request_batch_engages_more_than_two_workers() {
    let data = data::by_name("astro2d", 2000, 31).unwrap().points;
    let h_star = silverman(&data);
    let session = prepared(&data, 8);
    let requests = [
        EvalRequest::kde(0.5 * h_star, 0.01).with_method(Method::Dito),
        EvalRequest::kde(1.5 * h_star, 0.01).with_method(Method::Dito),
    ];
    let mut engaged = 0;
    for _ in 0..10 {
        for res in session.evaluate_batch(&requests) {
            res.unwrap();
        }
        engaged = session.pool().worker_task_counts().iter().filter(|&&c| c > 0).count();
        if engaged > 2 {
            break;
        }
    }
    assert!(
        engaged > 2,
        "2 requests × 8 workers must spread beyond 2 workers (engaged {engaged}); \
         nested traversal tasks are not reaching the shared pool"
    );
}
