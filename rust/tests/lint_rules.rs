//! Self-tests for the repo-native invariant linter: every rule family
//! fires on a fixture that violates it, stays quiet on the compliant
//! twin, and the real source tree is pinned at zero findings.

use std::path::Path;

use fastgauss::lint::{
    lint_parity, lint_source, lint_tree, Finding, ParitySources, RULE_LANES, RULE_ORDERING,
    RULE_PANIC, RULE_PARITY, RULE_SAFETY, RULE_SYNC, RULE_THREAD, RULE_WAIVER,
};

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---- safety-comment ----

#[test]
fn unsafe_without_justification_flags_and_commented_unsafe_is_clean() {
    let bad = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let f = lint_source("geometry.rs", bad);
    assert_eq!(rules(&f), vec![RULE_SAFETY]);
    assert_eq!(f[0].line, 2);
    let good = "// SAFETY: the caller upholds the aliasing contract\n\
                fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert!(lint_source("geometry.rs", good).is_empty());
}

#[test]
fn unsafe_inside_comments_and_strings_does_not_count() {
    let src = "// unsafe is discussed here only\nfn f() { let _ = \"unsafe\"; }\n";
    assert!(lint_source("geometry.rs", src).is_empty());
}

// ---- lanes-bypass ----

#[test]
fn hot_kernel_bypass_flags_but_lanes_field_calls_are_clean() {
    let bad = "fn f(xs: &mut [f64]) { fastexp::exp_block(xs); }\n";
    let f = lint_source("algo/new.rs", bad);
    assert_eq!(rules(&f), vec![RULE_LANES]);
    let good = "fn f(l: &Lanes, xs: &mut [f64]) { (l.exp_block)(xs); }\n";
    assert!(lint_source("algo/new.rs", good).is_empty());
    // the defining modules may name their own kernels
    assert!(lint_source("compute/fastexp.rs", bad).is_empty());
    // related-but-distinct identifiers do not match
    let cousin = "fn f() { dot_tile_f32_scalar(); }\n";
    assert!(lint_source("algo/new.rs", cousin).is_empty());
}

// ---- raw-thread ----

#[test]
fn raw_thread_primitives_flag_outside_the_sync_shim() {
    let bad = "fn f() { std::thread::spawn(|| {}); }\n";
    let f = lint_source("algo/new.rs", bad);
    assert_eq!(rules(&f), vec![RULE_THREAD]);
    // the shim layer and the model checker beneath it are the one home
    assert!(lint_source("runtime/sync.rs", bad).is_empty());
    assert!(lint_source("runtime/modelcheck.rs", bad).is_empty());
    // the pool lost its historical exemption when it moved onto the shim
    assert_eq!(rules(&lint_source("runtime/pool.rs", bad)), vec![RULE_THREAD]);
    let waived = "// lint: allow(raw-thread): benchmark needs the pre-pool shape\n\
                  fn f() { std::thread::scope(|s| { let _ = s; }); }\n";
    assert!(lint_source("algo/new.rs", waived).is_empty());
}

// ---- sync-bypass ----

#[test]
fn raw_sync_primitives_flag_outside_the_sync_shim() {
    let bad = "use std::sync::{Condvar, Mutex};\n\
               static GATE: std::sync::atomic::AtomicBool = \
               std::sync::atomic::AtomicBool::new(false);\n\
               fn f() { std::thread::park(); }\n";
    let f = lint_source("algo/new.rs", bad);
    assert_eq!(
        f.iter().filter(|x| x.rule == RULE_SYNC).count(),
        5,
        "Condvar, Mutex, AtomicBool x2, park: {f:?}"
    );
    assert!(lint_source("runtime/sync.rs", bad).is_empty());
    assert!(lint_source("runtime/modelcheck.rs", bad).is_empty());
    // the shim's own re-exported types do not match the needles
    let shimmed = "fn f(m: &SyncMutex<u32>, c: &SyncCondvar) -> u32 { let _ = c; *m.lock().unwrap() }\n";
    assert!(lint_source("algo/new.rs", shimmed).is_empty());
    let waived = "// lint: allow(sync-bypass): one-time init below the runtime layer\n\
                  use std::sync::OnceLock;\n";
    assert!(lint_source("algo/new.rs", waived).is_empty());
    // test modules may use raw primitives as scaffolding
    let in_test = "#[cfg(test)]\nmod tests {\n    use std::sync::Mutex;\n}\n";
    assert!(lint_source("algo/new.rs", in_test).is_empty());
}

// ---- ordering-audit ----

#[test]
fn weak_orderings_require_an_order_comment_within_the_window() {
    let bad = "fn f(a: &S) { a.flag.store(true, Ordering::Release); }\n";
    let f = lint_source("algo/new.rs", bad);
    assert_eq!(rules(&f), vec![RULE_ORDERING]);
    assert!(f[0].message.contains("Release"), "{f:?}");
    let good = "// ORDER: Release — publishes the write before the flag flips.\n\
                fn f(a: &S) { a.flag.store(true, Ordering::Release); }\n";
    assert!(lint_source("algo/new.rs", good).is_empty());
    // SeqCst carries no obligation, and neither do imports
    let seq = "use std::sync::atomic::Ordering::{self, SeqCst};\n\
               fn f(a: &S) { a.flag.store(true, Ordering::SeqCst); }\n";
    assert!(lint_source("algo/new.rs", seq).is_empty());
}

#[test]
fn malformed_or_distant_order_comments_do_not_justify() {
    // missing colon: "ORDER" alone is not the marker
    let no_colon = "// ORDER Release — publishes the write.\n\
                    fn f(a: &S) { a.flag.store(true, Ordering::Release); }\n";
    assert_eq!(rules(&lint_source("algo/new.rs", no_colon)), vec![RULE_ORDERING]);
    // a comment further than the window above the site does not count
    let distant = "// ORDER: Release — publishes the write.\n\n\n\n\n\
                   fn f(a: &S) { a.flag.store(true, Ordering::Release); }\n";
    assert_eq!(rules(&lint_source("algo/new.rs", distant)), vec![RULE_ORDERING]);
    // an explicit waiver still works where a comment is impractical
    let waived = "// lint: allow(ordering-audit): ordering chosen by the caller\n\
                  fn f(a: &S, o: u8) { a.flag.store(true, Ordering::Relaxed); let _ = o; }\n";
    assert!(lint_source("algo/new.rs", waived).is_empty());
}

// ---- no-panic ----

#[test]
fn panic_family_flags_with_blessed_and_waived_exceptions() {
    let bad = "fn f(v: &[u32]) -> u32 { *v.last().expect(\"nonempty\") }\n";
    assert_eq!(rules(&lint_source("algo/new.rs", bad)), vec![RULE_PANIC]);
    let blessed = "fn f(m: &SyncMutex<u32>) -> u32 { *m.lock().unwrap() }\n";
    assert!(lint_source("algo/new.rs", blessed).is_empty());
    // driver modules may abort by design
    assert!(lint_source("cli.rs", bad).is_empty());
    assert!(lint_source("bin/tool.rs", bad).is_empty());
    let waived = "// lint: allow(no-panic): length is checked by the caller\n\
                  fn f(v: &[u32]) -> u32 { *v.last().unwrap() }\n";
    assert!(lint_source("algo/new.rs", waived).is_empty());
    let macro_hit = "fn f() { unreachable!() }\n";
    assert_eq!(rules(&lint_source("algo/new.rs", macro_hit)), vec![RULE_PANIC]);
    // `unwrap_or` and friends are not the panicking form
    let non_panicking = "fn f(v: Option<u32>) -> u32 { v.unwrap_or(0) }\n";
    assert!(lint_source("algo/new.rs", non_panicking).is_empty());
}

#[test]
fn malformed_waiver_is_itself_a_finding_and_does_not_waive() {
    let src = "// lint: allow(no-panic)\nfn f(v: &[u32]) -> u32 { *v.last().unwrap() }\n";
    let f = lint_source("algo/new.rs", src);
    assert!(f.iter().any(|x| x.rule == RULE_WAIVER), "{f:?}");
    assert!(f.iter().any(|x| x.rule == RULE_PANIC), "malformed waiver must not waive: {f:?}");
}

#[test]
fn findings_render_with_clickable_paths() {
    let f = lint_source("algo/new.rs", "fn f() { todo!() }\n");
    assert_eq!(f.len(), 1);
    let line = f[0].to_string();
    assert!(line.starts_with("rust/src/algo/new.rs:1: [no-panic]"), "{line}");
}

// ---- parity ----

const CONFIG_OK: &str = r#"
const VALID_KEYS: [&str; 6] = [
    "workers", "leaf-size", "fast-exp", "simd", "precision", "kernel",
];
"#;

const CLI_OK: &str = r#"
fn usage() {
    let _ = "--workers --leaf-size --fast-exp";
    let _ = "--simd --precision --kernel --help";
}
"#;

const SESSION_OK: &str = r#"
pub struct PrepareOptions {
    pub threads: usize,
    pub leaf_size: usize,
    pub fast_exp: bool,
    pub simd: usize,
    pub precision: usize,
    pub kernel: usize,
}
"#;

#[test]
fn parity_clean_triple_passes() {
    let f = lint_parity(&ParitySources { config: CONFIG_OK, cli: CLI_OK, session: SESSION_OK });
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn parity_gaps_are_flagged_per_surface() {
    // a flag nobody maps
    let cli = CLI_OK.replace("--help", "--help --turbo");
    let f = lint_parity(&ParitySources { config: CONFIG_OK, cli: &cli, session: SESSION_OK });
    assert!(f.iter().any(|x| x.rule == RULE_PARITY && x.message.contains("turbo")), "{f:?}");
    // a field with neither mapping nor internal allowlisting
    let session =
        SESSION_OK.replace("pub kernel: usize,", "pub kernel: usize,\n    pub shadow: bool,");
    let f = lint_parity(&ParitySources { config: CONFIG_OK, cli: CLI_OK, session: &session });
    assert!(f.iter().any(|x| x.message.contains("shadow")), "{f:?}");
    // a mapped key gone missing from the config surface
    let config = CONFIG_OK.replace("\"kernel\",", "");
    let f = lint_parity(&ParitySources { config: &config, cli: CLI_OK, session: SESSION_OK });
    assert!(f.iter().any(|x| x.message.contains("`kernel`")), "{f:?}");
}

// ---- the real tree ----

#[test]
#[cfg_attr(miri, ignore = "walks and lexes the whole source tree")]
fn the_real_tree_is_pinned_at_zero_findings() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let (files, findings) = lint_tree(root).expect("source tree must be readable");
    assert!(files >= 60, "suspiciously few files walked: {files}");
    for f in &findings {
        eprintln!("{f}");
    }
    assert!(findings.is_empty(), "{} findings — see stderr", findings.len());
}
