//! AOT round-trip: for every paper dimension, the PJRT-executed artifact
//! must agree with the pure-rust exhaustive sum to near machine
//! precision, including the padding paths. Requires `make artifacts`;
//! the tests skip (with a note) when artifacts are absent so `cargo
//! test` works on a fresh checkout.

use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::runtime::{artifacts_dir, ArtifactManifest, TiledNaive};

fn have_artifacts() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("NOTE: built without the `pjrt` feature — skipping artifact round-trips");
        return false;
    }
    let ok = artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("NOTE: artifacts missing — run `make artifacts`; skipping");
    }
    ok
}

/// Without `pjrt`, `TiledNaive` must load anyway and round-trip through
/// the CPU compute-microkernel fallback for every paper dimension.
#[cfg(not(feature = "pjrt"))]
#[test]
fn cpu_fallback_round_trips_every_dimension() {
    for (name, _, d) in data::PAPER_SUITE {
        let ds = data::by_name(name, 250, 5).unwrap();
        let h = silverman(&ds.points);
        let problem = GaussSumProblem::kde(&ds.points, h, 0.01);
        let tiled = TiledNaive::load(*d).unwrap();
        assert!(tiled.is_cpu_fallback());
        let got = tiled.run(&problem).unwrap().sums;
        let want = Naive::new().run(&problem).unwrap().sums;
        let rel = max_relative_error(&got, &want);
        assert!(rel < 1e-12, "{name} (D={d}): rel {rel:.2e}");
    }
}

#[test]
fn manifest_covers_all_paper_dims() {
    if !have_artifacts() {
        return;
    }
    let m = ArtifactManifest::load(&artifacts_dir()).unwrap();
    for d in [2usize, 3, 5, 7, 10, 16] {
        let spec = m.spec(d).unwrap_or_else(|| panic!("no artifact for D={d}"));
        assert!(spec.file.exists(), "artifact file missing for D={d}");
    }
}

#[test]
fn every_dimension_round_trips() {
    if !have_artifacts() {
        return;
    }
    for (name, _, d) in data::PAPER_SUITE {
        // sizes straddle the tile boundaries (TQ=256, NR=4096)
        let n = 300;
        let ds = data::by_name(name, n, 5).unwrap();
        let h = silverman(&ds.points);
        let problem = GaussSumProblem::kde(&ds.points, h, 0.01);
        let tiled = TiledNaive::load(*d).unwrap();
        let got = tiled.run(&problem).unwrap().sums;
        let want = Naive::new().run(&problem).unwrap().sums;
        let rel = max_relative_error(&got, &want);
        assert!(rel < 1e-10, "{name} (D={d}): rel {rel:.2e}");
    }
}

#[test]
fn exact_tile_boundary_sizes() {
    if !have_artifacts() {
        return;
    }
    // n exactly at TQ and NR multiples — no padding anywhere
    let ds = data::by_name("astro2d", 256, 6).unwrap();
    let h = 0.1;
    let problem = GaussSumProblem::kde(&ds.points, h, 0.01);
    let tiled = TiledNaive::load(2).unwrap();
    let got = tiled.run(&problem).unwrap().sums;
    let want = Naive::new().run(&problem).unwrap().sums;
    assert!(max_relative_error(&got, &want) < 1e-10);
}

#[test]
fn bichromatic_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let refs = data::by_name("galaxy3d", 900, 7).unwrap();
    let queries = data::by_name("galaxy3d", 123, 8).unwrap();
    let mut rng = fastgauss::util::Pcg32::new(9);
    let w: Vec<f64> = (0..900).map(|_| rng.uniform_in(0.2, 3.0)).collect();
    let problem =
        GaussSumProblem::new(&queries.points, &refs.points, Some(&w), 0.07, 0.01);
    let tiled = TiledNaive::load(3).unwrap();
    let got = tiled.run(&problem).unwrap().sums;
    let want = Naive::new().run(&problem).unwrap().sums;
    assert!(max_relative_error(&got, &want) < 1e-10);
}
