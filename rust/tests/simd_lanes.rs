//! Integration suite for the vector-lane dispatch and the certified
//! mixed-precision tile (PR 7).
//!
//! * `exp_block` — active table vs libm truth on 10⁶ random inputs
//!   spanning the certified domain plus the adversarial seams (every
//!   half-ln2 reduction boundary ± 1 ulp, the underflow edge, ±0),
//!   streamed through odd-sized blocks so lane tails are exercised.
//! * end-to-end lane equivalence — `SimdMode::Auto` and
//!   `SimdMode::Off` sessions both hold ε on the paper datasets, and
//!   agree bitwise whenever runtime detection resolves Auto to the
//!   scalar table (the forced-off / no-AVX2 case).
//! * the f32 tile — ε-correct through the session at ε ∈ {1e-2, 1e-4}
//!   for Naive, DFDO, DITO and FGT, with `split_epsilon_prec`'s ε/4
//!   admission gate observed through the `f32_base_cases` routing
//!   counter: engaged at the loose ε, demoted to the f64 fast tile at
//!   the tight one.
//! * pool widths — batch answers with SIMD *and* f32 on are bitwise
//!   identical across worker counts {1, 2, 8} (the fixed task
//!   decomposition of PR 5 survives the lane kernels).

use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::api::{EvalRequest, Method, Precision, PrepareOptions, Session, SimdMode};
use fastgauss::compute::fastexp::{EXP_MAX_REL_ERR, EXP_UNDERFLOW_X};
use fastgauss::compute::simd::{self, Backend};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::util::Pcg32;

/// A nonzero float and its two 1-ulp neighbours — the adversarial
/// inputs for range-reduction seams.
fn neighbors(x: f64) -> [f64; 3] {
    let b = x.to_bits();
    [x, f64::from_bits(b + 1), f64::from_bits(b.wrapping_sub(1))]
}

#[test]
#[cfg_attr(miri, ignore = "10^6-input sweep; the small-block variant covers the interpreter")]
fn exp_block_certified_on_a_million_random_and_seam_inputs() {
    let mut rng = Pcg32::new(20_260_808);
    let mut xs: Vec<f64> = (0..1_000_000).map(|_| -750.0 + 751.0 * rng.uniform()).collect();
    // every half-ln2 multiple in (and just below) the certified
    // domain, ± 1 ulp: the `k = round(x/ln2)` reduction boundaries
    // where the polynomial argument |r| peaks
    let half_ln2 = 0.5 * std::f64::consts::LN_2;
    for m in -2046..0 {
        xs.extend(neighbors(m as f64 * half_ln2));
    }
    xs.extend(neighbors(EXP_UNDERFLOW_X));
    xs.extend([0.0, -0.0, 1.0, -1e-300, -709.0, -745.0, -750.0]);

    let mut got_active = xs.clone();
    let mut got_scalar = xs.clone();
    // odd block size: every call ends in a lane tail on any backend
    for chunk in got_active.chunks_mut(1021) {
        (simd::active().exp_block)(chunk);
    }
    (simd::scalar().exp_block)(&mut got_scalar);
    for (j, &x) in xs.iter().enumerate() {
        for (label, got) in [("active", got_active[j]), ("scalar", got_scalar[j])] {
            if x < EXP_UNDERFLOW_X {
                assert_eq!(got, 0.0, "{label} x={x}: underflow tail must be exactly 0");
            } else {
                let truth = x.exp();
                let rel = (got - truth).abs() / truth;
                assert!(rel <= EXP_MAX_REL_ERR, "{label} x={x}: rel={rel:.2e}");
            }
        }
    }
}

/// Miri-sized shadow of the 10⁶ sweep: a few thousand random inputs
/// plus the domain edges, still streamed through odd-sized blocks.
#[test]
fn exp_block_certified_on_a_small_sample() {
    let mut rng = Pcg32::new(20_260_808);
    let mut xs: Vec<f64> = (0..2_000).map(|_| -750.0 + 751.0 * rng.uniform()).collect();
    let half_ln2 = 0.5 * std::f64::consts::LN_2;
    for m in (-2046..0).step_by(97) {
        xs.extend(neighbors(m as f64 * half_ln2));
    }
    xs.extend(neighbors(EXP_UNDERFLOW_X));
    xs.extend([0.0, -0.0, 1.0, -1e-300, -709.0, -745.0, -750.0]);
    let mut got = xs.clone();
    for chunk in got.chunks_mut(127) {
        (simd::active().exp_block)(chunk);
    }
    for (j, &x) in xs.iter().enumerate() {
        if x < EXP_UNDERFLOW_X {
            assert_eq!(got[j], 0.0, "x={x}: underflow tail must be exactly 0");
        } else {
            let truth = x.exp();
            let rel = (got[j] - truth).abs() / truth;
            assert!(rel <= EXP_MAX_REL_ERR, "x={x}: rel={rel:.2e}");
        }
    }
}

/// Auto and Off sessions both hold the ε guarantee; Off pins the
/// scalar table (recorded in the stats), and when detection resolves
/// Auto to scalar anyway the two runs must be bitwise identical —
/// SimdMode::Off *is* the bit-exact reference, not a different
/// algorithm.
#[test]
#[cfg_attr(miri, ignore = "session e2e is too slow under the interpreter")]
fn auto_and_off_sessions_hold_eps_and_off_pins_the_scalar_table() {
    let eps = 1e-2;
    let h = 0.25;
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, 350, 11).unwrap();
        let problem = GaussSumProblem::kde(&ds.points, h, eps);
        let truth = Naive::new().run(&problem).unwrap().sums;
        let run = |mode: SimdMode| {
            let opts = PrepareOptions { simd: mode, ..Default::default() };
            let session = Session::prepare(&ds.points, opts);
            [Method::Dfdo, Method::Dito].map(|method| {
                let req = EvalRequest::kde(h, eps).with_method(method);
                session.evaluate(&req).unwrap()
            })
        };
        let auto = run(SimdMode::Auto);
        let off = run(SimdMode::Off);
        for (a, o) in auto.iter().zip(&off) {
            let rel_a = max_relative_error(&a.sums, &truth);
            let rel_o = max_relative_error(&o.sums, &truth);
            assert!(rel_a <= eps * (1.0 + 1e-9), "{name} {} auto: {rel_a:.2e}", a.method);
            assert!(rel_o <= eps * (1.0 + 1e-9), "{name} {} off: {rel_o:.2e}", o.method);
            assert_eq!(o.stats.simd_backend, "scalar", "{name}: Off must pin the scalar table");
            assert!(!a.stats.simd_backend.is_empty(), "{name}: fast run must record a backend");
            if simd::active().backend == Backend::Scalar {
                assert_eq!(a.sums, o.sums, "{name}: scalar-resolved Auto diverged from Off");
            }
        }
    }
}

/// The mixed-precision tile end to end: every answer stays inside ε at
/// both tolerances, and the ε/4 admission gate routes exactly as the
/// derived bound predicts — at h = 0.2 on the unit-cube datasets the
/// f32 certificate is ≈1e-4, so it fits ε = 1e-2 (tile engages) and
/// fails ε = 1e-4 (silent demotion to the f64 fast tile).
#[test]
#[cfg_attr(miri, ignore = "session e2e is too slow under the interpreter")]
fn f32_mode_is_eps_correct_and_gated_by_the_reserved_budget() {
    let h = 0.2;
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, 400, 42).unwrap();
        let problem = GaussSumProblem::kde(&ds.points, h, 1e-2);
        let truth = Naive::new().run(&problem).unwrap().sums;
        let opts = PrepareOptions { precision: Precision::F32, ..Default::default() };
        let session = Session::prepare(&ds.points, opts);
        for eps in [1e-2, 1e-4] {
            for method in [Method::Naive, Method::Dfdo, Method::Dito, Method::Fgt] {
                let req = EvalRequest::kde(h, eps).with_method(method);
                let ev = match session.evaluate(&req) {
                    Ok(ev) => ev,
                    // FGT tuning is ε-verified: an unreachable tolerance
                    // is reported, never a silently wrong answer
                    Err(_) if method == Method::Fgt => continue,
                    Err(e) => panic!("{name} {method} ε={eps}: {e}"),
                };
                let rel = max_relative_error(&ev.sums, &truth);
                assert!(rel <= eps * (1.0 + 1e-9), "{name} {} ε={eps}: rel={rel:.2e}", ev.method);
                if method != Method::Dfdo {
                    continue;
                }
                if eps == 1e-2 {
                    assert!(ev.stats.f32_base_cases > 0, "{name}: f32 tile never engaged");
                    let backend = ev.stats.simd_backend;
                    assert!(!backend.is_empty(), "{name}: backend unrecorded on the fast path");
                } else {
                    assert_eq!(ev.stats.f32_base_cases, 0, "{name}: gate failed to demote");
                    assert!(ev.stats.fast_base_cases > 0, "{name}: f64 fast tile not used");
                }
            }
        }
    }
}

/// Worker counts {1, 2, 8} with SIMD and the f32 tile both on: sums,
/// routing counters and the recorded backend are bitwise identical —
/// the lane kernels live inside the fixed task decomposition, so
/// scheduling still cannot change a single bit.
#[test]
#[cfg_attr(miri, ignore = "multi-width batch e2e is too slow under the interpreter")]
fn batch_answers_bitwise_invariant_across_pool_widths_with_lanes_on() {
    let data = data::by_name("astro2d", 500, 17).unwrap().points;
    let h_star = silverman(&data);
    let requests: Vec<EvalRequest<'static>> = [0.5, 1.0, 2.0]
        .iter()
        .flat_map(|&m| {
            [Method::Dfdo, Method::Dito]
                .into_iter()
                .map(move |method| EvalRequest::kde(m * h_star, 1e-2).with_method(method))
        })
        .collect();
    let prep = |threads: usize| {
        let opts = PrepareOptions {
            threads,
            simd: SimdMode::Auto,
            precision: Precision::F32,
            ..Default::default()
        };
        Session::prepare(&data, opts)
    };
    let base = prep(1);
    let want: Vec<_> = requests.iter().map(|r| base.evaluate(r).unwrap()).collect();
    for threads in [2usize, 8] {
        let session = prep(threads);
        for (got, want) in session.evaluate_batch(&requests).into_iter().zip(&want) {
            let got = got.unwrap();
            assert_eq!(got.sums, want.sums, "threads={threads}: lanes broke pool invariance");
            assert_eq!(got.stats.f32_base_cases, want.stats.f32_base_cases);
            assert_eq!(got.stats.simd_backend, want.stats.simd_backend);
        }
    }
}
