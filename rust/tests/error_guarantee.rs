//! The paper's central correctness claim, end-to-end: every dual-tree
//! algorithm automatically achieves the user's relative tolerance
//! ∀q |G̃(q)−G(q)| ≤ ε·G(q), on every dataset family, across the whole
//! bandwidth range of the cross-validation sweep — plus the kernel
//! layer's extension of it: non-Gaussian kernels answered through the
//! certified sum-of-Gaussians decomposition satisfy the weight-scaled
//! absolute guarantee ∀q |K̃(q)−K(q)| ≤ ε·W against the exhaustive
//! true-kernel sum.

use fastgauss::algo::{
    dfd::Dfd, dfdo::Dfdo, dfto::Dfto, dito::Dito, max_relative_error, max_weight_scaled_error,
    naive::Naive, GaussSum, GaussSumProblem,
};
use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::data;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::Kernel;

const N: usize = 400;
const EPS: f64 = 0.01;

fn engines() -> Vec<Box<dyn GaussSum>> {
    vec![
        Box::new(Dfd::new()),
        Box::new(Dfdo::new()),
        Box::new(Dfto::new()),
        Box::new(Dito::default()),
    ]
}

fn check_dataset(name: &str, multipliers: &[f64]) {
    let ds = data::by_name(name, N, 2024).unwrap();
    let pilot = silverman(&ds.points);
    for &m in multipliers {
        let h = pilot * m;
        let problem = GaussSumProblem::kde(&ds.points, h, EPS);
        let exact = Naive::new().run(&problem).unwrap().sums;
        for engine in engines() {
            let out = engine.run(&problem).unwrap();
            let rel = max_relative_error(&out.sums, &exact);
            assert!(
                rel <= EPS * (1.0 + 1e-9),
                "{name} {} h={h:.5}: rel {rel:.2e} > {EPS}",
                engine.name()
            );
        }
    }
}

// Full 10^-3..10^3 sweep on the low-D sets (fast), pruned sweep on the
// high-D ones to keep test time sane.
#[test]
fn astro2d_full_sweep() {
    check_dataset("astro2d", &[1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3]);
}

#[test]
fn galaxy3d_full_sweep() {
    check_dataset("galaxy3d", &[1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3]);
}

#[test]
fn bio5_sweep() {
    check_dataset("bio5", &[1e-2, 1.0, 1e2]);
}

#[test]
fn pall7_sweep() {
    check_dataset("pall7", &[1e-2, 1.0, 1e2]);
}

#[test]
fn covtype10_sweep() {
    check_dataset("covtype10", &[1e-1, 1.0, 1e1]);
}

#[test]
fn texture16_sweep() {
    check_dataset("texture16", &[1e-1, 1.0, 1e1]);
}

#[test]
fn tighter_tolerances_also_hold() {
    let ds = data::by_name("astro2d", 300, 7).unwrap();
    let pilot = silverman(&ds.points);
    for eps in [1e-3, 1e-5] {
        let problem = GaussSumProblem::kde(&ds.points, pilot, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        for engine in engines() {
            let out = engine.run(&problem).unwrap();
            let rel = max_relative_error(&out.sums, &exact);
            assert!(rel <= eps * (1.0 + 1e-9), "{} eps={eps}: {rel:.2e}", engine.name());
        }
    }
}

#[test]
fn weighted_problems_hold() {
    let ds = data::by_name("galaxy3d", 300, 8).unwrap();
    let mut rng = fastgauss::util::Pcg32::new(9);
    let w: Vec<f64> = (0..300).map(|_| rng.uniform_in(0.1, 5.0)).collect();
    let h = silverman(&ds.points);
    let problem = GaussSumProblem::new(&ds.points, &ds.points, Some(&w), h, EPS);
    let exact = Naive::new().run(&problem).unwrap().sums;
    for engine in engines() {
        let out = engine.run(&problem).unwrap();
        let rel = max_relative_error(&out.sums, &exact);
        assert!(rel <= EPS * (1.0 + 1e-9), "{}: {rel:.2e}", engine.name());
    }
}

// ---- the kernel layer's guarantee: every non-Gaussian family on
// astro2d and galaxy3d, at ε ∈ {1e-2, 1e-4}, via the exhaustive
// engine AND a tree-based one (plus Auto), all verified against the
// exhaustive true-kernel sum ----

fn check_sog(dataset: &str, kernel: Kernel) {
    let ds = data::by_name(dataset, 300, 31).unwrap();
    let scale = silverman(&ds.points);
    let session = Session::prepare(
        &ds.points,
        PrepareOptions { kernel, threads: 2, ..Default::default() },
    );
    let w = session.total_weight();
    for eps in [1e-2, 1e-4] {
        let (exact, _, _) = session
            .exact_kernel_sums(kernel, scale, eps)
            .unwrap_or_else(|e| panic!("{dataset} {kernel} truth: {e}"));
        for m in [Method::Naive, Method::Dfdo, Method::Auto] {
            let req = EvalRequest::kde(scale, eps).with_method(m);
            let ev = session.evaluate(&req).unwrap_or_else(|e| {
                panic!("{dataset} {kernel} {} eps={eps}: {e}", m.name())
            });
            let err = max_weight_scaled_error(&ev.sums, &exact, w);
            assert!(
                err <= eps * (1.0 + 1e-9),
                "{dataset} {kernel} {} eps={eps}: scaled err {err:.2e}",
                m.name()
            );
            // the certificate trail: components exist, every one was
            // routed to a concrete paper method, and the decomposition
            // charge respected the ε/4 gate
            let report = ev.sog.as_ref().expect("non-Gaussian answers carry a SoG report");
            assert!(ev.stats.sog_components > 0, "{dataset} {kernel}: no SoG fan-out");
            assert_eq!(
                ev.stats.sog_routed.iter().sum::<u64>(),
                ev.stats.sog_components,
                "{dataset} {kernel}: routing must account for every component"
            );
            assert_eq!(report.components.len() as u64, ev.stats.sog_components);
            assert!(
                report.components.iter().all(|c| c.method != Method::Auto),
                "{dataset} {kernel}: per-component routes must be concrete"
            );
            assert!(
                report.decomp_err <= 0.25 * eps,
                "{dataset} {kernel}: decomp_err {:.2e} breaks the ε/4 gate",
                report.decomp_err
            );
        }
    }
}

#[test]
fn sog_laplace_astro2d() {
    check_sog("astro2d", Kernel::Laplace);
}

#[test]
fn sog_laplace_galaxy3d() {
    check_sog("galaxy3d", Kernel::Laplace);
}

#[test]
fn sog_matern32_astro2d() {
    check_sog("astro2d", Kernel::Matern32);
}

#[test]
fn sog_matern32_galaxy3d() {
    check_sog("galaxy3d", Kernel::Matern32);
}

#[test]
fn sog_matern52_astro2d() {
    check_sog("astro2d", Kernel::Matern52);
}

#[test]
fn sog_matern52_galaxy3d() {
    check_sog("galaxy3d", Kernel::Matern52);
}

#[test]
fn sog_imq_astro2d() {
    check_sog("astro2d", Kernel::InvMultiquadric);
}

#[test]
fn sog_imq_galaxy3d() {
    check_sog("galaxy3d", Kernel::InvMultiquadric);
}

#[test]
fn series_methods_actually_fire_where_paper_says() {
    // D=2 large bandwidth: DITO must be pruning via expansions, not
    // just finite differences (otherwise we've built DFD twice)
    let ds = data::by_name("astro2d", 1000, 10).unwrap();
    let h = silverman(&ds.points) * 100.0;
    let problem = GaussSumProblem::kde(&ds.points, h, EPS);
    let out = Dito::default().run(&problem).unwrap();
    let series = out.stats.dh_prunes + out.stats.dl_prunes + out.stats.h2l_prunes;
    assert!(series > 0, "no series prunes at large h: {:?}", out.stats);
}
