//! Property suite for the monomorphization refactor: each type-level
//! dual-tree variant must match the runtime-switch interface (the
//! `DualTreeConfig`-driven engine that predates the refactor) within
//! 1e-12, and meet the ε guarantee against exhaustive truth — on the
//! paper datasets (astro2d, galaxy3d) and on random monochromatic and
//! bichromatic problems, across ε ∈ {1e-2, 1e-4, 1e-6}.

use fastgauss::algo::dualtree::{
    run_dualtree, run_dualtree_variant, DualTreeConfig, NoExpansion, OdpGraded, OpdGrid,
    SeriesKind, Theorem2, TokenLedger,
};
use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::geometry::Matrix;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::util::Pcg32;

const EPSILONS: [f64; 3] = [1e-2, 1e-4, 1e-6];

/// Max relative deviation between two result vectors (vs the second).
fn max_rel_dev(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / y.abs().max(1e-300))
        .fold(0.0, f64::max)
}

/// Run all four paper variants on `problem` through both interfaces and
/// check: (1) type-level ≡ config-dispatch within 1e-12 (they are the
/// same monomorphized code, so this is a bitwise regression harness for
/// the dispatch layer), (2) ε guarantee vs `exact`.
fn check_all_variants(problem: &GaussSumProblem<'_>, exact: &[f64], label: &str) {
    let cases: [(&str, DualTreeConfig); 4] = [
        (
            "DFD",
            DualTreeConfig { use_tokens: false, series: None, ..Default::default() },
        ),
        (
            "DFDO",
            DualTreeConfig { use_tokens: true, series: None, ..Default::default() },
        ),
        (
            "DFTO",
            DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() },
        ),
        ("DITO", DualTreeConfig::default()),
    ];
    for (name, cfg) in cases {
        let via_cfg = run_dualtree(problem, &cfg).unwrap();
        let via_type = match name {
            "DFD" => run_dualtree_variant::<NoExpansion, Theorem2>(problem, 32, None),
            "DFDO" => run_dualtree_variant::<NoExpansion, TokenLedger>(problem, 32, None),
            "DFTO" => run_dualtree_variant::<OpdGrid, TokenLedger>(problem, 32, None),
            _ => run_dualtree_variant::<OdpGraded, TokenLedger>(problem, 32, None),
        }
        .unwrap();
        let dev = max_rel_dev(&via_type.sums, &via_cfg.sums);
        assert!(
            dev <= 1e-12,
            "{label} {name} eps={}: type-level vs config dispatch diverged by {dev:.2e}",
            problem.epsilon
        );
        let rel = max_relative_error(&via_cfg.sums, exact);
        assert!(
            rel <= problem.epsilon * (1.0 + 1e-9),
            "{label} {name}: rel {rel:.2e} > eps {}",
            problem.epsilon
        );
    }
}

#[test]
fn paper_datasets_all_variants_all_epsilons() {
    for (name, n) in [("astro2d", 600), ("galaxy3d", 450)] {
        let ds = data::by_name(name, n, 42).unwrap();
        let h = silverman(&ds.points);
        for eps in EPSILONS {
            let problem = GaussSumProblem::kde(&ds.points, h, eps);
            let exact = Naive::new().run(&problem).unwrap().sums;
            check_all_variants(&problem, &exact, name);
        }
    }
}

#[test]
fn random_monochromatic_all_variants_all_epsilons() {
    let mut rng = Pcg32::new(2024);
    let data = Matrix::from_rows(
        &(0..400)
            .map(|i| {
                // two blobs + a uniform background
                match i % 3 {
                    0 => vec![0.3 + 0.05 * rng.normal(), 0.3 + 0.05 * rng.normal()],
                    1 => vec![0.7 + 0.05 * rng.normal(), 0.8 + 0.05 * rng.normal()],
                    _ => vec![rng.uniform(), rng.uniform()],
                }
            })
            .collect::<Vec<_>>(),
    );
    for h in [0.05, 0.5] {
        for eps in EPSILONS {
            let problem = GaussSumProblem::kde(&data, h, eps);
            let exact = Naive::new().run(&problem).unwrap().sums;
            check_all_variants(&problem, &exact, "random-mono");
        }
    }
}

#[test]
fn random_bichromatic_weighted_all_variants_all_epsilons() {
    let mut rng = Pcg32::new(2025);
    let refs = Matrix::from_rows(
        &(0..350)
            .map(|_| (0..3).map(|_| rng.uniform()).collect())
            .collect::<Vec<_>>(),
    );
    let queries = Matrix::from_rows(
        &(0..90)
            .map(|_| (0..3).map(|_| rng.uniform_in(-0.2, 1.2)).collect())
            .collect::<Vec<_>>(),
    );
    let w: Vec<f64> = (0..350).map(|_| rng.uniform_in(0.2, 2.5)).collect();
    for eps in EPSILONS {
        let problem = GaussSumProblem::new(&queries, &refs, Some(&w), 0.25, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        check_all_variants(&problem, &exact, "random-bichromatic");
    }
}
