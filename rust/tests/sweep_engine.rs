//! Regression suite for the two-phase [`SweepEngine`]: a prepared
//! engine must reproduce the per-h rebuild path exactly, build its
//! kd-tree exactly once per sweep, and keep the ε guarantee when the
//! sweep is multi-threaded.

use fastgauss::algo::dualtree::{run_dualtree, DualTreeConfig, SeriesKind, SweepEngine};
use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::kde::bandwidth::{log_grid, silverman};
use fastgauss::kde::lscv::select_bandwidth_engine;

const EPS: f64 = 0.01;

/// The headline regression: across a 7-point log grid, a prepared
/// engine's sums are identical (within 1e-12) to rebuilding the tree at
/// every h via `run_dualtree` — and the engine built its tree once.
#[test]
fn engine_matches_per_h_rebuilds_on_paper_datasets() {
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, 400, 2024).unwrap();
        let pilot = silverman(&ds.points);
        let grid = log_grid(pilot, 1e-3, 1e3, 7);
        let engine = SweepEngine::for_kde(&ds.points, 32);
        let cfg = DualTreeConfig::default();
        for &h in &grid {
            let problem = GaussSumProblem::kde(&ds.points, h, EPS);
            let rebuilt = run_dualtree(&problem, &cfg).unwrap();
            let prepared = engine.evaluate(h, EPS, &cfg).unwrap();
            assert_eq!(rebuilt.sums.len(), prepared.sums.len());
            for (a, b) in rebuilt.sums.iter().zip(&prepared.sums) {
                assert!(
                    (a - b).abs() <= 1e-12 * a.abs().max(1.0),
                    "{name} h={h:.4e}: {a} vs {b}"
                );
            }
            // per-h rebuild reports its builds; the engine reports none
            assert!(rebuilt.stats.tree_builds >= 1);
            assert_eq!(prepared.stats.tree_builds, 0);
        }
        // exactly one kd-tree construction for the whole 7-point sweep
        assert_eq!(engine.tree_builds(), 1, "{name}");
        assert!(engine.build_secs() >= 0.0);
    }
}

/// evaluate_grid (the multi-threaded sweep) performs one build total
/// and meets the ε guarantee at every grid point.
#[test]
fn threaded_grid_sweep_builds_once_and_verifies() {
    let ds = data::by_name("astro2d", 500, 7).unwrap();
    let pilot = silverman(&ds.points);
    let grid = log_grid(pilot, 1e-2, 1e2, 7);
    let engine = SweepEngine::for_kde(&ds.points, 32).with_threads(4);
    let cfg = DualTreeConfig::default();
    let results = engine.evaluate_grid(&grid, EPS, &cfg).unwrap();
    assert_eq!(results.len(), grid.len());
    assert_eq!(engine.tree_builds(), 1);
    for (res, &h) in results.iter().zip(&grid) {
        let problem = GaussSumProblem::kde(&ds.points, h, EPS);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let rel = max_relative_error(&res.sums, &exact);
        assert!(rel <= EPS * (1.0 + 1e-9), "h={h:.4e}: rel={rel:.2e}");
        assert_eq!(res.stats.tree_builds, 0);
    }
}

/// Subtree-parallel evaluation keeps the guarantee for every variant
/// the paper's table runs (DFD / DFDO / DFTO / DITO settings).
#[test]
fn parallel_evaluate_guarantee_all_variants() {
    let ds = data::by_name("galaxy3d", 400, 11).unwrap();
    let pilot = silverman(&ds.points);
    let engine = SweepEngine::for_kde(&ds.points, 16).with_threads(3);
    let variants = [
        DualTreeConfig { use_tokens: false, series: None, ..Default::default() },
        DualTreeConfig { use_tokens: true, series: None, ..Default::default() },
        DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() },
        DualTreeConfig::default(),
    ];
    for mult in [0.1, 1.0, 10.0] {
        let h = pilot * mult;
        let problem = GaussSumProblem::kde(&ds.points, h, EPS);
        let exact = Naive::new().run(&problem).unwrap().sums;
        for cfg in &variants {
            let res = engine.evaluate(h, EPS, cfg).unwrap();
            let rel = max_relative_error(&res.sums, &exact);
            assert!(rel <= EPS * (1.0 + 1e-9), "h={h:.4e} cfg={cfg:?}: rel={rel:.2e}");
        }
    }
    assert_eq!(engine.tree_builds(), 1);
}

/// The engine-based LSCV sweep touches tree construction once and
/// agrees with DITO-over-rebuilds on the selected bandwidth.
#[test]
fn lscv_engine_sweep_one_build_and_consistent() {
    let ds = data::by_name("astro2d", 300, 5).unwrap();
    let pilot = silverman(&ds.points);
    let grid = log_grid(pilot, 0.1, 10.0, 7);
    let engine = SweepEngine::for_kde(&ds.points, 32).with_threads(2);
    let (h_engine, scores) =
        select_bandwidth_engine(&engine, &grid, 1e-4, &DualTreeConfig::default()).unwrap();
    assert_eq!(scores.len(), 7);
    assert_eq!(engine.tree_builds(), 1);
    let (h_rebuild, _) = fastgauss::kde::lscv::select_bandwidth(
        &ds.points,
        &grid,
        1e-4,
        &fastgauss::algo::dito::Dito::default(),
    )
    .unwrap();
    assert_eq!(h_engine, h_rebuild);
}
