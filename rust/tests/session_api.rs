//! Session front-door integration: session-vs-oneshot equivalence for
//! every `Method`, golden `Auto` selections, and the built-once /
//! reused-everywhere contract of the lazy session state.

use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::algo::dualtree::run_dualtree;
use fastgauss::algo::fgt::Fgt;
use fastgauss::algo::ifgt::ifgt_tuning_loop;
use fastgauss::algo::naive::Naive;
use fastgauss::algo::{max_relative_error, GaussSum, GaussSumProblem};
use fastgauss::data;
use fastgauss::geometry::Matrix;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::util::Pcg32;

fn dataset(name: &str, n: usize) -> Matrix {
    data::by_name(name, n, 21).unwrap().points
}

/// (a) On astro2d and galaxy3d, the session answer for every
/// deterministic method equals the pre-session one-shot path exactly
/// (the 1e-12 equivalence budget is met with room to spare: the code
/// paths are the same monomorphized functions).
#[test]
fn session_matches_oneshot_for_naive_and_dual_tree() {
    for name in ["astro2d", "galaxy3d"] {
        let data = dataset(name, 400);
        let h_star = silverman(&data);
        let session = Session::kde(&data);
        for mult in [0.1, 1.0, 10.0] {
            let h = h_star * mult;
            let problem = GaussSumProblem::kde(&data, h, 0.01);
            for method in [Method::Naive, Method::Dfd, Method::Dfdo, Method::Dfto, Method::Dito]
            {
                let ev = session
                    .evaluate(&EvalRequest::kde(h, 0.01).with_method(method))
                    .unwrap();
                assert_eq!(ev.method, method);
                let oneshot = match method {
                    Method::Naive => Naive::new().run(&problem).unwrap().sums,
                    m => {
                        let cfg = m.dual_tree_config(32, None).unwrap();
                        run_dualtree(&problem, &cfg).unwrap().sums
                    }
                };
                assert_eq!(
                    ev.sums, oneshot,
                    "{name} h={h}: session {method} diverged from one-shot"
                );
            }
        }
        assert_eq!(session.tree_builds(), 1, "{name}: one build for all methods × h");
    }
}

/// (a) FGT: the session's built-in τ-halving must reproduce the paper
/// protocol (the loop the coordinator used to own) bit-for-bit, and
/// come back ε-verified.
#[test]
fn session_matches_oneshot_fgt_protocol() {
    for name in ["astro2d", "galaxy3d"] {
        let data = dataset(name, 350);
        let h = silverman(&data);
        let eps = 0.01;
        let session = Session::kde(&data);
        let ev = session.evaluate(&EvalRequest::kde(h, eps).with_method(Method::Fgt)).unwrap();
        assert!(ev.rel_err.unwrap() <= eps * (1.0 + 1e-9), "{name}: unverified FGT answer");
        // replicate the paper protocol by hand
        let problem = GaussSumProblem::kde(&data, h, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let mut tau = eps;
        let manual = loop {
            let r = Fgt::new(tau).run(&problem).unwrap();
            if max_relative_error(&r.sums, &exact) <= eps * (1.0 + 1e-9) {
                break r.sums;
            }
            tau *= 0.5;
        };
        assert_eq!(ev.sums, manual, "{name}: session FGT diverged from the manual protocol");
    }
}

/// (a) FGT failure modes surface as the paper's X through the session.
#[test]
fn session_fgt_propagates_ram_exhaustion() {
    let data = dataset("astro2d", 200);
    let session = Session::kde(&data);
    let err = session
        .evaluate(&EvalRequest::kde(1e-5, 0.01).with_method(Method::Fgt))
        .unwrap_err();
    assert!(err.to_string().contains('X'), "{err}");
}

/// (a) IFGT: the session's K-doubling equals the standalone tuning
/// loop on the same problem (same rounds, same plans, same result).
#[test]
fn session_matches_oneshot_ifgt_protocol() {
    let data = dataset("astro2d", 300);
    let eps = 0.01;
    let h = 2.0; // large bandwidth: tuning converges in the early rounds
    let session = Session::kde(&data);
    let ev = session.evaluate(&EvalRequest::kde(h, eps).with_method(Method::Ifgt)).unwrap();
    assert!(ev.rel_err.unwrap() <= eps, "unverified IFGT answer");
    let problem = GaussSumProblem::kde(&data, h, eps);
    let exact = Naive::new().run(&problem).unwrap().sums;
    let (manual, _params) = ifgt_tuning_loop(&problem, &exact, 8, 60.0).unwrap();
    assert_eq!(ev.sums, manual.sums, "session IFGT diverged from the manual protocol");
}

/// (b) Golden `Auto` selections. The h-to-scale ratio equals the
/// Silverman factor (4/((D+2)n))^(1/(D+4)) exactly (the data spread
/// cancels), so these pins are deterministic for any seed.
#[test]
fn auto_selection_goldens() {
    let eps = 0.01;
    // low-D, mid-size: the paper's regimes
    let data = dataset("astro2d", 1000);
    let h_star = silverman(&data);
    let session = Session::kde(&data);
    let resolve = |h: f64| session.resolve(&EvalRequest::kde(h, eps));
    assert_eq!(resolve(1e-3 * h_star), Method::Dfdo, "low-D tiny h → FD-only");
    assert_eq!(resolve(h_star), Method::Dito, "low-D mid h → the paper's algorithm");
    assert_eq!(resolve(1e3 * h_star), Method::Dfdo, "low-D huge h → FD-only");
    // high-D: DITO holds the middle band, FD-only takes tiny h
    let hi = dataset("texture16", 600);
    let hi_star = silverman(&hi);
    let hi_session = Session::kde(&hi);
    assert_eq!(
        hi_session.resolve(&EvalRequest::kde(hi_star, eps)),
        Method::Dito,
        "high-D mid h → DITO"
    );
    assert_eq!(
        hi_session.resolve(&EvalRequest::kde(1e-3 * hi_star, eps)),
        Method::Dfdo,
        "high-D tiny h → FD-only"
    );
    // tiny N: preparation cannot pay for itself
    let small = dataset("astro2d", 100);
    let small_session = Session::kde(&small);
    assert_eq!(
        small_session.resolve(&EvalRequest::kde(silverman(&small), eps)),
        Method::Naive,
        "tiny N → exhaustive"
    );
    // an Auto evaluation reports the resolved method and meets ε
    let ev = session.evaluate(&EvalRequest::kde(h_star, eps)).unwrap();
    assert_eq!(ev.method, Method::Dito);
    let exact = session.exact_sums(h_star, eps).unwrap().0;
    assert!(max_relative_error(&ev.sums, &exact) <= eps * (1.0 + 1e-9));
}

/// (c) Lazy FGT state (grid frame + truth) is built once per session
/// and reused across requests, observable through `RunStats`.
#[test]
fn fgt_session_state_built_once_and_reused() {
    let data = dataset("astro2d", 300);
    let h = silverman(&data);
    let session = Session::kde(&data);
    let req = EvalRequest::kde(h, 0.01).with_method(Method::Fgt);
    let first = session.evaluate(&req).unwrap();
    assert!(first.stats.session_cache_misses >= 1, "first request must build state");
    let second = session.evaluate(&req).unwrap();
    assert_eq!(second.stats.session_cache_misses, 0, "state must be reused, not rebuilt");
    assert!(second.stats.session_cache_hits >= 1);
    assert_eq!(first.sums, second.sums);
}

/// (c) Lazy IFGT clustering plans are built once per (K, seed) and
/// reused across requests (and across tuning rounds within a request).
#[test]
fn ifgt_session_state_built_once_and_reused() {
    let data = dataset("astro2d", 300);
    let session = Session::kde(&data);
    let req = EvalRequest::kde(2.0, 0.01).with_method(Method::Ifgt);
    let first = session.evaluate(&req).unwrap();
    assert!(first.stats.session_cache_misses >= 1, "first request must cluster");
    let second = session.evaluate(&req).unwrap();
    assert_eq!(second.stats.session_cache_misses, 0, "clustering must be reused");
    assert!(second.stats.session_cache_hits >= 1);
    assert_eq!(first.sums, second.sums);
}

/// (c) The exhaustive-truth memo: Naive answers are computed once per
/// bandwidth, then served from the session.
#[test]
fn truth_memo_serves_repeat_naive_requests() {
    let data = dataset("galaxy3d", 250);
    let h = silverman(&data);
    let session = Session::kde(&data);
    let req = EvalRequest::kde(h, 0.01).with_method(Method::Naive);
    let first = session.evaluate(&req).unwrap();
    assert_eq!(first.stats.session_cache_misses, 1);
    assert_eq!(first.rel_err, Some(0.0));
    let second = session.evaluate(&req).unwrap();
    assert_eq!(second.stats.session_cache_hits, 1);
    assert_eq!(second.stats.session_cache_misses, 0);
    assert_eq!(first.sums, second.sums);
    // reported cost is the original compute time, not the lookup
    assert_eq!(first.stats.total_secs, second.stats.total_secs);
}

/// evaluate_batch ≡ sequential evaluate, bit-for-bit, regardless of
/// the session's worker count. Requests now share one work-stealing
/// pool with their nested traversal tasks (no more one-inner-thread
/// pinning); the guarantee survives because the traversal's task
/// decomposition and reduction order are pool-width-invariant.
#[test]
fn batch_matches_sequential_in_any_worker_count() {
    let data = dataset("astro2d", 400);
    let h_star = silverman(&data);
    let sequential = Session::kde(&data); // threads = 1
    let parallel =
        Session::prepare(&data, PrepareOptions { threads: 3, ..Default::default() });
    let reqs: Vec<EvalRequest<'static>> = [0.1, 1.0, 10.0]
        .iter()
        .flat_map(|&m| {
            [Method::Dito, Method::Dfdo, Method::Naive, Method::Auto]
                .into_iter()
                .map(move |method| EvalRequest::kde(m * h_star, 0.01).with_method(method))
        })
        .collect();
    let batch = parallel.evaluate_batch(&reqs);
    assert_eq!(batch.len(), reqs.len());
    for (req, res) in reqs.iter().zip(batch) {
        let got = res.unwrap();
        let want = sequential.evaluate(req).unwrap();
        assert_eq!(got.sums, want.sums, "h={} {}", req.h, req.method);
        assert_eq!(got.method, want.method);
    }
}

/// Bichromatic requests ride on the prepared reference tree: results
/// equal the one-shot paths exactly, with exactly one per-request
/// query-tree build.
#[test]
fn bichromatic_requests_match_oneshot() {
    let mut rng = Pcg32::new(31);
    let refs = dataset("astro2d", 300);
    let queries = Matrix::from_rows(
        &(0..60).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
    );
    let h = silverman(&refs);
    let session = Session::kde(&refs);
    let problem = GaussSumProblem::new(&queries, &refs, None, h, 0.01);

    let naive = session
        .evaluate(&EvalRequest::kde(h, 0.01).with_queries(&queries).with_method(Method::Naive))
        .unwrap();
    assert_eq!(naive.sums, Naive::new().run(&problem).unwrap().sums);

    let dito = session
        .evaluate(&EvalRequest::kde(h, 0.01).with_queries(&queries).with_method(Method::Dito))
        .unwrap();
    let cfg = Method::Dito.dual_tree_config(32, None).unwrap();
    assert_eq!(dito.sums, run_dualtree(&problem, &cfg).unwrap().sums);
    assert_eq!(dito.stats.tree_builds, 1, "one query-tree build per bichromatic request");
    assert_eq!(session.tree_builds(), 1, "the reference tree is never rebuilt");
}

/// Per-request weight overrides stay correct through the documented
/// one-shot fallback.
#[test]
fn weight_override_falls_back_and_matches_oneshot() {
    let mut rng = Pcg32::new(32);
    let data = dataset("astro2d", 250);
    let w: Vec<f64> = (0..250).map(|_| rng.uniform_in(0.5, 1.5)).collect();
    let h = silverman(&data);
    let session = Session::kde(&data);
    let ev = session
        .evaluate(&EvalRequest::kde(h, 0.01).with_weights(&w).with_method(Method::Dito))
        .unwrap();
    let mut problem = GaussSumProblem::new(&data, &data, Some(&w), h, 0.01);
    problem.monochromatic = true;
    let cfg = Method::Dito.dual_tree_config(32, None).unwrap();
    let oneshot = run_dualtree(&problem, &cfg).unwrap();
    assert_eq!(ev.sums, oneshot.sums);
    assert_eq!(ev.stats.tree_builds, 1, "override pays a one-shot build");
    // the weighted answer is ε-correct vs the weighted exhaustive sum
    let exact = Naive::new().run(&problem).unwrap().sums;
    assert!(max_relative_error(&ev.sums, &exact) <= 0.01 * (1.0 + 1e-9));
}

/// plimit overrides thread through to the engine.
#[test]
fn plimit_override_respected_via_session() {
    let data = dataset("astro2d", 300);
    let h = silverman(&data);
    let session = Session::kde(&data);
    let exact = session.exact_sums(h, 0.01).unwrap().0;
    for plimit in [1, 2, 4] {
        let ev = session
            .evaluate(&EvalRequest::kde(h, 0.01).with_method(Method::Dito).with_plimit(plimit))
            .unwrap();
        assert!(
            max_relative_error(&ev.sums, &exact) <= 0.01 * (1.0 + 1e-9),
            "plimit={plimit}"
        );
    }
}
