//! Property suite for the GEMM-shaped base-case pipeline:
//!
//! 1. the certified fast-exp bound holds on 10⁶ random inputs plus the
//!    adversarial cases (range-reduction seams, underflow-to-zero
//!    tail, ±0);
//! 2. the tiled drivers match the scalar reference within 1e-12 across
//!    odd tile shapes, monochromatic and bichromatic;
//! 3. end to end, every method stays ε-correct against exhaustive
//!    truth with fast-exp ON, at ε ∈ {1e-2, 1e-4, 1e-6}.

use fastgauss::algo::{max_relative_error, naive::Naive, GaussSum, GaussSumProblem};
use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
use fastgauss::compute::fastexp::{exp_block, fast_exp, EXP_MAX_REL_ERR, EXP_UNDERFLOW_X};
use fastgauss::compute::{self, reference, Scratch};
use fastgauss::data;
use fastgauss::geometry::Matrix;
use fastgauss::kde::bandwidth::silverman;
use fastgauss::kernel::GaussianKernel;
use fastgauss::util::Pcg32;

fn rel_vs_libm(x: f64) -> f64 {
    let truth = x.exp();
    (fast_exp(x) - truth).abs() / truth
}

// ---- 1. certified fast-exp bound ----

#[test]
#[cfg_attr(miri, ignore = "10^6-input sweep; the small-sample variant covers the interpreter")]
fn fastexp_bound_holds_on_a_million_random_inputs() {
    let mut rng = Pcg32::new(0xFA57E);
    let mut worst = (0.0f64, 0.0f64);
    for i in 0..1_000_000u32 {
        // mix uniform coverage of the full domain with log-uniform
        // coverage of the near-zero regime the kernel visits most
        let x = if i % 2 == 0 {
            rng.uniform_in(EXP_UNDERFLOW_X, 0.0)
        } else {
            -10f64.powf(rng.uniform_in(-12.0, 2.8)) // −1e-12 .. −630
        };
        let rel = rel_vs_libm(x);
        if rel > worst.1 {
            worst = (x, rel);
        }
    }
    assert!(
        worst.1 <= EXP_MAX_REL_ERR,
        "certified bound violated: x = {:.17e} rel = {:.3e}",
        worst.0,
        worst.1
    );
}

/// The Miri-sized shadow of the 10⁶ sweep: same generator and domain
/// mix, few enough samples for the interpreter to chew through.
#[test]
fn fastexp_bound_holds_on_a_small_random_sample() {
    let mut rng = Pcg32::new(0xFA57E);
    for i in 0..2_000u32 {
        let x = if i % 2 == 0 {
            rng.uniform_in(EXP_UNDERFLOW_X, 0.0)
        } else {
            -10f64.powf(rng.uniform_in(-12.0, 2.8))
        };
        let rel = rel_vs_libm(x);
        assert!(rel <= EXP_MAX_REL_ERR, "x = {x:.17e} rel = {rel:.3e}");
    }
}

#[test]
fn fastexp_adversarial_cases() {
    // ±0 → exactly 1
    assert_eq!(fast_exp(0.0), 1.0);
    assert_eq!(fast_exp(-0.0), 1.0);
    // range-reduction seams: k·ln2 and the half-way rounding boundaries
    let ln2 = std::f64::consts::LN_2;
    let ulp_next = |x: f64| f64::from_bits(x.to_bits() + 1);
    let ulp_prev = |x: f64| f64::from_bits(x.to_bits() - 1);
    // sample the seam ladder under the interpreter; walk it natively
    let step = if cfg!(miri) { 43 } else { 1 };
    for k in (1..=1021).step_by(step) {
        for x in [-(k as f64) * ln2, -(k as f64 - 0.5) * ln2] {
            if x < EXP_UNDERFLOW_X {
                continue;
            }
            for v in [x, ulp_next(x), ulp_prev(x)] {
                assert!(rel_vs_libm(v) <= EXP_MAX_REL_ERR, "seam k={k} x={v:.17e}");
            }
        }
    }
    // underflow-to-zero tail: exactly 0.0, monotonically
    for x in [EXP_UNDERFLOW_X - 1e-9, -709.0, -745.0, -1e6, -1e308, f64::MIN] {
        assert_eq!(fast_exp(x), 0.0, "x={x}");
    }
    // just inside the domain: positive and within bound
    assert!(fast_exp(EXP_UNDERFLOW_X) > 0.0);
    assert!(rel_vs_libm(EXP_UNDERFLOW_X) <= EXP_MAX_REL_ERR);
    // tiny magnitudes must not lose to cancellation
    for x in [-1e-300, -1e-100, -1e-30, -4.9e-324] {
        assert_eq!(fast_exp(x), 1.0, "x={x}");
    }
    // block form ≡ scalar form
    let mut xs: Vec<f64> = (0..4096).map(|i| -(i as f64) * 0.173).collect();
    let want: Vec<f64> = xs.iter().map(|&x| fast_exp(x)).collect();
    exp_block(&mut xs);
    assert_eq!(xs, want);
}

// ---- 2. tiled vs scalar equivalence ----

fn random(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_rows(
        &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
    )
}

#[test]
#[cfg_attr(miri, ignore = "full shape grid; the small-shape variant covers the interpreter")]
fn tiled_matches_scalar_across_odd_shapes_mono_and_bichromatic() {
    // shapes straddle the QUERY_TILE boundary and odd block remainders
    let shapes = [(1usize, 1usize), (3, 7), (7, 8), (8, 9), (9, 257), (13, 100), (31, 63)];
    for (nq, nr) in shapes {
        for d in [1usize, 2, 3, 5] {
            let refs = random(nr, d, 1000 + (nq * nr + d) as u64);
            let queries = random(nq, d, 2000 + (nq + nr * d) as u64);
            let mut rng = Pcg32::new(3000 + nr as u64);
            let w: Vec<f64> = (0..nr).map(|_| rng.uniform_in(0.1, 2.0)).collect();
            // h ≥ 0.2 keeps even the *worst-case* certified norms-trick
            // bound (4(D+3)·ε_mach·max‖x‖²/2h²) under the 1e-12 budget
            // for unit-cube data up to D = 5
            for h in [0.2, 0.5, 1.5] {
                let kernel = GaussianKernel::new(h);
                // bichromatic
                let mut want = vec![0.0; nq];
                reference::scalar_gauss_sums(&queries, &refs, &w, &kernel, &mut want);
                let mut got = vec![0.0; nq];
                let mut scratch = Scratch::new(d);
                compute::gauss_sum_all_fast(
                    &queries, &refs, &w, &kernel, 64, &mut scratch, &mut got,
                );
                for i in 0..nq {
                    let rel = (got[i] - want[i]).abs() / want[i].max(1e-300);
                    assert!(
                        rel <= 1e-12,
                        "bichromatic nq={nq} nr={nr} d={d} h={h} i={i}: {rel:.2e}"
                    );
                }
                // monochromatic (queries = references)
                let mut want_m = vec![0.0; nr];
                reference::scalar_gauss_sums(&refs, &refs, &w, &kernel, &mut want_m);
                let mut got_m = vec![0.0; nr];
                compute::gauss_sum_all_fast(
                    &refs, &refs, &w, &kernel, 64, &mut scratch, &mut got_m,
                );
                for i in 0..nr {
                    let rel = (got_m[i] - want_m[i]).abs() / want_m[i].max(1e-300);
                    assert!(rel <= 1e-12, "mono nr={nr} d={d} h={h} i={i}: {rel:.2e}");
                }
            }
        }
    }
}

/// Miri-sized shadow of the shape grid: one shape straddling the
/// QUERY_TILE boundary, both chromatic forms.
#[test]
fn tiled_matches_scalar_on_a_small_shape() {
    let (nq, nr, d, h) = (9, 13, 2, 0.5);
    let refs = random(nr, d, 1000 + (nq * nr + d) as u64);
    let queries = random(nq, d, 2000 + (nq + nr * d) as u64);
    let mut rng = Pcg32::new(3000 + nr as u64);
    let w: Vec<f64> = (0..nr).map(|_| rng.uniform_in(0.1, 2.0)).collect();
    let kernel = GaussianKernel::new(h);
    let mut scratch = Scratch::new(d);
    let mut want = vec![0.0; nq];
    reference::scalar_gauss_sums(&queries, &refs, &w, &kernel, &mut want);
    let mut got = vec![0.0; nq];
    compute::gauss_sum_all_fast(&queries, &refs, &w, &kernel, 64, &mut scratch, &mut got);
    for i in 0..nq {
        let rel = (got[i] - want[i]).abs() / want[i].max(1e-300);
        assert!(rel <= 1e-12, "i={i}: {rel:.2e}");
    }
    let mut want_m = vec![0.0; nr];
    reference::scalar_gauss_sums(&refs, &refs, &w, &kernel, &mut want_m);
    let mut got_m = vec![0.0; nr];
    compute::gauss_sum_all_fast(&refs, &refs, &w, &kernel, 64, &mut scratch, &mut got_m);
    for i in 0..nr {
        let rel = (got_m[i] - want_m[i]).abs() / want_m[i].max(1e-300);
        assert!(rel <= 1e-12, "mono i={i}: {rel:.2e}");
    }
}

/// Regression for the norms-trick clamp: with duplicated
/// high-magnitude points, ‖q‖² + ‖r‖² − 2·q·r cancels catastrophically
/// and can land a hair *negative* in floating point — unclamped, that
/// negative squared distance becomes a positive exponent and a kernel
/// value > 1. The clamp pins the self-pair distance to exactly 0, so
/// every duplicated point contributes exactly weight·K(0) = weight.
#[test]
fn duplicated_high_magnitude_points_clamp_to_exact_self_interaction() {
    for d in [2usize, 3] {
        // one far-from-origin location, duplicated n times: worst-case
        // cancellation (‖x‖² huge, distance 0)
        let n = 37;
        let coords: Vec<f64> = (0..d).map(|k| 1e6 + k as f64).collect();
        let pts = Matrix::from_rows(&vec![coords.clone(); n]);
        let mut rng = Pcg32::new(777 + d as u64);
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 2.0)).collect();
        let total: f64 = w.iter().sum();
        for h in [1e-3, 0.2] {
            let kernel = GaussianKernel::new(h);
            let mut got = vec![0.0; n];
            let mut scratch = Scratch::new(d);
            compute::gauss_sum_all_fast(&pts, &pts, &w, &kernel, 64, &mut scratch, &mut got);
            for (i, &g) in got.iter().enumerate() {
                assert!(
                    (g - total).abs() <= 1e-12 * total,
                    "d={d} h={h} i={i}: sum {g:.17e} != Σw {total:.17e} — negative \
                     squared distance leaked through the clamp"
                );
            }
            // the scalar reference (direct Σ(q−r)², no norms trick)
            // agrees within the tiled pipeline's certified budget
            let mut want = vec![0.0; n];
            reference::scalar_gauss_sums(&pts, &pts, &w, &kernel, &mut want);
            for i in 0..n {
                let rel = (got[i] - want[i]).abs() / want[i];
                assert!(rel <= 1e-12, "d={d} h={h} i={i}: {rel:.2e}");
            }
        }
    }
}

// ---- 3. end-to-end ε-correctness with fast-exp on ----

const EPSILONS: [f64; 3] = [1e-2, 1e-4, 1e-6];

#[test]
#[cfg_attr(miri, ignore = "tree-building e2e sweep is too slow under the interpreter")]
fn every_method_stays_eps_correct_with_fast_exp_on() {
    for (name, n) in [("astro2d", 400), ("galaxy3d", 350)] {
        let ds = data::by_name(name, n, 42).unwrap();
        let h = silverman(&ds.points);
        // fast_exp defaults ON in PrepareOptions — assert that, then
        // rely on it: this whole test runs the tiled pipeline
        assert!(PrepareOptions::default().fast_exp);
        let session = Session::kde(&ds.points);
        for eps in EPSILONS {
            let (exact, _, _) = session.exact_sums(h, eps).unwrap();
            for method in [Method::Dfd, Method::Dfdo, Method::Dfto, Method::Dito, Method::Auto]
            {
                let ev = session
                    .evaluate(&EvalRequest::kde(h, eps).with_method(method))
                    .unwrap();
                let rel = max_relative_error(&ev.sums, &exact);
                assert!(
                    rel <= eps * (1.0 + 1e-9),
                    "{name} {method} eps={eps}: rel {rel:.2e}"
                );
            }
            // the verified methods report their measured error ≤ ε
            for method in [Method::Fgt, Method::Ifgt] {
                match session.evaluate(&EvalRequest::kde(h, eps).with_method(method)) {
                    Ok(ev) => {
                        let rel = ev.rel_err.expect("verified method reports rel_err");
                        assert!(rel <= eps * (1.0 + 1e-9), "{name} {method} eps={eps}: {rel:.2e}");
                    }
                    // the paper's X/∞ cells are legitimate outcomes for
                    // FGT/IFGT at tight ε — ε-correctness is only
                    // claimed for answers actually returned
                    Err(e) => eprintln!("{name} {method} eps={eps}: {e} (paper X/∞)"),
                }
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "session e2e is too slow under the interpreter")]
fn fast_exp_off_session_also_meets_eps_and_routes_exact() {
    let ds = data::by_name("galaxy3d", 300, 7).unwrap();
    let h = silverman(&ds.points);
    let session = Session::prepare(
        &ds.points,
        PrepareOptions { fast_exp: false, ..Default::default() },
    );
    let (exact, _, _) = session.exact_sums(h, 1e-4).unwrap();
    let ev = session.evaluate(&EvalRequest::kde(h, 1e-4).with_method(Method::Dito)).unwrap();
    assert!(max_relative_error(&ev.sums, &exact) <= 1e-4 * (1.0 + 1e-9));
    assert_eq!(ev.stats.fast_base_cases, 0, "{:?}", ev.stats);
    // and the default session actually exercises the fast kernel
    let fast_session = Session::kde(&ds.points);
    let ev_fast =
        fast_session.evaluate(&EvalRequest::kde(h, 1e-4).with_method(Method::Dito)).unwrap();
    assert!(ev_fast.stats.fast_base_cases > 0, "{:?}", ev_fast.stats);
    assert!(max_relative_error(&ev_fast.sums, &exact) <= 1e-4 * (1.0 + 1e-9));
}

#[test]
#[cfg_attr(miri, ignore = "dual-tree e2e is too slow under the interpreter")]
fn bichromatic_dual_tree_with_fast_exp_meets_eps() {
    let mut rng = Pcg32::new(99);
    let refs = random(320, 3, 55);
    let queries = Matrix::from_rows(
        &(0..75).map(|_| (0..3).map(|_| rng.uniform_in(-0.2, 1.2)).collect()).collect::<Vec<_>>(),
    );
    let w: Vec<f64> = (0..320).map(|_| rng.uniform_in(0.3, 2.0)).collect();
    for eps in EPSILONS {
        let problem = GaussSumProblem::new(&queries, &refs, Some(&w), 0.2, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = fastgauss::algo::dualtree::run_dualtree(
            &problem,
            &fastgauss::algo::dualtree::DualTreeConfig::default(),
        )
        .unwrap();
        let rel = max_relative_error(&got.sums, &exact);
        assert!(rel <= eps * (1.0 + 1e-9), "eps={eps}: rel {rel:.2e}");
    }
}
