//! The one synchronization seam of the crate: every lock, condvar,
//! atomic, spawn and yield in library code goes through these shim
//! types instead of naming `std::sync` directly (the `sync-bypass`
//! lint rule pins that, with audited waivers for the few one-time
//! `OnceLock` init sites below the runtime layer).
//!
//! In a normal build the shim delegates verbatim to `std::sync`:
//! [`crate::runtime::modelcheck::current`] is a constant `None`
//! without the `modelcheck` feature, so every virtual branch below
//! folds away and the only residue is a never-populated `Option` on
//! the lock guards. Under `--features modelcheck`, threads registered
//! with a [`crate::runtime::modelcheck::Controller`] route every
//! operation through the virtual scheduler first — the op becomes a
//! decision point, the controller updates its vector clocks, and only
//! then does the real `std::sync` primitive execute, serialized so
//! the real operation can neither block nor race.
//!
//! Two ordering rules keep the virtual and real worlds consistent:
//! a guard drop performs the *virtual* release first and the real
//! unlock second (the thread holds the scheduler baton until its next
//! operation, so no other registered thread can observe the window),
//! and a condvar wait drops the real guard *before* parking virtually
//! (same argument, mirrored). Plain data access through a held guard
//! is not a decision point: the lock discipline itself serializes it.
//!
//! Threads not registered with a controller (all threads in a normal
//! build; non-scenario threads in a test build) take the `std::sync`
//! fast path unconditionally.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize};
pub use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use super::modelcheck::{self, AtomicAccess, Controller};

// ---------------------------------------------------------------------------
// SyncMutex
// ---------------------------------------------------------------------------

/// Shimmed `std::sync::Mutex`: identical semantics (including
/// poisoning), plus a virtual lock-order decision point and
/// acquire/release clock propagation under the model checker.
pub struct SyncMutex<T> {
    inner: Mutex<T>,
}

impl<T> SyncMutex<T> {
    pub const fn new(value: T) -> SyncMutex<T> {
        SyncMutex { inner: Mutex::new(value) }
    }

    /// Stable identity for the controller's per-object state. An
    /// address can be reused after the mutex is dropped; stale mutex
    /// clocks can only add happens-before edges that are older than
    /// any later tick, so the scope-ordering assertion cannot be
    /// fooled into a false pass (see `modelcheck` docs).
    fn addr(&self) -> usize {
        &self.inner as *const Mutex<T> as usize
    }

    pub fn lock(&self) -> LockResult<SyncMutexGuard<'_, T>> {
        let mc = match modelcheck::current() {
            Some((ctl, me)) => {
                ctl.op_mutex_lock(me, self.addr());
                // the virtual lock is now ours: no registered thread
                // can hold the real mutex, so this cannot block on one
                Some((ctl, me))
            }
            None => None,
        };
        match self.inner.lock() {
            Ok(g) => Ok(SyncMutexGuard { owner: self, inner: Some(g), mc }),
            Err(p) => Err(PoisonError::new(SyncMutexGuard {
                owner: self,
                inner: Some(p.into_inner()),
                mc,
            })),
        }
    }

    /// Consume the mutex. Exclusive ownership means no schedule
    /// decision is involved.
    pub fn into_inner(self) -> LockResult<T> {
        if let Some((ctl, _)) = modelcheck::current() {
            ctl.op_retire(self.addr());
        }
        self.inner.into_inner()
    }
}

/// Guard for [`SyncMutex`]. Drop order matters: the virtual release
/// happens in `drop`, then the real `MutexGuard` field drops — the
/// baton is held across both, so the window is invisible to other
/// registered threads.
pub struct SyncMutexGuard<'a, T> {
    owner: &'a SyncMutex<T>,
    /// `Some` from construction until drop (or until a condvar wait
    /// consumes the guard).
    inner: Option<MutexGuard<'a, T>>,
    mc: Option<(Arc<Controller>, usize)>,
}

impl<T> Deref for SyncMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // lint: allow(no-panic): guard invariant — `inner` is Some for the guard's whole visible life
        self.inner.as_deref().unwrap()
    }
}

impl<T> DerefMut for SyncMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // lint: allow(no-panic): guard invariant — `inner` is Some for the guard's whole visible life
        self.inner.as_deref_mut().unwrap()
    }
}

impl<T> Drop for SyncMutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some((ctl, me)) = self.mc.take() {
            ctl.op_mutex_unlock(me, self.owner.addr());
        }
        // `inner` drops after this body: real unlock second
    }
}

// ---------------------------------------------------------------------------
// SyncCondvar
// ---------------------------------------------------------------------------

/// Result of [`SyncCondvar::wait_timeout`] (std's `WaitTimeoutResult`
/// cannot be constructed by user code, so the shim carries its own).
#[derive(Clone, Copy, Debug)]
pub struct SyncWaitTimeoutResult {
    timed_out: bool,
}

impl SyncWaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed_out
    }
}

/// Shimmed `std::sync::Condvar`. Under the model checker the real
/// condvar is never touched: waiting releases the virtual mutex and
/// parks on the scheduler, a notify moves virtual waiters to the
/// mutex-reacquire state, and a *timeout* fires only when no thread
/// is runnable (each such forced wake is counted, and the invariant
/// suites treat it as a lost-wakeup failure).
pub struct SyncCondvar {
    inner: Condvar,
}

impl SyncCondvar {
    pub const fn new() -> SyncCondvar {
        SyncCondvar { inner: Condvar::new() }
    }

    fn addr(&self) -> usize {
        &self.inner as *const Condvar as usize
    }

    pub fn notify_one(&self) {
        if let Some((ctl, me)) = modelcheck::current() {
            ctl.op_cv_notify(me, self.addr(), false);
            return;
        }
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        if let Some((ctl, me)) = modelcheck::current() {
            ctl.op_cv_notify(me, self.addr(), true);
            return;
        }
        self.inner.notify_all();
    }

    pub fn wait<'a, T>(&self, guard: SyncMutexGuard<'a, T>) -> LockResult<SyncMutexGuard<'a, T>> {
        match self.wait_inner(guard, None) {
            Ok((g, _)) => Ok(g),
            Err(p) => {
                let (g, _) = p.into_inner();
                Err(PoisonError::new(g))
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: SyncMutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(SyncMutexGuard<'a, T>, SyncWaitTimeoutResult)> {
        self.wait_inner(guard, Some(dur))
    }

    fn wait_inner<'a, T>(
        &self,
        mut guard: SyncMutexGuard<'a, T>,
        dur: Option<Duration>,
    ) -> LockResult<(SyncMutexGuard<'a, T>, SyncWaitTimeoutResult)> {
        let owner = guard.owner;
        if let Some((ctl, me)) = guard.mc.take() {
            // real unlock first — the baton is held, so the window
            // between the real release and the virtual one is
            // invisible to every registered thread
            guard.inner = None;
            drop(guard); // `mc` already taken: no virtual unlock op
            let notified = ctl.op_cv_wait(me, self.addr(), owner.addr(), dur.is_some());
            // the virtual mutex is re-acquired; take the real one
            let res = SyncWaitTimeoutResult { timed_out: !notified };
            return match owner.inner.lock() {
                Ok(g) => {
                    Ok((SyncMutexGuard { owner, inner: Some(g), mc: Some((ctl, me)) }, res))
                }
                Err(p) => Err(PoisonError::new((
                    SyncMutexGuard { owner, inner: Some(p.into_inner()), mc: Some((ctl, me)) },
                    res,
                ))),
            };
        }
        // lint: allow(no-panic): guard invariant — a live guard always holds the real lock
        let inner = guard.inner.take().unwrap();
        drop(guard); // empty shell: no-op drop
        match dur {
            None => match self.inner.wait(inner) {
                Ok(g) => Ok((
                    SyncMutexGuard { owner, inner: Some(g), mc: None },
                    SyncWaitTimeoutResult { timed_out: false },
                )),
                Err(p) => Err(PoisonError::new((
                    SyncMutexGuard { owner, inner: Some(p.into_inner()), mc: None },
                    SyncWaitTimeoutResult { timed_out: false },
                ))),
            },
            Some(d) => match self.inner.wait_timeout(inner, d) {
                Ok((g, r)) => Ok((
                    SyncMutexGuard { owner, inner: Some(g), mc: None },
                    SyncWaitTimeoutResult { timed_out: r.timed_out() },
                )),
                Err(p) => {
                    let (g, r) = p.into_inner();
                    Err(PoisonError::new((
                        SyncMutexGuard { owner, inner: Some(g), mc: None },
                        SyncWaitTimeoutResult { timed_out: r.timed_out() },
                    )))
                }
            },
        }
    }
}

impl Default for SyncCondvar {
    fn default() -> SyncCondvar {
        SyncCondvar::new()
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

macro_rules! sync_atomic {
    ($(#[$doc:meta])* $name:ident, $inner:ty, $prim:ty) => {
        $(#[$doc])*
        pub struct $name {
            inner: $inner,
        }

        impl $name {
            pub const fn new(v: $prim) -> $name {
                $name { inner: <$inner>::new(v) }
            }

            fn addr(&self) -> usize {
                &self.inner as *const $inner as usize
            }

            /// Decision point + clock bookkeeping before the real op.
            fn gate(&self, access: AtomicAccess, ord: Ordering) {
                if let Some((ctl, me)) = modelcheck::current() {
                    ctl.op_atomic(me, self.addr(), access, ord);
                }
            }

            pub fn load(&self, ord: Ordering) -> $prim {
                self.gate(AtomicAccess::Load, ord);
                self.inner.load(ord)
            }

            pub fn store(&self, v: $prim, ord: Ordering) {
                self.gate(AtomicAccess::Store, ord);
                self.inner.store(v, ord)
            }

            pub fn swap(&self, v: $prim, ord: Ordering) -> $prim {
                self.gate(AtomicAccess::Rmw, ord);
                self.inner.swap(v, ord)
            }
        }

        impl Drop for $name {
            fn drop(&mut self) {
                // forget per-object clocks so a reused address cannot
                // inherit them (statics never drop; that is fine)
                if let Some((ctl, _)) = modelcheck::current() {
                    ctl.op_retire(self.addr());
                }
            }
        }
    };
}

macro_rules! sync_atomic_arith {
    ($name:ident, $prim:ty) => {
        impl $name {
            pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                self.gate(AtomicAccess::Rmw, ord);
                self.inner.fetch_add(v, ord)
            }

            pub fn fetch_sub(&self, v: $prim, ord: Ordering) -> $prim {
                self.gate(AtomicAccess::Rmw, ord);
                self.inner.fetch_sub(v, ord)
            }
        }
    };
}

sync_atomic!(
    /// Shimmed `AtomicBool` (load/store/swap).
    SyncAtomicBool,
    AtomicBool,
    bool
);
sync_atomic!(
    /// Shimmed `AtomicUsize` (load/store/swap/fetch_add/fetch_sub).
    SyncAtomicUsize,
    AtomicUsize,
    usize
);
sync_atomic!(
    /// Shimmed `AtomicU64` (load/store/swap/fetch_add/fetch_sub).
    SyncAtomicU64,
    AtomicU64,
    u64
);
sync_atomic_arith!(SyncAtomicUsize, usize);
sync_atomic_arith!(SyncAtomicU64, u64);

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Join handle from [`spawn_thread`]. Joining a model-checked thread
/// first waits for it virtually (a decision point that also joins the
/// child's final vector clock), then joins the real thread.
pub struct SyncJoinHandle {
    inner: std::thread::JoinHandle<()>,
    mc: Option<(Arc<Controller>, usize)>,
}

impl SyncJoinHandle {
    pub fn join(self) -> std::thread::Result<()> {
        if let Some((ctl, vtid)) = &self.mc {
            if let Some((_, me)) = modelcheck::current() {
                ctl.op_join(me, *vtid);
            }
        }
        self.inner.join()
    }
}

/// Spawn a named thread. Under a controller the child is registered
/// as a virtual thread: it inherits the parent's clock, waits for its
/// first schedule grant before running `f`, reports any non-abort
/// panic as a model-check failure, and marks itself finished on exit.
pub fn spawn_thread<F>(
    name: String,
    stack_size: Option<usize>,
    f: F,
) -> std::io::Result<SyncJoinHandle>
where
    F: FnOnce() + Send + 'static,
{
    let mut builder = std::thread::Builder::new().name(name.clone());
    if let Some(size) = stack_size {
        builder = builder.stack_size(size);
    }
    if let Some((ctl, me)) = modelcheck::current() {
        let vtid = ctl.op_spawn_register(me, &name);
        if vtid != usize::MAX {
            let child_ctl = Arc::clone(&ctl);
            return match builder.spawn(move || modelcheck::run_child(child_ctl, vtid, f)) {
                Ok(inner) => {
                    // post-spawn decision point: the child may now be
                    // scheduled before the parent continues
                    ctl.op_yield(me);
                    Ok(SyncJoinHandle { inner, mc: Some((ctl, vtid)) })
                }
                Err(e) => {
                    ctl.op_spawn_abandon(vtid);
                    Err(e)
                }
            };
        }
    }
    builder.spawn(f).map(|inner| SyncJoinHandle { inner, mc: None })
}

/// A pure decision point (no state change); `std::thread::yield_now`
/// outside a model-checked schedule.
pub fn yield_now() {
    if let Some((ctl, me)) = modelcheck::current() {
        ctl.op_yield(me);
        return;
    }
    std::thread::yield_now();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutex_and_guard_delegate_to_std() {
        let m = SyncMutex::new(41);
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        assert_eq!(*m.lock().unwrap(), 42);
        assert_eq!(m.into_inner().unwrap(), 42);
    }

    #[test]
    fn condvar_wait_timeout_times_out_without_notify() {
        let m = SyncMutex::new(());
        let cv = SyncCondvar::new();
        let g = m.lock().unwrap();
        let (_g, res) = cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        assert!(res.timed_out());
    }

    #[test]
    fn condvar_notify_wakes_a_real_waiter() {
        let state = Arc::new((SyncMutex::new(false), SyncCondvar::new()));
        let s2 = Arc::clone(&state);
        let h = spawn_thread("sync-test".to_string(), None, move || {
            let (m, cv) = &*s2;
            *m.lock().unwrap() = true;
            cv.notify_all();
        })
        .unwrap();
        let (m, cv) = &*state;
        let mut g = m.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
        drop(g);
        h.join().unwrap();
    }

    #[test]
    fn atomics_delegate_and_support_rmw() {
        let a = SyncAtomicUsize::new(1);
        assert_eq!(a.fetch_add(4, Ordering::AcqRel), 1);
        assert_eq!(a.fetch_sub(2, Ordering::AcqRel), 5);
        assert_eq!(a.load(Ordering::Acquire), 3);
        let b = SyncAtomicBool::new(false);
        assert!(!b.swap(true, Ordering::Relaxed));
        assert!(b.load(Ordering::Relaxed));
        let c = SyncAtomicU64::new(7);
        c.store(9, Ordering::Release);
        assert_eq!(c.swap(1, Ordering::AcqRel), 9);
    }

    #[test]
    fn poisoning_propagates_like_std() {
        let m = Arc::new(SyncMutex::new(0u32));
        let m2 = Arc::clone(&m);
        let h = spawn_thread("sync-poison".to_string(), None, move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .unwrap();
        assert!(h.join().is_err());
        assert!(m.lock().is_err(), "poisoning must propagate through the shim");
    }
}
