//! Artifact manifest: which HLO file serves which dimension, and the
//! fixed tile/chunk shapes the executor must pad to.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::util::json::Json;

/// Shape contract of one compiled artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub dim: usize,
    /// Fixed query tile rows (TQ).
    pub tile_queries: usize,
    /// Pallas reference block rows (TR) — informational.
    pub block_refs: usize,
    /// Reference chunk rows per execution (NR).
    pub chunk_refs: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dtype: String,
    specs: BTreeMap<usize, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load and validate a manifest from `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let dtype = json
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing dtype"))?
            .to_string();
        let arts =
            json.get("artifacts").and_then(Json::as_obj).ok_or_else(|| anyhow!("no artifacts"))?;
        let mut specs = BTreeMap::new();
        for (key, v) in arts {
            let dim: usize = key.parse().map_err(|_| anyhow!("bad dim key {key:?}"))?;
            let field = |name: &str| -> Result<usize> {
                v.get(name)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact {key}: missing {name}"))
            };
            let file = dir.join(
                v.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("missing file"))?,
            );
            let spec = ArtifactSpec {
                file,
                dim: field("dim")?,
                tile_queries: field("tile_queries")?,
                block_refs: field("block_refs")?,
                chunk_refs: field("chunk_refs")?,
            };
            if spec.dim != dim {
                return Err(anyhow!("artifact {key}: dim mismatch"));
            }
            if spec.chunk_refs == 0 || spec.chunk_refs % spec.block_refs != 0 {
                return Err(anyhow!("artifact {key}: chunk_refs not a block multiple"));
            }
            specs.insert(dim, spec);
        }
        Ok(ArtifactManifest { dtype, specs })
    }

    /// Spec for dimension `dim`, if compiled.
    pub fn spec(&self, dim: usize) -> Option<&ArtifactSpec> {
        self.specs.get(&dim)
    }

    /// All compiled dimensions.
    pub fn dims(&self) -> impl Iterator<Item = usize> + '_ {
        self.specs.keys().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("fg_manifest_ok");
        write_manifest(
            &dir,
            r#"{"dtype":"f64","artifacts":{"2":{"file":"gauss_d2.hlo.txt","dim":2,
               "tile_queries":256,"block_refs":512,"chunk_refs":4096}}}"#,
        );
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.dtype, "f64");
        let s = m.spec(2).unwrap();
        assert_eq!(s.tile_queries, 256);
        assert_eq!(s.chunk_refs, 4096);
        assert!(m.spec(5).is_none());
        assert_eq!(m.dims().collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn rejects_dim_mismatch() {
        let dir = std::env::temp_dir().join("fg_manifest_bad1");
        write_manifest(
            &dir,
            r#"{"dtype":"f64","artifacts":{"2":{"file":"x","dim":3,
               "tile_queries":1,"block_refs":1,"chunk_refs":1}}}"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_non_multiple_chunk() {
        let dir = std::env::temp_dir().join("fg_manifest_bad2");
        write_manifest(
            &dir,
            r#"{"dtype":"f64","artifacts":{"2":{"file":"x","dim":2,
               "tile_queries":8,"block_refs":3,"chunk_refs":10}}}"#,
        );
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn missing_file_is_contextual_error() {
        let dir = std::env::temp_dir().join("fg_manifest_missing_xyz");
        let _ = std::fs::remove_dir_all(&dir);
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_manifest_if_built() {
        // integration hook: when `make artifacts` has run, the real
        // manifest must load and cover the paper's dimensions
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            for d in [2, 3, 5, 7, 10, 16] {
                assert!(m.spec(d).is_some(), "missing artifact for D={d}");
            }
        }
    }
}
