//! A schedule-exploring model checker for the crate's concurrency
//! core — the std-only, in-repo analogue of `loom`/`shuttle` (the
//! build container has no registry access, the same constraint the
//! hand-rolled linter lexer worked under).
//!
//! # How it works
//!
//! Under `--features modelcheck`, every operation on the
//! [`crate::runtime::sync`] shim types (mutex lock/unlock, condvar
//! wait/notify, atomic load/store/RMW, spawn/join/yield) routes
//! through a per-scenario [`Controller`] before touching the real
//! primitive. The controller serializes execution — real threads are
//! gated one-runnable-at-a-time by per-thread baton gates — and every
//! operation is a *decision point* where the scheduler may switch to
//! any other runnable thread. Exploring those decisions systematically
//! (bounded-preemption DFS for small scenarios, PCG-seeded random
//! sampling for larger ones) walks the scenario through adversarial
//! interleavings the OS scheduler would produce once a year in
//! production.
//!
//! Because execution is serialized, every interleaving the controller
//! produces is *sequentially consistent* — a memory-ordering bug
//! (a `Relaxed` latch decrement, say) changes no value any load
//! observes. Orderings are checked separately with **vector clocks**:
//! each thread, mutex, and atomic carries a clock; release stores and
//! lock releases publish the writer's clock, acquire loads and lock
//! acquisitions join it, following the C++ release-sequence rules
//! (an RMW continues the sequence regardless of its own ordering; a
//! plain relaxed store breaks it). The pool's scope latch then asserts
//! a *happens-before* invariant at every scope exit: the waiter's
//! clock must dominate the clock each completed task published — see
//! [`scope_assert`]. A weakened ordering breaks the dominance even
//! though the serialized values still look right.
//!
//! Lost wakeups are caught by construction: a timed condvar wait is
//! woken by timeout **only when no thread is runnable** (a real
//! schedule could always run someone else first), the event is
//! counted, and [`McConfig::fail_on_forced_timeout`] turns it into a
//! failure — the pool's wake protocol (notify under the `idle` lock,
//! re-check the predicate under the same lock before parking) never
//! needs a timeout to make progress, so a forced timeout means a
//! wakeup was lost. An all-blocked state with no timed waiter is a
//! deadlock and fails with the blocked-thread list.
//!
//! # Reproducibility
//!
//! Every schedule is identified by the explicit choice sequence the
//! chooser took; a failure report ([`McFailure`]) carries the seed,
//! the schedule index, the choices, and the event trace, and
//! [`replay`] re-runs exactly that schedule bitwise. Random mode
//! derives schedule `i` from [`Pcg32::new_stream`]`(seed, i)`, so one
//! printed `(seed, index)` pair pins the whole run; the
//! `FASTGAUSS_MC_SEED` environment variable overrides the seed in CI
//! and `FASTGAUSS_MC_TRACE_DIR` saves failing traces as artifacts.
//!
//! # Cost model
//!
//! Without the `modelcheck` feature this module still compiles (so
//! the default build lints and type-checks it) but nothing routes
//! through it: the shim's fast paths delegate straight to `std::sync`
//! and [`current`] is a constant `None`. Scenario code pays the
//! controller cost only inside [`explore`]/[`replay`].

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::atomic::Ordering::{self, AcqRel, Acquire, Relaxed, Release, SeqCst};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::util::Pcg32;

/// Panic payload used to unwind scenario threads when a schedule
/// aborts (failure found or budget exhausted). The thread wrappers in
/// `runtime/sync` swallow it; anything else escaping a scenario
/// thread is itself a detected failure.
pub struct McAbort;

/// Cap on stored trace events per schedule (diagnostics only; the
/// choice sequence, not the trace, is what replays a schedule).
const TRACE_CAP: usize = 20_000;

/// Watchdog for scenario threads to unwind after an abort.
const EXIT_WATCHDOG: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

/// A vector clock over virtual thread ids; missing entries are zero.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct VClock(Vec<u32>);

impl VClock {
    fn grow(&mut self, len: usize) {
        if self.0.len() < len {
            self.0.resize(len, 0);
        }
    }

    fn tick(&mut self, id: usize) {
        self.grow(id + 1);
        self.0[id] += 1;
    }

    fn join(&mut self, other: &VClock) {
        self.grow(other.0.len());
        for (mine, theirs) in self.0.iter_mut().zip(other.0.iter()) {
            *mine = (*mine).max(*theirs);
        }
    }

    /// `self ≥ other` componentwise: everything `other` has seen
    /// happened-before the state `self` describes.
    fn dominates(&self, other: &VClock) -> bool {
        other
            .0
            .iter()
            .enumerate()
            .all(|(i, &theirs)| theirs == 0 || self.0.get(i).copied().unwrap_or(0) >= theirs)
    }
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

/// What kind of access an atomic shim op performs (HB bookkeeping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AtomicAccess {
    Load,
    Store,
    Rmw,
}

/// Run state of one virtual thread.
#[derive(Clone, Debug)]
enum Run {
    Runnable,
    /// Waiting to (re)acquire a mutex; eligible whenever it is free.
    /// `timed_out` carries a condvar-wait result across the reacquire.
    LockWait { mutex: usize, timed_out: bool },
    /// Parked on a condvar having released `mutex`.
    CvWait { cv: usize, mutex: usize, timed: bool },
    JoinWait { target: usize },
    Finished,
}

struct ThreadSt {
    name: String,
    gate: Arc<(Mutex<bool>, Condvar)>,
    clock: VClock,
    run: Run,
}

#[derive(Default)]
struct MutexSt {
    locked_by: Option<usize>,
    /// Joined by the releaser, adopted by the next acquirer.
    clock: VClock,
}

#[derive(Default)]
struct AtomicSt {
    /// Clock of the release-sequence head (C++ §release sequences):
    /// set by a release store, extended by release RMWs, *kept* by
    /// relaxed RMWs, and broken by a relaxed plain store.
    release: VClock,
}

enum Chooser {
    /// Fixed prefix (DFS frontier or a replayed failure); `0` — the
    /// first eligible option — past the end.
    Script { path: Vec<u32>, at: usize },
    Random(Pcg32),
}

impl Chooser {
    fn pick(&mut self, n: u32) -> u32 {
        match self {
            Chooser::Script { path, at } => {
                let c = if *at < path.len() { path[*at] } else { 0 };
                *at += 1;
                c.min(n - 1)
            }
            Chooser::Random(rng) => rng.next_u32() % n,
        }
    }
}

struct Sched {
    threads: Vec<ThreadSt>,
    mutexes: HashMap<usize, MutexSt>,
    atomics: HashMap<usize, AtomicSt>,
    /// Scope-token store: clocks published by completed scope tasks.
    scopes: Vec<Vec<VClock>>,
    chooser: Chooser,
    /// Every multi-option decision this schedule: `(choice, options)`.
    taken: Vec<(u32, u32)>,
    trace: Vec<String>,
    steps: u64,
    preemptions: u32,
    forced_timeouts: u64,
    failure: Option<String>,
    /// Threads not yet `Finished`.
    live: usize,
}

impl Sched {
    fn trace(&mut self, msg: impl FnOnce() -> String) {
        if self.trace.len() < TRACE_CAP {
            self.trace.push(msg());
        }
    }

    fn mutex_free(&self, addr: usize) -> bool {
        self.mutexes.get(&addr).is_none_or(|m| m.locked_by.is_none())
    }

    /// Threads that could run right now, in vtid order (deterministic).
    fn eligible(&self) -> Vec<usize> {
        self.threads
            .iter()
            .enumerate()
            .filter_map(|(i, t)| match t.run {
                Run::Runnable => Some(i),
                Run::LockWait { mutex, .. } => self.mutex_free(mutex).then_some(i),
                Run::JoinWait { target } => {
                    matches!(self.threads[target].run, Run::Finished).then_some(i)
                }
                _ => None,
            })
            .collect()
    }
}

enum Pick {
    Grant(usize),
    AllDone,
    Aborted,
}

// ---------------------------------------------------------------------------
// Controller
// ---------------------------------------------------------------------------

/// The per-scenario scheduler: serializes registered threads and
/// explores/records their interleaving. One controller per schedule.
pub struct Controller {
    sched: Mutex<Sched>,
    /// Signaled when `live` reaches zero (or on failure).
    done: Condvar,
    /// Fast-path mirror of `failure.is_some()`.
    aborting: AtomicBool,
    max_steps: u64,
    max_preemptions: u32,
    fail_on_forced_timeout: bool,
}

thread_local! {
    static TL: RefCell<Option<(Arc<Controller>, usize)>> = const { RefCell::new(None) };
}

/// The controller and virtual thread id of the current thread, when
/// it is participating in a model-checked schedule. Constant `None`
/// unless the `modelcheck` feature is enabled.
#[cfg(feature = "modelcheck")]
pub fn current() -> Option<(Arc<Controller>, usize)> {
    TL.with(|tl| tl.borrow().clone())
}

/// The controller and virtual thread id of the current thread, when
/// it is participating in a model-checked schedule. Constant `None`
/// unless the `modelcheck` feature is enabled — the shim's virtual
/// branches fold away in normal builds.
#[cfg(not(feature = "modelcheck"))]
#[inline(always)]
pub fn current() -> Option<(Arc<Controller>, usize)> {
    None
}

fn set_current(v: Option<(Arc<Controller>, usize)>) {
    TL.with(|tl| *tl.borrow_mut() = v);
}

fn grant(gate: &(Mutex<bool>, Condvar)) {
    *gate.0.lock().unwrap() = true;
    gate.1.notify_all();
}

fn wait_gate(gate: &(Mutex<bool>, Condvar)) {
    let mut g = gate.0.lock().unwrap();
    while !*g {
        g = gate.1.wait(g).unwrap();
    }
    *g = false;
}

impl Controller {
    fn new(cfg: &McConfig, chooser: Chooser) -> Arc<Controller> {
        let root = ThreadSt {
            name: "root".to_string(),
            gate: Arc::new((Mutex::new(false), Condvar::new())),
            clock: {
                let mut c = VClock::default();
                c.tick(0);
                c
            },
            run: Run::Runnable,
        };
        Arc::new(Controller {
            sched: Mutex::new(Sched {
                threads: vec![root],
                mutexes: HashMap::new(),
                atomics: HashMap::new(),
                scopes: Vec::new(),
                chooser,
                taken: Vec::new(),
                trace: Vec::new(),
                steps: 0,
                preemptions: 0,
                forced_timeouts: 0,
                failure: None,
                live: 1,
            }),
            done: Condvar::new(),
            aborting: AtomicBool::new(false),
            max_steps: cfg.max_steps,
            max_preemptions: cfg.max_preemptions,
            fail_on_forced_timeout: cfg.fail_on_forced_timeout,
        })
    }

    /// Unwind out of the scenario unless this thread is already
    /// unwinding (a guard drop mid-panic must not double-panic).
    fn bail(&self) {
        if !std::thread::panicking() {
            panic_any(McAbort);
        }
    }

    /// Record the first failure, then release every gate so all
    /// threads unwind out of the scenario at their next operation.
    fn fail(&self, s: &mut Sched, msg: String) {
        if s.failure.is_none() {
            s.trace(|| format!("FAIL: {msg}"));
            s.failure = Some(msg);
        }
        self.aborting.store(true, SeqCst);
        for t in &s.threads {
            grant(&t.gate);
        }
        self.done.notify_all();
    }

    /// Common op prelude: abort check, step budget, clock tick, trace.
    /// `None` means the op must pass through untracked (this thread is
    /// unwinding through an aborted schedule).
    fn begin(
        &self,
        me: usize,
        desc: impl FnOnce() -> String,
    ) -> Option<MutexGuard<'_, Sched>> {
        if self.aborting.load(SeqCst) {
            self.bail();
            return None;
        }
        let mut s = self.sched.lock().unwrap();
        if s.failure.is_some() {
            drop(s);
            self.bail();
            return None;
        }
        s.steps += 1;
        if s.steps > self.max_steps {
            let msg = format!(
                "step budget exceeded ({} ops) — livelock or a scenario too large \
                 for the configured budget",
                self.max_steps
            );
            self.fail(&mut s, msg);
            drop(s);
            self.bail();
            return None;
        }
        s.threads[me].clock.tick(me);
        s.trace(|| format!("t{me} {}", desc()));
        Some(s)
    }

    /// Record a scheduling decision among `options` (vtids, ascending).
    fn choose(&self, s: &mut Sched, options: &[usize]) -> usize {
        let n = options.len() as u32;
        if n == 1 {
            return options[0];
        }
        let c = s.chooser.pick(n);
        s.taken.push((c, n));
        options[c as usize]
    }

    /// Hand the baton to `next` and wait until this thread is granted
    /// again. Returns the re-acquired scheduler lock, or `None` when
    /// the schedule aborted while we slept.
    fn handoff(
        &self,
        s: MutexGuard<'_, Sched>,
        me: usize,
        next: usize,
    ) -> Option<MutexGuard<'_, Sched>> {
        let my_gate = Arc::clone(&s.threads[me].gate);
        let next_gate = Arc::clone(&s.threads[next].gate);
        drop(s);
        grant(&next_gate);
        wait_gate(&my_gate);
        if self.aborting.load(SeqCst) {
            self.bail();
            return None;
        }
        let s = self.sched.lock().unwrap();
        if s.failure.is_some() {
            drop(s);
            self.bail();
            return None;
        }
        Some(s)
    }

    /// The pre-op decision point: possibly preempt `me` (runnable) in
    /// favor of another eligible thread.
    fn reschedule(
        &self,
        mut s: MutexGuard<'_, Sched>,
        me: usize,
    ) -> Option<MutexGuard<'_, Sched>> {
        let elig = s.eligible();
        let options = if s.preemptions >= self.max_preemptions { vec![me] } else { elig };
        let next = self.choose(&mut s, &options);
        if next == me {
            return Some(s);
        }
        s.preemptions += 1;
        s.trace(|| format!("t{me} preempted -> t{next}"));
        self.handoff(s, me, next)
    }

    /// Pick someone to run when the caller cannot continue. Loops so a
    /// forced timeout conversion can re-derive eligibility.
    fn pick_next(&self, s: &mut Sched) -> Pick {
        loop {
            let elig = s.eligible();
            if !elig.is_empty() {
                return Pick::Grant(self.choose(s, &elig));
            }
            let timed: Vec<usize> = s
                .threads
                .iter()
                .enumerate()
                .filter_map(|(i, t)| matches!(t.run, Run::CvWait { timed: true, .. }).then_some(i))
                .collect();
            if !timed.is_empty() {
                s.forced_timeouts += 1;
                if self.fail_on_forced_timeout {
                    let msg = format!(
                        "forced timeout wake: no thread is runnable while t{} waits on a \
                         timed condvar — a wakeup was lost (the protocol's timeouts are \
                         documented as pure safety nets)",
                        timed[0]
                    );
                    self.fail(s, msg);
                    return Pick::Aborted;
                }
                let w = self.choose(s, &timed);
                if let Run::CvWait { mutex, .. } = s.threads[w].run {
                    s.threads[w].run = Run::LockWait { mutex, timed_out: true };
                }
                s.trace(|| format!("t{w} forced timeout wake"));
                continue;
            }
            if s.live == 0 {
                return Pick::AllDone;
            }
            let blocked: Vec<String> = s
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| !matches!(t.run, Run::Finished))
                .map(|(i, t)| format!("t{i}:{} {:?}", t.name, t.run))
                .collect();
            let msg = format!("deadlock: no runnable thread; blocked: [{}]", blocked.join(", "));
            self.fail(s, msg);
            return Pick::Aborted;
        }
    }

    /// Block `me` with the given run state and sleep until granted.
    fn block(
        &self,
        mut s: MutexGuard<'_, Sched>,
        me: usize,
        run: Run,
    ) -> Option<MutexGuard<'_, Sched>> {
        s.threads[me].run = run;
        match self.pick_next(&mut s) {
            Pick::Grant(next) => self.handoff(s, me, next),
            Pick::Aborted | Pick::AllDone => {
                drop(s);
                self.bail();
                None
            }
        }
    }

    // -- shim operations ---------------------------------------------------

    pub(crate) fn op_yield(&self, me: usize) {
        let Some(s) = self.begin(me, || "yield".to_string()) else { return };
        self.reschedule(s, me);
    }

    pub(crate) fn op_atomic(&self, me: usize, addr: usize, access: AtomicAccess, ord: Ordering) {
        let Some(s) = self.begin(me, || format!("atomic {access:?} {ord:?} @{addr:#x}")) else {
            return;
        };
        let Some(mut s) = self.reschedule(s, me) else { return };
        let s = &mut *s;
        let st = s.atomics.entry(addr).or_default();
        let acquire = matches!(ord, Acquire | AcqRel | SeqCst);
        let release = matches!(ord, Release | AcqRel | SeqCst);
        match access {
            AtomicAccess::Load => {
                if acquire {
                    s.threads[me].clock.join(&st.release);
                }
            }
            AtomicAccess::Store => {
                // a release store starts a new release sequence; a
                // relaxed store breaks the existing one
                st.release =
                    if release { s.threads[me].clock.clone() } else { VClock::default() };
            }
            AtomicAccess::Rmw => {
                if acquire {
                    let rel = st.release.clone();
                    s.threads[me].clock.join(&rel);
                }
                if release {
                    let mine = s.threads[me].clock.clone();
                    s.atomics.entry(addr).or_default().release.join(&mine);
                }
                // a relaxed RMW continues the release sequence
                // untouched — it neither publishes nor breaks it
            }
        }
    }

    pub(crate) fn op_mutex_lock(&self, me: usize, addr: usize) {
        let Some(s) = self.begin(me, || format!("lock @{addr:#x}")) else { return };
        let Some(mut s) = self.reschedule(s, me) else { return };
        loop {
            if s.mutex_free(addr) {
                let s = &mut *s;
                let st = s.mutexes.entry(addr).or_default();
                st.locked_by = Some(me);
                let c = st.clock.clone();
                s.threads[me].clock.join(&c);
                s.threads[me].run = Run::Runnable;
                return;
            }
            let Some(ns) = self.block(s, me, Run::LockWait { mutex: addr, timed_out: false })
            else {
                return;
            };
            s = ns;
        }
    }

    pub(crate) fn op_mutex_unlock(&self, me: usize, addr: usize) {
        let Some(s) = self.begin(me, || format!("unlock @{addr:#x}")) else { return };
        let Some(mut s) = self.reschedule(s, me) else { return };
        let s = &mut *s;
        if let Some(st) = s.mutexes.get_mut(&addr) {
            st.locked_by = None;
            st.clock.join(&s.threads[me].clock);
        }
    }

    /// Atomically release `mutex` and park on `cv`; returns `true` if
    /// notified, `false` on a (forced) timeout. The caller has already
    /// dropped the real guard and re-locks the real mutex afterwards.
    pub(crate) fn op_cv_wait(&self, me: usize, cv: usize, mutex: usize, timed: bool) -> bool {
        let Some(mut s) = self.begin(me, || format!("cv-wait @{cv:#x} mutex @{mutex:#x}")) else {
            return true;
        };
        {
            let s = &mut *s;
            if let Some(st) = s.mutexes.get_mut(&mutex) {
                st.locked_by = None;
                st.clock.join(&s.threads[me].clock);
            }
        }
        let Some(ns) = self.block(s, me, Run::CvWait { cv, mutex, timed }) else { return true };
        s = ns;
        // Granted again: a notify or forced timeout turned this thread
        // into a LockWait, and the mutex is free. Re-acquire it.
        loop {
            let timed_out = matches!(s.threads[me].run, Run::LockWait { timed_out: true, .. });
            if s.mutex_free(mutex) {
                let s = &mut *s;
                let st = s.mutexes.entry(mutex).or_default();
                st.locked_by = Some(me);
                let c = st.clock.clone();
                s.threads[me].clock.join(&c);
                s.threads[me].run = Run::Runnable;
                return !timed_out;
            }
            let Some(ns) = self.block(s, me, Run::LockWait { mutex, timed_out }) else {
                return true;
            };
            s = ns;
        }
    }

    pub(crate) fn op_cv_notify(&self, me: usize, cv: usize, all: bool) {
        let Some(s) = self.begin(me, || format!("notify-{} @{cv:#x}", if all { "all" } else { "one" }))
        else {
            return;
        };
        let Some(mut s) = self.reschedule(s, me) else { return };
        let waiters: Vec<usize> = s
            .threads
            .iter()
            .enumerate()
            .filter_map(|(i, t)| match t.run {
                Run::CvWait { cv: c, .. } if c == cv => Some(i),
                _ => None,
            })
            .collect();
        if waiters.is_empty() {
            return;
        }
        let woken: Vec<usize> =
            if all { waiters } else { vec![self.choose(&mut s, &waiters)] };
        for w in woken {
            if let Run::CvWait { mutex, .. } = s.threads[w].run {
                // no direct HB edge from notifier to waiter: ordering
                // flows through the mutex, as with a real condvar
                s.threads[w].run = Run::LockWait { mutex, timed_out: false };
            }
        }
    }

    /// Register a child thread; the child waits for its first grant
    /// before running. Follow with [`Controller::op_yield`] once the
    /// real spawn succeeded (the post-spawn decision point).
    pub(crate) fn op_spawn_register(&self, me: usize, name: &str) -> usize {
        let Some(mut s) = self.begin(me, || format!("spawn {name}")) else { return usize::MAX };
        let vtid = s.threads.len();
        let mut clock = s.threads[me].clock.clone();
        clock.tick(vtid);
        s.threads.push(ThreadSt {
            name: name.to_string(),
            gate: Arc::new((Mutex::new(false), Condvar::new())),
            clock,
            run: Run::Runnable,
        });
        s.live += 1;
        vtid
    }

    /// Roll back a registration whose real `thread::Builder::spawn`
    /// failed.
    pub(crate) fn op_spawn_abandon(&self, vtid: usize) {
        if let Ok(mut s) = self.sched.lock() {
            if vtid < s.threads.len() {
                s.threads[vtid].run = Run::Finished;
                s.live -= 1;
            }
        }
    }

    /// First thing a child thread does: wait to be scheduled. Returns
    /// `false` when the schedule aborted before the child ever ran.
    pub(crate) fn child_start(&self, vtid: usize) -> bool {
        let gate = {
            let s = self.sched.lock().unwrap();
            Arc::clone(&s.threads[vtid].gate)
        };
        wait_gate(&gate);
        !self.aborting.load(SeqCst)
    }

    /// A scenario thread panicked with something other than [`McAbort`]
    /// — a real invariant violation (e.g. a latch-underflow
    /// `debug_assert`). Recorded as the schedule's failure.
    pub(crate) fn thread_panicked(&self, vtid: usize, msg: &str) {
        if let Ok(mut s) = self.sched.lock() {
            let name = s.threads.get(vtid).map(|t| t.name.clone()).unwrap_or_default();
            self.fail(&mut s, format!("thread t{vtid}:{name} panicked: {msg}"));
        }
    }

    /// Mark a thread finished and hand the baton on. Never panics —
    /// it runs during unwinds and in thread-exit wrappers.
    pub(crate) fn op_finish(&self, me: usize) {
        let Ok(mut s) = self.sched.lock() else { return };
        if matches!(s.threads[me].run, Run::Finished) {
            return;
        }
        s.threads[me].run = Run::Finished;
        s.live -= 1;
        s.trace(|| format!("t{me} finished"));
        if s.failure.is_some() {
            self.done.notify_all();
            return;
        }
        match self.pick_next(&mut s) {
            Pick::Grant(next) => {
                let gate = Arc::clone(&s.threads[next].gate);
                drop(s);
                grant(&gate);
            }
            Pick::AllDone => self.done.notify_all(),
            Pick::Aborted => {}
        }
    }

    pub(crate) fn op_join(&self, me: usize, target: usize) {
        let Some(mut s) = self.begin(me, || format!("join t{target}")) else { return };
        loop {
            if matches!(s.threads[target].run, Run::Finished) {
                let c = s.threads[target].clock.clone();
                s.threads[me].clock.join(&c);
                s.threads[me].run = Run::Runnable;
                return;
            }
            let Some(ns) = self.block(s, me, Run::JoinWait { target }) else { return };
            s = ns;
        }
    }

    /// Forget per-object state when a shim primitive is dropped, so a
    /// later allocation reusing the address cannot inherit stale
    /// clocks. Passive: no decision point, never panics.
    pub(crate) fn op_retire(&self, addr: usize) {
        if let Ok(mut s) = self.sched.lock() {
            s.mutexes.remove(&addr);
            s.atomics.remove(&addr);
        }
    }

    // -- scope-token invariant --------------------------------------------

    fn scope_new(&self, me: usize) -> u64 {
        let Some(mut s) = self.begin(me, || "scope-new".to_string()) else { return u64::MAX };
        let id = s.scopes.len() as u64;
        s.scopes.push(Vec::new());
        id
    }

    fn scope_publish(&self, me: usize, id: u64) {
        let Some(mut s) = self.begin(me, || format!("scope-token #{id}")) else { return };
        let clock = s.threads[me].clock.clone();
        if let Some(tokens) = s.scopes.get_mut(id as usize) {
            tokens.push(clock);
        }
    }

    fn scope_assert(&self, me: usize, id: u64) {
        let Some(mut s) = self.begin(me, || format!("scope-assert #{id}")) else { return };
        let bad = s.scopes.get(id as usize).and_then(|tokens| {
            tokens.iter().position(|t| !s.threads[me].clock.dominates(t))
        });
        if let Some(k) = bad {
            let msg = format!(
                "scope-ordering violation: waiter t{me} exited scope #{id} without a \
                 happens-before edge from completed task {k} — the latch decrement or \
                 completion wake does not publish (missing release/acquire ordering)",
                );
            self.fail(&mut s, msg);
            drop(s);
            self.bail();
        }
    }

    // -- end-of-schedule ----------------------------------------------------

    /// Root finished: wait (with a watchdog) for every scenario thread
    /// to unwind, then extract the schedule result.
    fn finish_and_collect(&self) -> ScheduleResult {
        self.op_finish(0);
        let mut s = self.sched.lock().unwrap();
        let mut waited = Duration::ZERO;
        while s.live > 0 && waited < EXIT_WATCHDOG {
            let (ns, _) = self.done.wait_timeout(s, Duration::from_millis(50)).unwrap();
            s = ns;
            waited += Duration::from_millis(50);
        }
        if s.live > 0 && s.failure.is_none() {
            let n = s.live;
            s.failure =
                Some(format!("{n} scenario thread(s) failed to exit within the watchdog"));
        }
        ScheduleResult {
            taken: std::mem::take(&mut s.taken),
            trace: std::mem::take(&mut s.trace),
            failure: s.failure.clone(),
            forced_timeouts: s.forced_timeouts,
        }
    }
}

// ---------------------------------------------------------------------------
// Scope-token entry points (called from runtime/pool.rs)
// ---------------------------------------------------------------------------

/// New scope-token id for the current schedule, or `None` outside a
/// model-checked run. Compiled to a constant `None` without the
/// `modelcheck` feature — production scopes pay nothing.
pub fn scope_new_current() -> Option<u64> {
    current().map(|(ctl, me)| ctl.scope_new(me))
}

/// Publish the current thread's clock as a completed-task token.
pub fn scope_publish(id: u64) {
    if let Some((ctl, me)) = current() {
        ctl.scope_publish(me, id);
    }
}

/// Assert the scope waiter happens-after every published token.
pub fn scope_assert(id: u64) {
    if let Some((ctl, me)) = current() {
        ctl.scope_assert(me, id);
    }
}

// ---------------------------------------------------------------------------
// Exploration driver
// ---------------------------------------------------------------------------

/// Exploration strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum McMode {
    /// Systematic bounded-preemption DFS over the decision tree.
    Dfs,
    /// PCG-seeded random schedule sampling (schedule `i` uses stream
    /// `i` of the base seed).
    Random,
}

/// Budgets and reproducibility knobs for [`explore`].
#[derive(Clone, Debug)]
pub struct McConfig {
    pub mode: McMode,
    pub max_schedules: u64,
    /// Voluntary-switch budget per schedule (CHESS-style); random
    /// mode typically leaves this unbounded.
    pub max_preemptions: u32,
    pub max_steps: u64,
    pub seed: u64,
    /// Treat a forced timeout wake as a failure (a lost wakeup): the
    /// pool's park protocol never needs its timeout safety nets.
    pub fail_on_forced_timeout: bool,
}

impl McConfig {
    /// Systematic DFS for small scenarios.
    pub fn dfs() -> McConfig {
        McConfig {
            mode: McMode::Dfs,
            max_schedules: 4000,
            max_preemptions: 2,
            max_steps: 200_000,
            seed: 0xFA57_6A55,
            fail_on_forced_timeout: true,
        }
    }

    /// Random sampling for scenarios too large to enumerate.
    pub fn random(max_schedules: u64) -> McConfig {
        McConfig {
            mode: McMode::Random,
            max_schedules,
            max_preemptions: u32::MAX,
            max_steps: 400_000,
            seed: 0xFA57_6A55,
            fail_on_forced_timeout: true,
        }
    }

    /// Apply `FASTGAUSS_MC_SEED` / `FASTGAUSS_MC_SCHEDULES` overrides
    /// (decimal or `0x`-prefixed hex), the CI reproducibility hook.
    pub fn from_env(mut self) -> McConfig {
        if let Some(seed) = env_u64("FASTGAUSS_MC_SEED") {
            self.seed = seed;
        }
        if let Some(n) = env_u64("FASTGAUSS_MC_SCHEDULES") {
            self.max_schedules = n;
        }
        self
    }
}

fn env_u64(key: &str) -> Option<u64> {
    let v = std::env::var(key).ok()?;
    let v = v.trim();
    if let Some(hex) = v.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}

/// A schedule that violated an invariant, with everything needed to
/// reproduce it bitwise.
#[derive(Clone, Debug)]
pub struct McFailure {
    pub message: String,
    /// Index of the failing schedule within its run.
    pub schedule: u64,
    pub seed: u64,
    /// The decision sequence; feed to [`replay`].
    pub choices: Vec<u32>,
    pub trace: Vec<String>,
}

impl std::fmt::Display for McFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "model-check failure at schedule #{} (seed {:#x}): {}",
            self.schedule, self.seed, self.message
        )?;
        writeln!(f, "replay choices: {:?}", self.choices)?;
        write!(f, "trace ({} events):", self.trace.len())?;
        for line in &self.trace {
            write!(f, "\n  {line}")?;
        }
        Ok(())
    }
}

/// Outcome of one [`explore`]/[`replay`] run.
#[derive(Clone, Debug)]
pub struct McReport {
    pub schedules: u64,
    /// DFS only: the whole bounded tree was enumerated.
    pub exhausted: bool,
    pub forced_timeouts: u64,
    pub failure: Option<McFailure>,
    pub seed: u64,
}

impl McReport {
    pub fn ok(&self) -> bool {
        self.failure.is_none()
    }
}

struct ScheduleResult {
    taken: Vec<(u32, u32)>,
    trace: Vec<String>,
    failure: Option<String>,
    forced_timeouts: u64,
}

/// Run one schedule of `scenario` under a fresh controller, with this
/// thread as virtual thread 0.
fn run_one(cfg: &McConfig, chooser: Chooser, scenario: &dyn Fn()) -> ScheduleResult {
    let ctl = Controller::new(cfg, chooser);
    set_current(Some((Arc::clone(&ctl), 0)));
    let outcome = catch_unwind(AssertUnwindSafe(scenario));
    set_current(None);
    if let Err(payload) = outcome {
        if payload.downcast_ref::<McAbort>().is_none() {
            ctl.thread_panicked(0, &payload_msg(payload.as_ref()));
        }
    }
    ctl.finish_and_collect()
}

pub(crate) fn payload_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Body of a thread spawned through the shim while registered with a
/// controller (`sync::spawn_thread` real-spawns this wrapper): install
/// the thread-local identity, wait for the first schedule grant, run
/// the payload with abort-aware panic capture, and mark the virtual
/// thread finished no matter how the payload exits.
pub fn run_child<F: FnOnce()>(ctl: Arc<Controller>, vtid: usize, f: F) {
    set_current(Some((Arc::clone(&ctl), vtid)));
    if ctl.child_start(vtid) {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
            if payload.downcast_ref::<McAbort>().is_none() {
                ctl.thread_panicked(vtid, &payload_msg(payload.as_ref()));
            }
        }
    }
    set_current(None);
    ctl.op_finish(vtid);
}

/// Given the decisions one DFS schedule took, compute the next path
/// to force (increment the deepest incrementable choice), or `None`
/// when the bounded tree is exhausted.
fn next_dfs_path(mut taken: Vec<(u32, u32)>) -> Option<Vec<u32>> {
    loop {
        let (choice, options) = taken.pop()?;
        if choice + 1 < options {
            let mut path: Vec<u32> = taken.iter().map(|&(c, _)| c).collect();
            path.push(choice + 1);
            return Some(path);
        }
    }
}

fn failure_report(
    cfg: &McConfig,
    schedules: u64,
    index: u64,
    res: ScheduleResult,
    message: String,
) -> McReport {
    let failure = McFailure {
        message,
        schedule: index,
        seed: cfg.seed,
        choices: res.taken.iter().map(|&(c, _)| c).collect(),
        trace: res.trace,
    };
    dump_trace(&failure);
    McReport {
        schedules,
        exhausted: false,
        forced_timeouts: res.forced_timeouts,
        failure: Some(failure),
        seed: cfg.seed,
    }
}

/// Save a failing trace under `FASTGAUSS_MC_TRACE_DIR` (the CI
/// artifact hook); silently skipped when unset or unwritable.
fn dump_trace(failure: &McFailure) {
    let Ok(dir) = std::env::var("FASTGAUSS_MC_TRACE_DIR") else { return };
    if dir.is_empty() {
        return;
    }
    let _ = std::fs::create_dir_all(&dir);
    let path = format!("{dir}/mc-{:#x}-{}.txt", failure.seed, failure.schedule);
    let _ = std::fs::write(path, format!("{failure}\n"));
}

/// Explore interleavings of `scenario` under `cfg`. The scenario runs
/// once per schedule on the calling thread (virtual thread 0); it
/// must be deterministic apart from scheduling, and must join every
/// thread it spawns (the pool's `Drop` does). Panics the scenario
/// *intends* to propagate must be caught inside it — any panic
/// escaping a scenario thread is reported as a failure.
pub fn explore(cfg: &McConfig, scenario: impl Fn()) -> McReport {
    assert!(
        cfg!(feature = "modelcheck"),
        "modelcheck::explore requires --features modelcheck (the sync shim \
         does not route operations without it)"
    );
    let mut forced = 0u64;
    match cfg.mode {
        McMode::Dfs => {
            let mut path: Vec<u32> = Vec::new();
            let mut schedules = 0u64;
            loop {
                if schedules >= cfg.max_schedules {
                    return McReport {
                        schedules,
                        exhausted: false,
                        forced_timeouts: forced,
                        failure: None,
                        seed: cfg.seed,
                    };
                }
                let chooser = Chooser::Script { path: path.clone(), at: 0 };
                let res = run_one(cfg, chooser, &scenario);
                let index = schedules;
                schedules += 1;
                forced += res.forced_timeouts;
                if let Some(msg) = res.failure.clone() {
                    return failure_report(cfg, schedules, index, res, msg);
                }
                match next_dfs_path(res.taken) {
                    Some(p) => path = p,
                    None => {
                        return McReport {
                            schedules,
                            exhausted: true,
                            forced_timeouts: forced,
                            failure: None,
                            seed: cfg.seed,
                        };
                    }
                }
            }
        }
        McMode::Random => {
            for i in 0..cfg.max_schedules {
                let chooser = Chooser::Random(Pcg32::new_stream(cfg.seed, i));
                let res = run_one(cfg, chooser, &scenario);
                forced += res.forced_timeouts;
                if let Some(msg) = res.failure.clone() {
                    return failure_report(cfg, i + 1, i, res, msg);
                }
            }
            McReport {
                schedules: cfg.max_schedules,
                exhausted: false,
                forced_timeouts: forced,
                failure: None,
                seed: cfg.seed,
            }
        }
    }
}

/// Re-run exactly one schedule from its recorded decision sequence —
/// the bitwise replay contract for a failure's `choices`.
pub fn replay(cfg: &McConfig, choices: &[u32], scenario: impl Fn()) -> McReport {
    assert!(
        cfg!(feature = "modelcheck"),
        "modelcheck::replay requires --features modelcheck"
    );
    let chooser = Chooser::Script { path: choices.to_vec(), at: 0 };
    let res = run_one(cfg, chooser, &scenario);
    match res.failure.clone() {
        Some(msg) => failure_report(cfg, 1, 0, res, msg),
        None => McReport {
            schedules: 1,
            exhausted: false,
            forced_timeouts: res.forced_timeouts,
            failure: None,
            seed: cfg.seed,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vclock_join_tick_dominates() {
        let mut a = VClock::default();
        a.tick(0);
        a.tick(0);
        let mut b = VClock::default();
        b.tick(2);
        assert!(!a.dominates(&b));
        assert!(!b.dominates(&a));
        a.join(&b);
        assert!(a.dominates(&b));
        assert_eq!(a.0, vec![2, 0, 1]);
        // domination ignores trailing zeros on either side
        let c = VClock(vec![2, 0, 1, 0]);
        assert!(a.dominates(&c) && c.dominates(&a));
    }

    #[test]
    fn scripted_chooser_replays_then_defaults_to_first() {
        let mut ch = Chooser::Script { path: vec![1, 2], at: 0 };
        assert_eq!(ch.pick(3), 1);
        assert_eq!(ch.pick(2), 1, "out-of-range scripted choices clamp");
        assert_eq!(ch.pick(5), 0, "past the prefix, take the first option");
    }

    #[test]
    fn random_chooser_is_deterministic_per_stream() {
        let mut a = Chooser::Random(Pcg32::new_stream(7, 3));
        let mut b = Chooser::Random(Pcg32::new_stream(7, 3));
        for _ in 0..64 {
            assert_eq!(a.pick(5), b.pick(5));
        }
    }

    #[test]
    fn dfs_advance_enumerates_the_whole_tree() {
        // simulate a fixed 2x3 decision tree and count the leaves DFS visits
        let mut path: Vec<u32> = Vec::new();
        let mut leaves = Vec::new();
        loop {
            let mut ch = Chooser::Script { path: path.clone(), at: 0 };
            let a = ch.pick(2);
            let b = ch.pick(3);
            leaves.push((a, b));
            let taken = vec![(a, 2), (b, 3)];
            match next_dfs_path(taken) {
                Some(p) => path = p,
                None => break,
            }
        }
        assert_eq!(leaves.len(), 6);
        let expect: Vec<(u32, u32)> =
            (0..2).flat_map(|a| (0..3).map(move |b| (a, b))).collect();
        assert_eq!(leaves, expect);
    }

    #[test]
    fn env_u64_parses_decimal_and_hex() {
        std::env::set_var("FASTGAUSS_MC_TEST_ENV_A", "123");
        std::env::set_var("FASTGAUSS_MC_TEST_ENV_B", "0xff");
        assert_eq!(env_u64("FASTGAUSS_MC_TEST_ENV_A"), Some(123));
        assert_eq!(env_u64("FASTGAUSS_MC_TEST_ENV_B"), Some(255));
        assert_eq!(env_u64("FASTGAUSS_MC_TEST_ENV_MISSING"), None);
    }

    /// Hand-stepped controllers drive several vtids from one real
    /// thread; a zero preemption budget keeps `reschedule` from ever
    /// handing the baton to a gate nobody waits on.
    fn hand_stepped() -> Arc<Controller> {
        let cfg = McConfig { max_preemptions: 0, ..McConfig::dfs() };
        Controller::new(&cfg, Chooser::Script { path: Vec::new(), at: 0 })
    }

    #[test]
    fn release_sequence_semantics_on_atomics() {
        let ctl = hand_stepped();
        let writer = ctl.op_spawn_register(0, "writer");
        assert_eq!(writer, 1);
        let addr = 0x1000;
        // release store publishes t1's clock...
        ctl.op_atomic(writer, addr, AtomicAccess::Store, Release);
        let t1_at_store = ctl.sched.lock().unwrap().threads[writer].clock.clone();
        // ...a relaxed RMW (another thread's fetch_sub) keeps the
        // sequence alive...
        ctl.op_atomic(0, addr, AtomicAccess::Rmw, Relaxed);
        // ...so an acquire load still joins the writer's clock
        ctl.op_atomic(0, addr, AtomicAccess::Load, Acquire);
        let t0 = ctl.sched.lock().unwrap().threads[0].clock.clone();
        assert!(t0.dominates(&t1_at_store), "release sequence must survive a relaxed RMW");
        // but a relaxed *store* breaks the sequence
        ctl.op_atomic(writer, addr, AtomicAccess::Store, Release);
        ctl.op_atomic(writer, addr, AtomicAccess::Store, Relaxed);
        let t1_latest = ctl.sched.lock().unwrap().threads[writer].clock.clone();
        ctl.op_atomic(0, addr, AtomicAccess::Load, Acquire);
        let t0 = ctl.sched.lock().unwrap().threads[0].clock.clone();
        assert!(
            !t0.dominates(&t1_latest),
            "a relaxed store must break the release sequence"
        );
        ctl.op_finish(writer);
    }

    #[test]
    fn mutex_clock_flows_from_releaser_to_acquirer() {
        let ctl = hand_stepped();
        let other = ctl.op_spawn_register(0, "other");
        let addr = 0x2000;
        ctl.op_mutex_lock(other, addr);
        let held = ctl.sched.lock().unwrap().threads[other].clock.clone();
        ctl.op_mutex_unlock(other, addr);
        ctl.op_mutex_lock(0, addr);
        let mine = ctl.sched.lock().unwrap().threads[0].clock.clone();
        assert!(mine.dominates(&held));
        ctl.op_mutex_unlock(0, addr);
        ctl.op_finish(other);
    }
}
