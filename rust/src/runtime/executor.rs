//! The tile executor: compile one artifact per dimension on the PJRT
//! CPU client and stream (query tile × reference chunk) executions
//! through it, handling all padding at this boundary so callers work
//! with natural sizes.
//!
//! The real implementation needs the `xla` PJRT bindings, which are not
//! vendored in this offline tree; it is gated behind the `pjrt` cargo
//! feature. Without the feature a stub with the identical API is built
//! whose `load` fails with a descriptive error, so code that names
//! `TileExecutor` behind runtime `cfg!` guards (the `kernels` bench)
//! still compiles. [`super::TiledNaive`] no longer routes through the
//! stub at all — without `pjrt` it falls back to the
//! [`crate::compute`] CPU microkernel instead.

#[cfg(feature = "pjrt")]
pub use pjrt_impl::TileExecutor;
#[cfg(not(feature = "pjrt"))]
pub use stub::TileExecutor;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use crate::anyhow;
    use crate::geometry::Matrix;
    use crate::kernel::GaussianKernel;
    use crate::util::error::{Context, Result};

    use super::super::artifact::{ArtifactManifest, ArtifactSpec};

    /// A compiled Gaussian-chunk executable for one dimension.
    pub struct TileExecutor {
        exe: xla::PjRtLoadedExecutable,
        spec: ArtifactSpec,
    }

    impl TileExecutor {
        /// Compile the artifact for `dim` from `dir` on a fresh CPU client.
        pub fn load(dir: &std::path::Path, dim: usize) -> Result<Self> {
            let manifest = ArtifactManifest::load(dir)?;
            let spec = manifest
                .spec(dim)
                .ok_or_else(|| anyhow!("no artifact for D={dim} (run `make artifacts`)"))?
                .clone();
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )
            .with_context(|| format!("parsing {}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("XLA compile")?;
            Ok(TileExecutor { exe, spec })
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        /// Execute one padded (TQ × NR) chunk. Inputs must already have the
        /// artifact's exact shapes (flat row-major).
        fn execute_raw(&self, q: &[f64], r: &[f64], w: &[f64], s: f64) -> Result<Vec<f64>> {
            let d = self.spec.dim as i64;
            let tq = self.spec.tile_queries as i64;
            let nr = self.spec.chunk_refs as i64;
            debug_assert_eq!(q.len() as i64, tq * d);
            debug_assert_eq!(r.len() as i64, nr * d);
            debug_assert_eq!(w.len() as i64, nr);
            let ql = xla::Literal::vec1(q).reshape(&[tq, d])?;
            let rl = xla::Literal::vec1(r).reshape(&[nr, d])?;
            let wl = xla::Literal::vec1(w);
            let sl = xla::Literal::vec1(&[s]);
            let out = self.exe.execute::<xla::Literal>(&[ql, rl, wl, sl])?[0][0]
                .to_literal_sync()?
                .to_tuple1()?;
            Ok(out.to_vec::<f64>()?)
        }

        /// Full Gaussian summation of `queries` against `(references,
        /// weights)` at bandwidth `h`: pads/chunks everything to the
        /// artifact shapes and accumulates partial sums across chunks.
        pub fn gauss_sum(
            &self,
            queries: &Matrix,
            references: &Matrix,
            weights: &[f64],
            h: f64,
        ) -> Result<Vec<f64>> {
            let d = self.spec.dim;
            crate::ensure!(queries.cols() == d && references.cols() == d, "dim mismatch");
            crate::ensure!(weights.len() == references.rows(), "weights length");
            let kernel = GaussianKernel::new(h);
            let s = -0.5 / (h * h);
            let _ = kernel; // kernel kept for parity/validation hooks
            let tq = self.spec.tile_queries;
            let nr = self.spec.chunk_refs;

            let mut sums = vec![0.0; queries.rows()];
            let mut qbuf = vec![0.0; tq * d];
            let mut rbuf = vec![0.0; nr * d];
            let mut wbuf = vec![0.0; nr];

            for q0 in (0..queries.rows()).step_by(tq) {
                let qn = (q0 + tq).min(queries.rows()) - q0;
                qbuf.fill(0.0);
                for i in 0..qn {
                    qbuf[i * d..(i + 1) * d].copy_from_slice(queries.row(q0 + i));
                }
                for r0 in (0..references.rows()).step_by(nr) {
                    let rn = (r0 + nr).min(references.rows()) - r0;
                    rbuf.fill(0.0);
                    wbuf.fill(0.0); // zero weight ⇒ padded rows contribute 0
                    for i in 0..rn {
                        rbuf[i * d..(i + 1) * d].copy_from_slice(references.row(r0 + i));
                        wbuf[i] = weights[r0 + i];
                    }
                    let part = self.execute_raw(&qbuf, &rbuf, &wbuf, s)?;
                    for i in 0..qn {
                        sums[q0 + i] += part[i];
                    }
                }
            }
            Ok(sums)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::geometry::Matrix;
    use crate::util::error::Result;

    use super::super::artifact::ArtifactSpec;

    /// Unconstructible placeholder built when the `pjrt` feature is off.
    pub struct TileExecutor {
        spec: ArtifactSpec,
        never: std::convert::Infallible,
    }

    impl TileExecutor {
        /// Always fails: the PJRT bindings are not part of this build.
        pub fn load(_dir: &std::path::Path, dim: usize) -> Result<Self> {
            Err(crate::anyhow!(
                "PJRT runtime unavailable: fastgauss was built without the `pjrt` \
                 feature, so the artifact for D={dim} cannot be executed \
                 (rebuild with `--features pjrt` and the xla bindings)"
            ))
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        pub fn gauss_sum(
            &self,
            _queries: &Matrix,
            _references: &Matrix,
            _weights: &[f64],
            _h: f64,
        ) -> Result<Vec<f64>> {
            match self.never {}
        }
    }
}

#[cfg(test)]
mod tests {
    #[allow(unused_imports)]
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let err = TileExecutor::load(std::path::Path::new("artifacts"), 2).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    mod with_pjrt {
        use super::super::*;
        use crate::algo::max_relative_error;
        use crate::algo::{naive::Naive, GaussSum, GaussSumProblem};
        use crate::geometry::Matrix;
        use crate::util::Pcg32;

        fn artifacts_available() -> bool {
            crate::runtime::artifacts_dir().join("manifest.json").exists()
        }

        fn random(n: usize, d: usize, seed: u64) -> Matrix {
            let mut rng = Pcg32::new(seed);
            Matrix::from_rows(
                &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
            )
        }

        /// End-to-end: PJRT chunk execution equals the rust naive sum.
        /// (Requires `make artifacts`; skipped otherwise.)
        #[test]
        fn pjrt_matches_rust_naive() {
            if !artifacts_available() {
                eprintln!("skipping: no artifacts");
                return;
            }
            let exec = TileExecutor::load(&crate::runtime::artifacts_dir(), 2).unwrap();
            // sizes that exercise both query and reference padding
            let q = random(300, 2, 21);
            let r = random(5000, 2, 22);
            let mut rng = Pcg32::new(23);
            let w: Vec<f64> = (0..5000).map(|_| rng.uniform_in(0.1, 2.0)).collect();
            let h = 0.2;
            let got = exec.gauss_sum(&q, &r, &w, h).unwrap();
            let p = GaussSumProblem::new(&q, &r, Some(&w), h, 0.01);
            let want = Naive::new().run(&p).unwrap().sums;
            assert!(max_relative_error(&got, &want) < 1e-9);
        }

        #[test]
        fn load_missing_dim_errors() {
            if !artifacts_available() {
                return;
            }
            assert!(TileExecutor::load(&crate::runtime::artifacts_dir(), 4).is_err());
        }

        #[test]
        fn spec_shapes_consistent() {
            if !artifacts_available() {
                return;
            }
            let exec = TileExecutor::load(&crate::runtime::artifacts_dir(), 3).unwrap();
            let s = exec.spec();
            assert_eq!(s.dim, 3);
            assert_eq!(s.chunk_refs % s.block_refs, 0);
        }
    }
}
