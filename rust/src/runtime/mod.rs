//! Execution runtime: the shared work-stealing task pool every fan-out
//! in the crate schedules onto ([`pool`]), the sync shim every
//! runtime-layer primitive routes through ([`sync`]) and the
//! deterministic schedule explorer behind it ([`modelcheck`]), plus
//! the PJRT path — load
//! the AOT-compiled HLO artifacts (`make artifacts`) and execute them
//! from the rust hot path. Python never runs here — the artifacts are
//! self-contained HLO text compiled once per process by the XLA CPU
//! backend. Built without the `pjrt` feature, [`TiledNaive`] degrades
//! gracefully to the [`crate::compute`] SoA microkernel so every bench
//! and CLI path still runs.

pub mod artifact;
pub mod executor;
pub mod modelcheck;
pub mod pool;
pub mod sync;
pub mod tiled_naive;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use executor::TileExecutor;
pub use pool::WorkStealPool;
pub use tiled_naive::TiledNaive;

/// Default artifacts directory, overridable with `FASTGAUSS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FASTGAUSS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
