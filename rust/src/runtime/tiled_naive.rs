//! `TiledNaive`: the exhaustive baseline executed through the AOT
//! PJRT artifacts — i.e. the L1 Pallas kernel driven from the L3 rust
//! coordinator with python nowhere in sight. Implements [`GaussSum`] so
//! the bench harness can swap it in for the pure-rust `Naive`.

use std::sync::Mutex;

use crate::algo::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

use super::executor::TileExecutor;

/// Exhaustive summation through the compiled artifact for its dimension.
pub struct TiledNaive {
    exec: Mutex<TileExecutor>,
    dim: usize,
}

impl TiledNaive {
    /// Load the artifact for `dim` from the default artifacts directory.
    pub fn load(dim: usize) -> crate::util::error::Result<Self> {
        let exec = TileExecutor::load(&super::artifacts_dir(), dim)?;
        Ok(TiledNaive { exec: Mutex::new(exec), dim })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

impl GaussSum for TiledNaive {
    fn name(&self) -> &'static str {
        "Naive(PJRT)"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        assert_eq!(problem.dim(), self.dim, "artifact dimension mismatch");
        let w = problem.weight_vec();
        let sums = self
            .exec
            .lock()
            .unwrap()
            .gauss_sum(problem.queries, problem.references, &w, problem.h)
            .map_err(|e| AlgoError::RamExhausted(format!("PJRT failure: {e}")))?;
        let stats = RunStats {
            base_point_pairs: (problem.num_queries() * problem.num_references()) as u64,
            ..Default::default()
        };
        Ok(GaussSumResult { sums, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    #[test]
    fn matches_pure_rust_naive() {
        if !cfg!(feature = "pjrt")
            || !crate::runtime::artifacts_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: no pjrt feature or no artifacts");
            return;
        }
        let mut rng = Pcg32::new(31);
        let data = Matrix::from_rows(
            &(0..700).map(|_| vec![rng.uniform(), rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        let p = GaussSumProblem::kde(&data, 0.15, 0.01);
        let tiled = TiledNaive::load(3).unwrap();
        let a = tiled.run(&p).unwrap().sums;
        let b = Naive::new().run(&p).unwrap().sums;
        assert!(max_relative_error(&a, &b) < 1e-10);
        assert_eq!(tiled.name(), "Naive(PJRT)");
    }
}
