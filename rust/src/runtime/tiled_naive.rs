//! `TiledNaive`: the exhaustive baseline executed through the AOT
//! PJRT artifacts — i.e. the L1 Pallas kernel driven from the L3 rust
//! coordinator with python nowhere in sight. Implements [`GaussSum`] so
//! the bench harness can swap it in for the pure-rust `Naive`.
//!
//! Without the `pjrt` cargo feature the executor bindings don't exist;
//! instead of erroring through the stub, [`TiledNaive::load`] degrades
//! to a CPU backend on the shared [`crate::compute`] SoA microkernel
//! (logged once per process), so benches and the CLI `runtime` command
//! run everywhere. With the feature enabled, a missing artifact is
//! still a hard error — that's a build/setup problem, not a platform
//! limitation.

use crate::algo::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

#[cfg(feature = "pjrt")]
use super::executor::TileExecutor;
#[cfg(feature = "pjrt")]
use super::sync::SyncMutex;

/// Reference block width of the CPU fallback — matches the default
/// `algo::naive` tiling, so fallback results are bit-identical to
/// `Naive::new()`.
#[cfg(not(feature = "pjrt"))]
const CPU_FALLBACK_BLOCK: usize = 256;

/// Exhaustive summation through the compiled artifact for its dimension
/// (or the CPU microkernel fallback when built without `pjrt`).
pub struct TiledNaive {
    #[cfg(feature = "pjrt")]
    exec: SyncMutex<TileExecutor>,
    dim: usize,
    /// CPU fallback only: run the GEMM-shaped fast driver
    /// (`compute::gauss_sum_all_fast`) instead of the bit-exact
    /// microkernel. Off by default so the fallback stays bit-identical
    /// to `algo::naive::Naive::new()` (the documented contract).
    #[cfg_attr(feature = "pjrt", allow(dead_code))]
    fast_exp: bool,
}

impl TiledNaive {
    /// Load the artifact for `dim` from the default artifacts directory.
    #[cfg(feature = "pjrt")]
    pub fn load(dim: usize) -> crate::util::error::Result<Self> {
        let exec = TileExecutor::load(&super::artifacts_dir(), dim)?;
        Ok(TiledNaive { exec: SyncMutex::new(exec), dim, fast_exp: false })
    }

    /// Built without `pjrt`: fall back to the CPU compute microkernel.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(dim: usize) -> crate::util::error::Result<Self> {
        static FALLBACK_NOTICE: super::sync::SyncAtomicBool =
            super::sync::SyncAtomicBool::new(false);
        // ORDER: AcqRel — first swap wins the once-per-process notice.
        if !FALLBACK_NOTICE.swap(true, super::sync::Ordering::AcqRel) {
            crate::log_warn!(
                "PJRT runtime unavailable (built without the `pjrt` feature); \
                 TiledNaive falls back to the CPU compute microkernel"
            );
        }
        Ok(TiledNaive { dim, fast_exp: false })
    }

    /// Opt the CPU fallback into the certified fast tiled driver
    /// (norms trick + `exp_block`; no effect on the PJRT path, whose
    /// kernel is fixed at artifact-compile time).
    pub fn with_fast_exp(mut self, on: bool) -> Self {
        self.fast_exp = on;
        self
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True when this instance runs on the CPU microkernel instead of a
    /// PJRT artifact.
    pub fn is_cpu_fallback(&self) -> bool {
        cfg!(not(feature = "pjrt"))
    }

    #[cfg(feature = "pjrt")]
    fn sums_for(&self, problem: &GaussSumProblem<'_>, w: &[f64]) -> Result<Vec<f64>, AlgoError> {
        self.exec
            .lock()
            .unwrap()
            .gauss_sum(problem.queries, problem.references, w, problem.h)
            .map_err(|e| AlgoError::RamExhausted(format!("PJRT failure: {e}")))
    }

    #[cfg(not(feature = "pjrt"))]
    fn sums_for(&self, problem: &GaussSumProblem<'_>, w: &[f64]) -> Result<Vec<f64>, AlgoError> {
        let kernel = crate::kernel::GaussianKernel::new(problem.h);
        let mut scratch = crate::compute::Scratch::with_block(
            self.dim,
            CPU_FALLBACK_BLOCK.min(problem.num_references()).max(1),
        );
        let mut sums = vec![0.0; problem.num_queries()];
        if self.fast_exp {
            crate::compute::gauss_sum_all_fast(
                problem.queries,
                problem.references,
                w,
                &kernel,
                CPU_FALLBACK_BLOCK,
                &mut scratch,
                &mut sums,
            );
        } else {
            crate::compute::gauss_sum_all(
                problem.queries,
                problem.references,
                w,
                &kernel,
                CPU_FALLBACK_BLOCK,
                &mut scratch,
                &mut sums,
            );
        }
        Ok(sums)
    }
}

impl GaussSum for TiledNaive {
    fn name(&self) -> &'static str {
        if cfg!(feature = "pjrt") {
            "Naive(PJRT)"
        } else {
            "Naive(TiledCPU)"
        }
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        assert_eq!(problem.dim(), self.dim, "artifact dimension mismatch");
        let w = problem.weight_vec();
        let sums = self.sums_for(problem, &w)?;
        let stats = RunStats {
            base_point_pairs: (problem.num_queries() * problem.num_references()) as u64,
            ..Default::default()
        };
        Ok(GaussSumResult { sums, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn random3d(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n)
                .map(|_| vec![rng.uniform(), rng.uniform(), rng.uniform()])
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn matches_pure_rust_naive() {
        if cfg!(feature = "pjrt")
            && !crate::runtime::artifacts_dir().join("manifest.json").exists()
        {
            eprintln!("skipping: pjrt feature on but no artifacts");
            return;
        }
        // with pjrt + artifacts this exercises the compiled kernel;
        // without pjrt it exercises the CPU microkernel fallback
        let data = random3d(700, 31);
        let p = GaussSumProblem::kde(&data, 0.15, 0.01);
        let tiled = TiledNaive::load(3).unwrap();
        let a = tiled.run(&p).unwrap().sums;
        let b = Naive::new().run(&p).unwrap().sums;
        assert!(max_relative_error(&a, &b) < 1e-10);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn cpu_fallback_loads_any_dimension_and_is_bitwise_naive() {
        let tiled = TiledNaive::load(3).unwrap();
        assert!(tiled.is_cpu_fallback());
        assert_eq!(tiled.name(), "Naive(TiledCPU)");
        assert_eq!(tiled.dim(), 3);
        let data = random3d(300, 32);
        let mut rng = Pcg32::new(33);
        let w: Vec<f64> = (0..300).map(|_| rng.uniform_in(0.2, 2.0)).collect();
        let p = GaussSumProblem::new(&data, &data, Some(&w), 0.2, 0.01);
        let a = tiled.run(&p).unwrap();
        let b = Naive::new().run(&p).unwrap();
        // same block width, same microkernel → identical arithmetic
        assert_eq!(a.sums, b.sums);
        assert_eq!(a.stats.base_point_pairs, b.stats.base_point_pairs);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn cpu_fallback_fast_exp_matches_within_certified_budget() {
        let data = random3d(200, 34);
        let p = GaussSumProblem::kde(&data, 0.2, 0.01);
        let exact = TiledNaive::load(3).unwrap().run(&p).unwrap().sums;
        let fast = TiledNaive::load(3).unwrap().with_fast_exp(true).run(&p).unwrap().sums;
        for i in 0..200 {
            let rel = (fast[i] - exact[i]).abs() / exact[i];
            assert!(rel <= 1e-12, "i={i}: rel={rel:.2e}");
        }
    }
}
