//! The one shared work-stealing task pool under every fan-out in the
//! crate: dual-tree traversal splits, [`crate::api::Session`] request
//! batches, and the coordinator's (algorithm × bandwidth) sweep cells
//! all schedule onto the same workers, so nested parallelism composes
//! instead of fragmenting (a batch of 2 requests on an 8-worker pool
//! exposes 2 × up-to-[`crate::algo::dualtree::TRAVERSAL_TASKS`] leaf
//! tasks — every core stays busy, where the pre-pool design pinned
//! each request to one inner thread and left 6 cores idle).
//!
//! # Design
//!
//! * **Per-worker deques + stealing.** Each worker owns a deque; it
//!   pushes tasks it spawns onto its own deque (LIFO pop for cache
//!   locality) and steals FIFO from the injector or from other workers
//!   when its deque runs dry. External (non-worker) threads submit
//!   through the shared injector queue.
//! * **Scoped tasks, no `'static` bound.** [`WorkStealPool::scope`]
//!   mirrors `std::thread::scope`: tasks may borrow the caller's stack,
//!   and the scope does not return until every spawned task has
//!   finished (the lifetime erasure inside `spawn` is sound for exactly
//!   this reason).
//! * **Workers help, externals park.** A pool worker waiting on a
//!   nested scope executes pending tasks instead of blocking — this is
//!   what makes nested parallelism deadlock-free: a batch task that
//!   fans its traversal out into the same pool helps drain that work
//!   rather than occupying a worker with a bare wait. An *external*
//!   caller waiting on its scope just parks: its tasks drain on the
//!   workers anyway, and helping would let one stolen multi-second
//!   foreign task delay a cheap call long after its own tasks
//!   finished.
//! * **Deterministic indexed reduction.** [`WorkStealPool::run_indexed`]
//!   runs `n` tasks and returns their results **in index order**,
//!   regardless of which worker ran what when. Callers that combine
//!   floating-point partial results iterate that vector in order, so
//!   the combination order — and therefore every bit of the result —
//!   is independent of the pool width and of stealing. All three
//!   fan-outs are built on it.
//! * **Panic propagation.** A panicking task can neither poison the
//!   pool nor silently vanish: the first panic of a scope is captured
//!   and re-raised from `scope`/`run_indexed` on the waiting thread
//!   after the remaining tasks finish.
//! * **Inline mode.** `WorkStealPool::new(1)` spawns no threads at
//!   all: `spawn` runs the task immediately on the caller, in spawn
//!   order. Combined with the fixed task decomposition used by the
//!   traversal, results are bit-identical across every pool width —
//!   the determinism suite (`rust/tests/pool_determinism.rs`) pins
//!   widths {1, 2, 8}.
//! * **Model-checked.** Every lock, condvar, atomic and spawn below
//!   goes through [`crate::runtime::sync`], so under
//!   `--features modelcheck` the whole pool runs inside the
//!   deterministic scheduler of [`crate::runtime::modelcheck`] and the
//!   invariants above are checked across systematically explored
//!   interleavings (`rust/tests/modelcheck_pool.rs`). The `// ORDER:`
//!   comments on every non-SeqCst atomic are enforced by the
//!   `ordering-audit` lint rule.

use std::cell::Cell;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use crate::runtime::modelcheck;
use crate::runtime::sync::{
    self, Ordering, SyncAtomicBool, SyncAtomicU64, SyncAtomicUsize, SyncCondvar, SyncJoinHandle,
    SyncMutex,
};

/// A queued unit of work (lifetime-erased; see the safety comment in
/// [`PoolScope::spawn`]).
type RawTask = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// `(pool id, worker index)` when the current thread is a pool
    /// worker — lets `spawn` push to the worker's own deque and lets a
    /// nested `scope` help under the correct identity. A thread belongs
    /// to at most one pool, so the id disambiguates nested pools.
    static CURRENT_WORKER: Cell<Option<(u64, usize)>> = const { Cell::new(None) };
}

/// Process-unique pool ids for `CURRENT_WORKER` disambiguation.
static NEXT_POOL_ID: SyncAtomicU64 = SyncAtomicU64::new(0);

/// How long an idle worker parks between queue re-checks. The wake
/// protocol has no missed-wakeup window (pushers notify under the
/// `idle` lock, workers re-check the predicate under the same lock
/// before parking), so this is purely a safety net — generous, so an
/// idle pool costs ~1 wakeup/s/worker instead of busy-ticking. The
/// model checker pins the "safety net" claim: its invariant suites
/// treat a schedule that *needs* the timeout as a lost-wakeup failure.
const PARK_TIMEOUT: Duration = Duration::from_millis(1000);

/// How long a helping worker mid-scope parks when no task is runnable
/// (woken by task completions as well as pushes; same airtight
/// protocol, so also just a safety net).
const WAIT_TIMEOUT: Duration = Duration::from_millis(50);

/// Fault injection for the model-check suite. The public constructors
/// always use `Mutation::None`; [`WorkStealPool::new_mutated`] exists
/// so `rust/tests/modelcheck_pool.rs` can prove the checker detects a
/// deliberately broken pool within its schedule budget. Each variant
/// re-creates a classic pool bug:
///
/// * `RelaxedLatchDecrement` — downgrades the scope-latch decrement to
///   `Relaxed`, dropping the release edge that publishes a finished
///   task's writes to the scope waiter.
/// * `SkipCompletionWake` — a completing task no longer notifies the
///   condvar, losing the wakeup a parked scope waiter depends on.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mutation {
    None,
    RelaxedLatchDecrement,
    SkipCompletionWake,
}

struct Shared {
    id: u64,
    /// One deque per spawned worker (empty for an inline pool).
    deques: Vec<SyncMutex<VecDeque<RawTask>>>,
    /// Submission queue for external (non-worker) threads.
    injector: SyncMutex<VecDeque<RawTask>>,
    /// Tasks pushed but not yet popped — sleep/wake bookkeeping only.
    pending: SyncAtomicUsize,
    shutdown: SyncAtomicBool,
    idle: SyncMutex<()>,
    wake: SyncCondvar,
    /// Tasks executed per worker (telemetry; the determinism suite's
    /// engagement assertion reads this).
    worker_tasks: Vec<SyncAtomicU64>,
    /// Tasks executed inline or by helping external threads.
    external_tasks: SyncAtomicU64,
    /// Always `Mutation::None` outside the model-check suite.
    mutation: Mutation,
}

impl Shared {
    /// Account one popped task. `pending` is incremented *before* every
    /// push, so observing zero here means the accounting protocol broke.
    fn note_popped(&self) {
        // ORDER: AcqRel — pairs with the AcqRel increment in `push`:
        // the acquire half orders this decrement after the enqueue it
        // consumes, the release half publishes it to parking workers.
        let prev = self.pending.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "pool pending-task counter underflow");
    }

    /// Pop one runnable task: own deque (LIFO), then the injector, then
    /// steal FIFO from the other workers.
    fn pop_task(&self, me: Option<usize>) -> Option<RawTask> {
        if let Some(i) = me {
            if let Some(t) = self.deques[i].lock().unwrap().pop_back() {
                self.note_popped();
                return Some(t);
            }
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            self.note_popped();
            return Some(t);
        }
        let n = self.deques.len();
        let start = me.map_or(0, |i| i + 1);
        for k in 0..n {
            let j = (start + k) % n;
            if Some(j) == me {
                continue;
            }
            if let Some(t) = self.deques[j].lock().unwrap().pop_front() {
                self.note_popped();
                return Some(t);
            }
        }
        None
    }

    /// Pop and execute one task; `false` when nothing was runnable.
    fn run_one(&self, me: Option<usize>) -> bool {
        match self.pop_task(me) {
            Some(task) => {
                match me {
                    Some(i) => {
                        // ORDER: Relaxed — monotonic telemetry counter;
                        // readers tolerate staleness and never use it
                        // to order other memory.
                        self.worker_tasks[i].fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        // ORDER: Relaxed — telemetry, as above.
                        self.external_tasks.fetch_add(1, Ordering::Relaxed);
                    }
                }
                task();
                true
            }
            None => false,
        }
    }

    fn notify_all(&self) {
        let _guard = self.idle.lock().unwrap();
        self.wake.notify_all();
    }

    fn push(&self, task: RawTask) {
        // pending is incremented BEFORE the push so a racing pop can
        // never decrement below zero.
        //
        // ORDER: AcqRel — pairs with `note_popped`'s AcqRel decrement;
        // the release half makes the increment visible to a parking
        // worker's predicate check before the notify below.
        self.pending.fetch_add(1, Ordering::AcqRel);
        let me = CURRENT_WORKER.with(|c| c.get());
        match me {
            Some((pool, i)) if pool == self.id => {
                self.deques[i].lock().unwrap().push_back(task);
            }
            _ => self.injector.lock().unwrap().push_back(task),
        }
        self.notify_all();
    }

    /// This thread's worker index in *this* pool, if any.
    fn my_index(&self) -> Option<usize> {
        CURRENT_WORKER
            .with(|c| c.get())
            .and_then(|(pool, i)| (pool == self.id).then_some(i))
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize) {
    CURRENT_WORKER.with(|c| c.set(Some((shared.id, index))));
    loop {
        if shared.run_one(Some(index)) {
            continue;
        }
        // ORDER: Acquire — pairs with the Release store in `Drop`; a
        // worker observing `true` must also observe every task pushed
        // before shutdown began, so nothing is left behind.
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        let guard = shared.idle.lock().unwrap();
        // ORDER: Acquire — pairs with `push`'s AcqRel increment;
        // re-checked under the `idle` lock pushers hold while
        // notifying, so the park cannot miss a wakeup.
        if shared.pending.load(Ordering::Acquire) == 0
            // ORDER: Acquire — pairs with the Release store in `Drop`.
            && !shared.shutdown.load(Ordering::Acquire)
        {
            let (_parked, _) = shared.wake.wait_timeout(guard, PARK_TIMEOUT).unwrap();
        }
    }
}

/// Completion latch of one [`WorkStealPool::scope`]: outstanding-task
/// count plus the first captured panic.
struct ScopeLatch {
    remaining: SyncAtomicUsize,
    panic: SyncMutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Scope-ordering token id under the model checker (`None` in a
    /// normal build): each threaded task publishes its vector clock
    /// under this id right before its latch decrement, and the scope
    /// waiter asserts its own clock dominates every published token at
    /// exit — exactly the happens-before edge the `AcqRel` decrement
    /// exists to provide, so downgrading it to `Relaxed` is detected.
    mc_scope: Option<u64>,
}

impl ScopeLatch {
    fn record_panic(&self, payload: Box<dyn std::any::Any + Send>) {
        let mut slot = self.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(payload);
        }
    }
}

/// Spawn handle passed to the closure of [`WorkStealPool::scope`];
/// tasks may borrow anything that outlives the scope (`'env`).
pub struct PoolScope<'scope, 'env: 'scope> {
    shared: &'scope Arc<Shared>,
    latch: &'scope Arc<ScopeLatch>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> PoolScope<'scope, 'env> {
    /// Queue `task` onto the pool. On an inline pool (width 1) the task
    /// runs immediately, in spawn order; panics are captured either way
    /// and re-raised when the scope completes.
    pub fn spawn<F: FnOnce() + Send + 'env>(&self, task: F) {
        if self.shared.deques.is_empty() {
            // inline pool: no workers — run now, deterministically in
            // spawn order, with pooled panic semantics (remaining tasks
            // still run; the first panic re-raises at scope exit)
            //
            // ORDER: Relaxed — telemetry counter.
            self.shared.external_tasks.fetch_add(1, Ordering::Relaxed);
            if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                self.latch.record_panic(p);
            }
            return;
        }
        // ORDER: AcqRel — reserves the task before it is queued; pairs
        // with the completion decrement below and the scope waiter's
        // Acquire loads, so `remaining` can never transiently read
        // zero while the task is in flight.
        self.latch.remaining.fetch_add(1, Ordering::AcqRel);
        let latch = Arc::clone(self.latch);
        let shared = Arc::clone(self.shared);
        let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                latch.record_panic(p);
            }
            if let Some(id) = latch.mc_scope {
                // publish this task's clock before the decrement: the
                // scope waiter must end up dominating it
                modelcheck::scope_publish(id);
            }
            let ord = match shared.mutation {
                Mutation::RelaxedLatchDecrement => {
                    // ORDER: Relaxed — DELIBERATELY WRONG: fault
                    // injection for the model-check suite; unreachable
                    // from the public constructors.
                    Ordering::Relaxed
                }
                // ORDER: AcqRel — the release half publishes the
                // finished task's writes to the scope waiter's Acquire
                // load of `remaining`; the acquire half orders the
                // decrement after the task body and panic capture.
                _ => Ordering::AcqRel,
            };
            let prev = latch.remaining.fetch_sub(1, ord);
            debug_assert!(prev > 0, "scope latch underflow: a task completed twice");
            // wake any scope waiter parked on the shared condvar
            if !matches!(shared.mutation, Mutation::SkipCompletionWake) {
                shared.notify_all();
            }
        });
        // SAFETY: `scope` does not return (or unwind) before `remaining`
        // reaches zero, i.e. before this closure — and every `'env`
        // borrow it captures — has finished running. The transmute only
        // erases that lifetime so the task can sit in a queue typed
        // `'static`; it can never actually outlive the borrowed data.
        let raw = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, RawTask>(wrapped)
        };
        self.shared.push(raw);
    }
}

/// The shared work-stealing pool. See the module docs for the design;
/// construction is cheap for width 1 (no threads are spawned).
pub struct WorkStealPool {
    shared: Arc<Shared>,
    handles: Vec<SyncJoinHandle>,
}

impl WorkStealPool {
    /// A pool of `workers` parallel executors. `workers <= 1` builds an
    /// *inline* pool: no threads, `spawn` executes immediately on the
    /// caller — the deterministic sequential baseline every other width
    /// must (and does) reproduce bit-for-bit.
    pub fn new(workers: usize) -> Self {
        Self::new_with(workers, Mutation::None)
    }

    /// A deliberately broken pool for the model-check suite — see
    /// [`Mutation`]. Hidden rather than `cfg(test)`-gated so the
    /// integration tests in `rust/tests/` can reach it.
    #[doc(hidden)]
    pub fn new_mutated(workers: usize, mutation: Mutation) -> Self {
        Self::new_with(workers, mutation)
    }

    fn new_with(workers: usize, mutation: Mutation) -> Self {
        let spawned = if workers <= 1 { 0 } else { workers };
        let shared = Arc::new(Shared {
            // ORDER: Relaxed — unique id allocation; nothing is
            // published through this counter.
            id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            deques: (0..spawned).map(|_| SyncMutex::new(VecDeque::new())).collect(),
            injector: SyncMutex::new(VecDeque::new()),
            pending: SyncAtomicUsize::new(0),
            shutdown: SyncAtomicBool::new(false),
            idle: SyncMutex::new(()),
            wake: SyncCondvar::new(),
            worker_tasks: (0..spawned).map(|_| SyncAtomicU64::new(0)).collect(),
            external_tasks: SyncAtomicU64::new(0),
            mutation,
        });
        let handles = (0..spawned)
            .map(|i| {
                let shared = Arc::clone(&shared);
                sync::spawn_thread(
                    format!("fastgauss-pool-{i}"),
                    // helping waits can nest task chains (a worker
                    // waiting on a nested scope executes further tasks
                    // on its own stack) — give workers generous room
                    Some(8 << 20),
                    move || worker_loop(shared, i),
                )
                // lint: allow(no-panic): no pool without workers — spawn failure at construction is unrecoverable
                .expect("failed to spawn pool worker")
            })
            .collect();
        WorkStealPool { shared, handles }
    }

    /// The inline (width-1, zero-thread) pool.
    pub fn inline() -> Self {
        Self::new(1)
    }

    /// Parallelism width: spawned workers, or 1 for an inline pool.
    pub fn workers(&self) -> usize {
        self.shared.deques.len().max(1)
    }

    /// True when this pool runs everything inline on the caller.
    pub fn is_inline(&self) -> bool {
        self.shared.deques.is_empty()
    }

    /// Tasks executed so far by each spawned worker (empty for an
    /// inline pool). Telemetry: the determinism suite asserts a small
    /// batch on a wide pool engages more workers than requests.
    pub fn worker_task_counts(&self) -> Vec<u64> {
        self.shared
            .worker_tasks
            .iter()
            // ORDER: Relaxed — telemetry; read after the pool quiesces.
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Tasks executed inline on the caller (width-1 pools only — on a
    /// threaded pool every task runs on a worker).
    pub fn external_task_count(&self) -> u64 {
        // ORDER: Relaxed — telemetry; read after the pool quiesces.
        self.shared.external_tasks.load(Ordering::Relaxed)
    }

    /// Run `f(&scope)` with the ability to spawn borrowed tasks, then
    /// wait for every spawned task. A pool worker waiting here (a
    /// nested scope) *helps* execute pending pool work, so nested
    /// scopes never deadlock; an external caller parks until its tasks
    /// drain on the workers. The first task panic (or a panic of `f`
    /// itself) is re-raised here after all tasks finish.
    pub fn scope<'env, R>(
        &self,
        f: impl for<'scope> FnOnce(&'scope PoolScope<'scope, 'env>) -> R,
    ) -> R {
        let latch = Arc::new(ScopeLatch {
            remaining: SyncAtomicUsize::new(0),
            panic: SyncMutex::new(None),
            mc_scope: modelcheck::scope_new_current(),
        });
        let result = {
            let scope = PoolScope { shared: &self.shared, latch: &latch, _env: PhantomData };
            catch_unwind(AssertUnwindSafe(|| f(&scope)))
        };
        // Wait for completion. Must happen even if `f` panicked:
        // spawned tasks still borrow `'env` data on our stack.
        //
        // Only POOL WORKERS help while waiting: a worker parked inside
        // a nested scope would deadlock the pool, so it executes
        // pending tasks instead — that is what makes batch → traversal
        // nesting compose. An external caller, by contrast, simply
        // parks: its tasks drain on the workers regardless, and
        // helping would let one stolen multi-second foreign task delay
        // this scope's return long after its own tasks finished.
        match self.shared.my_index() {
            me @ Some(_) => {
                // ORDER: Acquire — pairs with the AcqRel latch
                // decrement; reading zero must make every finished
                // task's writes visible before `scope` returns.
                while latch.remaining.load(Ordering::Acquire) != 0 {
                    if self.shared.run_one(me) {
                        continue;
                    }
                    let guard = self.shared.idle.lock().unwrap();
                    // ORDER: Acquire — latch pairing as above, but
                    // re-checked under the `idle` lock completers
                    // hold while notifying: no missed wakeup.
                    if latch.remaining.load(Ordering::Acquire) != 0
                        // ORDER: Acquire — pairs with `push`'s AcqRel.
                        && self.shared.pending.load(Ordering::Acquire) == 0
                    {
                        let (_parked, _) =
                            self.shared.wake.wait_timeout(guard, WAIT_TIMEOUT).unwrap();
                    }
                }
            }
            None => loop {
                let guard = self.shared.idle.lock().unwrap();
                // ORDER: Acquire — pairs with the AcqRel latch
                // decrement, checked under the `idle` lock as above.
                if latch.remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                let (_parked, _) = self.shared.wake.wait_timeout(guard, WAIT_TIMEOUT).unwrap();
            },
        }
        if let Some(id) = latch.mc_scope {
            // model checker: our clock must dominate every finished
            // task's published clock — the latch's release/acquire
            // chain is exactly what establishes that
            modelcheck::scope_assert(id);
        }
        match result {
            Err(p) => resume_unwind(p),
            Ok(r) => {
                if let Some(p) = latch.panic.lock().unwrap().take() {
                    resume_unwind(p);
                }
                r
            }
        }
    }

    /// The deterministic fan-out primitive: run `f(0) .. f(n-1)` as
    /// pool tasks and return the results **in index order**, however
    /// the tasks were scheduled or stolen. Callers that fold
    /// floating-point partials iterate the returned vector in order,
    /// which makes their reductions independent of the pool width —
    /// the keystone of the batch ≡ sequential and sweep-bit-identity
    /// guarantees. Panics inside any task propagate to the caller
    /// (after the remaining tasks finish); results can therefore never
    /// be silently dropped — every index is either present or the call
    /// panics.
    pub fn run_indexed<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<SyncMutex<Option<T>>> = (0..n).map(|_| SyncMutex::new(None)).collect();
        {
            let slots = &slots;
            let f = &f;
            self.scope(|scope| {
                for k in 0..n {
                    scope.spawn(move || {
                        let value = f(k);
                        *slots[k].lock().unwrap() = Some(value);
                    });
                }
            });
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(k, slot)| {
                slot.into_inner()
                    .unwrap()
                    // lint: allow(no-panic): a lost indexed slot means the scheduler broke; returning would corrupt sums
                    .unwrap_or_else(|| panic!("work-steal pool lost indexed task {k}"))
            })
            .collect()
    }
}

impl Drop for WorkStealPool {
    fn drop(&mut self) {
        // ORDER: Release — pairs with the workers' Acquire load of
        // `shutdown`; everything this thread did before dropping the
        // pool is visible to a worker that observes the flag.
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, AtomicU64};
    use std::sync::Mutex;

    #[test]
    fn run_indexed_returns_results_in_index_order() {
        // the interpreter simulates every context switch — keep the
        // schedule short there, wide natively
        let n = if cfg!(miri) { 24 } else { 100 };
        for workers in [1, 2, 4, 8] {
            let pool = WorkStealPool::new(workers);
            let out = pool.run_indexed(n, |k| k * k);
            assert_eq!(out.len(), n, "workers={workers}");
            for (k, v) in out.iter().enumerate() {
                assert_eq!(*v, k * k, "workers={workers} k={k}");
            }
        }
    }

    #[test]
    fn inline_pool_spawns_no_threads_and_runs_in_spawn_order() {
        let pool = WorkStealPool::inline();
        assert!(pool.is_inline());
        assert_eq!(pool.workers(), 1);
        let order = Mutex::new(Vec::new());
        pool.scope(|s| {
            for k in 0..10 {
                let order = &order;
                s.spawn(move || order.lock().unwrap().push(k));
            }
        });
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
        assert!(pool.worker_task_counts().is_empty());
        assert_eq!(pool.external_task_count(), 10);
    }

    #[test]
    fn scoped_tasks_borrow_caller_stack() {
        let pool = WorkStealPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let total = AtomicU64::new(0);
        pool.scope(|s| {
            for chunk in data.chunks(100) {
                let total = &total;
                s.spawn(move || {
                    total.fetch_add(chunk.iter().sum::<u64>(), Ordering::Relaxed);
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 1000 * 999 / 2);
    }

    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let pool = WorkStealPool::new(2);
        let ran = AtomicU32::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_indexed(8, |k| {
                ran.fetch_add(1, Ordering::Relaxed);
                if k == 3 {
                    panic!("injected task failure");
                }
                k
            })
        }));
        let payload = result.expect_err("task panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("injected task failure"), "{msg}");
        // every task still ran (no sibling cancellation) …
        assert_eq!(ran.load(Ordering::Relaxed), 8);
        // … and the pool is not poisoned: it keeps scheduling fine
        let out = pool.run_indexed(5, |k| k + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn panic_propagates_from_inline_pool_too() {
        let pool = WorkStealPool::inline();
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_indexed(3, |k| {
                if k == 1 {
                    panic!("inline failure");
                }
                k
            })
        }));
        assert!(result.is_err());
        let out = pool.run_indexed(2, |k| k);
        assert_eq!(out, vec![0, 1]);
    }

    #[test]
    fn nested_scopes_compose_without_deadlock() {
        // outer tasks each fan out again into the same pool — the
        // worker running an outer task must help drain inner tasks
        // rather than block (this is the batch × traversal shape)
        for workers in [1, 2, 4] {
            let pool = WorkStealPool::new(workers);
            let out = pool.run_indexed(4, |outer| {
                let inner = pool.run_indexed(8, |k| (outer * 100 + k) as u64);
                inner.iter().sum::<u64>()
            });
            for (outer, total) in out.iter().enumerate() {
                let want: u64 = (0..8).map(|k| (outer * 100 + k) as u64).sum();
                assert_eq!(*total, want, "workers={workers} outer={outer}");
            }
        }
    }

    #[test]
    fn external_and_worker_task_counts_account_everything() {
        let pool = WorkStealPool::new(3);
        pool.run_indexed(50, |k| k);
        let by_workers: u64 = pool.worker_task_counts().iter().sum();
        let total = by_workers + pool.external_task_count();
        assert_eq!(total, 50, "every task must be counted exactly once");
    }

    #[test]
    fn empty_scope_and_zero_tasks_return_immediately() {
        let pool = WorkStealPool::new(2);
        pool.scope(|_| {});
        let out: Vec<u32> = pool.run_indexed(0, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn results_bitwise_identical_across_widths() {
        // a floating-point fold over indexed results must not depend on
        // the pool width — the contract every engine guarantee rests on
        let fold = |workers: usize| -> f64 {
            let pool = WorkStealPool::new(workers);
            let parts = pool.run_indexed(64, |k| 1.0 / (k as f64 + 1.0));
            parts.iter().fold(0.0, |acc, v| acc + v)
        };
        let base = fold(1);
        let widths: &[usize] = if cfg!(miri) { &[2, 4] } else { &[2, 4, 8] };
        for &workers in widths {
            assert_eq!(fold(workers).to_bits(), base.to_bits(), "workers={workers}");
        }
    }

    #[test]
    fn mutated_constructor_still_completes_on_benign_schedules() {
        // the fault-injected variant is wrong only under adversarial
        // interleavings — a plain run must still finish (the waiter's
        // timeout safety net absorbs the lost wake), so the model
        // checker (not luck) is what catches it. RelaxedLatchDecrement
        // is exercised only under the model checker's virtual clocks:
        // run on real threads its missing release edge is a genuine
        // data race the TSan job would (rightly) flag.
        let pool = WorkStealPool::new_mutated(2, Mutation::SkipCompletionWake);
        let out = pool.run_indexed(8, |k| k + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }
}
