//! Sweep job/result types shared by the coordinator, the CLI and the
//! bench harness.

use crate::algo::RunStats;
use crate::compute::simd::{Precision, SimdMode};
use crate::data::Dataset;
use crate::kernel::Kernel;

/// Which algorithm a sweep row runs — since the session front door
/// unified method naming, this is simply [`crate::api::Method`] (rows
/// may therefore also be `Auto`, resolved per cell by the session's
/// cost model). The alias is kept so pre-session coordinator callers
/// compile unchanged.
pub use crate::api::Method as AlgoSpec;

/// Configuration for one dataset's table sweep.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub dataset: Dataset,
    pub epsilon: f64,
    /// The optimal bandwidth the multipliers scale.
    pub h_star: f64,
    /// Bandwidth multipliers (paper: 10⁻³…10³).
    pub multipliers: Vec<f64>,
    pub algorithms: Vec<AlgoSpec>,
    /// Width of the session's shared work-stealing pool for the whole
    /// sweep: (algo × h) cells *and* the traversal tasks each dual-tree
    /// cell fans out run on the same workers, so the tail of a sweep no
    /// longer leaves cores idle. For the deterministic rows — Naive,
    /// the dual-tree family, FGT's τ-halving — results (outcomes and
    /// verified errors) are bit-identical for every width; only
    /// wall-clock changes. IFGT rows are the exception at *any* width:
    /// its K-doubling stops on a wall-clock budget, so those cells are
    /// ε-verified but inherently schedule/load-dependent.
    pub workers: usize,
    pub leaf_size: usize,
    /// Certified fast tiled base cases for the dual-tree cells
    /// (`true` = the default production path; `false` = the bit-exact
    /// reference configuration, what `--fast-exp false` requests).
    pub fast_exp: bool,
    /// SIMD dispatch for the fast base cases (`--simd`): `Auto` = the
    /// per-process detected backend, `Off` = the bit-exact scalar table.
    pub simd: SimdMode,
    /// Fast-tile arithmetic precision (`--precision`): `F32` engages
    /// the mixed-precision tile only where its derived certificate fits
    /// the ε/4 gate, demoting to f64 elsewhere — cells stay ε-verified.
    pub precision: Precision,
    /// Kernel the sweep evaluates. Non-Gaussian kernels route every
    /// cell through the session's sum-of-Gaussians layer, truth comes
    /// from the exhaustive true-kernel sum, and cells are verified
    /// against the weight-scaled absolute guarantee
    /// max_q|G̃−G| ≤ ε·W instead of the Gaussian relative one.
    pub kernel: Kernel,
}

/// One table cell's outcome, mirroring the paper's entries.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum CellOutcome {
    /// CPU seconds (verified within ε).
    Time(f64),
    /// The paper's `X`.
    RamExhausted,
    /// The paper's `∞`.
    ToleranceUnreachable,
}

/// One (algorithm × bandwidth) result.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub algo_index: usize,
    pub bandwidth_index: usize,
    pub outcome: CellOutcome,
    /// Verified max relative error (when a result was produced).
    pub rel_err: Option<f64>,
    pub stats: Option<RunStats>,
}

/// Full sweep output for one dataset.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub dataset: String,
    pub dim: usize,
    pub n: usize,
    pub h_star: f64,
    pub epsilon: f64,
    /// Kernel the table was swept under (non-Gaussian rows went
    /// through the SoG layer; their `rel_err` is the weight-scaled
    /// absolute error).
    pub kernel: Kernel,
    pub multipliers: Vec<f64>,
    pub algorithms: Vec<AlgoSpec>,
    /// The Naive row (exhaustive truth timings, one per bandwidth).
    pub naive_secs: Vec<f64>,
    /// One-time session preparation (kd-tree build) amortized over the
    /// whole table. Built even for sweeps without dual-tree rows: the
    /// session's truth/frame/plan memos serve every cell, and the tree
    /// cost is negligible next to a single exhaustive truth run.
    pub prep_secs: f64,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    /// Cell lookup.
    pub fn cell(&self, algo: usize, bw: usize) -> &CellResult {
        &self.cells[algo * self.multipliers.len() + bw]
    }

    /// Per-algorithm Σ column: total seconds, or `None` when any cell
    /// failed (paper propagates X/∞ into Σ).
    pub fn totals(&self) -> Vec<Option<f64>> {
        self.algorithms
            .iter()
            .enumerate()
            .map(|(a, _)| {
                let mut sum = 0.0;
                for b in 0..self.multipliers.len() {
                    match self.cell(a, b).outcome {
                        CellOutcome::Time(t) => sum += t,
                        _ => return None,
                    }
                }
                Some(sum)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_spec_parse_roundtrip() {
        for spec in AlgoSpec::paper_order() {
            assert_eq!(AlgoSpec::parse(spec.name()), Some(spec));
        }
        assert_eq!(AlgoSpec::parse("bogus"), None);
        assert_eq!(AlgoSpec::parse("dito"), Some(AlgoSpec::Dito));
    }

    #[test]
    fn totals_propagate_failures() {
        let res = SweepResult {
            dataset: "t".into(),
            dim: 2,
            n: 10,
            h_star: 0.1,
            epsilon: 0.01,
            kernel: Kernel::Gaussian,
            multipliers: vec![1.0, 10.0],
            algorithms: vec![AlgoSpec::Dito, AlgoSpec::Fgt],
            naive_secs: vec![1.0, 1.0],
            prep_secs: 0.0,
            cells: vec![
                CellResult { algo_index: 0, bandwidth_index: 0, outcome: CellOutcome::Time(1.5), rel_err: Some(0.001), stats: None },
                CellResult { algo_index: 0, bandwidth_index: 1, outcome: CellOutcome::Time(0.5), rel_err: Some(0.002), stats: None },
                CellResult { algo_index: 1, bandwidth_index: 0, outcome: CellOutcome::RamExhausted, rel_err: None, stats: None },
                CellResult { algo_index: 1, bandwidth_index: 1, outcome: CellOutcome::Time(0.1), rel_err: Some(0.0), stats: None },
            ],
        };
        let totals = res.totals();
        assert_eq!(totals[0], Some(2.0));
        assert_eq!(totals[1], None);
        assert_eq!(res.cell(1, 1).outcome, CellOutcome::Time(0.1));
    }
}
