//! The sweep coordinator — the "leader" that reproduces the paper's
//! experiment protocol: for one dataset, run every algorithm at every
//! bandwidth multiplier around h*, verify each cell against exhaustive
//! truth, and render the paper-style table.
//!
//! The whole protocol runs on one prepared [`Session`]: the kd-tree is
//! built once, cells share the per-bandwidth moment/truth/clustering
//! memos, and the FGT τ-halving / IFGT K-doubling tuning live in the
//! session (`api::tuning`), not here. Work is scheduled as
//! (algorithm × bandwidth) cells on a small worker pool; the
//! per-bandwidth exhaustive truth runs — formerly a *serial* pass the
//! pool sat idle behind — are folded into the scheduled cells: the
//! first worker that needs a bandwidth's truth computes it inside the
//! pool, concurrent requesters of the same bandwidth block on that one
//! computation, and other bandwidths proceed in parallel.
//!
//! Rows may also be [`AlgoSpec::Auto`] (= [`crate::api::Method::Auto`]):
//! the cell resolves through the session's cost model before running.

pub mod job;
pub mod report;

use std::sync::mpsc;

use crate::api::{EvalRequest, PrepareOptions, Session};
use crate::algo::{max_relative_error, AlgoError};
use crate::util::timer::time_it;

pub use job::{AlgoSpec, CellOutcome, CellResult, SweepConfig, SweepResult};

/// Run the full table protocol for one dataset.
pub fn run_sweep(cfg: &SweepConfig) -> SweepResult {
    let data = &cfg.dataset.points;
    let bandwidths: Vec<f64> = cfg.multipliers.iter().map(|m| m * cfg.h_star).collect();

    // ---- one prepared session for the whole table: every cell (all
    // algorithms × all bandwidths) shares its tree, moment memo, truth
    // memo, FGT frame and IFGT clustering plans ----
    let (session, prep_secs) = time_it(|| {
        let defaults = PrepareOptions::default();
        Session::prepare(
            data,
            PrepareOptions {
                leaf_size: cfg.leaf_size,
                fast_exp: cfg.fast_exp,
                // never evict a truth this sweep will revisit: each of
                // the 7 algorithm rows verifies against every bandwidth
                truth_cache_capacity: bandwidths.len().max(defaults.truth_cache_capacity),
                ..defaults
            },
        )
    });

    // ---- schedule the (algo × h) cells on a worker pool ----
    let jobs: Vec<(usize, usize)> = (0..cfg.algorithms.len())
        .flat_map(|a| (0..bandwidths.len()).map(move |b| (a, b)))
        .collect();
    let workers = cfg.workers.max(1);
    let (result_tx, result_rx) = mpsc::channel::<CellResult>();
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let result_tx = result_tx.clone();
            let jobs = &jobs;
            let next = &next;
            let bandwidths = &bandwidths;
            let session = &session;
            scope.spawn(move || loop {
                let k = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if k >= jobs.len() {
                    break;
                }
                let (ai, bi) = jobs[k];
                let cell = run_cell(cfg, session, cfg.algorithms[ai], ai, bi, bandwidths[bi]);
                let _ = result_tx.send(cell);
            });
        }
        drop(result_tx);
    });

    let mut cells: Vec<CellResult> = result_rx.into_iter().collect();
    cells.sort_by_key(|c| (c.algo_index, c.bandwidth_index));

    // The Naive row's timings, read back from the session's truth memo
    // (every scheduled cell verified against it, so these are all warm;
    // a sweep with no cells at all computes them here).
    let naive_secs: Vec<f64> =
        bandwidths.iter().map(|&h| session.exact_sums(h, cfg.epsilon).1).collect();

    SweepResult {
        dataset: cfg.dataset.name.clone(),
        dim: cfg.dataset.dim(),
        n: cfg.dataset.len(),
        h_star: cfg.h_star,
        epsilon: cfg.epsilon,
        multipliers: cfg.multipliers.clone(),
        algorithms: cfg.algorithms.clone(),
        naive_secs,
        prep_secs,
        cells,
    }
}

/// Run one (algorithm, bandwidth) cell with verification on the shared
/// session. Dual-tree cells evaluate on the prepared tree (zero
/// per-cell builds); their reported time is the h-dependent evaluate
/// only, with the one-time preparation in `SweepResult::prep_secs`.
/// FGT/IFGT cells run the session's verification-tuning and report the
/// time the paper reports (successful attempt / whole tuning,
/// respectively).
fn run_cell(
    cfg: &SweepConfig,
    session: &Session<'_>,
    spec: AlgoSpec,
    algo_index: usize,
    bandwidth_index: usize,
    h: f64,
) -> CellResult {
    let mut cell = CellResult {
        algo_index,
        bandwidth_index,
        outcome: CellOutcome::ToleranceUnreachable,
        rel_err: None,
        stats: None,
    };

    // Fold this bandwidth's exhaustive truth into the pool: the paper
    // protocol verifies every cell, so fetch (= compute, first time)
    // before running the algorithm.
    let (exact, _naive_secs, _warm) = session.exact_sums(h, cfg.epsilon);

    let req = EvalRequest::kde(h, cfg.epsilon).with_method(spec);
    match session.evaluate(&req) {
        Ok(ev) => {
            let rel = match ev.rel_err {
                Some(r) => r, // Naive/FGT/IFGT come back pre-verified
                None => max_relative_error(&ev.sums, &exact),
            };
            cell.rel_err = Some(rel);
            cell.outcome = if rel <= cfg.epsilon * (1.0 + 1e-9) {
                CellOutcome::Time(ev.stats.total_secs)
            } else {
                CellOutcome::ToleranceUnreachable
            };
            cell.stats = Some(ev.stats);
        }
        Err(AlgoError::RamExhausted(_)) => cell.outcome = CellOutcome::RamExhausted,
        Err(AlgoError::ToleranceUnreachable(_)) => {
            // no result was produced, so rel_err stays None (an FGT cell
            // that exhausts its τ-halvings reports the last measured rel
            // only in the error message — its sums are discarded)
            cell.outcome = CellOutcome::ToleranceUnreachable
        }
    }
    cell
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kde::bandwidth::silverman;

    fn small_cfg() -> SweepConfig {
        let ds = data::by_name("astro2d", 300, 11).unwrap();
        let h = silverman(&ds.points);
        SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![0.1, 1.0, 10.0],
            algorithms: vec![AlgoSpec::Naive, AlgoSpec::Dfd, AlgoSpec::Dito],
            workers: 2,
            leaf_size: 16,
            fast_exp: true,
        }
    }

    #[test]
    fn sweep_produces_all_cells_verified() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert_eq!(res.cells.len(), 9);
        for c in &res.cells {
            match c.outcome {
                CellOutcome::Time(t) => {
                    assert!(t >= 0.0);
                    assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
                }
                _ => panic!(
                    "algo {} h-idx {} failed: {:?}",
                    res.algorithms[c.algo_index].name(),
                    c.bandwidth_index,
                    c.outcome
                ),
            }
        }
        assert_eq!(res.naive_secs.len(), 3);
    }

    #[test]
    fn cells_ordered_and_totals_compute() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.algo_index, i / 3);
            assert_eq!(c.bandwidth_index, i % 3);
        }
        let totals = res.totals();
        assert_eq!(totals.len(), 3);
        assert!(totals.iter().all(|t| t.is_some()));
    }

    #[test]
    fn dual_tree_cells_share_one_prepared_engine() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert!(res.prep_secs >= 0.0);
        for c in &res.cells {
            let spec = res.algorithms[c.algo_index];
            if spec.is_dual_tree() {
                // evaluated on the shared session → zero per-cell builds
                let stats = c.stats.as_ref().expect("dual-tree cell must have stats");
                assert_eq!(stats.tree_builds, 0, "{} rebuilt its tree", spec.name());
            }
        }
    }

    #[test]
    fn auto_rows_resolve_and_verify() {
        let ds = data::by_name("astro2d", 400, 13).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            // spans the FD-only and the series regimes of the cost model
            multipliers: vec![1e-3, 1.0],
            algorithms: vec![AlgoSpec::Auto],
            workers: 2,
            leaf_size: 16,
            fast_exp: true,
        };
        let res = run_sweep(&cfg);
        assert_eq!(res.cells.len(), 2);
        for c in &res.cells {
            assert!(
                matches!(c.outcome, CellOutcome::Time(_)),
                "auto cell failed: {:?}",
                c.outcome
            );
            assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
        }
        assert_eq!(res.naive_secs.len(), 2, "truth must be recorded per bandwidth");
        assert!(res.naive_secs.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn fgt_cell_protocol_small_h_is_ram_bound() {
        let ds = data::by_name("astro2d", 200, 12).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![1e-3],
            algorithms: vec![AlgoSpec::Fgt],
            workers: 1,
            leaf_size: 16,
            fast_exp: true,
        };
        let res = run_sweep(&cfg);
        assert!(matches!(res.cells[0].outcome, CellOutcome::RamExhausted));
    }
}
