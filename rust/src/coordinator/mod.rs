//! The sweep coordinator — the L3 "leader" that reproduces the paper's
//! experiment protocol: for one dataset, run every algorithm at every
//! bandwidth multiplier around h*, verify each cell against exhaustive
//! truth, and render the paper-style table.
//!
//! Work is scheduled as (algorithm × bandwidth) cells on a small worker
//! pool (std threads + channels; the protocol is embarrassingly
//! parallel across cells once the shared exact sums are cached).
//! FGT/IFGT cells embed the paper's parameter-tuning protocols: τ is
//! halved until FGT meets ε; IFGT doubles K until verified or hopeless.

pub mod job;
pub mod report;

use std::sync::mpsc;

use crate::algo::dualtree::{DualTreeConfig, SeriesKind};
use crate::algo::{
    fgt::Fgt, ifgt::ifgt_tuning_loop, max_relative_error, naive::Naive, AlgoError, GaussSum,
    GaussSumProblem, SweepEngine,
};
use crate::util::timer::time_it;

pub use job::{AlgoSpec, CellOutcome, CellResult, SweepConfig, SweepResult};

/// The engine variant a dual-tree table row runs, or `None` for the
/// non-dual-tree algorithms (Naive/FGT/IFGT).
fn dual_tree_variant(spec: AlgoSpec, leaf_size: usize) -> Option<DualTreeConfig> {
    let base = DualTreeConfig { leaf_size, ..Default::default() };
    match spec {
        AlgoSpec::Dfd => Some(DualTreeConfig { use_tokens: false, series: None, ..base }),
        AlgoSpec::Dfdo => Some(DualTreeConfig { use_tokens: true, series: None, ..base }),
        AlgoSpec::Dfto => {
            Some(DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..base })
        }
        AlgoSpec::Dito => Some(base),
        AlgoSpec::Naive | AlgoSpec::Fgt | AlgoSpec::Ifgt => None,
    }
}

/// Run the full table protocol for one dataset.
pub fn run_sweep(cfg: &SweepConfig) -> SweepResult {
    let data = &cfg.dataset.points;
    let bandwidths: Vec<f64> = cfg.multipliers.iter().map(|m| m * cfg.h_star).collect();

    // ---- exhaustive truth per bandwidth (timed → the Naive row) ----
    let mut exact: Vec<Vec<f64>> = Vec::with_capacity(bandwidths.len());
    let mut naive_secs: Vec<f64> = Vec::with_capacity(bandwidths.len());
    for &h in &bandwidths {
        let p = GaussSumProblem::kde(data, h, cfg.epsilon);
        let (res, secs) = time_it(|| Naive::new().run(&p).unwrap());
        exact.push(res.sums);
        naive_secs.push(secs);
    }

    // ---- one tree build for the whole table: every dual-tree cell
    // (all four variants × all bandwidths) shares this engine; skipped
    // entirely when the sweep runs no dual-tree algorithm ----
    let needs_engine =
        cfg.algorithms.iter().any(|&a| dual_tree_variant(a, cfg.leaf_size).is_some());
    let (engine, prep_secs) = if needs_engine {
        let (e, secs) = time_it(|| SweepEngine::for_kde(data, cfg.leaf_size));
        (Some(e), secs)
    } else {
        (None, 0.0)
    };

    // ---- schedule the (algo × h) cells on a worker pool ----
    let jobs: Vec<(usize, usize)> = (0..cfg.algorithms.len())
        .flat_map(|a| (0..bandwidths.len()).map(move |b| (a, b)))
        .collect();
    let workers = cfg.workers.max(1);
    let (result_tx, result_rx) = mpsc::channel::<CellResult>();
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let result_tx = result_tx.clone();
            let jobs = &jobs;
            let next = &next;
            let exact = &exact;
            let bandwidths = &bandwidths;
            let naive_secs = &naive_secs;
            let engine = &engine;
            scope.spawn(move || loop {
                let k = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if k >= jobs.len() {
                    break;
                }
                let (ai, bi) = jobs[k];
                let cell = run_cell(
                    cfg,
                    engine.as_ref(),
                    cfg.algorithms[ai],
                    ai,
                    bi,
                    bandwidths[bi],
                    &exact[bi],
                    naive_secs[bi],
                );
                let _ = result_tx.send(cell);
            });
        }
        drop(result_tx);
    });

    let mut cells: Vec<CellResult> = result_rx.into_iter().collect();
    cells.sort_by_key(|c| (c.algo_index, c.bandwidth_index));

    SweepResult {
        dataset: cfg.dataset.name.clone(),
        dim: cfg.dataset.dim(),
        n: cfg.dataset.len(),
        h_star: cfg.h_star,
        epsilon: cfg.epsilon,
        multipliers: cfg.multipliers.clone(),
        algorithms: cfg.algorithms.clone(),
        naive_secs,
        prep_secs,
        cells,
    }
}

/// Run one (algorithm, bandwidth) cell with verification. Dual-tree
/// cells evaluate on the shared prepared `engine` (zero tree builds);
/// their reported time is the h-dependent evaluate only, with the
/// one-time preparation in `SweepResult::prep_secs`.
#[allow(clippy::too_many_arguments)]
fn run_cell(
    cfg: &SweepConfig,
    engine: Option<&SweepEngine>,
    spec: AlgoSpec,
    algo_index: usize,
    bandwidth_index: usize,
    h: f64,
    exact: &[f64],
    naive_secs: f64,
) -> CellResult {
    let data = &cfg.dataset.points;
    let problem = GaussSumProblem::kde(data, h, cfg.epsilon);
    let mut cell = CellResult {
        algo_index,
        bandwidth_index,
        outcome: CellOutcome::ToleranceUnreachable,
        rel_err: None,
        stats: None,
    };

    let finish = |cell: &mut CellResult,
                  res: Result<(crate::algo::GaussSumResult, f64), AlgoError>| {
        match res {
            Ok((r, secs)) => {
                let rel = max_relative_error(&r.sums, exact);
                cell.rel_err = Some(rel);
                if rel <= cfg.epsilon * (1.0 + 1e-9) {
                    cell.outcome = CellOutcome::Time(secs);
                } else {
                    cell.outcome = CellOutcome::ToleranceUnreachable;
                }
                cell.stats = Some(r.stats);
            }
            Err(AlgoError::RamExhausted(_)) => cell.outcome = CellOutcome::RamExhausted,
            Err(AlgoError::ToleranceUnreachable(_)) => {
                cell.outcome = CellOutcome::ToleranceUnreachable
            }
        }
    };

    match spec {
        AlgoSpec::Naive => {
            let (r, secs) = time_it(|| Naive::new().run(&problem));
            finish(&mut cell, r.map(|r| (r, secs)));
        }
        AlgoSpec::Dfd | AlgoSpec::Dfdo | AlgoSpec::Dfto | AlgoSpec::Dito => {
            let variant = dual_tree_variant(spec, cfg.leaf_size).unwrap();
            let engine = engine.expect("engine prepared whenever a dual-tree algo runs");
            let (r, secs) = time_it(|| engine.evaluate(h, cfg.epsilon, &variant));
            finish(&mut cell, r.map(|r| (r, secs)));
        }
        AlgoSpec::Fgt => {
            // paper protocol: τ = ε, halve until the relative tolerance
            // holds (verified against exact); report the successful run.
            let mut tau = cfg.epsilon;
            let mut attempts = 0;
            loop {
                attempts += 1;
                let (r, secs) = time_it(|| Fgt::new(tau).run(&problem));
                match r {
                    Err(e) => {
                        finish(&mut cell, Err(e));
                        break;
                    }
                    Ok(r) => {
                        let rel = max_relative_error(&r.sums, exact);
                        if rel <= cfg.epsilon * (1.0 + 1e-9) {
                            cell.rel_err = Some(rel);
                            cell.outcome = CellOutcome::Time(secs);
                            cell.stats = Some(r.stats);
                            break;
                        }
                        if attempts >= 20 {
                            cell.rel_err = Some(rel);
                            cell.outcome = CellOutcome::ToleranceUnreachable;
                            break;
                        }
                        tau *= 0.5;
                    }
                }
            }
        }
        AlgoSpec::Ifgt => {
            // tuning budget: a few multiples of the exhaustive time —
            // past that, IFGT has lost by definition (paper's by-hand cutoff)
            let budget = (5.0 * naive_secs).max(2.0);
            let (r, secs) = time_it(|| ifgt_tuning_loop(&problem, exact, 8, budget));
            match r {
                Ok((res, _params)) => {
                    cell.rel_err = Some(max_relative_error(&res.sums, exact));
                    cell.outcome = CellOutcome::Time(secs);
                    cell.stats = Some(res.stats);
                }
                Err(e) => finish(&mut cell, Err(e)),
            }
        }
    }
    cell
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kde::bandwidth::silverman;

    fn small_cfg() -> SweepConfig {
        let ds = data::by_name("astro2d", 300, 11).unwrap();
        let h = silverman(&ds.points);
        SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![0.1, 1.0, 10.0],
            algorithms: vec![AlgoSpec::Naive, AlgoSpec::Dfd, AlgoSpec::Dito],
            workers: 2,
            leaf_size: 16,
        }
    }

    #[test]
    fn sweep_produces_all_cells_verified() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert_eq!(res.cells.len(), 9);
        for c in &res.cells {
            match c.outcome {
                CellOutcome::Time(t) => {
                    assert!(t >= 0.0);
                    assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
                }
                _ => panic!(
                    "algo {} h-idx {} failed: {:?}",
                    res.algorithms[c.algo_index].name(),
                    c.bandwidth_index,
                    c.outcome
                ),
            }
        }
        assert_eq!(res.naive_secs.len(), 3);
    }

    #[test]
    fn cells_ordered_and_totals_compute() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.algo_index, i / 3);
            assert_eq!(c.bandwidth_index, i % 3);
        }
        let totals = res.totals();
        assert_eq!(totals.len(), 3);
        assert!(totals.iter().all(|t| t.is_some()));
    }

    #[test]
    fn dual_tree_cells_share_one_prepared_engine() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert!(res.prep_secs >= 0.0);
        for c in &res.cells {
            let spec = res.algorithms[c.algo_index];
            if dual_tree_variant(spec, cfg.leaf_size).is_some() {
                // evaluated on the shared engine → zero per-cell builds
                let stats = c.stats.as_ref().expect("dual-tree cell must have stats");
                assert_eq!(stats.tree_builds, 0, "{} rebuilt its tree", spec.name());
            }
        }
    }

    #[test]
    fn fgt_cell_protocol_small_h_is_ram_bound() {
        let ds = data::by_name("astro2d", 200, 12).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![1e-3],
            algorithms: vec![AlgoSpec::Fgt],
            workers: 1,
            leaf_size: 16,
        };
        let res = run_sweep(&cfg);
        assert!(matches!(res.cells[0].outcome, CellOutcome::RamExhausted));
    }
}
