//! The sweep coordinator — the "leader" that reproduces the paper's
//! experiment protocol: for one dataset, run every algorithm at every
//! bandwidth multiplier around h*, verify each cell against exhaustive
//! truth, and render the paper-style table.
//!
//! The whole protocol runs on one prepared [`Session`]: the kd-tree is
//! built once, cells share the per-bandwidth moment/truth/clustering
//! memos, and the FGT τ-halving / IFGT K-doubling tuning live in the
//! session (`api::tuning`), not here. The (algorithm × bandwidth)
//! cells are scheduled straight onto the **session's shared
//! work-stealing pool** (sized by [`SweepConfig::workers`]) — the same
//! pool every dual-tree cell fans its traversal tasks into, so a
//! 2-cell tail no longer strands the other workers. The per-bandwidth
//! exhaustive truth runs — formerly a *serial* pass the pool sat idle
//! behind — stay folded into the scheduled cells: the first cell that
//! needs a bandwidth's truth computes it inside the pool, concurrent
//! requesters of the same bandwidth block on that one computation, and
//! other bandwidths proceed in parallel.
//!
//! Cell results come back through the pool's **indexed reduction**:
//! every scheduled cell is either present at its slot or the sweep
//! panics with the worker's original panic — a crashing cell can no
//! longer silently vanish from the table (the old code ignored
//! `result_tx.send` failures and never compared received against
//! scheduled). Because each deterministic cell's evaluation is
//! pool-width-invariant, tables built from Naive / dual-tree / FGT
//! rows are bit-identical (outcomes and verified errors, not timings)
//! for any `workers` setting; IFGT rows remain wall-clock-dependent at
//! every width — its K-doubling tuning stops on a time budget — so
//! they are ε-verified but not schedule-invariant (see
//! [`SweepConfig::workers`]).
//!
//! Rows may also be [`AlgoSpec::Auto`] (= [`crate::api::Method::Auto`]):
//! the cell resolves through the session's cost model before running.

pub mod job;
pub mod report;

use crate::api::{EvalRequest, PrepareOptions, Session};
use crate::algo::{max_relative_error, max_weight_scaled_error, AlgoError};
use crate::util::timer::time_it;

pub use job::{AlgoSpec, CellOutcome, CellResult, SweepConfig, SweepResult};

/// Run the full table protocol for one dataset.
pub fn run_sweep(cfg: &SweepConfig) -> SweepResult {
    let data = &cfg.dataset.points;
    let bandwidths: Vec<f64> = cfg.multipliers.iter().map(|m| m * cfg.h_star).collect();

    // ---- one prepared session for the whole table: every cell (all
    // algorithms × all bandwidths) shares its tree, moment memo, truth
    // memo, FGT frame, IFGT clustering plans — and its work-stealing
    // pool, which `threads: cfg.workers` sizes for the whole sweep ----
    let (session, prep_secs) = time_it(|| {
        let defaults = PrepareOptions::default();
        Session::prepare(
            data,
            PrepareOptions {
                leaf_size: cfg.leaf_size,
                threads: cfg.workers,
                fast_exp: cfg.fast_exp,
                simd: cfg.simd,
                precision: cfg.precision,
                kernel: cfg.kernel,
                // never evict a truth this sweep will revisit: each of
                // the 7 algorithm rows verifies against every bandwidth
                truth_cache_capacity: bandwidths.len().max(defaults.truth_cache_capacity),
                ..defaults
            },
        )
    });
    run_sweep_on(cfg, &session, prep_secs)
}

/// The scheduling core of [`run_sweep`], split out so tests can inject
/// a pre-poisoned session: fan the (algo × h) cells onto the session's
/// pool, reduce by cell index, and assemble the table.
pub(crate) fn run_sweep_on(
    cfg: &SweepConfig,
    session: &Session<'_>,
    prep_secs: f64,
) -> SweepResult {
    let bandwidths: Vec<f64> = cfg.multipliers.iter().map(|m| m * cfg.h_star).collect();
    let jobs: Vec<(usize, usize)> = (0..cfg.algorithms.len())
        .flat_map(|a| (0..bandwidths.len()).map(move |b| (a, b)))
        .collect();

    // Indexed reduction: cell k lands at slot k or the pool re-raises
    // the worker's panic — results cannot be silently dropped.
    let cells: Vec<CellResult> = session.pool().run_indexed(jobs.len(), |k| {
        let (ai, bi) = jobs[k];
        run_cell(cfg, session, cfg.algorithms[ai], ai, bi, bandwidths[bi])
    });
    assert_eq!(
        cells.len(),
        jobs.len(),
        "sweep lost cells: received {} of {} scheduled",
        cells.len(),
        jobs.len()
    );
    debug_assert!(
        cells.iter().enumerate().all(|(k, c)| (c.algo_index, c.bandwidth_index) == jobs[k]),
        "indexed reduction must preserve (algo, h) order"
    );

    // The Naive row's timings, read back from the session's truth memo
    // (every scheduled cell verified against it, so these are all warm;
    // a sweep with no cells at all computes them here). For a
    // non-Gaussian sweep this is the exhaustive *true-kernel* sum.
    let naive_secs: Vec<f64> = bandwidths
        .iter()
        .map(|&h| {
            session
                .exact_kernel_sums(cfg.kernel, h, cfg.epsilon)
                // lint: allow(no-panic): sweep-abort by design — a missing truth row must fail the sweep, not mislabel it
                .unwrap_or_else(|e| panic!("naive row truth for h={h:.6e}: {e}"))
                .1
        })
        .collect();

    SweepResult {
        dataset: cfg.dataset.name.clone(),
        dim: cfg.dataset.dim(),
        n: cfg.dataset.len(),
        h_star: cfg.h_star,
        epsilon: cfg.epsilon,
        kernel: cfg.kernel,
        multipliers: cfg.multipliers.clone(),
        algorithms: cfg.algorithms.clone(),
        naive_secs,
        prep_secs,
        cells,
    }
}

/// Run one (algorithm, bandwidth) cell with verification on the shared
/// session. Dual-tree cells evaluate on the prepared tree (zero
/// per-cell builds); their reported time is the h-dependent evaluate
/// only, with the one-time preparation in `SweepResult::prep_secs`.
/// FGT/IFGT cells run the session's verification-tuning and report the
/// time the paper reports (successful attempt / whole tuning,
/// respectively).
fn run_cell(
    cfg: &SweepConfig,
    session: &Session<'_>,
    spec: AlgoSpec,
    algo_index: usize,
    bandwidth_index: usize,
    h: f64,
) -> CellResult {
    let mut cell = CellResult {
        algo_index,
        bandwidth_index,
        outcome: CellOutcome::ToleranceUnreachable,
        rel_err: None,
        stats: None,
    };

    // Fold this bandwidth's exhaustive truth into the pool: the paper
    // protocol verifies every cell, so fetch (= compute, first time)
    // before running the algorithm. A truth failure is infrastructure,
    // not an algorithmic X/∞ — surface the underlying panic instead of
    // mislabeling the cell (the pool re-raises it to run_sweep's
    // caller). Non-Gaussian sweeps verify against the exhaustive
    // *true-kernel* sum, not a Gaussian proxy.
    let exact = match session.exact_kernel_sums(cfg.kernel, h, cfg.epsilon) {
        Ok((exact, _, _)) => exact,
        // lint: allow(no-panic): sweep-abort by design — the pool re-raises this to run_sweep's caller
        Err(e) => panic!(
            "sweep cell {}×h[{bandwidth_index}]: exhaustive truth unavailable: {e}",
            spec.name()
        ),
    };

    let req = EvalRequest::kde(h, cfg.epsilon).with_method(spec);
    match session.evaluate(&req) {
        Ok(ev) => {
            // Gaussian cells carry the paper's relative guarantee; SoG
            // cells carry the weight-scaled absolute one
            // (max_q|G̃−G| ≤ ε·W) — same ε threshold, different norm.
            let err = if cfg.kernel.is_gaussian() {
                match ev.rel_err {
                    Some(r) => r, // Naive/FGT/IFGT come back pre-verified
                    None => max_relative_error(&ev.sums, &exact),
                }
            } else {
                max_weight_scaled_error(&ev.sums, &exact, session.total_weight())
            };
            cell.rel_err = Some(err);
            cell.outcome = if err <= cfg.epsilon * (1.0 + 1e-9) {
                CellOutcome::Time(ev.stats.total_secs)
            } else {
                CellOutcome::ToleranceUnreachable
            };
            cell.stats = Some(ev.stats);
        }
        Err(AlgoError::RamExhausted(_)) => cell.outcome = CellOutcome::RamExhausted,
        Err(AlgoError::ToleranceUnreachable(_)) => {
            // no result was produced, so rel_err stays None (an FGT cell
            // that exhausts its τ-halvings reports the last measured rel
            // only in the error message — its sums are discarded)
            cell.outcome = CellOutcome::ToleranceUnreachable
        }
        // lint: allow(no-panic): internal errors are bugs, not tolerance failures — abort the sweep loudly
        Err(e @ AlgoError::Internal(_)) => panic!(
            "sweep cell {}×h[{bandwidth_index}] hit an internal failure: {e}",
            spec.name()
        ),
    }
    cell
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::simd::{Precision, SimdMode};
    use crate::data;
    use crate::kde::bandwidth::silverman;
    use crate::kernel::Kernel;

    fn small_cfg() -> SweepConfig {
        let ds = data::by_name("astro2d", 300, 11).unwrap();
        let h = silverman(&ds.points);
        SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![0.1, 1.0, 10.0],
            algorithms: vec![AlgoSpec::Naive, AlgoSpec::Dfd, AlgoSpec::Dito],
            workers: 2,
            leaf_size: 16,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
        }
    }

    #[test]
    fn sweep_produces_all_cells_verified() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert_eq!(res.cells.len(), 9);
        for c in &res.cells {
            match c.outcome {
                CellOutcome::Time(t) => {
                    assert!(t >= 0.0);
                    assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
                }
                _ => panic!(
                    "algo {} h-idx {} failed: {:?}",
                    res.algorithms[c.algo_index].name(),
                    c.bandwidth_index,
                    c.outcome
                ),
            }
        }
        assert_eq!(res.naive_secs.len(), 3);
    }

    #[test]
    fn cells_ordered_and_totals_compute() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.algo_index, i / 3);
            assert_eq!(c.bandwidth_index, i % 3);
        }
        let totals = res.totals();
        assert_eq!(totals.len(), 3);
        assert!(totals.iter().all(|t| t.is_some()));
    }

    #[test]
    fn dual_tree_cells_share_one_prepared_engine() {
        let cfg = small_cfg();
        let res = run_sweep(&cfg);
        assert!(res.prep_secs >= 0.0);
        for c in &res.cells {
            let spec = res.algorithms[c.algo_index];
            if spec.is_dual_tree() {
                // evaluated on the shared session → zero per-cell builds
                let stats = c.stats.as_ref().expect("dual-tree cell must have stats");
                assert_eq!(stats.tree_builds, 0, "{} rebuilt its tree", spec.name());
            }
        }
    }

    #[test]
    fn auto_rows_resolve_and_verify() {
        let ds = data::by_name("astro2d", 400, 13).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            // spans the FD-only and the series regimes of the cost model
            multipliers: vec![1e-3, 1.0],
            algorithms: vec![AlgoSpec::Auto],
            workers: 2,
            leaf_size: 16,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
        };
        let res = run_sweep(&cfg);
        assert_eq!(res.cells.len(), 2);
        for c in &res.cells {
            assert!(
                matches!(c.outcome, CellOutcome::Time(_)),
                "auto cell failed: {:?}",
                c.outcome
            );
            assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
        }
        assert_eq!(res.naive_secs.len(), 2, "truth must be recorded per bandwidth");
        assert!(res.naive_secs.iter().all(|&s| s > 0.0));
    }

    /// Regression for the silently-dropped-cell bug: the old pool
    /// ignored `result_tx.send` failures and never compared received
    /// against scheduled, so a panicking worker shrank the table. Now a
    /// poisoned cell surfaces the original panic to `run_sweep`'s
    /// caller instead of returning a partial table.
    #[test]
    fn poisoned_cell_panics_the_sweep_instead_of_dropping_cells() {
        let cfg = small_cfg();
        let bandwidths: Vec<f64> = cfg.multipliers.iter().map(|m| m * cfg.h_star).collect();
        let session = Session::prepare(
            &cfg.dataset.points,
            PrepareOptions {
                leaf_size: cfg.leaf_size,
                threads: cfg.workers,
                fast_exp: cfg.fast_exp,
                truth_cache_capacity: bandwidths.len().max(64),
                ..Default::default()
            },
        );
        // poison one bandwidth's truth: its computing requester panics
        assert!(session
            .exact_sums_with(bandwidths[1], || panic!("injected cell failure"))
            .is_err());
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_sweep_on(&cfg, &session, 0.0)
        }));
        let payload = result.expect_err("a poisoned cell must fail the sweep loudly");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("injected cell failure") || msg.contains("truth unavailable"),
            "panic must carry the cell context: {msg}"
        );
    }

    /// Every scheduled cell is delivered, in (algo, h) order, on every
    /// pool width — the received == scheduled contract.
    #[test]
    fn all_scheduled_cells_are_received_in_order() {
        for workers in [1, 3] {
            let mut cfg = small_cfg();
            cfg.workers = workers;
            let res = run_sweep(&cfg);
            assert_eq!(res.cells.len(), cfg.algorithms.len() * cfg.multipliers.len());
            for (k, c) in res.cells.iter().enumerate() {
                assert_eq!(c.algo_index, k / cfg.multipliers.len(), "workers={workers}");
                assert_eq!(c.bandwidth_index, k % cfg.multipliers.len(), "workers={workers}");
            }
        }
    }

    #[test]
    fn fgt_cell_protocol_small_h_is_ram_bound() {
        let ds = data::by_name("astro2d", 200, 12).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![1e-3],
            algorithms: vec![AlgoSpec::Fgt],
            workers: 1,
            leaf_size: 16,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
        };
        let res = run_sweep(&cfg);
        assert!(matches!(res.cells[0].outcome, CellOutcome::RamExhausted));
    }

    /// A non-Gaussian sweep: every cell routes through the SoG layer,
    /// verifies against the exhaustive true-kernel sum under the
    /// weight-scaled guarantee, and reports per-component routing.
    #[test]
    fn laplace_sweep_verifies_weight_scaled() {
        let ds = data::by_name("astro2d", 200, 17).unwrap();
        let h = silverman(&ds.points);
        let cfg = SweepConfig {
            dataset: ds,
            epsilon: 0.01,
            h_star: h,
            multipliers: vec![1.0],
            algorithms: vec![AlgoSpec::Dfdo, AlgoSpec::Auto],
            workers: 2,
            leaf_size: 16,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Laplace,
        };
        let res = run_sweep(&cfg);
        assert_eq!(res.kernel, Kernel::Laplace);
        assert_eq!(res.cells.len(), 2);
        for c in &res.cells {
            assert!(
                matches!(c.outcome, CellOutcome::Time(_)),
                "laplace cell failed: {:?}",
                c.outcome
            );
            assert!(c.rel_err.unwrap() <= 0.01 * (1.0 + 1e-9));
            let stats = c.stats.as_ref().expect("sog cell must carry stats");
            assert!(stats.sog_components > 0, "cell must report SoG fan-out");
            assert_eq!(
                stats.sog_routed.iter().sum::<u64>(),
                stats.sog_components,
                "every component must be routed to a concrete method"
            );
        }
        assert!(res.naive_secs.iter().all(|&s| s > 0.0));
    }
}
