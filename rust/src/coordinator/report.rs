//! Paper-style table rendering: one row per algorithm, one column per
//! bandwidth multiplier plus the Σ column the paper's conclusions rest
//! on. `X` = RAM exhausted, `∞` = tolerance unreachable — exactly the
//! paper's conventions.

use crate::util::timer::fmt_secs;

use super::job::{CellOutcome, SweepResult};

/// Render the sweep as the paper's table.
pub fn render_table(res: &SweepResult) -> String {
    let mut out = String::new();
    // the paper's tables are all Gaussian; flag SoG sweeps (and their
    // weight-scaled error norm) explicitly rather than silently
    let kernel_tag = if res.kernel.is_gaussian() {
        String::new()
    } else {
        format!(", kernel = {} (SoG, err ≤ eps·W)", res.kernel)
    };
    out.push_str(&format!(
        "{}, D = {}, N = {}, h* = {:.6}, eps = {}{}\n",
        res.dataset, res.dim, res.n, res.h_star, res.epsilon, kernel_tag
    ));
    // header
    out.push_str(&format!("{:<8}", "Alg\\h*"));
    for m in &res.multipliers {
        out.push_str(&format!("{:>9}", fmt_mult(*m)));
    }
    out.push_str(&format!("{:>10}\n", "Σ"));
    // rows
    let totals = res.totals();
    for (a, spec) in res.algorithms.iter().enumerate() {
        out.push_str(&format!("{:<8}", spec.name()));
        for b in 0..res.multipliers.len() {
            let cell = res.cell(a, b);
            let txt = match cell.outcome {
                CellOutcome::Time(t) => fmt_secs(t),
                CellOutcome::RamExhausted => "X".to_string(),
                CellOutcome::ToleranceUnreachable => "inf".to_string(),
            };
            out.push_str(&format!("{txt:>9}"));
        }
        let tot = match totals[a] {
            Some(t) => fmt_secs(t),
            None => {
                // propagate the dominant failure type like the paper
                let any_ram = (0..res.multipliers.len())
                    .any(|b| res.cell(a, b).outcome == CellOutcome::RamExhausted);
                if any_ram { "X".to_string() } else { "inf".to_string() }
            }
        };
        out.push_str(&format!("{tot:>10}\n"));
    }
    out
}

/// Render a machine-readable CSV of the same data.
pub fn render_csv(res: &SweepResult) -> String {
    let mut out = String::from("dataset,dim,n,algorithm,multiplier,bandwidth,outcome,secs,rel_err\n");
    for (a, spec) in res.algorithms.iter().enumerate() {
        for (b, m) in res.multipliers.iter().enumerate() {
            let cell = res.cell(a, b);
            let (outcome, secs) = match cell.outcome {
                CellOutcome::Time(t) => ("ok", t),
                CellOutcome::RamExhausted => ("ram", f64::NAN),
                CellOutcome::ToleranceUnreachable => ("tol", f64::NAN),
            };
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{}\n",
                res.dataset,
                res.dim,
                res.n,
                spec.name(),
                m,
                m * res.h_star,
                outcome,
                secs,
                cell.rel_err.map(|e| e.to_string()).unwrap_or_default()
            ));
        }
    }
    out
}

fn fmt_mult(m: f64) -> String {
    let l = m.log10();
    if (l - l.round()).abs() < 1e-9 {
        let e = l.round() as i32;
        match e {
            0 => "1".to_string(),
            _ => format!("1e{e}"),
        }
    } else {
        format!("{m}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{AlgoSpec, CellResult, SweepResult};
    use crate::kernel::Kernel;

    fn sample() -> SweepResult {
        SweepResult {
            dataset: "astro2d".into(),
            dim: 2,
            n: 100,
            h_star: 0.0139,
            epsilon: 0.01,
            kernel: Kernel::Gaussian,
            multipliers: vec![0.001, 1.0, 1000.0],
            algorithms: vec![AlgoSpec::Naive, AlgoSpec::Fgt, AlgoSpec::Dito],
            naive_secs: vec![4.0, 4.0, 4.0],
            prep_secs: 0.0,
            cells: vec![
                CellResult { algo_index: 0, bandwidth_index: 0, outcome: CellOutcome::Time(452.0), rel_err: Some(0.0), stats: None },
                CellResult { algo_index: 0, bandwidth_index: 1, outcome: CellOutcome::Time(452.0), rel_err: Some(0.0), stats: None },
                CellResult { algo_index: 0, bandwidth_index: 2, outcome: CellOutcome::Time(452.0), rel_err: Some(0.0), stats: None },
                CellResult { algo_index: 1, bandwidth_index: 0, outcome: CellOutcome::RamExhausted, rel_err: None, stats: None },
                CellResult { algo_index: 1, bandwidth_index: 1, outcome: CellOutcome::Time(4.36), rel_err: Some(0.004), stats: None },
                CellResult { algo_index: 1, bandwidth_index: 2, outcome: CellOutcome::Time(0.13), rel_err: Some(0.001), stats: None },
                CellResult { algo_index: 2, bandwidth_index: 0, outcome: CellOutcome::Time(2.61), rel_err: Some(0.003), stats: None },
                CellResult { algo_index: 2, bandwidth_index: 1, outcome: CellOutcome::Time(9.21), rel_err: Some(0.008), stats: None },
                CellResult { algo_index: 2, bandwidth_index: 2, outcome: CellOutcome::Time(0.84), rel_err: Some(0.002), stats: None },
            ],
        }
    }

    #[test]
    fn table_contains_paper_conventions() {
        let t = render_table(&sample());
        assert!(t.contains("astro2d, D = 2, N = 100"));
        assert!(t.contains("1e-3"), "{t}");
        assert!(t.contains('X'), "{t}");
        assert!(t.contains("Naive"));
        // FGT row total must be X (RAM failure dominates)
        let fgt_line = t.lines().find(|l| l.starts_with("FGT")).unwrap();
        assert!(fgt_line.trim_end().ends_with('X'), "{fgt_line}");
        // DITO total = 2.61+9.21+0.84 = 12.66 → "12.7"
        let dito_line = t.lines().find(|l| l.starts_with("DITO")).unwrap();
        assert!(dito_line.contains("12.7"), "{dito_line}");
    }

    #[test]
    fn csv_has_one_row_per_cell() {
        let c = render_csv(&sample());
        assert_eq!(c.lines().count(), 1 + 9);
        assert!(c.contains("FGT,0.001"));
        assert!(c.contains(",ram,"));
    }

    #[test]
    fn non_gaussian_table_flags_kernel_and_norm() {
        let mut res = sample();
        res.kernel = Kernel::Matern32;
        let t = render_table(&res);
        assert!(t.contains("kernel = matern32"), "{t}");
        assert!(t.contains("eps·W"), "{t}");
        // Gaussian header stays byte-identical to the paper's
        assert!(!render_table(&sample()).contains("kernel"), "gaussian must stay untagged");
    }

    #[test]
    fn multiplier_formatting() {
        assert_eq!(fmt_mult(0.001), "1e-3");
        assert_eq!(fmt_mult(1.0), "1");
        assert_eq!(fmt_mult(1000.0), "1e3");
        assert_eq!(fmt_mult(2.5), "2.5");
    }
}
