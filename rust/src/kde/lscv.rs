//! Least-squares cross-validation (Silverman 1986) — the paper's
//! criterion for the optimal bandwidth h*.
//!
//! LSCV(h) = ∫f̂² − (2/n)·Σ_i f̂₋ᵢ(x_i), minimized over h. Both terms
//! reduce to Gaussian summations — which is exactly why the paper
//! stresses that bandwidth selection needs fast summation *across a
//! whole range of bandwidths*:
//!
//! * ∫f̂² = (2π·2h²)^(−D/2)/n² · S_{√2·h}   (Gaussian convolution identity),
//! * Σ_i f̂₋ᵢ(x_i) = (2πh²)^(−D/2)/(n−1) · (S_h − n),
//!
//! with S_h = Σ_i Σ_j K_h(‖x_i−x_j‖) the self-included summation both
//! engines already compute.
//!
//! Three evaluation paths:
//! * [`lscv_score_session`]/[`select_bandwidth_session`] — the front
//!   door: a prepared [`Session`], any [`Method`] (incl. `Auto`), the
//!   whole grid batched through [`Session::evaluate_batch`];
//! * [`lscv_score_engine`]/[`select_bandwidth_engine`] run a prepared
//!   [`SweepEngine`] directly (the dual-tree layer the session embeds);
//! * [`lscv_score`]/[`select_bandwidth`] run any [`GaussSum`] engine and
//!   rebuild its data structures per call — deprecated shims for
//!   one-off scores and engine mocks.

use crate::api::{EvalRequest, Method, Session};
use crate::algo::dualtree::DualTreeConfig;
use crate::algo::{AlgoError, GaussSum, GaussSumProblem, SweepEngine};
use crate::geometry::Matrix;
use crate::kernel::{GaussianKernel, Kernel};

/// The closed-form LSCV score from the two self-summations
/// S_h (`s1`) and S_{√2·h} (`s2`).
fn score_from_sums(n: f64, dim: usize, h: f64, s1: f64, s2: f64) -> f64 {
    let h2 = std::f64::consts::SQRT_2 * h;
    let term1 = GaussianKernel::new(h2).norm_const(dim) * s2 / (n * n);
    let term2 = 2.0 * GaussianKernel::new(h).norm_const(dim) * (s1 - n) / (n * (n - 1.0));
    term1 - term2
}

/// The LSCV score for one bandwidth (lower is better).
pub fn lscv_score(
    data: &Matrix,
    h: f64,
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<f64, AlgoError> {
    let n = data.rows() as f64;
    let d = data.cols();
    // term 1: ∫ f̂² via the √2·h summation
    let h2 = std::f64::consts::SQRT_2 * h;
    let p2 = GaussSumProblem::kde(data, h2, epsilon);
    let s2: f64 = engine.run(&p2)?.sums.iter().sum();
    // term 2: leave-one-out mean density via the h summation
    let p1 = GaussSumProblem::kde(data, h, epsilon);
    let s1: f64 = engine.run(&p1)?.sums.iter().sum();
    Ok(score_from_sums(n, d, h, s1, s2))
}

/// The LSCV score for one bandwidth on a prepared [`SweepEngine`]
/// (monochromatic engines only): two `evaluate` calls, zero tree
/// builds.
pub fn lscv_score_engine(
    engine: &SweepEngine,
    h: f64,
    epsilon: f64,
    variant: &DualTreeConfig,
) -> Result<f64, AlgoError> {
    assert!(
        engine.is_monochromatic(),
        "LSCV is defined on a single dataset (monochromatic engine)"
    );
    let n = engine.num_points() as f64;
    let d = engine.dim();
    let h2 = std::f64::consts::SQRT_2 * h;
    let s2: f64 = engine.evaluate(h2, epsilon, variant)?.sums.iter().sum();
    let s1: f64 = engine.evaluate(h, epsilon, variant)?.sums.iter().sum();
    Ok(score_from_sums(n, d, h, s1, s2))
}

/// Pick the winning bandwidth from a scored grid.
///
/// Non-finite scores (NaN/±∞ — e.g. a poisoned summation) are *skipped
/// with a warning* instead of silently losing every comparison, which
/// previously let a NaN-poisoned grid return `grid[0]` as if it had
/// won. Exact ties break deterministically toward the smaller h
/// (smoother estimates are the safer default). Errors when no score is
/// finite.
pub fn pick_best(grid: &[f64], scores: &[f64]) -> Result<f64, AlgoError> {
    assert_eq!(grid.len(), scores.len());
    let mut best: Option<(f64, f64)> = None; // (h, score)
    for (&h, &s) in grid.iter().zip(scores) {
        if !s.is_finite() {
            eprintln!("lscv: skipping non-finite score {s} at h={h:.6e}");
            continue;
        }
        best = Some(match best {
            None => (h, s),
            Some((bh, bs)) => {
                if s < bs || (s == bs && h < bh) {
                    (h, s)
                } else {
                    (bh, bs)
                }
            }
        });
    }
    best.map(|(h, _)| h).ok_or_else(|| {
        AlgoError::ToleranceUnreachable(format!(
            "LSCV: all {} grid scores are non-finite",
            grid.len()
        ))
    })
}

/// Evaluate LSCV over a bandwidth grid and return (best h, all scores).
pub fn select_bandwidth(
    data: &Matrix,
    grid: &[f64],
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<(f64, Vec<f64>), AlgoError> {
    assert!(!grid.is_empty());
    let mut scores = Vec::with_capacity(grid.len());
    for &h in grid {
        scores.push(lscv_score(data, h, epsilon, engine)?);
    }
    Ok((pick_best(grid, &scores)?, scores))
}

/// The LSCV score for one bandwidth through the session front door:
/// two summations against the session's prepared state, any
/// [`Method`] (including `Auto`, resolved per bandwidth).
pub fn lscv_score_session(
    session: &Session<'_>,
    h: f64,
    epsilon: f64,
    method: Method,
) -> Result<f64, AlgoError> {
    assert!(session.is_unweighted(), "LSCV is defined for unweighted KDE");
    let n = session.num_points() as f64;
    let d = session.dim();
    let h2 = std::f64::consts::SQRT_2 * h;
    // the √2·h convolution identity behind the score is
    // Gaussian-specific, so these requests pin the Gaussian kernel
    // regardless of the session default
    let s2: f64 = session
        .evaluate(&EvalRequest::kde(h2, epsilon).with_method(method).with_kernel(Kernel::Gaussian))?
        .sums
        .iter()
        .sum();
    let s1: f64 = session
        .evaluate(&EvalRequest::kde(h, epsilon).with_method(method).with_kernel(Kernel::Gaussian))?
        .sums
        .iter()
        .sum();
    Ok(score_from_sums(n, d, h, s1, s2))
}

/// Evaluate LSCV over a bandwidth grid on a prepared [`Session`]: the
/// 2·G summations (each grid h and its √2·h companion) go through one
/// [`Session::evaluate_batch`] call — request tasks and their nested
/// traversal tasks share the session's work-stealing pool, so even a
/// 2-bandwidth grid saturates every worker — with zero further tree
/// builds. Scores are bit-identical to [`select_bandwidth_engine`] for
/// the corresponding dual-tree method, in any pool width.
pub fn select_bandwidth_session(
    session: &Session<'_>,
    grid: &[f64],
    epsilon: f64,
    method: Method,
) -> Result<(f64, Vec<f64>), AlgoError> {
    assert!(!grid.is_empty());
    assert!(session.is_unweighted(), "LSCV is defined for unweighted KDE");
    let n = session.num_points() as f64;
    let d = session.dim();
    let grid2: Vec<f64> = grid.iter().map(|&h| std::f64::consts::SQRT_2 * h).collect();
    // Gaussian pinned: the LSCV score's closed form is (see
    // lscv_score_session) — a non-Gaussian session default must not
    // leak into it
    let requests: Vec<EvalRequest<'static>> = grid
        .iter()
        .chain(grid2.iter())
        .map(|&h| EvalRequest::kde(h, epsilon).with_method(method).with_kernel(Kernel::Gaussian))
        .collect();
    let mut sums = Vec::with_capacity(requests.len());
    for res in session.evaluate_batch(&requests) {
        sums.push(res?.sums.iter().sum::<f64>());
    }
    let scores: Vec<f64> = grid
        .iter()
        .enumerate()
        .map(|(i, &h)| score_from_sums(n, d, h, sums[i], sums[grid.len() + i]))
        .collect();
    Ok((pick_best(grid, &scores)?, scores))
}

/// Evaluate LSCV over a bandwidth grid on a prepared [`SweepEngine`]:
/// the kd-tree is built once for the whole grid, and both summation
/// grids (h and √2·h) run through [`SweepEngine::evaluate_grid`], which
/// parallelizes across bandwidths with the engine's thread count.
pub fn select_bandwidth_engine(
    engine: &SweepEngine,
    grid: &[f64],
    epsilon: f64,
    variant: &DualTreeConfig,
) -> Result<(f64, Vec<f64>), AlgoError> {
    assert!(!grid.is_empty());
    assert!(
        engine.is_monochromatic(),
        "LSCV is defined on a single dataset (monochromatic engine)"
    );
    let n = engine.num_points() as f64;
    let d = engine.dim();
    let grid2: Vec<f64> = grid.iter().map(|&h| std::f64::consts::SQRT_2 * h).collect();
    let r1 = engine.evaluate_grid(grid, epsilon, variant)?;
    let r2 = engine.evaluate_grid(&grid2, epsilon, variant)?;
    let scores: Vec<f64> = grid
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            let s1: f64 = r1[i].sums.iter().sum();
            let s2: f64 = r2[i].sums.iter().sum();
            score_from_sums(n, d, h, s1, s2)
        })
        .collect();
    Ok((pick_best(grid, &scores)?, scores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::{GaussSumResult, RunStats};
    use crate::kde::bandwidth::{log_grid, silverman};
    use crate::util::Pcg32;

    fn gaussian_1d(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(&(0..n).map(|_| vec![rng.normal()]).collect::<Vec<_>>())
    }

    /// LSCV must pick a bandwidth near the Silverman pilot for Gaussian
    /// data (where the pilot is near-optimal), rejecting extremes.
    #[test]
    fn selects_reasonable_bandwidth_for_gaussian_data() {
        let data = gaussian_1d(400, 141);
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 1e-2, 1e2, 13);
        let (h_star, scores) = select_bandwidth(&data, &grid, 1e-6, &Naive::new()).unwrap();
        assert_eq!(scores.len(), 13);
        assert!(
            h_star > pilot / 10.0 && h_star < pilot * 10.0,
            "h*={h_star} pilot={pilot}"
        );
        // extremes must be worse than the winner
        let best_score = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(scores[0] > best_score);
        assert!(scores[12] > best_score);
    }

    /// The LSCV identity: our closed-form score equals the direct
    /// definition computed by brute force.
    #[test]
    fn matches_bruteforce_definition() {
        let data = gaussian_1d(60, 142);
        let n = data.rows() as f64;
        let h = 0.4;
        let score = lscv_score(&data, h, 1e-9, &Naive::new()).unwrap();
        // brute force: ∫f̂² on a fine grid, LOO term by direct loops
        let grid_step = 0.01;
        let mut integral = 0.0;
        let norm = GaussianKernel::new(h).norm_const(1) / n;
        let mut x = -8.0;
        while x < 8.0 {
            let mut f = 0.0;
            for i in 0..data.rows() {
                let dd = x - data.get(i, 0);
                f += (-0.5 * dd * dd / (h * h)).exp();
            }
            integral += (f * norm) * (f * norm) * grid_step;
            x += grid_step;
        }
        let mut loo = 0.0;
        for i in 0..data.rows() {
            let mut f = 0.0;
            for j in 0..data.rows() {
                if i != j {
                    let dd = data.get(i, 0) - data.get(j, 0);
                    f += (-0.5 * dd * dd / (h * h)).exp();
                }
            }
            loo += f * GaussianKernel::new(h).norm_const(1) / (n - 1.0);
        }
        let brute = integral - 2.0 * loo / n;
        assert!((score - brute).abs() < 2e-3 * brute.abs().max(1.0), "{score} vs {brute}");
    }

    /// Dual-tree engines must agree with Naive on the selected h.
    #[test]
    fn dito_and_naive_agree_on_selection() {
        use crate::algo::dito::Dito;
        let mut rng = Pcg32::new(143);
        let data = Matrix::from_rows(
            &(0..300)
                .map(|_| vec![0.3 + 0.05 * rng.normal(), 0.7 + 0.08 * rng.normal()])
                .collect::<Vec<_>>(),
        );
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 0.1, 10.0, 7);
        let (h_naive, _) = select_bandwidth(&data, &grid, 1e-4, &Naive::new()).unwrap();
        let (h_dito, _) = select_bandwidth(&data, &grid, 1e-4, &Dito::default()).unwrap();
        assert_eq!(h_naive, h_dito);
    }

    /// The prepared-engine sweep must select the same bandwidth as the
    /// per-h rebuild path.
    #[test]
    fn engine_sweep_agrees_with_rebuild_path() {
        let mut rng = Pcg32::new(144);
        let data = Matrix::from_rows(
            &(0..300)
                .map(|_| vec![0.4 + 0.06 * rng.normal(), 0.6 + 0.05 * rng.normal()])
                .collect::<Vec<_>>(),
        );
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 0.1, 10.0, 7);
        let variant = DualTreeConfig::default();
        let (h_rebuild, scores_rebuild) =
            select_bandwidth(&data, &grid, 1e-4, &crate::algo::dito::Dito::default()).unwrap();
        let engine = SweepEngine::for_kde(&data, 32).with_threads(2);
        let (h_engine, scores_engine) =
            select_bandwidth_engine(&engine, &grid, 1e-4, &variant).unwrap();
        assert_eq!(h_rebuild, h_engine);
        assert_eq!(engine.tree_builds(), 1);
        for (a, b) in scores_rebuild.iter().zip(&scores_engine) {
            assert!((a - b).abs() <= 1e-12 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    /// The session sweep must reproduce the engine sweep bit-for-bit
    /// (same single-threaded per-h code path underneath).
    #[test]
    fn session_sweep_matches_engine_sweep() {
        use crate::api::{PrepareOptions, Session};
        let mut rng = Pcg32::new(147);
        let data = Matrix::from_rows(
            &(0..250)
                .map(|_| vec![0.5 + 0.07 * rng.normal(), 0.5 + 0.05 * rng.normal()])
                .collect::<Vec<_>>(),
        );
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 0.1, 10.0, 5);
        let engine = SweepEngine::for_kde(&data, 32).with_threads(2);
        let (h_engine, scores_engine) =
            select_bandwidth_engine(&engine, &grid, 1e-4, &DualTreeConfig::default()).unwrap();
        let session =
            Session::prepare(&data, PrepareOptions { threads: 2, ..Default::default() });
        let (h_session, scores_session) =
            select_bandwidth_session(&session, &grid, 1e-4, Method::Dito).unwrap();
        assert_eq!(h_engine, h_session);
        assert_eq!(scores_engine, scores_session, "session sweep diverged from engine sweep");
        assert_eq!(session.tree_builds(), 1);
        // per-h scores also match the single-score session entry point.
        // Since the shared pool's fixed task decomposition made the
        // traversal pool-width-invariant, this holds for ANY thread
        // count — pin both the inline-pool and a wide-pool session.
        let session1 = Session::kde(&data);
        let s0 = lscv_score_session(&session1, grid[0], 1e-4, Method::Dito).unwrap();
        assert_eq!(s0, scores_session[0]);
        let session8 =
            Session::prepare(&data, PrepareOptions { threads: 8, ..Default::default() });
        let s0_wide = lscv_score_session(&session8, grid[0], 1e-4, Method::Dito).unwrap();
        assert_eq!(s0_wide, scores_session[0], "pool width must not change LSCV scores");
    }

    /// A mock summation engine that poisons chosen bandwidths with NaN.
    struct NanAt {
        nan_below_h: f64,
    }

    impl GaussSum for NanAt {
        fn name(&self) -> &'static str {
            "NanAt"
        }

        fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
            let n = problem.num_queries();
            let v = if problem.h < self.nan_below_h { f64::NAN } else { problem.h };
            Ok(GaussSumResult { sums: vec![v; n], stats: RunStats::default() })
        }
    }

    /// Regression: a NaN score must be skipped (previously `s < best`
    /// was false for NaN, so a fully poisoned grid silently returned
    /// `grid[0]` as the winner).
    #[test]
    fn nan_scores_are_skipped_not_winners() {
        let data = gaussian_1d(40, 145);
        // h=0.1 and h=0.2 poisoned; only h=0.4 yields a finite score
        let grid = [0.1, 0.2, 0.4];
        let engine = NanAt { nan_below_h: 0.3 };
        let (h, scores) = select_bandwidth(&data, &grid, 1e-6, &engine).unwrap();
        assert!(scores[0].is_nan() && scores[1].is_nan());
        assert!(scores[2].is_finite());
        assert_eq!(h, 0.4, "NaN score must not win the grid");
    }

    /// Regression: an all-NaN grid must surface an error, not grid[0].
    #[test]
    fn all_nan_grid_errors() {
        let data = gaussian_1d(40, 146);
        let grid = [0.1, 0.2];
        let engine = NanAt { nan_below_h: f64::INFINITY };
        let err = select_bandwidth(&data, &grid, 1e-6, &engine).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }

    /// Exact score ties break toward the smaller bandwidth.
    #[test]
    fn ties_break_toward_smaller_h() {
        assert_eq!(pick_best(&[0.4, 0.1, 0.2], &[1.0, 1.0, 1.0]).unwrap(), 0.1);
        assert_eq!(pick_best(&[0.4, 0.1], &[0.5, 1.0]).unwrap(), 0.4);
        // non-finite entries are ignored entirely
        assert_eq!(
            pick_best(&[0.1, 0.2, 0.3], &[f64::NAN, 2.0, f64::INFINITY]).unwrap(),
            0.2
        );
    }
}
