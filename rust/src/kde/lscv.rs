//! Least-squares cross-validation (Silverman 1986) — the paper's
//! criterion for the optimal bandwidth h*.
//!
//! LSCV(h) = ∫f̂² − (2/n)·Σ_i f̂₋ᵢ(x_i), minimized over h. Both terms
//! reduce to Gaussian summations — which is exactly why the paper
//! stresses that bandwidth selection needs fast summation *across a
//! whole range of bandwidths*:
//!
//! * ∫f̂² = (2π·2h²)^(−D/2)/n² · S_{√2·h}   (Gaussian convolution identity),
//! * Σ_i f̂₋ᵢ(x_i) = (2πh²)^(−D/2)/(n−1) · (S_h − n),
//!
//! with S_h = Σ_i Σ_j K_h(‖x_i−x_j‖) the self-included summation both
//! engines already compute.

use crate::algo::{AlgoError, GaussSum, GaussSumProblem};
use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

/// The LSCV score for one bandwidth (lower is better).
pub fn lscv_score(
    data: &Matrix,
    h: f64,
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<f64, AlgoError> {
    let n = data.rows() as f64;
    let d = data.cols();
    // term 1: ∫ f̂² via the √2·h summation
    let h2 = std::f64::consts::SQRT_2 * h;
    let p2 = GaussSumProblem::kde(data, h2, epsilon);
    let s2: f64 = engine.run(&p2)?.sums.iter().sum();
    let term1 = GaussianKernel::new(h2).norm_const(d) * s2 / (n * n);
    // term 2: leave-one-out mean density via the h summation
    let p1 = GaussSumProblem::kde(data, h, epsilon);
    let s1: f64 = engine.run(&p1)?.sums.iter().sum();
    let term2 = 2.0 * GaussianKernel::new(h).norm_const(d) * (s1 - n) / (n * (n - 1.0));
    Ok(term1 - term2)
}

/// Evaluate LSCV over a bandwidth grid and return (best h, all scores).
pub fn select_bandwidth(
    data: &Matrix,
    grid: &[f64],
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<(f64, Vec<f64>), AlgoError> {
    assert!(!grid.is_empty());
    let mut scores = Vec::with_capacity(grid.len());
    let mut best = (grid[0], f64::INFINITY);
    for &h in grid {
        let s = lscv_score(data, h, epsilon, engine)?;
        if s < best.1 {
            best = (h, s);
        }
        scores.push(s);
    }
    Ok((best.0, scores))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::kde::bandwidth::{log_grid, silverman};
    use crate::util::Pcg32;

    fn gaussian_1d(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(&(0..n).map(|_| vec![rng.normal()]).collect::<Vec<_>>())
    }

    /// LSCV must pick a bandwidth near the Silverman pilot for Gaussian
    /// data (where the pilot is near-optimal), rejecting extremes.
    #[test]
    fn selects_reasonable_bandwidth_for_gaussian_data() {
        let data = gaussian_1d(400, 141);
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 1e-2, 1e2, 13);
        let (h_star, scores) = select_bandwidth(&data, &grid, 1e-6, &Naive::new()).unwrap();
        assert_eq!(scores.len(), 13);
        assert!(
            h_star > pilot / 10.0 && h_star < pilot * 10.0,
            "h*={h_star} pilot={pilot}"
        );
        // extremes must be worse than the winner
        let best_score = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(scores[0] > best_score);
        assert!(scores[12] > best_score);
    }

    /// The LSCV identity: our closed-form score equals the direct
    /// definition computed by brute force.
    #[test]
    fn matches_bruteforce_definition() {
        let data = gaussian_1d(60, 142);
        let n = data.rows() as f64;
        let h = 0.4;
        let score = lscv_score(&data, h, 1e-9, &Naive::new()).unwrap();
        // brute force: ∫f̂² on a fine grid, LOO term by direct loops
        let grid_step = 0.01;
        let mut integral = 0.0;
        let norm = GaussianKernel::new(h).norm_const(1) / n;
        let mut x = -8.0;
        while x < 8.0 {
            let mut f = 0.0;
            for i in 0..data.rows() {
                let dd = x - data.get(i, 0);
                f += (-0.5 * dd * dd / (h * h)).exp();
            }
            integral += (f * norm) * (f * norm) * grid_step;
            x += grid_step;
        }
        let mut loo = 0.0;
        for i in 0..data.rows() {
            let mut f = 0.0;
            for j in 0..data.rows() {
                if i != j {
                    let dd = data.get(i, 0) - data.get(j, 0);
                    f += (-0.5 * dd * dd / (h * h)).exp();
                }
            }
            loo += f * GaussianKernel::new(h).norm_const(1) / (n - 1.0);
        }
        let brute = integral - 2.0 * loo / n;
        assert!((score - brute).abs() < 2e-3 * brute.abs().max(1.0), "{score} vs {brute}");
    }

    /// Dual-tree engines must agree with Naive on the selected h.
    #[test]
    fn dito_and_naive_agree_on_selection() {
        use crate::algo::dito::Dito;
        let mut rng = Pcg32::new(143);
        let data = Matrix::from_rows(
            &(0..300)
                .map(|_| vec![0.3 + 0.05 * rng.normal(), 0.7 + 0.08 * rng.normal()])
                .collect::<Vec<_>>(),
        );
        let pilot = silverman(&data);
        let grid = log_grid(pilot, 0.1, 10.0, 7);
        let (h_naive, _) = select_bandwidth(&data, &grid, 1e-4, &Naive::new()).unwrap();
        let (h_dito, _) = select_bandwidth(&data, &grid, 1e-4, &Dito::default()).unwrap();
        assert_eq!(h_naive, h_dito);
    }
}
