//! Bandwidth utilities: the Silverman rule-of-thumb pilot, and the
//! paper's 10⁻³h*…10³h* sweep grid.

use crate::geometry::Matrix;
use crate::util::stats;

/// Silverman's rule-of-thumb bandwidth for D-dim Gaussian KDE:
/// h = σ̄ · (4/((D+2)·n))^(1/(D+4)), with σ̄ the average per-dimension
/// standard deviation (Silverman 1986, eq. 4.14 generalization).
pub fn silverman(data: &Matrix) -> f64 {
    let d = data.cols() as f64;
    let n = data.rows() as f64;
    let sigma = stats::mean(&data.col_std());
    let sigma = if sigma > 0.0 { sigma } else { 1.0 };
    sigma * (4.0 / ((d + 2.0) * n)).powf(1.0 / (d + 4.0))
}

/// The paper's per-table bandwidth multipliers 10⁻³ … 10³.
pub const PAPER_MULTIPLIERS: [f64; 7] =
    [1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3];

/// Log-spaced bandwidth grid of `count` points spanning
/// [lo_mult·h_star, hi_mult·h_star].
pub fn log_grid(h_star: f64, lo_mult: f64, hi_mult: f64, count: usize) -> Vec<f64> {
    assert!(count >= 2 && lo_mult > 0.0 && hi_mult > lo_mult);
    let l0 = (h_star * lo_mult).ln();
    let l1 = (h_star * hi_mult).ln();
    (0..count)
        .map(|i| (l0 + (l1 - l0) * i as f64 / (count - 1) as f64).exp())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn silverman_1d_gaussian_known_value() {
        // for σ=1, n=1000, D=1: h = (4/3000)^(1/5) ≈ 0.2661
        let mut rng = Pcg32::new(131);
        let data =
            Matrix::from_rows(&(0..1000).map(|_| vec![rng.normal()]).collect::<Vec<_>>());
        let h = silverman(&data);
        assert!((h - 0.266).abs() < 0.03, "h={h}");
    }

    #[test]
    fn shrinks_with_n_grows_with_spread() {
        let mut rng = Pcg32::new(132);
        let small =
            Matrix::from_rows(&(0..100).map(|_| vec![rng.normal()]).collect::<Vec<_>>());
        let big =
            Matrix::from_rows(&(0..10000).map(|_| vec![rng.normal()]).collect::<Vec<_>>());
        assert!(silverman(&big) < silverman(&small));
        let wide = Matrix::from_rows(
            &(0..100).map(|_| vec![5.0 * rng.normal()]).collect::<Vec<_>>(),
        );
        assert!(silverman(&wide) > silverman(&small));
    }

    #[test]
    fn degenerate_constant_data() {
        let data = Matrix::from_rows(&vec![vec![3.0, 3.0]; 10]);
        let h = silverman(&data);
        assert!(h > 0.0 && h.is_finite());
    }

    #[test]
    fn log_grid_endpoints_and_monotone() {
        let g = log_grid(0.5, 1e-3, 1e3, 7);
        assert_eq!(g.len(), 7);
        assert!((g[0] - 0.5e-3).abs() < 1e-12);
        assert!((g[6] - 0.5e3).abs() < 1e-9);
        for i in 1..7 {
            assert!(g[i] > g[i - 1]);
        }
        // paper multipliers: factor 10 between consecutive points
        assert!((g[1] / g[0] - 10.0).abs() < 1e-9);
    }
}
