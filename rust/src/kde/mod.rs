//! Kernel density estimation on top of the Gaussian-summation engines —
//! the paper's motivating application, including least-squares
//! cross-validation for optimal bandwidth selection.

pub mod bandwidth;
pub mod lscv;

use crate::api::{EvalRequest, Method, Session};
use crate::algo::{AlgoError, GaussSum, GaussSumProblem};
use crate::geometry::Matrix;
use crate::kernel::{GaussianKernel, Kernel};

/// f̂ normalization: (1/n)·(2πh²)^(−D/2).
fn kde_norm(h: f64, dim: usize, n: usize) -> f64 {
    GaussianKernel::new(h).norm_const(dim) / n as f64
}

/// Density estimates f̂(x_i) for every point of the session's dataset
/// at bandwidth `h`, under relative tolerance `epsilon`, with `method`
/// (use [`Method::Auto`] to let the session choose).
///
/// f̂(x) = (1/n)·(2πh²)^(−D/2)·Σ_r K_h(‖x−x_r‖)   (self term included,
/// as in the paper's summation definition).
pub fn density_at_points_session(
    session: &Session<'_>,
    h: f64,
    epsilon: f64,
    method: Method,
) -> Result<Vec<f64>, AlgoError> {
    // Gaussian pinned: the (2πh²)^(−D/2) normalizer is the Gaussian
    // one, so these estimators stay correct on any session default
    let req = EvalRequest::kde(h, epsilon).with_method(method).with_kernel(Kernel::Gaussian);
    let ev = session.evaluate(&req)?;
    let norm = kde_norm(h, session.dim(), session.num_points());
    Ok(ev.sums.into_iter().map(|s| s * norm).collect())
}

/// Density at arbitrary query points (bichromatic form) on a prepared
/// session: the reference tree and per-bandwidth state are reused, only
/// a query tree is built per call.
pub fn density_at_session(
    session: &Session<'_>,
    queries: &Matrix,
    h: f64,
    epsilon: f64,
    method: Method,
) -> Result<Vec<f64>, AlgoError> {
    let req = EvalRequest::kde(h, epsilon)
        .with_queries(queries)
        .with_method(method)
        .with_kernel(Kernel::Gaussian);
    let ev = session.evaluate(&req)?;
    let norm = kde_norm(h, session.dim(), session.num_points());
    Ok(ev.sums.into_iter().map(|s| s * norm).collect())
}

/// One-shot form of [`density_at_points_session`] with an explicit
/// engine — a deprecated shim kept for callers (and mocks) that carry
/// their own [`GaussSum`]; it rebuilds all data structures per call.
/// Prefer a [`Session`] in new code.
pub fn density_at_points(
    data: &Matrix,
    h: f64,
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<Vec<f64>, AlgoError> {
    let problem = GaussSumProblem::kde(data, h, epsilon);
    let sums = engine.run(&problem)?.sums;
    let norm = kde_norm(h, data.cols(), data.rows());
    Ok(sums.into_iter().map(|s| s * norm).collect())
}

/// One-shot form of [`density_at_session`] — deprecated shim, see
/// [`density_at_points`].
pub fn density_at(
    queries: &Matrix,
    data: &Matrix,
    h: f64,
    epsilon: f64,
    engine: &dyn GaussSum,
) -> Result<Vec<f64>, AlgoError> {
    let problem = GaussSumProblem::new(queries, data, None, h, epsilon);
    let sums = engine.run(&problem)?.sums;
    let norm = kde_norm(h, data.cols(), data.rows());
    Ok(sums.into_iter().map(|s| s * norm).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::util::Pcg32;

    #[test]
    fn density_integrates_to_one_1d() {
        // Riemann-integrate a 1-D KDE over a wide grid: ≈ 1
        let mut rng = Pcg32::new(121);
        let data =
            Matrix::from_rows(&(0..200).map(|_| vec![rng.normal()]).collect::<Vec<_>>());
        let h = 0.3;
        let grid: Vec<Vec<f64>> = (0..2000).map(|i| vec![-8.0 + 0.008 * i as f64]).collect();
        let gm = Matrix::from_rows(&grid);
        let dens = density_at(&gm, &data, h, 1e-6, &Naive::new()).unwrap();
        let integral: f64 = dens.iter().sum::<f64>() * 0.008;
        assert!((integral - 1.0).abs() < 0.01, "∫f̂ = {integral}");
    }

    #[test]
    fn density_positive_and_peaks_near_mass() {
        let data = Matrix::from_rows(&[vec![0.0, 0.0], vec![0.1, 0.0], vec![5.0, 5.0]]);
        let q = Matrix::from_rows(&[vec![0.05, 0.0], vec![2.5, 2.5]]);
        let dens = density_at(&q, &data, 0.5, 1e-9, &Naive::new()).unwrap();
        assert!(dens.iter().all(|&v| v > 0.0));
        assert!(dens[0] > dens[1]);
    }

    #[test]
    fn session_densities_match_oneshot_shims() {
        let mut rng = Pcg32::new(123);
        let data = Matrix::from_rows(
            &(0..80).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        let q = Matrix::from_rows(&[vec![0.2, 0.3], vec![0.8, 0.1]]);
        let session = Session::kde(&data);
        let a = density_at_points_session(&session, 0.2, 1e-9, Method::Naive).unwrap();
        let b = density_at_points(&data, 0.2, 1e-9, &Naive::new()).unwrap();
        assert_eq!(a, b, "session Naive density must equal the one-shot shim bitwise");
        let c = density_at_session(&session, &q, 0.2, 1e-9, Method::Naive).unwrap();
        let d = density_at(&q, &data, 0.2, 1e-9, &Naive::new()).unwrap();
        assert_eq!(c, d);
    }

    #[test]
    fn monochromatic_matches_bichromatic_on_same_points() {
        let mut rng = Pcg32::new(122);
        let data = Matrix::from_rows(
            &(0..50).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        let a = density_at_points(&data, 0.2, 1e-9, &Naive::new()).unwrap();
        let b = density_at(&data, &data, 0.2, 1e-9, &Naive::new()).unwrap();
        for i in 0..50 {
            assert!((a[i] - b[i]).abs() < 1e-12 * a[i]);
        }
    }
}
