//! Exhaustive O(N·M) Gaussian summation — the ground truth every other
//! algorithm is verified against, and the "Naive" row of the paper's
//! tables. Runs on the shared [`crate::compute`] SoA microkernel,
//! blocked over references for cache locality; a PJRT-offloaded variant
//! lives in [`crate::runtime::tiled_naive`].

use crate::compute::{self, Scratch};
use crate::kernel::GaussianKernel;

use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

/// Blocked exhaustive summation.
#[derive(Copy, Clone, Debug, Default)]
pub struct Naive {
    /// Reference block size (cache tile). 0 = unblocked.
    pub block: usize,
    /// Route through the GEMM-shaped fast driver
    /// ([`compute::gauss_sum_all_fast`]: cached norms + query tiles +
    /// certified fast exp). **Off by default**: `Naive` is the
    /// verification truth every other engine is measured against, so
    /// its default stays bit-exact; opt in via [`Naive::fast`] for
    /// workloads where ~1e-13-relative answers are fine.
    pub fast_exp: bool,
}

impl Naive {
    pub fn new() -> Self {
        Naive { block: 256, fast_exp: false }
    }

    /// The tiled fast-exp configuration (certified per-pair relative
    /// error ≤ `errorcontrol::base_case_rel_err(dim, h, max‖x‖²)`).
    pub fn fast() -> Self {
        Naive { block: 256, fast_exp: true }
    }
}

impl GaussSum for Naive {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        let kernel = GaussianKernel::new(problem.h);
        let q = problem.queries;
        let r = problem.references;
        let w = problem.weight_vec();
        let mut sums = vec![0.0; q.rows()];
        let mut stats = RunStats::default();

        let block = if self.block == 0 { r.rows() } else { self.block };
        let mut scratch = Scratch::with_block(q.cols(), block.min(r.rows()).max(1));
        if self.fast_exp {
            compute::gauss_sum_all_fast(q, r, &w, &kernel, self.block, &mut scratch, &mut sums);
        } else {
            compute::gauss_sum_all(q, r, &w, &kernel, self.block, &mut scratch, &mut sums);
        }

        stats.base_point_pairs = (q.rows() * r.rows()) as u64;
        Ok(GaussSumResult { sums, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn single_pair_known_value() {
        let q = Matrix::from_rows(&[vec![0.0, 0.0]]);
        let r = Matrix::from_rows(&[vec![3.0, 4.0]]);
        let p = GaussSumProblem::new(&q, &r, None, 5.0, 0.01);
        let out = Naive::new().run(&p).unwrap();
        // δ = 5, h = 5 → exp(−25/50) = e^(−1/2)
        assert!((out.sums[0] - (-0.5f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn self_sum_includes_self() {
        let m = random(10, 2, 1);
        let p = GaussSumProblem::kde(&m, 0.1, 0.01);
        let out = Naive::new().run(&p).unwrap();
        // every G(x_q) ≥ K(0)·w_q = 1
        for s in out.sums {
            assert!(s >= 1.0);
        }
    }

    #[test]
    fn blocked_equals_unblocked() {
        let m = random(100, 3, 2);
        let p = GaussSumProblem::kde(&m, 0.2, 0.01);
        let a = Naive { block: 7, ..Naive::default() }.run(&p).unwrap().sums;
        let b = Naive { block: 0, ..Naive::default() }.run(&p).unwrap().sums;
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-12 * b[i].max(1.0));
        }
    }

    #[test]
    fn fast_config_matches_exact_within_certified_budget() {
        let m = random(120, 3, 11);
        let p = GaussSumProblem::kde(&m, 0.25, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let fast = Naive::fast().run(&p).unwrap().sums;
        for i in 0..120 {
            let rel = (fast[i] - exact[i]).abs() / exact[i];
            assert!(rel <= 1e-12, "i={i}: rel={rel:.2e}");
        }
        // the default stays the bit-exact truth configuration
        assert!(!Naive::new().fast_exp && !Naive::default().fast_exp);
    }

    #[test]
    fn microkernel_path_matches_scalar_reference() {
        let m = random(80, 4, 6);
        let p = GaussSumProblem::kde(&m, 0.25, 0.01);
        let got = Naive { block: 0, ..Naive::default() }.run(&p).unwrap().sums;
        let kernel = GaussianKernel::new(0.25);
        let w = vec![1.0; 80];
        let mut want = vec![0.0; 80];
        crate::compute::reference::scalar_gauss_sums(&m, &m, &w, &kernel, &mut want);
        assert_eq!(got, want, "unblocked naive must equal the scalar loop bit-for-bit");
    }

    #[test]
    fn weights_scale_linearly() {
        let m = random(30, 2, 3);
        let w2 = vec![2.0; 30];
        let p1 = GaussSumProblem::kde(&m, 0.3, 0.01);
        let p2 = GaussSumProblem::new(&m, &m, Some(&w2), 0.3, 0.01);
        let a = Naive::new().run(&p1).unwrap().sums;
        let b = Naive::new().run(&p2).unwrap().sums;
        for i in 0..30 {
            assert!((b[i] - 2.0 * a[i]).abs() < 1e-12 * a[i]);
        }
    }

    #[test]
    fn bichromatic_shapes() {
        let q = random(5, 2, 4);
        let r = random(20, 2, 5);
        let p = GaussSumProblem::new(&q, &r, None, 0.5, 0.01);
        let out = Naive::new().run(&p).unwrap();
        assert_eq!(out.sums.len(), 5);
        assert_eq!(out.stats.base_point_pairs, 100);
    }
}
