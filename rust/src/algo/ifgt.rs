//! **IFGT** — the Improved Fast Gauss Transform (Yang et al. 2003):
//! farthest-point (k-center) clustering instead of a grid, and the
//! rearranged O(Dᵖ) factorization
//!
//!   K(y,x) = e^(−‖Δy‖²/2h²)·e^(−‖Δx‖²/2h²)·Σ_α (2^|α|/α!)·u^α·v^α,
//!   u = Δy/(√2h), v = Δx/(√2h),
//!
//! truncated by total degree. Flat (no translation operators, no
//! hierarchy) and — as the paper stresses — shipped with an *incorrect*
//! error bound, so it cannot guarantee ε; the harness reproduces the
//! paper's protocol (recommended parameters, double K until verified
//! tolerance or give up → the tables' `∞` entries).

use crate::compute;
use crate::geometry::{dist, Matrix};
use crate::kernel::GaussianKernel;
use crate::multiindex::{Layout, MultiIndexSet};

use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

/// IFGT with explicit parameters (the paper's recommended defaults via
/// [`Ifgt::recommended`]).
#[derive(Copy, Clone, Debug)]
pub struct Ifgt {
    /// Number of clusters K.
    pub clusters: usize,
    /// Truncation order p (series keeps |α| < p).
    pub order: usize,
    /// Query cutoff multiple ρ: clusters farther than ρ·h + r_cluster
    /// from a query are dropped.
    pub rho: f64,
    /// Deterministic seed for the farthest-point start.
    pub seed: u64,
}

impl Ifgt {
    /// The paper's recommendation: p = 8 for D = 2, p = 6 for D = 3
    /// (p = 4 above), ρ_x = 2.5, K = √N.
    pub fn recommended(dim: usize, n: usize) -> Self {
        let order = match dim {
            1 | 2 => 8,
            3 => 6,
            _ => 4,
        };
        Ifgt { clusters: (n as f64).sqrt().ceil() as usize, order, rho: 2.5, seed: 0xD1CE }
    }
}

/// Farthest-point (Gonzalez) k-center clustering: returns (assignment,
/// center indices). The O(k·N) distance sweep runs on the shared tiled
/// drivers: the point set is transposed into SoA lanes *and* its
/// squared norms cached once, then each center streams one GEMM-shaped
/// pass (`‖c‖² + ‖x‖² − 2·c·x`, one multiply-add chain per dimension)
/// over the lanes. The norms-trick cancellation (≤ O(ε_mach·‖x‖²)
/// absolute) is harmless here: any clustering is a *valid* clustering —
/// radii and the downstream expansion error are computed from it, and
/// IFGT answers are ε-verified regardless.
pub fn k_center(points: &Matrix, k: usize, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let n = points.rows();
    let k = k.min(n).max(1);
    let mut centers = Vec::with_capacity(k);
    let mut assign = vec![0usize; n];
    let mut best_d = vec![f64::INFINITY; n];
    let norms = compute::tile::sq_norms(points);
    let mut scratch = compute::Scratch::with_block(points.cols(), n);
    scratch.load(points, 0, n);
    scratch.load_ref_norms(&norms, 0, n);
    let first = (seed as usize) % n;
    centers.push(first);
    for c in 0.. {
        let ci = centers[c];
        let sq = scratch.sqdist_into_via_norms(points.row(ci), norms[ci]);
        for (i, &d) in sq.iter().enumerate() {
            if d < best_d[i] {
                best_d[i] = d;
                assign[i] = c;
            }
        }
        if centers.len() == k {
            break;
        }
        // next center = farthest point from all current centers
        // (total_cmp: distances are never NaN, and an empty point set
        // simply ends the seeding loop)
        let Some(far) = (0..n).max_by(|&a, &b| best_d[a].total_cmp(&best_d[b])) else { break };
        if best_d[far] == 0.0 {
            break; // fewer distinct points than k
        }
        centers.push(far);
    }
    (assign, centers)
}

/// H-independent clustering state for IFGT on one reference set:
/// farthest-point assignment, cluster centers and per-cluster radii.
/// Depends only on `(points, clusters, seed)` — the session layer
/// caches one plan per `(K, seed)` and reuses it across bandwidths and
/// K-doubling tuning rounds; [`Ifgt::run`] builds a throwaway plan.
#[derive(Clone, Debug)]
pub struct IfgtPlan {
    pub assign: Vec<usize>,
    pub centers: Vec<Vec<f64>>,
    pub radius: Vec<f64>,
}

impl IfgtPlan {
    pub fn build(refs: &Matrix, clusters: usize, seed: u64) -> Self {
        let (assign, center_idx) = k_center(refs, clusters, seed);
        let centers: Vec<Vec<f64>> =
            center_idx.iter().map(|&i| refs.row(i).to_vec()).collect();
        let mut radius = vec![0.0f64; centers.len()];
        for i in 0..refs.rows() {
            let c = assign[i];
            radius[c] = radius[c].max(dist(refs.row(i), &centers[c]));
        }
        IfgtPlan { assign, centers, radius }
    }
}

/// Expansion-workspace memory guard (2 GB testbed, as for FGT).
const MEM_CAP_SLOTS: usize = (2usize << 30) / 8;

impl Ifgt {
    /// Build the h-independent clustering plan for this parameter set.
    pub fn plan(&self, refs: &Matrix) -> IfgtPlan {
        IfgtPlan::build(refs, self.clusters, self.seed)
    }

    /// The 2 GB expansion-workspace guard (the paper's `X`), cheap
    /// enough to run *before* the O(K·N) clustering pass so hopeless K
    /// fails fast on every path (one-shot run and tuning loop alike).
    pub fn check_memory(&self, dim: usize) -> Result<(), AlgoError> {
        let terms = MultiIndexSet::new(Layout::Graded, dim, self.order).len();
        if terms * self.clusters > MEM_CAP_SLOTS {
            return Err(AlgoError::RamExhausted(format!(
                "{} clusters × {terms} coeffs",
                self.clusters
            )));
        }
        Ok(())
    }

    /// [`GaussSum::run`] with the clustering factored out: callers that
    /// evaluate many bandwidths on one dataset (the session layer) pass
    /// a cached [`IfgtPlan`] instead of re-clustering every call.
    pub fn run_with_plan(
        &self,
        problem: &GaussSumProblem<'_>,
        plan: &IfgtPlan,
    ) -> Result<GaussSumResult, AlgoError> {
        let d = problem.dim();
        let h = problem.h;
        let kernel = GaussianKernel::new(h);
        let refs = problem.references;
        let queries = problem.queries;
        let weights = problem.weight_vec();
        let scale = kernel.series_scale();

        let set = MultiIndexSet::new(Layout::Graded, d, self.order);
        if set.len() * self.clusters > MEM_CAP_SLOTS {
            return Err(AlgoError::RamExhausted(format!(
                "{} clusters × {} coeffs",
                self.clusters,
                set.len()
            )));
        }

        let assign = &plan.assign;
        let centers = &plan.centers;
        let radius = &plan.radius;
        let kk = centers.len();
        debug_assert_eq!(assign.len(), refs.rows(), "plan built for another point set");

        // ---- cluster coefficients C_α = 2^|α|/α! Σ w e^(−‖v‖²) v^α ----
        let mut coeffs = vec![0.0; kk * set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut v = vec![0.0; d];
        for i in 0..refs.rows() {
            let c = assign[i];
            let v2 = compute::scaled_offset(refs.row(i), &centers[c], scale, &mut v);
            let base = weights[i] * (-v2).exp();
            set.eval_monomials(&v, &mut mono);
            let cc = &mut coeffs[c * set.len()..(c + 1) * set.len()];
            for (t, _alpha) in set.iter() {
                let two_pow = (1u64 << set.degree(t).min(62)) as f64;
                cc[t] += base * two_pow * set.inv_factorial(t) * mono[t];
            }
        }

        // ---- evaluation with the ρ cutoff ----
        let cutoff = self.rho * h;
        let mut sums = vec![0.0; queries.rows()];
        let mut stats = RunStats::default();
        let mut u = vec![0.0; d];
        for (qi, sum) in sums.iter_mut().enumerate() {
            let qrow = queries.row(qi);
            for c in 0..kk {
                let dc = dist(qrow, &centers[c]);
                if dc > cutoff + radius[c] {
                    continue; // dropped — the (unaccounted) source of IFGT's error
                }
                stats.dh_prunes += 1;
                let u2 = compute::scaled_offset(qrow, &centers[c], scale, &mut u);
                set.eval_monomials(&u, &mut mono);
                let cc = &coeffs[c * set.len()..(c + 1) * set.len()];
                let mut acc = 0.0;
                for t in 0..set.len() {
                    acc += cc[t] * mono[t];
                }
                *sum += (-u2).exp() * acc;
            }
        }
        Ok(GaussSumResult { sums, stats })
    }
}

impl GaussSum for Ifgt {
    fn name(&self) -> &'static str {
        "IFGT"
    }

    fn guarantees_tolerance(&self) -> bool {
        false // the original bound is incorrect; needs external verification
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        self.check_memory(problem.dim())?;
        self.run_with_plan(problem, &self.plan(problem.references))
    }
}

/// The paper's IFGT protocol: start at the recommended parameters,
/// double K (and stretch ρ) until the *verified* relative error meets ε,
/// or give up — producing the tables' `∞`. Requires the exact sums
/// (which the paper also computed exhaustively for verification).
///
/// K is capped at N/2: past that every point is (nearly) its own
/// cluster, the "expansion" is the exhaustive sum in disguise, and the
/// comparison would be meaningless — the paper's tuning never reaches
/// that regime either.
///
/// `budget_secs` bounds the total tuning wall-clock — the analogue of
/// the paper's "we resorted to additional trial and error by hand"
/// cutoff: once tuning has burned a multiple of the exhaustive time,
/// the cell is hopeless (∞) by any practical standard.
pub fn ifgt_tuning_loop(
    problem: &GaussSumProblem<'_>,
    exact: &[f64],
    max_rounds: usize,
    budget_secs: f64,
) -> Result<(GaussSumResult, Ifgt), AlgoError> {
    ifgt_tuning_loop_with_plans(problem, exact, max_rounds, budget_secs, |p| {
        std::sync::Arc::new(p.plan(problem.references))
    })
}

/// [`ifgt_tuning_loop`] with the clustering supplied by the caller —
/// the session layer passes its per-`(K, seed)` plan cache here so
/// repeated tuning on one dataset re-clusters nothing.
pub fn ifgt_tuning_loop_with_plans<F>(
    problem: &GaussSumProblem<'_>,
    exact: &[f64],
    max_rounds: usize,
    budget_secs: f64,
    mut plan_for: F,
) -> Result<(GaussSumResult, Ifgt), AlgoError>
where
    F: FnMut(&Ifgt) -> std::sync::Arc<IfgtPlan>,
{
    let started = std::time::Instant::now();
    let k_cap = (problem.num_references() / 2).max(1);
    let mut params = Ifgt::recommended(problem.dim(), problem.num_references());
    params.clusters = params.clusters.min(k_cap);
    for round in 0..max_rounds {
        if round > 0 && started.elapsed().as_secs_f64() > budget_secs {
            return Err(AlgoError::ToleranceUnreachable(format!(
                "IFGT tuning exceeded {budget_secs:.1}s budget at round {round}"
            )));
        }
        // fail fast (and skip polluting any plan cache) before the
        // O(K·N) clustering when this K can't fit in memory anyway
        params.check_memory(problem.dim())?;
        let plan = plan_for(&params);
        let out = params.run_with_plan(problem, &plan)?;
        let rel = super::max_relative_error(&out.sums, exact);
        if rel <= problem.epsilon {
            return Ok((out, params));
        }
        if params.clusters >= k_cap && params.rho > 10.0 && params.order >= 12 {
            break;
        }
        params.clusters = (params.clusters * 2).min(k_cap);
        params.rho *= 1.5;
        params.order = (params.order + 2).min(12);
    }
    Err(AlgoError::ToleranceUnreachable(format!(
        "IFGT failed after {max_rounds} doubling rounds (K={}, p={}, ρ={:.1})",
        params.clusters, params.order, params.rho
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::sqdist;
    use crate::util::Pcg32;

    fn uniform(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn k_center_covers_all_points() {
        let pts = uniform(200, 3, 111);
        let (assign, centers) = k_center(&pts, 10, 7);
        assert_eq!(centers.len(), 10);
        assert_eq!(assign.len(), 200);
        // every point assigned to its nearest center
        for i in 0..200 {
            let own = sqdist(pts.row(i), pts.row(centers[assign[i]]));
            for &c in &centers {
                assert!(own <= sqdist(pts.row(i), pts.row(c)) + 1e-12);
            }
        }
    }

    #[test]
    fn k_center_handles_duplicates() {
        let pts = Matrix::from_rows(&vec![vec![0.5, 0.5]; 20]);
        let (_, centers) = k_center(&pts, 5, 3);
        assert_eq!(centers.len(), 1); // only one distinct point
    }

    #[test]
    fn accurate_at_large_bandwidth_with_generous_params() {
        // large h, high order, all clusters in range → should be accurate
        let data = uniform(200, 2, 112);
        let p = GaussSumProblem::kde(&data, 1.0, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let ifgt = Ifgt { clusters: 20, order: 12, rho: 50.0, seed: 1 };
        let out = ifgt.run(&p).unwrap();
        assert!(
            max_relative_error(&out.sums, &exact) < 1e-3,
            "rel={}",
            max_relative_error(&out.sums, &exact)
        );
    }

    #[test]
    fn small_bandwidth_defeats_recommended_params() {
        // the paper's ∞ regime: tiny h — truncation and cutoff error
        // blow past ε at the recommended settings
        let data = uniform(300, 2, 113);
        let p = GaussSumProblem::kde(&data, 1e-3, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let out = Ifgt::recommended(2, 300).run(&p).unwrap();
        let rel = max_relative_error(&out.sums, &exact);
        assert!(rel > 0.01, "expected failure, rel={rel}");
    }

    #[test]
    fn tuning_loop_succeeds_large_h_fails_small_h() {
        let data = uniform(200, 2, 114);
        // large bandwidth: loop should find workable parameters
        let p_big = GaussSumProblem::kde(&data, 2.0, 0.01);
        let exact_big = Naive::new().run(&p_big).unwrap().sums;
        assert!(ifgt_tuning_loop(&p_big, &exact_big, 8, 60.0).is_ok());
        // tiny bandwidth: give up with ∞
        let p_small = GaussSumProblem::kde(&data, 1e-4, 0.01);
        let exact_small = Naive::new().run(&p_small).unwrap().sums;
        match ifgt_tuning_loop(&p_small, &exact_small, 4, 60.0) {
            Err(AlgoError::ToleranceUnreachable(_)) => {}
            other => panic!("expected ∞, got {other:?}"),
        }
    }
}
