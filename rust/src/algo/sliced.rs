//! `Sliced` — the eighth engine: sliced Fourier fast summation for
//! high dimensions (Hertrich, arXiv 2401.08260, adapted to the repo's
//! kernel convention and determinism contracts).
//!
//! Series-expansion engines die above D ≈ 5 (the paper's own caveat);
//! `Sliced` instead averages P one-dimensional problems: draw seeded
//! random unit directions ξ_p, project references and queries onto
//! each, and evaluate the **sliced kernel** (a degree-m polynomial ×
//! Gaussian, see [`crate::fourier`]) with a truncated-Fourier fast sum
//! costing O((N+M)·K) per slice — near-linear and dimension-free.
//! The per-slice Fourier error carries a deterministic certificate
//! ([`crate::fourier::SlicePlan::bound`]); the Monte-Carlo slicing
//! error is verified a posteriori by the P-doubling loop in
//! [`crate::api::tuning::sliced_doubling`], mirroring the FGT/IFGT
//! protocols.
//!
//! Determinism: slice p always draws from `Pcg32::new_stream(seed, p)`
//! — the direction set depends only on (seed, p), never on thread
//! count or scheduling — and slices are folded block-by-block in
//! ascending slice order, so answers are bit-identical across pool
//! widths and repeated evaluates.

use crate::compute::microkernel::transpose_rows;
use crate::compute::simd::{self, Lanes};
use crate::fourier::{fast_sum, plan_slice, SliceProfile};
use crate::runtime::pool::WorkStealPool;
use crate::util::rng::Pcg32;

use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

/// Slices per scheduling block. Blocks are aligned to absolute slice
/// indices, so the accumulation order (and hence every bit of the
/// answer) is invariant to how many slices a call adds at once.
pub const SLICE_BLOCK: usize = 8;

/// Initial slice count of the P-doubling verification loop.
pub const P_INIT: usize = 32;

/// Default seed for the projection streams ("SLICED" in hex-speak).
pub const DEFAULT_SEED: u64 = 0x51_1CED;

/// Incremental slice accumulator: owns the SoA projections of one
/// problem and a running sum over slices, so the P-doubling loop pays
/// only for the *new* slices of each round.
pub struct SlicedState {
    profile: SliceProfile,
    dim: usize,
    n_refs: usize,
    n_queries: usize,
    h: f64,
    /// dim-major SoA of the references (stride = n_refs).
    ref_soa: Vec<f64>,
    /// dim-major SoA of the queries; `None` when monochromatic (the
    /// reference lanes double as query lanes).
    query_soa: Option<Vec<f64>>,
    weights: Vec<f64>,
    seed: u64,
    /// Certified pointwise target for each slice plan.
    target_bound: f64,
    lanes: &'static Lanes,
    /// Σ over completed slices of the per-query slice sums.
    accum: Vec<f64>,
    slices_done: usize,
    /// Worst certified per-slice pointwise bound seen so far.
    max_bound: f64,
}

impl SlicedState {
    /// Set up the projection lanes for `problem`. `target_bound` is
    /// the pointwise Fourier certificate each slice plan must meet
    /// (the caller charges `W · target_bound` out of its ε budget).
    pub fn new(problem: &GaussSumProblem<'_>, target_bound: f64, seed: u64) -> Self {
        let dim = problem.dim();
        let n_refs = problem.num_references();
        let n_queries = problem.num_queries();
        let mut ref_soa = vec![0.0; dim * n_refs];
        transpose_rows(problem.references, 0, n_refs, n_refs, &mut ref_soa);
        let query_soa = if problem.monochromatic {
            None
        } else {
            let mut soa = vec![0.0; dim * n_queries];
            transpose_rows(problem.queries, 0, n_queries, n_queries, &mut soa);
            Some(soa)
        };
        SlicedState {
            profile: SliceProfile::for_dim(dim),
            dim,
            n_refs,
            n_queries,
            h: problem.h,
            ref_soa,
            query_soa,
            weights: problem.weight_vec(),
            seed,
            target_bound,
            lanes: simd::active(),
            accum: vec![0.0; n_queries],
            slices_done: 0,
            max_bound: 0.0,
        }
    }

    /// Slices accumulated so far.
    pub fn slices_done(&self) -> usize {
        self.slices_done
    }

    /// Worst certified per-slice pointwise Fourier bound over all
    /// completed slices (≤ the construction target).
    pub fn certified_bound(&self) -> f64 {
        self.max_bound
    }

    /// SIMD backend the projections dispatch to.
    pub fn backend(&self) -> &'static str {
        self.lanes.backend.name()
    }

    /// Extend the accumulator up to `total` slices. Blocks run on the
    /// pool when one is given (sequentially otherwise) and are folded
    /// in ascending slice order either way, so the result is
    /// bit-identical across pool widths — including width "none".
    pub fn add_slices(
        &mut self,
        total: usize,
        pool: Option<&WorkStealPool>,
    ) -> Result<(), AlgoError> {
        let from = self.slices_done;
        if total <= from {
            return Ok(());
        }
        let blocks: Vec<(usize, usize)> = (from..total)
            .step_by(SLICE_BLOCK)
            .map(|s0| (s0, (s0 + SLICE_BLOCK).min(total)))
            .collect();
        let results: Vec<Result<(Vec<f64>, f64), AlgoError>> = match pool {
            Some(pool) => pool.run_indexed(blocks.len(), |bi| {
                let (s0, s1) = blocks[bi];
                self.run_block(s0, s1)
            }),
            None => blocks.iter().map(|&(s0, s1)| self.run_block(s0, s1)).collect(),
        };
        // Merge only after every block succeeded, so a failed round
        // leaves the accumulator untouched and reusable.
        let mut parts = Vec::with_capacity(results.len());
        for r in results {
            parts.push(r?);
        }
        for (partial, worst) in parts {
            for (acc, p) in self.accum.iter_mut().zip(&partial) {
                *acc += p;
            }
            self.max_bound = self.max_bound.max(worst);
        }
        self.slices_done = total;
        Ok(())
    }

    /// Current estimates: the slice average, in query row order.
    pub fn estimates(&self) -> Vec<f64> {
        let inv = 1.0 / self.slices_done.max(1) as f64;
        self.accum.iter().map(|a| a * inv).collect()
    }

    /// Evaluate slices `[s0, s1)` sequentially into a fresh partial
    /// sum; returns the partial and the worst certified bound.
    fn run_block(&self, s0: usize, s1: usize) -> Result<(Vec<f64>, f64), AlgoError> {
        let mut partial = vec![0.0; self.n_queries];
        let mut worst = 0.0f64;
        let mut t_ref = vec![0.0; self.n_refs];
        let mut t_query = vec![0.0; if self.query_soa.is_some() { self.n_queries } else { 0 }];
        let mut a = vec![0.0; self.n_refs];
        let mut b = vec![0.0; if self.query_soa.is_some() { self.n_queries } else { 0 }];
        let mut out = vec![0.0; self.n_queries];
        for s in s0..s1 {
            let dir = self.direction(s);
            (self.lanes.dot_soa)(&dir, &self.ref_soa, self.n_refs, self.n_refs, &mut t_ref);
            if let Some(qsoa) = &self.query_soa {
                (self.lanes.dot_soa)(&dir, qsoa, self.n_queries, self.n_queries, &mut t_query);
            }
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &t in t_ref.iter().chain(t_query.iter()) {
                lo = lo.min(t);
                hi = hi.max(t);
            }
            let center = 0.5 * (lo + hi);
            let half_range = 0.5 * (hi - lo);
            let plan = plan_slice(&self.profile, self.h, half_range, self.target_bound)
                .map_err(|e| AlgoError::ToleranceUnreachable(format!("slice {s}: {e}")))?;
            for (dst, &t) in a.iter_mut().zip(&t_ref) {
                *dst = plan.gamma * (t - center);
            }
            let queries_scaled: &[f64] = if self.query_soa.is_some() {
                for (dst, &t) in b.iter_mut().zip(&t_query) {
                    *dst = plan.gamma * (t - center);
                }
                &b
            } else {
                &a
            };
            fast_sum(&plan, &a, &self.weights, queries_scaled, &mut out);
            for (acc, &v) in partial.iter_mut().zip(&out) {
                *acc += v;
            }
            worst = worst.max(plan.bound);
        }
        Ok((partial, worst))
    }

    /// Unit direction of slice `s`: its own PCG stream, normalized
    /// Gaussian draw in the (odd) sliced dimension, truncated to the
    /// data dimension — the even→odd embedding appends an implicit
    /// zero coordinate to every point, so the extra component only
    /// contributes to the normalization.
    fn direction(&self, s: usize) -> Vec<f64> {
        let ds = self.profile.sliced_dim();
        let mut rng = Pcg32::new_stream(self.seed, s as u64);
        loop {
            let g: Vec<f64> = (0..ds).map(|_| rng.normal()).collect();
            let norm2: f64 = g.iter().map(|v| v * v).sum();
            if norm2 > 1e-24 {
                let inv = 1.0 / norm2.sqrt();
                return g.iter().take(self.dim).map(|v| v * inv).collect();
            }
        }
    }
}

/// One-shot engine front for [`SlicedState`] with a fixed slice
/// count. Like FGT/IFGT it does **not** guarantee the ε tolerance by
/// itself — the session pairs it with the verified P-doubling loop —
/// but the Fourier half of the budget is still certified: the
/// per-query error from the 1-D fast sums is ≤ W · target, with
/// target = ε/4 scaled by W (an absolute ε/4 charge).
#[derive(Clone, Debug)]
pub struct Sliced {
    /// Number of slices P (rounded up to a block multiple).
    pub slices: usize,
    /// Projection seed.
    pub seed: u64,
}

impl Default for Sliced {
    fn default() -> Self {
        Sliced { slices: 4 * P_INIT, seed: DEFAULT_SEED }
    }
}

impl GaussSum for Sliced {
    fn name(&self) -> &'static str {
        "Sliced"
    }

    fn guarantees_tolerance(&self) -> bool {
        false
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        let w = problem.total_weight();
        let target_bound = 0.25 * problem.epsilon / w;
        let mut state = SlicedState::new(problem, target_bound, self.seed);
        let total = self.slices.max(1).div_ceil(SLICE_BLOCK) * SLICE_BLOCK;
        state.add_slices(total, None)?;
        let stats = RunStats { simd_backend: state.backend(), ..RunStats::default() };
        Ok(GaussSumResult { sums: state.estimates(), stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{max_relative_error, naive::Naive};
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn converges_to_naive_truth_in_high_dim() {
        let m = random(150, 12, 3);
        let p = GaussSumProblem::kde(&m, 0.8, 0.05);
        let exact = Naive::new().run(&p).unwrap().sums;
        let approx = Sliced { slices: 2048, ..Sliced::default() }.run(&p).unwrap().sums;
        let rel = max_relative_error(&approx, &exact);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn doubling_reuses_prefix_slices_exactly() {
        let m = random(60, 8, 5);
        let p = GaussSumProblem::kde(&m, 0.5, 0.1);
        let mut grown = SlicedState::new(&p, 1e-6, DEFAULT_SEED);
        grown.add_slices(32, None).unwrap();
        grown.add_slices(64, None).unwrap();
        let mut fresh = SlicedState::new(&p, 1e-6, DEFAULT_SEED);
        fresh.add_slices(64, None).unwrap();
        assert_eq!(grown.estimates(), fresh.estimates(), "block-aligned growth must be exact");
        assert_eq!(grown.slices_done(), 64);
        assert!(grown.certified_bound() <= 1e-6);
    }

    #[test]
    fn seeds_change_the_estimate_directions() {
        let m = random(40, 10, 9);
        let p = GaussSumProblem::kde(&m, 0.6, 0.1);
        let a = Sliced { slices: 16, seed: 1 }.run(&p).unwrap().sums;
        let b = Sliced { slices: 16, seed: 2 }.run(&p).unwrap().sums;
        assert_ne!(a, b, "different seeds must draw different slices");
        let c = Sliced { slices: 16, seed: 1 }.run(&p).unwrap().sums;
        assert_eq!(a, c, "same seed must be bit-identical");
    }

    #[test]
    fn bichromatic_and_weighted_paths() {
        let q = random(30, 6, 21);
        let r = random(80, 6, 22);
        let w: Vec<f64> = (0..80).map(|i| 0.5 + (i % 7) as f64 * 0.3).collect();
        let p = GaussSumProblem::new(&q, &r, Some(&w), 0.9, 0.05);
        let exact = Naive::new().run(&p).unwrap().sums;
        let approx = Sliced { slices: 4096, ..Sliced::default() }.run(&p).unwrap().sums;
        let rel = max_relative_error(&approx, &exact);
        assert!(rel < 0.08, "rel={rel}");
    }

    #[test]
    fn hopeless_bandwidth_reports_tolerance_unreachable() {
        // h ≪ data spread forces a tiny working bandwidth, where the
        // truncation order needed blows past K_CAP — the paper's ∞.
        let m = random(20, 14, 2);
        let p = GaussSumProblem::kde(&m, 0.001, 0.01);
        let err = Sliced::default().run(&p).unwrap_err();
        assert!(matches!(err, AlgoError::ToleranceUnreachable(_)), "{err:?}");
    }
}
