//! **DFD** — dual-tree finite difference (Gray & Moore 2003b): the
//! classic baseline. A thin instantiation of the generic engine:
//! `run_dualtree_variant::<NoExpansion, Theorem2>` — finite-difference
//! approximation only, classic per-node Theorem-2 rule *without* the
//! token ledger.

use super::dualtree::{run_dualtree_variant, NoExpansion, Theorem2};
use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult};

#[derive(Copy, Clone, Debug)]
pub struct Dfd {
    pub leaf_size: usize,
}

impl Default for Dfd {
    fn default() -> Self {
        Dfd { leaf_size: 32 }
    }
}

impl Dfd {
    pub fn new() -> Self {
        Self::default()
    }
}

impl GaussSum for Dfd {
    fn name(&self) -> &'static str {
        "DFD"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        run_dualtree_variant::<NoExpansion, Theorem2>(problem, self.leaf_size, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    #[test]
    fn guarantee_holds_and_no_series_prunes() {
        let mut rng = Pcg32::new(91);
        let data = Matrix::from_rows(
            &(0..300).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        let p = GaussSumProblem::kde(&data, 0.1, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let out = Dfd::new().run(&p).unwrap();
        assert!(max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9));
        assert_eq!(out.stats.dh_prunes + out.stats.dl_prunes + out.stats.h2l_prunes, 0);
        assert_eq!(out.stats.tokens_banked, 0.0);
        assert!(Dfd::new().guarantees_tolerance());
    }
}
