//! The Gaussian-summation algorithms the paper evaluates:
//!
//! | name | module | description |
//! |---|---|---|
//! | Naive | [`naive`] | exhaustive O(NM) summation |
//! | FGT   | [`fgt`]   | flat-grid Fast Gauss Transform (Greengard & Strain 1991) |
//! | IFGT  | [`ifgt`]  | Improved FGT: k-center clusters + O(Dᵖ) Taylor (Yang et al. 2003) |
//! | DFD   | [`dfd`]   | dual-tree finite difference (Gray & Moore 2003b) |
//! | DFDO  | [`dfdo`]  | DFD + the paper's token error control |
//! | DFTO  | [`dfto`]  | dual-tree O(pᴰ) expansion + token control (Lee et al. 2006 bounds) |
//! | DITO  | [`dito`]  | **the paper's contribution**: dual-tree O(Dᵖ) expansion + token control |
//! | Sliced | [`sliced`] | post-paper: random 1-D projections + certified Fourier fast sums for D ≳ 10 |
//!
//! All implement [`GaussSum`] over a shared [`GaussSumProblem`]. The four
//! dual-tree variants are monomorphized instantiations of one generic
//! engine ([`dualtree`]), generic over the expansion family
//! ([`dualtree::Expansion`]) and the prune rule
//! ([`crate::errorcontrol::PruneRule`]) — the paper's "one algorithm
//! with switches", with the switches resolved at compile time. Every
//! exhaustive inner loop (here and in FGT/IFGT/the runtime fallback)
//! runs on the shared [`crate::compute`] SoA microkernel.

pub mod bestmethod;
pub mod dualtree;
pub mod dfd;
pub mod dfdo;
pub mod dfto;
pub mod dito;
pub mod fgt;
pub mod ifgt;
pub mod naive;
pub mod sliced;

pub use dualtree::SweepEngine;

use crate::geometry::Matrix;

/// Why an algorithm could not produce a result — mirrors the paper's
/// table entries: `X` (RAM exhaustion) and `∞` (no parameter setting
/// meets the tolerance).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AlgoError {
    /// The method would exhaust memory (paper's `X`).
    RamExhausted(String),
    /// No parameter setting can satisfy the error tolerance (paper's `∞`).
    ToleranceUnreachable(String),
    /// Infrastructure failure, not an algorithmic verdict — e.g. the
    /// shared exhaustive-truth computation panicked and the session
    /// reports a clean error to every waiter instead of poisoning the
    /// cell lock. Callers must not record this as an X/∞ table entry.
    Internal(String),
}

impl std::fmt::Display for AlgoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AlgoError::RamExhausted(s) => write!(f, "memory exhausted (paper 'X'): {s}"),
            AlgoError::ToleranceUnreachable(s) => {
                write!(f, "tolerance unreachable (paper '∞'): {s}")
            }
            AlgoError::Internal(s) => write!(f, "internal failure: {s}"),
        }
    }
}

impl std::error::Error for AlgoError {}

/// One Gaussian-summation instance: compute
/// G(x_q) = Σ_r w_r·exp(−‖x_q−x_r‖²/2h²) for every query row, with the
/// guarantee |G̃−G| ≤ ε·G for the guaranteed algorithms.
#[derive(Clone, Debug)]
pub struct GaussSumProblem<'a> {
    pub queries: &'a Matrix,
    pub references: &'a Matrix,
    /// Per-reference weights; `None` = all ones.
    pub weights: Option<&'a [f64]>,
    /// Bandwidth h of the Gaussian kernel.
    pub h: f64,
    /// Relative error tolerance ε.
    pub epsilon: f64,
    /// True when queries and references are the *same* point set (the
    /// paper's KDE setting) — lets dual-tree algorithms build one tree.
    pub monochromatic: bool,
}

impl<'a> GaussSumProblem<'a> {
    /// Bichromatic problem with explicit query/reference sets.
    pub fn new(
        queries: &'a Matrix,
        references: &'a Matrix,
        weights: Option<&'a [f64]>,
        h: f64,
        epsilon: f64,
    ) -> Self {
        assert_eq!(queries.cols(), references.cols(), "dimension mismatch");
        if let Some(w) = weights {
            assert_eq!(w.len(), references.rows());
            assert!(w.iter().all(|&x| x > 0.0), "weights must be positive");
        }
        assert!(h > 0.0 && epsilon > 0.0);
        GaussSumProblem { queries, references, weights, h, epsilon, monochromatic: false }
    }

    /// The paper's KDE setting: queries = references, unit weights.
    pub fn kde(data: &'a Matrix, h: f64, epsilon: f64) -> Self {
        let mut p = Self::new(data, data, None, h, epsilon);
        p.monochromatic = true;
        p
    }

    pub fn dim(&self) -> usize {
        self.references.cols()
    }

    pub fn num_queries(&self) -> usize {
        self.queries.rows()
    }

    pub fn num_references(&self) -> usize {
        self.references.rows()
    }

    /// Materialize the weight vector (ones when unweighted).
    pub fn weight_vec(&self) -> Vec<f64> {
        match self.weights {
            Some(w) => w.to_vec(),
            None => vec![1.0; self.references.rows()],
        }
    }

    /// W = Σ w_r.
    pub fn total_weight(&self) -> f64 {
        match self.weights {
            Some(w) => w.iter().sum(),
            None => self.references.rows() as f64,
        }
    }
}

/// Instrumentation counters for one run — the prune-type histogram used
/// by EXPERIMENTS.md and the ablation benches.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    /// Node-pair recursions visited.
    pub node_pairs: u64,
    /// Leaf-leaf exhaustive base cases (pairs of points computed).
    pub base_point_pairs: u64,
    /// Finite-difference prunes.
    pub fd_prunes: u64,
    /// Direct Hermite evaluation prunes (EVALM).
    pub dh_prunes: u64,
    /// Direct local accumulation prunes (DIRECTL).
    pub dl_prunes: u64,
    /// Hermite-to-local translation prunes.
    pub h2l_prunes: u64,
    /// Tokens banked / spent by the error-control ledger.
    pub tokens_banked: f64,
    pub tokens_spent: f64,
    /// Leaf-pair base cases drained through the certified fast tiled
    /// kernel (norms trick + `exp_block`).
    pub fast_base_cases: u64,
    /// Leaf-pair base cases drained through the bit-exact scalar-order
    /// path (fast-exp off, or the ε-split refused the certified bound
    /// at this bandwidth).
    pub exact_base_cases: u64,
    /// Leaf-pair base cases drained through the mixed-precision f32
    /// tile (admitted by `errorcontrol::split_epsilon_prec`; 0 whenever
    /// an f32 request demoted itself to the f64 or bit-exact path).
    pub f32_base_cases: u64,
    /// SIMD dispatch table the run's fast tiles executed on ("avx2",
    /// "neon" or "scalar"; empty for paths that never consult the
    /// dispatcher, e.g. a pure bit-exact run).
    pub simd_backend: &'static str,
    /// Tree construction + moment precomputation seconds.
    pub build_secs: f64,
    /// kd-tree constructions performed by this run: 1–2 for a one-shot
    /// [`dualtree::run_dualtree`], 0 for an evaluate on a prepared
    /// [`SweepEngine`] (the engine amortizes its builds over the sweep).
    pub tree_builds: u64,
    /// Moment-memo hits for this evaluate (0 or 1; [`SweepEngine`]
    /// variants with a series family only).
    pub moment_cache_hits: u64,
    /// Moment-memo misses for this evaluate (0 or 1).
    pub moment_cache_misses: u64,
    /// Session-level lazy-state hits for this evaluate: exhaustive-truth
    /// memo, FGT grid frame and IFGT clustering plans reused from a
    /// prepared [`crate::api::Session`].
    pub session_cache_hits: u64,
    /// Session-level lazy-state misses (entries built by this evaluate).
    pub session_cache_misses: u64,
    /// Total wall-clock seconds (filled by the harness/run wrapper).
    pub total_secs: f64,
    /// Gaussian component requests a sum-of-Gaussians (non-Gaussian
    /// [`crate::kernel::Kernel`]) evaluate fanned out into; 0 on the
    /// native Gaussian path.
    pub sog_components: u64,
    /// Per-method routing histogram of those components, indexed by the
    /// paper's seven-row order ([`crate::api::Method::paper_index`]:
    /// Naive, FGT, IFGT, DFD, DFDO, DFTO, DITO).
    pub sog_routed: [u64; 7],
}

impl RunStats {
    /// Total prunes of any kind.
    pub fn total_prunes(&self) -> u64 {
        self.fd_prunes + self.dh_prunes + self.dl_prunes + self.h2l_prunes
    }

    /// Accumulate another run's counters (used when merging the
    /// per-worker stats of a parallel traversal).
    pub fn merge(&mut self, other: &RunStats) {
        self.node_pairs += other.node_pairs;
        self.base_point_pairs += other.base_point_pairs;
        self.fd_prunes += other.fd_prunes;
        self.dh_prunes += other.dh_prunes;
        self.dl_prunes += other.dl_prunes;
        self.h2l_prunes += other.h2l_prunes;
        self.tokens_banked += other.tokens_banked;
        self.tokens_spent += other.tokens_spent;
        self.fast_base_cases += other.fast_base_cases;
        self.exact_base_cases += other.exact_base_cases;
        self.f32_base_cases += other.f32_base_cases;
        if self.simd_backend.is_empty() {
            self.simd_backend = other.simd_backend;
        }
        self.build_secs += other.build_secs;
        self.tree_builds += other.tree_builds;
        self.moment_cache_hits += other.moment_cache_hits;
        self.moment_cache_misses += other.moment_cache_misses;
        self.session_cache_hits += other.session_cache_hits;
        self.session_cache_misses += other.session_cache_misses;
        self.total_secs += other.total_secs;
        self.sog_components += other.sog_components;
        for (mine, theirs) in self.sog_routed.iter_mut().zip(other.sog_routed.iter()) {
            *mine += theirs;
        }
    }
}

/// Result of a run: per-query sums in the original query row order.
#[derive(Clone, Debug)]
pub struct GaussSumResult {
    pub sums: Vec<f64>,
    pub stats: RunStats,
}

/// A Gaussian-summation algorithm.
pub trait GaussSum {
    /// Short table name ("DITO", "DFD", …).
    fn name(&self) -> &'static str;

    /// Run on a problem. `Err(AlgoError)` maps to the paper's X/∞ cells.
    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError>;

    /// Whether the algorithm guarantees the ε tolerance by construction
    /// (the dual-tree family) or needs external verification (FGT/IFGT).
    fn guarantees_tolerance(&self) -> bool {
        true
    }
}

/// Maximum relative error of `approx` vs `exact` — the paper's
/// verification criterion max_q |G̃−G|/G.
pub fn max_relative_error(approx: &[f64], exact: &[f64]) -> f64 {
    assert_eq!(approx.len(), exact.len());
    approx
        .iter()
        .zip(exact)
        .map(|(a, e)| if *e > 0.0 { (a - e).abs() / e } else { (a - e).abs() })
        .fold(0.0, f64::max)
}

/// Maximum absolute error scaled by the total reference weight W —
/// the verification criterion for sum-of-Gaussians kernels:
/// max_q |G̃−G| / W ≤ ε (see
/// [`crate::errorcontrol::split_epsilon_kernel`]).
pub fn max_weight_scaled_error(approx: &[f64], exact: &[f64], total_weight: f64) -> f64 {
    assert_eq!(approx.len(), exact.len());
    assert!(total_weight > 0.0);
    approx.iter().zip(exact).map(|(a, e)| (a - e).abs()).fold(0.0, f64::max) / total_weight
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts() -> Matrix {
        Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]])
    }

    #[test]
    fn kde_problem_is_monochromatic() {
        let m = pts();
        let p = GaussSumProblem::kde(&m, 0.5, 0.01);
        assert!(p.monochromatic);
        assert_eq!(p.total_weight(), 3.0);
        assert_eq!(p.weight_vec(), vec![1.0; 3]);
    }

    #[test]
    #[should_panic]
    fn dimension_mismatch_rejected() {
        let a = pts();
        let b = Matrix::from_rows(&[vec![0.0]]);
        GaussSumProblem::new(&a, &b, None, 0.5, 0.01);
    }

    #[test]
    #[should_panic]
    fn nonpositive_weights_rejected() {
        let m = pts();
        let w = vec![1.0, 0.0, 1.0];
        GaussSumProblem::new(&m, &m, Some(&w), 0.5, 0.01);
    }

    #[test]
    fn max_rel_error_basic() {
        assert!((max_relative_error(&[1.1, 2.0], &[1.0, 2.0]) - 0.1).abs() < 1e-12);
        assert_eq!(max_relative_error(&[0.5], &[0.0]), 0.5);
    }

    #[test]
    fn max_weight_scaled_error_basic() {
        assert!((max_weight_scaled_error(&[1.2, 2.0], &[1.0, 2.1], 4.0) - 0.05).abs() < 1e-12);
        assert_eq!(max_weight_scaled_error(&[3.0], &[3.0], 10.0), 0.0);
    }

    #[test]
    fn algo_error_display() {
        let x = AlgoError::RamExhausted("grid 10^20 boxes".into());
        assert!(x.to_string().contains('X'));
        let inf = AlgoError::ToleranceUnreachable("K > N".into());
        assert!(inf.to_string().contains('∞'));
    }
}
