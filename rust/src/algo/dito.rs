//! **DITO** — the paper's headline algorithm: dual-tree recursion with
//! the O(Dᵖ) graded expansion, the Lemma 4–6 error bounds (no node-size
//! restriction), per-pair cheapest-method selection (Fig. 6), and the
//! token-based error control (Section 5), with H2H moment precomputation
//! (Fig. 5) and L2L post-processing (Fig. 8). A thin instantiation of
//! the generic engine: `run_dualtree_variant::<OdpGraded, TokenLedger>`
//! (or `Theorem2` when the token ablation switch is off).

use super::dualtree::{run_dualtree_variant, OdpGraded, Theorem2, TokenLedger};
use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult};

/// Configuration for [`Dito`].
#[derive(Copy, Clone, Debug)]
pub struct DitoConfig {
    pub leaf_size: usize,
    /// Override the paper's PLIMIT-per-dimension schedule.
    pub plimit: Option<usize>,
    /// Disable the token ledger (for ablation only; the paper's DITO
    /// always uses it).
    pub use_tokens: bool,
}

impl Default for DitoConfig {
    fn default() -> Self {
        DitoConfig { leaf_size: 32, plimit: None, use_tokens: true }
    }
}

#[derive(Copy, Clone, Debug, Default)]
pub struct Dito {
    pub config: DitoConfig,
}

impl Dito {
    pub fn new(config: DitoConfig) -> Self {
        Dito { config }
    }
}

impl GaussSum for Dito {
    fn name(&self) -> &'static str {
        "DITO"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        let (leaf, plimit) = (self.config.leaf_size, self.config.plimit);
        if self.config.use_tokens {
            run_dualtree_variant::<OdpGraded, TokenLedger>(problem, leaf, plimit)
        } else {
            run_dualtree_variant::<OdpGraded, Theorem2>(problem, leaf, plimit)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn blobs(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        let centers: Vec<Vec<f64>> =
            (0..4).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
        Matrix::from_rows(
            &(0..n)
                .map(|i| (0..d).map(|j| centers[i % 4][j] + 0.05 * rng.normal()).collect())
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn guarantee_across_bandwidth_sweep_2d() {
        let data = blobs(500, 2, 96);
        // the paper's 10^-3 h* … 10^3 h* style sweep
        for h in [1e-3, 1e-2, 0.1, 0.3, 1.0, 10.0, 100.0] {
            let p = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&p).unwrap().sums;
            let out = Dito::default().run(&p).unwrap();
            assert!(
                max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "h={h}"
            );
        }
    }

    #[test]
    fn guarantee_in_higher_dims() {
        for d in [5, 7, 10] {
            let data = blobs(200, d, 97);
            let p = GaussSumProblem::kde(&data, 0.5, 0.01);
            let exact = Naive::new().run(&p).unwrap().sums;
            let out = Dito::default().run(&p).unwrap();
            assert!(
                max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "d={d}"
            );
        }
    }

    #[test]
    fn large_bandwidth_prefers_series_over_base_cases() {
        let data = blobs(800, 2, 98);
        let p = GaussSumProblem::kde(&data, 5.0, 0.01);
        let out = Dito::default().run(&p).unwrap();
        // at huge h everything is far-field: almost no exhaustive work
        assert!(
            out.stats.base_point_pairs < 800 * 800 / 10,
            "base pairs {}",
            out.stats.base_point_pairs
        );
        assert!(out.stats.total_prunes() > 0);
    }

    #[test]
    fn plimit_override_respected() {
        let data = blobs(300, 2, 99);
        let p = GaussSumProblem::kde(&data, 0.5, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        for plimit in [1, 2, 4] {
            let dito = Dito::new(DitoConfig { plimit: Some(plimit), ..Default::default() });
            let out = dito.run(&p).unwrap();
            assert!(
                max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "plimit={plimit}"
            );
        }
    }
}
