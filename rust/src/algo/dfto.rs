//! **DFTO** — dual-tree fast Gauss transform with the classical O(pᴰ)
//! grid expansion (Lee et al. 2006) and the improved (token) error
//! control. A thin instantiation of the generic engine:
//! `run_dualtree_variant::<OpdGrid, TokenLedger>`. Its geometric-series
//! error bounds require scaled node radii < 1, so series pruning only
//! activates once nodes are small relative to the bandwidth — the
//! node-size restriction the O(Dᵖ) bounds remove.

use super::dualtree::{run_dualtree_variant, OpdGrid, TokenLedger};
use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult};

#[derive(Copy, Clone, Debug)]
pub struct Dfto {
    pub leaf_size: usize,
    /// Override the PLIMIT schedule.
    pub plimit: Option<usize>,
}

impl Default for Dfto {
    fn default() -> Self {
        Dfto { leaf_size: 32, plimit: None }
    }
}

impl Dfto {
    pub fn new() -> Self {
        Self::default()
    }
}

impl GaussSum for Dfto {
    fn name(&self) -> &'static str {
        "DFTO"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        run_dualtree_variant::<OpdGrid, TokenLedger>(problem, self.leaf_size, self.plimit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    #[test]
    fn guarantee_across_bandwidths_2d() {
        let mut rng = Pcg32::new(94);
        let data = Matrix::from_rows(
            &(0..400).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        for h in [0.05, 0.3, 1.0, 10.0] {
            let p = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&p).unwrap().sums;
            let out = Dfto::new().run(&p).unwrap();
            assert!(
                max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "h={h}"
            );
        }
    }

    #[test]
    fn large_bandwidth_triggers_series_prunes() {
        let mut rng = Pcg32::new(95);
        let data = Matrix::from_rows(
            &(0..600).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        );
        // node radii / h < 1 for large h → grid series usable
        let p = GaussSumProblem::kde(&data, 2.0, 0.01);
        let out = Dfto::new().run(&p).unwrap();
        assert!(
            out.stats.dh_prunes + out.stats.dl_prunes + out.stats.h2l_prunes > 0,
            "{:?}",
            out.stats
        );
    }
}
