//! **FGT** — the original flat-grid Fast Gauss Transform (Greengard &
//! Strain 1991). Space is cut into a uniform grid of boxes with side
//! ≤ r·√(2h²) (r = 1/2, keeping every box inside the geometric-series
//! convergence region); each source box carries an O(pᴰ) Hermite
//! expansion about its center; each query sums expansions of boxes
//! within an interaction range chosen so dropped boxes contribute less
//! than half the error budget.
//!
//! FGT guarantees an *absolute* tolerance |G̃−G| ≤ W·τ (the paper's
//! note); the harness wraps it in the "halve τ until relative ε is met"
//! loop the paper describes. Small bandwidths explode the box count —
//! reproduced faithfully as an [`AlgoError::RamExhausted`] (the paper's
//! `X` cells) past a memory cap, matching the 2 GB testbed.

use std::collections::HashMap;

use crate::bounds::{opd::OpdBounds, NodeGeometry};
use crate::compute::simd::SimdMode;
use crate::compute::{microkernel, simd, tile};
use crate::geometry::Matrix;
use crate::hermite::{accumulate_farfield, eval_farfield, HermiteTable};
use crate::kernel::GaussianKernel;
use crate::multiindex::{Layout, MultiIndexSet};

use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult, RunStats};

/// Flat-grid FGT with absolute tolerance `tau` (per unit total weight).
#[derive(Copy, Clone, Debug)]
pub struct Fgt {
    /// Absolute error tolerance: |G̃−G| ≤ W·τ.
    pub tau: f64,
    /// Box scaled radius target r (box side = 2·r·h, giving L∞ radius
    /// r·h per box, i.e. scaled radius r < 1 as the bounds require).
    pub box_radius: f64,
    /// Maximum truncation order to try.
    pub max_order: usize,
    /// Memory cap in f64 slots for (boxes × coefficients) — exceeding it
    /// reproduces the paper's RAM-exhaustion `X` (2 GB testbed).
    pub mem_cap_slots: usize,
    /// Run the sparse-box direct path on the GEMM-shaped fast kernel
    /// (cached box norms + dot products + certified fast exp). Default
    /// on: FGT answers are ε-verified downstream (the τ-halving loop),
    /// and the certified ~1e-13 per-pair error is far inside the W·τ
    /// absolute budget. `false` restores the bit-exact direct path.
    pub fast_exp: bool,
    /// Vector-lane dispatch for the fast direct path (`Auto` = detected
    /// backend, `Off` = scalar table, bit-exact vs. pre-SIMD). The
    /// exact path (`fast_exp = false`) never consults the dispatcher.
    pub simd: SimdMode,
}

impl Default for Fgt {
    fn default() -> Self {
        Fgt {
            tau: 1e-2,
            box_radius: 0.5,
            max_order: 12,
            // 2 GB of f64 — the paper machine's main memory
            mem_cap_slots: (2usize << 30) / 8,
            fast_exp: true,
            simd: SimdMode::Auto,
        }
    }
}

impl Fgt {
    pub fn new(tau: f64) -> Self {
        Fgt { tau, ..Default::default() }
    }
}

/// The h-independent joint bounding box of (queries ∪ references) —
/// the dataset-dependent half of FGT's grid geometry. The session
/// layer computes it lazily once per dataset and reuses it across
/// bandwidths and τ-halving attempts; [`Fgt::run`] derives it on the
/// fly, bit-identically.
#[derive(Clone, Debug)]
pub struct GridFrame {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl GridFrame {
    /// Per-dimension min/max over both point sets (no padding — the
    /// grid's `+1e-12` open-end nudge is applied at run time).
    pub fn joint(queries: &Matrix, refs: &Matrix) -> Self {
        let mut lo = refs.col_min();
        let mut hi = refs.col_max();
        let qlo = queries.col_min();
        let qhi = queries.col_max();
        for j in 0..lo.len() {
            lo[j] = lo[j].min(qlo[j]);
            hi[j] = hi[j].max(qhi[j]);
        }
        GridFrame { lo, hi }
    }
}

impl Fgt {
    /// [`GaussSum::run`] with the bounding-box scan factored out:
    /// callers that evaluate many bandwidths on one dataset (the
    /// session layer) pass a precomputed [`GridFrame`] instead of
    /// rescanning the point sets every attempt.
    pub fn run_with_frame(
        &self,
        problem: &GaussSumProblem<'_>,
        frame: &GridFrame,
    ) -> Result<GaussSumResult, AlgoError> {
        let d = problem.dim();
        let h = problem.h;
        let kernel = GaussianKernel::new(h);
        let refs = problem.references;
        let queries = problem.queries;
        let weights = problem.weight_vec();

        // ---- grid geometry over the joint bounding box ----
        debug_assert_eq!(frame.lo.len(), d, "frame dimension mismatch");
        let lo = frame.lo.clone();
        let mut hi = frame.hi.clone();
        for j in 0..d {
            hi[j] += 1e-12;
        }
        let side = 2.0 * self.box_radius * h;
        let mut boxes_per_dim = vec![0usize; d];
        let mut total_boxes = 1usize;
        for j in 0..d {
            let n = (((hi[j] - lo[j]) / side).ceil() as usize).max(1);
            boxes_per_dim[j] = n;
            total_boxes = total_boxes.checked_mul(n).ok_or_else(|| {
                AlgoError::RamExhausted(format!("grid overflows usize at dim {j}"))
            })?;
            if total_boxes > self.mem_cap_slots {
                return Err(AlgoError::RamExhausted(format!(
                    "{total_boxes}+ boxes of side {side:.3e}"
                )));
            }
        }

        // ---- truncation order from the Hermite tail bound ----
        // per-box scaled L∞ radius is ≤ box_radius (side/2 / h)
        let geo = NodeGeometry {
            dim: d,
            min_sqdist: 0.0,
            r_ref: self.box_radius,
            r_query: 0.0,
            h,
        };
        let mut order = None;
        for p in 1..=self.max_order {
            if OpdBounds::e_dh(&geo, p) <= 0.5 * self.tau {
                order = Some(p);
                break;
            }
        }
        let p = order.ok_or_else(|| {
            AlgoError::ToleranceUnreachable(format!(
                "no order ≤ {} meets τ/2 = {:.1e}",
                self.max_order,
                0.5 * self.tau
            ))
        })?;
        // The pᴰ term count is both the per-box workspace and the
        // per-source/per-query work multiplier. The original FGT's
        // workspace (coefficients + interaction-list scratch per box,
        // 2 GB era) dies well before 2²⁰ terms — this is exactly why the
        // paper reports X for every bandwidth at D ≥ 5.
        let term_count = (p as f64).powi(d as i32);
        if term_count > (1u64 << 20) as f64 {
            return Err(AlgoError::RamExhausted(format!(
                "p^D = {p}^{d} ≈ {term_count:.2e} expansion terms/box"
            )));
        }
        let set = MultiIndexSet::new(Layout::Grid, d, p);
        let coeff_slots = total_boxes
            .checked_mul(set.len())
            .filter(|&s| s <= self.mem_cap_slots)
            .ok_or_else(|| {
                AlgoError::RamExhausted(format!(
                    "{total_boxes} boxes × {} coeffs",
                    set.len()
                ))
            })?;

        // ---- interaction range: drop boxes with K ≤ τ/2 ----
        // distance beyond which a whole box's unit-weight contribution
        // is under τ/2: K(δ) ≤ τ/2 → δ = h·√(2·ln(2/τ))
        let cutoff = h * (2.0 * (2.0 / self.tau).ln()).sqrt();
        let reach = (cutoff / side).ceil() as isize + 1;

        // ---- scatter sources into boxes ----
        let box_of = |x: &[f64]| -> usize {
            let mut idx = 0usize;
            for j in 0..d {
                let mut b = ((x[j] - lo[j]) / side) as usize;
                if b >= boxes_per_dim[j] {
                    b = boxes_per_dim[j] - 1;
                }
                idx = idx * boxes_per_dim[j] + b;
            }
            idx
        };
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); total_boxes];
        for i in 0..refs.rows() {
            members[box_of(refs.row(i))].push(i);
        }

        let center_of = |idx: usize| -> Vec<f64> {
            let mut rem = idx;
            let mut c = vec![0.0; d];
            for j in (0..d).rev() {
                let b = rem % boxes_per_dim[j];
                rem /= boxes_per_dim[j];
                c[j] = lo[j] + (b as f64 + 0.5) * side;
            }
            c
        };

        // ---- per-box Hermite moments (skip empty boxes) ----
        let mut coeffs = vec![0.0; coeff_slots];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        let scale = kernel.series_scale();
        let mut nonempty = 0u64;
        for (b, rows) in members.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            nonempty += 1;
            accumulate_farfield(
                &set,
                refs,
                rows,
                &weights,
                &center_of(b),
                scale,
                &mut coeffs[b * set.len()..(b + 1) * set.len()],
                &mut mono,
                &mut off,
            );
        }

        // ---- evaluate: per query, Hermite expansions (or direct for
        //      sparse boxes) of boxes within reach ----
        let mut table = HermiteTable::new(d, p);
        let mut sums = vec![0.0; queries.rows()];
        let mut stats = RunStats { dh_prunes: nonempty, ..Default::default() };
        let direct_cheaper = set.len(); // box with fewer sources: direct
        // Sparse boxes evaluate exhaustively through the shared tiled
        // drivers: each box's gathered lanes, weights and (fast path)
        // squared norms are transposed once and amortized across every
        // query that visits the box; per-query squared norms are
        // computed once and reused across its whole neighbor cube.
        let mut box_lanes: HashMap<usize, (Vec<f64>, Vec<f64>, Vec<f64>)> = HashMap::new();
        let mut sqbuf = vec![0.0; direct_cheaper.max(1)];
        let mut qbox = vec![0usize; d];
        let lanes = simd::select(self.simd);
        if self.fast_exp {
            stats.simd_backend = lanes.backend.name();
        }
        for (qi, sum) in sums.iter_mut().enumerate() {
            let qrow = queries.row(qi);
            let qnorm: f64 = if self.fast_exp {
                qrow.iter().map(|v| v * v).sum()
            } else {
                0.0
            };
            for j in 0..d {
                let mut b = ((qrow[j] - lo[j]) / side) as usize;
                if b >= boxes_per_dim[j] {
                    b = boxes_per_dim[j] - 1;
                }
                qbox[j] = b;
            }
            // iterate the neighbor hyper-cube
            let mut cursor = vec![0isize; d];
            for j in 0..d {
                cursor[j] = qbox[j] as isize - reach;
            }
            'boxes: loop {
                // in-bounds check + flat index
                let mut flat = 0usize;
                let mut inb = true;
                for j in 0..d {
                    if cursor[j] < 0 || cursor[j] >= boxes_per_dim[j] as isize {
                        inb = false;
                        break;
                    }
                    flat = flat * boxes_per_dim[j] + cursor[j] as usize;
                }
                if inb && !members[flat].is_empty() {
                    let rows = &members[flat];
                    if rows.len() < direct_cheaper {
                        let m = rows.len();
                        let fast = self.fast_exp;
                        let (soa, wblk, rnorm) = box_lanes.entry(flat).or_insert_with(|| {
                            let mut soa = vec![0.0; d * m];
                            microkernel::transpose_rows_indexed(refs, rows, m, &mut soa);
                            let wblk: Vec<f64> = rows.iter().map(|&i| weights[i]).collect();
                            let rnorm: Vec<f64> = if fast {
                                rows.iter()
                                    .map(|&i| refs.row(i).iter().map(|v| v * v).sum())
                                    .collect()
                            } else {
                                Vec::new()
                            };
                            (soa, wblk, rnorm)
                        });
                        if fast {
                            (lanes.dot_soa)(qrow, soa, m, m, &mut sqbuf);
                            let vals = &mut sqbuf;
                            tile::gauss_from_norms_into_with(lanes, &kernel, qnorm, rnorm, vals, m);
                            *sum += (lanes.weighted_sum)(wblk, &sqbuf[..m]);
                        } else {
                            microkernel::sqdist_soa(qrow, soa, m, m, &mut sqbuf);
                            microkernel::gauss_in_place(&kernel, &mut sqbuf[..m]);
                            // scalar table = the microkernel pointer:
                            // the exact branch stays bit-exact
                            *sum += (simd::scalar().weighted_sum)(wblk, &sqbuf[..m]);
                        }
                        stats.base_point_pairs += m as u64;
                    } else {
                        *sum += eval_farfield(
                            &set,
                            &coeffs[flat * set.len()..(flat + 1) * set.len()],
                            &center_of(flat),
                            scale,
                            qrow,
                            &mut table,
                            &mut off,
                        );
                    }
                }
                // advance the neighbor cursor
                for j in (0..d).rev() {
                    cursor[j] += 1;
                    if cursor[j] <= qbox[j] as isize + reach {
                        continue 'boxes;
                    }
                    cursor[j] = qbox[j] as isize - reach;
                }
                break;
            }
        }
        Ok(GaussSumResult { sums, stats })
    }
}

impl GaussSum for Fgt {
    fn name(&self) -> &'static str {
        "FGT"
    }

    fn guarantees_tolerance(&self) -> bool {
        false // absolute-τ scheme; relative ε needs the verification loop
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        self.run_with_frame(problem, &GridFrame::joint(problem.queries, problem.references))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn uniform(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn meets_absolute_tolerance_2d() {
        let data = uniform(400, 2, 101);
        for h in [0.1, 0.3, 1.0] {
            let p = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&p).unwrap().sums;
            let tau = 1e-4;
            let out = Fgt::new(tau).run(&p).unwrap();
            let w = p.total_weight();
            for i in 0..exact.len() {
                assert!(
                    (out.sums[i] - exact[i]).abs() <= w * tau + 1e-9,
                    "h={h} i={i}: {} vs {}",
                    out.sums[i],
                    exact[i]
                );
            }
        }
    }

    #[test]
    fn small_bandwidth_exhausts_ram() {
        // tiny h in 2-D with the 2 GB cap → the paper's X
        let data = uniform(100, 2, 102);
        let p = GaussSumProblem::kde(&data, 1e-5, 0.01);
        match Fgt::new(1e-3).run(&p) {
            Err(AlgoError::RamExhausted(_)) => {}
            other => panic!("expected RamExhausted, got {other:?}"),
        }
    }

    #[test]
    fn high_dim_exhausts_ram() {
        // even moderate h in 10-D explodes the grid (paper: X for D≥3
        // at small h, X everywhere for D ≥ 5)
        let data = uniform(100, 10, 103);
        let p = GaussSumProblem::kde(&data, 0.01, 0.01);
        assert!(matches!(
            Fgt::new(1e-3).run(&p),
            Err(AlgoError::RamExhausted(_))
        ));
    }

    #[test]
    fn tau_controls_accuracy() {
        let data = uniform(300, 2, 104);
        let p = GaussSumProblem::kde(&data, 0.5, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let loose = Fgt::new(1e-2).run(&p).unwrap().sums;
        let tight = Fgt::new(1e-6).run(&p).unwrap().sums;
        let err = |xs: &[f64]| -> f64 {
            xs.iter().zip(&exact).map(|(a, e)| (a - e).abs()).fold(0.0, f64::max)
        };
        assert!(err(&tight) <= err(&loose) + 1e-12);
        assert!(err(&tight) <= 300.0 * 1e-6);
    }

    #[test]
    fn not_flagged_as_guaranteeing() {
        assert!(!Fgt::default().guarantees_tolerance());
    }

    #[test]
    fn fast_and_exact_direct_paths_agree() {
        // small h drives everything through the sparse-box direct path
        let data = uniform(250, 2, 105);
        let p = GaussSumProblem::kde(&data, 0.05, 0.01);
        let exact_mode = Fgt { fast_exp: false, ..Fgt::new(1e-5) }.run(&p).unwrap();
        let fast_mode = Fgt::new(1e-5).run(&p).unwrap();
        assert!(fast_mode.stats.base_point_pairs > 0, "direct path not exercised");
        for i in 0..250 {
            let rel = (fast_mode.sums[i] - exact_mode.sums[i]).abs()
                / exact_mode.sums[i].max(1e-300);
            assert!(rel <= 1e-10, "i={i}: rel={rel:.2e}");
        }
    }
}
