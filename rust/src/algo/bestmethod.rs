//! `bestMethod` (paper Fig. 6): given a (Q, R) pair and a maximum
//! admissible absolute error, find — for each FMM-type approximation —
//! the smallest truncation order that meets the error, cost the four
//! contenders, and return the cheapest.
//!
//! Costs follow the paper's model with the expansion size made explicit
//! (so one cost model serves both layouts):
//!   c_DH     = N_Q · |set(p_DH)| · D      (EVALM at every query point)
//!   c_DL     = N_R · |set(p_DL)| · D      (DIRECTL from every reference)
//!   c_H2L    = |set(p_H2L)|² · D          (one translation)
//!   c_DIRECT = D · N_Q · N_R              (exhaustive / keep recursing)

use crate::bounds::{NodeGeometry, SeriesMethod, TruncationBounds};
use crate::multiindex::MultiIndexSet;

/// The choice returned by [`best_method`].
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum Choice {
    /// Evaluate the reference node's Hermite expansion at each query
    /// point, at the given order, with the given error bound.
    DH { p: usize, err: f64 },
    /// Accumulate a local Taylor expansion directly from each reference
    /// point.
    DL { p: usize, err: f64 },
    /// Translate the reference Hermite expansion into the query node's
    /// local expansion.
    H2L { p: usize, err: f64 },
    /// No series method is cheapest (or none feasible): compute exactly
    /// or keep recursing.
    Direct,
}

/// Inputs that don't change per pair evaluation.
pub struct CostModel<'a> {
    /// The PLIMIT-order index set (sub-orders read off via `in_order`).
    pub set: &'a MultiIndexSet,
    /// Maximum truncation order to consider (PLIMIT).
    pub p_limit: usize,
}

impl<'a> CostModel<'a> {
    /// Size of the sub-order-p expansion.
    fn len_at(&self, p: usize) -> f64 {
        self.set.len_at_order(p) as f64
    }

    /// Pick the cheapest feasible method for a pair.
    ///
    /// * `bounds`: the bound family (O(Dᵖ) for DITO, O(pᴰ) for DFTO).
    ///   Generic so monomorphized traversal variants get static dispatch
    ///   on this per-node-pair hot path; `&dyn TruncationBounds` still
    ///   works for runtime-polymorphic callers.
    /// * `geo`: pair geometry; `weight`: W_R; `max_err`: admissible E_A.
    /// * `nq`, `nr`: point counts of the two nodes.
    pub fn best_method<B: TruncationBounds + ?Sized>(
        &self,
        bounds: &B,
        geo: &NodeGeometry,
        weight: f64,
        max_err: f64,
        nq: usize,
        nr: usize,
    ) -> Choice {
        let d = geo.dim as f64;
        let c_direct = d * nq as f64 * nr as f64;

        let dh = bounds.smallest_order(SeriesMethod::DH, geo, weight, max_err, self.p_limit);
        let dl = bounds.smallest_order(SeriesMethod::DL, geo, weight, max_err, self.p_limit);
        let h2l = bounds.smallest_order(SeriesMethod::H2L, geo, weight, max_err, self.p_limit);

        let c_dh = dh.map_or(f64::INFINITY, |(p, _)| nq as f64 * self.len_at(p) * d);
        let c_dl = dl.map_or(f64::INFINITY, |(p, _)| nr as f64 * self.len_at(p) * d);
        let c_h2l = h2l.map_or(f64::INFINITY, |(p, _)| {
            let l = self.len_at(p);
            l * l * d
        });

        let c = c_dh.min(c_dl).min(c_h2l).min(c_direct);
        if c == c_direct {
            Choice::Direct
        } else if c == c_dh {
            // lint: allow(no-panic): a winning finite cost implies the candidate was computed
            let (p, err) = dh.unwrap();
            Choice::DH { p, err }
        } else if c == c_dl {
            // lint: allow(no-panic): a winning finite cost implies the candidate was computed
            let (p, err) = dl.unwrap();
            Choice::DL { p, err }
        } else {
            // lint: allow(no-panic): a winning finite cost implies the candidate was computed
            let (p, err) = h2l.unwrap();
            Choice::H2L { p, err }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::odp::OdpBounds;
    use crate::multiindex::Layout;

    fn geo(dim: usize, min_sqdist: f64, r_ref: f64, r_query: f64, h: f64) -> NodeGeometry {
        NodeGeometry { dim, min_sqdist, r_ref, r_query, h }
    }

    fn model(set: &MultiIndexSet) -> CostModel<'_> {
        CostModel { set, p_limit: set.order() }
    }

    #[test]
    fn far_pair_prefers_h2l_when_both_nodes_big() {
        // far apart, lots of points on both sides, budget loose enough
        // for the (large-constant) H2L bound → translation wins on cost
        let set = MultiIndexSet::new(Layout::Graded, 2, 8);
        let cm = model(&set);
        let g = geo(2, 25.0, 0.3, 0.3, 1.0);
        let c = cm.best_method(&OdpBounds, &g, 1000.0, 0.1, 5000, 5000);
        assert!(matches!(c, Choice::H2L { .. }), "{c:?}");
    }

    #[test]
    fn many_refs_few_queries_prefers_dh() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 8);
        let cm = model(&set);
        let g = geo(2, 25.0, 0.3, 0.3, 1.0);
        let c = cm.best_method(&OdpBounds, &g, 1000.0, 1e-3, 3, 100000);
        // DH cost = 3·len·2, far below H2L's len²·2 for feasible p
        assert!(matches!(c, Choice::DH { .. }), "{c:?}");
    }

    #[test]
    fn many_queries_few_refs_prefers_dl() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 8);
        let cm = model(&set);
        let g = geo(2, 25.0, 0.3, 0.3, 1.0);
        let c = cm.best_method(&OdpBounds, &g, 5.0, 1e-3, 100000, 3);
        assert!(matches!(c, Choice::DL { .. }), "{c:?}");
    }

    #[test]
    fn tiny_nodes_prefer_direct() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 8);
        let cm = model(&set);
        let g = geo(2, 0.01, 0.5, 0.5, 1.0);
        let c = cm.best_method(&OdpBounds, &g, 2.0, 1e-6, 2, 2);
        assert_eq!(c, Choice::Direct);
    }

    #[test]
    fn infeasible_bounds_fall_back_to_direct() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 2);
        let cm = model(&set);
        // adjacent large nodes, impossible tolerance
        let g = geo(2, 0.0, 5.0, 5.0, 0.01);
        let c = cm.best_method(&OdpBounds, &g, 1000.0, 1e-12, 10000, 10000);
        assert_eq!(c, Choice::Direct);
    }

    #[test]
    fn chosen_order_meets_error() {
        let set = MultiIndexSet::new(Layout::Graded, 3, 6);
        let cm = model(&set);
        let g = geo(3, 9.0, 0.4, 0.4, 1.0);
        let max_err = 0.05;
        match cm.best_method(&OdpBounds, &g, 10.0, max_err, 1000, 1000) {
            Choice::DH { err, .. } | Choice::DL { err, .. } | Choice::H2L { err, .. } => {
                assert!(err <= max_err);
            }
            Choice::Direct => panic!("expected a series method"),
        }
    }
}
