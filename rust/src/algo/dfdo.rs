//! **DFDO** — DFD with the paper's improved error control: identical
//! finite-difference approximation, but slack error budget is banked in
//! the per-node W_T token ledger and spent on later prunes. A thin
//! instantiation of the generic engine:
//! `run_dualtree_variant::<NoExpansion, TokenLedger>`. The paper
//! reports a consistent 10–15 % improvement over DFD in higher
//! dimensions from this change alone.

use super::dualtree::{run_dualtree_variant, NoExpansion, TokenLedger};
use super::{AlgoError, GaussSum, GaussSumProblem, GaussSumResult};

#[derive(Copy, Clone, Debug)]
pub struct Dfdo {
    pub leaf_size: usize,
}

impl Default for Dfdo {
    fn default() -> Self {
        Dfdo { leaf_size: 32 }
    }
}

impl Dfdo {
    pub fn new() -> Self {
        Self::default()
    }
}

impl GaussSum for Dfdo {
    fn name(&self) -> &'static str {
        "DFDO"
    }

    fn run(&self, problem: &GaussSumProblem<'_>) -> Result<GaussSumResult, AlgoError> {
        run_dualtree_variant::<NoExpansion, TokenLedger>(problem, self.leaf_size, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::dfd::Dfd;
    use crate::algo::naive::Naive;
    use crate::algo::max_relative_error;
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn blobs(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        let centers: Vec<Vec<f64>> =
            (0..5).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
        Matrix::from_rows(
            &(0..n)
                .map(|i| {
                    (0..d).map(|j| centers[i % 5][j] + 0.04 * rng.normal()).collect()
                })
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn guarantee_holds_and_banks_tokens() {
        let data = blobs(400, 3, 92);
        let p = GaussSumProblem::kde(&data, 0.2, 0.01);
        let exact = Naive::new().run(&p).unwrap().sums;
        let out = Dfdo::new().run(&p).unwrap();
        assert!(max_relative_error(&out.sums, &exact) <= 0.01 * (1.0 + 1e-9));
        assert!(out.stats.tokens_banked > 0.0);
    }

    #[test]
    fn never_worse_pruning_than_dfd() {
        // token control only *adds* prune opportunities: base-case work
        // must be ≤ DFD's on identical input
        for h in [0.05, 0.2, 1.0] {
            let data = blobs(500, 2, 93);
            let p = GaussSumProblem::kde(&data, h, 0.01);
            let a = Dfdo::new().run(&p).unwrap().stats.base_point_pairs;
            let b = Dfd::new().run(&p).unwrap().stats.base_point_pairs;
            assert!(a <= b, "h={h}: DFDO={a} DFD={b}");
        }
    }
}
