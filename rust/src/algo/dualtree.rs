//! The shared dual-tree engine behind DFD, DFDO, DFTO and DITO.
//!
//! One recursion (paper Fig. 7), with the paper's "one algorithm with
//! switches" lifted into the type system: the traversal is generic over
//!
//! * [`PruneRule`] — plain Theorem-2 acceptance ([`Theorem2`], DFD) vs
//!   the W_T token ledger ([`TokenLedger`], DFDO/DFTO/DITO);
//! * [`Expansion`] — [`NoExpansion`] (finite difference only),
//!   [`OdpGraded`] (O(Dᵖ) graded expansion + Lemma 4–6 bounds, DITO) or
//!   [`OpdGrid`] (O(pᴰ) grid expansion + geometric bounds, DFTO);
//!
//! and each of the six (expansion × rule) combinations monomorphizes
//! into its own branch-free hot loop — no `SeriesKind` or `use_tokens`
//! test survives inside the per-pair recursion. The four paper
//! algorithms are thin instantiations ([`run_dualtree_variant`]); the
//! runtime-switch interface ([`DualTreeConfig`] + [`run_dualtree`] /
//! [`SweepEngine::evaluate`]) dispatches **once per evaluate** to the
//! matching instantiation and is otherwise identical.
//!
//! Leaf-leaf base cases — the dominant cost at tight ε — are **not**
//! computed eagerly: the traversal registers each surviving pair's
//! bounds (and banks its full token entitlement) and pushes the pair
//! onto the task's queue, which is drained after the recursion in
//! tile batches *grouped by reference leaf* — each reference leaf's SoA
//! transpose is amortized across every query leaf that hit it within
//! the task, and the recycled [`crate::compute::Scratch`] arena (sized
//! at prepare time) stays hot across tasks. The drain
//! runs the GEMM-shaped fast kernel ([`crate::compute::tile`]: cached
//! norms + dot-product tiles + certified `exp_block`) whenever
//! [`crate::errorcontrol::split_epsilon`] admits its certified error
//! into the ε budget (`fast_exp` on [`DualTreeConfig`], default on),
//! and the bit-exact per-query scalar-order path otherwise.
//!
//! Correctness architecture: per-query-node state lives in a
//! [`QueryLedger`]; bounds are hierarchical (summed along the root→leaf
//! path) with the ancestor part carried down the recursion as
//! `inherited_min` and the subtree part cached in `below_min` — see
//! `errorcontrol` for the soundness argument. Approximation results are
//! either per-point (base cases, EVALM) or node-level (FD estimates in
//! `node_est`, local Taylor coefficients in `lcoeffs`), and the
//! post-processing pass (paper Fig. 8) pushes node-level state down with
//! the **L2L** operator and evaluates local expansions at the leaves.
//!
//! # Two-phase evaluation: [`SweepEngine`]
//!
//! The paper's motivating workload — LSCV bandwidth selection — runs
//! Gaussian summations *across a whole grid of bandwidths on the same
//! dataset*. Everything h-independent (kd-tree construction, the weight
//! permutation, node geometry) is factored into
//! [`SweepEngine::prepare`], done **once per dataset**; each
//! [`SweepEngine::evaluate`] call then computes only the h-dependent
//! state (Hermite moment tables, the [`QueryLedger`]) and runs the
//! traversal. Per-(h, layout, plimit) moments are memoized in a
//! **bounded** cache (capacity [`DEFAULT_MOMENT_CACHE_CAPACITY`],
//! true LRU — hits promote recency; see
//! [`SweepEngine::with_moment_cache_capacity`]).
//!
//! # Threading: the shared pool + a fixed task decomposition
//!
//! All parallelism runs on one [`WorkStealPool`]
//! (see [`crate::runtime::pool`]), shared with the session batch and
//! sweep layers above so nested fan-outs compose instead of
//! fragmenting. Each evaluate cuts the query tree into **at most
//! [`TRAVERSAL_TASKS`] disjoint subtree tasks — a decomposition that
//! depends only on the tree, never on the pool width** — and each task
//! recurses against the full reference tree, drains its own base-case
//! queue, and post-processes its own subtree into a private output
//! slice. Partial results are then combined by an *indexed reduction*
//! in fixed task order. Per-task mutable state (ledger, Hermite
//! workspace, the [`crate::compute::Scratch`] arena sized at prepare)
//! is recycled through a per-evaluate free list, so the number of
//! `State` allocations equals the pool's effective concurrency, not
//! the task count (each task additionally owns just a small
//! subtree-sized output buffer). Because the task set, each task's
//! work, and the reduction
//! order are all width-independent, **results are bit-identical for
//! every pool width** — an inline width-1 pool reproduces an 8-worker
//! pool exactly (`rust/tests/pool_determinism.rs` pins {1, 2, 8}).
//! Each subtree root starts with `inherited_min = 0` (no ancestor
//! bound), which only makes prune tests more conservative — the ε
//! guarantee is unaffected.
//!
//! [`evaluate_grid`](SweepEngine::evaluate_grid) schedules its grid
//! points as pool tasks too (each nests its own traversal tasks), and
//! [`run_dualtree`] is the one-shot wrapper: prepare + evaluate on an
//! inline pool, bit-identical to every other width by the invariance
//! above.

use std::collections::HashMap;
use std::sync::Arc;

use crate::bounds::{odp::OdpBounds, opd::OpdBounds, NeverBounds, NodeGeometry, TruncationBounds};
use crate::compute::simd::{Lanes, Precision, SimdMode};
use crate::compute::{simd, tile, Scratch};
use crate::errorcontrol::{split_epsilon_prec, PruneDecision, QueryLedger};
pub use crate::errorcontrol::{PruneRule, Theorem2, TokenLedger};
use crate::geometry::Matrix;
use crate::hermite::{
    accumulate_local_truncated, eval_farfield_truncated, eval_local, h2l_truncated, l2l,
    HermiteTable,
};
use crate::kernel::GaussianKernel;
use crate::multiindex::Layout;
use crate::runtime::pool::WorkStealPool;
use crate::runtime::sync::SyncMutex;
use crate::tree::{plimit_for_dim, BuildParams, KdTree, RefMoments};
use crate::util::timer::time_it;

use super::bestmethod::{Choice, CostModel};
use super::{AlgoError, GaussSumProblem, GaussSumResult, RunStats};

/// Expansion family for FMM-type pruning — the runtime tag used by
/// [`DualTreeConfig`] and the moment cache; the traversal itself works
/// on the type-level [`Expansion`] instantiations.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SeriesKind {
    /// O(Dᵖ) graded expansion with the paper's Lemma 4–6 bounds (DITO).
    OdpGraded,
    /// O(pᴰ) grid expansion with geometric-series bounds (DFTO).
    OpdGrid,
}

impl SeriesKind {
    fn layout(self) -> Layout {
        match self {
            SeriesKind::OdpGraded => Layout::Graded,
            SeriesKind::OpdGrid => Layout::Grid,
        }
    }
}

/// The series half of the paper's switchboard, lifted to a type: which
/// expansion family (if any) the traversal may prune with. The three
/// instantiations are [`NoExpansion`], [`OdpGraded`] and [`OpdGrid`];
/// `ENABLED == false` compiles the whole FMM branch out of the
/// recursion, and `Bounds` is statically dispatched on the per-pair
/// order search.
pub trait Expansion: Copy + Send + Sync + 'static {
    /// Series pruning active? `false` = finite-difference-only engine.
    const ENABLED: bool;
    /// Runtime tag for moments/caching; `None` iff `!ENABLED`.
    const KIND: Option<SeriesKind>;
    /// Truncation-bound family (zero-sized, monomorphized).
    type Bounds: TruncationBounds + Send + Sync;
    /// The bound family instance handed to the cost model.
    const BOUNDS: Self::Bounds;
}

/// Finite-difference-only traversal (DFD/DFDO): no series machinery is
/// even compiled into the hot loop.
#[derive(Copy, Clone, Debug, Default)]
pub struct NoExpansion;

impl Expansion for NoExpansion {
    const ENABLED: bool = false;
    const KIND: Option<SeriesKind> = None;
    type Bounds = NeverBounds;
    const BOUNDS: NeverBounds = NeverBounds;
}

/// O(Dᵖ) graded expansion with the Lemma 4–6 bounds (DITO).
#[derive(Copy, Clone, Debug, Default)]
pub struct OdpGraded;

impl Expansion for OdpGraded {
    const ENABLED: bool = true;
    const KIND: Option<SeriesKind> = Some(SeriesKind::OdpGraded);
    type Bounds = OdpBounds;
    const BOUNDS: OdpBounds = OdpBounds;
}

/// O(pᴰ) grid expansion with geometric-series bounds (DFTO).
#[derive(Copy, Clone, Debug, Default)]
pub struct OpdGrid;

impl Expansion for OpdGrid {
    const ENABLED: bool = true;
    const KIND: Option<SeriesKind> = Some(SeriesKind::OpdGrid);
    type Bounds = OpdBounds;
    const BOUNDS: OpdBounds = OpdBounds;
}

/// Engine configuration; the four public algorithms are fixed settings
/// of this struct. Each `evaluate` resolves the switches **once** to a
/// monomorphized (Expansion, PruneRule) instantiation.
#[derive(Copy, Clone, Debug)]
pub struct DualTreeConfig {
    /// Tree leaf size. Used at preparation time ([`run_dualtree`] /
    /// [`SweepEngine::prepare`]); ignored by [`SweepEngine::evaluate`],
    /// whose trees are already built.
    pub leaf_size: usize,
    /// Enable the W_T token ledger (the paper's improved error control).
    pub use_tokens: bool,
    /// FMM-type pruning family, or `None` for finite-difference only.
    pub series: Option<SeriesKind>,
    /// Override the PLIMIT schedule (`None` = paper's per-D schedule).
    pub plimit: Option<usize>,
    /// Run drained base cases on the certified fast tiled kernel
    /// (default on). The ε guarantee is preserved by reserving the
    /// certified error out of the budget
    /// ([`crate::errorcontrol::split_epsilon`]); bandwidths where the
    /// certified bound is not affordable fall back to the bit-exact
    /// path automatically, and `false` forces the bit-exact path
    /// everywhere (the reference configuration).
    pub fast_exp: bool,
    /// Vector-lane dispatch for the drained base cases: `Auto` installs
    /// the per-process detected backend (AVX2/NEON/scalar), `Off` pins
    /// the scalar table, which is bit-exact vs. the pre-SIMD code.
    pub simd: SimdMode,
    /// Arithmetic precision of the fast tile. `F32` stores the
    /// reference lanes/weights/norms in f32 (f64 accumulation) and is
    /// admitted per evaluate only when its *derived* certificate
    /// ([`crate::errorcontrol::base_case_rel_err_f32`]) fits the ε/4
    /// gate of [`crate::errorcontrol::split_epsilon_prec`]; otherwise
    /// the evaluate silently demotes to the certified f64 fast path
    /// (then to bit-exact), so the guarantee never weakens.
    pub precision: Precision,
}

impl Default for DualTreeConfig {
    fn default() -> Self {
        DualTreeConfig {
            leaf_size: 32,
            use_tokens: true,
            series: Some(SeriesKind::OdpGraded),
            plimit: None,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
        }
    }
}

/// Resolve the runtime switches of a [`DualTreeConfig`] into one of the
/// six monomorphized (Expansion, PruneRule) instantiations and run
/// `$body` with `$X`/`$P` bound to the chosen types.
macro_rules! dispatch_variant {
    ($cfg:expr, $X:ident, $P:ident => $body:expr) => {{
        match ($cfg.series, $cfg.use_tokens) {
            (None, false) => {
                type $X = NoExpansion;
                type $P = Theorem2;
                $body
            }
            (None, true) => {
                type $X = NoExpansion;
                type $P = TokenLedger;
                $body
            }
            (Some(SeriesKind::OdpGraded), false) => {
                type $X = OdpGraded;
                type $P = Theorem2;
                $body
            }
            (Some(SeriesKind::OdpGraded), true) => {
                type $X = OdpGraded;
                type $P = TokenLedger;
                $body
            }
            (Some(SeriesKind::OpdGrid), false) => {
                type $X = OpdGrid;
                type $P = Theorem2;
                $body
            }
            (Some(SeriesKind::OpdGrid), true) => {
                type $X = OpdGrid;
                type $P = TokenLedger;
                $body
            }
        }
    }};
}

/// Immutable per-run context (data only; the algorithm switches live in
/// the generic parameters of the traversal functions).
struct Ctx<'a> {
    qt: &'a KdTree,
    rt: &'a KdTree,
    kernel: GaussianKernel,
    /// The *tree* half of the ε budget (user ε minus the certified
    /// base-case reservation when `fast` is on).
    eps: f64,
    total_w: f64,
    /// Drain base cases through the certified fast tiled kernel.
    fast: bool,
    /// Drain base cases through the f32 mixed-precision tile (implies
    /// `fast`; admitted by `split_epsilon_prec`'s gate).
    f32_tile: bool,
    /// SIMD dispatch table the drained base cases run on (resolved
    /// once per evaluate from the config's [`SimdMode`]).
    lanes: &'static Lanes,
    /// Present iff the variant's `Expansion::ENABLED`.
    series: Option<SeriesPack<'a>>,
}

struct SeriesPack<'a> {
    moments: &'a RefMoments,
    p_limit: usize,
}

impl<'a> Ctx<'a> {
    /// The series pack of an expansion-enabled variant. Every caller
    /// sits under `if X::ENABLED`, and construction populates the pack
    /// for exactly those variants — reaching a `None` here means the
    /// variant/config pairing is broken, not a user error.
    fn series(&self) -> &SeriesPack<'a> {
        match self.series.as_ref() {
            Some(pack) => pack,
            // lint: allow(no-panic): X::ENABLED without moments is a construction bug; abort loudly
            None => panic!("series moments missing for expansion variant"),
        }
    }
}

/// Mutable per-task state, recycled through a per-evaluate free list
/// (tasks own disjoint query subtrees, so a reused instance's stale
/// slots are never read).
struct State {
    ledger: QueryLedger,
    /// Local Taylor coefficients per query node (node-major), when a
    /// series family is active.
    lcoeffs: Vec<f64>,
    set_len: usize,
    table: HermiteTable,
    mono: Vec<f64>,
    off: Vec<f64>,
    /// SoA block arena for the base case, sized to the reference tree's
    /// largest leaf so base cases never allocate.
    scratch: Scratch,
    /// Surviving (query leaf, reference leaf) pairs awaiting their
    /// exhaustive sums — bounds/tokens are registered at enqueue time,
    /// the sums at drain time (grouped by reference leaf).
    queue: Vec<(u32, u32)>,
    stats: RunStats,
}

impl State {
    fn new(qt: &KdTree, set_len: usize, dim: usize, table_order: usize, leaf_block: usize) -> Self {
        State {
            ledger: QueryLedger::new(qt.num_nodes(), qt.num_points()),
            lcoeffs: vec![0.0; qt.num_nodes() * set_len],
            set_len,
            table: HermiteTable::new(dim, table_order),
            mono: vec![0.0; set_len.max(1)],
            off: vec![0.0; dim],
            scratch: Scratch::with_block(dim, leaf_block),
            queue: Vec::new(),
            stats: RunStats::default(),
        }
    }
}

/// Upper bound on the number of disjoint query-subtree tasks one
/// evaluate fans out (fewer on shallow trees). Deliberately a constant
/// rather than `pool width × k`: the decomposition must depend only on
/// the tree so that results are bit-identical across pool widths, and
/// 32 tasks keep an 8-worker pool load-balanced under stealing while
/// preserving most of the per-task ref-leaf drain grouping.
pub const TRAVERSAL_TASKS: usize = 32;

/// Memoization key for per-bandwidth reference moments.
type MomentKey = (u64, Layout, usize);

/// Default capacity of the per-engine moment memo (distinct
/// `(h, layout, plimit)` triples kept live).
pub const DEFAULT_MOMENT_CACHE_CAPACITY: usize = 64;

/// Bounded memo for per-bandwidth moment tables: capacity-capped,
/// true-LRU eviction (a hit promotes its entry to most-recent, so an
/// adaptive h-search hammering one bandwidth never loses it to grid
/// churn), plus hit/miss counters.
struct MomentCache {
    map: HashMap<MomentKey, (u64, Arc<RefMoments>)>,
    /// Monotone use stamp; the minimum stamp is the least recently
    /// used entry. Refreshed on hit, not just on insert.
    next_stamp: u64,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl MomentCache {
    fn new(capacity: usize) -> Self {
        MomentCache {
            map: HashMap::new(),
            next_stamp: 0,
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    fn get(&mut self, key: &MomentKey) -> Option<Arc<RefMoments>> {
        let stamp = self.next_stamp;
        match self.map.get_mut(key) {
            Some(slot) => {
                // LRU: a hit promotes the entry to most-recently-used
                slot.0 = stamp;
                self.next_stamp += 1;
                self.hits += 1;
                Some(Arc::clone(&slot.1))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    fn insert(&mut self, key: MomentKey, m: Arc<RefMoments>) {
        if let Some(slot) = self.map.get_mut(&key) {
            // racing compute of the same key: replacing the value is a
            // use — promote it like a hit
            slot.0 = self.next_stamp;
            slot.1 = m;
            self.next_stamp += 1;
            return;
        }
        self.evict_down_to(self.capacity.saturating_sub(1));
        self.map.insert(key, (self.next_stamp, m));
        self.next_stamp += 1;
    }

    /// Evict least-recently-used entries until at most `keep` remain.
    fn evict_down_to(&mut self, keep: usize) {
        while self.map.len() > keep {
            let lru = self.map.iter().min_by_key(|(_, (s, _))| *s).map(|(k, _)| *k);
            match lru {
                Some(k) => {
                    self.map.remove(&k);
                }
                None => break,
            }
        }
    }
}

/// A dataset prepared for repeated dual-tree evaluation across
/// bandwidths and engine variants.
///
/// `prepare` does all h-independent work exactly once: kd-tree
/// construction (with the point permutation and cached node geometry /
/// sufficient statistics). `evaluate` does only h-dependent work —
/// Hermite moments (memoized per `(h, layout, plimit)` in a bounded
/// cache), the [`QueryLedger`] and the traversal itself — so a full
/// LSCV grid touches tree construction exactly once.
///
/// ```no_run
/// use fastgauss::algo::dualtree::{DualTreeConfig, SweepEngine};
/// let data = fastgauss::data::synthetic::astro2d(10_000, 42);
/// let engine = SweepEngine::for_kde(&data, 32).with_threads(4);
/// let cfg = DualTreeConfig::default(); // DITO
/// let results = engine.evaluate_grid(&[0.01, 0.1, 1.0], 0.01, &cfg).unwrap();
/// assert_eq!(engine.tree_builds(), 1); // one build, three bandwidths
/// # let _ = results;
/// ```
pub struct SweepEngine {
    rtree: KdTree,
    /// `None` when queries == references (monochromatic / KDE).
    qtree: Option<KdTree>,
    dim: usize,
    total_w: f64,
    build_secs: f64,
    tree_builds: u64,
    /// The shared work-stealing pool every evaluate schedules onto
    /// (inline/width-1 by default; a [`crate::api::Session`] shares its
    /// pool here so batches and traversals compose).
    pool: Arc<WorkStealPool>,
    moment_cache: SyncMutex<MomentCache>,
}

impl SweepEngine {
    /// Build the tree(s) for `problem`'s point sets. The problem's `h`
    /// and `epsilon` are *not* baked in — pass them to [`evaluate`].
    ///
    /// [`evaluate`]: SweepEngine::evaluate
    pub fn prepare(problem: &GaussSumProblem<'_>, leaf_size: usize) -> Self {
        let weights = problem.weight_vec();
        let params = BuildParams { leaf_size };
        let ((rtree, qtree), build_secs) = time_it(|| {
            let rtree = KdTree::build(problem.references, &weights, params);
            let qtree = if problem.monochromatic {
                None
            } else {
                // query tree weights are irrelevant; use ones
                let qw = vec![1.0; problem.queries.rows()];
                Some(KdTree::build(problem.queries, &qw, params))
            };
            (rtree, qtree)
        });
        let tree_builds = 1 + qtree.is_some() as u64;
        SweepEngine {
            dim: problem.dim(),
            total_w: problem.total_weight(),
            rtree,
            qtree,
            build_secs,
            tree_builds,
            pool: Arc::new(WorkStealPool::inline()),
            moment_cache: SyncMutex::new(MomentCache::new(DEFAULT_MOMENT_CACHE_CAPACITY)),
        }
    }

    /// Prepare for the paper's KDE setting: queries = references =
    /// `data`, unit weights, one tree.
    pub fn for_kde(data: &Matrix, leaf_size: usize) -> Self {
        // placeholder h/ε: prepare ignores them by construction
        Self::prepare(&GaussSumProblem::kde(data, 1.0, 1.0), leaf_size)
    }

    /// Give the engine a private work-stealing pool of `threads`
    /// workers, used by [`evaluate`] (across query-subtree tasks) and
    /// [`evaluate_grid`] (across bandwidths, nesting the subtree
    /// tasks). The task decomposition and reduction order are fixed, so
    /// **results are bit-identical for every worker count** — width
    /// only changes wall-clock time. Width 1 (the default) is the
    /// inline pool: no threads are spawned at all.
    ///
    /// [`evaluate`]: SweepEngine::evaluate
    /// [`evaluate_grid`]: SweepEngine::evaluate_grid
    pub fn with_threads(self, threads: usize) -> Self {
        self.with_pool(Arc::new(WorkStealPool::new(threads)))
    }

    /// Share an existing pool — how a [`crate::api::Session`] puts its
    /// batch fan-out and every traversal it triggers on one scheduler.
    pub fn with_pool(mut self, pool: Arc<WorkStealPool>) -> Self {
        self.pool = pool;
        self
    }

    /// The pool this engine schedules onto.
    pub fn pool(&self) -> &Arc<WorkStealPool> {
        &self.pool
    }

    /// Cap the moment memo at `capacity` entries (≥ 1). The default is
    /// [`DEFAULT_MOMENT_CACHE_CAPACITY`]; grid sweeps want at least the
    /// grid size, adaptive h-searches can shrink it (or call
    /// [`clear_moment_cache`] between phases). Shrinking below the
    /// current occupancy evicts the least-recently-used entries
    /// immediately.
    ///
    /// [`clear_moment_cache`]: SweepEngine::clear_moment_cache
    pub fn with_moment_cache_capacity(self, capacity: usize) -> Self {
        {
            let mut cache = self.moment_cache.lock().unwrap();
            cache.capacity = capacity.max(1);
            let keep = cache.capacity;
            cache.evict_down_to(keep);
        }
        self
    }

    /// Seconds spent building the tree(s) in `prepare`.
    pub fn build_secs(&self) -> f64 {
        self.build_secs
    }

    /// Number of kd-tree constructions performed (1 for KDE, 2 for
    /// bichromatic problems) — constant over any number of evaluates.
    pub fn tree_builds(&self) -> u64 {
        self.tree_builds
    }

    /// Number of query points.
    pub fn num_points(&self) -> usize {
        self.qtree.as_ref().unwrap_or(&self.rtree).num_points()
    }

    /// Data dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Whether queries and references are the same point set.
    pub fn is_monochromatic(&self) -> bool {
        self.qtree.is_none()
    }

    /// Drop all memoized per-bandwidth moment tables — the documented
    /// escape hatch for releasing moment memory immediately (e.g.
    /// between phases of an adaptive bandwidth search). The cache is
    /// otherwise self-bounding: at most
    /// [`with_moment_cache_capacity`](SweepEngine::with_moment_cache_capacity)
    /// entries stay live, with the least-recently-*used* entry evicted
    /// first (hits promote recency — true LRU, not insertion order).
    /// Hit/miss counters survive the clear.
    pub fn clear_moment_cache(&self) {
        self.moment_cache.lock().unwrap().map.clear();
    }

    /// Lifetime `(hits, misses)` of the moment memo. Per-run hit/miss
    /// flags are also reported in
    /// [`RunStats::moment_cache_hits`]/[`RunStats::moment_cache_misses`].
    pub fn moment_cache_stats(&self) -> (u64, u64) {
        let c = self.moment_cache.lock().unwrap();
        (c.hits, c.misses)
    }

    /// Entries currently memoized.
    pub fn moment_cache_len(&self) -> usize {
        self.moment_cache.lock().unwrap().map.len()
    }

    /// Memoized per-bandwidth reference moments. Returns the table, the
    /// seconds spent computing it (0 on a hit) and whether it was a hit.
    fn moments_for(
        &self,
        kernel: &GaussianKernel,
        kind: SeriesKind,
        plimit: usize,
    ) -> (Arc<RefMoments>, f64, bool) {
        let key = (kernel.bandwidth().to_bits(), kind.layout(), plimit);
        if let Some(m) = self.moment_cache.lock().unwrap().get(&key) {
            return (m, 0.0, true);
        }
        // compute outside the lock: concurrent h-workers must not
        // serialize on each other's moment passes (racing computes of
        // the same key are identical; last insert wins)
        let (m, secs) = time_it(|| {
            Arc::new(RefMoments::compute(&self.rtree, kernel, kind.layout(), plimit))
        });
        self.moment_cache.lock().unwrap().insert(key, Arc::clone(&m));
        (m, secs, false)
    }

    /// Run one bandwidth under `cfg` on the engine's shared pool. The
    /// result's `stats.build_secs` covers only the h-dependent moment
    /// pass; the one-time tree cost is reported by [`build_secs`].
    /// Results are bit-identical for every pool width (see the module
    /// docs: fixed task decomposition + indexed reduction).
    ///
    /// [`build_secs`]: SweepEngine::build_secs
    pub fn evaluate(
        &self,
        h: f64,
        epsilon: f64,
        cfg: &DualTreeConfig,
    ) -> Result<GaussSumResult, AlgoError> {
        dispatch_variant!(cfg, X, P => {
            self.evaluate_variant_cfg::<X, P>(
                h,
                epsilon,
                cfg.plimit,
                cfg.fast_exp,
                cfg.simd,
                cfg.precision,
            )
        })
    }

    /// Run one bandwidth as an explicit monomorphized variant — the
    /// type-level form of [`evaluate`]; the four paper algorithms are
    /// `X`/`P` choices (e.g. DITO = `evaluate_variant::<OdpGraded,
    /// TokenLedger>`). Runs with the default fast-exp base case (use
    /// [`evaluate`] with a [`DualTreeConfig`] for the toggle).
    ///
    /// [`evaluate`]: SweepEngine::evaluate
    pub fn evaluate_variant<X: Expansion, P: PruneRule>(
        &self,
        h: f64,
        epsilon: f64,
        plimit: Option<usize>,
    ) -> Result<GaussSumResult, AlgoError> {
        self.evaluate_variant_cfg::<X, P>(h, epsilon, plimit, true, SimdMode::Auto, Precision::F64)
    }

    /// Evaluate one bandwidth against an *explicit* query matrix: a
    /// query kd-tree is built for this call, while the reference tree,
    /// its node geometry and the per-bandwidth moment memo are all
    /// reused — the bichromatic form of the prepare-once contract.
    /// Results are bit-identical to a one-shot [`run_dualtree`] on the
    /// same (queries, references) problem with matching leaf size.
    pub fn evaluate_queries(
        &self,
        queries: &Matrix,
        leaf_size: usize,
        h: f64,
        epsilon: f64,
        cfg: &DualTreeConfig,
    ) -> Result<GaussSumResult, AlgoError> {
        assert_eq!(queries.cols(), self.dim, "query dimension mismatch");
        let qw = vec![1.0; queries.rows()];
        let (qtree, qsecs) = time_it(|| KdTree::build(queries, &qw, BuildParams { leaf_size }));
        let mut res = dispatch_variant!(cfg, X, P => {
            self.evaluate_variant_inner::<X, P>(
                &qtree,
                h,
                epsilon,
                cfg.plimit,
                cfg.fast_exp,
                cfg.simd,
                cfg.precision,
            )
        })?;
        res.stats.build_secs += qsecs;
        res.stats.tree_builds += 1;
        Ok(res)
    }

    /// Resolve the prepared query tree and run the traversal.
    fn evaluate_variant_cfg<X: Expansion, P: PruneRule>(
        &self,
        h: f64,
        epsilon: f64,
        plimit_override: Option<usize>,
        fast_exp: bool,
        simd: SimdMode,
        precision: Precision,
    ) -> Result<GaussSumResult, AlgoError> {
        let qt: &KdTree = self.qtree.as_ref().unwrap_or(&self.rtree);
        self.evaluate_variant_inner::<X, P>(
            qt,
            h,
            epsilon,
            plimit_override,
            fast_exp,
            simd,
            precision,
        )
    }

    /// The traversal core, parameterized over the query tree so both
    /// the prepared monochromatic/bichromatic trees and the per-call
    /// trees of [`evaluate_queries`] share one implementation.
    ///
    /// Scheduling: the query tree is cut into at most
    /// [`TRAVERSAL_TASKS`] disjoint subtree tasks (a function of the
    /// tree only), each task runs recursion → base-case drain →
    /// post-processing for its subtree on the shared pool, and the
    /// partial results are combined by an indexed reduction in fixed
    /// task order — so the sums and the merged stats are independent
    /// of the pool width and of work stealing.
    ///
    /// [`evaluate_queries`]: SweepEngine::evaluate_queries
    fn evaluate_variant_inner<X: Expansion, P: PruneRule>(
        &self,
        qt: &KdTree,
        h: f64,
        epsilon: f64,
        plimit_override: Option<usize>,
        fast_exp: bool,
        simd: SimdMode,
        precision: Precision,
    ) -> Result<GaussSumResult, AlgoError> {
        assert!(h > 0.0 && h.is_finite(), "bandwidth must be positive");
        assert!(epsilon > 0.0, "epsilon must be positive");
        let kernel = GaussianKernel::new(h);
        let dim = self.dim;
        // ε-budget split: reserve the certified fast-base-case error
        // (at the requested precision) out of the tree budget, or fall
        // back — f32 → f64 fast → bit-exact — when a bound is not
        // affordable at this bandwidth
        let split = split_epsilon_prec(
            epsilon,
            fast_exp,
            precision,
            dim,
            h,
            self.rtree.max_sq_norm().max(qt.max_sq_norm()),
        );
        let plimit = plimit_override.unwrap_or_else(|| plimit_for_dim(dim));
        let (moments, moment_secs, cache_hit) = match X::KIND {
            Some(kind) => {
                let (m, secs, hit) = self.moments_for(&kernel, kind, plimit);
                (Some(m), secs, hit)
            }
            None => (None, 0.0, false),
        };
        let rt: &KdTree = &self.rtree;
        let set_len = moments.as_ref().map_or(0, |m| m.set().len());
        let table_order = if set_len > 0 { 2 * plimit.max(1) } else { 1 };
        let total_w = self.total_w;
        let leaf_block = rt.max_leaf_count().max(1);

        let ctx = Ctx {
            qt,
            rt,
            kernel,
            eps: split.tree_eps,
            total_w,
            fast: split.fast,
            f32_tile: split.f32_tile,
            lanes: simd::select(simd),
            series: series_pack(&moments, plimit),
        };

        // Fixed decomposition: disjoint subtree roots covering every
        // query point, a function of the tree alone. Each root starts
        // with inherited_min = 0 (no ancestor bound), which only makes
        // prune tests more conservative — the ε guarantee holds.
        let roots = subtree_roots(qt, TRAVERSAL_TASKS);
        // Per-evaluate free list of task states: a task pops a recycled
        // State (ledger + Hermite workspace + Scratch arena, all sized
        // at prepare) or builds one on first use, and returns it after
        // draining — live States ≈ effective concurrency, not tasks.
        // Reuse is sound because tasks touch disjoint subtree slots.
        let states: SyncMutex<Vec<State>> = SyncMutex::new(Vec::new());
        let parts: Vec<(RunStats, Vec<f64>)> = self.pool.run_indexed(roots.len(), |k| {
            let q0 = roots[k];
            let mut st = states
                .lock()
                .unwrap()
                .pop()
                .unwrap_or_else(|| State::new(qt, set_len, dim, table_order, leaf_block));
            recurse::<X, P>(&ctx, &mut st, q0, rt.root(), 0.0);
            // this task's whole base-case queue drains in one grouped
            // pass before its post-processing
            drain_base_cases(&ctx, &mut st);
            let begin = qt.node(q0).begin;
            let mut out = vec![0.0; qt.node(q0).end - begin];
            postprocess_from::<X>(&ctx, &mut st, q0, begin, &mut out);
            let stats = std::mem::take(&mut st.stats);
            states.lock().unwrap().push(st);
            (stats, out)
        });

        // Indexed reduction: partials combine in fixed task order, so
        // the merged counters (f64 token sums included) are identical
        // however the tasks were scheduled.
        let mut tree_sums = vec![0.0; qt.num_points()];
        let mut stats = RunStats::default();
        for (&q0, (task_stats, out)) in roots.iter().zip(parts) {
            stats.merge(&task_stats);
            let node = qt.node(q0);
            tree_sums[node.begin..node.end].copy_from_slice(&out);
        }

        stats.build_secs = moment_secs;
        stats.moment_cache_hits = cache_hit as u64;
        stats.moment_cache_misses = (X::KIND.is_some() && !cache_hit) as u64;
        if split.fast {
            stats.simd_backend = ctx.lanes.backend.name();
        }
        let sums = qt.unpermute(&tree_sums);
        Ok(GaussSumResult { sums, stats })
    }

    /// Evaluate a whole bandwidth grid: grid points are scheduled as
    /// pool tasks, and each nests its own traversal tasks into the same
    /// pool (so a 2-point grid on an 8-worker pool still keeps every
    /// worker busy). Results come back in grid order, each bit-identical
    /// to a standalone [`evaluate`](SweepEngine::evaluate) at that h.
    pub fn evaluate_grid(
        &self,
        grid: &[f64],
        epsilon: f64,
        cfg: &DualTreeConfig,
    ) -> Result<Vec<GaussSumResult>, AlgoError> {
        self.pool
            .run_indexed(grid.len(), |k| self.evaluate(grid[k], epsilon, cfg))
            .into_iter()
            .collect()
    }
}

/// Borrow a [`SeriesPack`] out of the memoized moments.
fn series_pack(moments: &Option<Arc<RefMoments>>, plimit: usize) -> Option<SeriesPack<'_>> {
    moments.as_ref().map(|m| SeriesPack { moments: m.as_ref(), p_limit: plimit })
}

/// Pick ≥ `want` disjoint query-subtree roots that cover the whole
/// tree, repeatedly splitting the most populous splittable root (a
/// greedy balance heuristic). Returns fewer when the tree is shallow.
fn subtree_roots(qt: &KdTree, want: usize) -> Vec<usize> {
    let mut roots = vec![qt.root()];
    while roots.len() < want {
        let mut best: Option<(usize, usize)> = None; // (position, count)
        for (pos, &q) in roots.iter().enumerate() {
            if qt.children(q).is_some() {
                let c = qt.node(q).count();
                if best.map_or(true, |(_, bc)| c > bc) {
                    best = Some((pos, c));
                }
            }
        }
        match best {
            Some((pos, _)) => {
                let (l, r) = qt.children_of_internal(roots[pos]);
                roots[pos] = l;
                roots.push(r);
            }
            None => break, // all leaves
        }
    }
    roots.sort_by_key(|&q| qt.node(q).begin);
    roots
}

/// Run the dual-tree algorithm defined by `cfg` on `problem`: a
/// one-shot prepare + evaluate. For repeated evaluations on one dataset
/// (bandwidth sweeps, LSCV), hold a [`SweepEngine`] instead so the tree
/// is built once.
pub fn run_dualtree(
    problem: &GaussSumProblem<'_>,
    cfg: &DualTreeConfig,
) -> Result<GaussSumResult, AlgoError> {
    dispatch_variant!(cfg, X, P => {
        run_dualtree_variant::<X, P>(problem, cfg.leaf_size, cfg.plimit)
    })
}

/// One-shot prepare + evaluate of an explicit monomorphized variant —
/// the type-level form of [`run_dualtree`]. The four paper algorithms
/// are thin instantiations:
///
/// | algorithm | instantiation |
/// |---|---|
/// | DFD  | `run_dualtree_variant::<NoExpansion, Theorem2>`   |
/// | DFDO | `run_dualtree_variant::<NoExpansion, TokenLedger>`|
/// | DFTO | `run_dualtree_variant::<OpdGrid, TokenLedger>`    |
/// | DITO | `run_dualtree_variant::<OdpGraded, TokenLedger>`  |
pub fn run_dualtree_variant<X: Expansion, P: PruneRule>(
    problem: &GaussSumProblem<'_>,
    leaf_size: usize,
    plimit: Option<usize>,
) -> Result<GaussSumResult, AlgoError> {
    let engine = SweepEngine::prepare(problem, leaf_size);
    let mut res =
        engine.evaluate_variant_cfg::<X, P>(problem.h, problem.epsilon, plimit, true)?;
    // preserve the paper's "times include preprocessing" convention
    res.stats.build_secs += engine.build_secs();
    res.stats.tree_builds = engine.tree_builds();
    Ok(res)
}

/// The main recursion (paper Fig. 7), monomorphized per variant: all
/// `X::ENABLED` / `P::USE_TOKENS` tests below are compile-time
/// constants, so each instantiation's hot loop is branch-free on the
/// algorithm switches.
fn recurse<X: Expansion, P: PruneRule>(
    ctx: &Ctx<'_>,
    st: &mut State,
    q: usize,
    r: usize,
    inherited_min: f64,
) {
    st.stats.node_pairs += 1;
    let qn = ctx.qt.node(q);
    let rn = ctx.rt.node(r);
    let dmin = qn.min_dist(rn);
    let dmax = qn.max_dist(rn);
    let ku = ctx.kernel.eval(dmin); // largest possible kernel value
    let kl = ctx.kernel.eval(dmax); // smallest possible kernel value
    let wr = rn.weight;
    let dl = wr * kl;
    let du = wr * (ku - 1.0);
    let gq_min = st.ledger.gq_min(q, inherited_min);

    // ---- finite-difference prune (optimized rule first, Fig. 7) ----
    let e_fd = 0.5 * wr * (ku - kl);
    match P::decide(e_fd, wr, st.ledger.tokens[q], gq_min, ctx.eps, ctx.total_w) {
        PruneDecision::Accept { token_delta } => {
            apply_tokens(st, q, token_delta);
            st.ledger.node_min[q] += dl;
            st.ledger.node_max[q] += du;
            st.ledger.node_est[q] += 0.5 * wr * (ku + kl);
            st.stats.fd_prunes += 1;
            return;
        }
        PruneDecision::Reject => {}
    }

    // ---- FMM-type prune (series variants only; compiled out when
    //      X::ENABLED is false) ----
    if X::ENABLED {
        let series = ctx.series();
        if gq_min > 0.0 {
            let budget_w = wr + if P::USE_TOKENS { st.ledger.tokens[q] } else { 0.0 };
            let max_err = ctx.eps * budget_w * gq_min / ctx.total_w;
            let geo = NodeGeometry {
                dim: ctx.qt.dim(),
                min_sqdist: dmin * dmin,
                r_ref: rn.linf_radius / ctx.kernel.bandwidth(),
                r_query: qn.linf_radius / ctx.kernel.bandwidth(),
                h: ctx.kernel.bandwidth(),
            };
            let cm = CostModel { set: series.moments.set(), p_limit: series.p_limit };
            let choice = cm.best_method(&X::BOUNDS, &geo, wr, max_err, qn.count(), rn.count());
            if choice != Choice::Direct {
                let err = match choice {
                    Choice::DH { p, err } => {
                        let set = series.moments.set();
                        let coeffs = series.moments.node_coeffs(r);
                        for qi in qn.begin..qn.end {
                            st.ledger.point_est[qi] += eval_farfield_truncated(
                                set,
                                p,
                                coeffs,
                                &rn.centroid,
                                series.moments.scale(),
                                ctx.qt.points().row(qi),
                                &mut st.table,
                                &mut st.off,
                            );
                        }
                        st.stats.dh_prunes += 1;
                        err
                    }
                    Choice::DL { p, err } => {
                        let set = series.moments.set();
                        let lc = &mut st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                        accumulate_local_truncated(
                            set,
                            p,
                            ctx.rt.points(),
                            rn.begin..rn.end,
                            ctx.rt.weights(),
                            &qn.centroid,
                            series.moments.scale(),
                            lc,
                            &mut st.table,
                            &mut st.off,
                        );
                        st.stats.dl_prunes += 1;
                        err
                    }
                    Choice::H2L { p, err } => {
                        let set = series.moments.set();
                        let lc = &mut st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                        h2l_truncated(
                            set,
                            p,
                            series.moments.node_coeffs(r),
                            &rn.centroid,
                            &qn.centroid,
                            series.moments.scale(),
                            lc,
                            &mut st.table,
                            &mut st.off,
                        );
                        st.stats.h2l_prunes += 1;
                        err
                    }
                    // lint: allow(no-panic): the prune arm only runs when bestMethod chose a series form
                    Choice::Direct => unreachable!(),
                };
                // account the accepted error against the ledger
                match P::decide(err, wr, st.ledger.tokens[q], gq_min, ctx.eps, ctx.total_w) {
                    PruneDecision::Accept { token_delta } => apply_tokens(st, q, token_delta),
                    // feasibility guaranteed by max_err construction
                    // lint: allow(no-panic): feasibility is guaranteed by the max_err construction above
                    PruneDecision::Reject => unreachable!("bestMethod returned infeasible"),
                }
                st.ledger.node_min[q] += dl;
                st.ledger.node_max[q] += du;
                return;
            }
        }
    }

    // ---- expand ----
    match (qn.is_leaf(), rn.is_leaf()) {
        (true, true) => {
            // Exhaustive base case, deferred: register the pair's exact
            // bounds now (dl/du from the libm kernel at dmax/dmin, like
            // an FD prune) and bank the full token entitlement — the
            // sums are exact up to the drained kernel's certified
            // error, which split_epsilon already reserved — then queue
            // the pair for the grouped tile drain. G_Q^min only ever
            // reads these exact bounds, never the approximate sums, so
            // later prune tests stay sound (if a little conservative:
            // wr·kl in place of the computed per-point minima the
            // eager base case used to register).
            st.ledger.node_min[q] += dl;
            st.ledger.node_max[q] += du;
            if P::USE_TOKENS {
                st.ledger.tokens[q] += wr;
                st.stats.tokens_banked += wr;
            }
            st.stats.base_point_pairs += (qn.count() * rn.count()) as u64;
            st.queue.push((q as u32, r as u32));
        }
        (true, false) => {
            // split reference side, nearer child first (tightens G_Q^min
            // before the farther child is considered)
            let (a, b) = ctx.rt.children_of_internal(r);
            let (near, far) = order_by_dist(ctx.qt.node(q), ctx.rt, a, b);
            recurse::<X, P>(ctx, st, q, near, inherited_min);
            recurse::<X, P>(ctx, st, q, far, inherited_min);
        }
        (false, true) => {
            let (l, rr) = ctx.qt.children_of_internal(q);
            let inh = inherited_min + st.ledger.node_min[q];
            recurse::<X, P>(ctx, st, l, r, inh);
            recurse::<X, P>(ctx, st, rr, r, inh);
            st.ledger.refresh_below_from_children(q, l, rr);
        }
        (false, false) => {
            let (ql, qr) = ctx.qt.children_of_internal(q);
            let inh = inherited_min + st.ledger.node_min[q];
            for qc in [ql, qr] {
                let (a, b) = ctx.rt.children_of_internal(r);
                let (near, far) = order_by_dist(ctx.qt.node(qc), ctx.rt, a, b);
                recurse::<X, P>(ctx, st, qc, near, inh);
                recurse::<X, P>(ctx, st, qc, far, inh);
            }
            st.ledger.refresh_below_from_children(q, ql, qr);
        }
    }
}

fn apply_tokens(st: &mut State, q: usize, delta: f64) {
    if delta >= 0.0 {
        st.stats.tokens_banked += delta;
    } else {
        st.stats.tokens_spent += -delta;
    }
    st.ledger.tokens[q] += delta;
}

fn order_by_dist(qn: &crate::tree::Node, rt: &KdTree, a: usize, b: usize) -> (usize, usize) {
    if qn.min_dist(rt.node(a)) <= qn.min_dist(rt.node(b)) {
        (a, b)
    } else {
        (b, a)
    }
}

/// Drain the deferred leaf–leaf base cases (paper's DITOBase), grouped
/// by reference leaf: each reference leaf is transposed into the
/// task's [`Scratch`] exactly once per drain and reused by every
/// query leaf that hit it. With `ctx.fast` the Q×R tile runs the
/// GEMM-shaped kernel (cached norms outer sum − 2·dot, fused certified
/// `exp_block` — see [`crate::compute::tile`]) on the evaluate's
/// resolved SIMD lane table; with `ctx.f32_tile` it runs the
/// mixed-precision f32 variant instead, whose larger certified bound
/// `split_epsilon_prec` already reserved; otherwise each query
/// runs the bit-exact fused distance → libm-exp → accumulate sweep,
/// whose per-pair arithmetic matches the pre-queue scalar loop exactly.
/// Sums land in `point_est` only — bounds and tokens were already
/// registered at enqueue time.
fn drain_base_cases(ctx: &Ctx<'_>, st: &mut State) {
    if st.queue.is_empty() {
        return;
    }
    // group by reference leaf; ascending query order within a group
    // keeps the drain deterministic for a fixed traversal
    st.queue.sort_unstable_by_key(|&(q, r)| (r, q));
    let State { queue, scratch, ledger, stats, .. } = st;
    let (qt, rt) = (ctx.qt, ctx.rt);
    let mut cur_r = u32::MAX;
    for &(q, r) in queue.iter() {
        let rn = rt.node(r as usize);
        if r != cur_r {
            if ctx.f32_tile {
                scratch.load_f32(rt.points(), rn.begin, rn.end);
                scratch.load_weights_f32(rt.weights(), rn.begin, rn.end);
                scratch.load_ref_norms_f32(rt.sq_norms_f32(), rn.begin, rn.end);
            } else {
                scratch.load(rt.points(), rn.begin, rn.end);
                scratch.load_weights(rt.weights(), rn.begin, rn.end);
                if ctx.fast {
                    scratch.load_ref_norms(rt.sq_norms(), rn.begin, rn.end);
                }
            }
            cur_r = r;
        }
        let qn = qt.node(q as usize);
        if ctx.f32_tile {
            tile::gauss_sums_fast_f32_on_loaded(
                scratch,
                &ctx.kernel,
                qt.points(),
                qt.sq_norms(),
                qn.begin,
                qn.end,
                &mut ledger.point_est[qn.begin..qn.end],
                ctx.lanes,
            );
            stats.f32_base_cases += 1;
        } else if ctx.fast {
            tile::gauss_sums_fast_on_loaded(
                scratch,
                &ctx.kernel,
                qt.points(),
                qt.sq_norms(),
                qn.begin,
                qn.end,
                &mut ledger.point_est[qn.begin..qn.end],
                ctx.lanes,
            );
            stats.fast_base_cases += 1;
        } else {
            for qi in qn.begin..qn.end {
                ledger.point_est[qi] += scratch.gauss_dot(&ctx.kernel, qt.points().row(qi));
            }
            stats.exact_base_cases += 1;
        }
    }
    queue.clear();
}

/// Post-processing (paper Fig. 8): push node-level estimates and local
/// expansions down the query subtree rooted at `start` (L2L), then
/// evaluate at leaf points, writing per-point sums into `out`, which
/// covers exactly `start`'s point range — tree index `qi` lands at
/// `out[qi - base]` (each pool task owns a private slice of the final
/// buffer, so tasks never write through shared memory).
fn postprocess_from<X: Expansion>(
    ctx: &Ctx<'_>,
    st: &mut State,
    start: usize,
    base: usize,
    out: &mut [f64],
) {
    let qt = ctx.qt;
    // BFS order: parents processed before children.
    let mut queue = std::collections::VecDeque::from([start]);
    while let Some(q) = queue.pop_front() {
        if let Some((l, r)) = qt.children(q) {
            let est = st.ledger.node_est[q];
            st.ledger.node_est[l] += est;
            st.ledger.node_est[r] += est;
            if X::ENABLED {
                let series = ctx.series();
                let set = series.moments.set();
                let pairs = series.moments.pairs();
                let scale = series.moments.scale();
                let len = st.set_len;
                for child in [l, r] {
                    // split-borrow the node-major lcoeffs buffer
                    let (parent_part, child_part) =
                        split_blocks(&mut st.lcoeffs, q, child, len);
                    l2l(
                        set,
                        pairs,
                        parent_part,
                        &qt.node(q).centroid,
                        &qt.node(child).centroid,
                        scale,
                        child_part,
                        &mut st.mono,
                        &mut st.off,
                    );
                }
            }
            queue.push_back(l);
            queue.push_back(r);
        } else {
            let node_est = st.ledger.node_est[q];
            for qi in qt.node(q).begin..qt.node(q).end {
                let mut v = st.ledger.point_est[qi] + node_est;
                if X::ENABLED {
                    let series = ctx.series();
                    let set = series.moments.set();
                    let lc = &st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                    v += eval_local(
                        set,
                        lc,
                        &qt.node(q).centroid,
                        series.moments.scale(),
                        qt.points().row(qi),
                        &mut st.mono,
                        &mut st.off,
                    );
                }
                out[qi - base] = v;
            }
        }
    }
}

/// Disjoint (&parent, &mut child) blocks of a node-major buffer.
fn split_blocks(buf: &mut [f64], parent: usize, child: usize, len: usize) -> (&[f64], &mut [f64]) {
    assert_ne!(parent, child);
    if parent < child {
        let (lo, hi) = buf.split_at_mut(child * len);
        (&lo[parent * len..(parent + 1) * len], &mut hi[..len])
    } else {
        let (lo, hi) = buf.split_at_mut(parent * len);
        (&hi[..len], &mut lo[child * len..(child + 1) * len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::{max_relative_error, GaussSum};
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn clustered(n: usize, d: usize, seed: u64) -> Matrix {
        // a few Gaussian blobs — the regime dual trees exploit
        let mut rng = Pcg32::new(seed);
        let k = 4;
        let centers: Vec<Vec<f64>> =
            (0..k).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
        Matrix::from_rows(
            &(0..n)
                .map(|i| {
                    let c = &centers[i % k];
                    (0..d).map(|j| c[j] + 0.05 * rng.normal()).collect()
                })
                .collect::<Vec<_>>(),
        )
    }

    fn check_config(cfg: DualTreeConfig, n: usize, d: usize, h: f64, eps: f64, seed: u64) {
        let data = clustered(n, d, seed);
        let problem = GaussSumProblem::kde(&data, h, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &cfg).unwrap();
        let rel = max_relative_error(&got.sums, &exact);
        assert!(
            rel <= eps * (1.0 + 1e-9),
            "cfg={cfg:?} d={d} h={h}: rel={rel} > eps={eps}"
        );
    }

    #[test]
    fn dfd_style_meets_tolerance() {
        let cfg = DualTreeConfig { use_tokens: false, series: None, ..Default::default() };
        for h in [0.01, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 71);
        }
    }

    #[test]
    fn tokens_only_meets_tolerance() {
        let cfg = DualTreeConfig { use_tokens: true, series: None, ..Default::default() };
        for h in [0.01, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 72);
        }
    }

    #[test]
    fn odp_series_meets_tolerance_2d() {
        let cfg = DualTreeConfig::default(); // tokens + OdpGraded
        for h in [0.02, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 73);
        }
    }

    #[test]
    fn opd_series_meets_tolerance_2d() {
        let cfg =
            DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() };
        for h in [0.02, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 74);
        }
    }

    #[test]
    fn higher_dims_meet_tolerance() {
        for d in [3, 5, 7] {
            let cfg = DualTreeConfig::default();
            check_config(cfg, 300, d, 0.3, 0.01, 75);
        }
    }

    #[test]
    fn tight_epsilon_still_met() {
        check_config(DualTreeConfig::default(), 300, 2, 0.2, 1e-4, 76);
    }

    #[test]
    fn loose_epsilon_prunes_more() {
        let data = clustered(500, 2, 77);
        let loose = GaussSumProblem::kde(&data, 0.3, 0.5);
        let tight = GaussSumProblem::kde(&data, 0.3, 1e-6);
        let cfg = DualTreeConfig::default();
        let a = run_dualtree(&loose, &cfg).unwrap();
        let b = run_dualtree(&tight, &cfg).unwrap();
        assert!(
            a.stats.base_point_pairs < b.stats.base_point_pairs,
            "loose={} tight={}",
            a.stats.base_point_pairs,
            b.stats.base_point_pairs
        );
    }

    #[test]
    fn bichromatic_queries_differ_from_refs() {
        let mut rng = Pcg32::new(78);
        let refs = clustered(300, 2, 79);
        let queries = Matrix::from_rows(
            &(0..50)
                .map(|_| (0..2).map(|_| rng.uniform()).collect())
                .collect::<Vec<_>>(),
        );
        let w: Vec<f64> = (0..300).map(|_| rng.uniform_in(0.5, 1.5)).collect();
        let problem = GaussSumProblem::new(&queries, &refs, Some(&w), 0.2, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9));
    }

    #[test]
    fn stats_account_all_prune_types_in_2d() {
        // moderate bandwidth → FMM (series) prunes dominate
        let data = clustered(800, 2, 80);
        let problem = GaussSumProblem::kde(&data, 0.5, 0.01);
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(
            got.stats.dh_prunes + got.stats.dl_prunes + got.stats.h2l_prunes > 0,
            "series prunes expected: {:?}",
            got.stats
        );
        assert!(got.stats.tokens_banked > 0.0);
        assert!(got.stats.tokens_spent > 0.0);
        // tiny bandwidth → distant pairs have e_FD ≈ 0 → FD prunes fire
        let problem2 = GaussSumProblem::kde(&data, 0.005, 0.01);
        let got2 = run_dualtree(&problem2, &DualTreeConfig::default()).unwrap();
        assert!(got2.stats.fd_prunes > 0, "{:?}", got2.stats);
    }

    #[test]
    fn duplicate_heavy_data_is_handled() {
        // many identical points stress zero-width nodes
        let mut rows = vec![vec![0.25, 0.25]; 100];
        rows.extend(vec![vec![0.75, 0.75]; 100]);
        let data = Matrix::from_rows(&rows);
        let problem = GaussSumProblem::kde(&data, 0.1, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9));
    }

    #[test]
    fn extreme_bandwidths() {
        let data = clustered(300, 3, 81);
        for h in [1e-4, 1e3] {
            let problem = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&problem).unwrap().sums;
            let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
            assert!(
                max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "h={h}"
            );
        }
    }

    // ---- monomorphized variants ----

    #[test]
    fn monomorphized_variants_match_config_dispatch_bitwise() {
        // the runtime-switch interface must resolve to exactly the same
        // monomorphized code as the explicit type instantiation
        fn check(
            problem: &GaussSumProblem<'_>,
            cfg: DualTreeConfig,
            via_type: GaussSumResult,
        ) {
            let via_cfg = run_dualtree(problem, &cfg).unwrap();
            assert_eq!(via_cfg.sums, via_type.sums, "h={} cfg={cfg:?}", problem.h);
            assert_eq!(
                via_cfg.stats.base_point_pairs, via_type.stats.base_point_pairs,
                "h={} cfg={cfg:?}",
                problem.h
            );
        }
        let data = clustered(350, 2, 89);
        for h in [0.05, 0.4, 3.0] {
            let p = GaussSumProblem::kde(&data, h, 0.01);
            check(
                &p,
                DualTreeConfig { use_tokens: false, series: None, ..Default::default() },
                run_dualtree_variant::<NoExpansion, Theorem2>(&p, 32, None).unwrap(),
            );
            check(
                &p,
                DualTreeConfig { use_tokens: true, series: None, ..Default::default() },
                run_dualtree_variant::<NoExpansion, TokenLedger>(&p, 32, None).unwrap(),
            );
            check(
                &p,
                DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() },
                run_dualtree_variant::<OpdGrid, TokenLedger>(&p, 32, None).unwrap(),
            );
            check(
                &p,
                DualTreeConfig::default(),
                run_dualtree_variant::<OdpGraded, TokenLedger>(&p, 32, None).unwrap(),
            );
        }
    }

    #[test]
    fn theorem2_with_series_is_a_valid_variant() {
        // the two ablation-only combinations (series without tokens)
        // must also meet the guarantee
        let data = clustered(400, 2, 90);
        let problem = GaussSumProblem::kde(&data, 0.3, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let a = run_dualtree_variant::<OdpGraded, Theorem2>(&problem, 32, None).unwrap();
        let b = run_dualtree_variant::<OpdGrid, Theorem2>(&problem, 32, None).unwrap();
        assert!(max_relative_error(&a.sums, &exact) <= 0.01 * (1.0 + 1e-9));
        assert!(max_relative_error(&b.sums, &exact) <= 0.01 * (1.0 + 1e-9));
        assert_eq!(a.stats.tokens_banked, 0.0);
        assert_eq!(a.stats.tokens_spent, 0.0);
    }

    // ---- SweepEngine ----

    #[test]
    fn engine_single_thread_matches_run_dualtree_bitwise() {
        let data = clustered(400, 2, 82);
        let engine = SweepEngine::for_kde(&data, 32);
        let cfg = DualTreeConfig::default();
        for h in [0.01, 0.1, 1.0, 10.0] {
            let problem = GaussSumProblem::kde(&data, h, 0.01);
            let a = run_dualtree(&problem, &cfg).unwrap();
            let b = engine.evaluate(h, 0.01, &cfg).unwrap();
            assert_eq!(a.sums, b.sums, "h={h}: prepared engine diverged");
        }
        assert_eq!(engine.tree_builds(), 1);
    }

    #[test]
    fn engine_parallel_meets_tolerance_all_variants() {
        let data = clustered(600, 2, 83);
        let engine = SweepEngine::for_kde(&data, 16).with_threads(4);
        let variants = [
            DualTreeConfig { use_tokens: false, series: None, ..Default::default() },
            DualTreeConfig { use_tokens: true, series: None, ..Default::default() },
            DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() },
            DualTreeConfig::default(),
        ];
        for h in [0.02, 0.3, 3.0] {
            let problem = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&problem).unwrap().sums;
            for cfg in &variants {
                let got = engine.evaluate(h, 0.01, cfg).unwrap();
                let rel = max_relative_error(&got.sums, &exact);
                assert!(rel <= 0.01 * (1.0 + 1e-9), "h={h} cfg={cfg:?}: rel={rel}");
            }
        }
        assert_eq!(engine.tree_builds(), 1);
    }

    #[test]
    fn engine_grid_matches_individual_evaluates() {
        let data = clustered(300, 2, 84);
        let engine = SweepEngine::for_kde(&data, 32).with_threads(3);
        let cfg = DualTreeConfig::default();
        let grid = [0.05, 0.2, 0.8, 3.2];
        let batch = engine.evaluate_grid(&grid, 0.01, &cfg).unwrap();
        assert_eq!(batch.len(), grid.len());
        for (res, &h) in batch.iter().zip(&grid) {
            let single = engine.evaluate(h, 0.01, &cfg).unwrap();
            assert_eq!(res.sums, single.sums, "h={h}");
        }
    }

    /// The pool-width invariance that the batch ≡ sequential and
    /// sweep-bit-identity guarantees rest on: the fixed subtree
    /// decomposition + indexed reduction make every evaluate
    /// bit-identical whether the pool is inline or 8 workers wide —
    /// down to the f64 token counters merged across tasks.
    #[test]
    fn evaluate_bitwise_identical_across_pool_widths() {
        let data = clustered(500, 2, 97);
        let variants = [
            DualTreeConfig { use_tokens: false, series: None, ..Default::default() },
            DualTreeConfig::default(),
        ];
        for cfg in &variants {
            for h in [0.03, 0.3] {
                let base_engine = SweepEngine::for_kde(&data, 16); // inline pool
                let base = base_engine.evaluate(h, 0.01, cfg).unwrap();
                for threads in [2, 8] {
                    let engine = SweepEngine::for_kde(&data, 16).with_threads(threads);
                    let got = engine.evaluate(h, 0.01, cfg).unwrap();
                    assert_eq!(got.sums, base.sums, "threads={threads} h={h}");
                    assert_eq!(got.stats.node_pairs, base.stats.node_pairs);
                    assert_eq!(got.stats.base_point_pairs, base.stats.base_point_pairs);
                    assert_eq!(
                        got.stats.tokens_banked.to_bits(),
                        base.stats.tokens_banked.to_bits(),
                        "stats reduction must be order-fixed (threads={threads} h={h})"
                    );
                }
            }
        }
    }

    #[test]
    fn engine_moment_cache_hits_on_repeat_bandwidth() {
        let data = clustered(200, 2, 85);
        let engine = SweepEngine::for_kde(&data, 32);
        let cfg = DualTreeConfig::default();
        let first = engine.evaluate(0.3, 0.01, &cfg).unwrap();
        let second = engine.evaluate(0.3, 0.01, &cfg).unwrap();
        assert_eq!(first.sums, second.sums);
        // cached moments → no recompute time attributed to the second run
        assert_eq!(second.stats.build_secs, 0.0);
        assert!(first.stats.build_secs > 0.0);
        assert_eq!(first.stats.moment_cache_misses, 1);
        assert_eq!(second.stats.moment_cache_hits, 1);
        assert_eq!(engine.moment_cache_stats(), (1, 1));
    }

    #[test]
    fn engine_moment_cache_is_bounded_with_lru_eviction() {
        let data = clustered(200, 2, 91);
        let engine = SweepEngine::for_kde(&data, 32).with_moment_cache_capacity(2);
        let cfg = DualTreeConfig::default();
        let baseline = engine.evaluate(0.1, 0.01, &cfg).unwrap();
        engine.evaluate(0.2, 0.01, &cfg).unwrap();
        assert_eq!(engine.moment_cache_len(), 2);
        // third distinct h evicts the least recently used (h = 0.1)
        engine.evaluate(0.4, 0.01, &cfg).unwrap();
        assert_eq!(engine.moment_cache_len(), 2);
        let again = engine.evaluate(0.1, 0.01, &cfg).unwrap();
        assert_eq!(again.stats.moment_cache_misses, 1, "evicted entry must recompute");
        assert_eq!(again.sums, baseline.sums, "eviction must not change results");
        // h = 0.4 survived the h = 0.1 re-insert (it evicted h = 0.2,
        // the least recently used remaining)
        let warm = engine.evaluate(0.4, 0.01, &cfg).unwrap();
        assert_eq!(warm.stats.moment_cache_hits, 1);
        let (hits, misses) = engine.moment_cache_stats();
        assert_eq!((hits, misses), (1, 4));
        // the documented escape hatch drops everything
        engine.clear_moment_cache();
        assert_eq!(engine.moment_cache_len(), 0);
        let cold = engine.evaluate(0.4, 0.01, &cfg).unwrap();
        assert_eq!(cold.stats.moment_cache_misses, 1);
    }

    /// Regression for the advertised-but-absent LRU behavior: the cache
    /// claimed recency eviction yet never refreshed recency on hit, so
    /// a hot entry could be evicted by cold grid churn. A hit must
    /// promote: after touching h = 0.1, inserting a third bandwidth
    /// evicts h = 0.2 (the true LRU), not h = 0.1 (the oldest insert).
    #[test]
    fn moment_cache_hit_promotes_recency() {
        let data = clustered(200, 2, 94);
        let engine = SweepEngine::for_kde(&data, 32).with_moment_cache_capacity(2);
        let cfg = DualTreeConfig::default();
        engine.evaluate(0.1, 0.01, &cfg).unwrap(); // miss, insert 0.1
        engine.evaluate(0.2, 0.01, &cfg).unwrap(); // miss, insert 0.2
        let touch = engine.evaluate(0.1, 0.01, &cfg).unwrap(); // hit → promote
        assert_eq!(touch.stats.moment_cache_hits, 1);
        engine.evaluate(0.4, 0.01, &cfg).unwrap(); // miss → evicts 0.2, NOT 0.1
        let hot = engine.evaluate(0.1, 0.01, &cfg).unwrap();
        assert_eq!(
            hot.stats.moment_cache_hits, 1,
            "hit must have promoted h = 0.1 past insertion-order eviction"
        );
        let cold = engine.evaluate(0.2, 0.01, &cfg).unwrap();
        assert_eq!(cold.stats.moment_cache_misses, 1, "h = 0.2 was the true LRU victim");
        // lifetime counters stay exact across promotions:
        // hits = {touch 0.1, hot 0.1}; misses = {0.1, 0.2, 0.4, 0.2}
        assert_eq!(engine.moment_cache_stats(), (2, 4));
    }

    #[test]
    fn fast_and_exact_base_case_routing() {
        let data = clustered(400, 2, 95);
        let engine = SweepEngine::for_kde(&data, 32);
        // small-ish h so real leaf-leaf work survives pruning
        let on = engine.evaluate(0.05, 1e-4, &DualTreeConfig::default()).unwrap();
        assert!(on.stats.fast_base_cases > 0, "{:?}", on.stats);
        assert_eq!(on.stats.exact_base_cases, 0);
        let off = engine
            .evaluate(0.05, 1e-4, &DualTreeConfig { fast_exp: false, ..Default::default() })
            .unwrap();
        assert!(off.stats.exact_base_cases > 0, "{:?}", off.stats);
        assert_eq!(off.stats.fast_base_cases, 0);
        // both modes meet ε against exhaustive truth
        let problem = GaussSumProblem::kde(&data, 0.05, 1e-4);
        let exact = Naive::new().run(&problem).unwrap().sums;
        for sums in [&on.sums, &off.sums] {
            assert!(max_relative_error(sums, &exact) <= 1e-4 * (1.0 + 1e-9));
        }
        // and agree with each other to the certified reservation
        let dev = on
            .sums
            .iter()
            .zip(&off.sums)
            .map(|(a, b)| (a - b).abs() / b.max(1e-300))
            .fold(0.0f64, f64::max);
        assert!(dev <= 2.1e-4, "fast vs exact diverged by {dev:.2e}");
    }

    #[test]
    fn tiny_bandwidth_auto_falls_back_to_exact_base_case() {
        // at h = 1e-7 the certified norms-trick bound exceeds ε/4, so
        // even with fast_exp requested the drain must run bit-exact
        // (FD-only engine: no point computing a degenerate moment table
        // at a bandwidth where series prunes can never fire)
        let data = clustered(300, 2, 96);
        let engine = SweepEngine::for_kde(&data, 32);
        let res = engine
            .evaluate(1e-7, 1e-6, &DualTreeConfig { series: None, ..Default::default() })
            .unwrap();
        assert_eq!(res.stats.fast_base_cases, 0, "{:?}", res.stats);
        // (prunes may absorb everything at extreme h; the invariant is
        // that nothing routed through the fast kernel)
    }

    #[test]
    fn shrinking_moment_cache_capacity_evicts_immediately() {
        let data = clustered(150, 2, 93);
        let engine = SweepEngine::for_kde(&data, 32);
        let cfg = DualTreeConfig::default();
        for h in [0.1, 0.2, 0.4, 0.8] {
            engine.evaluate(h, 0.01, &cfg).unwrap();
        }
        assert_eq!(engine.moment_cache_len(), 4);
        let engine = engine.with_moment_cache_capacity(2);
        assert_eq!(engine.moment_cache_len(), 2, "shrink must release entries immediately");
        // the two newest entries (h = 0.4, 0.8) survive
        assert_eq!(engine.evaluate(0.8, 0.01, &cfg).unwrap().stats.moment_cache_hits, 1);
        assert_eq!(engine.evaluate(0.1, 0.01, &cfg).unwrap().stats.moment_cache_misses, 1);
    }

    #[test]
    fn fd_only_variants_skip_the_moment_cache() {
        let data = clustered(150, 2, 92);
        let engine = SweepEngine::for_kde(&data, 32);
        let cfg = DualTreeConfig { series: None, ..Default::default() };
        let res = engine.evaluate(0.3, 0.01, &cfg).unwrap();
        assert_eq!(res.stats.moment_cache_hits + res.stats.moment_cache_misses, 0);
        assert_eq!(engine.moment_cache_stats(), (0, 0));
        assert_eq!(engine.moment_cache_len(), 0);
    }

    #[test]
    fn engine_bichromatic_parallel() {
        let mut rng = Pcg32::new(86);
        let refs = clustered(300, 2, 87);
        let queries = Matrix::from_rows(
            &(0..120).map(|_| (0..2).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let w: Vec<f64> = (0..300).map(|_| rng.uniform_in(0.5, 1.5)).collect();
        let problem = GaussSumProblem::new(&queries, &refs, Some(&w), 0.2, 0.01);
        let engine = SweepEngine::prepare(&problem, 16).with_threads(3);
        assert_eq!(engine.tree_builds(), 2);
        assert!(!engine.is_monochromatic());
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = engine.evaluate(0.2, 0.01, &DualTreeConfig::default()).unwrap();
        assert!(max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9));
    }

    #[test]
    fn subtree_roots_partition_points() {
        let data = clustered(500, 3, 88);
        let engine = SweepEngine::for_kde(&data, 8);
        let qt = &engine.rtree;
        for want in [1, 2, 5, 16] {
            let roots = subtree_roots(qt, want);
            assert!(!roots.is_empty());
            // contiguous, disjoint, covering [0, n)
            let mut cursor = 0;
            for &q in &roots {
                assert_eq!(qt.node(q).begin, cursor, "gap before node {q}");
                cursor = qt.node(q).end;
            }
            assert_eq!(cursor, qt.num_points());
            if want > 1 {
                assert!(roots.len() >= want.min(2));
            }
        }
    }
}
