//! The shared dual-tree engine behind DFD, DFDO, DFTO and DITO.
//!
//! One recursion (paper Fig. 7), parameterized by:
//! * `use_tokens` — plain Theorem-2 rule (DFD) vs the W_T token ledger
//!   (DFDO/DFTO/DITO);
//! * `series` — `None` (finite difference only) or an expansion family:
//!   O(Dᵖ) graded + Lemma 4–6 bounds (DITO) or O(pᴰ) grid + geometric
//!   bounds (DFTO).
//!
//! Correctness architecture: per-query-node state lives in a
//! [`QueryLedger`]; bounds are hierarchical (summed along the root→leaf
//! path) with the ancestor part carried down the recursion as
//! `inherited_min` and the subtree part cached in `below_min` — see
//! `errorcontrol` for the soundness argument. Approximation results are
//! either per-point (base cases, EVALM) or node-level (FD estimates in
//! `node_est`, local Taylor coefficients in `lcoeffs`), and the
//! post-processing pass (paper Fig. 8) pushes node-level state down with
//! the **L2L** operator and evaluates local expansions at the leaves.

use crate::bounds::{odp::OdpBounds, opd::OpdBounds, NodeGeometry, TruncationBounds};
use crate::errorcontrol::{token_rule, PruneDecision, QueryLedger};
use crate::hermite::{
    accumulate_local_truncated, eval_farfield_truncated, eval_local, h2l_truncated, l2l,
    HermiteTable,
};
use crate::kernel::GaussianKernel;
use crate::multiindex::Layout;
use crate::tree::{plimit_for_dim, BuildParams, KdTree, RefMoments};
use crate::util::timer::time_it;

use super::bestmethod::{Choice, CostModel};
use super::{AlgoError, GaussSumProblem, GaussSumResult, RunStats};

/// Expansion family for FMM-type pruning.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SeriesKind {
    /// O(Dᵖ) graded expansion with the paper's Lemma 4–6 bounds (DITO).
    OdpGraded,
    /// O(pᴰ) grid expansion with geometric-series bounds (DFTO).
    OpdGrid,
}

impl SeriesKind {
    fn layout(self) -> Layout {
        match self {
            SeriesKind::OdpGraded => Layout::Graded,
            SeriesKind::OpdGrid => Layout::Grid,
        }
    }
}

/// Engine configuration; the four public algorithms are fixed settings
/// of this struct.
#[derive(Copy, Clone, Debug)]
pub struct DualTreeConfig {
    /// Tree leaf size.
    pub leaf_size: usize,
    /// Enable the W_T token ledger (the paper's improved error control).
    pub use_tokens: bool,
    /// FMM-type pruning family, or `None` for finite-difference only.
    pub series: Option<SeriesKind>,
    /// Override the PLIMIT schedule (`None` = paper's per-D schedule).
    pub plimit: Option<usize>,
}

impl Default for DualTreeConfig {
    fn default() -> Self {
        DualTreeConfig {
            leaf_size: 32,
            use_tokens: true,
            series: Some(SeriesKind::OdpGraded),
            plimit: None,
        }
    }
}

/// Immutable per-run context.
struct Ctx<'a> {
    qt: &'a KdTree,
    rt: &'a KdTree,
    kernel: GaussianKernel,
    eps: f64,
    total_w: f64,
    use_tokens: bool,
    series: Option<SeriesPack<'a>>,
}

struct SeriesPack<'a> {
    moments: &'a RefMoments,
    bounds: &'a dyn TruncationBounds,
    p_limit: usize,
}

/// Mutable per-run state.
struct State {
    ledger: QueryLedger,
    /// Local Taylor coefficients per query node (node-major), when a
    /// series family is active.
    lcoeffs: Vec<f64>,
    set_len: usize,
    table: HermiteTable,
    mono: Vec<f64>,
    off: Vec<f64>,
    stats: RunStats,
}

/// Run the dual-tree algorithm defined by `cfg` on `problem`.
pub fn run_dualtree(
    problem: &GaussSumProblem<'_>,
    cfg: &DualTreeConfig,
) -> Result<GaussSumResult, AlgoError> {
    let weights = problem.weight_vec();
    let params = BuildParams { leaf_size: cfg.leaf_size };
    let kernel = GaussianKernel::new(problem.h);
    let dim = problem.dim();
    let plimit = cfg.plimit.unwrap_or_else(|| plimit_for_dim(dim));

    // ---- preprocessing (timed, included in totals as in the paper) ----
    let ((rtree, qtree_opt, moments), build_secs) = time_it(|| {
        let rtree = KdTree::build(problem.references, &weights, params);
        let qtree_opt = if problem.monochromatic {
            None
        } else {
            // query tree weights are irrelevant; use ones
            let qw = vec![1.0; problem.queries.rows()];
            Some(KdTree::build(problem.queries, &qw, params))
        };
        let moments = cfg
            .series
            .map(|s| RefMoments::compute(&rtree, &kernel, s.layout(), plimit));
        (rtree, qtree_opt, moments)
    });

    let qt: &KdTree = qtree_opt.as_ref().unwrap_or(&rtree);
    let rt: &KdTree = &rtree;

    let series = match (&moments, cfg.series) {
        (Some(m), Some(kind)) => Some(SeriesPack {
            moments: m,
            bounds: match kind {
                SeriesKind::OdpGraded => &OdpBounds as &dyn TruncationBounds,
                SeriesKind::OpdGrid => &OpdBounds as &dyn TruncationBounds,
            },
            p_limit: plimit,
        }),
        _ => None,
    };

    let set_len = series.as_ref().map_or(0, |s| s.moments.set().len());
    let table_order = if set_len > 0 { 2 * plimit.max(1) } else { 1 };

    let ctx = Ctx {
        qt,
        rt,
        kernel,
        eps: problem.epsilon,
        total_w: problem.total_weight(),
        use_tokens: cfg.use_tokens,
        series,
    };
    let mut st = State {
        ledger: QueryLedger::new(qt.num_nodes(), qt.num_points()),
        lcoeffs: vec![0.0; qt.num_nodes() * set_len],
        set_len,
        table: HermiteTable::new(dim, table_order),
        mono: vec![0.0; set_len.max(1)],
        off: vec![0.0; dim],
        stats: RunStats { build_secs, ..Default::default() },
    };

    recurse(&ctx, &mut st, qt.root(), rt.root(), 0.0);
    let tree_order_sums = postprocess(&ctx, &mut st);
    let sums = qt.unpermute(&tree_order_sums);

    Ok(GaussSumResult { sums, stats: st.stats })
}

/// The main recursion (paper Fig. 7).
fn recurse(ctx: &Ctx<'_>, st: &mut State, q: usize, r: usize, inherited_min: f64) {
    st.stats.node_pairs += 1;
    let qn = ctx.qt.node(q);
    let rn = ctx.rt.node(r);
    let dmin = qn.min_dist(rn);
    let dmax = qn.max_dist(rn);
    let ku = ctx.kernel.eval(dmin); // largest possible kernel value
    let kl = ctx.kernel.eval(dmax); // smallest possible kernel value
    let wr = rn.weight;
    let dl = wr * kl;
    let du = wr * (ku - 1.0);
    let gq_min = st.ledger.gq_min(q, inherited_min);

    // ---- finite-difference prune (optimized rule first, Fig. 7) ----
    let e_fd = 0.5 * wr * (ku - kl);
    match token_rule(e_fd, wr, st.ledger.tokens[q], gq_min, ctx.eps, ctx.total_w, ctx.use_tokens)
    {
        PruneDecision::Accept { token_delta } => {
            apply_tokens(st, q, token_delta);
            st.ledger.node_min[q] += dl;
            st.ledger.node_max[q] += du;
            st.ledger.node_est[q] += 0.5 * wr * (ku + kl);
            st.stats.fd_prunes += 1;
            return;
        }
        PruneDecision::Reject => {}
    }

    // ---- FMM-type prune (series families only) ----
    if let Some(series) = &ctx.series {
        if gq_min > 0.0 {
            let budget_w = wr + if ctx.use_tokens { st.ledger.tokens[q] } else { 0.0 };
            let max_err = ctx.eps * budget_w * gq_min / ctx.total_w;
            let geo = NodeGeometry {
                dim: ctx.qt.dim(),
                min_sqdist: dmin * dmin,
                r_ref: rn.linf_radius / ctx.kernel.bandwidth(),
                r_query: qn.linf_radius / ctx.kernel.bandwidth(),
                h: ctx.kernel.bandwidth(),
            };
            let cm = CostModel { set: series.moments.set(), p_limit: series.p_limit };
            let choice =
                cm.best_method(series.bounds, &geo, wr, max_err, qn.count(), rn.count());
            if choice != Choice::Direct {
                let err = match choice {
                    Choice::DH { p, err } => {
                        let set = series.moments.set();
                        let coeffs = series.moments.node_coeffs(r);
                        for qi in qn.begin..qn.end {
                            st.ledger.point_est[qi] += eval_farfield_truncated(
                                set,
                                p,
                                coeffs,
                                &rn.centroid,
                                series.moments.scale(),
                                ctx.qt.points().row(qi),
                                &mut st.table,
                                &mut st.off,
                            );
                        }
                        st.stats.dh_prunes += 1;
                        err
                    }
                    Choice::DL { p, err } => {
                        let set = series.moments.set();
                        let lc = &mut st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                        accumulate_local_truncated(
                            set,
                            p,
                            ctx.rt.points(),
                            rn.begin..rn.end,
                            ctx.rt.weights(),
                            &qn.centroid,
                            series.moments.scale(),
                            lc,
                            &mut st.table,
                            &mut st.off,
                        );
                        st.stats.dl_prunes += 1;
                        err
                    }
                    Choice::H2L { p, err } => {
                        let set = series.moments.set();
                        let lc = &mut st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                        h2l_truncated(
                            set,
                            p,
                            series.moments.node_coeffs(r),
                            &rn.centroid,
                            &qn.centroid,
                            series.moments.scale(),
                            lc,
                            &mut st.table,
                            &mut st.off,
                        );
                        st.stats.h2l_prunes += 1;
                        err
                    }
                    Choice::Direct => unreachable!(),
                };
                // account the accepted error against the ledger
                match token_rule(
                    err,
                    wr,
                    st.ledger.tokens[q],
                    gq_min,
                    ctx.eps,
                    ctx.total_w,
                    ctx.use_tokens,
                ) {
                    PruneDecision::Accept { token_delta } => apply_tokens(st, q, token_delta),
                    // feasibility guaranteed by max_err construction
                    PruneDecision::Reject => unreachable!("bestMethod returned infeasible"),
                }
                st.ledger.node_min[q] += dl;
                st.ledger.node_max[q] += du;
                return;
            }
        }
    }

    // ---- expand ----
    match (qn.is_leaf(), rn.is_leaf()) {
        (true, true) => base_case(ctx, st, q, r),
        (true, false) => {
            // split reference side, nearer child first (tightens G_Q^min
            // before the farther child is considered)
            let (a, b) = ctx.rt.children(r).unwrap();
            let (near, far) = order_by_dist(ctx.qt.node(q), ctx.rt, a, b);
            recurse(ctx, st, q, near, inherited_min);
            recurse(ctx, st, q, far, inherited_min);
        }
        (false, true) => {
            let (l, rr) = ctx.qt.children(q).unwrap();
            let inh = inherited_min + st.ledger.node_min[q];
            recurse(ctx, st, l, r, inh);
            recurse(ctx, st, rr, r, inh);
            st.ledger.refresh_below_from_children(q, l, rr);
        }
        (false, false) => {
            let (ql, qr) = ctx.qt.children(q).unwrap();
            let inh = inherited_min + st.ledger.node_min[q];
            for qc in [ql, qr] {
                let (a, b) = ctx.rt.children(r).unwrap();
                let (near, far) = order_by_dist(ctx.qt.node(qc), ctx.rt, a, b);
                recurse(ctx, st, qc, near, inh);
                recurse(ctx, st, qc, far, inh);
            }
            st.ledger.refresh_below_from_children(q, ql, qr);
        }
    }
}

fn apply_tokens(st: &mut State, q: usize, delta: f64) {
    if delta >= 0.0 {
        st.stats.tokens_banked += delta;
    } else {
        st.stats.tokens_spent += -delta;
    }
    st.ledger.tokens[q] += delta;
}

fn order_by_dist(qn: &crate::tree::Node, rt: &KdTree, a: usize, b: usize) -> (usize, usize) {
    if qn.min_dist(rt.node(a)) <= qn.min_dist(rt.node(b)) {
        (a, b)
    } else {
        (b, a)
    }
}

/// Leaf–leaf exhaustive base case (paper's DITOBase).
fn base_case(ctx: &Ctx<'_>, st: &mut State, q: usize, r: usize) {
    let qn = ctx.qt.node(q);
    let rn = ctx.rt.node(r);
    let wr_total = rn.weight;
    let d = ctx.qt.dim();
    for qi in qn.begin..qn.end {
        let qrow = ctx.qt.points().row(qi);
        let mut acc = 0.0;
        for ri in rn.begin..rn.end {
            let rrow = ctx.rt.points().row(ri);
            let mut sq = 0.0;
            for k in 0..d {
                let dd = qrow[k] - rrow[k];
                sq += dd * dd;
            }
            acc += ctx.rt.weights()[ri] * ctx.kernel.eval_sq(sq);
        }
        st.ledger.point_min[qi] += acc;
        st.ledger.point_est[qi] += acc;
        st.ledger.point_max[qi] += acc - wr_total;
    }
    st.stats.base_point_pairs += (qn.count() * rn.count()) as u64;
    if ctx.use_tokens {
        // exhaustive computation banks its full entitlement (Fig. 7)
        st.ledger.tokens[q] += wr_total;
        st.stats.tokens_banked += wr_total;
    }
    st.ledger.refresh_below_from_points(q, qn.begin, qn.end);
}

/// Post-processing (paper Fig. 8): push node-level estimates and local
/// expansions down the query tree (L2L), then evaluate at leaf points.
/// Returns per-point sums in tree order.
fn postprocess(ctx: &Ctx<'_>, st: &mut State) -> Vec<f64> {
    let qt = ctx.qt;
    let mut out = vec![0.0; qt.num_points()];
    // BFS order: parents processed before children.
    let mut queue = std::collections::VecDeque::from([qt.root()]);
    while let Some(q) = queue.pop_front() {
        if let Some((l, r)) = qt.children(q) {
            let est = st.ledger.node_est[q];
            st.ledger.node_est[l] += est;
            st.ledger.node_est[r] += est;
            if let Some(series) = &ctx.series {
                let set = series.moments.set();
                let pairs = series.moments.pairs();
                let scale = series.moments.scale();
                let len = st.set_len;
                for child in [l, r] {
                    // split-borrow the node-major lcoeffs buffer
                    let (parent_part, child_part) =
                        split_blocks(&mut st.lcoeffs, q, child, len);
                    l2l(
                        set,
                        pairs,
                        parent_part,
                        &qt.node(q).centroid,
                        &qt.node(child).centroid,
                        scale,
                        child_part,
                        &mut st.mono,
                        &mut st.off,
                    );
                }
            }
            queue.push_back(l);
            queue.push_back(r);
        } else {
            let node_est = st.ledger.node_est[q];
            for qi in qt.node(q).begin..qt.node(q).end {
                let mut v = st.ledger.point_est[qi] + node_est;
                if let Some(series) = &ctx.series {
                    let set = series.moments.set();
                    let lc = &st.lcoeffs[q * st.set_len..(q + 1) * st.set_len];
                    v += eval_local(
                        set,
                        lc,
                        &qt.node(q).centroid,
                        series.moments.scale(),
                        qt.points().row(qi),
                        &mut st.mono,
                        &mut st.off,
                    );
                }
                out[qi] = v;
            }
        }
    }
    out
}

/// Disjoint (&parent, &mut child) blocks of a node-major buffer.
fn split_blocks(buf: &mut [f64], parent: usize, child: usize, len: usize) -> (&[f64], &mut [f64]) {
    assert_ne!(parent, child);
    if parent < child {
        let (lo, hi) = buf.split_at_mut(child * len);
        (&lo[parent * len..(parent + 1) * len], &mut hi[..len])
    } else {
        let (lo, hi) = buf.split_at_mut(parent * len);
        (&hi[..len], &mut lo[child * len..(child + 1) * len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::naive::Naive;
    use crate::algo::{max_relative_error, GaussSum};
    use crate::geometry::Matrix;
    use crate::util::Pcg32;

    fn clustered(n: usize, d: usize, seed: u64) -> Matrix {
        // a few Gaussian blobs — the regime dual trees exploit
        let mut rng = Pcg32::new(seed);
        let k = 4;
        let centers: Vec<Vec<f64>> =
            (0..k).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
        Matrix::from_rows(
            &(0..n)
                .map(|i| {
                    let c = &centers[i % k];
                    (0..d).map(|j| c[j] + 0.05 * rng.normal()).collect()
                })
                .collect::<Vec<_>>(),
        )
    }

    fn check_config(cfg: DualTreeConfig, n: usize, d: usize, h: f64, eps: f64, seed: u64) {
        let data = clustered(n, d, seed);
        let problem = GaussSumProblem::kde(&data, h, eps);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &cfg).unwrap();
        let rel = max_relative_error(&got.sums, &exact);
        assert!(
            rel <= eps * (1.0 + 1e-9),
            "cfg={cfg:?} d={d} h={h}: rel={rel} > eps={eps}"
        );
    }

    #[test]
    fn dfd_style_meets_tolerance() {
        let cfg = DualTreeConfig { use_tokens: false, series: None, ..Default::default() };
        for h in [0.01, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 71);
        }
    }

    #[test]
    fn tokens_only_meets_tolerance() {
        let cfg = DualTreeConfig { use_tokens: true, series: None, ..Default::default() };
        for h in [0.01, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 72);
        }
    }

    #[test]
    fn odp_series_meets_tolerance_2d() {
        let cfg = DualTreeConfig::default(); // tokens + OdpGraded
        for h in [0.02, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 73);
        }
    }

    #[test]
    fn opd_series_meets_tolerance_2d() {
        let cfg =
            DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..Default::default() };
        for h in [0.02, 0.1, 0.5, 2.0] {
            check_config(cfg, 400, 2, h, 0.01, 74);
        }
    }

    #[test]
    fn higher_dims_meet_tolerance() {
        for d in [3, 5, 7] {
            let cfg = DualTreeConfig::default();
            check_config(cfg, 300, d, 0.3, 0.01, 75);
        }
    }

    #[test]
    fn tight_epsilon_still_met() {
        check_config(DualTreeConfig::default(), 300, 2, 0.2, 1e-4, 76);
    }

    #[test]
    fn loose_epsilon_prunes_more() {
        let data = clustered(500, 2, 77);
        let loose = GaussSumProblem::kde(&data, 0.3, 0.5);
        let tight = GaussSumProblem::kde(&data, 0.3, 1e-6);
        let cfg = DualTreeConfig::default();
        let a = run_dualtree(&loose, &cfg).unwrap();
        let b = run_dualtree(&tight, &cfg).unwrap();
        assert!(
            a.stats.base_point_pairs < b.stats.base_point_pairs,
            "loose={} tight={}",
            a.stats.base_point_pairs,
            b.stats.base_point_pairs
        );
    }

    #[test]
    fn bichromatic_queries_differ_from_refs() {
        let mut rng = Pcg32::new(78);
        let refs = clustered(300, 2, 79);
        let queries = Matrix::from_rows(
            &(0..50)
                .map(|_| (0..2).map(|_| rng.uniform()).collect())
                .collect::<Vec<_>>(),
        );
        let w: Vec<f64> = (0..300).map(|_| rng.uniform_in(0.5, 1.5)).collect();
        let problem = GaussSumProblem::new(&queries, &refs, Some(&w), 0.2, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9));
    }

    #[test]
    fn stats_account_all_prune_types_in_2d() {
        // moderate bandwidth → FMM (series) prunes dominate
        let data = clustered(800, 2, 80);
        let problem = GaussSumProblem::kde(&data, 0.5, 0.01);
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(
            got.stats.dh_prunes + got.stats.dl_prunes + got.stats.h2l_prunes > 0,
            "series prunes expected: {:?}",
            got.stats
        );
        assert!(got.stats.tokens_banked > 0.0);
        assert!(got.stats.tokens_spent > 0.0);
        // tiny bandwidth → distant pairs have e_FD ≈ 0 → FD prunes fire
        let problem2 = GaussSumProblem::kde(&data, 0.005, 0.01);
        let got2 = run_dualtree(&problem2, &DualTreeConfig::default()).unwrap();
        assert!(got2.stats.fd_prunes > 0, "{:?}", got2.stats);
    }

    #[test]
    fn duplicate_heavy_data_is_handled() {
        // many identical points stress zero-width nodes
        let mut rows = vec![vec![0.25, 0.25]; 100];
        rows.extend(vec![vec![0.75, 0.75]; 100]);
        let data = Matrix::from_rows(&rows);
        let problem = GaussSumProblem::kde(&data, 0.1, 0.01);
        let exact = Naive::new().run(&problem).unwrap().sums;
        let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
        assert!(max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9));
    }

    #[test]
    fn extreme_bandwidths() {
        let data = clustered(300, 3, 81);
        for h in [1e-4, 1e3] {
            let problem = GaussSumProblem::kde(&data, h, 0.01);
            let exact = Naive::new().run(&problem).unwrap().sums;
            let got = run_dualtree(&problem, &DualTreeConfig::default()).unwrap();
            assert!(
                max_relative_error(&got.sums, &exact) <= 0.01 * (1.0 + 1e-9),
                "h={h}"
            );
        }
    }
}
