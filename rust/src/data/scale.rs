//! Unit-hypercube scaling — the paper scales every dataset to [0,1]ᴰ
//! before the experiments.

use crate::geometry::Matrix;

/// Min–max scale each column to [0, 1]. Constant columns map to 0.5.
pub fn to_unit_cube(m: &Matrix) -> Matrix {
    let lo = m.col_min();
    let hi = m.col_max();
    let mut out = Matrix::zeros(m.rows(), m.cols());
    for i in 0..m.rows() {
        let r = m.row(i);
        for j in 0..m.cols() {
            let span = hi[j] - lo[j];
            let v = if span > 0.0 { (r[j] - lo[j]) / span } else { 0.5 };
            out.set(i, j, v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_to_unit_interval() {
        let m = Matrix::from_rows(&[vec![-5.0, 10.0], vec![5.0, 20.0], vec![0.0, 15.0]]);
        let s = to_unit_cube(&m);
        assert_eq!(s.row(0), &[0.0, 0.0]);
        assert_eq!(s.row(1), &[1.0, 1.0]);
        assert_eq!(s.row(2), &[0.5, 0.5]);
    }

    #[test]
    fn constant_column_centered() {
        let m = Matrix::from_rows(&[vec![3.0, 1.0], vec![3.0, 2.0]]);
        let s = to_unit_cube(&m);
        assert_eq!(s.get(0, 0), 0.5);
        assert_eq!(s.get(1, 0), 0.5);
    }

    #[test]
    fn idempotent_on_unit_data() {
        let m = Matrix::from_rows(&[vec![0.0], vec![0.25], vec![1.0]]);
        assert_eq!(to_unit_cube(&m), m);
    }
}
