//! Headerless numeric CSV load/save, for round-tripping datasets to
//! external tools and loading user data into the CLI.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::geometry::Matrix;

/// Load a headerless numeric CSV (comma or whitespace separated).
pub fn load(path: &Path) -> Result<Matrix> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let vals: Result<Vec<f64>> = trimmed
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.parse::<f64>()
                    .map_err(|_| anyhow!("{}:{}: bad number {t:?}", path.display(), lineno + 1))
            })
            .collect();
        let vals = vals?;
        if let Some(first) = rows.first() {
            if vals.len() != first.len() {
                return Err(anyhow!(
                    "{}:{}: expected {} columns, got {}",
                    path.display(),
                    lineno + 1,
                    first.len(),
                    vals.len()
                ));
            }
        }
        rows.push(vals);
    }
    if rows.is_empty() {
        return Err(anyhow!("{}: no data rows", path.display()));
    }
    Ok(Matrix::from_rows(&rows))
}

/// Save a matrix as comma-separated values with full f64 precision.
pub fn save(path: &Path, m: &Matrix) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for i in 0..m.rows() {
        let row = m.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                write!(w, ",")?;
            }
            write!(w, "{v:.17}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(name)
    }

    #[test]
    fn roundtrip() {
        let m = Matrix::from_rows(&[vec![1.5, -2.25], vec![0.0, 1e-17]]);
        let p = tmp("fg_csv_rt.csv");
        save(&p, &m).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let p = tmp("fg_csv_comments.csv");
        std::fs::write(&p, "# header\n1,2\n\n3,4\n").unwrap();
        let m = load(&p).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn whitespace_separated_accepted() {
        let p = tmp("fg_csv_ws.csv");
        std::fs::write(&p, "1.0 2.0\n3.0\t4.0\n").unwrap();
        let m = load(&p).unwrap();
        assert_eq!(m.row(0), &[1.0, 2.0]);
    }

    #[test]
    fn ragged_rows_rejected() {
        let p = tmp("fg_csv_ragged.csv");
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn bad_number_reported_with_line() {
        let p = tmp("fg_csv_bad.csv");
        std::fs::write(&p, "1,2\n3,abc\n").unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
    }

    #[test]
    fn empty_file_rejected() {
        let p = tmp("fg_csv_empty.csv");
        std::fs::write(&p, "# only comments\n").unwrap();
        assert!(load(&p).is_err());
    }
}
