//! Datasets: the six synthetic stand-ins for the paper's evaluation
//! data (see DESIGN.md §Substitutions), two post-paper high-dimensional
//! sets (`hyper20`, `hyper50`) for the sliced Fourier engine, unit-cube
//! scaling, and CSV I/O.

pub mod csv;
pub mod scale;
pub mod synthetic;

use crate::geometry::Matrix;

/// A named point set, scaled to the unit hypercube as in the paper.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub points: Matrix,
}

impl Dataset {
    pub fn new(name: impl Into<String>, points: Matrix) -> Self {
        Dataset { name: name.into(), points }
    }

    pub fn dim(&self) -> usize {
        self.points.cols()
    }

    pub fn len(&self) -> usize {
        self.points.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.points.rows() == 0
    }
}

/// The paper's evaluation suite: (our name, paper dataset, D).
pub const PAPER_SUITE: &[(&str, &str, usize)] = &[
    ("astro2d", "sj2-50000-2", 2),
    ("galaxy3d", "mockgalaxy-D-1M-rnd", 3),
    ("bio5", "bio5-rnd", 5),
    ("pall7", "pall7-rnd", 7),
    ("covtype10", "covtype-rnd", 10),
    ("texture16", "CoocTexture-rnd", 16),
];

/// Generate a paper-suite dataset by name (scaled to [0,1]ᴰ).
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    let m = match name {
        "astro2d" => synthetic::astro2d(n, seed),
        "galaxy3d" => synthetic::galaxy3d(n, seed),
        "bio5" => synthetic::bio5(n, seed),
        "pall7" => synthetic::pall7(n, seed),
        "covtype10" => synthetic::covtype10(n, seed),
        "texture16" => synthetic::texture16(n, seed),
        "hyper20" => synthetic::hyper20(n, seed),
        "hyper50" => synthetic::hyper50(n, seed),
        "uniform2d" => synthetic::uniform(n, 2, seed),
        "uniform5d" => synthetic::uniform(n, 5, seed),
        _ => return None,
    };
    Some(Dataset::new(name, m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_suite() {
        for (name, _paper, d) in PAPER_SUITE {
            let ds = by_name(name, 200, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(ds.dim(), *d, "{name}");
            assert_eq!(ds.len(), 200);
            // unit-cube scaling
            for j in 0..ds.dim() {
                let lo = ds.points.col_min()[j];
                let hi = ds.points.col_max()[j];
                assert!(lo >= -1e-12 && hi <= 1.0 + 1e-12, "{name} dim {j}: [{lo},{hi}]");
            }
        }
        assert!(by_name("nonexistent", 10, 0).is_none());
    }

    #[test]
    fn registry_covers_high_dim_sets() {
        // the hyper sets ride outside PAPER_SUITE (the paper's table
        // protocol must keep its six rows) but resolve by name
        for (name, d) in [("hyper20", 20), ("hyper50", 50)] {
            let ds = by_name(name, 150, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(ds.dim(), d, "{name}");
            assert_eq!(ds.len(), 150);
            for j in 0..ds.dim() {
                let lo = ds.points.col_min()[j];
                let hi = ds.points.col_max()[j];
                assert!(lo >= -1e-12 && hi <= 1.0 + 1e-12, "{name} dim {j}: [{lo},{hi}]");
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = by_name("astro2d", 100, 42).unwrap();
        let b = by_name("astro2d", 100, 42).unwrap();
        assert_eq!(a.points, b.points);
        let c = by_name("astro2d", 100, 43).unwrap();
        assert_ne!(a.points, c.points);
    }
}
