//! Synthetic stand-ins for the paper's six real datasets.
//!
//! The paper's data (SDSS astronomy, mock galaxy catalogs, drug-
//! discovery descriptors, forest cover, image textures) is not
//! redistributable; what the *algorithms* are sensitive to is the
//! clustered, multi-scale, anisotropic structure of real data — uniform
//! noise would flatter every method equally and hide the bandwidth
//! crossovers the paper's tables show. Each generator below reproduces
//! the qualitative structure of its counterpart at matching
//! dimensionality; everything is min–max scaled to [0,1]ᴰ exactly as in
//! the paper. See DESIGN.md §Substitutions.

use crate::geometry::Matrix;
use crate::util::Pcg32;

use super::scale::to_unit_cube;

/// Uniform noise in the unit cube (calibration baseline, not paper data).
pub fn uniform(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    Matrix::from_rows(
        &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
    )
}

/// sj2-like (2-D astronomy): sky-survey point pattern — filaments plus
/// compact clusters over a sparse background, strongly multi-scale.
pub fn astro2d(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    let mut rows = Vec::with_capacity(n);
    // a handful of filament segments
    let nfil = 6;
    let fils: Vec<([f64; 2], [f64; 2])> = (0..nfil)
        .map(|_| {
            let a = [rng.uniform(), rng.uniform()];
            let ang = rng.uniform_in(0.0, std::f64::consts::PI);
            let len = rng.uniform_in(0.3, 0.8);
            ([a[0], a[1]], [a[0] + len * ang.cos(), a[1] + len * ang.sin()])
        })
        .collect();
    // compact clusters sitting on filaments
    let nclu = 12;
    let clus: Vec<[f64; 2]> = (0..nclu)
        .map(|_| {
            let (a, b) = &fils[rng.below(nfil)];
            let t = rng.uniform();
            [a[0] + t * (b[0] - a[0]), a[1] + t * (b[1] - a[1])]
        })
        .collect();
    for _ in 0..n {
        let u = rng.uniform();
        let p = if u < 0.45 {
            // filament population: along-segment uniform, tight transverse
            let (a, b) = &fils[rng.below(nfil)];
            let t = rng.uniform();
            let nx = -(b[1] - a[1]);
            let ny = b[0] - a[0];
            let norm = (nx * nx + ny * ny).sqrt().max(1e-12);
            let off = 0.008 * rng.normal();
            vec![
                a[0] + t * (b[0] - a[0]) + off * nx / norm,
                a[1] + t * (b[1] - a[1]) + off * ny / norm,
            ]
        } else if u < 0.85 {
            // cluster population at two scales
            let c = &clus[rng.below(nclu)];
            let s = if rng.uniform() < 0.5 { 0.004 } else { 0.02 };
            vec![c[0] + s * rng.normal(), c[1] + s * rng.normal()]
        } else {
            vec![rng.uniform(), rng.uniform()]
        };
        rows.push(p);
    }
    to_unit_cube(&Matrix::from_rows(&rows))
}

/// mockgalaxy-like (3-D): clustered walls and voids — Gaussian blobs on
/// a coarse lattice of "halo" sites with power-law-ish sizes.
pub fn galaxy3d(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed);
    let nhalo = 40;
    let halos: Vec<(Vec<f64>, f64)> = (0..nhalo)
        .map(|_| {
            let c: Vec<f64> = (0..3).map(|_| rng.uniform()).collect();
            // halo radius roughly power-law distributed
            let r = 0.003 / (rng.uniform() + 0.02);
            (c, r.min(0.08))
        })
        .collect();
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            if rng.uniform() < 0.92 {
                let (c, r) = &halos[rng.below(nhalo)];
                (0..3).map(|j| c[j] + r * rng.normal()).collect()
            } else {
                (0..3).map(|_| rng.uniform()).collect()
            }
        })
        .collect();
    to_unit_cube(&Matrix::from_rows(&rows))
}

/// bio5-like (5-D): correlated Gaussian mixture — biological descriptor
/// panels are strongly collinear.
pub fn bio5(n: usize, seed: u64) -> Matrix {
    correlated_mixture(n, 5, 8, 0.7, seed)
}

/// pall7-like (7-D): pharmaceutical descriptors — mixture with a few
/// dominant modes and heavier tails.
pub fn pall7(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed ^ 0x7a77);
    let base = correlated_mixture(n, 7, 5, 0.5, seed);
    // heavier tails: occasionally stretch points away from their mode
    let mut rows: Vec<Vec<f64>> = base.iter_rows().map(|r| r.to_vec()).collect();
    for row in rows.iter_mut() {
        if rng.uniform() < 0.05 {
            let f = 1.0 + rng.uniform_in(0.5, 2.0);
            for v in row.iter_mut() {
                *v = 0.5 + (*v - 0.5) * f;
            }
        }
    }
    to_unit_cube(&Matrix::from_rows(&rows))
}

/// covtype-like (10-D): forestry — mixed continuous terrain variables
/// plus quantized/binary-ish margins (soil/wilderness indicators).
pub fn covtype10(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed ^ 0xc04);
    let cont = correlated_mixture(n, 6, 7, 0.6, seed);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|i| {
            let mut r = cont.row(i).to_vec();
            // 2 quantized columns (elevation bands, aspect sectors)
            r.push((rng.below(8) as f64) / 7.0 + 0.01 * rng.normal());
            r.push((rng.below(4) as f64) / 3.0 + 0.01 * rng.normal());
            // 2 near-binary indicator columns
            r.push(if rng.uniform() < 0.3 { 1.0 } else { 0.0 } + 0.005 * rng.normal());
            r.push(if rng.uniform() < 0.6 { 1.0 } else { 0.0 } + 0.005 * rng.normal());
            r
        })
        .collect();
    to_unit_cube(&Matrix::from_rows(&rows))
}

/// CoocTexture-like (16-D): co-occurrence texture features — intrinsically
/// low-rank (images vary along few factors) with small ambient noise.
pub fn texture16(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed ^ 0x7e);
    let rank = 4;
    let d = 16;
    // random loading matrix (rank × d)
    let load: Vec<Vec<f64>> = (0..rank)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    let nmodes = 10;
    let modes: Vec<Vec<f64>> = (0..nmodes)
        .map(|_| (0..rank).map(|_| rng.normal()).collect())
        .collect();
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let m = &modes[rng.below(nmodes)];
            let factors: Vec<f64> = (0..rank).map(|k| m[k] + 0.2 * rng.normal()).collect();
            (0..d)
                .map(|j| {
                    let mut v = 0.0;
                    for k in 0..rank {
                        v += factors[k] * load[k][j];
                    }
                    v + 0.05 * rng.normal()
                })
                .collect()
        })
        .collect();
    to_unit_cube(&Matrix::from_rows(&rows))
}

/// hyper20 (20-D): clustered correlated Gaussian mixture past the
/// paper's dimensional range — the regime the sliced Fourier engine
/// targets, where series expansions explode and dual trees stop
/// pruning. More modes than the low-D sets so the mixture stays
/// genuinely multi-modal after unit-cube scaling.
pub fn hyper20(n: usize, seed: u64) -> Matrix {
    correlated_mixture(n, 20, 10, 0.6, seed)
}

/// hyper50 (50-D): the stress end of the high-dimensional regime —
/// same clustered structure as [`hyper20`] at 50 ambient dimensions.
pub fn hyper50(n: usize, seed: u64) -> Matrix {
    correlated_mixture(n, 50, 12, 0.6, seed ^ 0x50d1)
}

/// Shared helper: k-mode Gaussian mixture with per-mode correlation
/// (each mode stretched along a random direction by `anis`).
fn correlated_mixture(n: usize, d: usize, k: usize, anis: f64, seed: u64) -> Matrix {
    let mut rng = Pcg32::new(seed ^ 0x3117);
    let modes: Vec<(Vec<f64>, Vec<f64>, f64)> = (0..k)
        .map(|_| {
            let c: Vec<f64> = (0..d).map(|_| rng.uniform()).collect();
            let mut dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            for v in dir.iter_mut() {
                *v /= norm;
            }
            let scale = rng.uniform_in(0.02, 0.08);
            (c, dir, scale)
        })
        .collect();
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let (c, dir, s) = &modes[rng.below(k)];
            let along = anis * s * 4.0 * rng.normal();
            (0..d).map(|j| c[j] + along * dir[j] + s * rng.normal()).collect()
        })
        .collect();
    to_unit_cube(&Matrix::from_rows(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn clusteredness(m: &Matrix) -> f64 {
        // ratio of mean nearest-neighbor distance to the uniform
        // expectation — < 1 means clustered (Clark–Evans style, crude)
        let n = m.rows().min(300);
        let d = m.cols();
        let mut nn = Vec::with_capacity(n);
        for i in 0..n {
            let mut best = f64::INFINITY;
            for j in 0..m.rows() {
                if i != j {
                    let dd = crate::geometry::sqdist(m.row(i), m.row(j));
                    if dd < best {
                        best = dd;
                    }
                }
            }
            nn.push(best.sqrt());
        }
        let mean_nn = stats::mean(&nn);
        // expected NN distance for uniform: ~ (1/n)^(1/d) · Γ-factor; use
        // the simple scale (1/N)^(1/D)
        let expected = (1.0 / m.rows() as f64).powf(1.0 / d as f64);
        mean_nn / expected
    }

    #[test]
    fn paper_like_sets_are_clustered() {
        // all six stand-ins must be substantially more clustered than
        // uniform noise — the property the dual-tree speedups feed on
        let gens: Vec<(&str, Matrix)> = vec![
            ("astro2d", astro2d(1500, 5)),
            ("galaxy3d", galaxy3d(1500, 5)),
            ("bio5", bio5(1500, 5)),
            ("pall7", pall7(1500, 5)),
            ("covtype10", covtype10(1500, 5)),
            ("texture16", texture16(1500, 5)),
            ("hyper20", hyper20(1500, 5)),
            ("hyper50", hyper50(1500, 5)),
        ];
        for (name, m) in &gens {
            let u = uniform(1500, m.cols(), 99);
            let cm = clusteredness(m);
            let cu = clusteredness(&u);
            assert!(cm < 0.8 * cu, "{name}: clusteredness {cm:.3} vs uniform {cu:.3}");
        }
    }

    #[test]
    fn shapes_and_ranges() {
        for (m, d) in [
            (astro2d(400, 1), 2),
            (galaxy3d(400, 1), 3),
            (bio5(400, 1), 5),
            (pall7(400, 1), 7),
            (covtype10(400, 1), 10),
            (texture16(400, 1), 16),
            (hyper20(400, 1), 20),
            (hyper50(400, 1), 50),
        ] {
            assert_eq!(m.rows(), 400);
            assert_eq!(m.cols(), d);
            for j in 0..d {
                assert!(m.col_min()[j] >= -1e-12);
                assert!(m.col_max()[j] <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn covtype_has_quantized_margins() {
        let m = covtype10(2000, 3);
        // the two indicator columns (8, 9) should be strongly bimodal:
        // most mass near 0 or 1 after scaling
        for j in [8usize, 9] {
            let extreme = (0..m.rows())
                .filter(|&i| {
                    let v = m.get(i, j);
                    v < 0.2 || v > 0.8
                })
                .count();
            assert!(extreme > m.rows() * 8 / 10, "col {j}: only {extreme} extreme");
        }
    }

    #[test]
    fn texture_is_low_rank() {
        // crude rank proxy: column variance concentrated in a few PCs —
        // here just check strong pairwise correlations exist
        let m = texture16(1000, 2);
        let means = m.col_mean();
        let stds = m.col_std();
        let mut maxcorr = 0.0f64;
        for a in 0..16 {
            for b in (a + 1)..16 {
                let mut c = 0.0;
                for i in 0..m.rows() {
                    c += (m.get(i, a) - means[a]) * (m.get(i, b) - means[b]);
                }
                c /= m.rows() as f64 * stds[a] * stds[b];
                maxcorr = maxcorr.max(c.abs());
            }
        }
        assert!(maxcorr > 0.7, "max |corr| = {maxcorr}");
    }
}
