//! `fastgauss` — leader binary: paper tables, KDE with automatic
//! bandwidth selection, dataset generation, self-tests and the PJRT
//! runtime check. See `fastgauss help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = fastgauss::cli::run(&args) {
        eprintln!("fastgauss: {e:#}");
        std::process::exit(1);
    }
}
