//! Minimal JSON parser — just enough for `artifacts/manifest.json` and
//! config files. (The offline vendor set has no serde_json; this keeps
//! the runtime self-contained.)
//!
//! Supports objects, arrays, strings (with standard escapes), numbers,
//! booleans and null. Not streaming; input sizes here are tiny.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "dtype": "f64",
          "artifacts": {
            "2": {"file": "gauss_d2.hlo.txt", "dim": 2, "tile_queries": 256,
                   "block_refs": 512, "chunk_refs": 4096}
          }
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f64"));
        let a = v.get("artifacts").unwrap().get("2").unwrap();
        assert_eq!(a.get("dim").unwrap().as_usize(), Some(2));
        assert_eq!(a.get("chunk_refs").unwrap().as_usize(), Some(4096));
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn arrays_and_nesting() {
        let v = Json::parse("[1, [2, 3], {\"k\": []}]").unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_arr().unwrap()[1], Json::Num(3.0));
        assert_eq!(arr[2].get("k").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
