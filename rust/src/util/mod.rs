//! Small self-contained utilities: seeded RNG, timing, statistics and
//! leveled logging. The build is fully offline, so we carry our own RNG
//! instead of the `rand` crate.

pub mod error;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg32;
pub use timer::Timer;
