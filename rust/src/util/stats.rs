//! Basic descriptive statistics used by the bench harness (robust
//! reporting over repeated runs) and by dataset generators.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0 for len < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Interpolated percentile, q in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum of a non-empty slice.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum of a non-empty slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn variance_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
