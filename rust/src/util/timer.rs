//! Wall-clock timing helpers used by the bench harness and the
//! coordinator's metrics. We report both per-phase and cumulative times,
//! mirroring the paper's "times include preprocessing" convention.

use std::time::{Duration, Instant};

/// A simple start/stop timer accumulating total elapsed time.
#[derive(Debug)]
pub struct Timer {
    started: Option<Instant>,
    total: Duration,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer { started: None, total: Duration::ZERO }
    }

    /// Create a timer that is already running.
    pub fn started() -> Self {
        Timer { started: Some(Instant::now()), total: Duration::ZERO }
    }

    pub fn start(&mut self) {
        assert!(self.started.is_none(), "timer already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        // lint: allow(no-panic): unbalanced start/stop is a programmer error at the call site
        let s = self.started.take().expect("timer not running");
        self.total += s.elapsed();
    }

    /// Total accumulated time, including the in-flight span if running.
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(s) => self.total + s.elapsed(),
            None => self.total,
        }
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Format seconds the way the paper's tables do: 3 significant digits,
/// switching to fixed notation for large values.
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        return "inf".to_string();
    }
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 10.0 {
        format!("{s:.1}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        format!("{s:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_spans() {
        let mut t = Timer::new();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        let first = t.elapsed();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        assert!(t.elapsed() > first);
        assert!(t.secs() >= 0.009);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_secs(452.0), "452");
        assert_eq!(fmt_secs(85.6), "85.6");
        assert_eq!(fmt_secs(8.12), "8.12");
        assert_eq!(fmt_secs(0.82), "0.820");
        assert_eq!(fmt_secs(f64::INFINITY), "inf");
    }
}
