//! Minimal string-backed error plumbing in the style of `anyhow` — the
//! build is fully offline and std-only, so we carry our own
//! [`Error`]/[`Result`]/[`Context`] instead of the crate.
//!
//! The crate-root macros [`crate::anyhow!`], [`crate::bail!`] and
//! [`crate::ensure!`] mirror their namesakes; downstream users invoke
//! them as `fastgauss::anyhow!(...)` etc.

use std::fmt;

/// A boxed-string error with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form on exit; keep
        // it human-readable.
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`. (`Error` itself deliberately does NOT
// implement `std::error::Error`, which keeps this blanket impl free of
// overlap with `impl From<T> for T`.)
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// Crate-wide result type defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style extension for attaching context to errors.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return an `Err` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn macro_and_display() {
        let e = crate::anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        assert_eq!(format!("{e:?}"), "bad value 7");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            crate::ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                crate::bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert_eq!(f(200).unwrap_err().to_string(), "too big");
    }

    #[test]
    fn context_prefixes() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        let e2 = io_fail().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(e2.to_string(), "pass 2: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
        fn g() -> Result<usize> {
            let n: usize = "xyz".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
    }
}
