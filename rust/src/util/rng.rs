//! PCG32 pseudo-random generator (O'Neill, PCG-XSH-RR 64/32).
//!
//! Deterministic and seedable so every dataset/bench/test in the repo is
//! reproducible from a `u64` seed. Not cryptographic — statistical
//! quality is more than enough for synthetic data generation and
//! property testing.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different stream
    /// ids give statistically independent sequences for the same seed.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our sizes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (one value per call; we discard the
    /// pair partner for simplicity — generation is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new_stream(1, 10);
        let mut b = Pcg32::new_stream(1, 11);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg32::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg32::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg32::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }
}
