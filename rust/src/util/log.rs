//! Minimal leveled logger. Level is read once from `FASTGAUSS_LOG`
//! (`error|warn|info|debug|trace`, default `info`) — no global mutable
//! state beyond a lazily initialized level.

// lint: allow(sync-bypass): process-wide one-time log-level init below the runtime layer — no scheduling to explore
use std::sync::OnceLock;

#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

// lint: allow(sync-bypass): process-wide one-time log-level init below the runtime layer — no scheduling to explore
static LEVEL: OnceLock<Level> = OnceLock::new();

/// The active log level.
pub fn level() -> Level {
    *LEVEL.get_or_init(|| {
        std::env::var("FASTGAUSS_LOG").map(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

/// True when a message at `l` should be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit a log line (used via the macros below).
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[fastgauss {:5}] {}", format!("{l:?}").to_lowercase(), args);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("error"), Level::Error);
        assert_eq!(Level::parse("WARN"), Level::Warn);
        assert_eq!(Level::parse("bogus"), Level::Info);
        assert_eq!(Level::parse("trace"), Level::Trace);
    }

    #[test]
    fn ordering_gates_output() {
        assert!(Level::Error < Level::Trace);
        // enabled() must hold for levels at or below the active one.
        let active = level();
        assert!(enabled(Level::Error) || active < Level::Error);
    }
}
