//! Run configuration: defaults → optional config file (`key = value`
//! lines) → CLI `--key value` overrides, in that precedence order.
//! (Hand-rolled because the offline vendor set has no clap/serde.)

use std::path::Path;

use crate::api::Method;
use crate::compute::simd::{Precision, SimdMode};
use crate::kernel::Kernel;
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

/// Every option key `RunConfig::set` accepts (aliases joined by `|`),
/// listed in unknown-key errors so typos are self-diagnosing.
pub const VALID_KEYS: &[&str] = &[
    "dataset",
    "n",
    "seed",
    "epsilon|eps",
    "algorithms|algos",
    "workers",
    "leaf-size|leaf_size",
    "multipliers",
    "bandwidth|h",
    "method",
    "kernel",
    "fast-exp|fast_exp",
    "simd",
    "precision",
    "slices",
    "out",
    "config",
];

/// The method names `--method` / `--algos` accept.
const VALID_METHODS: &str = "naive, fgt, ifgt, dfd, dfdo, dfto, dito, sliced, auto";

/// The kernel names `--kernel` accepts (see [`Kernel::VALID_NAMES`]).
const VALID_KERNELS: &str = Kernel::VALID_NAMES;

/// Everything the CLI subcommands need.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Dataset name from `data::by_name` (or a CSV path for `kde`).
    pub dataset: String,
    /// Points to generate.
    pub n: usize,
    pub seed: u64,
    pub epsilon: f64,
    /// Algorithms for table/sweep commands.
    pub algorithms: Vec<String>,
    pub workers: usize,
    pub leaf_size: usize,
    /// Bandwidth multipliers for the table command.
    pub multipliers: Vec<f64>,
    /// Explicit bandwidth (`0` = auto/Silverman-LSCV).
    pub bandwidth: f64,
    /// Summation method for the kde command (default: automatic
    /// selection by the session cost model).
    pub method: Method,
    /// Kernel family every command's session answers (default:
    /// gaussian, the paper protocol; non-Gaussian families run under
    /// the certified sum-of-Gaussians ε·W guarantee).
    pub kernel: Kernel,
    /// Certified fast-exp tiled base cases (default on; `false` forces
    /// the bit-exact reference path everywhere).
    pub fast_exp: bool,
    /// SIMD dispatch for the fast tiles (`auto` = detected backend,
    /// `off` = the bit-exact scalar table).
    pub simd: SimdMode,
    /// Fast-tile arithmetic precision (`f64` default; `f32` engages the
    /// mixed-precision tile where its certificate fits the ε/4 gate).
    pub precision: Precision,
    /// Starting slice count P for the sliced Fourier engine's
    /// P-doubling verification loop (`0` = the engine default).
    pub slices: usize,
    /// Output path for commands that write files.
    pub out: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "astro2d".into(),
            n: 5000,
            seed: 42,
            epsilon: 0.01,
            algorithms: vec![
                "naive".into(),
                "fgt".into(),
                "ifgt".into(),
                "dfd".into(),
                "dfdo".into(),
                "dfto".into(),
                "dito".into(),
            ],
            workers: 1,
            leaf_size: 32,
            multipliers: vec![1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3],
            bandwidth: 0.0,
            method: Method::Auto,
            kernel: Kernel::Gaussian,
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            slices: 0,
            out: None,
        }
    }
}

impl RunConfig {
    /// Apply one key/value pair.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "dataset" => self.dataset = value.to_string(),
            "n" => self.n = value.parse().context("n")?,
            "seed" => self.seed = value.parse().context("seed")?,
            "epsilon" | "eps" => self.epsilon = value.parse().context("epsilon")?,
            "algorithms" | "algos" => {
                let parts: Vec<String> = value.split(',').map(|s| s.trim().to_string()).collect();
                for p in &parts {
                    if Method::parse(p).is_none() {
                        bail!("unknown algorithm {p:?} (valid: {VALID_METHODS})");
                    }
                }
                self.algorithms = parts;
            }
            "workers" => self.workers = value.parse().context("workers")?,
            "leaf-size" | "leaf_size" => self.leaf_size = value.parse().context("leaf size")?,
            "method" => {
                self.method = Method::parse(value)
                    .ok_or_else(|| anyhow!("unknown method {value:?} (valid: {VALID_METHODS})"))?
            }
            "kernel" => {
                self.kernel = Kernel::parse(value)
                    .ok_or_else(|| anyhow!("unknown kernel {value:?} (valid: {VALID_KERNELS})"))?
            }
            "multipliers" => {
                self.multipliers = value
                    .split(',')
                    .map(|s| s.trim().parse::<f64>().context("multiplier"))
                    .collect::<Result<_>>()?
            }
            "bandwidth" | "h" => self.bandwidth = value.parse().context("bandwidth")?,
            "fast-exp" | "fast_exp" => {
                self.fast_exp = match value.to_ascii_lowercase().as_str() {
                    "true" | "1" | "on" | "yes" => true,
                    "false" | "0" | "off" | "no" => false,
                    other => bail!("fast-exp must be true/false (got {other:?})"),
                }
            }
            "simd" => {
                self.simd = SimdMode::parse(value).ok_or_else(|| {
                    anyhow!("unknown simd mode {value:?} (valid: {})", SimdMode::VALID)
                })?
            }
            "precision" => {
                self.precision = Precision::parse(value).ok_or_else(|| {
                    anyhow!("unknown precision {value:?} (valid: {})", Precision::VALID)
                })?
            }
            "slices" => self.slices = value.parse().context("slices")?,
            "out" => self.out = Some(value.to_string()),
            other => bail!(
                "unknown option --{other} (valid: {})",
                VALID_KEYS
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            ),
        }
        self.validate()
    }

    /// Load `key = value` lines (with `#` comments) from a file.
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("{}:{}: expected key = value", path.display(), lineno + 1))?;
            self.set(k.trim(), v.trim())
                .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
        }
        Ok(())
    }

    /// Consume `--key value` pairs (after an optional `--config file`).
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            let key = arg
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --option, got {arg:?}"))?;
            let value = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("--{key} needs a value"))?;
            if key == "config" {
                self.load_file(Path::new(value))?;
            } else {
                self.set(key, value)?;
            }
            i += 2;
        }
        Ok(())
    }

    /// Parse-time validation: reject impossible settings with a clear
    /// message instead of letting them fail as asserts deep inside the
    /// engines.
    fn validate(&self) -> Result<()> {
        if self.n == 0 {
            bail!("n must be positive");
        }
        if !(self.epsilon > 0.0) {
            bail!("epsilon must be positive (got {})", self.epsilon);
        }
        if self.workers == 0 {
            bail!("workers must be >= 1 (got 0)");
        }
        if self.leaf_size == 0 {
            bail!("leaf-size must be >= 1 (got 0)");
        }
        if self.multipliers.is_empty() {
            bail!("multipliers must be non-empty");
        }
        if let Some(&m) = self.multipliers.iter().find(|m| !(**m > 0.0 && m.is_finite())) {
            bail!("multipliers must be positive and finite (got {m})");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = RunConfig::default();
        assert_eq!(c.epsilon, 0.01);
        assert_eq!(c.multipliers.len(), 7);
        assert_eq!(c.algorithms.len(), 7);
    }

    #[test]
    fn cli_overrides() {
        let mut c = RunConfig::default();
        let args: Vec<String> = ["--n", "100", "--epsilon", "0.05", "--algos", "dito,dfd"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        c.apply_args(&args).unwrap();
        assert_eq!(c.n, 100);
        assert_eq!(c.epsilon, 0.05);
        assert_eq!(c.algorithms, vec!["dito", "dfd"]);
    }

    #[test]
    fn config_file_then_cli_precedence() {
        let p = std::env::temp_dir().join("fg_cfg_test.conf");
        std::fs::write(&p, "# comment\nn = 777\nseed = 9\n").unwrap();
        let mut c = RunConfig::default();
        let args: Vec<String> =
            ["--config", p.to_str().unwrap(), "--seed", "10"].iter().map(|s| s.to_string()).collect();
        c.apply_args(&args).unwrap();
        assert_eq!(c.n, 777);
        assert_eq!(c.seed, 10); // CLI wins over file
    }

    #[test]
    fn rejects_unknown_and_invalid() {
        let mut c = RunConfig::default();
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("n", "0").is_err());
        assert!(c.set("epsilon", "-1").is_err());
        assert!(c.set("multipliers", "").is_err());
        let args = vec!["--n".to_string()];
        assert!(c.apply_args(&args).is_err());
    }

    #[test]
    fn unknown_key_error_lists_all_valid_keys() {
        let mut c = RunConfig::default();
        let msg = c.set("bogus", "1").unwrap_err().to_string();
        for key in VALID_KEYS {
            let first = key.split('|').next().unwrap();
            assert!(msg.contains(first), "error must list --{first}: {msg}");
        }
    }

    #[test]
    fn method_key_parses_and_rejects_with_listing() {
        let mut c = RunConfig::default();
        assert_eq!(c.method, Method::Auto, "auto must be the default");
        c.set("method", "dito").unwrap();
        assert_eq!(c.method, Method::Dito);
        c.set("method", "sliced").unwrap();
        assert_eq!(c.method, Method::Sliced);
        c.set("method", "AUTO").unwrap();
        assert_eq!(c.method, Method::Auto);
        let msg = c.set("method", "bogus").unwrap_err().to_string();
        assert!(msg.contains("dito") && msg.contains("sliced") && msg.contains("auto"), "{msg}");
    }

    #[test]
    fn slices_key_parses_and_rejects() {
        let mut c = RunConfig::default();
        assert_eq!(c.slices, 0, "0 (engine default) must be the default");
        c.set("slices", "256").unwrap();
        assert_eq!(c.slices, 256);
        assert!(c.set("slices", "many").is_err());
        assert_eq!(c.slices, 256, "failed set must not change the value");
    }

    #[test]
    fn kernel_key_parses_and_rejects_with_listing() {
        let mut c = RunConfig::default();
        assert_eq!(c.kernel, Kernel::Gaussian, "gaussian must be the default");
        c.set("kernel", "laplace").unwrap();
        assert_eq!(c.kernel, Kernel::Laplace);
        c.set("kernel", "MATERN32").unwrap();
        assert_eq!(c.kernel, Kernel::Matern32);
        c.set("kernel", "imq").unwrap();
        assert_eq!(c.kernel, Kernel::InvMultiquadric);
        // an unknown value is rejected at parse time (never a silent
        // Gaussian default), with every valid name in the message
        let msg = c.set("kernel", "bogus").unwrap_err().to_string();
        for k in Kernel::ALL {
            assert!(msg.contains(k.name()), "error must list {}: {msg}", k.name());
        }
        assert_eq!(c.kernel, Kernel::InvMultiquadric, "failed set must not change the value");
    }

    #[test]
    fn parse_time_bounds_checks() {
        // fresh config per case: a failed set leaves its value behind
        let msg = RunConfig::default().set("workers", "0").unwrap_err().to_string();
        assert!(msg.contains(">= 1"), "{msg}");
        let msg = RunConfig::default().set("leaf-size", "0").unwrap_err().to_string();
        assert!(msg.contains(">= 1"), "{msg}");
        assert!(RunConfig::default().set("multipliers", "1,0,10").is_err());
        assert!(RunConfig::default().set("multipliers", "0.5,2").is_ok());
        // algos validated at parse time, with the listing in the error
        let msg = RunConfig::default().set("algos", "dito,bogus").unwrap_err().to_string();
        assert!(msg.contains("bogus") && msg.contains("dfdo"), "{msg}");
        assert!(RunConfig::default().set("algos", "auto,dito").is_ok());
    }

    #[test]
    fn fast_exp_key_parses_and_rejects() {
        let mut c = RunConfig::default();
        assert!(c.fast_exp, "fast-exp must default on");
        c.set("fast-exp", "false").unwrap();
        assert!(!c.fast_exp);
        c.set("fast_exp", "ON").unwrap();
        assert!(c.fast_exp);
        c.set("fast-exp", "0").unwrap();
        assert!(!c.fast_exp);
        let msg = c.set("fast-exp", "maybe").unwrap_err().to_string();
        assert!(msg.contains("true/false"), "{msg}");
    }

    #[test]
    fn simd_key_parses_and_rejects_with_listing() {
        let mut c = RunConfig::default();
        assert_eq!(c.simd, SimdMode::Auto, "auto must be the default");
        c.set("simd", "off").unwrap();
        assert_eq!(c.simd, SimdMode::Off);
        c.set("simd", "SCALAR").unwrap();
        assert_eq!(c.simd, SimdMode::Off);
        c.set("simd", "auto").unwrap();
        assert_eq!(c.simd, SimdMode::Auto);
        // an unknown value is rejected at parse time (never a silent
        // auto default), with every valid name in the message
        let msg = c.set("simd", "avx512").unwrap_err().to_string();
        assert!(msg.contains("auto") && msg.contains("off"), "{msg}");
        assert_eq!(c.simd, SimdMode::Auto, "failed set must not change the value");
    }

    #[test]
    fn precision_key_parses_and_rejects_with_listing() {
        let mut c = RunConfig::default();
        assert_eq!(c.precision, Precision::F64, "f64 must be the default");
        c.set("precision", "f32").unwrap();
        assert_eq!(c.precision, Precision::F32);
        c.set("precision", "F64").unwrap();
        assert_eq!(c.precision, Precision::F64);
        let msg = c.set("precision", "f16").unwrap_err().to_string();
        assert!(msg.contains("f64") && msg.contains("f32"), "{msg}");
        assert_eq!(c.precision, Precision::F64, "failed set must not change the value");
    }

    #[test]
    fn multiplier_parsing() {
        let mut c = RunConfig::default();
        c.set("multipliers", "0.1, 1, 10").unwrap();
        assert_eq!(c.multipliers, vec![0.1, 1.0, 10.0]);
    }
}
