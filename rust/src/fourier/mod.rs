//! Truncated-Fourier fast summation of the **sliced** Gaussian kernel
//! in one dimension — the per-slice workhorse behind
//! [`crate::algo::sliced`] (Hertrich-style slicing, arXiv 2401.08260).
//!
//! # The sliced kernel
//!
//! For a unit vector ξ drawn uniformly on the sphere S^{D−1} and any
//! z ∈ R^D, the repo's Gaussian kernel K(δ) = exp(−δ²/(2h²)) satisfies
//!
//! ```text
//! E_ξ [ f(⟨ξ, z⟩) ] = exp(−‖z‖² / (2h²)),
//! f(t) = ₁F₁(D/2; 1/2; −t²/(2h²))          (confluent hypergeometric)
//! ```
//!
//! because the even moments of a sphere coordinate are
//! E[u^{2k}] = (1/2)_k / (D/2)_k, which turns the Gaussian's Taylor
//! series in ‖z‖² into the ₁F₁ series in t². For **odd** D = 2m+1,
//! Kummer's transformation collapses ₁F₁ to a degree-m polynomial
//! times a Gaussian:
//!
//! ```text
//! f(t) = e^{−x} Σ_{k=0}^{m} q_k x^k,   x = t²/(2h²),
//! q_0 = 1,   q_{k+1} = q_k · (k − m) / ((k + 1/2)(k + 1)).
//! ```
//!
//! (Checks: D = 1 gives f = e^{−x}; D = 3 gives e^{−x}(1 − 2x);
//! f(0) = 1 always.) Even dimensions are handled by embedding into
//! D+1: append a zero coordinate to every point and slice R^{D+1} —
//! the projections ⟨ξ, z⟩ only ever see the first D components.
//!
//! # Fourier representation and the certified bounds
//!
//! With the convention f̂(ν) = ∫ f(t) e^{−2πiνt} dt, the sliced kernel
//! has the closed-form transform
//!
//! ```text
//! f̂(ν) = C · |ν|^{2m} · e^{−aν²},   a = 2π²h²,
//! C = a^{m+1/2} / Γ(m+1/2),   ln Γ(m+1/2) = ln√π + Σ_{i=1}^{m} ln(i−1/2),
//! ```
//!
//! normalized so Σ_k f̂(k) ≈ ∫ f̂ = f(0) = 1. Restricting points to
//! [−1/8, 1/8] (pairwise differences z ∈ [−1/4, 1/4]) and truncating
//! the periodization g_K(z) = f̂(0) + 2 Σ_{k=1}^{K} f̂(k) cos(2πkz)
//! gives the pointwise certificate
//!
//! ```text
//! |f(z) − g_K(z)| ≤ aliasing + truncation
//! aliasing    ≤ 2 Σ_{n≥1} B(n − 1/4),  B ≥ |f| off the base period,
//! truncation  ≤ 2 Σ_{k>K} f̂(k) ≤ 4 f̂(K+1)   once f̂(K+2)/f̂(K+1) ≤ 1/2,
//! ```
//!
//! both evaluated in log space with geometric-tail guards (see
//! [`plan_slice`]). The caller shrinks the working bandwidth
//! (h̃ = γh, with points scaled by the same γ — the invariance
//! f_{γh}(γδ) = f_h(δ) is exact) until the aliasing side is small
//! enough, then picks the smallest K whose truncation tail fits.
//!
//! The factored sums ([`fast_sum`]) then cost O((N+M)·K) per slice:
//! A_k = Σ_n w_n e^{−2πik a_n} by a per-point complex recurrence, and
//! s_m = f̂(0)A_0 + 2 Σ_k f̂(k) Re(A_k e^{2πik b_m}) per query, both in
//! fixed ascending order so results are bit-identical across pool
//! widths and repeated runs.

/// Scaled points must lie in [−`SCALED_HALF_RANGE`, `SCALED_HALF_RANGE`];
/// the aliasing bound is derived for this window (differences stay
/// within one quarter period, every alias is ≥ 3/4 away).
pub const SCALED_HALF_RANGE: f64 = 0.125;

/// Hard cap on the truncation order K; a slice that cannot meet its
/// error target by this order reports failure instead of looping.
pub const K_CAP: usize = 8192;

/// Initial working-bandwidth cap: γ is chosen so h̃ = γh ≤ this before
/// any aliasing-driven halving (aliasing decays like e^{−c/h̃²}).
const H_TILDE_MAX: f64 = 0.05;

/// Aliasing-driven γ halvings before giving up.
const MAX_HALVINGS: u32 = 64;

/// Dimension-dependent pieces of the sliced kernel, shared by every
/// slice of one problem: the polynomial coefficients q_k and the
/// constants of the log-space Fourier/alias bounds.
#[derive(Clone, Debug)]
pub struct SliceProfile {
    /// Polynomial degree m; the sliced (odd) dimension is 2m+1.
    m: usize,
    /// q_0..q_m of the closed-form sliced kernel.
    q: Vec<f64>,
    /// ln Σ_k |q_k| (for the aliasing majorant).
    ln_q_abs_sum: f64,
    /// ln Γ(m + 1/2).
    ln_gamma_half: f64,
}

impl SliceProfile {
    /// Profile for data dimension `d` ≥ 1. Even `d` is embedded into
    /// `d + 1` (the projection directions get one extra component that
    /// multiplies an implicit zero coordinate).
    pub fn for_dim(d: usize) -> Self {
        assert!(d >= 1, "dimension must be positive");
        let odd = if d % 2 == 1 { d } else { d + 1 };
        let m = (odd - 1) / 2;
        let mut q = Vec::with_capacity(m + 1);
        q.push(1.0f64);
        for k in 0..m {
            let kf = k as f64;
            let next = q[k] * (kf - m as f64) / ((kf + 0.5) * (kf + 1.0));
            q.push(next);
        }
        let abs_sum: f64 = q.iter().map(|c| c.abs()).sum();
        let mut ln_gamma_half = 0.5 * std::f64::consts::PI.ln();
        for i in 1..=m {
            ln_gamma_half += (i as f64 - 0.5).ln();
        }
        SliceProfile { m, q, ln_q_abs_sum: abs_sum.ln(), ln_gamma_half }
    }

    /// The odd dimension 2m+1 the projections are drawn in.
    pub fn sliced_dim(&self) -> usize {
        2 * self.m + 1
    }

    /// Reference (slow) evaluation of the sliced kernel f(t) at
    /// bandwidth `h`: e^{−x} Σ q_k x^k with x = t²/(2h²). Horner in
    /// descending degree.
    pub fn eval(&self, h: f64, t: f64) -> f64 {
        let x = t * t / (2.0 * h * h);
        let mut poly = 0.0;
        for &c in self.q.iter().rev() {
            poly = poly * x + c;
        }
        (-x).exp() * poly
    }

    /// ln f̂(k) for integer frequency k ≥ 1 at working bandwidth
    /// `h_tilde`: ln C + 2m·ln k − a·k².
    fn ln_coeff(&self, h_tilde: f64, k: usize) -> f64 {
        let a = 2.0 * std::f64::consts::PI.powi(2) * h_tilde * h_tilde;
        let ln_c = (self.m as f64 + 0.5) * a.ln() - self.ln_gamma_half;
        let kf = k as f64;
        ln_c + 2.0 * self.m as f64 * kf.ln() - a * kf * kf
    }

    /// f̂(0): zero for m ≥ 1 (the |ν|^{2m} factor), C for m = 0.
    fn coeff_zero(&self, h_tilde: f64) -> f64 {
        if self.m == 0 {
            let a = 2.0 * std::f64::consts::PI.powi(2) * h_tilde * h_tilde;
            (0.5 * a.ln() - self.ln_gamma_half).exp()
        } else {
            0.0
        }
    }

    /// Certified aliasing bound at working bandwidth `h_tilde` ≤
    /// [`H_TILDE_MAX`], for differences within one quarter period:
    /// 2 Σ_{n≥1} B(n − 1/4) with the log-space majorant
    /// B(u) ≤ Qs·(2m/e)^m·e^{−x/2}, x = u²/(2h̃²) (from
    /// Σ|q_k|x^k ≤ Qs·x^m for x ≥ 1 and x^m e^{−x/2} ≤ (2m/e)^m),
    /// and the geometric tail r = e^{−(x₂−x₁)/2} (the exponent gaps
    /// only grow with n).
    fn alias_bound(&self, h_tilde: f64) -> f64 {
        let inv2h2 = 1.0 / (2.0 * h_tilde * h_tilde);
        let x1 = 0.75 * 0.75 * inv2h2;
        if x1 < 1.0 {
            // majorant needs x ≥ 1; treat as uncontrolled
            return f64::INFINITY;
        }
        let m = self.m as f64;
        let ln_peak = if self.m == 0 { 0.0 } else { m * (2.0 * m / std::f64::consts::E).ln() };
        let ln_first = self.ln_q_abs_sum + ln_peak - 0.5 * x1;
        let gap = (1.75 * 1.75 - 0.75 * 0.75) * inv2h2; // x₂ − x₁
        let r = (-0.5 * gap).exp();
        if r >= 0.5 {
            return f64::INFINITY;
        }
        2.0 * ln_first.exp() / (1.0 - r)
    }
}

/// A certified per-slice evaluation plan: the scaling that maps raw
/// projections into the Fourier window, the truncated coefficient
/// table, and the pointwise error certificate.
#[derive(Clone, Debug)]
pub struct SlicePlan {
    /// Scale factor: work in u = γ·(t − center), bandwidth h̃ = γ·h.
    pub gamma: f64,
    /// Working bandwidth γ·h.
    pub h_tilde: f64,
    /// Truncation order K.
    pub k_max: usize,
    /// f̂(k) for k = 0..=K at bandwidth h̃.
    pub coeffs: Vec<f64>,
    /// Certified pointwise bound: |f(z) − g_K(z)| ≤ `bound` for every
    /// difference z of scaled points within the window.
    pub bound: f64,
}

/// Build a plan for one slice: raw projections span `half_range`
/// around their midpoint, the kernel bandwidth is `h`, and the plan
/// must certify a pointwise error ≤ `target`. Fails (with a reason)
/// when no γ-halving / truncation order within the caps gets there —
/// the engine surfaces that as the paper's ∞ verdict.
pub fn plan_slice(
    profile: &SliceProfile,
    h: f64,
    half_range: f64,
    target: f64,
) -> Result<SlicePlan, String> {
    assert!(h > 0.0 && target > 0.0);
    let span = half_range.max(1e-12);
    let mut gamma = (SCALED_HALF_RANGE / span).min(H_TILDE_MAX / h);
    let mut alias = f64::INFINITY;
    let mut halvings = 0;
    while halvings <= MAX_HALVINGS {
        alias = profile.alias_bound(gamma * h);
        if alias <= 0.5 * target {
            break;
        }
        gamma *= 0.5;
        halvings += 1;
    }
    if alias > 0.5 * target {
        return Err(format!(
            "aliasing bound {alias:.2e} above {:.2e} after {MAX_HALVINGS} γ-halvings",
            0.5 * target
        ));
    }
    let h_tilde = gamma * h;
    let trunc_target = 0.5 * target;
    let mut coeffs = vec![profile.coeff_zero(h_tilde)];
    let mut k = 1usize;
    loop {
        let fk = profile.ln_coeff(h_tilde, k).exp();
        // Accept K = k−1 when the tail past it is certified: the
        // coefficient ratio is strictly decreasing, so once
        // f̂(k+1)/f̂(k) ≤ 1/2 the tail 2Σ_{j≥k} f̂(j) ≤ 4 f̂(k).
        let rho = (profile.ln_coeff(h_tilde, k + 1) - profile.ln_coeff(h_tilde, k)).exp();
        if rho <= 0.5 && 4.0 * fk <= trunc_target {
            let trunc = 4.0 * fk;
            return Ok(SlicePlan { gamma, h_tilde, k_max: k - 1, coeffs, bound: alias + trunc });
        }
        coeffs.push(fk);
        k += 1;
        if k > K_CAP {
            return Err(format!(
                "truncation order exceeds cap {K_CAP} at h̃ = {h_tilde:.3e} \
                 (target {trunc_target:.2e})"
            ));
        }
    }
}

/// Factored 1-D fast sum: `out[m] = Σ_n w[n] · g_K(b[m] − a[n])` for
/// the plan's truncated periodization g_K. Inputs are **scaled**
/// projections (|a|, |b| ≤ [`SCALED_HALF_RANGE`]); `out` is
/// overwritten. Deterministic: both loops accumulate in ascending
/// index/frequency order.
pub fn fast_sum(plan: &SlicePlan, a: &[f64], w: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), w.len());
    assert_eq!(b.len(), out.len());
    let kk = plan.k_max;
    let two_pi = 2.0 * std::f64::consts::PI;
    // A_k = Σ_n w_n e^{−2πik a_n}, k = 0..=K, complex as (re, im).
    let mut are = vec![0.0f64; kk + 1];
    let mut aim = vec![0.0f64; kk + 1];
    for (an, wn) in a.iter().zip(w) {
        let theta = -two_pi * an;
        let (zr, zi) = (theta.cos(), theta.sin());
        let (mut pr, mut pi) = (*wn, 0.0f64);
        for k in 0..=kk {
            are[k] += pr;
            aim[k] += pi;
            let nr = pr * zr - pi * zi;
            pi = pr * zi + pi * zr;
            pr = nr;
        }
    }
    // s_m = f̂(0)·A_0 + 2 Σ_{k≥1} f̂(k)·Re(A_k e^{2πik b_m}).
    for (bm, slot) in b.iter().zip(out.iter_mut()) {
        let theta = two_pi * bm;
        let (zr, zi) = (theta.cos(), theta.sin());
        let (mut pr, mut pi) = (1.0f64, 0.0f64);
        let mut s = plan.coeffs[0] * are[0];
        for k in 1..=kk {
            let nr = pr * zr - pi * zi;
            pi = pr * zi + pi * zr;
            pr = nr;
            s += 2.0 * plan.coeffs[k] * (are[k] * pr - aim[k] * pi);
        }
        *slot = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn polynomial_coefficients_match_closed_forms() {
        // d = 1 → m = 0 → f = e^{−x}
        let p1 = SliceProfile::for_dim(1);
        assert_eq!(p1.q, vec![1.0]);
        // d = 3 → m = 1 → f = e^{−x}(1 − 2x)
        let p3 = SliceProfile::for_dim(3);
        assert_eq!(p3.q.len(), 2);
        assert!((p3.q[0] - 1.0).abs() < 1e-15 && (p3.q[1] + 2.0).abs() < 1e-15);
        // even dims embed upward
        assert_eq!(SliceProfile::for_dim(4).sliced_dim(), 5);
        assert_eq!(SliceProfile::for_dim(20).sliced_dim(), 21);
        // f(0) = 1 in every dimension
        for d in [1, 2, 3, 5, 20, 50] {
            let p = SliceProfile::for_dim(d);
            assert!((p.eval(0.37, 0.0) - 1.0).abs() < 1e-12, "d={d}");
        }
    }

    #[test]
    fn ln_gamma_half_matches_known_values() {
        let sqrt_pi = std::f64::consts::PI.sqrt();
        // Γ(1/2) = √π, Γ(3/2) = √π/2, Γ(5/2) = 3√π/4
        assert!((SliceProfile::for_dim(1).ln_gamma_half - sqrt_pi.ln()).abs() < 1e-12);
        assert!((SliceProfile::for_dim(3).ln_gamma_half - (sqrt_pi / 2.0).ln()).abs() < 1e-12);
        assert!((SliceProfile::for_dim(5).ln_gamma_half - (3.0 * sqrt_pi / 4.0).ln()).abs() < 1e-12);
    }

    #[test]
    fn slicing_expectation_reproduces_the_gaussian() {
        // E_ξ f(⟨ξ, z⟩) = exp(−‖z‖²/(2h²)) — Monte Carlo check in d = 5.
        let d = 5;
        let profile = SliceProfile::for_dim(d);
        let h = 0.4;
        let z = [0.3, -0.1, 0.2, 0.05, -0.25];
        let znorm2: f64 = z.iter().map(|v| v * v).sum();
        let expect = (-znorm2 / (2.0 * h * h)).exp();
        let mut rng = Pcg32::new(42);
        let trials = 40_000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let g: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let norm = g.iter().map(|v| v * v).sum::<f64>().sqrt();
            let t: f64 = g.iter().zip(&z).map(|(gi, zi)| gi / norm * zi).sum();
            acc += profile.eval(h, t);
        }
        let mc = acc / trials as f64;
        assert!((mc - expect).abs() < 0.02, "mc={mc} expect={expect}");
    }

    #[test]
    fn fourier_coefficients_sum_to_one() {
        // Σ_k f̂(k) = Σ_n f(n) ≈ f(0) = 1 by Poisson summation.
        for d in [1, 3, 21, 51] {
            let profile = SliceProfile::for_dim(d);
            let h_tilde = 0.03;
            let mut sum = profile.coeff_zero(h_tilde);
            for k in 1..=4096 {
                sum += 2.0 * profile.ln_coeff(h_tilde, k).exp();
            }
            assert!((sum - 1.0).abs() < 1e-10, "d={d} sum={sum}");
        }
    }

    #[test]
    fn plan_certifies_and_truncates() {
        let profile = SliceProfile::for_dim(21);
        let plan = plan_slice(&profile, 0.5, 2.0, 1e-6).expect("plan");
        assert!(plan.k_max >= 1 && plan.k_max <= K_CAP);
        assert!(plan.bound <= 1e-6);
        assert!(plan.h_tilde <= H_TILDE_MAX + 1e-15);
        assert!(plan.gamma * 2.0 <= SCALED_HALF_RANGE + 1e-15);
        // d = 51 at the same bandwidth needs γ-halvings (the alias
        // majorant blows up at h̃ = 0.05) but still certifies.
        let p51 = SliceProfile::for_dim(51);
        let plan51 = plan_slice(&p51, 0.5, 2.0, 1e-6).expect("plan51");
        assert!(plan51.h_tilde < plan.h_tilde);
        assert!(plan51.bound <= 1e-6);
    }

    #[test]
    fn plan_reports_hopeless_targets() {
        let profile = SliceProfile::for_dim(21);
        assert!(plan_slice(&profile, 0.5, 2.0, 1e-300).is_err());
    }

    #[test]
    fn fast_sum_matches_direct_cosine_series() {
        let profile = SliceProfile::for_dim(7);
        let plan = plan_slice(&profile, 0.3, 1.5, 1e-8).expect("plan");
        let mut rng = Pcg32::new(7);
        let n = 40;
        let a: Vec<f64> = (0..n).map(|_| rng.uniform_in(-0.125, 0.125)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 2.0)).collect();
        let b: Vec<f64> = (0..25).map(|_| rng.uniform_in(-0.125, 0.125)).collect();
        let mut fast = vec![0.0; b.len()];
        fast_sum(&plan, &a, &w, &b, &mut fast);
        for (m, bm) in b.iter().enumerate() {
            let mut direct = 0.0;
            for (an, wn) in a.iter().zip(&w) {
                let z = bm - an;
                let mut g = plan.coeffs[0];
                for k in 1..=plan.k_max {
                    g += 2.0 * plan.coeffs[k]
                        * (2.0 * std::f64::consts::PI * k as f64 * z).cos();
                }
                direct += wn * g;
            }
            assert!((fast[m] - direct).abs() < 1e-9, "m={m}: {} vs {direct}", fast[m]);
        }
    }

    #[test]
    fn fast_sum_error_stays_within_the_certificate() {
        // Against the true sliced kernel Σ w f(γ(t_b − t_a)) at the
        // working bandwidth — the pointwise certificate times Σw.
        let profile = SliceProfile::for_dim(21);
        let h = 0.4;
        let half_range = 1.0;
        let target = 1e-7;
        let plan = plan_slice(&profile, h, half_range, target).expect("plan");
        let mut rng = Pcg32::new(11);
        let n = 60;
        let raw_a: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.5, 1.5)).collect();
        let raw_b: Vec<f64> = (0..30).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let a: Vec<f64> = raw_a.iter().map(|t| plan.gamma * t).collect();
        let b: Vec<f64> = raw_b.iter().map(|t| plan.gamma * t).collect();
        let mut fast = vec![0.0; b.len()];
        fast_sum(&plan, &a, &w, &b, &mut fast);
        let wsum: f64 = w.iter().sum();
        for (m, bm) in b.iter().enumerate() {
            let exact: f64 = a
                .iter()
                .zip(&w)
                .map(|(an, wn)| wn * profile.eval(plan.h_tilde, bm - an))
                .sum();
            let err = (fast[m] - exact).abs();
            // small slack over the certificate for fp roundoff
            assert!(err <= plan.bound * wsum + 1e-10, "m={m} err={err:.3e}");
        }
    }

    #[test]
    fn scaling_invariance_is_exact() {
        // f_{γh}(γδ) = f_h(δ): x = t²/(2h²) is γ-invariant.
        let profile = SliceProfile::for_dim(9);
        for gamma in [0.5, 0.01, 3.0] {
            let (h, delta) = (0.7, 0.33);
            let lhs = profile.eval(gamma * h, gamma * delta);
            let rhs = profile.eval(h, delta);
            assert!((lhs - rhs).abs() < 1e-14, "γ={gamma}");
        }
    }
}
