//! Automatic error control (paper Section 5).
//!
//! Theorem 2: any approximation A of reference node R's contribution
//! with absolute error E_A may be accepted while preserving the *global
//! relative* tolerance ∀q |G̃(q)−G(q)| ≤ ε·G(q), provided
//! `E_A ≤ (W_R/W)·ε·G_Q^min`.
//!
//! The improved scheme converts this into a **token ledger**: accounting
//! a reference node R at query node Q "costs" effective weight
//! W′ = W·E_A/(ε·G_Q^min); the leftover W_R − W′ (positive when the
//! approximation was cheaper than its entitlement, e.g. W_R itself for
//! exhaustive computation) is banked in `Q.W_T` and may be spent by
//! later prunes at the same query node whose W′ exceeds their W_R.
//! Soundness: along any root→leaf path every reference point's weight is
//! accounted exactly once, and every banked token at a node came from
//! weight accounted at that node for the same query subset, so the
//! per-point error telescopes to ≤ ε·G_Q^min ≤ ε·G(q).
//!
//! [`QueryLedger`] also owns the hierarchical running bounds
//! (G_Q^min / G_Q^max deltas, far-field estimates G_Q^est) that the
//! dual-tree algorithms maintain per query node.

use crate::compute::simd::Precision;

/// Decision returned by the token rule for one candidate prune.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum PruneDecision {
    /// Prune accepted; apply `token_delta` to the node's ledger
    /// (positive = banked leftover, negative = spent tokens).
    Accept { token_delta: f64 },
    /// Not enough budget; the pair must be expanded (or approximated
    /// more accurately).
    Reject,
}

/// A prune-acceptance policy, lifted to the type system so the generic
/// dual-tree traversal monomorphizes it: the runtime `use_tokens`
/// switch becomes the associated const [`USE_TOKENS`], and every
/// `if use_tokens` in the hot loop folds away per instantiation.
///
/// Two policies exist, mirroring the paper: [`Theorem2`] (the classic
/// per-node rule, DFD) and [`TokenLedger`] (the Section-5 banked-token
/// scheme, DFDO/DFTO/DITO).
///
/// [`USE_TOKENS`]: PruneRule::USE_TOKENS
pub trait PruneRule: Copy + Send + Sync + 'static {
    /// Whether slack budget is banked in the W_T ledger.
    const USE_TOKENS: bool;

    /// Decide one candidate prune (see [`token_rule`] for the
    /// parameters). Inlined so `USE_TOKENS` constant-folds.
    #[inline]
    fn decide(
        err: f64,
        weight: f64,
        available_tokens: f64,
        gq_min: f64,
        eps: f64,
        total_weight: f64,
    ) -> PruneDecision {
        token_rule(err, weight, available_tokens, gq_min, eps, total_weight, Self::USE_TOKENS)
    }
}

/// Plain Theorem-2 acceptance: each reference node must fit its own
/// entitlement `E_A ≤ (W_R/W)·ε·G_Q^min`; no banking (DFD).
#[derive(Copy, Clone, Debug, Default)]
pub struct Theorem2;

impl PruneRule for Theorem2 {
    const USE_TOKENS: bool = false;
}

/// The paper's improved control: leftover entitlement is banked in the
/// per-node W_T ledger and spent by later prunes (DFDO/DFTO/DITO).
#[derive(Copy, Clone, Debug, Default)]
pub struct TokenLedger;

impl PruneRule for TokenLedger {
    const USE_TOKENS: bool = true;
}

/// The token rule in one place, used by DFDO/DFTO/DITO (with
/// `use_tokens = true`) and plain DFD (with `use_tokens = false`).
/// Monomorphized callers go through [`PruneRule::decide`] instead.
///
/// * `err`: absolute error bound E_A of the candidate approximation.
/// * `weight`: W_R of the reference node being accounted.
/// * `available_tokens`: current Q.W_T.
/// * `gq_min`: current lower bound G_Q^min (≥ 0).
/// * `eps`, `total_weight`: ε and W.
pub fn token_rule(
    err: f64,
    weight: f64,
    available_tokens: f64,
    gq_min: f64,
    eps: f64,
    total_weight: f64,
    use_tokens: bool,
) -> PruneDecision {
    debug_assert!(err >= 0.0 && weight > 0.0);
    if err == 0.0 {
        // exhaustive-quality approximation: bank the full entitlement
        return PruneDecision::Accept { token_delta: if use_tokens { weight } else { 0.0 } };
    }
    if gq_min <= 0.0 {
        return PruneDecision::Reject;
    }
    // effective weight consumed by this approximation
    let w_eff = total_weight * err / (eps * gq_min);
    if !use_tokens {
        return if w_eff <= weight {
            PruneDecision::Accept { token_delta: 0.0 }
        } else {
            PruneDecision::Reject
        };
    }
    let needed = w_eff - weight; // tokens required (negative = leftover)
    if needed <= available_tokens {
        PruneDecision::Accept { token_delta: -needed }
    } else {
        PruneDecision::Reject
    }
}

// ---- ε-budget split for the certified fast base case ----

/// How one evaluate's ε budget is divided between the tree's prune
/// accounting and the certified error of the tiled fast base case
/// (see [`split_epsilon`]).
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct EpsSplit {
    /// The ε handed to every prune test: the user's ε minus the
    /// base-case reservation. Equal to the user's ε when `fast` is off.
    pub tree_eps: f64,
    /// Certified per-pair relative error of the drained base cases
    /// (0.0 when `fast` is off).
    pub base_rel_err: f64,
    /// Whether the tiled fast kernel is admitted for this evaluate.
    pub fast: bool,
    /// Whether the admitted fast kernel may additionally store the
    /// reference lanes, weights and value tile in f32 (implies `fast`;
    /// its larger certified bound is what `base_rel_err` then carries).
    pub f32_tile: bool,
}

/// Certified per-pair relative error of the fast tiled base case at
/// bandwidth `h` on data whose squared norms are ≤ `max_sq_norm`:
///
/// * the fast-exp polynomial bound
///   [`crate::compute::fastexp::EXP_MAX_REL_ERR`], plus
/// * the norms-trick cancellation term. Computing
///   `‖q‖² + ‖r‖² − 2·q·r` in f64 perturbs the squared distance by at
///   most `|Δsq| ≤ 4(D+3)·ε_mach·max‖x‖²` (a standard γ-style bound:
///   each norm is a D-term nonneg sum, the dot a D-term sum bounded by
///   `‖q‖·‖r‖`, and `(‖q‖+‖r‖)² ≤ 4·max‖x‖²`; `f64::EPSILON` = 2u
///   already doubles the per-op unit, absorbing the combination slop).
///   The kernel turns that into a relative factor
///   `e^(Δsq/2h²) − 1 ≤ 1.2·Δsq/(2h²)` — the linearization is valid
///   for ratios ≤ 0.25, which [`split_epsilon`]'s admission gate
///   (`bound ≤ ε/4 ≤ 0.25`) guarantees.
///
/// The bound is h-dependent: it blows up as `1/h²`, which is exactly
/// why tiny-bandwidth evaluates automatically fall back to the
/// bit-exact base case instead of carrying an unpayable reservation.
pub fn base_case_rel_err(dim: usize, h: f64, max_sq_norm: f64) -> f64 {
    let dsq = 4.0 * (dim as f64 + 3.0) * f64::EPSILON * max_sq_norm;
    let ratio = dsq / (2.0 * h * h);
    crate::compute::fastexp::EXP_MAX_REL_ERR + 1.2 * ratio
}

/// Certified per-pair relative error of the *mixed-precision* tiled
/// base case: reference coordinates, norms, weights and the value tile
/// stored as f32, dot products and exponent assembly in f32, exp and
/// accumulation in f64 (see `compute::tile::gauss_sums_fast_f32_on_loaded`).
///
/// Same shape as [`base_case_rel_err`], with two extra charges:
///
/// * the squared-distance perturbation now runs at `ε_f32`
///   (`f32::EPSILON`, ≈ 2u₃₂) instead of `f64::EPSILON`, and storing
///   each coordinate as f32 perturbs every norm/dot *input*
///   relatively by ≤ u₃₂ before any arithmetic — folded in by widening
///   the γ-style constant from 4(D+3) to 4(D+5); the kernel turns the
///   resulting `|Δsq| ≤ 4(D+5)·ε_f32·max‖x‖²` into a relative factor
///   via the same `e^x − 1 ≤ 1.2x` linearization (valid under the
///   [`split_epsilon_prec`] gate `bound ≤ ε/4 ≤ 0.25`);
/// * a flat `2·ε_f32` for rounding each weight to f32 (the per-pair
///   products `w_j·v_j` and the sum itself stay f64).
///
/// At moderate bandwidths on unit-scale data this lands around 1e-4,
/// so f32 tiles are affordable at ε = 1e-2 but are rejected (falling
/// back to the certified f64 fast path) at ε = 1e-4 — exactly the
/// automatic-demotion behavior the gate is for.
pub fn base_case_rel_err_f32(dim: usize, h: f64, max_sq_norm: f64) -> f64 {
    let eps32 = f32::EPSILON as f64;
    let dsq = 4.0 * (dim as f64 + 5.0) * eps32 * max_sq_norm;
    let ratio = dsq / (2.0 * h * h);
    crate::compute::fastexp::EXP_MAX_REL_ERR + 2.0 * eps32 + 1.2 * ratio
}

/// Decide whether this evaluate may run the fast tiled base case, and
/// reserve its certified error out of the ε budget if so.
///
/// Soundness: with the fast path on, the traversal's bounds (kl/ku,
/// FD estimates, series operators) are all still computed with exact
/// libm kernels — only the *drained base-case sums* are approximate,
/// each pair within `base_rel_err` relatively. So
///
/// ```text
///   |G̃(q) − G(q)| ≤ tree_eps·G(q)  +  base_rel_err·G_base(q)
///                 ≤ (tree_eps + base_rel_err)·G(q)  =  ε·G(q),
/// ```
///
/// since the base-case portion `G_base(q) ≤ G(q)` and `G_Q^min` never
/// reads an approximate value (base-case bounds are registered from
/// exact `kl` at enqueue time — see `algo::dualtree`). The fast path is
/// admitted only when its certified bound costs at most a quarter of
/// the budget, so `tree_eps ≥ 3ε/4` and pruning power is essentially
/// unaffected. (The fast exp's underflow-to-zero tail additionally
/// contributes < e⁻⁷⁰⁸·W ≈ 3e-308·W of absolute error — vacuous for
/// any G representable as a normal f64 sum, stated for completeness.)
pub fn split_epsilon(
    eps: f64,
    fast_requested: bool,
    dim: usize,
    h: f64,
    max_sq_norm: f64,
) -> EpsSplit {
    if fast_requested {
        let base = base_case_rel_err(dim, h, max_sq_norm);
        if base <= 0.25 * eps {
            return EpsSplit {
                tree_eps: eps - base,
                base_rel_err: base,
                fast: true,
                f32_tile: false,
            };
        }
    }
    EpsSplit { tree_eps: eps, base_rel_err: 0.0, fast: false, f32_tile: false }
}

/// Precision-aware front end to [`split_epsilon`]: when the caller
/// requested `precision = f32` (and the fast path at all), first try to
/// reserve the larger [`base_case_rel_err_f32`] bound under the same
/// ≤ ε/4 admission gate. If the f32 certificate is affordable the split
/// carries it (`f32_tile = true`) and the tree budget visibly shrinks
/// by that amount; otherwise the request *demotes* — first to the f64
/// fast split, then (tiny h) to the bit-exact base case — so a `f32`
/// request is always ε-sound, never best-effort.
pub fn split_epsilon_prec(
    eps: f64,
    fast_requested: bool,
    precision: Precision,
    dim: usize,
    h: f64,
    max_sq_norm: f64,
) -> EpsSplit {
    if precision == Precision::F32 && fast_requested {
        let base = base_case_rel_err_f32(dim, h, max_sq_norm);
        if base <= 0.25 * eps {
            return EpsSplit {
                tree_eps: eps - base,
                base_rel_err: base,
                fast: true,
                f32_tile: true,
            };
        }
    }
    split_epsilon(eps, fast_requested, dim, h, max_sq_norm)
}

// ---- ε-budget split for sum-of-Gaussians kernels ----

/// How one non-Gaussian evaluate's ε budget is divided between the
/// certified decomposition error and the per-component Gaussian
/// requests (see [`split_epsilon_kernel`]).
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct KernelEpsSplit {
    /// Certified sup-norm error of the sum-of-Gaussians decomposition
    /// ([`crate::kernel::SumOfGaussians::sup_error`]), charged up front.
    pub decomp_err: f64,
    /// The relative ε handed to every Gaussian component request.
    pub component_eps: f64,
}

/// Charge a SoG decomposition's certified sup-norm error out of the
/// caller's ε *before* the per-component fast-exp/tree split, so the
/// final answer carries one end-to-end certificate. Mirrors
/// [`split_epsilon`]'s gate exactly: the decomposition is admitted only
/// when it costs at most a quarter of the budget (`None` otherwise —
/// the session re-fits with more terms, and since its fit target is
/// ε/4 an in-budget decomposition always exists or the evaluate fails
/// cleanly with `ToleranceUnreachable`).
///
/// Soundness — the SoG guarantee is *absolute, scaled by the total
/// reference weight* W = Σ_j ω_j. With S(r) = Σᵢ wᵢ·Gauss_{hᵢ}(r),
/// sup_{[0,R]} |K − S| ≤ η, and component i answered within
/// |G̃ᵢ(q) − Gᵢ(q)| ≤ ε_c·Gᵢ(q) where Gᵢ(q) = Σ_j ω_j·Gauss_{hᵢ} ≤ W:
///
/// ```text
///   |G̃(q) − G_K(q)| ≤ η·W + Σᵢ wᵢ·ε_c·Gᵢ(q)
///                   ≤ (η + ε_c·Σᵢwᵢ)·W = ε·W
/// ```
///
/// with ε_c = (ε − η)/Σᵢwᵢ, i.e. ε_total = ε_decomp + Σᵢ wᵢ·ε_gaussᵢ.
/// Fitted decompositions have Σᵢwᵢ = 1, so components keep at least
/// 3ε/4 of the budget.
pub fn split_epsilon_kernel(eps: f64, decomp_err: f64, weight_sum: f64) -> Option<KernelEpsSplit> {
    debug_assert!(eps > 0.0 && decomp_err >= 0.0 && weight_sum > 0.0);
    if decomp_err > 0.25 * eps {
        return None;
    }
    Some(KernelEpsSplit { decomp_err, component_eps: (eps - decomp_err) / weight_sum })
}

// ---- ε-budget split for the sliced Fourier engine ----

/// How a `Method::Sliced` evaluate's ε budget is divided between the
/// deterministic truncated-Fourier certificate and the Monte-Carlo
/// slicing error the P-doubling loop verifies (see
/// [`split_epsilon_sliced`]).
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct SlicedEpsSplit {
    /// Relative charge of the certified per-slice Fourier error:
    /// `W·bound / min_q G(q)` for the worst per-slice pointwise bound.
    pub fourier_rel: f64,
    /// Relative budget left for the slicing Monte-Carlo error.
    pub mc_eps: f64,
}

/// Charge the sliced engine's deterministic Fourier error out of the
/// caller's ε before the Monte-Carlo verification loop, mirroring
/// [`split_epsilon_kernel`]'s admission gate: the certificate must
/// cost at most a quarter of the budget (`None` otherwise — the
/// session plans each slice against a ε/4-sized target, so an
/// in-budget certificate exists whenever planning succeeded).
///
/// Soundness — every slice plan certifies the pointwise bound
/// |f(z) − g_K(z)| ≤ β on its 1-D approximation, so each per-query
/// slice sum (and therefore their average over P slices) is within
/// W·β of the exact sliced average, absolutely. Dividing by the
/// smallest exact sum turns that into the relative charge
/// `fourier_rel = W·β / min_q G(q)`; the P-doubling loop then accepts
/// only when the *measured* total relative error (Fourier + Monte
/// Carlo together) is ≤ ε, with `mc_eps = ε − fourier_rel` the slack
/// the Monte-Carlo part may consume.
pub fn split_epsilon_sliced(eps: f64, fourier_rel: f64) -> Option<SlicedEpsSplit> {
    debug_assert!(eps > 0.0 && fourier_rel >= 0.0);
    if !fourier_rel.is_finite() || fourier_rel > 0.25 * eps {
        return None;
    }
    Some(SlicedEpsSplit { fourier_rel, mc_eps: eps - fourier_rel })
}

/// Per-query-node mutable state for one dual-tree run.
///
/// Bounds are *hierarchical*: the true running bound for a query point q
/// is the sum of `node_min` over the root→leaf path (and similarly for
/// est/max). `below_min` caches a lower bound on the contributions
/// registered strictly below each node, refined on the way back up the
/// recursion, so prune tests can read
/// `inherited + node_min[Q] + below_min[Q]` in O(1).
///
/// Since the deferred base-case queue (PR 4), *all* bound registration
/// is node-level: leaf–leaf pairs register `W_R·kl`/`W_R·(ku−1)` into
/// `node_min`/`node_max` at enqueue time, and only the estimates
/// (`point_est`) are per-point. The former `point_min`/`point_max`
/// lanes and `refresh_below_from_points` had no remaining writers and
/// were removed rather than carried as misleading dead state.
#[derive(Clone, Debug)]
pub struct QueryLedger {
    /// Contributions to the lower bound registered exactly at each node.
    pub node_min: Vec<f64>,
    /// Upper-bound *deficits* (du ≤ 0 deltas relative to the
    /// W-initialized maximum).
    pub node_max: Vec<f64>,
    /// Far-field estimate contributions registered at each node
    /// (finite-difference midpoints; propagated down in post-processing).
    pub node_est: Vec<f64>,
    /// Banked error-budget tokens Q.W_T.
    pub tokens: Vec<f64>,
    /// Cached min of contributions registered below each node.
    pub below_min: Vec<f64>,
    /// Per-point estimates (drained base cases + direct Hermite
    /// evaluations).
    pub point_est: Vec<f64>,
}

impl QueryLedger {
    pub fn new(num_nodes: usize, num_points: usize) -> Self {
        QueryLedger {
            node_min: vec![0.0; num_nodes],
            node_max: vec![0.0; num_nodes],
            node_est: vec![0.0; num_nodes],
            tokens: vec![0.0; num_nodes],
            below_min: vec![0.0; num_nodes],
            point_est: vec![0.0; num_points],
        }
    }

    /// G_Q^min visible at node `q` given the inherited ancestor sum.
    #[inline]
    pub fn gq_min(&self, q: usize, inherited: f64) -> f64 {
        inherited + self.node_min[q] + self.below_min[q]
    }

    /// Refresh `below_min[q]` from the children's ledgers.
    #[inline]
    pub fn refresh_below_from_children(&mut self, q: usize, left: usize, right: usize) {
        let l = self.node_min[left] + self.below_min[left];
        let r = self.node_min[right] + self.below_min[right];
        self.below_min[q] = l.min(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prune_rule_consts_mirror_runtime_switch() {
        // the monomorphized policies must agree with the runtime-switch
        // rule they absorbed, for both accept shapes and reject
        let cases = [
            (0.0, 5.0, 0.0, 0.0),
            (0.001, 5.0, 0.0, 10.0),
            (0.02, 2.0, 12.0, 50.0),
            (0.1, 1.0, 0.0, 10.0),
        ];
        for (e, wr, bank, gmin) in cases {
            assert_eq!(
                Theorem2::decide(e, wr, bank, gmin, 0.01, 100.0),
                token_rule(e, wr, bank, gmin, 0.01, 100.0, false)
            );
            assert_eq!(
                TokenLedger::decide(e, wr, bank, gmin, 0.01, 100.0),
                token_rule(e, wr, bank, gmin, 0.01, 100.0, true)
            );
        }
        assert!(!Theorem2::USE_TOKENS);
        assert!(TokenLedger::USE_TOKENS);
    }

    #[test]
    fn exact_accounting_banks_full_weight() {
        let d = token_rule(0.0, 5.0, 0.0, 0.0, 0.01, 100.0, true);
        assert_eq!(d, PruneDecision::Accept { token_delta: 5.0 });
        // without tokens, nothing banked but still accepted
        let d2 = token_rule(0.0, 5.0, 0.0, 0.0, 0.01, 100.0, false);
        assert_eq!(d2, PruneDecision::Accept { token_delta: 0.0 });
    }

    #[test]
    fn zero_gmin_rejects_nonzero_error() {
        assert_eq!(token_rule(0.1, 5.0, 100.0, 0.0, 0.01, 100.0, true), PruneDecision::Reject);
    }

    #[test]
    fn classic_rule_without_tokens() {
        // W' = W·E/(ε·Gmin) = 100·0.001/(0.01·10) = 1.0 ≤ W_R=5 → accept
        let d = token_rule(0.001, 5.0, 0.0, 10.0, 0.01, 100.0, false);
        assert_eq!(d, PruneDecision::Accept { token_delta: 0.0 });
        // E larger: W' = 100·0.01/(0.1) = 10 > 5 → reject
        let d2 = token_rule(0.01, 5.0, 0.0, 10.0, 0.01, 100.0, false);
        assert_eq!(d2, PruneDecision::Reject);
    }

    #[test]
    fn tokens_bank_leftover() {
        // W' = 1.0, W_R = 5 → leftover 4 banked
        match token_rule(0.001, 5.0, 0.0, 10.0, 0.01, 100.0, true) {
            PruneDecision::Accept { token_delta } => assert!((token_delta - 4.0).abs() < 1e-12),
            _ => panic!("expected accept"),
        }
    }

    #[test]
    fn tokens_enable_otherwise_impossible_prune() {
        // W' = 10 > W_R = 5: needs 5 tokens.
        let no_tokens = token_rule(0.01, 5.0, 1.0, 10.0, 0.01, 100.0, true);
        assert_eq!(no_tokens, PruneDecision::Reject);
        match token_rule(0.01, 5.0, 6.0, 10.0, 0.01, 100.0, true) {
            PruneDecision::Accept { token_delta } => assert!((token_delta + 5.0).abs() < 1e-12),
            _ => panic!("expected accept with spent tokens"),
        }
    }

    #[test]
    fn token_conservation_across_sequence() {
        // Simulated sequence at one node: ledger never goes negative and
        // net bank equals banked − spent.
        let mut bank: f64 = 0.0;
        let w = 100.0;
        let eps = 0.01;
        let gmin = 50.0;
        let seq = [
            (0.0, 10.0),  // exhaustive: +10
            (0.004, 5.0), // W' = 0.8 → +4.2
            (0.02, 2.0),  // W' = 4  → spend 2
            (0.1, 1.0),   // W' = 20 → needs 19; have 12.2 → reject
        ];
        let mut accepted = 0;
        for (e, wr) in seq {
            match token_rule(e, wr, bank, gmin, eps, w, true) {
                PruneDecision::Accept { token_delta } => {
                    bank += token_delta;
                    accepted += 1;
                    assert!(bank >= -1e-12, "ledger went negative");
                }
                PruneDecision::Reject => {}
            }
        }
        assert_eq!(accepted, 3);
        assert!((bank - (10.0 + 4.2 - 2.0)).abs() < 1e-9, "bank={bank}");
    }

    #[test]
    fn split_epsilon_reserves_and_gates() {
        // moderate h on unit-cube-ish data: fast admitted, reservation
        // comes out of the tree budget
        let s = split_epsilon(1e-4, true, 3, 0.3, 3.0);
        assert!(s.fast);
        assert!(s.base_rel_err > 0.0 && s.base_rel_err <= 0.25e-4);
        assert_eq!(s.tree_eps, 1e-4 - s.base_rel_err);
        // fast not requested: untouched budget
        let off = split_epsilon(1e-4, false, 3, 0.3, 3.0);
        let want = EpsSplit { tree_eps: 1e-4, base_rel_err: 0.0, fast: false, f32_tile: false };
        assert_eq!(off, want);
        // tiny bandwidth: the 1/h² cancellation bound exceeds ε/4, so
        // the evaluate falls back to the exact base case on its own
        let tiny = split_epsilon(1e-6, true, 3, 1e-7, 3.0);
        assert!(!tiny.fast);
        assert_eq!(tiny.tree_eps, 1e-6);
        // the bound grows with 1/h² and with the data magnitude
        assert!(base_case_rel_err(3, 0.01, 3.0) > base_case_rel_err(3, 0.1, 3.0));
        assert!(base_case_rel_err(3, 0.1, 300.0) > base_case_rel_err(3, 0.1, 3.0));
        assert!(base_case_rel_err(3, 0.1, 3.0) >= crate::compute::fastexp::EXP_MAX_REL_ERR);
    }

    #[test]
    fn split_epsilon_prec_charges_f32_and_demotes() {
        // moderate ε: the f32 certificate is affordable and its charge
        // is visible as the exact reservation taken from the tree budget
        let s = split_epsilon_prec(1e-2, true, Precision::F32, 3, 0.3, 3.0);
        assert!(s.fast && s.f32_tile);
        assert_eq!(s.base_rel_err, base_case_rel_err_f32(3, 0.3, 3.0));
        assert_eq!(s.tree_eps, 1e-2 - s.base_rel_err);
        assert!(s.base_rel_err <= 0.25e-2);
        // tight ε: the f32 bound (~1e-4 here) exceeds ε/4, so the
        // request demotes to the plain f64 fast split
        let d = split_epsilon_prec(1e-4, true, Precision::F32, 3, 0.3, 3.0);
        assert!(d.fast && !d.f32_tile);
        assert_eq!(d, split_epsilon(1e-4, true, 3, 0.3, 3.0));
        // tiny bandwidth: demotes all the way to the bit-exact base case
        let tiny = split_epsilon_prec(1e-6, true, Precision::F32, 3, 1e-7, 3.0);
        assert!(!tiny.fast && !tiny.f32_tile);
        // an f64-precision request is exactly the classic split
        let f = split_epsilon_prec(1e-2, true, Precision::F64, 3, 0.3, 3.0);
        assert_eq!(f, split_epsilon(1e-2, true, 3, 0.3, 3.0));
        // the f32 bound dominates the f64 one and keeps its 1/h² shape
        assert!(base_case_rel_err_f32(3, 0.3, 3.0) > base_case_rel_err(3, 0.3, 3.0));
        assert!(base_case_rel_err_f32(3, 0.05, 3.0) > base_case_rel_err_f32(3, 0.5, 3.0));
    }

    #[test]
    fn split_epsilon_kernel_charges_and_gates() {
        // in-budget decomposition: components get the remainder
        let s = split_epsilon_kernel(1e-2, 2e-3, 1.0).unwrap();
        assert_eq!(s.decomp_err, 2e-3);
        assert_eq!(s.component_eps, 1e-2 - 2e-3);
        // ε_total = ε_decomp + Σwᵢ·ε_gauss exactly
        assert!((s.decomp_err + 1.0 * s.component_eps - 1e-2).abs() < 1e-18);
        // weight sums ≠ 1 rescale the component budget
        let w = split_epsilon_kernel(1e-2, 2e-3, 2.0).unwrap();
        assert_eq!(w.component_eps, (1e-2 - 2e-3) / 2.0);
        // same admission gate as the fast-exp split: > ε/4 is rejected
        assert!(split_epsilon_kernel(1e-2, 2.6e-3, 1.0).is_none());
        assert!(split_epsilon_kernel(1e-2, 2.5e-3, 1.0).is_some());
        // components always keep at least 3ε/4 when Σw = 1
        let edge = split_epsilon_kernel(1e-4, 0.25e-4, 1.0).unwrap();
        assert!(edge.component_eps >= 0.75e-4);
    }

    #[test]
    fn split_epsilon_sliced_charges_and_gates() {
        // in-budget certificate: the MC loop gets the remainder
        let s = split_epsilon_sliced(1e-2, 2e-3).unwrap();
        assert_eq!(s.fourier_rel, 2e-3);
        assert_eq!(s.mc_eps, 1e-2 - 2e-3);
        // same ε/4 admission gate as the other splits
        assert!(split_epsilon_sliced(1e-2, 2.6e-3).is_none());
        assert!(split_epsilon_sliced(1e-2, 2.5e-3).is_some());
        // non-finite charges (a slice plan that blew up) are rejected
        assert!(split_epsilon_sliced(1e-2, f64::INFINITY).is_none());
        // the MC budget keeps at least 3ε/4
        let edge = split_epsilon_sliced(1e-4, 0.25e-4).unwrap();
        assert!(edge.mc_eps >= 0.75e-4);
    }

    #[test]
    fn ledger_bound_bookkeeping() {
        // root 0, leaf children 1,2 — since the deferred base-case
        // queue, leaves register everything (FD prunes AND queued base
        // cases) at node level; below_min stays 0 for leaves
        let mut l = QueryLedger::new(3, 4);
        l.node_min[1] = 3.0; // e.g. 2.0 FD prune + 1.0 enqueued W_R·kl
        l.node_min[2] = 3.5;
        assert_eq!(l.below_min[1], 0.0);
        assert_eq!(l.below_min[2], 0.0);
        l.refresh_below_from_children(0, 1, 2);
        assert_eq!(l.below_min[0], 3.0); // min(3+0, 3.5+0)
        assert_eq!(l.gq_min(0, 0.0), 3.0);
        assert_eq!(l.gq_min(1, 5.0), 8.0);
        // deeper hierarchies sum node + below along the path
        l.below_min[1] = 0.5;
        l.refresh_below_from_children(0, 1, 2);
        assert_eq!(l.below_min[0], 3.5); // min(3+0.5, 3.5+0)
        assert!(l.gq_min(0, 0.0).is_finite());
    }
}
