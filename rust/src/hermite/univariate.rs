//! Univariate Hermite functions hₙ(t) = e^(−t²)·Hₙ(t), where Hₙ are the
//! physicists' Hermite polynomials (Rodrigues form). Computed by the
//! three-term recurrence
//!     h₀(t) = e^(−t²),    h₁(t) = 2t·e^(−t²),
//!     hₙ₊₁(t) = 2t·hₙ(t) − 2n·hₙ₋₁(t),
//! which is numerically stable for the small orders (≤ 16) used here.

/// Fill `out[n] = hₙ(t)` for n = 0..out.len().
pub fn hermite_values_into(t: f64, out: &mut [f64]) {
    if out.is_empty() {
        return;
    }
    let e = (-t * t).exp();
    out[0] = e;
    if out.len() == 1 {
        return;
    }
    out[1] = 2.0 * t * e;
    for n in 1..out.len() - 1 {
        out[n + 1] = 2.0 * t * out[n] - 2.0 * n as f64 * out[n - 1];
    }
}

/// Allocating variant: hₙ(t) for n = 0..=max_order.
pub fn hermite_values(t: f64, max_order: usize) -> Vec<f64> {
    let mut out = vec![0.0; max_order + 1];
    hermite_values_into(t, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h_direct(n: usize, t: f64) -> f64 {
        // Hermite polynomials by explicit small-order formulas.
        let h = match n {
            0 => 1.0,
            1 => 2.0 * t,
            2 => 4.0 * t * t - 2.0,
            3 => 8.0 * t.powi(3) - 12.0 * t,
            4 => 16.0 * t.powi(4) - 48.0 * t * t + 12.0,
            5 => 32.0 * t.powi(5) - 160.0 * t.powi(3) + 120.0 * t,
            _ => unreachable!(),
        };
        (-t * t).exp() * h
    }

    #[test]
    fn matches_explicit_polynomials() {
        for &t in &[-2.0, -0.5, 0.0, 0.3, 1.7] {
            let vals = hermite_values(t, 5);
            for n in 0..=5 {
                let d = h_direct(n, t);
                assert!(
                    (vals[n] - d).abs() < 1e-10 * d.abs().max(1.0),
                    "h_{n}({t}): {} vs {d}",
                    vals[n]
                );
            }
        }
    }

    #[test]
    fn parity() {
        // hₙ(−t) = (−1)ⁿ hₙ(t)
        let a = hermite_values(0.8, 8);
        let b = hermite_values(-0.8, 8);
        for n in 0..=8 {
            let sign = if n % 2 == 0 { 1.0 } else { -1.0 };
            assert!((a[n] - sign * b[n]).abs() < 1e-12);
        }
    }

    #[test]
    fn generating_function_identity() {
        // e^(−(t−s)²) = Σₙ (sⁿ/n!) hₙ(t) — the identity the whole
        // expansion machinery is built on. Converges fast for |s| < 1.
        for &(t, s) in &[(0.7, 0.3), (-1.2, 0.5), (2.0, -0.4), (0.0, 0.9)] {
            let vals = hermite_values(t, 40);
            let mut sum = 0.0;
            let mut spow_over_fact = 1.0;
            for (n, v) in vals.iter().enumerate() {
                sum += spow_over_fact * v;
                spow_over_fact *= s / (n + 1) as f64;
            }
            let exact = (-(t - s) * (t - s)).exp();
            assert!((sum - exact).abs() < 1e-10, "t={t} s={s}: {sum} vs {exact}");
        }
    }

    #[test]
    fn derivative_identity() {
        // h′ₙ(t) = −hₙ₊₁(t) (used by the H2L derivation); check by a
        // central finite difference.
        let t = 0.6;
        let eps = 1e-6;
        let up = hermite_values(t + eps, 6);
        let dn = hermite_values(t - eps, 6);
        let at = hermite_values(t, 7);
        for n in 0..=5 {
            let fd = (up[n] - dn[n]) / (2.0 * eps);
            assert!((fd + at[n + 1]).abs() < 1e-5, "n={n}: {fd} vs {}", -at[n + 1]);
        }
    }

    #[test]
    fn zero_order_only() {
        let v = hermite_values(1.5, 0);
        assert_eq!(v.len(), 1);
        assert!((v[0] - (-2.25f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn cramer_bound_holds() {
        // |hₙ(t)| ≤ K·2^(n/2)·√(n!)·e^(−t²/2), K ≈ 1.086435 — relied on
        // by the Lemma 4–6 error bounds.
        let k = 1.086435;
        for &t in &[-3.0, -1.0, 0.0, 0.5, 2.0, 4.0] {
            let vals = hermite_values(t, 16);
            let mut fact = 1.0f64;
            for (n, v) in vals.iter().enumerate() {
                if n > 0 {
                    fact *= n as f64;
                }
                let bound = k * 2f64.powf(n as f64 / 2.0) * fact.sqrt()
                    * (-t * t / 2.0).exp();
                assert!(v.abs() <= bound * (1.0 + 1e-12), "n={n} t={t}");
            }
        }
    }
}
