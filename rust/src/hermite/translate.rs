//! Translation operators (paper Lemmas 1–3):
//!
//! * **H2H** — shift far-field (Hermite) moments from a child center to
//!   its parent's center. Exact on downward-closed index sets: the new
//!   A_γ depends only on A'_α with α ≤ γ, all of which are in the set.
//! * **L2L** — re-center a local (Taylor) polynomial onto a child
//!   center. Also exact: recentring a truncated polynomial over a
//!   downward-closed set is pure binomial expansion.
//! * **H2L** — convert a (truncated) far-field expansion into a local
//!   expansion about a query center; inherently approximate, with the
//!   truncation error bounded by Lemma 6 / its O(pᴰ) analogue.
//!
//! Every operator is driven by a [`PairTable`], which precomputes the
//! position of α+μ for each in-set pair so the inner loops are pure
//! array arithmetic (no hashing on the hot path).

use crate::multiindex::{add, MultiIndexSet};

use super::expansion::{scaled_offset, HermiteTable};

/// Precomputed pairwise structure over one [`MultiIndexSet`]:
/// `sum_pos[a*len + m]` = position of α_a + μ_m in the set, or
/// `u32::MAX` when the sum falls outside the truncation.
#[derive(Clone, Debug)]
pub struct PairTable {
    len: usize,
    sum_pos: Vec<u32>,
    /// binomial(α+μ, α) = (α+μ)!/(α!·μ!) for each pair (used by L2L).
    binom: Vec<f64>,
}

const NONE: u32 = u32::MAX;

impl PairTable {
    pub fn new(set: &MultiIndexSet) -> Self {
        let len = set.len();
        let mut sum_pos = vec![NONE; len * len];
        let mut binom = vec![0.0; len * len];
        for (a, alpha) in set.iter() {
            for (m, mu) in set.iter() {
                let s = add(alpha, mu);
                if let Some(p) = set.position(&s) {
                    sum_pos[a * len + m] = p as u32;
                    // (α+μ)!/(α!·μ!) = 1/( invfac(α+μ)⁻¹ … ) computed
                    // from the set's inverse factorials.
                    binom[a * len + m] =
                        set.inv_factorial(a) * set.inv_factorial(m) / set.inv_factorial(p);
                }
            }
        }
        PairTable { len, sum_pos, binom }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Position of α_a + μ_m, if inside the set.
    #[inline]
    pub fn sum(&self, a: usize, m: usize) -> Option<usize> {
        let v = self.sum_pos[a * self.len + m];
        if v == NONE {
            None
        } else {
            Some(v as usize)
        }
    }

    #[inline]
    fn binom(&self, a: usize, m: usize) -> f64 {
        self.binom[a * self.len + m]
    }
}

/// **H2H** (Lemma 2): add to `parent_coeffs` (far field about
/// `new_center`) the translation of `child_coeffs` (far field about
/// `old_center`):
///   A_γ += Σ_{α≤γ} A'_α · dx^{γ−α} / (γ−α)!,  dx = (old−new)/scale.
pub fn h2h(
    set: &MultiIndexSet,
    pairs: &PairTable,
    child_coeffs: &[f64],
    old_center: &[f64],
    new_center: &[f64],
    scale: f64,
    parent_coeffs: &mut [f64],
    mono_buf: &mut [f64],
    off_buf: &mut [f64],
) {
    debug_assert_eq!(child_coeffs.len(), set.len());
    debug_assert_eq!(parent_coeffs.len(), set.len());
    for i in 0..off_buf.len() {
        off_buf[i] = (old_center[i] - new_center[i]) / scale;
    }
    set.eval_monomials(off_buf, mono_buf);
    // γ = α + μ: A[γ] += A'[α] · dx^μ / μ!
    for a in 0..set.len() {
        let ca = child_coeffs[a];
        if ca == 0.0 {
            continue;
        }
        for m in 0..set.len() {
            if let Some(g) = pairs.sum(a, m) {
                parent_coeffs[g] += ca * mono_buf[m] * set.inv_factorial(m);
            }
        }
    }
}

/// **L2L** (Lemma 3): add to `child_coeffs` (local about `new_center`)
/// the re-centering of `parent_coeffs` (local about `old_center`):
///   B'_α += Σ_{β≥α} (β!/(α!(β−α)!)) · B_β · dx^{β−α},
///   dx = (new−old)/scale.   (β = α+μ over in-set pairs.)
pub fn l2l(
    set: &MultiIndexSet,
    pairs: &PairTable,
    parent_coeffs: &[f64],
    old_center: &[f64],
    new_center: &[f64],
    scale: f64,
    child_coeffs: &mut [f64],
    mono_buf: &mut [f64],
    off_buf: &mut [f64],
) {
    debug_assert_eq!(parent_coeffs.len(), set.len());
    debug_assert_eq!(child_coeffs.len(), set.len());
    for i in 0..off_buf.len() {
        off_buf[i] = (new_center[i] - old_center[i]) / scale;
    }
    set.eval_monomials(off_buf, mono_buf);
    for a in 0..set.len() {
        let mut acc = 0.0;
        for m in 0..set.len() {
            if let Some(b) = pairs.sum(a, m) {
                acc += pairs.binom(a, m) * parent_coeffs[b] * mono_buf[m];
            }
        }
        child_coeffs[a] += acc;
    }
}

/// **H2L** (Lemma 1): convert far-field moments about `r_center` into
/// local coefficients about `q_center`:
///   B_β += (1/β!) Σ_α (−1)^{|α|} A_α h_{α+β}( (x_R − x_Q)/scale ).
/// The Hermite table must hold orders up to 2(p−1); it is refilled here.
pub fn h2l(
    set: &MultiIndexSet,
    far_coeffs: &[f64],
    r_center: &[f64],
    q_center: &[f64],
    scale: f64,
    local_coeffs: &mut [f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) {
    debug_assert_eq!(far_coeffs.len(), set.len());
    debug_assert_eq!(local_coeffs.len(), set.len());
    debug_assert!(table.max_order() >= 2 * (set.order() - 1));
    scaled_offset(r_center, q_center, scale, off_buf);
    table.fill(off_buf);
    let dim = set.dim();
    let mut sum_idx = vec![0u32; dim];
    for (b, beta) in set.iter() {
        let mut acc = 0.0;
        for (a, alpha) in set.iter() {
            let ca = far_coeffs[a];
            if ca == 0.0 {
                continue;
            }
            let mut prod = 1.0;
            for d in 0..dim {
                sum_idx[d] = alpha[d] + beta[d];
                prod *= table.get(d, sum_idx[d]);
            }
            let sign = if set.degree(a) % 2 == 0 { 1.0 } else { -1.0 };
            acc += sign * ca * prod;
        }
        local_coeffs[b] += set.inv_factorial(b) * acc;
    }
}

/// **H2L** at sub-order `p ≤ set.order()`: convert only the order-p part
/// of the far field into order-p local coefficients (Lemma 6 bounds the
/// error of exactly this truncation). Coefficient arrays stay full-size.
#[allow(clippy::too_many_arguments)]
pub fn h2l_truncated(
    set: &MultiIndexSet,
    p: usize,
    far_coeffs: &[f64],
    r_center: &[f64],
    q_center: &[f64],
    scale: f64,
    local_coeffs: &mut [f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) {
    debug_assert_eq!(far_coeffs.len(), set.len());
    debug_assert_eq!(local_coeffs.len(), set.len());
    debug_assert!(table.max_order() >= 2 * (set.order() - 1));
    scaled_offset(r_center, q_center, scale, off_buf);
    table.fill(off_buf);
    let dim = set.dim();
    // graded layout: sub-order set is an enumeration prefix → tight loops
    let limit = set.order_prefix(p).unwrap_or(set.len());
    for b in 0..limit {
        if !set.in_order(b, p) {
            continue; // only possible on the grid layout
        }
        let beta = set.index(b);
        let mut acc = 0.0;
        for a in 0..limit {
            if !set.in_order(a, p) {
                continue;
            }
            let ca = far_coeffs[a];
            if ca == 0.0 {
                continue;
            }
            let alpha = set.index(a);
            let mut prod = 1.0;
            for d in 0..dim {
                prod *= table.get(d, alpha[d] + beta[d]);
            }
            let sign = if set.degree(a) % 2 == 0 { 1.0 } else { -1.0 };
            acc += sign * ca * prod;
        }
        local_coeffs[b] += set.inv_factorial(b) * acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Matrix;
    use crate::hermite::expansion::{
        accumulate_farfield, accumulate_local, eval_local,
    };
    use crate::kernel::GaussianKernel;
    use crate::multiindex::Layout;
    use crate::util::Pcg32;

    fn cluster(rng: &mut Pcg32, n: usize, d: usize, c: f64, s: f64) -> Matrix {
        Matrix::from_rows(
            &(0..n)
                .map(|_| (0..d).map(|_| c + s * rng.uniform_in(-1.0, 1.0)).collect())
                .collect::<Vec<_>>(),
        )
    }

    fn exact(points: &Matrix, w: &[f64], xq: &[f64], h: f64) -> f64 {
        let k = GaussianKernel::new(h);
        (0..points.rows())
            .map(|r| w[r] * k.eval_sq(crate::geometry::sqdist(points.row(r), xq)))
            .sum()
    }

    /// H2H must be EXACT: moments accumulated at a child center then
    /// translated to the parent center equal moments accumulated
    /// directly at the parent center.
    #[test]
    fn h2h_exact_on_downward_closed_sets() {
        let mut rng = Pcg32::new(31);
        for layout in [Layout::Grid, Layout::Graded] {
            for (d, p) in [(1usize, 6usize), (2, 5), (3, 3)] {
                let pts = cluster(&mut rng, 12, d, 0.3, 0.2);
                let w: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.2, 1.0)).collect();
                let rows: Vec<usize> = (0..12).collect();
                let set = MultiIndexSet::new(layout, d, p);
                let pairs = PairTable::new(&set);
                let scale = 0.9;
                let child_c: Vec<f64> = (0..d).map(|_| rng.uniform_in(0.2, 0.4)).collect();
                let parent_c: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.1, 0.1)).collect();

                let mut mono = vec![0.0; set.len()];
                let mut off = vec![0.0; d];
                let mut child = vec![0.0; set.len()];
                accumulate_farfield(&set, &pts, &rows, &w, &child_c, scale, &mut child, &mut mono, &mut off);

                let mut translated = vec![0.0; set.len()];
                h2h(&set, &pairs, &child, &child_c, &parent_c, scale, &mut translated, &mut mono, &mut off);

                let mut direct = vec![0.0; set.len()];
                accumulate_farfield(&set, &pts, &rows, &w, &parent_c, scale, &mut direct, &mut mono, &mut off);

                for i in 0..set.len() {
                    assert!(
                        (translated[i] - direct[i]).abs() < 1e-10 * direct[i].abs().max(1.0),
                        "{layout:?} D={d} p={p} i={i}: {} vs {}",
                        translated[i],
                        direct[i]
                    );
                }
            }
        }
    }

    /// L2L must exactly re-center the truncated polynomial: evaluation
    /// before and after agrees at any point.
    #[test]
    fn l2l_exactly_recenters_polynomial() {
        let mut rng = Pcg32::new(32);
        for layout in [Layout::Grid, Layout::Graded] {
            let d = 2;
            let p = 5;
            let set = MultiIndexSet::new(layout, d, p);
            let pairs = PairTable::new(&set);
            let scale = 1.3;
            // arbitrary coefficients — any polynomial, not just a kernel sum
            let coeffs: Vec<f64> = (0..set.len()).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let old_c = vec![0.5, -0.2];
            let new_c = vec![0.1, 0.3];
            let mut shifted = vec![0.0; set.len()];
            let mut mono = vec![0.0; set.len()];
            let mut off = vec![0.0; d];
            l2l(&set, &pairs, &coeffs, &old_c, &new_c, scale, &mut shifted, &mut mono, &mut off);
            for _ in 0..10 {
                let xq: Vec<f64> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                let v_old = eval_local(&set, &coeffs, &old_c, scale, &xq, &mut mono, &mut off);
                let v_new = eval_local(&set, &shifted, &new_c, scale, &xq, &mut mono, &mut off);
                assert!(
                    (v_old - v_new).abs() < 1e-10 * v_old.abs().max(1.0),
                    "{layout:?}: {v_old} vs {v_new}"
                );
            }
        }
    }

    /// L2L accumulates (+=): translating onto non-zero target adds.
    #[test]
    fn l2l_accumulates() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 3);
        let pairs = PairTable::new(&set);
        let coeffs = vec![1.0; set.len()];
        let mut out1 = vec![0.0; set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; 2];
        let oc = [0.0, 0.0];
        let nc = [0.5, 0.5];
        l2l(&set, &pairs, &coeffs, &oc, &nc, 1.0, &mut out1, &mut mono, &mut off);
        let mut out2 = out1.clone();
        l2l(&set, &pairs, &coeffs, &oc, &nc, 1.0, &mut out2, &mut mono, &mut off);
        for i in 0..set.len() {
            assert!((out2[i] - 2.0 * out1[i]).abs() < 1e-12 * out1[i].abs().max(1.0));
        }
    }

    /// H2L of an (effectively untruncated) far field approximates the
    /// direct local accumulation; the resulting local expansion
    /// approximates the exact kernel sum for well-separated nodes.
    #[test]
    fn h2l_approximates_direct_local() {
        let mut rng = Pcg32::new(33);
        let d = 2;
        let h = 1.0;
        let k = GaussianKernel::new(h);
        let scale = k.series_scale();
        let p = 10;
        // reference cluster near (1.2, 1.2), queries near origin
        let pts = cluster(&mut rng, 15, d, 1.2, 0.1);
        let w = vec![1.0; 15];
        let rows: Vec<usize> = (0..15).collect();
        let r_c = pts.col_mean();
        let q_c = vec![0.0, 0.0];

        let set = MultiIndexSet::new(Layout::Grid, d, p);
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        let mut far = vec![0.0; set.len()];
        accumulate_farfield(&set, &pts, &rows, &w, &r_c, scale, &mut far, &mut mono, &mut off);

        let mut table = HermiteTable::new(d, 2 * p);
        let mut local_via_h2l = vec![0.0; set.len()];
        h2l(&set, &far, &r_c, &q_c, scale, &mut local_via_h2l, &mut table, &mut off);

        let xq = vec![0.05, -0.04];
        let est = eval_local(&set, &local_via_h2l, &q_c, scale, &xq, &mut mono, &mut off);
        let truth = exact(&pts, &w, &xq, h);
        assert!(
            (est - truth).abs() < 1e-6 * truth.max(1e-30),
            "h2l est={est} exact={truth}"
        );
    }

    /// The full FMM chain: accumulate far field at child, H2H to parent,
    /// H2L to query node, L2L to query child, evaluate — approximates
    /// the exact sum.
    #[test]
    fn full_translation_chain() {
        let mut rng = Pcg32::new(34);
        let d = 2;
        let h = 0.8;
        let k = GaussianKernel::new(h);
        let scale = k.series_scale();
        let p = 8;
        let set = MultiIndexSet::new(Layout::Graded, d, p);
        let pairs = PairTable::new(&set);

        let pts = cluster(&mut rng, 20, d, 1.5, 0.1);
        let w: Vec<f64> = (0..20).map(|_| rng.uniform_in(0.5, 1.0)).collect();
        let rows: Vec<usize> = (0..20).collect();

        let r_child_c = pts.col_mean();
        let r_parent_c: Vec<f64> = r_child_c.iter().map(|v| v + 0.05).collect();
        let q_parent_c = vec![0.0, 0.0];
        let q_child_c = vec![0.08, -0.05];

        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        let mut far_child = vec![0.0; set.len()];
        accumulate_farfield(&set, &pts, &rows, &w, &r_child_c, scale, &mut far_child, &mut mono, &mut off);
        let mut far_parent = vec![0.0; set.len()];
        h2h(&set, &pairs, &far_child, &r_child_c, &r_parent_c, scale, &mut far_parent, &mut mono, &mut off);

        let mut table = HermiteTable::new(d, 2 * p);
        let mut local_parent = vec![0.0; set.len()];
        h2l(&set, &far_parent, &r_parent_c, &q_parent_c, scale, &mut local_parent, &mut table, &mut off);
        let mut local_child = vec![0.0; set.len()];
        l2l(&set, &pairs, &local_parent, &q_parent_c, &q_child_c, scale, &mut local_child, &mut mono, &mut off);

        let xq = vec![0.1, -0.02];
        let est = eval_local(&set, &local_child, &q_child_c, scale, &xq, &mut mono, &mut off);
        let truth = exact(&pts, &w, &xq, h);
        let rel = (est - truth).abs() / truth.max(1e-300);
        assert!(rel < 1e-4, "chain est={est} exact={truth} rel={rel}");
    }

    /// Far-field evaluated directly vs via H2L+EVALL agree for the same
    /// truncation (consistency between EVALM and the local conversion).
    #[test]
    fn h2l_consistent_with_direct_local_coefficients() {
        let mut rng = Pcg32::new(35);
        let d = 1;
        let h = 1.0;
        let scale = GaussianKernel::new(h).series_scale();
        let p = 12;
        let set = MultiIndexSet::new(Layout::Grid, d, p);
        let pts = cluster(&mut rng, 10, d, 2.0, 0.05);
        let w = vec![1.0; 10];
        let rows: Vec<usize> = (0..10).collect();
        let r_c = pts.col_mean();
        let q_c = vec![0.0];

        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        let mut far = vec![0.0; set.len()];
        accumulate_farfield(&set, &pts, &rows, &w, &r_c, scale, &mut far, &mut mono, &mut off);
        let mut table = HermiteTable::new(d, 2 * p);
        let mut via_h2l = vec![0.0; set.len()];
        h2l(&set, &far, &r_c, &q_c, scale, &mut via_h2l, &mut table, &mut off);
        let mut direct = vec![0.0; set.len()];
        accumulate_local(&set, &pts, &rows, &w, &q_c, scale, &mut direct, &mut table, &mut off);
        // low-order coefficients must agree closely (truncation affects
        // mainly the high orders)
        for i in 0..4 {
            assert!(
                (via_h2l[i] - direct[i]).abs() < 1e-6 * direct[i].abs().max(1e-12),
                "i={i}: {} vs {}",
                via_h2l[i],
                direct[i]
            );
        }
    }

    #[test]
    fn pair_table_sums_and_binomials() {
        let set = MultiIndexSet::new(Layout::Graded, 2, 3);
        let pairs = PairTable::new(&set);
        let a = set.position(&[1, 0]).unwrap();
        let m = set.position(&[0, 1]).unwrap();
        let s = pairs.sum(a, m).unwrap();
        assert_eq!(set.index(s), &[1, 1]);
        // (1,1)!/( (1,0)!·(0,1)! ) = 1 → binom = C(α+μ, α) = 1·1? No:
        // (α+μ)!/(α!μ!) = (1!·1!)/(1·1) = 1
        let b = pairs.binom(a, m);
        assert!((b - 1.0).abs() < 1e-12);
        // out-of-set sum: (2,0)+(0,2) has degree 4 ≥ p=3
        let a2 = set.position(&[2, 0]).unwrap();
        let m2 = set.position(&[0, 2]).unwrap();
        assert!(pairs.sum(a2, m2).is_none());
    }
}
