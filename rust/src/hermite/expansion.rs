//! Far-field (Hermite) and local (Taylor) expansions over a
//! [`MultiIndexSet`]: moment accumulation (paper's DIRECTM/DIRECTL) and
//! series evaluation (EVALM/EVALL).
//!
//! All functions operate on caller-provided coefficient slices so tree
//! nodes can own plain `Vec<f64>` and algorithms can reuse scratch
//! buffers on the hot path.

use crate::geometry::Matrix;
use crate::multiindex::MultiIndexSet;

use super::univariate::hermite_values_into;

/// Per-dimension table of univariate Hermite values h_n(u_d) for
/// n = 0..=max_order — the basis for multivariate products
/// h_α(u) = Π_d h_{α_d}(u_d).
#[derive(Clone, Debug)]
pub struct HermiteTable {
    vals: Vec<f64>,
    dim: usize,
    stride: usize,
}

impl HermiteTable {
    /// Allocate for `dim` dimensions up to `max_order`.
    pub fn new(dim: usize, max_order: usize) -> Self {
        HermiteTable { vals: vec![0.0; dim * (max_order + 1)], dim, stride: max_order + 1 }
    }

    /// Fill the table for the scaled vector `u` (length `dim`).
    pub fn fill(&mut self, u: &[f64]) {
        debug_assert_eq!(u.len(), self.dim);
        for d in 0..self.dim {
            hermite_values_into(u[d], &mut self.vals[d * self.stride..(d + 1) * self.stride]);
        }
    }

    /// h_n(u_d).
    #[inline]
    pub fn get(&self, d: usize, n: u32) -> f64 {
        self.vals[d * self.stride + n as usize]
    }

    /// Multivariate product h_α(u) for one multi-index.
    #[inline]
    pub fn product(&self, alpha: &[u32]) -> f64 {
        let mut p = 1.0;
        for (d, &n) in alpha.iter().enumerate() {
            p *= self.get(d, n);
        }
        p
    }

    /// Largest order the table holds.
    pub fn max_order(&self) -> usize {
        self.stride - 1
    }
}

/// Scale and shift a point: out = (x − center)/scale.
#[inline]
pub fn scaled_offset(x: &[f64], center: &[f64], scale: f64, out: &mut [f64]) {
    for i in 0..x.len() {
        out[i] = (x[i] - center[i]) / scale;
    }
}

/// DIRECTM: accumulate far-field (Hermite) moments of the selected
/// reference rows into `coeffs`:
///   coeffs[i] += Σ_r w_r · (1/α_i!) · ((x_r − center)/scale)^{α_i}.
/// `mono_buf` must have `set.len()` slots; `off_buf` `set.dim()` slots.
pub fn accumulate_farfield(
    set: &MultiIndexSet,
    points: &Matrix,
    rows: &[usize],
    weights: &[f64],
    center: &[f64],
    scale: f64,
    coeffs: &mut [f64],
    mono_buf: &mut [f64],
    off_buf: &mut [f64],
) {
    debug_assert_eq!(coeffs.len(), set.len());
    for &r in rows {
        scaled_offset(points.row(r), center, scale, off_buf);
        set.eval_monomials(off_buf, mono_buf);
        let w = weights[r];
        for i in 0..set.len() {
            coeffs[i] += w * set.inv_factorial(i) * mono_buf[i];
        }
    }
}

/// EVALM: evaluate a far-field expansion at query point `xq`:
///   Σ_i coeffs[i] · h_{α_i}((xq − center)/scale).
pub fn eval_farfield(
    set: &MultiIndexSet,
    coeffs: &[f64],
    center: &[f64],
    scale: f64,
    xq: &[f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) -> f64 {
    debug_assert_eq!(coeffs.len(), set.len());
    scaled_offset(xq, center, scale, off_buf);
    table.fill(off_buf);
    let mut sum = 0.0;
    for (i, alpha) in set.iter() {
        sum += coeffs[i] * table.product(alpha);
    }
    sum
}

/// DIRECTL: accumulate local (Taylor) coefficients about `center` from
/// the selected reference rows:
///   coeffs[i] += Σ_r w_r · (1/β_i!) · h_{β_i}((x_r − center)/scale).
pub fn accumulate_local(
    set: &MultiIndexSet,
    points: &Matrix,
    rows: &[usize],
    weights: &[f64],
    center: &[f64],
    scale: f64,
    coeffs: &mut [f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) {
    debug_assert_eq!(coeffs.len(), set.len());
    for &r in rows {
        scaled_offset(points.row(r), center, scale, off_buf);
        table.fill(off_buf);
        let w = weights[r];
        for (i, beta) in set.iter() {
            coeffs[i] += w * set.inv_factorial(i) * table.product(beta);
        }
    }
}

/// EVALM at sub-order `p ≤ set.order()`: evaluate only the coefficients
/// inside the order-p truncation (Lemma 4 covers exactly this error).
#[allow(clippy::too_many_arguments)]
pub fn eval_farfield_truncated(
    set: &MultiIndexSet,
    p: usize,
    coeffs: &[f64],
    center: &[f64],
    scale: f64,
    xq: &[f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) -> f64 {
    scaled_offset(xq, center, scale, off_buf);
    table.fill(off_buf);
    let mut sum = 0.0;
    match set.order_prefix(p) {
        // graded layout: the sub-order set is a prefix — branch-free loop
        Some(n) => {
            for i in 0..n {
                sum += coeffs[i] * table.product(set.index(i));
            }
        }
        None => {
            for (i, alpha) in set.iter() {
                if set.in_order(i, p) {
                    sum += coeffs[i] * table.product(alpha);
                }
            }
        }
    }
    sum
}

/// DIRECTL at sub-order `p`: accumulate only order-p coefficients into a
/// full-size (PLIMIT) coefficient array (higher entries untouched).
#[allow(clippy::too_many_arguments)]
pub fn accumulate_local_truncated(
    set: &MultiIndexSet,
    p: usize,
    points: &Matrix,
    rows: std::ops::Range<usize>,
    weights: &[f64],
    center: &[f64],
    scale: f64,
    coeffs: &mut [f64],
    table: &mut HermiteTable,
    off_buf: &mut [f64],
) {
    debug_assert_eq!(coeffs.len(), set.len());
    let prefix = set.order_prefix(p);
    for r in rows {
        scaled_offset(points.row(r), center, scale, off_buf);
        table.fill(off_buf);
        let w = weights[r];
        match prefix {
            Some(n) => {
                for i in 0..n {
                    coeffs[i] += w * set.inv_factorial(i) * table.product(set.index(i));
                }
            }
            None => {
                for (i, beta) in set.iter() {
                    if set.in_order(i, p) {
                        coeffs[i] += w * set.inv_factorial(i) * table.product(beta);
                    }
                }
            }
        }
    }
}

/// EVALL: evaluate a local (Taylor) expansion at `xq`:
///   Σ_i coeffs[i] · ((xq − center)/scale)^{β_i}.
pub fn eval_local(
    set: &MultiIndexSet,
    coeffs: &[f64],
    center: &[f64],
    scale: f64,
    xq: &[f64],
    mono_buf: &mut [f64],
    off_buf: &mut [f64],
) -> f64 {
    debug_assert_eq!(coeffs.len(), set.len());
    scaled_offset(xq, center, scale, off_buf);
    set.eval_monomials(off_buf, mono_buf);
    let mut sum = 0.0;
    for i in 0..set.len() {
        sum += coeffs[i] * mono_buf[i];
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::GaussianKernel;
    use crate::multiindex::Layout;
    use crate::util::Pcg32;

    /// Exhaustive Gaussian sum for reference.
    fn exact_sum(points: &Matrix, rows: &[usize], w: &[f64], xq: &[f64], h: f64) -> f64 {
        let k = GaussianKernel::new(h);
        rows.iter().map(|&r| w[r] * k.eval_sq(crate::geometry::sqdist(points.row(r), xq))).sum()
    }

    fn random_cluster(rng: &mut Pcg32, n: usize, d: usize, center: f64, spread: f64) -> Matrix {
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| center + spread * rng.uniform_in(-1.0, 1.0)).collect())
            .collect();
        Matrix::from_rows(&rows)
    }

    /// Far-field expansion converges to the exact sum as p grows, for
    /// both layouts.
    #[test]
    fn farfield_converges_to_exact() {
        let mut rng = Pcg32::new(21);
        for layout in [Layout::Grid, Layout::Graded] {
            let d = 2;
            let h = 0.5;
            let k = GaussianKernel::new(h);
            let pts = random_cluster(&mut rng, 20, d, 0.0, 0.1);
            let w = vec![1.0; 20];
            let rows: Vec<usize> = (0..20).collect();
            let center = pts.col_mean();
            let xq = vec![0.8, -0.3];
            let exact = exact_sum(&pts, &rows, &w, &xq, h);
            let mut prev_err = f64::INFINITY;
            for p in [2usize, 4, 6, 8] {
                let set = MultiIndexSet::new(layout, d, p);
                let mut coeffs = vec![0.0; set.len()];
                let mut mono = vec![0.0; set.len()];
                let mut off = vec![0.0; d];
                accumulate_farfield(
                    &set, &pts, &rows, &w, &center, k.series_scale(), &mut coeffs, &mut mono,
                    &mut off,
                );
                let mut table = HermiteTable::new(d, p);
                let est = eval_farfield(&set, &coeffs, &center, k.series_scale(), &xq, &mut table, &mut off);
                let err = (est - exact).abs();
                assert!(err <= prev_err * 1.5 + 1e-12, "{layout:?} p={p} err={err}");
                prev_err = err;
            }
            assert!(prev_err < 1e-6 * exact.abs().max(1e-30), "{layout:?} final err {prev_err}");
        }
    }

    /// Local expansion converges to the exact sum as p grows.
    #[test]
    fn local_converges_to_exact() {
        let mut rng = Pcg32::new(22);
        for layout in [Layout::Grid, Layout::Graded] {
            let d = 3;
            let h = 0.6;
            let k = GaussianKernel::new(h);
            let pts = random_cluster(&mut rng, 15, d, 1.0, 0.3);
            let w: Vec<f64> = (0..15).map(|_| rng.uniform_in(0.5, 1.5)).collect();
            let rows: Vec<usize> = (0..15).collect();
            // queries clustered near the origin; expansion center there
            let qcenter = vec![0.0; d];
            let xq = vec![0.05, -0.1, 0.08];
            let exact = exact_sum(&pts, &rows, &w, &xq, h);
            let mut last = f64::INFINITY;
            for p in [2usize, 4, 6] {
                let set = MultiIndexSet::new(layout, d, p);
                let mut coeffs = vec![0.0; set.len()];
                let mut table = HermiteTable::new(d, p.max(1));
                let mut off = vec![0.0; d];
                accumulate_local(
                    &set, &pts, &rows, &w, &qcenter, k.series_scale(), &mut coeffs, &mut table,
                    &mut off,
                );
                let mut mono = vec![0.0; set.len()];
                let est = eval_local(&set, &coeffs, &qcenter, k.series_scale(), &xq, &mut mono, &mut off);
                last = (est - exact).abs();
            }
            assert!(last < 1e-5 * exact.abs().max(1e-30), "{layout:?} err={last}");
        }
    }

    /// With p high enough to be exact-ish, far-field and local agree.
    #[test]
    fn farfield_and_local_agree() {
        let mut rng = Pcg32::new(23);
        let d = 2;
        let h = 1.0;
        let k = GaussianKernel::new(h);
        let pts = random_cluster(&mut rng, 10, d, 0.5, 0.2);
        let w = vec![1.0; 10];
        let rows: Vec<usize> = (0..10).collect();
        let set = MultiIndexSet::new(Layout::Grid, d, 10);
        let scale = k.series_scale();

        let rcenter = pts.col_mean();
        let mut a = vec![0.0; set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];
        accumulate_farfield(&set, &pts, &rows, &w, &rcenter, scale, &mut a, &mut mono, &mut off);

        let qcenter = vec![0.4, 0.6];
        let mut b = vec![0.0; set.len()];
        let mut table = HermiteTable::new(d, 10);
        accumulate_local(&set, &pts, &rows, &w, &qcenter, scale, &mut b, &mut table, &mut off);

        let xq = vec![0.45, 0.55];
        let ff = eval_farfield(&set, &a, &rcenter, scale, &xq, &mut table, &mut off);
        let loc = eval_local(&set, &b, &qcenter, scale, &xq, &mut mono, &mut off);
        let exact = exact_sum(&pts, &rows, &w, &xq, h);
        assert!((ff - exact).abs() < 1e-8, "ff={ff} exact={exact}");
        assert!((loc - exact).abs() < 1e-8, "loc={loc} exact={exact}");
    }

    /// Weights scale the expansions linearly.
    #[test]
    fn linear_in_weights() {
        let mut rng = Pcg32::new(24);
        let d = 2;
        let pts = random_cluster(&mut rng, 8, d, 0.0, 0.2);
        let rows: Vec<usize> = (0..8).collect();
        let k = GaussianKernel::new(0.7);
        let set = MultiIndexSet::new(Layout::Graded, d, 5);
        let center = vec![0.0; d];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; d];

        let w1 = vec![1.0; 8];
        let w3 = vec![3.0; 8];
        let mut c1 = vec![0.0; set.len()];
        let mut c3 = vec![0.0; set.len()];
        accumulate_farfield(&set, &pts, &rows, &w1, &center, k.series_scale(), &mut c1, &mut mono, &mut off);
        accumulate_farfield(&set, &pts, &rows, &w3, &center, k.series_scale(), &mut c3, &mut mono, &mut off);
        for i in 0..set.len() {
            assert!((c3[i] - 3.0 * c1[i]).abs() < 1e-12 * c1[i].abs().max(1.0));
        }
    }

    /// Zeroth coefficient of the far field is exactly W_R (the monopole).
    #[test]
    fn farfield_monopole_is_total_weight() {
        let mut rng = Pcg32::new(25);
        let pts = random_cluster(&mut rng, 12, 3, 0.5, 0.4);
        let rows: Vec<usize> = (0..12).collect();
        let w: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.1, 2.0)).collect();
        let set = MultiIndexSet::new(Layout::Graded, 3, 3);
        let mut c = vec![0.0; set.len()];
        let mut mono = vec![0.0; set.len()];
        let mut off = vec![0.0; 3];
        accumulate_farfield(&set, &pts, &rows, &w, &pts.col_mean(), 1.0, &mut c, &mut mono, &mut off);
        let total: f64 = w.iter().sum();
        assert!((c[0] - total).abs() < 1e-12 * total);
    }

    #[test]
    fn hermite_table_product() {
        let mut t = HermiteTable::new(2, 3);
        t.fill(&[0.5, -0.7]);
        let u0 = crate::hermite::hermite_values(0.5, 3);
        let u1 = crate::hermite::hermite_values(-0.7, 3);
        assert!((t.product(&[2, 1]) - u0[2] * u1[1]).abs() < 1e-15);
        assert_eq!(t.max_order(), 3);
    }
}
