//! Hermite-function series machinery: univariate Hermite functions,
//! far-field (Hermite) and local (Taylor) expansions of the Gaussian
//! kernel sum over either multi-index layout, and the three translation
//! operators H2H, H2L and L2L (paper Lemmas 1–3).
//!
//! Conventions (matching the paper):
//! * series scale c = √(2h²); every expansion argument is (x − center)/c;
//! * Hermite functions hₙ(t) = e^(−t²) Hₙ(t), with the generating
//!   identity e^(−(t−s)²) = Σₙ (sⁿ/n!) hₙ(t) the expansions rest on;
//! * far-field about x_R:  G(x_q) = Σ_α A_α h_α((x_q−x_R)/c),
//!   A_α = Σ_r (w_r/α!) ((x_r−x_R)/c)^α              (`accumulate_farfield`)
//! * local about x_Q:      G(x_q) = Σ_β B_β ((x_q−x_Q)/c)^β,
//!   B_β = Σ_r (w_r/β!) h_β((x_r−x_Q)/c)             (`accumulate_local`)

pub mod univariate;
pub mod expansion;
pub mod translate;

pub use expansion::{
    accumulate_farfield, accumulate_local, accumulate_local_truncated, eval_farfield,
    eval_farfield_truncated, eval_local, HermiteTable,
};
pub use translate::{h2h, h2l, h2l_truncated, l2l, PairTable};
pub use univariate::hermite_values;
