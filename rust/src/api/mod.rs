//! The crate's front door: a prepare-once / evaluate-many [`Session`]
//! over all eight Gaussian-summation engines, with automatic method
//! selection.
//!
//! The paper's central performance lesson is that the hierarchical
//! data structure should be amortized across many evaluations, and
//! that the best operator is problem-dependent. This layer exposes
//! both halves as one API:
//!
//! * [`Session::prepare`] builds every dataset-dependent structure
//!   once (kd-tree eagerly; FGT grid frame, IFGT clustering plans and
//!   exhaustive truth lazily, memoized per session);
//! * [`Session::evaluate`] / [`Session::evaluate_batch`] answer
//!   [`EvalRequest`]s — monochromatic or with explicit queries, any
//!   [`Method`] including [`Method::Auto`] (resolved by the promoted
//!   [`CostModel`]), with the FGT τ-halving and IFGT K-doubling
//!   verification loops ([`tuning`]) run inside the session so every
//!   caller gets ε-verified answers. Batches and the traversals they
//!   trigger share the session's one work-stealing pool
//!   ([`crate::runtime::pool`]), and results are bit-identical to
//!   sequential evaluation in any pool width.
//!
//! Sessions are kernel-independent: [`PrepareOptions::kernel`] /
//! [`EvalRequest::with_kernel`] select any [`Kernel`] family, with the
//! non-Gaussian ones answered through a certified sum-of-Gaussians
//! component batch (see [`crate::kernel::sog`]) under the ε·W
//! guarantee — the Gaussian default stays bit-for-bit identical.
//!
//! Every pre-existing call path — `kde::*`, `coordinator::run_sweep`,
//! the CLI, the examples and the paper benches — routes through here;
//! the one-shot [`crate::algo::GaussSum`] impls and the raw
//! [`crate::algo::SweepEngine`] remain as thin compatibility shims
//! underneath (prefer a `Session` in new code).

pub mod method;
pub mod session;
pub mod tuning;

pub use crate::compute::simd::{Precision, SimdMode};
pub use crate::kernel::Kernel;
pub use method::{CostModel, Method, ProblemProfile};
pub use session::{
    EvalRequest, Evaluation, PrepareOptions, Session, SogComponentRoute, SogReport,
};
