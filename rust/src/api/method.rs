//! The [`Method`] enum — the one name every caller uses to pick a
//! Gaussian-summation algorithm — and the promoted, problem-level
//! [`CostModel`] behind [`Method::Auto`].
//!
//! `Method` replaces the coordinator's stringly-routed `AlgoSpec` (kept
//! as a re-export alias) *and* the ad-hoc `DualTreeConfig` construction
//! scattered across callers: the four dual-tree variants map to their
//! configs via [`Method::dual_tree_config`], and Naive/FGT/IFGT are
//! first-class variants instead of side doors.

use crate::algo::dualtree::{DualTreeConfig, SeriesKind};

/// Which algorithm a [`crate::api::Session`] evaluation runs.
///
/// The first seven concrete variants are the paper's seven table rows,
/// [`Method::Sliced`] is the post-paper eighth engine, and
/// [`Method::Auto`] defers the choice to the session's [`CostModel`]
/// (dimension, N, h-to-scale ratio) at evaluate time.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Exhaustive O(N·M) summation.
    Naive,
    /// Flat-grid Fast Gauss Transform (needs ε-verification; the
    /// session runs the paper's τ-halving loop).
    Fgt,
    /// Improved FGT (needs ε-verification; the session runs the
    /// paper's K-doubling loop).
    Ifgt,
    /// Dual-tree finite difference, Theorem-2 control.
    Dfd,
    /// DFD + the paper's token error control.
    Dfdo,
    /// Dual-tree O(pᴰ) grid expansion + token control.
    Dfto,
    /// The paper's contribution: dual-tree O(Dᵖ) graded expansion +
    /// token control.
    Dito,
    /// Sliced Fourier fast summation (Hertrich, arXiv 2401.08260):
    /// seeded random 1-D projections + truncated-Fourier fast sums,
    /// ε-verified by the session's P-doubling loop. The eighth engine,
    /// added for the high-D regimes where series expansions die.
    Sliced,
    /// Let the session's [`CostModel`] pick per problem.
    Auto,
}

impl Method {
    /// Short table name ("DITO", "FGT", …; "Auto" for the selector).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Naive => "Naive",
            Method::Fgt => "FGT",
            Method::Ifgt => "IFGT",
            Method::Dfd => "DFD",
            Method::Dfdo => "DFDO",
            Method::Dfto => "DFTO",
            Method::Dito => "DITO",
            Method::Sliced => "Sliced",
            Method::Auto => "Auto",
        }
    }

    /// Case-insensitive parse of [`name`](Method::name)-style strings.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(Method::Naive),
            "fgt" => Some(Method::Fgt),
            "ifgt" => Some(Method::Ifgt),
            "dfd" => Some(Method::Dfd),
            "dfdo" => Some(Method::Dfdo),
            "dfto" => Some(Method::Dfto),
            "dito" => Some(Method::Dito),
            "sliced" => Some(Method::Sliced),
            "auto" => Some(Method::Auto),
            _ => None,
        }
    }

    /// The paper's seven-row table order (concrete methods only).
    pub fn paper_order() -> Vec<Method> {
        vec![
            Method::Naive,
            Method::Fgt,
            Method::Ifgt,
            Method::Dfd,
            Method::Dfdo,
            Method::Dfto,
            Method::Dito,
        ]
    }

    /// Index of a concrete method in [`paper_order`](Method::paper_order)
    /// — the row this method occupies in per-method histograms such as
    /// [`crate::algo::RunStats::sog_routed`]. `None` for `Auto` (which
    /// always resolves to a concrete method before any work is
    /// counted) and for post-paper engines like `Sliced` that have no
    /// row in the paper's tables.
    pub fn paper_index(&self) -> Option<usize> {
        match self {
            Method::Naive => Some(0),
            Method::Fgt => Some(1),
            Method::Ifgt => Some(2),
            Method::Dfd => Some(3),
            Method::Dfdo => Some(4),
            Method::Dfto => Some(5),
            Method::Dito => Some(6),
            Method::Sliced | Method::Auto => None,
        }
    }

    /// Every variant, `Auto` included.
    pub const ALL: [Method; 9] = [
        Method::Naive,
        Method::Fgt,
        Method::Ifgt,
        Method::Dfd,
        Method::Dfdo,
        Method::Dfto,
        Method::Dito,
        Method::Sliced,
        Method::Auto,
    ];

    /// Whether this method runs on the generic dual-tree engine.
    pub fn is_dual_tree(&self) -> bool {
        matches!(self, Method::Dfd | Method::Dfdo | Method::Dfto | Method::Dito)
    }

    /// Whether an answer carries the ε guarantee *by construction*.
    /// FGT/IFGT/Sliced answers are still ε-verified by the session's
    /// tuning loops (τ-halving, K-doubling, P-doubling), just not by
    /// the algorithm itself. `Auto` reports `true`: whatever it
    /// resolves to, the session either has the guarantee by
    /// construction or verifies it before answering.
    pub fn guarantees_tolerance(&self) -> bool {
        !matches!(self, Method::Fgt | Method::Ifgt | Method::Sliced)
    }

    /// The engine configuration a dual-tree method denotes, or `None`
    /// for Naive/FGT/IFGT/Sliced/Auto. This is the single point where
    /// method names meet `DualTreeConfig` — callers no longer
    /// hand-assemble `use_tokens`/`series` combinations.
    pub fn dual_tree_config(
        &self,
        leaf_size: usize,
        plimit: Option<usize>,
    ) -> Option<DualTreeConfig> {
        let base = DualTreeConfig { leaf_size, plimit, ..Default::default() };
        match self {
            Method::Dfd => Some(DualTreeConfig { use_tokens: false, series: None, ..base }),
            Method::Dfdo => Some(DualTreeConfig { use_tokens: true, series: None, ..base }),
            Method::Dfto => Some(DualTreeConfig { series: Some(SeriesKind::OpdGrid), ..base }),
            Method::Dito => Some(base),
            Method::Naive | Method::Fgt | Method::Ifgt | Method::Sliced | Method::Auto => None,
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Everything [`CostModel::best_method`] looks at: the problem-level
/// analogue of the per-node-pair geometry the traversal's
/// [`crate::algo::bestmethod::CostModel`] costs out.
#[derive(Copy, Clone, Debug)]
pub struct ProblemProfile {
    pub dim: usize,
    pub n_queries: usize,
    pub n_references: usize,
    pub h: f64,
    pub epsilon: f64,
    /// Mean per-dimension standard deviation of the reference set (the
    /// same spread measure Silverman's rule uses) — the yardstick the
    /// bandwidth is compared against.
    pub data_scale: f64,
}

impl ProblemProfile {
    /// Bandwidth relative to the data spread — the axis the paper's
    /// tables sweep (h as a multiple of h*, up to the pilot constant).
    pub fn h_ratio(&self) -> f64 {
        let scale = if self.data_scale > 0.0 { self.data_scale } else { 1.0 };
        self.h / scale
    }
}

/// The promoted, problem-level `bestMethod`: where the traversal-level
/// [`crate::algo::bestmethod::CostModel`] picks the cheapest *operator*
/// per node pair, this one picks the cheapest *algorithm* per problem
/// from (dimension, N, h-to-scale ratio). Thresholds are data-driven
/// defaults from the paper's tables and this repo's `ablations` bench;
/// all are overridable via [`crate::api::PrepareOptions`].
///
/// The decision table (see DESIGN.md for the full rationale):
///
/// | regime | choice | why |
/// |---|---|---|
/// | max(N_Q, N_R) ≤ `naive_cutoff` | Naive | tree prep can't pay for itself |
/// | h/scale < `fd_ratio` | DFDO | kernel ≈ local: series never fires, FD-only constant wins |
/// | D ≥ `sliced_dim` | Sliced | series sizes explode and dual trees stop pruning in high D |
/// | h/scale > max(`far_ratio`/√D, `far_floor`) | DFDO | kernel ≈ flat: root-level FD prune |
/// | otherwise | DITO | the paper's winner in the contested middle band |
///
/// The far-field threshold is **clamped below** by `far_floor`: the
/// raw `far_ratio/√D` bound was derived from low-D dual-tree behavior
/// and collapses toward 0 as D grows, which used to shunt essentially
/// every high-D problem to DFDO — where the dual tree prunes nothing
/// and the run degenerates to a slow O(N·M). High-D problems now go
/// to Sliced instead, and mid-D far-field ones keep a sane threshold.
///
/// FGT/IFGT are never auto-selected: their answers need ε-verification
/// against an exhaustive run, so as one-shot choices they are dominated
/// by Naive itself (they remain reachable explicitly for the paper's
/// table protocol). DFD is dominated by DFDO (tokens only add prune
/// opportunities) and DFTO by DITO (the O(Dᵖ) bounds subsume the grid
/// expansion's node-size restriction), per the paper's conclusions.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Below this many points on the larger side, preparation cannot
    /// amortize: exhaustive summation wins outright.
    pub naive_cutoff: usize,
    /// h/scale below which the finite-difference-only engine wins.
    pub fd_ratio: f64,
    /// Dimension-normalized h/scale above which everything is far
    /// field and the FD-only engine wins again (threshold is
    /// `far_ratio / sqrt(D)`: the contested series band narrows as the
    /// expansion sizes grow with D).
    pub far_ratio: f64,
    /// Lower clamp on the far-field threshold: `far_ratio/√D` is a
    /// low-D calibration and must not collapse to 0 in high D.
    pub far_floor: f64,
    /// Dimension at and above which non-near-diagonal problems route
    /// to the sliced Fourier engine.
    pub sliced_dim: usize,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            naive_cutoff: 256,
            fd_ratio: 0.02,
            far_ratio: 5.0,
            far_floor: 2.0,
            sliced_dim: 20,
        }
    }
}

impl CostModel {
    /// Resolve [`Method::Auto`] for one problem.
    pub fn best_method(&self, p: &ProblemProfile) -> Method {
        if p.n_queries.max(p.n_references) <= self.naive_cutoff {
            return Method::Naive;
        }
        let ratio = p.h_ratio();
        if ratio < self.fd_ratio {
            // near-diagonal: only immediate neighbors matter, and the
            // kd-tree finds them in any dimension
            return Method::Dfdo;
        }
        if p.dim >= self.sliced_dim {
            return Method::Sliced;
        }
        let far = (self.far_ratio / (p.dim as f64).sqrt()).max(self.far_floor);
        if ratio > far {
            Method::Dfdo
        } else {
            Method::Dito
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_all_methods() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
            assert_eq!(Method::parse(&m.name().to_lowercase()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
        assert_eq!(Method::parse("auto"), Some(Method::Auto));
    }

    #[test]
    fn paper_order_is_the_seven_concrete_rows() {
        let order = Method::paper_order();
        assert_eq!(order.len(), 7);
        assert!(!order.contains(&Method::Auto));
        assert_eq!(order[0], Method::Naive);
        assert_eq!(order[6], Method::Dito);
        for (i, m) in order.iter().enumerate() {
            assert_eq!(m.paper_index(), Some(i));
        }
        assert_eq!(Method::Auto.paper_index(), None);
    }

    #[test]
    fn dual_tree_config_matches_paper_switchboard() {
        let dfd = Method::Dfd.dual_tree_config(16, None).unwrap();
        assert!(!dfd.use_tokens && dfd.series.is_none() && dfd.leaf_size == 16);
        let dfdo = Method::Dfdo.dual_tree_config(32, None).unwrap();
        assert!(dfdo.use_tokens && dfdo.series.is_none());
        let dfto = Method::Dfto.dual_tree_config(32, Some(4)).unwrap();
        assert_eq!(dfto.series, Some(SeriesKind::OpdGrid));
        assert_eq!(dfto.plimit, Some(4));
        let dito = Method::Dito.dual_tree_config(32, None).unwrap();
        assert_eq!(dito.series, Some(SeriesKind::OdpGraded));
        assert!(dito.use_tokens);
        for m in [Method::Naive, Method::Fgt, Method::Ifgt, Method::Sliced, Method::Auto] {
            assert!(m.dual_tree_config(32, None).is_none(), "{m}");
        }
    }

    #[test]
    fn cost_model_regimes() {
        let cm = CostModel::default();
        let mk = |dim, n, h, scale| ProblemProfile {
            dim,
            n_queries: n,
            n_references: n,
            h,
            epsilon: 0.01,
            data_scale: scale,
        };
        // tiny problems: exhaustive
        assert_eq!(cm.best_method(&mk(2, 100, 0.1, 0.2)), Method::Naive);
        // local kernel: FD-only
        assert_eq!(cm.best_method(&mk(2, 5000, 1e-4, 0.2)), Method::Dfdo);
        // flat kernel: FD-only again
        assert_eq!(cm.best_method(&mk(2, 5000, 100.0, 0.2)), Method::Dfdo);
        // contested middle band: the paper's algorithm
        assert_eq!(cm.best_method(&mk(2, 5000, 0.05, 0.2)), Method::Dito);
        // high-D middle band still DITO (the O(Dᵖ) selling point)
        assert_eq!(cm.best_method(&mk(16, 5000, 0.1, 0.2)), Method::Dito);
        // degenerate zero spread must not divide by zero
        assert_eq!(cm.best_method(&mk(2, 5000, 0.5, 0.0)), Method::Dito);
        // D ≥ sliced_dim routes to the sliced Fourier engine …
        assert_eq!(cm.best_method(&mk(20, 5000, 0.1, 0.2)), Method::Sliced);
        assert_eq!(cm.best_method(&mk(50, 5000, 1.0, 0.2)), Method::Sliced);
        // … unless the kernel is near-diagonal (neighbors-only work
        // stays on the dual tree in any dimension) or the problem tiny
        assert_eq!(cm.best_method(&mk(20, 5000, 1e-4, 0.2)), Method::Dfdo);
        assert_eq!(cm.best_method(&mk(50, 100, 1.0, 0.2)), Method::Naive);
        // the far_floor clamp: at D = 16 the raw 5/√D ≈ 1.25 threshold
        // used to misroute h/scale = 1.8 to DFDO (which prunes nothing
        // there); the clamped threshold max(1.25, 2.0) keeps DITO
        assert_eq!(cm.best_method(&mk(16, 5000, 0.36, 0.2)), Method::Dito);
        // genuinely flat kernels still go far-field even mid-D
        assert_eq!(cm.best_method(&mk(16, 5000, 0.5, 0.2)), Method::Dfdo);
    }
}
