//! The [`Session`] — prepare a dataset once, answer many
//! Gaussian-summation requests.
//!
//! `prepare` does all dataset-dependent, h-independent work eagerly:
//! the kd-tree (with its permuted SoA point storage and cached node
//! geometry) via the embedded [`SweepEngine`], plus the data-spread
//! statistic the [`CostModel`] compares bandwidths against. Everything
//! else is built lazily on first use and memoized per session:
//!
//! * per-bandwidth Hermite **moments** (the engine's bounded memo),
//! * per-bandwidth **exhaustive truth** (needed to ε-verify FGT/IFGT
//!   and to serve [`Method::Naive`]; computed at most once per h, with
//!   concurrent requesters blocking on the first computation instead
//!   of duplicating it),
//! * the **FGT grid frame** (joint bounding box),
//! * **IFGT clustering plans** per (K, seed).
//!
//! [`Session::evaluate`] answers one [`EvalRequest`];
//! [`Session::evaluate_batch`] schedules the whole request list onto
//! the session's shared [`WorkStealPool`] — the *same* pool every
//! dual-tree traversal fans its subtree tasks into, so a batch of 2
//! requests on an 8-worker session exposes 2 × up-to-32 leaf tasks and
//! keeps every worker busy (the pre-pool design pinned each request to
//! one inner thread, leaving workers − requests cores idle). Results
//! of the deterministic methods are still bit-identical to sequential
//! evaluation in any worker count: the traversal's task decomposition
//! and indexed reduction are pool-width-invariant (see
//! [`crate::algo::dualtree`]), and the batch itself reduces by request
//! index. (IFGT is the standing exception — its K-doubling tunes
//! against a wall-clock budget, so it is ε-verified but
//! timing-dependent at any width.) Monochromatic dual-tree requests
//! run on the prepared tree; requests with an explicit query matrix
//! reuse the prepared reference tree and moment memo and build only a
//! query tree; requests with a per-request weight override fall back
//! to a one-shot prepare (the prepared tree bakes the session weights
//! into its node statistics).

use std::collections::HashMap;
use std::hash::Hash;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::runtime::sync::SyncMutex;

use crate::algo::dualtree::{run_dualtree, SweepEngine, DEFAULT_MOMENT_CACHE_CAPACITY};
use crate::algo::fgt::GridFrame;
use crate::algo::ifgt::IfgtPlan;
use crate::algo::naive::Naive;
use crate::algo::{AlgoError, GaussSum, GaussSumProblem, RunStats};
use crate::compute::simd::{Precision, SimdMode};
use crate::errorcontrol::split_epsilon_kernel;
use crate::geometry::Matrix;
use crate::kernel::{Kernel, SumOfGaussians};
use crate::runtime::pool::WorkStealPool;
use crate::util::stats;
use crate::util::timer::time_it;

use super::method::{CostModel, Method, ProblemProfile};
use super::tuning;

/// Preparation-time knobs. The defaults match the paper protocol and
/// every pre-session call path (leaf 32, one thread, unit weights).
#[derive(Clone, Debug)]
pub struct PrepareOptions {
    /// kd-tree leaf size (also used for per-request query trees).
    pub leaf_size: usize,
    /// Width of the session's shared work-stealing pool, used by
    /// [`Session::evaluate`] (across query-subtree tasks) and
    /// [`Session::evaluate_batch`] (across requests *and* their nested
    /// subtree tasks — one scheduler, so small batches still use every
    /// worker). Results of the deterministic methods (Naive, the
    /// dual-tree family, FGT's τ-halving) are bit-identical for every
    /// width; IFGT tunes against a wall-clock budget and is therefore
    /// ε-verified but timing-dependent at *any* width. 1 (the default)
    /// runs inline without spawning threads.
    pub threads: usize,
    /// Per-reference weights baked into the prepared tree (`None` =
    /// unit weights, the paper's KDE setting).
    pub weights: Option<Vec<f64>>,
    /// Capacity of the per-bandwidth Hermite-moment memo.
    pub moment_cache_capacity: usize,
    /// Capacity of the per-bandwidth exhaustive-truth memo. Size it to
    /// at least the number of distinct bandwidths a sweep will touch
    /// (the coordinator does) — an evicted entry costs a repeated
    /// O(N·M) run on the next request for that h.
    pub truth_cache_capacity: usize,
    /// Thresholds behind [`Method::Auto`].
    pub cost_model: CostModel,
    /// Run dual-tree base cases on the certified fast tiled kernel
    /// (default on). The certified error is reserved out of each
    /// request's ε budget (`errorcontrol::split_epsilon`), so answers
    /// stay ε-guaranteed; bandwidths where the bound is unaffordable
    /// fall back to the bit-exact path automatically. `false` forces
    /// the bit-exact base case for every request (the reference
    /// configuration, also reachable as the `fast_exp = false` config
    /// key / `--fast-exp false` CLI flag). Naive answers (the
    /// verification truth) are always bit-exact regardless.
    pub fast_exp: bool,
    /// Vector-lane dispatch for the fast base-case tiles: `Auto` (the
    /// default) installs the backend detected once per process
    /// (AVX2+FMA on x86_64, NEON on aarch64, scalar otherwise); `Off`
    /// pins the scalar table, whose results are bit-identical to the
    /// pre-SIMD code. Also reachable as the `simd` config key /
    /// `--simd` CLI flag, and the `FASTGAUSS_SIMD=off` environment
    /// variable pins the whole process.
    pub simd: SimdMode,
    /// Arithmetic precision of the fast tile. [`Precision::F32`] stores
    /// reference lanes, weights and norms in f32 (f64 accumulation) and
    /// engages per request only when its derived certificate
    /// (`errorcontrol::base_case_rel_err_f32`) fits the ε/4 admission
    /// gate — otherwise the request silently demotes to the certified
    /// f64 fast path, so every answer stays ε-guaranteed. Also
    /// reachable as the `precision` config key / `--precision` flag.
    pub precision: Precision,
    /// Default kernel family for requests that don't carry their own
    /// ([`EvalRequest::kernel`] = `None`). [`Kernel::Gaussian`] (the
    /// default) leaves every existing path bit-for-bit untouched;
    /// non-Gaussian families route through the certified
    /// sum-of-Gaussians batch path (see [`Session::evaluate`]).
    pub kernel: Kernel,
    /// Starting slice count P for [`Method::Sliced`]'s P-doubling
    /// verification loop (0, the default, uses the engine's built-in
    /// start). The loop reuses already-computed slices across doublings,
    /// so a generous start only costs time when the problem needs fewer
    /// slices than it. Also reachable as the `slices` config key /
    /// `--slices` CLI flag.
    pub slices: usize,
}

impl Default for PrepareOptions {
    fn default() -> Self {
        PrepareOptions {
            leaf_size: 32,
            threads: 1,
            weights: None,
            moment_cache_capacity: DEFAULT_MOMENT_CACHE_CAPACITY,
            truth_cache_capacity: DEFAULT_TRUTH_CACHE_CAPACITY,
            cost_model: CostModel::default(),
            fast_exp: true,
            simd: SimdMode::Auto,
            precision: Precision::F64,
            kernel: Kernel::Gaussian,
            slices: 0,
        }
    }
}

/// One summation request against a prepared [`Session`].
#[derive(Copy, Clone, Debug)]
pub struct EvalRequest<'a> {
    /// Explicit query matrix, or `None` for the monochromatic setting
    /// (queries = the session's reference data, the paper's KDE case).
    pub queries: Option<&'a Matrix>,
    /// Per-request weight override. Dual-tree methods fall back to a
    /// one-shot tree build for such requests (the prepared tree bakes
    /// the session weights in); prefer [`PrepareOptions::weights`] for
    /// weighted workloads that should amortize.
    pub weights: Option<&'a [f64]>,
    /// Bandwidth h of the Gaussian kernel.
    pub h: f64,
    /// Relative error tolerance ε.
    pub epsilon: f64,
    /// Algorithm, or [`Method::Auto`] (the default) to let the
    /// session's cost model choose.
    pub method: Method,
    /// Override the paper's PLIMIT-per-dimension schedule (dual-tree
    /// series variants only).
    pub plimit: Option<usize>,
    /// Kernel-family override: `None` (the default) inherits the
    /// session's [`PrepareOptions::kernel`]. For non-Gaussian families
    /// `h` is the family's scale parameter (σ / ℓ / c) and `epsilon`
    /// bounds the *weight-scaled absolute* error max_q |G̃−G| ≤ ε·W
    /// (see [`crate::errorcontrol::split_epsilon_kernel`]); `method`
    /// applies to every Gaussian component, with [`Method::Auto`]
    /// routing each component's hᵢ independently through the cost
    /// model.
    pub kernel: Option<Kernel>,
}

impl<'a> EvalRequest<'a> {
    /// A monochromatic (KDE) request with automatic method selection.
    pub fn kde(h: f64, epsilon: f64) -> Self {
        EvalRequest {
            queries: None,
            weights: None,
            h,
            epsilon,
            method: Method::Auto,
            plimit: None,
            kernel: None,
        }
    }

    pub fn with_method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    pub fn with_queries(mut self, queries: &'a Matrix) -> Self {
        self.queries = Some(queries);
        self
    }

    pub fn with_weights(mut self, weights: &'a [f64]) -> Self {
        self.weights = Some(weights);
        self
    }

    pub fn with_plimit(mut self, plimit: usize) -> Self {
        self.plimit = Some(plimit);
        self
    }

    /// Pin this request to one kernel family, overriding the session
    /// default (`with_kernel(Kernel::Gaussian)` forces the native path
    /// on a non-Gaussian session — LSCV and the KDE normalizers do
    /// exactly that, their closed forms being Gaussian-specific).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = Some(kernel);
        self
    }
}

/// An answered request: per-query sums in the original row order, the
/// run's counters, the *resolved* method (`Auto` never appears here),
/// and — for the verified paths (Naive, FGT, IFGT, Sliced) — the
/// measured max relative error. Dual-tree answers carry
/// `rel_err: None`: their ε bound holds by construction, so no
/// exhaustive verification is run.
/// Non-Gaussian answers also carry `rel_err: None` (their guarantee is
/// the weight-scaled absolute form ε·W, certified by construction) plus
/// a [`SogReport`] describing the decomposition and the per-component
/// routing; `method` is then the resolved method of the
/// largest-weight component.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub sums: Vec<f64>,
    pub stats: RunStats,
    pub method: Method,
    pub rel_err: Option<f64>,
    /// The kernel family this answer is for (`Gaussian` on every
    /// pre-existing path, including the components of a SoG answer).
    pub kernel: Kernel,
    /// Present exactly when `kernel` is non-Gaussian.
    pub sog: Option<SogReport>,
}

/// How one Gaussian component of a sum-of-Gaussians evaluation was
/// answered.
#[derive(Clone, Debug)]
pub struct SogComponentRoute {
    /// Mixture weight wᵢ of this component.
    pub weight: f64,
    /// Gaussian bandwidth hᵢ of this component.
    pub bandwidth: f64,
    /// The resolved method this component ran (`Auto` never appears —
    /// each hᵢ routes independently through the cost model).
    pub method: Method,
    /// Wall-clock seconds of this component's evaluation.
    pub secs: f64,
}

/// The certificate trail of one non-Gaussian answer: how the ε budget
/// was split (ε = decomp_err + Σᵢ wᵢ·component_eps·…, see
/// [`crate::errorcontrol::split_epsilon_kernel`]) and which engine each
/// Gaussian component routed to.
#[derive(Clone, Debug)]
pub struct SogReport {
    /// Certified sup-norm error of the fitted decomposition, charged
    /// up front (always ≤ ε/4).
    pub decomp_err: f64,
    /// Relative ε every Gaussian component request ran under.
    pub component_eps: f64,
    /// Total reference weight W scaling the guarantee
    /// max_q |G̃(q) − G(q)| ≤ ε·W.
    pub total_weight: f64,
    /// Per-component routing, in fixed (ascending-u) decomposition
    /// order.
    pub components: Vec<SogComponentRoute>,
}

/// Insertion-order-bounded memo backing the session's truth and
/// clustering-plan caches. (The engine's `MomentCache` graduated to
/// true LRU — hot bandwidths get hammered by adaptive h-searches;
/// truth cells and clustering plans see one access pattern, the sweep
/// grid, where insertion order ≈ recency, so FIFO stays.)
struct BoundedMemo<K, V> {
    map: HashMap<K, (u64, V)>,
    next_stamp: u64,
    capacity: usize,
}

impl<K: Eq + Hash + Copy, V: Clone> BoundedMemo<K, V> {
    fn new(capacity: usize) -> Self {
        BoundedMemo { map: HashMap::new(), next_stamp: 0, capacity: capacity.max(1) }
    }

    fn get(&mut self, key: &K) -> Option<V> {
        self.map.get(key).map(|(_, v)| v.clone())
    }

    fn insert(&mut self, key: K, value: V) {
        if let Some(slot) = self.map.get_mut(&key) {
            slot.1 = value;
            return;
        }
        while self.map.len() + 1 > self.capacity {
            let oldest = self.map.iter().min_by_key(|(_, (s, _))| *s).map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                }
                None => break,
            }
        }
        self.map.insert(key, (self.next_stamp, value));
        self.next_stamp += 1;
    }
}

/// One bandwidth's exhaustive-truth slot.
enum TruthSlot {
    /// Not yet computed — the first requester computes under the cell
    /// lock while concurrent requesters of the same h block on it.
    Pending,
    /// `(sums, compute seconds)`.
    Ready(Arc<Vec<f64>>, f64),
    /// The computing requester panicked. The message is kept so every
    /// current and future waiter gets a clean [`AlgoError::Internal`]
    /// instead of panicking on a poisoned mutex or silently recomputing
    /// a run that just proved it can crash.
    Failed(String),
}

/// One bandwidth's exhaustive truth: computed under the cell lock so a
/// concurrent second requester blocks and reuses instead of duplicating
/// the O(N²) run — this is what lets the coordinator schedule truth
/// *inside* the shared pool. The compute runs under `catch_unwind`, so
/// a panic can neither poison this mutex nor strand waiters (see
/// [`TruthSlot::Failed`]).
struct TruthCell {
    slot: SyncMutex<TruthSlot>,
}

impl Default for TruthCell {
    fn default() -> Self {
        TruthCell { slot: SyncMutex::new(TruthSlot::Pending) }
    }
}

impl TruthCell {
    /// Resolve this cell: reuse a prior resolution, or run `compute`
    /// under the cell lock (the first requester computes; concurrent
    /// requesters of the same cell block on the lock and reuse the
    /// result — Pending→Ready/Failed is a single transition under one
    /// critical section, so a torn state is unobservable; the
    /// model-check suite in this file pins that across schedules).
    /// `Ok` carries `(sums, secs, was_memoized)`; `Err` carries
    /// `(message, panicked_in_this_call)`.
    fn get_or_compute(
        &self,
        compute: impl FnOnce() -> (Vec<f64>, f64),
    ) -> Result<(Arc<Vec<f64>>, f64, bool), (String, bool)> {
        let mut slot = self.slot.lock().unwrap();
        match &*slot {
            TruthSlot::Ready(sums, secs) => Ok((Arc::clone(sums), *secs, true)),
            TruthSlot::Failed(msg) => Err((msg.clone(), false)),
            TruthSlot::Pending => {
                // catch_unwind: the guard stays valid across a panic of
                // `compute`, so the mutex is not poisoned and blocked
                // waiters proceed into the Failed arm instead of
                // panicking on `.lock().unwrap()`.
                match catch_unwind(AssertUnwindSafe(compute)) {
                    Ok((sums, secs)) => {
                        let sums = Arc::new(sums);
                        *slot = TruthSlot::Ready(Arc::clone(&sums), secs);
                        Ok((sums, secs, false))
                    }
                    Err(payload) => {
                        let msg = panic_message(payload.as_ref());
                        *slot = TruthSlot::Failed(msg.clone());
                        Err((msg, true))
                    }
                }
            }
        }
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Default count of distinct bandwidths whose exhaustive truth stays
/// memoized — comfortably above the paper's 7-multiplier sweeps and the
/// 2×13-request LSCV grids.
pub const DEFAULT_TRUTH_CACHE_CAPACITY: usize = 64;

/// Distinct (K, seed) IFGT clustering plans kept live.
const IFGT_PLAN_CACHE_CAPACITY: usize = 16;

/// Distinct fitted sum-of-Gaussians decompositions kept live, keyed by
/// (kernel, scale, radius, fit target). A sweep touches one kernel at
/// ~7 scales; fits are 10–100 ms, so a small memo suffices.
const SOG_CACHE_CAPACITY: usize = 16;

/// A dataset prepared for repeated Gaussian-summation evaluation — the
/// crate's front door (see DESIGN.md for the lifecycle diagram).
///
/// ```no_run
/// use fastgauss::api::{EvalRequest, Method, PrepareOptions, Session};
/// let data = fastgauss::data::synthetic::astro2d(10_000, 42);
/// let session = Session::prepare(&data, PrepareOptions::default());
/// // one request, automatic method selection, guaranteed ε
/// let ans = session.evaluate(&EvalRequest::kde(0.05, 0.01)).unwrap();
/// println!("G(x_0) = {} via {}", ans.sums[0], ans.method);
/// // a bandwidth sweep amortized across the prepared state
/// let reqs: Vec<_> = [0.01, 0.05, 0.25]
///     .iter()
///     .map(|&h| EvalRequest::kde(h, 0.01).with_method(Method::Dito))
///     .collect();
/// for ans in session.evaluate_batch(&reqs) {
///     println!("{}", ans.unwrap().sums[0]);
/// }
/// assert_eq!(session.tree_builds(), 1); // everything shared one build
/// ```
pub struct Session<'d> {
    data: &'d Matrix,
    weights: Option<Vec<f64>>,
    leaf_size: usize,
    fast_exp: bool,
    simd: SimdMode,
    precision: Precision,
    kernel: Kernel,
    slices: usize,
    cost_model: CostModel,
    data_scale: f64,
    /// Per-dimension data bounding box — with a query box joined in,
    /// its diagonal bounds every pair distance a request can produce,
    /// which is the range SoG decompositions are certified on.
    data_lo: Vec<f64>,
    data_hi: Vec<f64>,
    prep_secs: f64,
    engine: SweepEngine,
    grid_frame: SyncMutex<Option<Arc<GridFrame>>>,
    ifgt_plans: SyncMutex<BoundedMemo<(usize, u64), Arc<IfgtPlan>>>,
    truth: SyncMutex<BoundedMemo<(Kernel, u64), Arc<TruthCell>>>,
    sog_memo: SyncMutex<BoundedMemo<(Kernel, u64, u64, u64), Arc<SumOfGaussians>>>,
}

impl<'d> Session<'d> {
    /// Build all eager dataset-dependent state: the kd-tree (one build,
    /// amortized over every evaluation this session answers) and the
    /// data-spread statistic for [`Method::Auto`].
    pub fn prepare(data: &'d Matrix, opts: PrepareOptions) -> Self {
        let PrepareOptions {
            leaf_size,
            threads,
            weights,
            moment_cache_capacity,
            truth_cache_capacity,
            cost_model,
            fast_exp,
            simd,
            precision,
            kernel,
            slices,
        } = opts;
        let (engine, prep_secs) = time_it(|| {
            // placeholder h/ε: prepare ignores them by construction
            let problem = match &weights {
                None => GaussSumProblem::kde(data, 1.0, 1.0),
                Some(w) => {
                    let mut p = GaussSumProblem::new(data, data, Some(w), 1.0, 1.0);
                    p.monochromatic = true;
                    p
                }
            };
            // one pool for the whole session: the engine's traversal
            // tasks and evaluate_batch's request tasks share it
            SweepEngine::prepare(&problem, leaf_size)
                .with_threads(threads)
                .with_moment_cache_capacity(moment_cache_capacity)
        });
        let data_scale = stats::mean(&data.col_std());
        Session {
            data,
            weights,
            leaf_size,
            fast_exp,
            simd,
            precision,
            kernel,
            slices,
            cost_model,
            data_scale,
            data_lo: data.col_min(),
            data_hi: data.col_max(),
            prep_secs,
            engine,
            grid_frame: SyncMutex::new(None),
            ifgt_plans: SyncMutex::new(BoundedMemo::new(IFGT_PLAN_CACHE_CAPACITY)),
            truth: SyncMutex::new(BoundedMemo::new(truth_cache_capacity)),
            sog_memo: SyncMutex::new(BoundedMemo::new(SOG_CACHE_CAPACITY)),
        }
    }

    /// [`prepare`](Session::prepare) with defaults — the paper's KDE
    /// setting on one dataset.
    pub fn kde(data: &'d Matrix) -> Self {
        Self::prepare(data, PrepareOptions::default())
    }

    /// The reference data this session was prepared on.
    pub fn data(&self) -> &'d Matrix {
        self.data
    }

    pub fn dim(&self) -> usize {
        self.data.cols()
    }

    /// Number of reference points (= query points in the monochromatic
    /// setting).
    pub fn num_points(&self) -> usize {
        self.data.rows()
    }

    /// Whether the session carries unit weights (LSCV requires this).
    pub fn is_unweighted(&self) -> bool {
        self.weights.is_none()
    }

    /// Seconds spent in [`prepare`](Session::prepare).
    pub fn prepare_secs(&self) -> f64 {
        self.prep_secs
    }

    /// kd-tree constructions performed by `prepare` — constant over any
    /// number of evaluations (per-request query trees are reported in
    /// each answer's `stats.tree_builds` instead).
    pub fn tree_builds(&self) -> u64 {
        self.engine.tree_builds()
    }

    /// Mean per-dimension standard deviation of the data — the h
    /// yardstick behind [`Method::Auto`].
    pub fn data_scale(&self) -> f64 {
        self.data_scale
    }

    /// The session's default kernel family ([`PrepareOptions::kernel`]).
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Total reference weight W = Σ_j ω_j (= N for unit weights) — the
    /// scale of the non-Gaussian guarantee max_q |G̃−G| ≤ ε·W.
    pub fn total_weight(&self) -> f64 {
        match &self.weights {
            Some(w) => w.iter().sum(),
            None => self.data.rows() as f64,
        }
    }

    /// The kernel family `req` resolves to: its explicit override, or
    /// the session default.
    pub fn kernel_for(&self, req: &EvalRequest<'_>) -> Kernel {
        req.kernel.unwrap_or(self.kernel)
    }

    /// The embedded two-phase dual-tree engine (lower-level API; kept
    /// public for callers that want `evaluate_grid`-style access).
    pub fn engine(&self) -> &SweepEngine {
        &self.engine
    }

    /// The session's shared work-stealing pool — the one scheduler
    /// under every traversal split, request batch and (through the
    /// coordinator) sweep cell this session serves.
    pub fn pool(&self) -> &Arc<WorkStealPool> {
        self.engine.pool()
    }

    /// The problem-level profile [`Method::Auto`] is resolved from.
    pub fn profile(&self, req: &EvalRequest<'_>) -> ProblemProfile {
        ProblemProfile {
            dim: self.data.cols(),
            n_queries: req.queries.map_or(self.data.rows(), |q| q.rows()),
            n_references: self.data.rows(),
            h: req.h,
            epsilon: req.epsilon,
            data_scale: self.data_scale,
        }
    }

    /// The concrete method `req` will run: `req.method` itself, or the
    /// cost model's pick when it is [`Method::Auto`].
    pub fn resolve(&self, req: &EvalRequest<'_>) -> Method {
        match req.method {
            Method::Auto => self.cost_model.best_method(&self.profile(req)),
            m => m,
        }
    }

    /// Answer one request. Panics on malformed requests (non-positive
    /// h/ε, dimension mismatch, non-positive weights) — the same
    /// contract as [`GaussSumProblem::new`]; algorithmic failure modes
    /// (the paper's X/∞) come back as [`AlgoError`].
    ///
    /// A non-Gaussian request (see [`EvalRequest::kernel`]) is resolved
    /// into its certified sum-of-Gaussians component batch and
    /// dispatched through [`evaluate_batch`](Session::evaluate_batch)
    /// — one tree, shared memos, each component's hᵢ routed through
    /// the cost model when the method is `Auto`. Gaussian requests take
    /// the pre-existing paths, bit for bit.
    pub fn evaluate(&self, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        assert!(req.h > 0.0 && req.h.is_finite(), "bandwidth must be positive");
        assert!(req.epsilon > 0.0, "epsilon must be positive");
        if let Some(q) = req.queries {
            assert_eq!(q.cols(), self.data.cols(), "query dimension mismatch");
        }
        let kernel = self.kernel_for(req);
        if !kernel.is_gaussian() {
            return self.eval_sog(kernel, req);
        }
        match self.resolve(req) {
            Method::Naive => self.eval_naive(req),
            Method::Fgt => self.eval_fgt(req),
            Method::Ifgt => self.eval_ifgt(req),
            Method::Sliced => self.eval_sliced(req),
            // lint: allow(no-panic): resolve() maps Auto to a concrete method before dispatch
            Method::Auto => unreachable!("resolve() returns a concrete method"),
            dual => self.eval_dualtree(dual, req),
        }
    }

    /// Answer a request list. Every request becomes a task on the
    /// session's shared pool, and each dual-tree request fans its
    /// subtree tasks into the *same* pool — so 2 requests on an
    /// 8-worker session yield 16-way useful work instead of pinning
    /// each request to one thread. Results come back in request order;
    /// for the deterministic methods (Naive, dual-tree, FGT) they are
    /// bit-identical to calling [`evaluate`](Session::evaluate)
    /// sequentially, in any worker count (each such evaluation is
    /// pool-width-invariant, and the batch reduces by request index) —
    /// IFGT requests tune against a wall-clock budget and are
    /// ε-verified but not schedule-invariant, batched or not; Sliced
    /// requests' accepted answers are pool-width-invariant, but their
    /// ∞ verdicts share IFGT's wall-clock dependence.
    /// Per-request failures (e.g. an FGT X cell) come back in place;
    /// they do not abort the batch.
    pub fn evaluate_batch(
        &self,
        requests: &[EvalRequest<'_>],
    ) -> Vec<Result<Evaluation, AlgoError>> {
        self.pool().run_indexed(requests.len(), |k| self.evaluate(&requests[k]))
    }

    /// The memoized exhaustive truth for one monochromatic bandwidth
    /// (session weights): `(sums, compute seconds, was_cached)`. The
    /// first requester computes under the per-bandwidth cell lock;
    /// concurrent requesters block on that cell and then share the
    /// result — whole different bandwidths never serialize on each
    /// other. If the computation panics, every waiter (and every later
    /// requester of this h) gets a clean [`AlgoError::Internal`]
    /// carrying the panic message — the cell mutex is never poisoned.
    pub fn exact_sums(
        &self,
        h: f64,
        epsilon: f64,
    ) -> Result<(Arc<Vec<f64>>, f64, bool), AlgoError> {
        self.exact_sums_with(h, || {
            let problem = self.mono_problem(h, epsilon);
            let (res, secs) = time_it(|| {
                // lint: allow(no-panic): the exhaustive reference on a prepared session is total
                Naive::new().run(&problem).expect("exhaustive run cannot fail")
            });
            (res.sums, secs)
        })
    }

    /// The memoized exhaustive truth of the *true* (non-decomposed)
    /// kernel at one monochromatic scale — what SoG answers are
    /// verified against under the weight-scaled absolute criterion.
    /// Gaussian delegates to [`exact_sums`](Session::exact_sums)
    /// (same memo slot, same bit-exact engine); the other families run
    /// the direct O(N²) closed-form summation, under the same
    /// blocking-dedupe cell machinery.
    pub fn exact_kernel_sums(
        &self,
        kernel: Kernel,
        scale: f64,
        epsilon: f64,
    ) -> Result<(Arc<Vec<f64>>, f64, bool), AlgoError> {
        if kernel.is_gaussian() {
            return self.exact_sums(scale, epsilon);
        }
        self.truth_slot(kernel, scale, || {
            time_it(|| kernel.direct_sums(scale, self.data, self.data, self.weights.as_deref()))
        })
    }

    /// [`exact_sums`](Session::exact_sums) with an explicit compute
    /// closure — the seam the panic-injection regression tests use.
    pub(crate) fn exact_sums_with(
        &self,
        h: f64,
        compute: impl FnOnce() -> (Vec<f64>, f64),
    ) -> Result<(Arc<Vec<f64>>, f64, bool), AlgoError> {
        self.truth_slot(Kernel::Gaussian, h, compute)
    }

    /// The (kernel, scale)-keyed truth cell behind
    /// [`exact_sums`](Session::exact_sums) and
    /// [`exact_kernel_sums`](Session::exact_kernel_sums).
    fn truth_slot(
        &self,
        kernel: Kernel,
        h: f64,
        compute: impl FnOnce() -> (Vec<f64>, f64),
    ) -> Result<(Arc<Vec<f64>>, f64, bool), AlgoError> {
        let cell = {
            let mut truth = self.truth.lock().unwrap();
            match truth.get(&(kernel, h.to_bits())) {
                Some(c) => c,
                None => {
                    let c = Arc::new(TruthCell::default());
                    truth.insert((kernel, h.to_bits()), Arc::clone(&c));
                    c
                }
            }
        };
        cell.get_or_compute(compute).map_err(|(msg, fresh)| {
            AlgoError::Internal(if fresh {
                format!("exhaustive {kernel} truth for h={h:.6e} panicked: {msg}")
            } else {
                format!("exhaustive {kernel} truth for h={h:.6e} previously failed: {msg}")
            })
        })
    }

    // ---- per-method evaluation paths ----

    fn eval_dualtree(
        &self,
        method: Method,
        req: &EvalRequest<'_>,
    ) -> Result<Evaluation, AlgoError> {
        let mut cfg = method
            .dual_tree_config(self.leaf_size, req.plimit)
            // lint: allow(no-panic): evaluate's match dispatches only dual-tree methods here
            .expect("eval_dualtree called with a dual-tree method");
        cfg.fast_exp = self.fast_exp;
        cfg.simd = self.simd;
        cfg.precision = self.precision;
        let (res, secs) = if req.weights.is_some() {
            // per-request weight override: the prepared tree bakes the
            // session weights into its node statistics, so this request
            // pays a one-shot prepare (documented trade-off)
            let problem = self.problem(req);
            time_it(|| run_dualtree(&problem, &cfg))
        } else if let Some(q) = req.queries {
            time_it(|| self.engine.evaluate_queries(q, self.leaf_size, req.h, req.epsilon, &cfg))
        } else {
            time_it(|| self.engine.evaluate(req.h, req.epsilon, &cfg))
        };
        let mut res = res?;
        res.stats.total_secs = secs;
        Ok(Evaluation {
            sums: res.sums,
            stats: res.stats,
            method,
            rel_err: None,
            kernel: Kernel::Gaussian,
            sog: None,
        })
    }

    fn eval_naive(&self, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        let n_refs = self.data.rows();
        if req.queries.is_none() && req.weights.is_none() {
            let (sums, secs, cached) = self.exact_sums(req.h, req.epsilon)?;
            let stats = RunStats {
                base_point_pairs: (n_refs * n_refs) as u64,
                session_cache_hits: cached as u64,
                session_cache_misses: !cached as u64,
                // on a cache hit this is the original compute time — the
                // honest cost of the answer, not of the lookup
                total_secs: secs,
                ..Default::default()
            };
            return Ok(Evaluation {
                sums: (*sums).clone(),
                stats,
                method: Method::Naive,
                rel_err: Some(0.0),
                kernel: Kernel::Gaussian,
                sog: None,
            });
        }
        let problem = self.problem(req);
        let (res, secs) = time_it(|| Naive::new().run(&problem));
        let mut res = res?;
        res.stats.total_secs = secs;
        Ok(Evaluation {
            sums: res.sums,
            stats: res.stats,
            method: Method::Naive,
            rel_err: Some(0.0),
            kernel: Kernel::Gaussian,
            sog: None,
        })
    }

    fn eval_fgt(&self, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        let problem = self.problem(req);
        let mut hits = 0u64;
        let mut misses = 0u64;
        let frame = if req.queries.is_none() {
            self.fgt_frame(&mut hits, &mut misses)
        } else {
            Arc::new(GridFrame::joint(problem.queries, problem.references))
        };
        let (exact, _truth_secs) = self.truth_for(&problem, req, &mut hits, &mut misses)?;
        let outcome = tuning::fgt_halving(&problem, &frame, &exact, tuning::FGT_MAX_ATTEMPTS)?;
        let mut res = outcome.result;
        res.stats.total_secs = outcome.attempt_secs;
        res.stats.session_cache_hits = hits;
        res.stats.session_cache_misses = misses;
        Ok(Evaluation {
            sums: res.sums,
            stats: res.stats,
            method: Method::Fgt,
            rel_err: Some(outcome.rel_err),
            kernel: Kernel::Gaussian,
            sog: None,
        })
    }

    fn eval_ifgt(&self, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        let problem = self.problem(req);
        let mut hits = 0u64;
        let mut misses = 0u64;
        let (exact, truth_secs) = self.truth_for(&problem, req, &mut hits, &mut misses)?;
        // tuning budget: a few multiples of the exhaustive time — past
        // that, IFGT has lost by definition (paper's by-hand cutoff)
        let budget_secs = (5.0 * truth_secs).max(2.0);
        let (outcome, total_secs) = time_it(|| {
            tuning::ifgt_doubling(&problem, &exact, tuning::IFGT_MAX_ROUNDS, budget_secs, |p| {
                self.ifgt_plan(p.clusters, p.seed, &mut hits, &mut misses)
            })
        });
        let outcome = outcome?;
        let rel_err = outcome.rel_err;
        let mut res = outcome.result;
        res.stats.total_secs = total_secs;
        res.stats.session_cache_hits = hits;
        res.stats.session_cache_misses = misses;
        Ok(Evaluation {
            sums: res.sums,
            stats: res.stats,
            method: Method::Ifgt,
            rel_err: Some(rel_err),
            kernel: Kernel::Gaussian,
            sog: None,
        })
    }

    /// Sliced Fourier evaluation under the P-doubling verification
    /// protocol ([`tuning::sliced_doubling`]). Slices fan out onto the
    /// session pool in fixed blocks, so any *accepted* answer is
    /// bit-identical across pool widths and repeated evaluates; like
    /// IFGT, only the budget-exhausted ∞ verdict is timing-dependent.
    fn eval_sliced(&self, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        let problem = self.problem(req);
        let mut hits = 0u64;
        let mut misses = 0u64;
        let (exact, truth_secs) = self.truth_for(&problem, req, &mut hits, &mut misses)?;
        // same tuning budget shape as IFGT: a few multiples of the
        // exhaustive time — past that, slicing has lost by definition
        let budget_secs = (5.0 * truth_secs).max(2.0);
        let (outcome, total_secs) = time_it(|| {
            tuning::sliced_doubling(
                &problem,
                &exact,
                self.slices,
                tuning::SLICED_MAX_ROUNDS,
                budget_secs,
                Some(self.pool().as_ref()),
            )
        });
        let outcome = outcome?;
        let rel_err = outcome.rel_err;
        let mut res = outcome.result;
        res.stats.total_secs = total_secs;
        res.stats.session_cache_hits = hits;
        res.stats.session_cache_misses = misses;
        Ok(Evaluation {
            sums: res.sums,
            stats: res.stats,
            method: Method::Sliced,
            rel_err: Some(rel_err),
            kernel: Kernel::Gaussian,
            sog: None,
        })
    }

    /// Answer a non-Gaussian request through its certified
    /// sum-of-Gaussians decomposition: fit (memoized) at target ε/4,
    /// charge the certified sup error out of the budget
    /// ([`split_epsilon_kernel`]), fan one Gaussian request per
    /// component into the pooled batch evaluator, and combine in fixed
    /// component order — bit-identical across pool widths for the
    /// deterministic engines, like every other batch.
    fn eval_sog(&self, kernel: Kernel, req: &EvalRequest<'_>) -> Result<Evaluation, AlgoError> {
        let (fit_result, fit_secs) = time_it(|| self.sog_decomposition(kernel, req));
        let (sog, cached) = fit_result?;
        let split = split_epsilon_kernel(req.epsilon, sog.sup_error, sog.weight_sum())
            .ok_or_else(|| {
                // unreachable for fits at target ε/4 ≤ gate; kept as a
                // clean failure rather than a debug assertion
                AlgoError::ToleranceUnreachable(format!(
                    "{kernel} decomposition error {:.3e} exceeds ε/4 = {:.3e}",
                    sog.sup_error,
                    0.25 * req.epsilon
                ))
            })?;
        let component_reqs: Vec<EvalRequest<'_>> = sog
            .terms
            .iter()
            .map(|t| EvalRequest {
                queries: req.queries,
                weights: req.weights,
                h: t.bandwidth,
                epsilon: split.component_eps,
                method: req.method,
                plimit: req.plimit,
                // explicit: components never re-enter the SoG path
                kernel: Some(Kernel::Gaussian),
            })
            .collect();
        let (results, batch_secs) = time_it(|| self.evaluate_batch(&component_reqs));
        let n_out = req.queries.map_or(self.data.rows(), |q| q.rows());
        let mut sums = vec![0.0; n_out];
        let mut stats = RunStats::default();
        let mut components = Vec::with_capacity(sog.terms.len());
        for (term, result) in sog.terms.iter().zip(results) {
            let ev = result?;
            for (acc, s) in sums.iter_mut().zip(&ev.sums) {
                *acc += term.weight * s;
            }
            stats.merge(&ev.stats);
            if let Some(idx) = ev.method.paper_index() {
                stats.sog_routed[idx] += 1;
            }
            components.push(SogComponentRoute {
                weight: term.weight,
                bandwidth: term.bandwidth,
                method: ev.method,
                secs: ev.stats.total_secs,
            });
        }
        stats.sog_components = components.len() as u64;
        stats.session_cache_hits += cached as u64;
        stats.session_cache_misses += !cached as u64;
        stats.total_secs = fit_secs + batch_secs;
        let method = components
            .iter()
            .max_by(|a, b| a.weight.total_cmp(&b.weight))
            .map(|c| c.method)
            // lint: allow(no-panic): fit() never returns an empty decomposition
            .expect("a fitted decomposition has at least one term");
        let total_weight = match req.weights {
            Some(w) => w.iter().sum(),
            None => self.total_weight(),
        };
        Ok(Evaluation {
            sums,
            stats,
            method,
            rel_err: None,
            kernel,
            sog: Some(SogReport {
                decomp_err: split.decomp_err,
                component_eps: split.component_eps,
                total_weight,
                components,
            }),
        })
    }

    // ---- lazy session state ----

    /// The request's problem view over the session data.
    fn problem<'s>(&'s self, req: &'s EvalRequest<'_>) -> GaussSumProblem<'s> {
        let weights = req.weights.or(self.weights.as_deref());
        match req.queries {
            Some(q) => GaussSumProblem::new(q, self.data, weights, req.h, req.epsilon),
            None => {
                let mut p = GaussSumProblem::new(self.data, self.data, weights, req.h, req.epsilon);
                p.monochromatic = true;
                p
            }
        }
    }

    fn mono_problem(&self, h: f64, epsilon: f64) -> GaussSumProblem<'_> {
        let mut p = GaussSumProblem::new(self.data, self.data, self.weights.as_deref(), h, epsilon);
        p.monochromatic = true;
        p
    }

    /// Exhaustive truth for verification: the session memo for
    /// monochromatic session-weight requests, a fresh run otherwise.
    fn truth_for(
        &self,
        problem: &GaussSumProblem<'_>,
        req: &EvalRequest<'_>,
        hits: &mut u64,
        misses: &mut u64,
    ) -> Result<(Arc<Vec<f64>>, f64), AlgoError> {
        if req.queries.is_none() && req.weights.is_none() {
            let (sums, secs, cached) = self.exact_sums(req.h, req.epsilon)?;
            if cached {
                *hits += 1;
            } else {
                *misses += 1;
            }
            Ok((sums, secs))
        } else {
            let (res, secs) = time_it(|| {
                // lint: allow(no-panic): the exhaustive reference on a prepared session is total
                Naive::new().run(problem).expect("exhaustive run cannot fail")
            });
            Ok((Arc::new(res.sums), secs))
        }
    }

    /// The lazily-built, session-cached FGT grid frame (monochromatic
    /// requests only — bichromatic frames depend on the query set).
    fn fgt_frame(&self, hits: &mut u64, misses: &mut u64) -> Arc<GridFrame> {
        let mut slot = self.grid_frame.lock().unwrap();
        match &*slot {
            Some(f) => {
                *hits += 1;
                Arc::clone(f)
            }
            None => {
                *misses += 1;
                let f = Arc::new(GridFrame::joint(self.data, self.data));
                *slot = Some(Arc::clone(&f));
                f
            }
        }
    }

    /// The memoized sum-of-Gaussians decomposition for `kernel` at the
    /// request's scale, certified over every distance this request can
    /// produce ([`pair_radius`](Session::pair_radius)). The fit target
    /// is ε/4 — exactly [`split_epsilon_kernel`]'s admission gate, so a
    /// successful fit always clears the budget split. Returns
    /// `(decomposition, was_cached)`; fitted outside the memo lock —
    /// racing fits of the same key are identical, like the moment memo.
    fn sog_decomposition(
        &self,
        kernel: Kernel,
        req: &EvalRequest<'_>,
    ) -> Result<(Arc<SumOfGaussians>, bool), AlgoError> {
        let radius = self.pair_radius(req.queries);
        let target = 0.25 * req.epsilon;
        let key = (kernel, req.h.to_bits(), radius.to_bits(), target.to_bits());
        if let Some(s) = self.sog_memo.lock().unwrap().get(&key) {
            return Ok((s, true));
        }
        let sog = SumOfGaussians::fit(kernel, req.h, radius, target).map_err(|e| {
            AlgoError::ToleranceUnreachable(format!(
                "{kernel} at scale {:.3e}: {e} — the ε·W guarantee needs a certified \
                 decomposition within ε/4 = {target:.3e}",
                req.h
            ))
        })?;
        let sog = Arc::new(sog);
        self.sog_memo.lock().unwrap().insert(key, Arc::clone(&sog));
        Ok((sog, false))
    }

    /// Upper bound on the largest query–reference distance of one
    /// request: the diagonal of the joint bounding box (the data box
    /// alone in the monochromatic setting).
    fn pair_radius(&self, queries: Option<&Matrix>) -> f64 {
        let (qlo, qhi) = match queries {
            Some(q) => (q.col_min(), q.col_max()),
            None => (self.data_lo.clone(), self.data_hi.clone()),
        };
        let mut sq = 0.0;
        for d in 0..self.data_lo.len() {
            let w = self.data_hi[d].max(qhi[d]) - self.data_lo[d].min(qlo[d]);
            sq += w * w;
        }
        sq.sqrt()
    }

    /// The lazily-built, session-cached IFGT clustering plan for one
    /// (K, seed). Computed outside the lock — racing computes of the
    /// same key are identical, exactly like the engine's moment memo.
    fn ifgt_plan(
        &self,
        clusters: usize,
        seed: u64,
        hits: &mut u64,
        misses: &mut u64,
    ) -> Arc<IfgtPlan> {
        if let Some(p) = self.ifgt_plans.lock().unwrap().get(&(clusters, seed)) {
            *hits += 1;
            return p;
        }
        *misses += 1;
        let plan = Arc::new(IfgtPlan::build(self.data, clusters, seed));
        self.ifgt_plans.lock().unwrap().insert((clusters, seed), Arc::clone(&plan));
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn small_data(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect::<Vec<_>>(),
        )
    }

    /// Regression for the poisoned-`TruthCell` bug: a panic inside the
    /// one requester computing a bandwidth's exhaustive truth used to
    /// poison the slot mutex, making every concurrent waiter panic on
    /// `.lock().unwrap()`. Now every requester — concurrent or later —
    /// gets a clean `AlgoError::Internal` carrying the injected panic
    /// message, and other bandwidths are unaffected.
    #[test]
    fn truth_panic_yields_clean_errors_not_poisoned_mutex() {
        let data = small_data(32, 9001);
        let session =
            Session::prepare(&data, PrepareOptions { threads: 2, ..Default::default() });
        let poisoned_h = 0.125;
        // two concurrent requesters race on the same bandwidth's cell;
        // the loser blocks on the winner's computation — both must get
        // a clean error, not a poisoned-mutex panic
        let results = session.pool().run_indexed(2, |_| {
            session.exact_sums_with(poisoned_h, || panic!("injected truth failure"))
        });
        for res in &results {
            let err = res.as_ref().expect_err("poisoned truth must error").to_string();
            assert!(err.contains("injected truth failure"), "{err}");
        }
        // the failure is sticky for that h (no silent recompute storm) …
        let again = session.exact_sums(poisoned_h, 0.01).expect_err("failure must stick");
        assert!(matches!(&again, AlgoError::Internal(_)), "{again}");
        // … surfaces through the evaluation path as an error in place …
        let ev = session
            .evaluate(&EvalRequest::kde(poisoned_h, 0.01).with_method(Method::Naive))
            .expect_err("Naive on a poisoned bandwidth must error cleanly");
        assert!(matches!(&ev, AlgoError::Internal(_)), "{ev}");
        // … and other bandwidths still compute fine on the same memo
        let (sums, _, cached) = session.exact_sums(0.25, 0.01).expect("fresh h must work");
        assert_eq!(sums.len(), 32);
        assert!(!cached);
    }

    /// The blocking-dedupe contract still holds on the happy path: one
    /// compute, every waiter shares it.
    #[test]
    fn concurrent_truth_requests_share_one_compute() {
        let data = small_data(48, 9002);
        let session =
            Session::prepare(&data, PrepareOptions { threads: 4, ..Default::default() });
        let h = 0.2;
        let results = session.pool().run_indexed(4, |_| session.exact_sums(h, 0.01).unwrap());
        let misses = results.iter().filter(|(_, _, cached)| !cached).count();
        assert_eq!(misses, 1, "exactly one requester may compute the truth");
        for (sums, _, _) in &results {
            assert!(Arc::ptr_eq(sums, &results[0].0), "waiters must share the one result");
        }
    }
}

/// Model-checked `TruthCell` invariants: the plain tests above try a
/// few OS schedules; these assert over *every* explored interleaving
/// of two requesters (`cargo test --features modelcheck`).
#[cfg(all(test, feature = "modelcheck"))]
mod mc_tests {
    use super::*;
    use crate::runtime::modelcheck::{self, McConfig};
    use crate::runtime::sync;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Pending→Ready resolves exactly once — no schedule lets two
    /// requesters both compute, or either observe a torn state.
    #[test]
    fn truth_cell_computes_exactly_once_across_all_schedules() {
        let report = modelcheck::explore(&McConfig::dfs(), || {
            let cell = Arc::new(TruthCell::default());
            let computes = Arc::new(AtomicUsize::new(0));
            let (c2, n2) = (Arc::clone(&cell), Arc::clone(&computes));
            let h = sync::spawn_thread("mc-truth".to_string(), None, move || {
                let got = c2.get_or_compute(|| {
                    n2.fetch_add(1, Ordering::SeqCst);
                    (vec![1.0, 2.0], 0.5)
                });
                let (sums, secs, _) = got.expect("truth compute must succeed");
                assert_eq!(*sums, vec![1.0, 2.0], "torn or wrong Ready state");
                assert_eq!(secs, 0.5);
            })
            .expect("spawn");
            let got = cell.get_or_compute(|| {
                computes.fetch_add(1, Ordering::SeqCst);
                (vec![1.0, 2.0], 0.5)
            });
            let (sums, _, _) = got.expect("truth compute must succeed");
            assert_eq!(*sums, vec![1.0, 2.0], "torn or wrong Ready state");
            h.join().expect("join");
            assert_eq!(computes.load(Ordering::SeqCst), 1, "exactly one compute may run");
        });
        assert!(report.ok(), "{}", report.failure.map(|f| f.to_string()).unwrap_or_default());
        assert!(report.exhausted, "two-requester DFS must fit the schedule budget");
    }

    /// A panicking compute resolves the cell to Failed for the
    /// concurrent waiter and stays failed for later requesters — under
    /// every schedule, with no poisoned-mutex panic escaping.
    #[test]
    fn truth_cell_panic_is_clean_and_sticky_across_all_schedules() {
        let report = modelcheck::explore(&McConfig::dfs(), || {
            let cell = Arc::new(TruthCell::default());
            let c2 = Arc::clone(&cell);
            let h = sync::spawn_thread("mc-truth-panic".to_string(), None, move || {
                let got = c2.get_or_compute(|| panic!("injected truth failure"));
                let (msg, _) = got.expect_err("both requesters must see the failure");
                assert!(msg.contains("injected truth failure"), "{msg}");
            })
            .expect("spawn");
            let got = cell.get_or_compute(|| panic!("injected truth failure"));
            let (msg, _) = got.expect_err("both requesters must see the failure");
            assert!(msg.contains("injected truth failure"), "{msg}");
            h.join().expect("join");
            // sticky: a later requester sees the memoized failure and
            // never recomputes (a recompute would resolve Ready)
            let (msg, fresh) = cell
                .get_or_compute(|| (vec![9.9], 9.9))
                .expect_err("cell must stay failed");
            assert!(msg.contains("injected truth failure"), "{msg}");
            assert!(!fresh, "later requesters must see a memoized failure");
        });
        assert!(report.ok(), "{}", report.failure.map(|f| f.to_string()).unwrap_or_default());
    }
}
