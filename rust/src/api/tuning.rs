//! The paper's verification-tuning protocols for the non-guaranteed
//! algorithms, promoted out of the sweep coordinator so *every* caller
//! of the session front door gets ε-verified answers, not just the
//! table harness:
//!
//! * **FGT** guarantees only an absolute tolerance W·τ, so the paper
//!   halves τ from ε until the *verified* relative error meets ε
//!   ([`fgt_halving`]);
//! * **IFGT** ships with an incorrect error bound, so the paper starts
//!   at the recommended parameters and doubles K (stretching ρ,
//!   raising p) until verified or hopeless ([`ifgt_doubling`]);
//! * **Sliced** carries a deterministic certificate only for its
//!   Fourier half — the slicing Monte-Carlo error is verified by
//!   doubling the slice count P until the measured relative error
//!   meets ε or the round budget runs out ([`sliced_doubling`]).
//!
//! All need exhaustive truth to verify against; the session feeds them
//! its memoized per-bandwidth truth (see `Session::exact_sums`).

use std::sync::Arc;

use crate::algo::fgt::{Fgt, GridFrame};
use crate::algo::ifgt::{ifgt_tuning_loop_with_plans, Ifgt, IfgtPlan};
use crate::algo::sliced::{SlicedState, DEFAULT_SEED, P_INIT, SLICE_BLOCK};
use crate::algo::{max_relative_error, AlgoError, GaussSumProblem, GaussSumResult, RunStats};
use crate::errorcontrol::{split_epsilon_sliced, SlicedEpsSplit};
use crate::runtime::pool::WorkStealPool;
use crate::util::timer::time_it;

/// τ-halvings before an FGT cell is declared ∞ (paper protocol).
pub const FGT_MAX_ATTEMPTS: usize = 20;

/// K-doubling rounds before an IFGT cell is declared ∞ (paper protocol).
pub const IFGT_MAX_ROUNDS: usize = 8;

/// P-doubling rounds before a Sliced cell is declared ∞.
pub const SLICED_MAX_ROUNDS: usize = 10;

/// A verified FGT answer plus the tuning metadata the table reports.
pub struct FgtOutcome {
    pub result: GaussSumResult,
    /// Verified max relative error (≤ ε by construction of the loop).
    pub rel_err: f64,
    /// Wall-clock of the *successful* attempt — the paper reports the
    /// cost of the working parameter setting, not the search for it.
    pub attempt_secs: f64,
    pub attempts: usize,
    /// The τ that met the tolerance.
    pub tau: f64,
}

/// The paper's FGT protocol: τ = ε, halve until the relative tolerance
/// is verified against `exact`, up to `max_attempts`. RAM exhaustion
/// propagates as the paper's `X`; running out of attempts is its `∞`.
pub fn fgt_halving(
    problem: &GaussSumProblem<'_>,
    frame: &GridFrame,
    exact: &[f64],
    max_attempts: usize,
) -> Result<FgtOutcome, AlgoError> {
    fgt_halving_with(problem, frame, exact, max_attempts, Fgt::default().fast_exp)
}

/// [`fgt_halving`] with an explicit sparse-box kernel choice —
/// `fast_exp = false` runs the bit-exact direct path on every attempt
/// (the `bench_json` old-vs-tiled comparison needs both).
pub fn fgt_halving_with(
    problem: &GaussSumProblem<'_>,
    frame: &GridFrame,
    exact: &[f64],
    max_attempts: usize,
    fast_exp: bool,
) -> Result<FgtOutcome, AlgoError> {
    let mut tau = problem.epsilon;
    let mut attempts = 0;
    loop {
        attempts += 1;
        let fgt = Fgt { fast_exp, ..Fgt::new(tau) };
        let (r, secs) = time_it(|| fgt.run_with_frame(problem, frame));
        let r = r?;
        let rel = max_relative_error(&r.sums, exact);
        if rel <= problem.epsilon * (1.0 + 1e-9) {
            return Ok(FgtOutcome { result: r, rel_err: rel, attempt_secs: secs, attempts, tau });
        }
        if attempts >= max_attempts {
            return Err(AlgoError::ToleranceUnreachable(format!(
                "FGT verified rel {rel:.2e} > ε after {attempts} τ-halvings (τ = {tau:.1e})"
            )));
        }
        tau *= 0.5;
    }
}

/// A verified IFGT answer plus the parameters the doubling landed on.
pub struct IfgtOutcome {
    pub result: GaussSumResult,
    /// Verified max relative error (≤ ε by construction of the loop).
    pub rel_err: f64,
    pub params: Ifgt,
}

/// The paper's IFGT protocol with caller-supplied clustering — the
/// session passes its per-`(K, seed)` plan cache so tuning rounds and
/// repeated requests on one dataset never re-cluster.
pub fn ifgt_doubling<F>(
    problem: &GaussSumProblem<'_>,
    exact: &[f64],
    max_rounds: usize,
    budget_secs: f64,
    plan_for: F,
) -> Result<IfgtOutcome, AlgoError>
where
    F: FnMut(&Ifgt) -> Arc<IfgtPlan>,
{
    let (result, params) =
        ifgt_tuning_loop_with_plans(problem, exact, max_rounds, budget_secs, plan_for)?;
    let rel_err = max_relative_error(&result.sums, exact);
    Ok(IfgtOutcome { result, rel_err, params })
}

/// A verified Sliced answer plus the tuning metadata the table reports.
pub struct SlicedOutcome {
    pub result: GaussSumResult,
    /// Verified max relative error (≤ ε by construction of the loop).
    pub rel_err: f64,
    /// Projections averaged by the accepted answer.
    pub slices: usize,
    /// The ε ledger: what the Fourier certificate charged and what was
    /// left for the slicing Monte-Carlo average.
    pub split: SlicedEpsSplit,
}

/// The Sliced protocol: every slice's 1-D Fourier sum carries a
/// deterministic truncation+aliasing certificate held under ε/4, so the
/// only unverified error is the Monte-Carlo average over projections.
/// Start at `initial_slices` (0 ⇒ the engine default) and double P —
/// reusing every already-computed slice, the doubling only pays for the
/// new half — until the measured relative error against `exact` meets
/// ε, the wall-clock budget runs out, or `max_rounds` is exhausted
/// (the paper's `∞`).
pub fn sliced_doubling(
    problem: &GaussSumProblem<'_>,
    exact: &[f64],
    initial_slices: usize,
    max_rounds: usize,
    budget_secs: f64,
    pool: Option<&WorkStealPool>,
) -> Result<SlicedOutcome, AlgoError> {
    let total_weight = problem.total_weight();
    let floor = exact.iter().copied().filter(|&e| e > 0.0).fold(f64::INFINITY, f64::min);
    // All-zero truth only happens when every pair underflows; fall back
    // to an absolute target so the plan builder still has a goal.
    let scale = if floor.is_finite() { floor } else { 1.0 };
    let target_bound = 0.25 * problem.epsilon * scale / total_weight;
    let mut state = SlicedState::new(problem, target_bound, DEFAULT_SEED);

    let start = if initial_slices == 0 { P_INIT } else { initial_slices };
    let mut slices = start.max(1).div_ceil(SLICE_BLOCK) * SLICE_BLOCK;
    let mut spent = 0.0;
    let mut rel = f64::INFINITY;
    let mut rounds = 0;
    while rounds < max_rounds {
        rounds += 1;
        let (outcome, secs) = time_it(|| {
            state.add_slices(slices, pool)?;
            Ok::<Vec<f64>, AlgoError>(state.estimates())
        });
        let estimates = outcome?;
        spent += secs;
        rel = max_relative_error(&estimates, exact);
        if rel <= problem.epsilon * (1.0 + 1e-9) {
            let fourier_rel = total_weight * state.certified_bound() / scale;
            let split = split_epsilon_sliced(problem.epsilon, fourier_rel).ok_or_else(|| {
                AlgoError::Internal(format!(
                    "Sliced Fourier certificate {fourier_rel:.2e} exceeded its ε/4 reservation"
                ))
            })?;
            let stats = RunStats { simd_backend: state.backend(), ..RunStats::default() };
            let result = GaussSumResult { sums: estimates, stats };
            return Ok(SlicedOutcome { result, rel_err: rel, slices: state.slices_done(), split });
        }
        if spent > budget_secs {
            break;
        }
        slices *= 2;
    }
    Err(AlgoError::ToleranceUnreachable(format!(
        "Sliced verified rel {rel:.2e} > ε after {rounds} P-doubling rounds (P = {})",
        state.slices_done()
    )))
}
