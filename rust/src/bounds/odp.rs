//! The paper's O(Dᵖ) truncation bounds (Lemmas 4–6).
//!
//! Shared structure: with p' = p mod D, the minimum of α! over |α| = p is
//! (⌊p/D⌋!)^(D−p')·(⌈p/D⌉!)^(p'), and the number of indices with
//! |α| = p is C(D+p−1, D−1); combining with Cramér's inequality on the
//! Hermite functions gives
//!
//!   E_DH(p)  = W_R · e^(−δ_min²/4h²) · C(D+p−1,D−1) · r_R^p / √(minfact)
//!   E_DL(p)  =   同 with r_Q
//!   E_H2L(p) = W_R · e^(−δ_min²/4h²) · C(D+p−1,D−1)/√(minfact)
//!              · ( r_Q^p + (√2·r_R)^p · C(D+p−1,D) · (√2·r_Q)^I(√2·r_Q) )
//!
//! with I(x) = 0 for x ≤ 1 and p−1 otherwise (Lemma 6's head-monomial
//! majorant). Crucially none of these require r < 1 — the bounds stay
//! finite (if possibly large) for any node size, which is what lets the
//! dual-tree algorithm attempt series pruning everywhere.

use crate::multiindex::{binomial, factorial};

use super::{NodeGeometry, SeriesMethod, TruncationBounds};

/// Bound family from Lemmas 4–6.
#[derive(Copy, Clone, Debug, Default)]
pub struct OdpBounds;

/// √( (⌊p/D⌋!)^(D−p') · (⌈p/D⌉!)^(p') ) — the minimum √(α!) over |α|=p
/// used as the denominator in all three lemmas.
fn sqrt_min_factorial(dim: usize, p: usize) -> f64 {
    let lo = p / dim;
    let rem = p % dim;
    let lo_f = factorial(lo);
    let hi_f = factorial(lo + usize::from(rem > 0));
    (lo_f.powi((dim - rem) as i32) * hi_f.powi(rem as i32)).sqrt()
}

impl OdpBounds {
    /// Lemma 4 without the decay factor.
    fn e_dh_nodecay(geo: &NodeGeometry, p: usize) -> f64 {
        let d = geo.dim;
        binomial(d + p - 1, d - 1) * geo.r_ref.powi(p as i32) / sqrt_min_factorial(d, p)
    }

    /// Lemma 5 without the decay factor.
    fn e_dl_nodecay(geo: &NodeGeometry, p: usize) -> f64 {
        let d = geo.dim;
        binomial(d + p - 1, d - 1) * geo.r_query.powi(p as i32) / sqrt_min_factorial(d, p)
    }

    /// Lemma 6 without the decay factor.
    fn e_h2l_nodecay(geo: &NodeGeometry, p: usize) -> f64 {
        let d = geo.dim;
        let sqrt2 = std::f64::consts::SQRT_2;
        let sq_rq = sqrt2 * geo.r_query;
        // I(x): the head Σ_{|β|<p} monomial majorant exponent.
        let head = if sq_rq <= 1.0 { 1.0 } else { sq_rq.powi(p as i32 - 1) };
        let e2 = geo.r_query.powi(p as i32);
        let e1 = (sqrt2 * geo.r_ref).powi(p as i32) * binomial(d + p - 1, d) * head;
        binomial(d + p - 1, d - 1) * (e2 + e1) / sqrt_min_factorial(d, p)
    }

    /// Lemma 4: truncated Hermite (far-field) evaluation error per unit
    /// reference weight.
    pub fn e_dh(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * Self::e_dh_nodecay(geo, p)
    }

    /// Lemma 5: direct local (Taylor) accumulation error per unit weight.
    pub fn e_dl(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * Self::e_dl_nodecay(geo, p)
    }

    /// Lemma 6: H2L-translated truncation error per unit weight.
    pub fn e_h2l(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * Self::e_h2l_nodecay(geo, p)
    }
}

impl TruncationBounds for OdpBounds {
    fn unit_error_nodecay(&self, method: SeriesMethod, geo: &NodeGeometry, p: usize) -> f64 {
        match method {
            SeriesMethod::DH => Self::e_dh_nodecay(geo, p),
            SeriesMethod::DL => Self::e_dl_nodecay(geo, p),
            SeriesMethod::H2L => Self::e_h2l_nodecay(geo, p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{linf_dist, Matrix};
    use crate::hermite::{accumulate_farfield, accumulate_local, eval_farfield, eval_local, h2l, HermiteTable};
    use crate::kernel::GaussianKernel;
    use crate::multiindex::{Layout, MultiIndexSet};
    use crate::util::Pcg32;

    fn geo(dim: usize, min_sqdist: f64, r_ref: f64, r_query: f64, h: f64) -> NodeGeometry {
        NodeGeometry { dim, min_sqdist, r_ref, r_query, h }
    }

    #[test]
    fn sqrt_min_factorial_cases() {
        // p=4, D=2: p'=0, (2!)^2 = 4 → √4 = 2
        assert!((sqrt_min_factorial(2, 4) - 2.0).abs() < 1e-12);
        // p=5, D=2: p'=1, (2!)^1·(3!)^1 = 12 → √12
        assert!((sqrt_min_factorial(2, 5) - 12f64.sqrt()).abs() < 1e-12);
        // p=1, D=3: p'=1, (0!)^2·(1!)^1 = 1
        assert!((sqrt_min_factorial(3, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bounds_positive_and_finite_for_large_nodes() {
        // The headline property: no node-size restriction — stay finite
        // even for scaled radii ≫ 1.
        let g = geo(5, 0.0, 10.0, 8.0, 0.1);
        for p in 1..=8 {
            for m in [SeriesMethod::DH, SeriesMethod::DL, SeriesMethod::H2L] {
                let e = OdpBounds.unit_error(m, &g, p);
                assert!(e.is_finite() && e > 0.0, "{m:?} p={p} e={e}");
            }
        }
    }

    #[test]
    fn decays_with_distance() {
        let near = geo(3, 0.01, 0.5, 0.5, 0.2);
        let far = geo(3, 1.0, 0.5, 0.5, 0.2);
        for m in [SeriesMethod::DH, SeriesMethod::DL, SeriesMethod::H2L] {
            assert!(OdpBounds.unit_error(m, &far, 3) < OdpBounds.unit_error(m, &near, 3));
        }
    }

    #[test]
    fn small_radius_bounds_shrink_with_p() {
        let g = geo(2, 0.5, 0.3, 0.3, 1.0);
        // DH/DL are strictly monotone for r < 1.
        for m in [SeriesMethod::DH, SeriesMethod::DL] {
            let mut prev = f64::INFINITY;
            for p in 1..=8 {
                let e = OdpBounds.unit_error(m, &g, p);
                assert!(e < prev, "{m:?} p={p}: {e} !< {prev}");
                prev = e;
            }
        }
        // H2L's C(D+p−1, D) factor can grow before the r^p term wins:
        // require only eventual decay by a large factor.
        let first = OdpBounds.unit_error(SeriesMethod::H2L, &g, 1);
        let last = OdpBounds.unit_error(SeriesMethod::H2L, &g, 8);
        assert!(last < first * 1e-2, "H2L must eventually decay: {first} → {last}");
    }

    /// The bound must actually bound: measure the true truncation error
    /// of a far-field evaluation against Lemma 4 over random geometry.
    #[test]
    fn lemma4_bounds_true_farfield_error() {
        let mut rng = Pcg32::new(41);
        for trial in 0..20 {
            let d = 1 + rng.below(3);
            let h = rng.uniform_in(0.3, 1.5);
            let k = GaussianKernel::new(h);
            let scale = k.series_scale();
            let spread = rng.uniform_in(0.02, 0.3);
            let n = 10;
            let pts = Matrix::from_rows(
                &(0..n)
                    .map(|_| (0..d).map(|_| spread * rng.uniform_in(-1.0, 1.0)).collect())
                    .collect::<Vec<_>>(),
            );
            let w = vec![1.0; n];
            let rows: Vec<usize> = (0..n).collect();
            let center = pts.col_mean();
            let r_ref = rows
                .iter()
                .map(|&r| linf_dist(pts.row(r), &center) / h)
                .fold(0.0f64, f64::max);
            // query somewhere at distance ≥ gap
            let gap = rng.uniform_in(0.2, 1.0);
            let mut xq = vec![0.0; d];
            xq[0] = center[0] + spread + gap;
            let dmin2 = {
                // min distance from xq to the point cloud bbox
                let lo = pts.col_min();
                let hi = pts.col_max();
                let mut s = 0.0;
                for i in 0..d {
                    let del = if xq[i] < lo[i] {
                        lo[i] - xq[i]
                    } else if xq[i] > hi[i] {
                        xq[i] - hi[i]
                    } else {
                        0.0
                    };
                    s += del * del;
                }
                s
            };
            let g = geo(d, dmin2, r_ref, 0.0, h);

            let exact: f64 = rows
                .iter()
                .map(|&r| k.eval_sq(crate::geometry::sqdist(pts.row(r), &xq)))
                .sum();
            for p in 1..=6 {
                let set = MultiIndexSet::new(Layout::Graded, d, p);
                let mut coeffs = vec![0.0; set.len()];
                let mut mono = vec![0.0; set.len()];
                let mut off = vec![0.0; d];
                accumulate_farfield(&set, &pts, &rows, &w, &center, scale, &mut coeffs, &mut mono, &mut off);
                let mut table = HermiteTable::new(d, p);
                let est =
                    eval_farfield(&set, &coeffs, &center, scale, &xq, &mut table, &mut off);
                let true_err = (est - exact).abs();
                let bound = (n as f64) * OdpBounds::e_dh(&g, p);
                assert!(
                    true_err <= bound * (1.0 + 1e-9) + 1e-12,
                    "trial={trial} d={d} p={p}: err={true_err} > bound={bound}"
                );
            }
        }
    }

    /// Lemma 5 bounds the true direct-local truncation error.
    #[test]
    fn lemma5_bounds_true_local_error() {
        let mut rng = Pcg32::new(42);
        for trial in 0..20 {
            let d = 1 + rng.below(3);
            let h = rng.uniform_in(0.4, 1.2);
            let k = GaussianKernel::new(h);
            let scale = k.series_scale();
            let n = 8;
            // references far away
            let pts = Matrix::from_rows(
                &(0..n)
                    .map(|_| (0..d).map(|_| 1.5 + 0.2 * rng.uniform_in(-1.0, 1.0)).collect())
                    .collect::<Vec<_>>(),
            );
            let w = vec![1.0; n];
            let rows: Vec<usize> = (0..n).collect();
            // queries near origin
            let q_c = vec![0.0; d];
            let q_spread = rng.uniform_in(0.02, 0.2);
            let mut xq = vec![0.0; d];
            xq[0] = q_spread; // within the query box
            let r_query = q_spread / h;
            let dmin2 = {
                let lo = pts.col_min();
                // min dist between query box [−s,s]^D and the ref cloud bbox
                let mut s = 0.0;
                for i in 0..d {
                    let del = (lo[i] - q_spread).max(0.0);
                    s += del * del;
                }
                s
            };
            let g = geo(d, dmin2, 0.0, r_query, h);
            let exact: f64 = rows
                .iter()
                .map(|&r| k.eval_sq(crate::geometry::sqdist(pts.row(r), &xq)))
                .sum();
            for p in 1..=6 {
                let set = MultiIndexSet::new(Layout::Graded, d, p);
                let mut coeffs = vec![0.0; set.len()];
                let mut table = HermiteTable::new(d, p);
                let mut off = vec![0.0; d];
                accumulate_local(&set, &pts, &rows, &w, &q_c, scale, &mut coeffs, &mut table, &mut off);
                let mut mono = vec![0.0; set.len()];
                let est = eval_local(&set, &coeffs, &q_c, scale, &xq, &mut mono, &mut off);
                let true_err = (est - exact).abs();
                let bound = (n as f64) * OdpBounds::e_dl(&g, p);
                assert!(
                    true_err <= bound * (1.0 + 1e-9) + 1e-12,
                    "trial={trial} d={d} p={p}: err={true_err} > bound={bound}"
                );
            }
        }
    }

    /// Lemma 6 bounds the combined H2L truncation error.
    #[test]
    fn lemma6_bounds_true_h2l_error() {
        let mut rng = Pcg32::new(43);
        for trial in 0..15 {
            let d = 1 + rng.below(2);
            let h = rng.uniform_in(0.5, 1.2);
            let k = GaussianKernel::new(h);
            let scale = k.series_scale();
            let n = 8;
            let r_spread = rng.uniform_in(0.02, 0.15);
            let q_spread = rng.uniform_in(0.02, 0.15);
            let pts = Matrix::from_rows(
                &(0..n)
                    .map(|_| {
                        (0..d).map(|_| 2.0 + r_spread * rng.uniform_in(-1.0, 1.0)).collect()
                    })
                    .collect::<Vec<_>>(),
            );
            let w = vec![1.0; n];
            let rows: Vec<usize> = (0..n).collect();
            let r_c = pts.col_mean();
            let q_c = vec![0.0; d];
            let mut xq = vec![0.0; d];
            xq[0] = -q_spread;
            let r_ref = rows
                .iter()
                .map(|&r| linf_dist(pts.row(r), &r_c) / h)
                .fold(0.0f64, f64::max);
            let dmin2 = {
                let lo = pts.col_min();
                let mut s = 0.0;
                for i in 0..d {
                    let del = (lo[i] - q_spread).max(0.0);
                    s += del * del;
                }
                s
            };
            let g = geo(d, dmin2, r_ref, q_spread / h, h);
            let exact: f64 = rows
                .iter()
                .map(|&r| k.eval_sq(crate::geometry::sqdist(pts.row(r), &xq)))
                .sum();
            for p in 1..=6 {
                let set = MultiIndexSet::new(Layout::Graded, d, p);
                let mut far = vec![0.0; set.len()];
                let mut mono = vec![0.0; set.len()];
                let mut off = vec![0.0; d];
                accumulate_farfield(&set, &pts, &rows, &w, &r_c, scale, &mut far, &mut mono, &mut off);
                let mut table = HermiteTable::new(d, 2 * p);
                let mut local = vec![0.0; set.len()];
                h2l(&set, &far, &r_c, &q_c, scale, &mut local, &mut table, &mut off);
                let est = eval_local(&set, &local, &q_c, scale, &xq, &mut mono, &mut off);
                let true_err = (est - exact).abs();
                let bound = (n as f64) * OdpBounds::e_h2l(&g, p);
                assert!(
                    true_err <= bound * (1.0 + 1e-9) + 1e-12,
                    "trial={trial} d={d} p={p}: err={true_err} > bound={bound}"
                );
            }
        }
    }
}
