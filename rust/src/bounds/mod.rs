//! Truncation-error bounds for the expansion-based approximation
//! methods.
//!
//! * [`odp`] — the paper's new O(Dᵖ) bounds (Lemmas 4–6), built on the
//!   multidimensional Taylor theorem; **no node-size restriction**.
//! * [`opd`] — classical O(pᴰ) geometric-series bounds in the style of
//!   Greengard & Strain / Baxter & Roussos / Lee et al. 2006; these are
//!   only valid when the scaled node radii are < 1 (the node-size
//!   restriction the paper's new bounds remove).
//!
//! Both expose the same three quantities per (Q, R, p):
//! `E_DH` (truncated Hermite evaluated at queries), `E_DL` (direct local
//! accumulation), `E_H2L` (far-field converted to local), with geometry
//! summarized by [`NodeGeometry`].

pub mod odp;
pub mod opd;

/// Geometry of a (query node, reference node) pair, pre-scaled the way
/// the bounds consume it.
#[derive(Copy, Clone, Debug)]
pub struct NodeGeometry {
    /// Dimension D.
    pub dim: usize,
    /// min squared distance between the nodes, (δ_QR^min)².
    pub min_sqdist: f64,
    /// r_R = max_{x_r∈R} ‖x_r − x_R‖∞ / h.
    pub r_ref: f64,
    /// r_Q = max_{x_q∈Q} ‖x_q − x_Q‖∞ / h.
    pub r_query: f64,
    /// Bandwidth h.
    pub h: f64,
}

impl NodeGeometry {
    /// The decay factor e^(−δ_min²/(4h²)) common to all bounds.
    #[inline]
    pub fn decay(&self) -> f64 {
        (-self.min_sqdist / (4.0 * self.h * self.h)).exp()
    }
}

/// Which approximation the bound refers to (paper's 𝔸 set minus EX/FD,
/// which have closed-form errors handled in `errorcontrol`).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SeriesMethod {
    /// Direct Hermite evaluation at each query point.
    DH,
    /// Direct local (Taylor) accumulation from each reference point.
    DL,
    /// Hermite-to-local translation.
    H2L,
}

/// A family of truncation bounds: given pair geometry and an order p,
/// an upper bound on the *per-unit-weight* absolute error (multiply by
/// W_R for the paper's E_A). Returns `f64::INFINITY` when the bound is
/// not valid for this geometry (e.g. O(pᴰ) node-size restriction).
///
/// Implementors provide the bound *without* the common e^(−δ²/4h²)
/// decay factor (`unit_error_nodecay`), so the order search in
/// `smallest_order` evaluates the exp once per pair instead of once per
/// (method, p) — this sits on the per-node-pair hot path.
pub trait TruncationBounds {
    /// The bound divided by the decay factor `geo.decay()`.
    fn unit_error_nodecay(&self, method: SeriesMethod, geo: &NodeGeometry, p: usize) -> f64;

    /// The full per-unit-weight bound.
    fn unit_error(&self, method: SeriesMethod, geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * self.unit_error_nodecay(method, geo, p)
    }

    /// Smallest p in 1..=p_limit with W_R·bound ≤ max_err, or None.
    fn smallest_order(
        &self,
        method: SeriesMethod,
        geo: &NodeGeometry,
        weight: f64,
        max_err: f64,
        p_limit: usize,
    ) -> Option<(usize, f64)> {
        let wd = weight * geo.decay();
        for p in 1..=p_limit {
            let e = wd * self.unit_error_nodecay(method, geo, p);
            if e <= max_err {
                return Some((p, e));
            }
        }
        None
    }
}

/// Placeholder bound family for traversal variants with series pruning
/// disabled (`Expansion::ENABLED == false`): every bound is `+∞`, so no
/// truncation order is ever feasible. The monomorphized
/// finite-difference-only engines compile their series branch out
/// entirely, so this is never reached at run time — it exists only to
/// satisfy the `Expansion::Bounds` associated type.
#[derive(Copy, Clone, Debug, Default)]
pub struct NeverBounds;

impl TruncationBounds for NeverBounds {
    fn unit_error_nodecay(&self, _method: SeriesMethod, _geo: &NodeGeometry, _p: usize) -> f64 {
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_bounds_is_always_infeasible() {
        let g = NodeGeometry { dim: 2, min_sqdist: 100.0, r_ref: 0.01, r_query: 0.01, h: 1.0 };
        assert_eq!(NeverBounds.unit_error_nodecay(SeriesMethod::DH, &g, 8), f64::INFINITY);
        assert!(NeverBounds.smallest_order(SeriesMethod::H2L, &g, 1.0, 1e300, 8).is_none());
    }

    #[test]
    fn decay_factor() {
        let g = NodeGeometry { dim: 2, min_sqdist: 4.0, r_ref: 0.5, r_query: 0.5, h: 1.0 };
        assert!((g.decay() - (-1.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn smallest_order_finds_first_valid() {
        struct Fake;
        impl TruncationBounds for Fake {
            fn unit_error_nodecay(&self, _m: SeriesMethod, _g: &NodeGeometry, p: usize) -> f64 {
                1.0 / (1 << p) as f64 // halves each order
            }
        }
        let g = NodeGeometry { dim: 2, min_sqdist: 0.0, r_ref: 0.1, r_query: 0.1, h: 1.0 };
        let (p, e) = Fake.smallest_order(SeriesMethod::DH, &g, 1.0, 0.13, 8).unwrap();
        assert_eq!(p, 3);
        assert!(e <= 0.13);
        assert!(Fake.smallest_order(SeriesMethod::DH, &g, 1.0, 1e-9, 8).is_none());
    }
}
