//! Classical O(pᴰ) truncation bounds (Greengard & Strain 1991, as
//! corrected by Baxter & Roussos 2002 and extended to the dual-tree
//! setting by Lee et al. 2006).
//!
//! Derivation sketch (documented because the exact constants matter for
//! the validity tests): per dimension the expansion is a univariate
//! Hermite series Σₙ (ρⁿ/n!)hₙ(u) with |ρ| ≤ r/√2 where r is the
//! paper-style L∞ node radius over h. Cramér's inequality
//! |hₙ(u)| ≤ K·2^(n/2)·√(n!)·e^(−u²/2) (K = 1.086435) gives per-term
//! majorant K·(r)ⁿ/√(n!)·e^(−u²/2); since rⁿ/√n! shrinks by at least a
//! factor r each step, head and tail are bounded by geometric series
//! **provided r < 1** — the node-size restriction:
//!
//!   per-dim head  s = K/(1−r),
//!   per-dim tail  t = K·rᵖ/(√(p!)(1−r)),
//!
//! and the D-dim product-series truncation error is
//! (s+t)ᴰ − sᴰ = Σ_{k<D} C(D,k)·sᵏ·t^{D−k}, times the separable decay
//! Π e^(−u_d²/2) = e^(−δ²/4h²).
//!
//! For H2L the double series needs the √2-inflated radii (cf. the √2
//! factors in the paper's Lemma 6), so validity requires √2·r < 1 in
//! both nodes.

use crate::multiindex::factorial;

use super::{NodeGeometry, SeriesMethod, TruncationBounds};

/// Cramér's constant K ≤ π^(−1/4)·√2 ≈ 1.086435.
pub const CRAMER_K: f64 = 1.086435;

/// Bound family for the O(pᴰ) grid truncation.
#[derive(Copy, Clone, Debug, Default)]
pub struct OpdBounds;

/// (s+t)^D − s^D with s, t per-dim head/tail majorants; INFINITY when
/// the geometric-series condition r < 1 fails.
fn product_series_error(r: f64, dim: usize, p: usize) -> f64 {
    if r >= 1.0 {
        return f64::INFINITY;
    }
    let s = CRAMER_K / (1.0 - r);
    let t = CRAMER_K * r.powi(p as i32) / (factorial(p).sqrt() * (1.0 - r));
    (s + t).powi(dim as i32) - s.powi(dim as i32)
}

impl OpdBounds {
    /// Truncated-Hermite evaluation error per unit weight; requires
    /// r_R < 1.
    pub fn e_dh(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * product_series_error(geo.r_ref, geo.dim, p)
    }

    /// Direct-local accumulation error per unit weight; requires r_Q < 1.
    pub fn e_dl(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * product_series_error(geo.r_query, geo.dim, p)
    }

    /// H2L error per unit weight; requires √2·r_R < 1 and √2·r_Q < 1.
    /// Bound: truncating both the α (reference) and β (query) series of
    /// the double expansion; per dim the double series majorant
    /// factorizes into (s_R+t_R)(s_Q+t_Q) with √2-inflated radii, and
    /// the D-dim truncation error is the product-minus-head difference.
    pub fn e_h2l(geo: &NodeGeometry, p: usize) -> f64 {
        geo.decay() * Self::e_h2l_nodecay(geo, p)
    }

    fn e_h2l_nodecay(geo: &NodeGeometry, p: usize) -> f64 {
        let sqrt2 = std::f64::consts::SQRT_2;
        let rr = sqrt2 * geo.r_ref;
        let rq = sqrt2 * geo.r_query;
        if rr >= 1.0 || rq >= 1.0 {
            return f64::INFINITY;
        }
        let s_r = CRAMER_K / (1.0 - rr);
        let t_r = CRAMER_K * rr.powi(p as i32) / (factorial(p).sqrt() * (1.0 - rr));
        let s_q = 1.0 / (1.0 - rq);
        let t_q = rq.powi(p as i32) / (factorial(p).sqrt() * (1.0 - rq));
        let full = ((s_r + t_r) * (s_q + t_q)).powi(geo.dim as i32);
        let head = (s_r * s_q).powi(geo.dim as i32);
        full - head
    }
}

impl TruncationBounds for OpdBounds {
    fn unit_error_nodecay(&self, method: SeriesMethod, geo: &NodeGeometry, p: usize) -> f64 {
        match method {
            SeriesMethod::DH => product_series_error(geo.r_ref, geo.dim, p),
            SeriesMethod::DL => product_series_error(geo.r_query, geo.dim, p),
            SeriesMethod::H2L => Self::e_h2l_nodecay(geo, p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::{linf_dist, Matrix};
    use crate::hermite::{accumulate_farfield, eval_farfield, HermiteTable};
    use crate::kernel::GaussianKernel;
    use crate::multiindex::{Layout, MultiIndexSet};
    use crate::util::Pcg32;

    fn geo(dim: usize, min_sqdist: f64, r_ref: f64, r_query: f64, h: f64) -> NodeGeometry {
        NodeGeometry { dim, min_sqdist, r_ref, r_query, h }
    }

    #[test]
    fn node_size_restriction_yields_infinity() {
        // THE defining weakness vs the O(Dᵖ) bounds: r ≥ 1 → no valid
        // bound at any order.
        let g = geo(3, 0.0, 1.2, 0.5, 1.0);
        assert!(OpdBounds::e_dh(&g, 8).is_infinite());
        let g2 = geo(3, 0.0, 0.5, 1.5, 1.0);
        assert!(OpdBounds::e_dl(&g2, 8).is_infinite());
        // H2L is stricter: √2·r ≥ 1 already kills it.
        let g3 = geo(3, 0.0, 0.8, 0.2, 1.0);
        assert!(OpdBounds::e_h2l(&g3, 8).is_infinite());
        assert!(OpdBounds::e_dh(&g3, 8).is_finite());
    }

    #[test]
    fn shrinks_with_order_when_valid() {
        let g = geo(2, 0.1, 0.4, 0.3, 1.0);
        for m in [SeriesMethod::DH, SeriesMethod::DL, SeriesMethod::H2L] {
            let mut prev = f64::INFINITY;
            for p in 1..=10 {
                let e = OpdBounds.unit_error(m, &g, p);
                assert!(e.is_finite());
                assert!(e < prev, "{m:?} p={p}");
                prev = e;
            }
        }
    }

    #[test]
    fn tighter_for_smaller_nodes() {
        let small = geo(2, 0.1, 0.1, 0.1, 1.0);
        let big = geo(2, 0.1, 0.6, 0.6, 1.0);
        for m in [SeriesMethod::DH, SeriesMethod::DL, SeriesMethod::H2L] {
            assert!(OpdBounds.unit_error(m, &small, 4) < OpdBounds.unit_error(m, &big, 4));
        }
    }

    /// Validity: the bound dominates the true truncation error of a
    /// grid-truncated far-field evaluation (the series it was derived
    /// for), over random small-radius geometry.
    #[test]
    fn bounds_true_grid_farfield_error() {
        let mut rng = Pcg32::new(51);
        for trial in 0..20 {
            let d = 1 + rng.below(2);
            let h = rng.uniform_in(0.5, 1.5);
            let k = GaussianKernel::new(h);
            let scale = k.series_scale();
            // keep the node well inside the r < 1 regime
            let spread = rng.uniform_in(0.05, 0.3) * h;
            let n = 10;
            let pts = Matrix::from_rows(
                &(0..n)
                    .map(|_| (0..d).map(|_| spread * rng.uniform_in(-1.0, 1.0)).collect())
                    .collect::<Vec<_>>(),
            );
            let w = vec![1.0; n];
            let rows: Vec<usize> = (0..n).collect();
            let center = pts.col_mean();
            let r_ref = rows
                .iter()
                .map(|&r| linf_dist(pts.row(r), &center) / h)
                .fold(0.0f64, f64::max);
            assert!(r_ref < 1.0);
            let mut xq = vec![0.0; d];
            xq[0] = center[0] + spread + rng.uniform_in(0.1, 0.8);
            let dmin2 = {
                let lo = pts.col_min();
                let hi = pts.col_max();
                let mut s = 0.0;
                for i in 0..d {
                    let del =
                        if xq[i] < lo[i] { lo[i] - xq[i] } else { (xq[i] - hi[i]).max(0.0) };
                    s += del * del;
                }
                s
            };
            let g = geo(d, dmin2, r_ref, 0.0, h);
            let exact: f64 = rows
                .iter()
                .map(|&r| k.eval_sq(crate::geometry::sqdist(pts.row(r), &xq)))
                .sum();
            for p in 1..=6 {
                let set = MultiIndexSet::new(Layout::Grid, d, p);
                let mut coeffs = vec![0.0; set.len()];
                let mut mono = vec![0.0; set.len()];
                let mut off = vec![0.0; d];
                accumulate_farfield(&set, &pts, &rows, &w, &center, scale, &mut coeffs, &mut mono, &mut off);
                let mut table = HermiteTable::new(d, p);
                let est = eval_farfield(&set, &coeffs, &center, scale, &xq, &mut table, &mut off);
                let true_err = (est - exact).abs();
                let bound = (n as f64) * OpdBounds::e_dh(&g, p);
                assert!(
                    true_err <= bound * (1.0 + 1e-9) + 1e-12,
                    "trial={trial} d={d} p={p}: err={true_err} > bound={bound}"
                );
            }
        }
    }

    #[test]
    fn odp_wins_for_large_nodes_opd_can_win_small() {
        use crate::bounds::odp::OdpBounds;
        // Large node: O(Dᵖ) finite, O(pᴰ) infinite.
        let big = geo(3, 1.0, 1.5, 1.5, 0.5);
        assert!(OdpBounds::e_dh(&big, 4).is_finite());
        assert!(OpdBounds::e_dh(&big, 4).is_infinite());
    }
}
