//! Tier-1 CI gate: run the repo-native invariant linter over
//! `rust/src/**` and fail on any finding. See [`fastgauss::lint`] for
//! the five rule families and the waiver syntax.
//!
//! Usage: `cargo run --bin fastgauss_lint [repo-root]` — the root
//! defaults to `CARGO_MANIFEST_DIR` (i.e. `cargo run` from anywhere
//! in the repo just works), falling back to the current directory.

use std::path::PathBuf;
use std::process::ExitCode;

use fastgauss::lint;

fn main() -> ExitCode {
    let default_root = std::env::var_os("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."));
    let root = std::env::args_os().nth(1).map(PathBuf::from).unwrap_or(default_root);
    match lint::lint_tree(&root) {
        Ok((files, findings)) => {
            for finding in &findings {
                eprintln!("{finding}");
            }
            if findings.is_empty() {
                println!("fastgauss-lint: {files} files checked, 0 findings");
                ExitCode::SUCCESS
            } else {
                eprintln!("fastgauss-lint: {files} files checked, {} findings", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("fastgauss-lint: cannot walk {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
