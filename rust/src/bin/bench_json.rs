//! `bench_json` — the perf-trajectory runner (see
//! `fastgauss::benchjson`). Default protocol (PR 5): old fractured
//! thread model vs the shared work-stealing pool on astro2d + galaxy3d
//! batch workloads at ε = 1e-4, every request ε-verified (the process
//! aborts on a violating cell, which is how CI fails the job). `--pr4`
//! re-runs the PR 4 protocol (old vs tiled base cases).
//!
//! `--pr7` runs the SIMD-lane protocol (forced-scalar vs vector lanes
//! vs the certified mixed-precision f32 tile, every cell ε-verified
//! with the lane backend recorded).
//!
//! `--pr9` runs the sliced-Fourier protocol (Sliced vs DITO vs
//! exhaustive on hyper20/hyper50 with galaxy3d as the low-D control,
//! answered cells ε-verified, refusals recorded as the paper's X/∞).
//!
//! ```text
//! cargo run --release --bin bench_json                 # BENCH_PR5.json
//! cargo run --release --bin bench_json -- --smoke      # tiny sizes (CI)
//! cargo run --release --bin bench_json -- --pr4        # BENCH_PR4.json
//! cargo run --release --bin bench_json -- --pr7        # BENCH_PR7.json
//! cargo run --release --bin bench_json -- --pr9        # BENCH_PR9.json
//! cargo run --release --bin bench_json -- --n 8000 --reps 5 --out perf.json
//! ```

use fastgauss::benchjson::{run_bench, run_bench_pr5, run_bench_pr7, run_bench_pr9, BenchConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = BenchConfig::full();
    let mut pr4 = false;
    let mut pr7 = false;
    let mut pr9 = false;
    let mut out: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--smoke" => {
                cfg = BenchConfig::smoke();
                i += 1;
            }
            "--pr4" => {
                pr4 = true;
                i += 1;
            }
            "--pr7" => {
                pr7 = true;
                i += 1;
            }
            "--pr9" => {
                pr9 = true;
                i += 1;
            }
            "--n" => {
                cfg.n = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .filter(|&v: &usize| v > 0)
                    .unwrap_or_else(|| {
                        eprintln!("--n needs a positive integer");
                        std::process::exit(2)
                    });
                i += 2;
            }
            "--reps" => {
                cfg.reps = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .filter(|&v: &usize| v > 0)
                    .unwrap_or_else(|| {
                        eprintln!("--reps needs a positive integer");
                        std::process::exit(2)
                    });
                i += 2;
            }
            "--out" => {
                out = Some(args.get(i + 1).cloned().unwrap_or_else(|| {
                    eprintln!("--out needs a path");
                    std::process::exit(2)
                }));
                i += 2;
            }
            other => {
                eprintln!(
                    "unknown option {other:?}\nusage: bench_json [--smoke] [--pr4] [--pr7] [--pr9] [--n N] [--reps R] [--out FILE]"
                );
                std::process::exit(2);
            }
        }
    }
    let out = out.unwrap_or_else(|| {
        let name = if pr4 {
            "BENCH_PR4.json"
        } else if pr7 {
            "BENCH_PR7.json"
        } else if pr9 {
            "BENCH_PR9.json"
        } else {
            "BENCH_PR5.json"
        };
        name.to_string()
    });
    let json = if pr4 {
        run_bench(&cfg)
    } else if pr7 {
        run_bench_pr7(&cfg)
    } else if pr9 {
        run_bench_pr9(&cfg)
    } else {
        run_bench_pr5(&cfg)
    };
    std::fs::write(&out, &json).unwrap_or_else(|e| {
        eprintln!("writing {out}: {e}");
        std::process::exit(1);
    });
    eprintln!("wrote {out} (n = {}, reps = {}, smoke = {})", cfg.n, cfg.reps, cfg.smoke);
    print!("{json}");
}
