//! `bench_json` — the perf-trajectory runner (see
//! `fastgauss::benchjson`). Times old vs tiled base cases for
//! Naive/DFDO/DITO/FGT on astro2d + galaxy3d at ε = 1e-4 and writes
//! machine-readable JSON.
//!
//! ```text
//! cargo run --release --bin bench_json                 # BENCH_PR4.json
//! cargo run --release --bin bench_json -- --smoke      # tiny sizes (CI)
//! cargo run --release --bin bench_json -- --n 8000 --reps 5 --out perf.json
//! ```

use fastgauss::benchjson::{run_bench, BenchConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = BenchConfig::full();
    let mut out = "BENCH_PR4.json".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--smoke" => {
                cfg = BenchConfig::smoke();
                i += 1;
            }
            "--n" => {
                cfg.n = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .filter(|&v: &usize| v > 0)
                    .unwrap_or_else(|| {
                        eprintln!("--n needs a positive integer");
                        std::process::exit(2)
                    });
                i += 2;
            }
            "--reps" => {
                cfg.reps = args
                    .get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .filter(|&v: &usize| v > 0)
                    .unwrap_or_else(|| {
                        eprintln!("--reps needs a positive integer");
                        std::process::exit(2)
                    });
                i += 2;
            }
            "--out" => {
                out = args.get(i + 1).cloned().unwrap_or_else(|| {
                    eprintln!("--out needs a path");
                    std::process::exit(2)
                });
                i += 2;
            }
            other => {
                eprintln!(
                    "unknown option {other:?}\nusage: bench_json [--smoke] [--n N] [--reps R] [--out FILE]"
                );
                std::process::exit(2);
            }
        }
    }
    let json = run_bench(&cfg);
    std::fs::write(&out, &json).unwrap_or_else(|e| {
        eprintln!("writing {out}: {e}");
        std::process::exit(1);
    });
    eprintln!("wrote {out} (n = {}, reps = {}, smoke = {})", cfg.n, cfg.reps, cfg.smoke);
    print!("{json}");
}
