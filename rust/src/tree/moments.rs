//! Bottom-up precomputation of far-field Hermite moments for every node
//! of a reference tree (paper Fig. 5): leaves accumulate their moments
//! directly from their points; internal nodes combine children via the
//! **H2H** translation operator. H2H is exact on downward-closed index
//! sets, so the result equals direct accumulation at every node — we
//! test exactly that.

use crate::hermite::{accumulate_farfield, h2h, PairTable};
use crate::kernel::GaussianKernel;
use crate::multiindex::{Layout, MultiIndexSet};

use super::KdTree;

/// Per-node far-field (Hermite) moments of order PLIMIT for one tree at
/// one bandwidth.
#[derive(Clone, Debug)]
pub struct RefMoments {
    set: MultiIndexSet,
    pairs: PairTable,
    /// Node-major coefficient storage: `coeffs[node * set.len() ..]`.
    coeffs: Vec<f64>,
    scale: f64,
}

impl RefMoments {
    /// Compute moments for every node of `tree` under `kernel`, with the
    /// given layout and truncation order `plimit` (paper's PLIMIT).
    pub fn compute(tree: &KdTree, kernel: &GaussianKernel, layout: Layout, plimit: usize) -> Self {
        let set = MultiIndexSet::new(layout, tree.dim(), plimit);
        let pairs = PairTable::new(&set);
        let scale = kernel.series_scale();
        let len = set.len();
        let mut coeffs = vec![0.0; tree.num_nodes() * len];
        let mut mono = vec![0.0; len];
        let mut off = vec![0.0; tree.dim()];

        for i in tree.postorder() {
            let node = tree.node(i);
            if node.is_leaf() {
                let rows: Vec<usize> = (node.begin..node.end).collect();
                accumulate_farfield(
                    &set,
                    tree.points(),
                    &rows,
                    tree.weights(),
                    &node.centroid,
                    scale,
                    &mut coeffs[i * len..(i + 1) * len],
                    &mut mono,
                    &mut off,
                );
            } else {
                let (l, r) = tree.children_of_internal(i);
                for child in [l, r] {
                    // split-borrow: child coeffs are read, parent written
                    let (child_part, parent_part) = split_two(&mut coeffs, child, i, len);
                    h2h(
                        &set,
                        &pairs,
                        child_part,
                        &tree.node(child).centroid,
                        &tree.node(i).centroid,
                        scale,
                        parent_part,
                        &mut mono,
                        &mut off,
                    );
                }
            }
        }
        RefMoments { set, pairs, coeffs, scale }
    }

    /// The multi-index set the moments are stored over.
    #[inline]
    pub fn set(&self) -> &MultiIndexSet {
        &self.set
    }

    /// Pair table for translation operators over the same set.
    #[inline]
    pub fn pairs(&self) -> &PairTable {
        &self.pairs
    }

    /// Series scale √(2h²) the moments were computed with.
    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Moments of node `i`.
    #[inline]
    pub fn node_coeffs(&self, i: usize) -> &[f64] {
        let len = self.set.len();
        &self.coeffs[i * len..(i + 1) * len]
    }
}

/// Disjoint mutable slices for (child, parent) coefficient blocks.
fn split_two(coeffs: &mut [f64], child: usize, parent: usize, len: usize) -> (&[f64], &mut [f64]) {
    assert_ne!(child, parent);
    if child < parent {
        let (lo, hi) = coeffs.split_at_mut(parent * len);
        (&lo[child * len..(child + 1) * len], &mut hi[..len])
    } else {
        let (lo, hi) = coeffs.split_at_mut(child * len);
        let child_part = &hi[..len];
        (child_part, &mut lo[parent * len..(parent + 1) * len])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Matrix;
    use crate::tree::BuildParams;
    use crate::util::Pcg32;

    fn random_tree(n: usize, d: usize, seed: u64, leaf: usize) -> KdTree {
        let mut rng = Pcg32::new(seed);
        let pts = Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        );
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.5, 1.5)).collect();
        KdTree::build(&pts, &w, BuildParams { leaf_size: leaf })
    }

    /// The central invariant (Fig. 5 correctness): moments via bottom-up
    /// H2H equal moments accumulated directly at each node's centroid.
    #[test]
    fn h2h_pass_equals_direct_accumulation() {
        for layout in [Layout::Grid, Layout::Graded] {
            let tree = random_tree(200, 2, 61, 16);
            let kernel = GaussianKernel::new(0.2);
            let m = RefMoments::compute(&tree, &kernel, layout, 4);
            let set = m.set();
            let mut mono = vec![0.0; set.len()];
            let mut off = vec![0.0; 2];
            for i in 0..tree.num_nodes() {
                let node = tree.node(i);
                let rows: Vec<usize> = (node.begin..node.end).collect();
                let mut direct = vec![0.0; set.len()];
                accumulate_farfield(
                    set,
                    tree.points(),
                    &rows,
                    tree.weights(),
                    &node.centroid,
                    m.scale(),
                    &mut direct,
                    &mut mono,
                    &mut off,
                );
                let got = m.node_coeffs(i);
                for j in 0..set.len() {
                    assert!(
                        (got[j] - direct[j]).abs() < 1e-9 * direct[j].abs().max(1.0),
                        "{layout:?} node={i} j={j}: {} vs {}",
                        got[j],
                        direct[j]
                    );
                }
            }
        }
    }

    /// Monopole term of every node equals its cached weight.
    #[test]
    fn monopole_equals_node_weight() {
        let tree = random_tree(150, 3, 62, 20);
        let kernel = GaussianKernel::new(0.5);
        let m = RefMoments::compute(&tree, &kernel, Layout::Graded, 3);
        for i in 0..tree.num_nodes() {
            let w = tree.node(i).weight;
            assert!((m.node_coeffs(i)[0] - w).abs() < 1e-9 * w, "node {i}");
        }
    }

    /// Moments scale correctly with bandwidth: recomputing at another h
    /// changes coefficients (they are h-dependent) but keeps monopoles.
    #[test]
    fn bandwidth_dependence() {
        let tree = random_tree(100, 2, 63, 16);
        let m1 = RefMoments::compute(&tree, &GaussianKernel::new(0.1), Layout::Graded, 3);
        let m2 = RefMoments::compute(&tree, &GaussianKernel::new(1.0), Layout::Graded, 3);
        assert!((m1.node_coeffs(0)[0] - m2.node_coeffs(0)[0]).abs() < 1e-9);
        // some higher-order coefficient must differ
        let differs = (1..m1.set().len())
            .any(|j| (m1.node_coeffs(0)[j] - m2.node_coeffs(0)[j]).abs() > 1e-12);
        assert!(differs);
    }

    #[test]
    fn split_two_borrows_disjoint() {
        let mut v: Vec<f64> = (0..12).map(|x| x as f64).collect();
        let (c, p) = split_two(&mut v, 0, 2, 4);
        assert_eq!(c, &[0.0, 1.0, 2.0, 3.0]);
        p[0] = 99.0;
        assert_eq!(v[8], 99.0);
        let (c2, p2) = split_two(&mut v, 2, 0, 4);
        assert_eq!(c2[0], 99.0);
        p2[0] = -1.0;
        assert_eq!(v[0], -1.0);
    }
}
