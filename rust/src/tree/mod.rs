//! Space-partitioning trees with cached sufficient statistics.
//!
//! The paper uses "an efficient form of sphere-rectangle trees
//! (Katayama & Satoh 1997), with … cached sufficient statistics as in
//! mrkd-trees (Deng & Moore 1995)". We implement that as a kd-style
//! median-split tree whose every node carries BOTH a bounding rectangle
//! and a bounding sphere (distance bounds take the tighter of the two),
//! plus the cached statistics the algorithms need: total weight W_R,
//! weighted centroid x_R, and the L∞ radius used by the Lemma 4–6
//! bounds.
//!
//! Far-field Hermite moments are *not* stored in the tree — they depend
//! on the bandwidth — but are computed per run by [`moments::RefMoments`]
//! in one bottom-up pass using the H2H operator (paper Fig. 5).

pub mod build;
pub mod moments;
pub mod node;

pub use build::{BuildParams, KdTree};
pub use moments::RefMoments;
pub use node::Node;

/// The paper's PLIMIT schedule: maximum expansion order precomputed per
/// dimension ("PLIMIT = 8 for D=2, 6 for D=3, 4 for D=5, 2 for D=6; we
/// presume PLIMIT = 1 for D > 6").
pub fn plimit_for_dim(dim: usize) -> usize {
    match dim {
        // lint: allow(no-panic): D = 0 is rejected when datasets are built; PLIMIT has no zero-D row
        0 => panic!("zero-dimensional data"),
        1 | 2 => 8,
        3 => 6,
        4 | 5 => 4,
        6 => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plimit_schedule_matches_paper() {
        assert_eq!(plimit_for_dim(2), 8);
        assert_eq!(plimit_for_dim(3), 6);
        assert_eq!(plimit_for_dim(5), 4);
        assert_eq!(plimit_for_dim(6), 2);
        assert_eq!(plimit_for_dim(7), 1);
        assert_eq!(plimit_for_dim(16), 1);
    }

    #[test]
    #[should_panic]
    fn plimit_zero_dim_panics() {
        plimit_for_dim(0);
    }
}
