//! Median-split tree construction. Points are reordered into a
//! permutation such that every node owns a contiguous range, which keeps
//! the base cases cache-friendly and lets moments/results be indexed by
//! position.

use crate::geometry::{linf_dist, HRect, Matrix, Sphere};

use super::node::{Node, NO_CHILD};

/// Tree construction parameters.
#[derive(Copy, Clone, Debug)]
pub struct BuildParams {
    /// Maximum points in a leaf.
    pub leaf_size: usize,
}

impl Default for BuildParams {
    fn default() -> Self {
        // Comparable to the mrkd-tree leaf sizes used in the paper's
        // lineage of dual-tree code (tens of points).
        BuildParams { leaf_size: 32 }
    }
}

/// A kd-style median-split tree over a point set, with SR-tree bounding
/// volumes and cached sufficient statistics in every node.
#[derive(Clone, Debug)]
pub struct KdTree {
    nodes: Vec<Node>,
    /// `perm[i]` = original row of the point at tree position `i`.
    perm: Vec<usize>,
    /// Points in tree order.
    points: Matrix,
    /// Weights in tree order.
    weights: Vec<f64>,
    /// Cached squared norms ‖x‖² in tree order — h-independent, computed
    /// once here so the tiled base case's norms-trick distances never
    /// rescan coordinates (see `compute::tile`).
    sq_norms: Vec<f64>,
    /// f32 shadow of `sq_norms` (rounded once at build) for the
    /// mixed-precision tile; its representation error is part of the
    /// certified `errorcontrol::base_case_rel_err_f32` bound.
    sq_norms32: Vec<f32>,
    /// max over `sq_norms` — the magnitude bound
    /// `errorcontrol::base_case_rel_err` certifies the norms-trick
    /// cancellation against.
    max_sq_norm: f64,
}

impl KdTree {
    /// Build over `points` with per-point `weights` (all > 0).
    pub fn build(points: &Matrix, weights: &[f64], params: BuildParams) -> Self {
        assert_eq!(points.rows(), weights.len());
        assert!(points.rows() > 0, "empty point set");
        assert!(params.leaf_size >= 1);
        let n = points.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut nodes = Vec::new();
        build_rec(points, weights, &mut perm, &mut nodes, 0, n, 0, params.leaf_size);
        // materialize reordered copies (+ h-independent squared norms)
        let reordered = points.select_rows(&perm);
        let rw: Vec<f64> = perm.iter().map(|&i| weights[i]).collect();
        let sq_norms = crate::compute::tile::sq_norms(&reordered);
        let sq_norms32: Vec<f32> = sq_norms.iter().map(|&s| s as f32).collect();
        let max_sq_norm = sq_norms.iter().cloned().fold(0.0, f64::max);
        KdTree { nodes, perm, points: reordered, weights: rw, sq_norms, sq_norms32, max_sq_norm }
    }

    /// Root node index (always 0).
    #[inline]
    pub fn root(&self) -> usize {
        0
    }

    #[inline]
    pub fn node(&self, i: usize) -> &Node {
        &self.nodes[i]
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    #[inline]
    pub fn num_points(&self) -> usize {
        self.points.rows()
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.points.cols()
    }

    /// Points in tree order.
    #[inline]
    pub fn points(&self) -> &Matrix {
        &self.points
    }

    /// Weights in tree order.
    #[inline]
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    /// Cached squared norms ‖x‖² in tree order (computed once at build;
    /// h-independent).
    #[inline]
    pub fn sq_norms(&self) -> &[f64] {
        &self.sq_norms
    }

    /// f32 shadow of [`Self::sq_norms`] for the mixed-precision tile.
    #[inline]
    pub fn sq_norms_f32(&self) -> &[f32] {
        &self.sq_norms32
    }

    /// Largest cached squared norm — feeds the certified norms-trick
    /// error bound (`errorcontrol::base_case_rel_err`).
    #[inline]
    pub fn max_sq_norm(&self) -> f64 {
        self.max_sq_norm
    }

    /// Original row of tree position `i`.
    #[inline]
    pub fn original_index(&self, i: usize) -> usize {
        self.perm[i]
    }

    /// Children of node `i`, if internal.
    pub fn children(&self, i: usize) -> Option<(usize, usize)> {
        let n = &self.nodes[i];
        if n.is_leaf() {
            None
        } else {
            Some((n.left as usize, n.right as usize))
        }
    }

    /// Children of a node the caller has already established to be
    /// internal (every traversal checks `is_leaf` before descending).
    /// Descending into a leaf means the traversal invariant is broken;
    /// continuing would silently corrupt sums, so abort loudly.
    pub fn children_of_internal(&self, i: usize) -> (usize, usize) {
        match self.children(i) {
            Some(pair) => pair,
            // lint: allow(no-panic): traversal-invariant breach must abort, not corrupt sums
            None => panic!("children_of_internal: node {i} is a leaf"),
        }
    }

    /// Total weight of the whole set.
    pub fn total_weight(&self) -> f64 {
        self.nodes[0].weight
    }

    /// Largest point count over leaf nodes — the block size a
    /// [`crate::compute::Scratch`] needs so leaf-leaf base cases run
    /// allocation-free.
    pub fn max_leaf_count(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.is_leaf())
            .map(|n| n.count())
            .max()
            .unwrap_or(0)
    }

    /// Scatter per-tree-position values back to original row order.
    pub fn unpermute(&self, tree_vals: &[f64]) -> Vec<f64> {
        assert_eq!(tree_vals.len(), self.perm.len());
        let mut out = vec![0.0; tree_vals.len()];
        for (tree_pos, &orig) in self.perm.iter().enumerate() {
            out[orig] = tree_vals[tree_pos];
        }
        out
    }

    /// Iterate node ids in a post-order (children before parents) —
    /// the order the bottom-up moment pass needs.
    pub fn postorder(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![(0usize, false)];
        while let Some((i, expanded)) = stack.pop() {
            if expanded || self.nodes[i].is_leaf() {
                out.push(i);
            } else {
                stack.push((i, true));
                stack.push((self.nodes[i].right as usize, false));
                stack.push((self.nodes[i].left as usize, false));
            }
        }
        out
    }
}

/// Recursive construction over `perm[begin..end]`; returns node index.
fn build_rec(
    points: &Matrix,
    weights: &[f64],
    perm: &mut [usize],
    nodes: &mut Vec<Node>,
    begin: usize,
    end: usize,
    depth: u32,
    leaf_size: usize,
) -> u32 {
    let slice = &perm[begin..end];
    let bbox = HRect::from_points(points, slice);
    // weighted centroid
    let d = points.cols();
    let mut centroid = vec![0.0; d];
    let mut weight = 0.0;
    for &i in slice.iter() {
        let w = weights[i];
        weight += w;
        let r = points.row(i);
        for j in 0..d {
            centroid[j] += w * r[j];
        }
    }
    for v in &mut centroid {
        *v /= weight;
    }
    let mut linf_radius = 0.0f64;
    let mut l2_radius = 0.0f64;
    for &i in slice.iter() {
        linf_radius = linf_radius.max(linf_dist(points.row(i), &centroid));
        l2_radius = l2_radius.max(crate::geometry::dist(points.row(i), &centroid));
    }
    let sphere = Sphere::new(centroid.clone(), l2_radius);

    let id = nodes.len() as u32;
    nodes.push(Node {
        begin,
        end,
        bbox,
        sphere,
        centroid,
        weight,
        linf_radius,
        left: NO_CHILD,
        right: NO_CHILD,
        depth,
    });

    let count = end - begin;
    if count > leaf_size {
        let axis = nodes[id as usize].bbox.widest_dim();
        // degenerate guard: all points identical in every dim → leaf
        if nodes[id as usize].bbox.widths()[axis] > 0.0 {
            let mid = begin + count / 2;
            // median partition by nth-element selection on `axis`
            perm[begin..end].select_nth_unstable_by(count / 2, |&a, &b| {
                points.get(a, axis).total_cmp(&points.get(b, axis))
            });
            let left = build_rec(points, weights, perm, nodes, begin, mid, depth + 1, leaf_size);
            let right = build_rec(points, weights, perm, nodes, mid, end, depth + 1, leaf_size);
            nodes[id as usize].left = left;
            nodes[id as usize].right = right;
        }
    }
    id
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    fn build(n: usize, d: usize, leaf: usize, seed: u64) -> (Matrix, KdTree) {
        let pts = random_points(n, d, seed);
        let w = vec![1.0; n];
        let t = KdTree::build(&pts, &w, BuildParams { leaf_size: leaf });
        (pts, t)
    }

    #[test]
    fn root_owns_everything() {
        let (_, t) = build(500, 3, 16, 1);
        assert_eq!(t.node(0).begin, 0);
        assert_eq!(t.node(0).end, 500);
        assert_eq!(t.total_weight(), 500.0);
    }

    #[test]
    fn children_partition_parent() {
        let (_, t) = build(300, 2, 8, 2);
        for i in 0..t.num_nodes() {
            if let Some((l, r)) = t.children(i) {
                let n = t.node(i);
                let ln = t.node(l);
                let rn = t.node(r);
                assert_eq!(ln.begin, n.begin);
                assert_eq!(ln.end, rn.begin);
                assert_eq!(rn.end, n.end);
                assert!((n.weight - ln.weight - rn.weight).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn leaves_respect_leaf_size() {
        let (_, t) = build(1000, 4, 25, 3);
        for i in 0..t.num_nodes() {
            let n = t.node(i);
            if n.is_leaf() {
                assert!(n.count() <= 25 || n.bbox.widths().iter().all(|&w| w == 0.0));
            } else {
                assert!(n.count() > 25);
            }
        }
    }

    #[test]
    fn max_leaf_count_bounds_every_leaf() {
        let (_, t) = build(700, 3, 20, 11);
        let m = t.max_leaf_count();
        assert!(m >= 1 && m <= 20);
        for i in 0..t.num_nodes() {
            let n = t.node(i);
            if n.is_leaf() {
                assert!(n.count() <= m);
            }
        }
        let single = KdTree::build(
            &Matrix::from_rows(&[vec![0.0, 0.0]]),
            &[1.0],
            BuildParams::default(),
        );
        assert_eq!(single.max_leaf_count(), 1);
    }

    #[test]
    fn bbox_contains_owned_points_and_centroid() {
        let (_, t) = build(400, 3, 10, 4);
        for i in 0..t.num_nodes() {
            let n = t.node(i);
            for pos in n.begin..n.end {
                assert!(n.bbox.contains(t.points().row(pos)));
                assert!(n.sphere.contains(t.points().row(pos)));
            }
            assert!(n.bbox.contains(&n.centroid));
        }
    }

    #[test]
    fn linf_radius_is_max_over_points() {
        let (_, t) = build(200, 2, 12, 5);
        for i in 0..t.num_nodes() {
            let n = t.node(i);
            let direct = (n.begin..n.end)
                .map(|p| linf_dist(t.points().row(p), &n.centroid))
                .fold(0.0f64, f64::max);
            assert!((n.linf_radius - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn sq_norms_cached_in_tree_order() {
        let (_, t) = build(150, 3, 10, 12);
        assert_eq!(t.sq_norms().len(), 150);
        let mut max_seen = 0.0f64;
        for pos in 0..150 {
            let want: f64 = t.points().row(pos).iter().map(|v| v * v).sum();
            assert_eq!(t.sq_norms()[pos], want, "pos {pos}");
            max_seen = max_seen.max(want);
        }
        assert_eq!(t.max_sq_norm(), max_seen);
    }

    #[test]
    fn perm_is_permutation_and_points_match() {
        let (pts, t) = build(250, 3, 9, 6);
        let mut seen = vec![false; 250];
        for pos in 0..250 {
            let orig = t.original_index(pos);
            assert!(!seen[orig]);
            seen[orig] = true;
            assert_eq!(t.points().row(pos), pts.row(orig));
        }
    }

    #[test]
    fn unpermute_roundtrip() {
        let (_, t) = build(100, 2, 7, 7);
        // tree-order values = original index → unpermute gives identity
        let tree_vals: Vec<f64> = (0..100).map(|p| t.original_index(p) as f64).collect();
        let orig = t.unpermute(&tree_vals);
        for (i, v) in orig.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn postorder_children_first() {
        let (_, t) = build(600, 3, 20, 8);
        let order = t.postorder();
        assert_eq!(order.len(), t.num_nodes());
        let mut pos = vec![0usize; t.num_nodes()];
        for (k, &i) in order.iter().enumerate() {
            pos[i] = k;
        }
        for i in 0..t.num_nodes() {
            if let Some((l, r)) = t.children(i) {
                assert!(pos[l] < pos[i]);
                assert!(pos[r] < pos[i]);
            }
        }
    }

    #[test]
    fn weighted_build_totals() {
        let pts = random_points(100, 2, 9);
        let mut rng = Pcg32::new(10);
        let w: Vec<f64> = (0..100).map(|_| rng.uniform_in(0.5, 2.0)).collect();
        let t = KdTree::build(&pts, &w, BuildParams::default());
        let total: f64 = w.iter().sum();
        assert!((t.total_weight() - total).abs() < 1e-9);
    }

    #[test]
    fn duplicate_points_terminate() {
        // all-identical points would recurse forever without the
        // zero-width guard
        let pts = Matrix::from_rows(&vec![vec![0.5, 0.5]; 100]);
        let w = vec![1.0; 100];
        let t = KdTree::build(&pts, &w, BuildParams { leaf_size: 4 });
        assert_eq!(t.num_nodes(), 1);
        assert!(t.node(0).is_leaf());
    }

    #[test]
    fn single_point_tree() {
        let pts = Matrix::from_rows(&[vec![1.0, 2.0, 3.0]]);
        let t = KdTree::build(&pts, &[2.5], BuildParams::default());
        assert_eq!(t.num_nodes(), 1);
        assert_eq!(t.total_weight(), 2.5);
        assert_eq!(t.node(0).linf_radius, 0.0);
    }
}
