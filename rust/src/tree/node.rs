//! Tree node: a contiguous range of (reordered) points plus cached
//! sufficient statistics and both bounding volumes.

use crate::geometry::{HRect, Sphere};

/// Sentinel for "no child".
pub const NO_CHILD: u32 = u32::MAX;

/// One node of a [`super::KdTree`]. Points owned by the node are the
/// contiguous range `begin..end` of the tree's reordered point matrix.
#[derive(Clone, Debug)]
pub struct Node {
    /// First owned point (inclusive), in tree order.
    pub begin: usize,
    /// One past the last owned point.
    pub end: usize,
    /// Bounding rectangle (tight).
    pub bbox: HRect,
    /// Bounding sphere about the centroid (tight).
    pub sphere: Sphere,
    /// Weighted centroid x_R of the owned points.
    pub centroid: Vec<f64>,
    /// Total weight W_R = Σ w_r over owned points.
    pub weight: f64,
    /// max_{x∈node} ‖x − centroid‖∞ (unscaled; bounds divide by h).
    pub linf_radius: f64,
    /// Left child index or [`NO_CHILD`].
    pub left: u32,
    /// Right child index or [`NO_CHILD`].
    pub right: u32,
    /// Depth from the root (root = 0).
    pub depth: u32,
}

impl Node {
    /// Number of owned points.
    #[inline]
    pub fn count(&self) -> usize {
        self.end - self.begin
    }

    /// Is this a leaf?
    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }

    /// Lower bound on the distance between points of `self` and `other`
    /// — the tighter of the rectangle and sphere bounds (SR-tree rule).
    pub fn min_dist(&self, other: &Node) -> f64 {
        let rect = self.bbox.min_sqdist(&other.bbox).sqrt();
        let sph = self.sphere.min_dist(&other.sphere);
        rect.max(sph)
    }

    /// Upper bound on the distance between points of the two nodes.
    pub fn max_dist(&self, other: &Node) -> f64 {
        let rect = self.bbox.max_sqdist(&other.bbox).sqrt();
        let sph = self.sphere.max_dist(&other.sphere);
        rect.min(sph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mknode(lo: Vec<f64>, hi: Vec<f64>) -> Node {
        let c: Vec<f64> = lo.iter().zip(&hi).map(|(a, b)| 0.5 * (a + b)).collect();
        let r = lo
            .iter()
            .zip(&hi)
            .map(|(a, b)| (b - a) * 0.5)
            .map(|x| x * x)
            .sum::<f64>()
            .sqrt();
        let linf = lo.iter().zip(&hi).map(|(a, b)| (b - a) * 0.5).fold(0.0f64, f64::max);
        Node {
            begin: 0,
            end: 1,
            bbox: HRect::new(lo, hi),
            sphere: Sphere::new(c.clone(), r),
            centroid: c,
            weight: 1.0,
            linf_radius: linf,
            left: NO_CHILD,
            right: NO_CHILD,
            depth: 0,
        }
    }

    #[test]
    fn sr_bounds_tighter_than_either() {
        let a = mknode(vec![0.0, 0.0], vec![1.0, 1.0]);
        let b = mknode(vec![3.0, 0.0], vec![4.0, 1.0]);
        let mind = a.min_dist(&b);
        let maxd = a.max_dist(&b);
        // rect min = 2.0; sphere min = 3 − √0.5 − √0.5 ≈ 1.586 → rect wins
        assert!((mind - 2.0).abs() < 1e-12);
        // rect max = √17 ≈ 4.123; sphere max = 3 + √2 ≈ 4.414 → rect wins
        assert!((maxd - 17f64.sqrt()).abs() < 1e-12);
        assert!(mind <= maxd);
    }

    #[test]
    fn leaf_detection() {
        let n = mknode(vec![0.0], vec![1.0]);
        assert!(n.is_leaf());
        assert_eq!(n.count(), 1);
    }
}
