//! The machine-readable perf-trajectory runner: times the old
//! (single-query, libm-exp) base cases against the tiled fast path on
//! the paper datasets and emits JSON — `BENCH_PR4.json` at the repo
//! root by convention (`cargo run --release --bin bench_json`).
//!
//! No external deps: timing via [`crate::util::timer::time_it`], JSON
//! emitted by hand and kept parseable by [`crate::util::json`] (the
//! smoke test round-trips it). Methods covered, per dataset
//! (astro2d, galaxy3d) at ε = 1e-4, h = Silverman's h*:
//!
//! * **Naive** — `gauss_sum_all` (bit-exact) vs `gauss_sum_all_fast`;
//! * **DFDO / DITO** — one prepared [`SweepEngine`], `fast_exp` off vs
//!   on (same tree, same memoized moments: the diff is the base case);
//! * **FGT** — the τ-halving protocol with the sparse-box direct path
//!   bit-exact vs tiled (may report the paper's X/∞ as a status).
//!
//! Every timed answer is ε-verified against the exhaustive truth
//! before its time is reported.

use crate::algo::dualtree::{DualTreeConfig, SweepEngine};
use crate::algo::fgt::GridFrame;
use crate::algo::naive::Naive;
use crate::algo::{max_relative_error, GaussSum, GaussSumProblem};
use crate::api::tuning;
use crate::data;
use crate::kde::bandwidth::silverman;
use crate::util::timer::time_it;

/// Knobs for one bench run.
#[derive(Copy, Clone, Debug)]
pub struct BenchConfig {
    /// Points per dataset (default 4000; `--smoke` uses 400).
    pub n: usize,
    /// Timing repetitions (median reported; 1 in smoke mode).
    pub reps: usize,
    /// Verified relative tolerance for every cell.
    pub epsilon: f64,
    /// Marked in the output so consumers can tell smoke JSON from a
    /// real trajectory point.
    pub smoke: bool,
}

impl BenchConfig {
    pub fn full() -> Self {
        BenchConfig { n: 4000, reps: 3, epsilon: 1e-4, smoke: false }
    }

    pub fn smoke() -> Self {
        BenchConfig { n: 400, reps: 1, epsilon: 1e-4, smoke: true }
    }
}

fn median_secs<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let ((), s) = time_it(&mut f);
            s
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6e}")
    } else {
        "null".into()
    }
}

/// One method's old-vs-tiled cell.
fn cell(old_secs: f64, tiled_secs: f64, rel_err: f64, status: &str) -> String {
    format!(
        "{{\"old_secs\": {}, \"tiled_secs\": {}, \"speedup\": {}, \"rel_err_tiled\": {}, \"status\": \"{status}\"}}",
        num(old_secs),
        num(tiled_secs),
        num(old_secs / tiled_secs),
        num(rel_err),
    )
}

fn failed_cell(status: &str) -> String {
    format!(
        "{{\"old_secs\": null, \"tiled_secs\": null, \"speedup\": null, \"rel_err_tiled\": null, \"status\": \"{status}\"}}"
    )
}

/// Run the whole protocol and return the JSON document.
pub fn run_bench(cfg: &BenchConfig) -> String {
    let eps = cfg.epsilon;
    let mut dataset_objs: Vec<String> = Vec::new();
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, cfg.n, 42).expect("paper dataset");
        let h = silverman(&ds.points);
        let problem = GaussSumProblem::kde(&ds.points, h, eps);

        // ---- exhaustive truth (also the Naive "old" timing) ----
        let (truth, truth_secs) = time_it(|| Naive::new().run(&problem).unwrap().sums);
        let naive_old = if cfg.reps > 1 {
            median_secs(|| drop(Naive::new().run(&problem).unwrap()), cfg.reps)
        } else {
            truth_secs
        };
        let fast_naive = Naive::fast();
        let mut naive_fast_sums = Vec::new();
        let naive_tiled = median_secs(
            || naive_fast_sums = fast_naive.run(&problem).unwrap().sums,
            cfg.reps,
        );
        let naive_rel = max_relative_error(&naive_fast_sums, &truth);
        assert!(naive_rel <= eps, "{name} Naive(fast): rel {naive_rel:.2e} > ε");
        let mut methods: Vec<(String, String)> =
            vec![("Naive".into(), cell(naive_old, naive_tiled, naive_rel, "ok"))];

        // ---- dual-tree variants on one prepared engine ----
        let engine = SweepEngine::for_kde(&ds.points, 32);
        let dualtree_cfgs = [
            ("DFDO", DualTreeConfig { use_tokens: true, series: None, ..Default::default() }),
            ("DITO", DualTreeConfig::default()),
        ];
        for (label, base) in dualtree_cfgs {
            let old_cfg = DualTreeConfig { fast_exp: false, ..base };
            let new_cfg = DualTreeConfig { fast_exp: true, ..base };
            // warm the (shared) moment memo so both modes time the
            // traversal + base cases, not the h-dependent moment pass
            engine.evaluate(h, eps, &old_cfg).unwrap();
            let t_old = median_secs(|| drop(engine.evaluate(h, eps, &old_cfg).unwrap()), cfg.reps);
            let mut sums = Vec::new();
            let t_new =
                median_secs(|| sums = engine.evaluate(h, eps, &new_cfg).unwrap().sums, cfg.reps);
            let rel = max_relative_error(&sums, &truth);
            assert!(rel <= eps * (1.0 + 1e-9), "{name} {label}: rel {rel:.2e} > ε");
            methods.push((label.into(), cell(t_old, t_new, rel, "ok")));
        }

        // ---- FGT through the paper's τ-halving, both kernels ----
        let frame = GridFrame::joint(&ds.points, &ds.points);
        let fgt_cell = {
            let old = tuning::fgt_halving_with(&problem, &frame, &truth, 20, false);
            let new = tuning::fgt_halving_with(&problem, &frame, &truth, 20, true);
            match (old, new) {
                (Ok(o), Ok(nw)) => cell(o.attempt_secs, nw.attempt_secs, nw.rel_err, "ok"),
                (Err(crate::algo::AlgoError::RamExhausted(_)), _)
                | (_, Err(crate::algo::AlgoError::RamExhausted(_))) => failed_cell("X"),
                _ => failed_cell("inf"),
            }
        };
        methods.push(("FGT".into(), fgt_cell));

        let body: Vec<String> =
            methods.iter().map(|(k, v)| format!("      \"{k}\": {v}")).collect();
        dataset_objs.push(format!(
            "  \"{name}\": {{\n    \"h\": {},\n    \"naive_truth_secs\": {},\n    \"methods\": {{\n{}\n    }}\n  }}",
            num(h),
            num(truth_secs),
            body.join(",\n"),
        ));
    }
    format!(
        "{{\n\"bench\": \"BENCH_PR4\",\n\"description\": \"old (single-query, libm exp) vs tiled \
         (norms-trick + certified fast-exp) base cases\",\n\"epsilon\": {},\n\"n\": {},\n\
         \"reps\": {},\n\"smoke\": {},\n\"generated_by\": \"cargo run --release --bin bench_json\",\n\
         \"datasets\": {{\n{}\n}}\n}}\n",
        num(eps),
        cfg.n,
        cfg.reps,
        cfg.smoke,
        dataset_objs.join(",\n"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// The emitter must produce parseable JSON with every advertised
    /// cell — this is what the CI smoke step exercises release-built.
    #[test]
    fn smoke_bench_emits_parseable_json() {
        let cfg = BenchConfig { n: 200, reps: 1, epsilon: 1e-4, smoke: true };
        let text = run_bench(&cfg);
        let doc = Json::parse(&text).expect("bench_json output must parse");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("BENCH_PR4"));
        assert_eq!(doc.get("smoke").unwrap(), &Json::Bool(true));
        for ds in ["astro2d", "galaxy3d"] {
            let d = doc.get("datasets").unwrap().get(ds).unwrap_or_else(|| panic!("{ds}"));
            let methods = d.get("methods").unwrap();
            for m in ["Naive", "DFDO", "DITO", "FGT"] {
                let cell = methods.get(m).unwrap_or_else(|| panic!("{ds}/{m}"));
                assert!(cell.get("status").unwrap().as_str().is_some(), "{ds}/{m}");
            }
            // the guaranteed methods always verify at ε
            for m in ["Naive", "DFDO", "DITO"] {
                let rel = methods.get(m).unwrap().get("rel_err_tiled").unwrap();
                assert!(rel.as_f64().unwrap() <= 1e-4, "{ds}/{m}");
            }
        }
    }
}
