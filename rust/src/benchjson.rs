//! The machine-readable perf-trajectory runner. The documents:
//!
//! * **PR 9 (`--pr9`, `BENCH_PR9.json`)** — [`run_bench_pr9`]: the
//!   sliced Fourier engine vs DITO vs exhaustive summation on the
//!   high-dimensional hyper sets, with low-D galaxy3d as the control.
//! * **PR 7 (`--pr7`, `BENCH_PR7.json`)** — [`run_bench_pr7`]:
//!   forced-scalar vs runtime-dispatched SIMD base cases, plus the
//!   certified f32 mixed-precision tile.
//! * **PR 5 (default, `BENCH_PR5.json`)** — [`run_bench_pr5`]: the old
//!   fractured thread model (per-request scoped threads, each request
//!   pinned to one inner thread) vs the shared work-stealing pool
//!   (requests and their nested traversal tasks on one scheduler) on
//!   astro2d + galaxy3d *batch* workloads, ε-verified per request and
//!   pinned bitwise-equal between the two models.
//! * **PR 4 (`--pr4`, `BENCH_PR4.json`)** — [`run_bench`]: old
//!   (single-query, libm-exp) base cases vs the tiled fast path.
//!
//! No external deps: timing via [`crate::util::timer::time_it`], JSON
//! emitted by hand and kept parseable by [`crate::util::json`] (the
//! smoke tests round-trip both). Every timed answer is ε-verified
//! against the exhaustive truth before its time is reported — the CI
//! smoke run therefore *fails the job* if any measured `rel_err`
//! exceeds its ε.

use crate::runtime::sync::{Ordering, SyncAtomicUsize, SyncMutex};

use crate::algo::dualtree::{DualTreeConfig, SweepEngine};
use crate::algo::fgt::GridFrame;
use crate::algo::naive::Naive;
use crate::algo::{max_relative_error, max_weight_scaled_error, GaussSum, GaussSumProblem};
use crate::api::{tuning, EvalRequest, Method, Precision, PrepareOptions, Session, SimdMode};
use crate::data;
use crate::kde::bandwidth::silverman;
use crate::kernel::Kernel;
use crate::util::timer::time_it;

/// Knobs for one bench run.
#[derive(Copy, Clone, Debug)]
pub struct BenchConfig {
    /// Points per dataset (default 4000; `--smoke` uses 400).
    pub n: usize,
    /// Timing repetitions (median reported; 1 in smoke mode).
    pub reps: usize,
    /// Verified relative tolerance for every cell.
    pub epsilon: f64,
    /// Marked in the output so consumers can tell smoke JSON from a
    /// real trajectory point.
    pub smoke: bool,
}

impl BenchConfig {
    pub fn full() -> Self {
        BenchConfig { n: 4000, reps: 3, epsilon: 1e-4, smoke: false }
    }

    pub fn smoke() -> Self {
        BenchConfig { n: 400, reps: 1, epsilon: 1e-4, smoke: true }
    }
}

fn median_secs<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let ((), s) = time_it(&mut f);
            s
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6e}")
    } else {
        "null".into()
    }
}

/// One method's old-vs-tiled cell.
fn cell(old_secs: f64, tiled_secs: f64, rel_err: f64, status: &str) -> String {
    format!(
        "{{\"old_secs\": {}, \"tiled_secs\": {}, \"speedup\": {}, \"rel_err_tiled\": {}, \"status\": \"{status}\"}}",
        num(old_secs),
        num(tiled_secs),
        num(old_secs / tiled_secs),
        num(rel_err),
    )
}

fn failed_cell(status: &str) -> String {
    format!(
        "{{\"old_secs\": null, \"tiled_secs\": null, \"speedup\": null, \"rel_err_tiled\": null, \"status\": \"{status}\"}}"
    )
}

/// Run the whole protocol and return the JSON document.
pub fn run_bench(cfg: &BenchConfig) -> String {
    let eps = cfg.epsilon;
    let mut dataset_objs: Vec<String> = Vec::new();
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, cfg.n, 42).expect("paper dataset");
        let h = silverman(&ds.points);
        let problem = GaussSumProblem::kde(&ds.points, h, eps);

        // ---- exhaustive truth (also the Naive "old" timing) ----
        let (truth, truth_secs) = time_it(|| Naive::new().run(&problem).unwrap().sums);
        let naive_old = if cfg.reps > 1 {
            median_secs(|| drop(Naive::new().run(&problem).unwrap()), cfg.reps)
        } else {
            truth_secs
        };
        let fast_naive = Naive::fast();
        let mut naive_fast_sums = Vec::new();
        let naive_tiled = median_secs(
            || naive_fast_sums = fast_naive.run(&problem).unwrap().sums,
            cfg.reps,
        );
        let naive_rel = max_relative_error(&naive_fast_sums, &truth);
        assert!(naive_rel <= eps, "{name} Naive(fast): rel {naive_rel:.2e} > ε");
        let mut methods: Vec<(String, String)> =
            vec![("Naive".into(), cell(naive_old, naive_tiled, naive_rel, "ok"))];

        // ---- dual-tree variants on one prepared engine ----
        let engine = SweepEngine::for_kde(&ds.points, 32);
        let dualtree_cfgs = [
            ("DFDO", DualTreeConfig { use_tokens: true, series: None, ..Default::default() }),
            ("DITO", DualTreeConfig::default()),
        ];
        for (label, base) in dualtree_cfgs {
            let old_cfg = DualTreeConfig { fast_exp: false, ..base };
            let new_cfg = DualTreeConfig { fast_exp: true, ..base };
            // warm the (shared) moment memo so both modes time the
            // traversal + base cases, not the h-dependent moment pass
            engine.evaluate(h, eps, &old_cfg).unwrap();
            let t_old = median_secs(|| drop(engine.evaluate(h, eps, &old_cfg).unwrap()), cfg.reps);
            let mut sums = Vec::new();
            let t_new =
                median_secs(|| sums = engine.evaluate(h, eps, &new_cfg).unwrap().sums, cfg.reps);
            let rel = max_relative_error(&sums, &truth);
            assert!(rel <= eps * (1.0 + 1e-9), "{name} {label}: rel {rel:.2e} > ε");
            methods.push((label.into(), cell(t_old, t_new, rel, "ok")));
        }

        // ---- FGT through the paper's τ-halving, both kernels ----
        let frame = GridFrame::joint(&ds.points, &ds.points);
        let fgt_cell = {
            let old = tuning::fgt_halving_with(&problem, &frame, &truth, 20, false);
            let new = tuning::fgt_halving_with(&problem, &frame, &truth, 20, true);
            match (old, new) {
                (Ok(o), Ok(nw)) => cell(o.attempt_secs, nw.attempt_secs, nw.rel_err, "ok"),
                (Err(crate::algo::AlgoError::RamExhausted(_)), _)
                | (_, Err(crate::algo::AlgoError::RamExhausted(_))) => failed_cell("X"),
                _ => failed_cell("inf"),
            }
        };
        methods.push(("FGT".into(), fgt_cell));

        let body: Vec<String> =
            methods.iter().map(|(k, v)| format!("      \"{k}\": {v}")).collect();
        dataset_objs.push(format!(
            "  \"{name}\": {{\n    \"h\": {},\n    \"naive_truth_secs\": {},\n    \"methods\": {{\n{}\n    }}\n  }}",
            num(h),
            num(truth_secs),
            body.join(",\n"),
        ));
    }
    format!(
        "{{\n\"bench\": \"BENCH_PR4\",\n\"description\": \"old (single-query, libm exp) vs tiled \
         (norms-trick + certified fast-exp) base cases\",\n\"epsilon\": {},\n\"n\": {},\n\
         \"reps\": {},\n\"smoke\": {},\n\"generated_by\": \"cargo run --release --bin bench_json\",\n\
         \"datasets\": {{\n{}\n}}\n}}\n",
        num(eps),
        cfg.n,
        cfg.reps,
        cfg.smoke,
        dataset_objs.join(",\n"),
    )
}

/// Emulate the pre-pool `Session::evaluate_batch`: `min(workers, k)`
/// scoped threads pull requests off a shared counter and evaluate each
/// on an inline (single-threaded) session — the fan-out this PR
/// removed, kept here as the measured baseline. A batch of k < workers
/// requests provably leaves `workers − k` cores idle.
fn old_model_batch(
    session: &Session<'_>,
    requests: &[EvalRequest<'_>],
    workers: usize,
) -> Vec<Vec<f64>> {
    let workers = workers.min(requests.len()).max(1);
    let slots: Vec<SyncMutex<Option<Vec<f64>>>> =
        (0..requests.len()).map(|_| SyncMutex::new(None)).collect();
    let next = SyncAtomicUsize::new(0);
    // lint: allow(raw-thread): this IS the pre-pool "old model" being benchmarked against the pool
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let slots = &slots;
            let next = &next;
            scope.spawn(move || loop {
                // ORDER: Relaxed — work-ticket counter; each index is
                // claimed by exactly one RMW and orders nothing else.
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= requests.len() {
                    break;
                }
                let ev = session.evaluate(&requests[k]).expect("bench request cannot fail");
                *slots[k].lock().unwrap() = Some(ev.sums);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("old-model worker lost a request"))
        .collect()
}

/// PR 5 protocol: batch workloads (3 bandwidths × {DFDO, DITO}) on
/// astro2d + galaxy3d, old thread model vs shared pool at the same
/// worker count. Every request is ε-verified against exhaustive truth
/// (the run aborts otherwise), and the two models' batches are pinned
/// bitwise-equal — the speedup comes from scheduling alone.
pub fn run_bench_pr5(cfg: &BenchConfig) -> String {
    let eps = cfg.epsilon;
    let workers =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
    let mults = [0.5, 1.0, 2.0];
    let methods = [Method::Dfdo, Method::Dito];
    let mut dataset_objs: Vec<String> = Vec::new();
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, cfg.n, 42).expect("paper dataset");
        let h_star = silverman(&ds.points);
        let hs: Vec<f64> = mults.iter().map(|m| m * h_star).collect();
        let requests: Vec<EvalRequest<'static>> = hs
            .iter()
            .flat_map(|&h| methods.into_iter().map(move |m| EvalRequest::kde(h, eps).with_method(m)))
            .collect();

        // exhaustive truths, one per distinct bandwidth
        let truths: Vec<Vec<f64>> = hs
            .iter()
            .map(|&h| {
                let p = GaussSumProblem::kde(&ds.points, h, eps);
                Naive::new().run(&p).unwrap().sums
            })
            .collect();

        // ---- old fractured model: outer request threads, inner
        // sequential (first pass warms the moment memo) ----
        let old_session =
            Session::prepare(&ds.points, PrepareOptions { threads: 1, ..Default::default() });
        let old_sums = old_model_batch(&old_session, &requests, workers);
        let old_secs =
            median_secs(|| drop(old_model_batch(&old_session, &requests, workers)), cfg.reps);

        // ---- shared pool: same batch, requests + nested traversal
        // tasks on one scheduler ----
        let pool_session = Session::prepare(
            &ds.points,
            PrepareOptions { threads: workers, ..Default::default() },
        );
        let pool_sums: Vec<Vec<f64>> = pool_session
            .evaluate_batch(&requests)
            .into_iter()
            .map(|r| r.expect("bench request cannot fail").sums)
            .collect();
        let pool_secs = median_secs(|| drop(pool_session.evaluate_batch(&requests)), cfg.reps);

        // ε-verify every request and pin the two models bitwise-equal
        let mut max_rel = 0.0f64;
        for (k, sums) in pool_sums.iter().enumerate() {
            let rel = max_relative_error(sums, &truths[k / methods.len()]);
            assert!(rel <= eps * (1.0 + 1e-9), "{name} request {k}: rel {rel:.2e} > ε");
            max_rel = max_rel.max(rel);
        }
        assert_eq!(
            old_sums, pool_sums,
            "{name}: pool batch diverged bitwise from the old thread model"
        );

        dataset_objs.push(format!(
            "  \"{name}\": {{\"h_star\": {}, \"requests\": {}, \"old_model_secs\": {}, \
             \"pool_secs\": {}, \"speedup\": {}, \"max_rel_err\": {}, \
             \"bitwise_equal_old_vs_pool\": true, \"status\": \"ok\"}}",
            num(h_star),
            requests.len(),
            num(old_secs),
            num(pool_secs),
            num(old_secs / pool_secs),
            num(max_rel),
        ));
    }

    // ---- one SoG cell: Matérn-3/2 on astro2d through the kernel
    // layer (decomposition fit + ε split + pooled component batch),
    // verified against the exhaustive true-kernel sum under the
    // weight-scaled guarantee max_q|G̃−G| ≤ ε·W ----
    let sog_obj = {
        let ds = data::by_name("astro2d", cfg.n, 42).expect("paper dataset");
        let h = silverman(&ds.points);
        let session = Session::prepare(
            &ds.points,
            PrepareOptions { threads: workers, kernel: Kernel::Matern32, ..Default::default() },
        );
        let (exact, _, _) = session
            .exact_kernel_sums(Kernel::Matern32, h, eps)
            .expect("matern32 truth cannot fail");
        let req = EvalRequest::kde(h, eps).with_method(Method::Auto);
        let ev = session.evaluate(&req).expect("sog cell cannot fail");
        let secs = median_secs(|| drop(session.evaluate(&req)), cfg.reps);
        let err = max_weight_scaled_error(&ev.sums, &exact, session.total_weight());
        assert!(err <= eps * (1.0 + 1e-9), "astro2d matern32: scaled err {err:.2e} > ε");
        let report = ev.sog.as_ref().expect("non-Gaussian answers carry a SoG report");
        format!(
            "{{\"kernel\": \"matern32\", \"dataset\": \"astro2d\", \"components\": {}, \
             \"decomp_err\": {}, \"scaled_err\": {}, \"secs\": {}, \"status\": \"ok\"}}",
            report.components.len(),
            num(report.decomp_err),
            num(err),
            num(secs),
        )
    };

    format!(
        "{{\n\"bench\": \"BENCH_PR5\",\n\"description\": \"fractured thread model (per-request \
         scoped threads, 1 inner thread each) vs shared work-stealing pool (requests + nested \
         traversal tasks on one scheduler) on batch workloads\",\n\"measured\": true,\n\
         \"epsilon\": {},\n\"n\": {},\n\"reps\": {},\n\"smoke\": {},\n\"workers\": {},\n\
         \"generated_by\": \"cargo run --release --bin bench_json\",\n\"sog\": {},\n\
         \"datasets\": {{\n{}\n}}\n}}\n",
        num(eps),
        cfg.n,
        cfg.reps,
        cfg.smoke,
        workers,
        sog_obj,
        dataset_objs.join(",\n"),
    )
}

/// PR 7 protocol: the three base-case configurations — forced-scalar
/// lanes (`SimdMode::Off`), the auto-detected vector lanes, and the
/// vector lanes plus the mixed-precision f32 tile — for DFDO + DITO
/// on astro2d + galaxy3d at ε ∈ {1e-2, 1e-4} and fixed h = 0.2. At
/// that bandwidth the derived f32 certificate
/// (`errorcontrol::base_case_rel_err_f32`, ≈1e-4 on the unit-cube
/// datasets) fits the ε/4 admission gate at 1e-2 and fails it at
/// 1e-4, so the emitted `f32_engaged` flags document the gate in
/// action. Every cell is ε-verified against the exhaustive truth (the
/// run aborts on a violation) and records the lane backend it
/// actually executed on.
pub fn run_bench_pr7(cfg: &BenchConfig) -> String {
    let h = 0.2;
    let epsilons = [1e-2, 1e-4];
    let methods = [Method::Dfdo, Method::Dito];
    let mut dataset_objs: Vec<String> = Vec::new();
    for name in ["astro2d", "galaxy3d"] {
        let ds = data::by_name(name, cfg.n, 42).expect("paper dataset");
        let problem = GaussSumProblem::kde(&ds.points, h, epsilons[0]);
        let (truth, truth_secs) = time_it(|| Naive::new().run(&problem).unwrap().sums);
        let prep = |simd: SimdMode, precision: Precision| {
            let opts = PrepareOptions { simd, precision, ..Default::default() };
            Session::prepare(&ds.points, opts)
        };
        let scalar_session = prep(SimdMode::Off, Precision::F64);
        let vector_session = prep(SimdMode::Auto, Precision::F64);
        let f32_session = prep(SimdMode::Auto, Precision::F32);
        let mut eps_objs: Vec<String> = Vec::new();
        for eps in epsilons {
            let mut method_objs: Vec<String> = Vec::new();
            for method in methods {
                let req = EvalRequest::kde(h, eps).with_method(method);
                let run = |s: &Session<'_>| {
                    let ev = s.evaluate(&req).expect("bench request cannot fail");
                    let rel = max_relative_error(&ev.sums, &truth);
                    let ok = rel <= eps * (1.0 + 1e-9);
                    assert!(ok, "{name} {method} ε={eps}: rel {rel:.2e} > ε");
                    let secs = median_secs(|| drop(s.evaluate(&req)), cfg.reps);
                    (secs, rel, ev.stats)
                };
                let (scalar_secs, _, _) = run(&scalar_session);
                let (simd_secs, rel_simd, simd_stats) = run(&vector_session);
                let (f32_secs, rel_f32, f32_stats) = run(&f32_session);
                method_objs.push(format!(
                    "        \"{}\": {{\"scalar_secs\": {}, \"simd_secs\": {}, \"f32_secs\": {}, \
                     \"simd_speedup\": {}, \"f32_speedup\": {}, \"rel_err_simd\": {}, \
                     \"rel_err_f32\": {}, \"backend\": \"{}\", \"f32_engaged\": {}, \
                     \"status\": \"ok\"}}",
                    method.name(),
                    num(scalar_secs),
                    num(simd_secs),
                    num(f32_secs),
                    num(scalar_secs / simd_secs),
                    num(scalar_secs / f32_secs),
                    num(rel_simd),
                    num(rel_f32),
                    simd_stats.simd_backend,
                    f32_stats.f32_base_cases > 0,
                ));
            }
            let body = method_objs.join(",\n");
            eps_objs.push(format!("      \"{eps:e}\": {{\n{body}\n      }}"));
        }
        dataset_objs.push(format!(
            "  \"{name}\": {{\n    \"h\": {}, \"naive_truth_secs\": {},\n    \
             \"epsilons\": {{\n{}\n    }}\n  }}",
            num(h),
            num(truth_secs),
            eps_objs.join(",\n"),
        ));
    }
    format!(
        "{{\n\"bench\": \"BENCH_PR7\",\n\"description\": \"forced-scalar vs runtime-dispatched \
         vector lanes vs the certified mixed-precision f32 tile in the fast base cases; every \
         cell eps-verified against exhaustive truth, backend recorded, and the f32_engaged \
         flags show the eps/4 admission gate of split_epsilon_prec\",\n\"measured\": true,\n\
         \"detected_backend\": \"{}\",\n\"h\": {},\n\"n\": {},\n\"reps\": {},\n\"smoke\": {},\n\
         \"generated_by\": \"cargo run --release --bin bench_json -- --pr7\",\n\
         \"datasets\": {{\n{}\n}}\n}}\n",
        crate::compute::simd::active().backend.name(),
        num(h),
        cfg.n,
        cfg.reps,
        cfg.smoke,
        dataset_objs.join(",\n"),
    )
}

/// PR 9 protocol: the sliced Fourier engine vs the paper's best
/// dual-tree engine (DITO) vs exhaustive summation, on the two
/// high-dimensional sets the engine targets plus low-D galaxy3d as the
/// control where the dual tree is expected to keep winning. Bandwidths
/// are pinned inside the slicing Monte-Carlo's ε = 1e-2 regime (h of
/// the order of the data diameter — see rust/tests/sliced_engine.rs
/// for the variance rationale); every answered cell is ε-verified
/// against the exhaustive truth (the run aborts on a violation), and a
/// cell that refuses to answer is recorded as the paper's X/∞ instead.
pub fn run_bench_pr9(cfg: &BenchConfig) -> String {
    let eps = 1e-2;
    let cases = [("galaxy3d", 1.0), ("hyper20", 2.5), ("hyper50", 3.5)];
    let mut dataset_objs: Vec<String> = Vec::new();
    for (name, h) in cases {
        let ds = data::by_name(name, cfg.n, 42).expect("bench dataset");
        let problem = GaussSumProblem::kde(&ds.points, h, eps);
        let (truth, truth_secs) = time_it(|| Naive::new().run(&problem).unwrap().sums);
        let naive_secs = if cfg.reps > 1 {
            median_secs(|| drop(Naive::new().run(&problem).unwrap()), cfg.reps)
        } else {
            truth_secs
        };
        let session = Session::prepare(&ds.points, PrepareOptions::default());
        // the probe evaluate warms the session's truth memo, so the
        // timed repeats measure the engine + its verification loop,
        // not the exhaustive reference
        let cell_for = |method: Method| -> (String, f64) {
            let req = EvalRequest::kde(h, eps).with_method(method);
            match session.evaluate(&req) {
                Ok(ev) => {
                    let rel = max_relative_error(&ev.sums, &truth);
                    assert!(rel <= eps * (1.0 + 1e-9), "{name} {method}: rel {rel:.2e} > ε");
                    let secs = median_secs(|| drop(session.evaluate(&req)), cfg.reps);
                    (
                        format!(
                            "{{\"secs\": {}, \"rel_err\": {}, \"status\": \"ok\"}}",
                            num(secs),
                            num(rel)
                        ),
                        secs,
                    )
                }
                Err(crate::algo::AlgoError::RamExhausted(_)) => {
                    ("{\"secs\": null, \"rel_err\": null, \"status\": \"X\"}".into(), f64::NAN)
                }
                Err(_) => {
                    ("{\"secs\": null, \"rel_err\": null, \"status\": \"inf\"}".into(), f64::NAN)
                }
            }
        };
        let (sliced_cell, sliced_secs) = cell_for(Method::Sliced);
        let (dito_cell, dito_secs) = cell_for(Method::Dito);
        dataset_objs.push(format!(
            "  \"{name}\": {{\n    \"dim\": {}, \"h\": {}, \"naive_secs\": {},\n    \
             \"sliced\": {sliced_cell},\n    \"dito\": {dito_cell},\n    \
             \"sliced_speedup_vs_naive\": {}, \"sliced_speedup_vs_dito\": {}\n  }}",
            ds.dim(),
            num(h),
            num(naive_secs),
            num(naive_secs / sliced_secs),
            num(dito_secs / sliced_secs),
        ));
    }
    format!(
        "{{\n\"bench\": \"BENCH_PR9\",\n\"description\": \"sliced Fourier fast summation vs DITO \
         vs exhaustive on high-dimensional sets (hyper20/hyper50) with low-D galaxy3d as the \
         control; bandwidths pinned in the slicing MC eps=1e-2 regime, every answered cell \
         eps-verified against exhaustive truth, refusals recorded as X/inf\",\n\
         \"measured\": true,\n\"epsilon\": {},\n\"n\": {},\n\"reps\": {},\n\"smoke\": {},\n\
         \"generated_by\": \"cargo run --release --bin bench_json -- --pr9\",\n\
         \"datasets\": {{\n{}\n}}\n}}\n",
        num(eps),
        cfg.n,
        cfg.reps,
        cfg.smoke,
        dataset_objs.join(",\n"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// The PR 5 emitter must produce parseable JSON with every
    /// advertised cell — this is what the CI smoke step exercises
    /// release-built (its internal asserts fail the job on any
    /// ε-violating cell).
    #[test]
    fn smoke_bench_pr5_emits_parseable_json() {
        let cfg = BenchConfig { n: 150, reps: 1, epsilon: 1e-4, smoke: true };
        let text = run_bench_pr5(&cfg);
        let doc = Json::parse(&text).expect("bench_json PR5 output must parse");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("BENCH_PR5"));
        assert_eq!(doc.get("measured").unwrap(), &Json::Bool(true));
        assert_eq!(doc.get("smoke").unwrap(), &Json::Bool(true));
        for ds in ["astro2d", "galaxy3d"] {
            let d = doc.get("datasets").unwrap().get(ds).unwrap_or_else(|| panic!("{ds}"));
            assert_eq!(d.get("status").unwrap().as_str(), Some("ok"), "{ds}");
            assert_eq!(d.get("bitwise_equal_old_vs_pool").unwrap(), &Json::Bool(true));
            let rel = d.get("max_rel_err").unwrap().as_f64().unwrap();
            assert!(rel <= 1e-4, "{ds}: {rel}");
            assert!(d.get("old_model_secs").unwrap().as_f64().unwrap() >= 0.0);
            assert!(d.get("pool_secs").unwrap().as_f64().unwrap() >= 0.0);
        }
        // the SoG cell: Matérn-3/2 on astro2d through the kernel layer
        let sog = doc.get("sog").expect("PR5 JSON must carry the sog cell");
        assert_eq!(sog.get("kernel").unwrap().as_str(), Some("matern32"));
        assert_eq!(sog.get("dataset").unwrap().as_str(), Some("astro2d"));
        assert_eq!(sog.get("status").unwrap().as_str(), Some("ok"));
        assert!(sog.get("components").unwrap().as_f64().unwrap() >= 1.0);
        let scaled = sog.get("scaled_err").unwrap().as_f64().unwrap();
        assert!(scaled <= 1e-4, "sog cell scaled_err {scaled}");
        let decomp = sog.get("decomp_err").unwrap().as_f64().unwrap();
        assert!(decomp <= 0.25 * 1e-4, "decomp_err {decomp} must fit the ε/4 gate");
    }

    /// The PR 7 emitter: parseable JSON, every cell ε-verified with a
    /// recorded backend, and the f32 admission gate visible in the
    /// emitted flags — DFDO's mixed-precision tile engages at ε = 1e-2
    /// and demotes at ε = 1e-4.
    #[test]
    fn smoke_bench_pr7_emits_parseable_json() {
        let cfg = BenchConfig { n: 150, reps: 1, epsilon: 1e-4, smoke: true };
        let text = run_bench_pr7(&cfg);
        let doc = Json::parse(&text).expect("bench_json PR7 output must parse");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("BENCH_PR7"));
        assert_eq!(doc.get("measured").unwrap(), &Json::Bool(true));
        assert_eq!(doc.get("smoke").unwrap(), &Json::Bool(true));
        let detected = doc.get("detected_backend").unwrap().as_str().unwrap();
        assert!(["scalar", "avx2", "neon"].contains(&detected), "{detected}");
        for ds in ["astro2d", "galaxy3d"] {
            let d = doc.get("datasets").unwrap().get(ds).unwrap_or_else(|| panic!("{ds}"));
            let eps_groups = d.get("epsilons").unwrap();
            for (key, eps) in [("1e-2", 1e-2), ("1e-4", 1e-4)] {
                let group = eps_groups.get(key).unwrap_or_else(|| panic!("{ds}/{key}"));
                for m in ["DFDO", "DITO"] {
                    let cell = group.get(m).unwrap_or_else(|| panic!("{ds}/{key}/{m}"));
                    assert_eq!(cell.get("status").unwrap().as_str(), Some("ok"));
                    for k in ["rel_err_simd", "rel_err_f32"] {
                        let rel = cell.get(k).unwrap().as_f64().unwrap();
                        assert!(rel <= eps, "{ds}/{key}/{m}/{k}: {rel}");
                    }
                    assert!(cell.get("scalar_secs").unwrap().as_f64().unwrap() >= 0.0);
                    let backend = cell.get("backend").unwrap().as_str().unwrap();
                    assert_eq!(backend, detected, "{ds}/{key}/{m}");
                }
                // the ε/4 admission gate in action: the derived f32
                // certificate (≈1e-4 at h = 0.2) fits 1e-2, fails 1e-4
                let engaged = group.get("DFDO").unwrap().get("f32_engaged").unwrap();
                assert_eq!(engaged, &Json::Bool(eps > 1e-3), "{ds}/{key}");
            }
        }
    }

    /// The PR 9 emitter: parseable JSON; the sliced engine answers and
    /// ε-verifies on both hyper sets at the bench's 1e-2 bandwidth
    /// regime, and every dataset row records a verdict for both
    /// engines (an ok cell or the paper's X/∞).
    #[test]
    fn smoke_bench_pr9_emits_parseable_json() {
        let cfg = BenchConfig { n: 150, reps: 1, epsilon: 1e-4, smoke: true };
        let text = run_bench_pr9(&cfg);
        let doc = Json::parse(&text).expect("bench_json PR9 output must parse");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("BENCH_PR9"));
        assert_eq!(doc.get("measured").unwrap(), &Json::Bool(true));
        assert_eq!(doc.get("smoke").unwrap(), &Json::Bool(true));
        for ds in ["galaxy3d", "hyper20", "hyper50"] {
            let d = doc.get("datasets").unwrap().get(ds).unwrap_or_else(|| panic!("{ds}"));
            assert!(d.get("naive_secs").unwrap().as_f64().unwrap() >= 0.0, "{ds}");
            for m in ["sliced", "dito"] {
                let cell = d.get(m).unwrap_or_else(|| panic!("{ds}/{m}"));
                assert!(cell.get("status").unwrap().as_str().is_some(), "{ds}/{m}");
            }
        }
        // the engine's home turf must answer, not refuse
        for ds in ["hyper20", "hyper50"] {
            let cell = doc.get("datasets").unwrap().get(ds).unwrap().get("sliced").unwrap();
            assert_eq!(cell.get("status").unwrap().as_str(), Some("ok"), "{ds}");
            let rel = cell.get("rel_err").unwrap().as_f64().unwrap();
            assert!(rel <= 1e-2 * (1.0 + 1e-9), "{ds}: rel {rel}");
        }
    }

    /// The emitter must produce parseable JSON with every advertised
    /// cell — this is what the CI smoke step exercises release-built.
    #[test]
    fn smoke_bench_emits_parseable_json() {
        let cfg = BenchConfig { n: 200, reps: 1, epsilon: 1e-4, smoke: true };
        let text = run_bench(&cfg);
        let doc = Json::parse(&text).expect("bench_json output must parse");
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("BENCH_PR4"));
        assert_eq!(doc.get("smoke").unwrap(), &Json::Bool(true));
        for ds in ["astro2d", "galaxy3d"] {
            let d = doc.get("datasets").unwrap().get(ds).unwrap_or_else(|| panic!("{ds}"));
            let methods = d.get("methods").unwrap();
            for m in ["Naive", "DFDO", "DITO", "FGT"] {
                let cell = methods.get(m).unwrap_or_else(|| panic!("{ds}/{m}"));
                assert!(cell.get("status").unwrap().as_str().is_some(), "{ds}/{m}");
            }
            // the guaranteed methods always verify at ε
            for m in ["Naive", "DFDO", "DITO"] {
                let rel = methods.get(m).unwrap().get("rel_err_tiled").unwrap();
                assert!(rel.as_f64().unwrap() <= 1e-4, "{ds}/{m}");
            }
        }
    }
}
