//! Certified sum-of-Gaussians (SoG) decompositions of radial kernels.
//!
//! Every non-Gaussian [`Kernel`] family supported here admits a
//! *Gamma-mixture* representation
//!
//! ```text
//! K(r) = (1/Γ(α)) ∫₀^∞ u^(α−1) e^(−u) · exp(−r²/(2·h(u)²)) du
//! ```
//!
//! i.e. the kernel is literally a continuous mixture of Gaussians with
//! a family-specific bandwidth map `h(u)` (a de la Vallée-Poussin-style
//! integral construction; PAPERS.md, arXiv 2010.05192 uses the same
//! reduction). Discretizing the integral with an n-point trapezoid rule
//! in t = ln u yields a finite decomposition
//!
//! ```text
//! S(r) = Σᵢ wᵢ · exp(−r²/(2hᵢ²)),   wᵢ > 0
//! ```
//!
//! which [`SumOfGaussians::fit`] refines — doubling n, then bisecting
//! on the number of terms — until a *certified* sup-norm bound
//! `sup_{r ∈ [0, R]} |K(r) − S(r)| ≤ target` holds. The certificate
//! does not trust quadrature theory: it is computed a posteriori from
//! the one structural fact both curves share — monotonicity. K and S
//! are nonincreasing on [0, ∞) (all weights positive), so on any
//! interval [a, b]
//!
//! ```text
//! sup_{r∈[a,b]} |K(r) − S(r)| ≤ max(K(a) − S(b), S(a) − K(b))
//! ```
//!
//! and adaptive interval refinement drives that bound below the target
//! everywhere on [0, R]. The resulting [`SumOfGaussians::sup_error`] is
//! a first-class number the session charges out of the caller's ε
//! budget via [`crate::errorcontrol::split_epsilon_kernel`].

use super::Kernel;

/// One Gaussian component of a decomposition: `weight · Gauss_{bandwidth}`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct SogTerm {
    /// Mixture weight wᵢ > 0; a fitted decomposition's weights sum to
    /// K(0) = 1.
    pub weight: f64,
    /// Gaussian bandwidth hᵢ > 0 of this component.
    pub bandwidth: f64,
}

/// A fitted decomposition K(r) ≈ Σᵢ wᵢ·exp(−r²/(2hᵢ²)) with a
/// certified sup-norm error bound on the distance range it was fitted
/// for.
#[derive(Clone, Debug)]
pub struct SumOfGaussians {
    /// The family being decomposed.
    pub kernel: Kernel,
    /// The family's scale parameter (σ / ℓ / c — the request's `h`).
    pub scale: f64,
    /// The decomposition is certified on distances r ∈ [0, radius].
    pub radius: f64,
    /// Components in fixed (ascending-u) order; summation order is part
    /// of the determinism contract.
    pub terms: Vec<SogTerm>,
    /// Certified bound on sup_{r ∈ [0, radius]} |K(r) − S(r)|.
    pub sup_error: f64,
}

/// Why a fit failed.
#[derive(Clone, Debug, PartialEq)]
pub enum SogFitError {
    /// No decomposition within [`MAX_TERMS`] terms certified at the
    /// requested target; carries the best certified bound reached.
    TargetUnreachable { target: f64, best: f64 },
}

impl std::fmt::Display for SogFitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SogFitError::TargetUnreachable { target, best } => write!(
                f,
                "no decomposition of at most {MAX_TERMS} terms certifies at {target:.3e} \
                 (best bound {best:.3e})"
            ),
        }
    }
}

/// Smallest term count tried by the doubling phase.
const MIN_TERMS: usize = 16;
/// Largest term count tried before giving up.
pub const MAX_TERMS: usize = 1024;
/// Midpoint-evaluation budget of one certification pass; refinement
/// past this returns the (sound, possibly loose) interval bounds as-is.
const MAX_CERTIFY_EVALS: usize = 400_000;
/// Slop added to every certified bound to absorb the certificate's own
/// f64 rounding (the interval argument is exact for the real-valued
/// functions; evaluations differ from them by a few ulps).
const CERT_SLOP: f64 = 1e-12;

/// The Gamma-mixture parameters of one family: the mixing exponent α
/// and Γ(α) (closed forms only — α ∈ {1/2, 3/2, 5/2}).
fn mixture(kernel: Kernel) -> (f64, f64) {
    let sqrt_pi = std::f64::consts::PI.sqrt();
    match kernel {
        Kernel::Laplace | Kernel::InvMultiquadric => (0.5, sqrt_pi),
        Kernel::Matern32 => (1.5, 0.5 * sqrt_pi),
        Kernel::Matern52 => (2.5, 0.75 * sqrt_pi),
        // lint: allow(no-panic): the session routes the Gaussian kernel past the SoG layer entirely
        Kernel::Gaussian => unreachable!("the Gaussian needs no decomposition"),
    }
}

/// The family's bandwidth map h(u): matching exp(−r²/(2h(u)²)) to the
/// Gaussian factor of the family's Gamma-mixture identity.
fn bandwidth_of(kernel: Kernel, scale: f64, u: f64) -> f64 {
    match kernel {
        // e^(−x) = (1/√π) ∫ u^(−1/2) e^(−u) e^(−x²/(4u)) du, x = r/σ
        Kernel::Laplace => scale * (2.0 * u).sqrt(),
        // Matérn-ν(r) = (1/Γ(ν)) ∫ u^(ν−1) e^(−u) e^(−νr²/(2uℓ²)) du
        Kernel::Matern32 => scale * (2.0 * u / 3.0).sqrt(),
        Kernel::Matern52 => scale * (2.0 * u / 5.0).sqrt(),
        // (1+x²)^(−1/2) = (1/√π) ∫ u^(−1/2) e^(−u) e^(−u·x²) du, x = r/c
        Kernel::InvMultiquadric => scale / (2.0 * u).sqrt(),
        Kernel::Gaussian => scale,
    }
}

/// n-point trapezoid discretization of the Gamma mixture in t = ln u,
/// truncated so each tail carries at most `target/8` of the mixing
/// mass, then renormalized to S(0) = K(0) = 1. Any inexactness the
/// truncation, pruning, or renormalization introduces is *measured* by
/// the certificate, not accounted analytically.
fn build(kernel: Kernel, scale: f64, n: usize, target: f64) -> Vec<SogTerm> {
    let (alpha, gamma_alpha) = mixture(kernel);
    let tail = (target / 8.0).min(1e-2);
    // Lower truncation: ∫₀^{u_lo} u^(α−1)e^(−u) du / Γ(α) ≤ u_lo^α/(α·Γ(α)).
    let u_lo = (tail * alpha * gamma_alpha).powf(1.0 / alpha).min(0.5);
    // Upper truncation: for U ≥ 2α+3, ∫_U^∞ u^(α−1)e^(−u) du ≤ 2·U^(α−1)e^(−U).
    let mut u_hi = 2.0 * alpha + 3.0;
    while 2.0 * u_hi.powf(alpha - 1.0) * (-u_hi).exp() / gamma_alpha > tail {
        u_hi *= 1.1;
    }
    let t_lo = u_lo.ln();
    let t_hi = u_hi.ln();
    let dt = (t_hi - t_lo) / (n as f64 - 1.0);
    let mut terms = Vec::with_capacity(n);
    for i in 0..n {
        let u = (t_lo + dt * i as f64).exp();
        // substitution u = e^t: the integrand becomes u^α e^(−u)/Γ(α)
        let mut w = u.powf(alpha) * (-u).exp() / gamma_alpha * dt;
        if i == 0 || i == n - 1 {
            w *= 0.5;
        }
        let bw = bandwidth_of(kernel, scale, u);
        // prune negligible terms: total dropped mass ≤ target/8
        if w > target / (8.0 * n as f64) && bw.is_finite() && bw > 0.0 {
            terms.push(SogTerm { weight: w, bandwidth: bw });
        }
    }
    let sum: f64 = terms.iter().map(|t| t.weight).sum();
    for t in &mut terms {
        t.weight /= sum;
    }
    terms
}

/// S(r) = Σᵢ wᵢ·exp(−r²/(2hᵢ²)), in fixed term order.
fn sog_value(terms: &[SogTerm], r: f64) -> f64 {
    let mut acc = 0.0;
    for t in terms {
        let x = r / t.bandwidth;
        acc += t.weight * (-0.5 * x * x).exp();
    }
    acc
}

/// A certified upper bound on sup_{r ∈ [0, radius]} |K(r) − S(r)|, by
/// adaptive refinement of the monotone-interval bound
/// max(K(a)−S(b), S(a)−K(b)). Returns +∞ as soon as a *pointwise*
/// error above the target is observed (refinement cannot repair that);
/// the returned value is a genuine sup bound whenever it is ≤ target.
fn certify(kernel: Kernel, scale: f64, terms: &[SogTerm], radius: f64, target: f64) -> f64 {
    struct Iv {
        a: f64,
        ka: f64,
        sa: f64,
        b: f64,
        kb: f64,
        sb: f64,
    }
    // Seed grid: 0 plus radius·2^(−k) — geometric coverage of the
    // near-origin region where both curves vary fastest.
    let mut pts = vec![0.0];
    for k in (0..=48).rev() {
        pts.push(radius * (0.5f64).powi(k));
    }
    let vals: Vec<(f64, f64)> =
        pts.iter().map(|&r| (kernel.eval(scale, r), sog_value(terms, r))).collect();
    for &(k, s) in &vals {
        if (k - s).abs() > target {
            return f64::INFINITY;
        }
    }
    let mut stack: Vec<Iv> = Vec::with_capacity(256);
    for i in 0..pts.len() - 1 {
        stack.push(Iv {
            a: pts[i],
            ka: vals[i].0,
            sa: vals[i].1,
            b: pts[i + 1],
            kb: vals[i + 1].0,
            sb: vals[i + 1].1,
        });
    }
    let mut worst: f64 = 0.0;
    let mut evals = 0usize;
    while let Some(iv) = stack.pop() {
        // both K and S nonincreasing ⇒ this dominates sup|K−S| on [a,b]
        let bound = (iv.ka - iv.sb).max(iv.sa - iv.kb);
        if bound <= target {
            worst = worst.max(bound);
            continue;
        }
        if evals >= MAX_CERTIFY_EVALS || (iv.b - iv.a) <= radius * 1e-14 {
            // out of budget / width floor: keep the sound loose bound
            worst = worst.max(bound);
            continue;
        }
        let m = 0.5 * (iv.a + iv.b);
        let km = kernel.eval(scale, m);
        let sm = sog_value(terms, m);
        evals += 1;
        if (km - sm).abs() > target {
            return f64::INFINITY;
        }
        stack.push(Iv { a: iv.a, ka: iv.ka, sa: iv.sa, b: m, kb: km, sb: sm });
        stack.push(Iv { a: m, ka: km, sa: sm, b: iv.b, kb: iv.kb, sb: iv.sb });
    }
    worst
}

impl SumOfGaussians {
    /// Fit a decomposition of `kernel` at `scale`, certified on
    /// r ∈ [0, radius], with sup-norm error at most `target`: double
    /// the term count (from 16) until a build certifies, then
    /// bisect on the number of terms for the smallest certifying build
    /// in the bracketed octave. The Gaussian family returns its trivial
    /// exact one-term decomposition.
    pub fn fit(
        kernel: Kernel,
        scale: f64,
        radius: f64,
        target: f64,
    ) -> Result<SumOfGaussians, SogFitError> {
        assert!(scale > 0.0 && scale.is_finite(), "kernel scale must be positive");
        assert!(target > 0.0 && target.is_finite(), "error target must be positive");
        assert!(radius >= 0.0 && radius.is_finite(), "radius must be nonnegative");
        // degenerate extents (single-point data) still get a real range
        let radius = if radius > 0.0 { radius } else { scale };
        if kernel.is_gaussian() {
            return Ok(SumOfGaussians {
                kernel,
                scale,
                radius,
                terms: vec![SogTerm { weight: 1.0, bandwidth: scale }],
                sup_error: 0.0,
            });
        }
        // doubling phase: bracket the smallest certifying octave
        let mut n = MIN_TERMS;
        let mut best = f64::INFINITY;
        let (mut hi_terms, mut hi_err) = loop {
            let terms = build(kernel, scale, n, target);
            let err = certify(kernel, scale, &terms, radius, target) + CERT_SLOP;
            best = best.min(err);
            if err <= target {
                break (terms, err);
            }
            if n >= MAX_TERMS {
                return Err(SogFitError::TargetUnreachable { target, best });
            }
            n *= 2;
        };
        // bisection phase: smallest certifying count in (n/2, n]
        let (mut lo, mut hi) = (n / 2, n);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            let terms = build(kernel, scale, mid, target);
            let err = certify(kernel, scale, &terms, radius, target) + CERT_SLOP;
            if err <= target {
                hi = mid;
                hi_terms = terms;
                hi_err = err;
            } else {
                lo = mid;
            }
        }
        Ok(SumOfGaussians { kernel, scale, radius, terms: hi_terms, sup_error: hi_err })
    }

    /// S(r), summed in the fixed component order.
    pub fn eval(&self, r: f64) -> f64 {
        sog_value(&self.terms, r)
    }

    /// Σᵢ wᵢ (≈ 1 for fitted decompositions; exactly 1 for Gaussian).
    pub fn weight_sum(&self) -> f64 {
        self.terms.iter().map(|t| t.weight).sum()
    }

    /// Number of Gaussian components.
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SOG_FAMILIES: [Kernel; 4] =
        [Kernel::Laplace, Kernel::Matern32, Kernel::Matern52, Kernel::InvMultiquadric];

    /// Dense empirical check that the certificate is honest: the
    /// observed error on a fine uniform grid never exceeds `sup_error`.
    fn observed_error(s: &SumOfGaussians) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..=20_000 {
            let r = s.radius * i as f64 / 20_000.0;
            worst = worst.max((s.kernel.eval(s.scale, r) - s.eval(r)).abs());
        }
        worst
    }

    #[test]
    fn every_family_fits_and_certifies() {
        for kernel in SOG_FAMILIES {
            for target in [1e-3, 2.5e-5] {
                let s = SumOfGaussians::fit(kernel, 0.3, 4.0, target)
                    .unwrap_or_else(|e| panic!("{kernel} @ {target}: {e}"));
                assert!(s.sup_error <= target, "{kernel}: bound {:.2e}", s.sup_error);
                assert!(!s.terms.is_empty() && s.terms.len() <= MAX_TERMS);
                let obs = observed_error(&s);
                assert!(
                    obs <= s.sup_error,
                    "{kernel} @ {target}: observed {obs:.3e} > certified {:.3e}",
                    s.sup_error
                );
            }
        }
    }

    #[test]
    fn weights_positive_and_sum_to_one() {
        for kernel in SOG_FAMILIES {
            let s = SumOfGaussians::fit(kernel, 1.0, 10.0, 1e-3).unwrap();
            assert!(s.terms.iter().all(|t| t.weight > 0.0 && t.bandwidth > 0.0));
            assert!((s.weight_sum() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn tighter_targets_need_more_terms() {
        let coarse = SumOfGaussians::fit(Kernel::Laplace, 1.0, 8.0, 1e-2).unwrap();
        let fine = SumOfGaussians::fit(Kernel::Laplace, 1.0, 8.0, 1e-5).unwrap();
        assert!(
            fine.num_terms() > coarse.num_terms(),
            "{} vs {}",
            fine.num_terms(),
            coarse.num_terms()
        );
    }

    #[test]
    fn gaussian_decomposition_is_trivial_and_exact() {
        let s = SumOfGaussians::fit(Kernel::Gaussian, 0.7, 5.0, 1e-9).unwrap();
        assert_eq!(s.num_terms(), 1);
        assert_eq!(s.sup_error, 0.0);
        assert_eq!(s.terms[0].bandwidth, 0.7);
        assert_eq!(s.terms[0].weight, 1.0);
    }

    #[test]
    fn exact_at_zero_distance() {
        // renormalization pins S(0) = K(0) = 1 up to summation rounding
        for kernel in SOG_FAMILIES {
            let s = SumOfGaussians::fit(kernel, 0.5, 6.0, 1e-3).unwrap();
            assert!((s.eval(0.0) - 1.0).abs() < 1e-12, "{kernel}: S(0) = {}", s.eval(0.0));
        }
    }

    #[test]
    fn scale_covariance() {
        // fitting at scale c is the unit fit with bandwidths scaled by c
        let unit = SumOfGaussians::fit(Kernel::Matern32, 1.0, 8.0, 1e-3).unwrap();
        let scaled = SumOfGaussians::fit(Kernel::Matern32, 2.0, 16.0, 1e-3).unwrap();
        assert_eq!(unit.num_terms(), scaled.num_terms());
        for (a, b) in unit.terms.iter().zip(&scaled.terms) {
            assert!((a.weight - b.weight).abs() < 1e-12);
            assert!((2.0 * a.bandwidth - b.bandwidth).abs() < 1e-9 * b.bandwidth);
        }
    }

    #[test]
    fn unreachable_target_reports_best_bound() {
        // an absurd target (below f64 resolution of the certificate)
        let err = SumOfGaussians::fit(Kernel::Laplace, 1.0, 8.0, 1e-14).unwrap_err();
        let SogFitError::TargetUnreachable { target, best } = err;
        assert_eq!(target, 1e-14);
        assert!(best > 1e-14);
    }

    #[test]
    fn zero_radius_falls_back_to_scale() {
        let s = SumOfGaussians::fit(Kernel::Laplace, 0.4, 0.0, 1e-3).unwrap();
        assert_eq!(s.radius, 0.4);
        assert!(s.sup_error <= 1e-3);
    }
}
