//! The kernel layer: the natively-evaluated Gaussian kernel
//! K(δ) = exp(−δ²/(2h²)) with its bandwidth plumbing, the [`Kernel`]
//! enum naming every radial family a [`crate::api::Session`] answers,
//! and the certified sum-of-Gaussians decompositions ([`sog`]) that
//! reduce the non-Gaussian families to Gaussian bandwidth batches.

pub mod sog;

pub use sog::{SogFitError, SogTerm, SumOfGaussians};

use crate::geometry::Matrix;

/// √3 and √5, for the Matérn closed forms (f64::sqrt is not const).
const SQRT_3: f64 = 1.732_050_807_568_877_2;
const SQRT_5: f64 = 2.236_067_977_499_79;

/// The radial kernel family of one summation request.
///
/// [`Kernel::Gaussian`] (the default) is evaluated natively by every
/// engine — that path is bit-for-bit unchanged by this enum's
/// existence. The other families are *sum-of-Gaussians* (SoG) kernels:
/// the session fits a certified decomposition
/// K(r) ≈ Σᵢ wᵢ·exp(−r²/(2hᵢ²)) (see [`sog`]) and answers through the
/// existing Gaussian machinery, one pooled component request per term,
/// with the decomposition's sup-norm error charged out of the caller's
/// ε budget ([`crate::errorcontrol::split_epsilon_kernel`]).
///
/// Every family is normalized to K(0) = 1 and parameterized by one
/// positive scale (reusing the request's `h` slot): the Gaussian
/// bandwidth h, the Laplace decay σ, the Matérn lengthscale ℓ, or the
/// inverse-multiquadric offset c.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// exp(−r²/(2h²)) — the paper's kernel, evaluated natively.
    #[default]
    Gaussian,
    /// Laplace / exponential kernel exp(−r/σ) (= Matérn ν = 1/2).
    Laplace,
    /// Matérn ν = 3/2: (1+z)·e^(−z) with z = √3·r/ℓ.
    Matern32,
    /// Matérn ν = 5/2: (1+z+z²/3)·e^(−z) with z = √5·r/ℓ.
    Matern52,
    /// Inverse multiquadric 1/√(1+(r/c)²).
    InvMultiquadric,
}

impl Kernel {
    /// Every supported family, Gaussian first.
    pub const ALL: [Kernel; 5] = [
        Kernel::Gaussian,
        Kernel::Laplace,
        Kernel::Matern32,
        Kernel::Matern52,
        Kernel::InvMultiquadric,
    ];

    /// The canonical config/CLI tokens, for parse-error listings.
    pub const VALID_NAMES: &'static str = "gaussian, laplace, matern32, matern52, imq";

    /// Canonical config/CLI token ("gaussian", "laplace", …).
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Gaussian => "gaussian",
            Kernel::Laplace => "laplace",
            Kernel::Matern32 => "matern32",
            Kernel::Matern52 => "matern52",
            Kernel::InvMultiquadric => "imq",
        }
    }

    /// Case-insensitive parse of [`name`](Kernel::name)-style tokens
    /// (with the common aliases).
    pub fn parse(s: &str) -> Option<Kernel> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "gauss" => Some(Kernel::Gaussian),
            "laplace" | "exponential" => Some(Kernel::Laplace),
            "matern32" => Some(Kernel::Matern32),
            "matern52" => Some(Kernel::Matern52),
            "imq" | "invmultiquadric" | "inverse-multiquadric" => Some(Kernel::InvMultiquadric),
            _ => None,
        }
    }

    /// Whether this is the natively-evaluated family (no decomposition).
    pub fn is_gaussian(&self) -> bool {
        matches!(self, Kernel::Gaussian)
    }

    /// K(r) at distance `r ≥ 0` with the family's scale parameter.
    /// Every family is monotone nonincreasing in `r` with K(0) = 1 —
    /// the property the SoG certification leans on.
    pub fn eval(&self, scale: f64, r: f64) -> f64 {
        debug_assert!(scale > 0.0 && r >= 0.0);
        match self {
            Kernel::Gaussian => {
                let x = r / scale;
                (-0.5 * x * x).exp()
            }
            Kernel::Laplace => (-r / scale).exp(),
            Kernel::Matern32 => {
                let z = SQRT_3 * r / scale;
                (1.0 + z) * (-z).exp()
            }
            Kernel::Matern52 => {
                let z = SQRT_5 * r / scale;
                (1.0 + z + z * z / 3.0) * (-z).exp()
            }
            Kernel::InvMultiquadric => {
                let x = r / scale;
                1.0 / (1.0 + x * x).sqrt()
            }
        }
    }

    /// Direct O(N·M) summation of the *true* (non-decomposed) kernel —
    /// the exhaustive reference every SoG answer's `ε·W` guarantee is
    /// verified against. Accumulation order is fixed (ascending
    /// reference index), so results are deterministic.
    pub fn direct_sums(
        &self,
        scale: f64,
        queries: &Matrix,
        references: &Matrix,
        weights: Option<&[f64]>,
    ) -> Vec<f64> {
        assert_eq!(queries.cols(), references.cols(), "dimension mismatch");
        if let Some(w) = weights {
            assert_eq!(w.len(), references.rows());
        }
        let dim = queries.cols();
        let mut out = vec![0.0; queries.rows()];
        for (i, slot) in out.iter_mut().enumerate() {
            let q = queries.row(i);
            let mut acc = 0.0;
            for j in 0..references.rows() {
                let r = references.row(j);
                let mut sq = 0.0;
                for d in 0..dim {
                    let t = q[d] - r[d];
                    sq += t * t;
                }
                let w = weights.map_or(1.0, |w| w[j]);
                acc += w * self.eval(scale, sq.sqrt());
            }
            *slot = acc;
        }
        out
    }
}

impl std::fmt::Display for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// An isotropic Gaussian kernel with bandwidth `h`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct GaussianKernel {
    h: f64,
    /// Precomputed −1/(2h²).
    neg_inv_2h2: f64,
}

impl GaussianKernel {
    pub fn new(h: f64) -> Self {
        assert!(h > 0.0 && h.is_finite(), "bandwidth must be positive");
        GaussianKernel { h, neg_inv_2h2: -0.5 / (h * h) }
    }

    #[inline]
    pub fn bandwidth(&self) -> f64 {
        self.h
    }

    /// The series scale c = √(2h²) = √2·h; expansions use (x−c₀)/c.
    #[inline]
    pub fn series_scale(&self) -> f64 {
        std::f64::consts::SQRT_2 * self.h
    }

    /// K from a squared distance — the hot-path form (avoids the sqrt).
    #[inline]
    pub fn eval_sq(&self, sqdist: f64) -> f64 {
        (sqdist * self.neg_inv_2h2).exp()
    }

    /// The precomputed exponent scale −1/(2h²) — what the tiled base
    /// case multiplies squared distances by before the fused
    /// [`crate::compute::fastexp::exp_block`] pass.
    #[inline]
    pub fn neg_inv_two_h2(&self) -> f64 {
        self.neg_inv_2h2
    }

    /// K from a distance.
    #[inline]
    pub fn eval(&self, dist: f64) -> f64 {
        self.eval_sq(dist * dist)
    }

    /// The factor e^(−δ²/(4h²)) appearing in the Lemma 4–6 bounds.
    #[inline]
    pub fn bound_decay_sq(&self, sqdist: f64) -> f64 {
        (-sqdist / (4.0 * self.h * self.h)).exp()
    }

    /// Multivariate density normalization (2πh²)^(−D/2) for KDE.
    pub fn norm_const(&self, dim: usize) -> f64 {
        (2.0 * std::f64::consts::PI * self.h * self.h).powf(-(dim as f64) / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_at_zero_distance() {
        let k = GaussianKernel::new(0.3);
        assert_eq!(k.eval(0.0), 1.0);
        assert_eq!(k.eval_sq(0.0), 1.0);
    }

    #[test]
    fn known_value() {
        let k = GaussianKernel::new(1.0);
        assert!((k.eval(1.0) - (-0.5f64).exp()).abs() < 1e-15);
        assert!((k.eval_sq(4.0) - (-2.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn monotone_decreasing() {
        let k = GaussianKernel::new(0.5);
        let mut prev = k.eval(0.0);
        for i in 1..100 {
            let v = k.eval(i as f64 * 0.05);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn bandwidth_scaling_identity() {
        // K_h(δ) = K_1(δ/h)
        let k1 = GaussianKernel::new(1.0);
        let kh = GaussianKernel::new(2.5);
        assert!((kh.eval(5.0) - k1.eval(2.0)).abs() < 1e-15);
    }

    #[test]
    fn bound_decay_is_sqrt_of_kernel() {
        // e^(−δ²/4h²) = K(δ)^(1/2)
        let k = GaussianKernel::new(0.7);
        let d2 = 1.3;
        assert!((k.bound_decay_sq(d2) - k.eval_sq(d2).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn series_scale() {
        let k = GaussianKernel::new(3.0);
        assert!((k.series_scale() - 3.0 * 2f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn norm_const_1d_matches_formula() {
        let k = GaussianKernel::new(2.0);
        let expect = 1.0 / (2.0 * std::f64::consts::PI * 4.0).sqrt();
        assert!((k.norm_const(1) - expect).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        GaussianKernel::new(0.0);
    }
}
