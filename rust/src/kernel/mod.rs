//! The Gaussian kernel K(δ) = exp(−δ²/(2h²)) and bandwidth plumbing.

/// An isotropic Gaussian kernel with bandwidth `h`.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct GaussianKernel {
    h: f64,
    /// Precomputed −1/(2h²).
    neg_inv_2h2: f64,
}

impl GaussianKernel {
    pub fn new(h: f64) -> Self {
        assert!(h > 0.0 && h.is_finite(), "bandwidth must be positive");
        GaussianKernel { h, neg_inv_2h2: -0.5 / (h * h) }
    }

    #[inline]
    pub fn bandwidth(&self) -> f64 {
        self.h
    }

    /// The series scale c = √(2h²) = √2·h; expansions use (x−c₀)/c.
    #[inline]
    pub fn series_scale(&self) -> f64 {
        std::f64::consts::SQRT_2 * self.h
    }

    /// K from a squared distance — the hot-path form (avoids the sqrt).
    #[inline]
    pub fn eval_sq(&self, sqdist: f64) -> f64 {
        (sqdist * self.neg_inv_2h2).exp()
    }

    /// The precomputed exponent scale −1/(2h²) — what the tiled base
    /// case multiplies squared distances by before the fused
    /// [`crate::compute::fastexp::exp_block`] pass.
    #[inline]
    pub fn neg_inv_two_h2(&self) -> f64 {
        self.neg_inv_2h2
    }

    /// K from a distance.
    #[inline]
    pub fn eval(&self, dist: f64) -> f64 {
        self.eval_sq(dist * dist)
    }

    /// The factor e^(−δ²/(4h²)) appearing in the Lemma 4–6 bounds.
    #[inline]
    pub fn bound_decay_sq(&self, sqdist: f64) -> f64 {
        (-sqdist / (4.0 * self.h * self.h)).exp()
    }

    /// Multivariate density normalization (2πh²)^(−D/2) for KDE.
    pub fn norm_const(&self, dim: usize) -> f64 {
        (2.0 * std::f64::consts::PI * self.h * self.h).powf(-(dim as f64) / 2.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_at_zero_distance() {
        let k = GaussianKernel::new(0.3);
        assert_eq!(k.eval(0.0), 1.0);
        assert_eq!(k.eval_sq(0.0), 1.0);
    }

    #[test]
    fn known_value() {
        let k = GaussianKernel::new(1.0);
        assert!((k.eval(1.0) - (-0.5f64).exp()).abs() < 1e-15);
        assert!((k.eval_sq(4.0) - (-2.0f64).exp()).abs() < 1e-15);
    }

    #[test]
    fn monotone_decreasing() {
        let k = GaussianKernel::new(0.5);
        let mut prev = k.eval(0.0);
        for i in 1..100 {
            let v = k.eval(i as f64 * 0.05);
            assert!(v < prev);
            prev = v;
        }
    }

    #[test]
    fn bandwidth_scaling_identity() {
        // K_h(δ) = K_1(δ/h)
        let k1 = GaussianKernel::new(1.0);
        let kh = GaussianKernel::new(2.5);
        assert!((kh.eval(5.0) - k1.eval(2.0)).abs() < 1e-15);
    }

    #[test]
    fn bound_decay_is_sqrt_of_kernel() {
        // e^(−δ²/4h²) = K(δ)^(1/2)
        let k = GaussianKernel::new(0.7);
        let d2 = 1.3;
        assert!((k.bound_decay_sq(d2) - k.eval_sq(d2).sqrt()).abs() < 1e-15);
    }

    #[test]
    fn series_scale() {
        let k = GaussianKernel::new(3.0);
        assert!((k.series_scale() - 3.0 * 2f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn norm_const_1d_matches_formula() {
        let k = GaussianKernel::new(2.0);
        let expect = 1.0 / (2.0 * std::f64::consts::PI * 4.0).sqrt();
        assert!((k.norm_const(1) - expect).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn zero_bandwidth_rejected() {
        GaussianKernel::new(0.0);
    }
}
