//! Row-major `n × d` matrix of `f64` — the point-set container used
//! everywhere. Rows are points; `row(i)` is a borrowed `&[f64]`.

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Build from a flat row-major buffer. Panics when the buffer length
    /// is not `rows*cols`.
    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { data, rows, cols }
    }

    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a slice of rows (each of equal length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty(), "no rows");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { data, rows: rows.len(), cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major view.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Gather a subset of rows (by index) into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Per-column minimum.
    pub fn col_min(&self) -> Vec<f64> {
        let mut m = vec![f64::INFINITY; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            for j in 0..self.cols {
                if r[j] < m[j] {
                    m[j] = r[j];
                }
            }
        }
        m
    }

    /// Per-column maximum.
    pub fn col_max(&self) -> Vec<f64> {
        let mut m = vec![f64::NEG_INFINITY; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            for j in 0..self.cols {
                if r[j] > m[j] {
                    m[j] = r[j];
                }
            }
        }
        m
    }

    /// Per-column mean.
    pub fn col_mean(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            for j in 0..self.cols {
                m[j] += r[j];
            }
        }
        for v in &mut m {
            *v /= self.rows as f64;
        }
        m
    }

    /// Per-column standard deviation (population).
    pub fn col_std(&self) -> Vec<f64> {
        let mean = self.col_mean();
        let mut v = vec![0.0; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            for j in 0..self.cols {
                let d = r[j] - mean[j];
                v[j] += d * d;
            }
        }
        v.iter().map(|x| (x / self.rows as f64).sqrt()).collect()
    }

    /// Iterator over rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> {
        (0..self.rows).map(move |i| self.row(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Matrix {
        Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2)
    }

    #[test]
    fn shape_and_access() {
        let m = m();
        assert_eq!((m.rows(), m.cols()), (3, 2));
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.get(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Matrix::from_vec(vec![1.0; 5], 2, 3);
    }

    #[test]
    fn from_rows_roundtrip() {
        let m2 = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m2, m());
    }

    #[test]
    fn select_rows_gathers() {
        let s = m().select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn column_stats() {
        let m = m();
        assert_eq!(m.col_min(), vec![1.0, 2.0]);
        assert_eq!(m.col_max(), vec![5.0, 6.0]);
        assert_eq!(m.col_mean(), vec![3.0, 4.0]);
        let std = m.col_std();
        assert!((std[0] - (8.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn set_and_row_mut() {
        let mut m = m();
        m.set(0, 0, 9.0);
        m.row_mut(1)[1] = -1.0;
        assert_eq!(m.get(0, 0), 9.0);
        assert_eq!(m.get(1, 1), -1.0);
    }
}
