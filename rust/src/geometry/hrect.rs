//! Bounding hyper-rectangles with the node–node distance bounds
//! δ_QR^min / δ_QR^max the dual-tree pruning rules are built on.

use super::Matrix;

/// Axis-aligned bounding box in D dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct HRect {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl HRect {
    /// Construct from explicit bounds. Panics if `lo[i] > hi[i]`.
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> Self {
        assert_eq!(lo.len(), hi.len());
        for i in 0..lo.len() {
            assert!(lo[i] <= hi[i], "inverted bounds in dim {i}");
        }
        HRect { lo, hi }
    }

    /// Tight bounding box of a set of rows of `m` given by `idx`.
    pub fn from_points(m: &Matrix, idx: &[usize]) -> Self {
        assert!(!idx.is_empty());
        let d = m.cols();
        let mut lo = vec![f64::INFINITY; d];
        let mut hi = vec![f64::NEG_INFINITY; d];
        for &i in idx {
            let r = m.row(i);
            for j in 0..d {
                if r[j] < lo[j] {
                    lo[j] = r[j];
                }
                if r[j] > hi[j] {
                    hi[j] = r[j];
                }
            }
        }
        HRect { lo, hi }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    #[inline]
    pub fn lo(&self) -> &[f64] {
        &self.lo
    }

    #[inline]
    pub fn hi(&self) -> &[f64] {
        &self.hi
    }

    /// Box center.
    pub fn center(&self) -> Vec<f64> {
        (0..self.dim()).map(|i| 0.5 * (self.lo[i] + self.hi[i])).collect()
    }

    /// Side length in each dimension.
    pub fn widths(&self) -> Vec<f64> {
        (0..self.dim()).map(|i| self.hi[i] - self.lo[i]).collect()
    }

    /// Index of the widest dimension (split axis for kd-trees).
    pub fn widest_dim(&self) -> usize {
        let mut best = 0;
        let mut bw = f64::NEG_INFINITY;
        for i in 0..self.dim() {
            let w = self.hi[i] - self.lo[i];
            if w > bw {
                bw = w;
                best = i;
            }
        }
        best
    }

    /// Does the box contain point `p` (closed)?
    pub fn contains(&self, p: &[f64]) -> bool {
        (0..self.dim()).all(|i| self.lo[i] <= p[i] && p[i] <= self.hi[i])
    }

    /// Smallest box containing both.
    pub fn union(&self, other: &HRect) -> HRect {
        let d = self.dim();
        assert_eq!(d, other.dim());
        HRect {
            lo: (0..d).map(|i| self.lo[i].min(other.lo[i])).collect(),
            hi: (0..d).map(|i| self.hi[i].max(other.hi[i])).collect(),
        }
    }

    /// Squared minimum distance from a point to the box (0 if inside).
    pub fn min_sqdist_point(&self, p: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..self.dim() {
            let d = if p[i] < self.lo[i] {
                self.lo[i] - p[i]
            } else if p[i] > self.hi[i] {
                p[i] - self.hi[i]
            } else {
                0.0
            };
            s += d * d;
        }
        s
    }

    /// Squared maximum distance from a point to the box.
    pub fn max_sqdist_point(&self, p: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..self.dim() {
            let d = (p[i] - self.lo[i]).abs().max((p[i] - self.hi[i]).abs());
            s += d * d;
        }
        s
    }

    /// Squared minimum distance between two boxes — the paper's
    /// (δ_QR^min)². Zero when they overlap.
    pub fn min_sqdist(&self, other: &HRect) -> f64 {
        let mut s = 0.0;
        for i in 0..self.dim() {
            let d = if other.hi[i] < self.lo[i] {
                self.lo[i] - other.hi[i]
            } else if self.hi[i] < other.lo[i] {
                other.lo[i] - self.hi[i]
            } else {
                0.0
            };
            s += d * d;
        }
        s
    }

    /// Squared maximum distance between two boxes — the paper's
    /// (δ_QR^max)².
    pub fn max_sqdist(&self, other: &HRect) -> f64 {
        let mut s = 0.0;
        for i in 0..self.dim() {
            let d = (self.hi[i] - other.lo[i]).abs().max((other.hi[i] - self.lo[i]).abs());
            s += d * d;
        }
        s
    }

    /// Maximum L∞ distance from `c` to any corner of the box — used for
    /// the paper's node radius r = max ‖x − c‖∞.
    pub fn max_linf_point(&self, c: &[f64]) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.dim() {
            m = m.max((c[i] - self.lo[i]).abs().max((c[i] - self.hi[i]).abs()));
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::sqdist;
    use crate::util::Pcg32;

    fn unit2() -> HRect {
        HRect::new(vec![0.0, 0.0], vec![1.0, 1.0])
    }

    #[test]
    fn from_points_is_tight() {
        let m = Matrix::from_rows(&[vec![0.0, 5.0], vec![2.0, 1.0], vec![1.0, 3.0]]);
        let r = HRect::from_points(&m, &[0, 1, 2]);
        assert_eq!(r.lo(), &[0.0, 1.0]);
        assert_eq!(r.hi(), &[2.0, 5.0]);
        assert!(r.contains(m.row(2)));
    }

    #[test]
    fn point_distance_inside_is_zero() {
        let r = unit2();
        assert_eq!(r.min_sqdist_point(&[0.5, 0.5]), 0.0);
        assert!(r.max_sqdist_point(&[0.5, 0.5]) > 0.0);
    }

    #[test]
    fn point_distance_outside() {
        let r = unit2();
        assert_eq!(r.min_sqdist_point(&[2.0, 0.5]), 1.0);
        // farthest corner from (2, 0.5) is (0,0) or (0,1): dist² = 4 + .25
        assert_eq!(r.max_sqdist_point(&[2.0, 0.5]), 4.25);
    }

    #[test]
    fn box_box_disjoint() {
        let a = unit2();
        let b = HRect::new(vec![3.0, 0.0], vec![4.0, 1.0]);
        assert_eq!(a.min_sqdist(&b), 4.0);
        // farthest pair: (0, 0)..(4, 1) or (0,1)..(4,0) → 16 + 1
        assert_eq!(a.max_sqdist(&b), 17.0);
    }

    #[test]
    fn box_box_overlap_min_zero() {
        let a = unit2();
        let b = HRect::new(vec![0.5, 0.5], vec![2.0, 2.0]);
        assert_eq!(a.min_sqdist(&b), 0.0);
        assert!(a.max_sqdist(&b) >= 0.0);
    }

    #[test]
    fn union_contains_both() {
        let a = unit2();
        let b = HRect::new(vec![2.0, -1.0], vec![3.0, 0.5]);
        let u = a.union(&b);
        assert_eq!(u.lo(), &[0.0, -1.0]);
        assert_eq!(u.hi(), &[3.0, 1.0]);
    }

    #[test]
    fn widest_dim_and_center() {
        let r = HRect::new(vec![0.0, 0.0], vec![1.0, 3.0]);
        assert_eq!(r.widest_dim(), 1);
        assert_eq!(r.center(), vec![0.5, 1.5]);
        assert_eq!(r.widths(), vec![1.0, 3.0]);
    }

    #[test]
    fn max_linf_point_corner() {
        let r = unit2();
        assert_eq!(r.max_linf_point(&[0.25, 0.5]), 0.75);
    }

    /// Randomized check: for all point pairs drawn from two boxes,
    /// min_sqdist ≤ d² ≤ max_sqdist. This is the correctness contract the
    /// pruning rules rely on.
    #[test]
    fn distance_bounds_bracket_all_pairs() {
        let mut rng = Pcg32::new(11);
        for _ in 0..50 {
            let d = 1 + rng.below(4);
            let mk = |rng: &mut Pcg32| {
                let a: Vec<f64> = (0..d).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
                let b: Vec<f64> = (0..d).map(|i| a[i] + rng.uniform()).collect();
                HRect::new(a, b)
            };
            let q = mk(&mut rng);
            let r = mk(&mut rng);
            for _ in 0..20 {
                let pq: Vec<f64> =
                    (0..d).map(|i| rng.uniform_in(q.lo()[i], q.hi()[i])).collect();
                let pr: Vec<f64> =
                    (0..d).map(|i| rng.uniform_in(r.lo()[i], r.hi()[i])).collect();
                let s = sqdist(&pq, &pr);
                assert!(q.min_sqdist(&r) <= s + 1e-12);
                assert!(s <= q.max_sqdist(&r) + 1e-12);
            }
        }
    }
}
