//! Bounding spheres. The paper uses sphere-rectangle (SR) trees
//! (Katayama & Satoh 1997): each node keeps *both* a bounding rectangle
//! and a bounding sphere, and distance bounds take the tighter of the
//! two.

use super::{dist, Matrix};

/// A bounding sphere: center + radius.
#[derive(Clone, Debug, PartialEq)]
pub struct Sphere {
    center: Vec<f64>,
    radius: f64,
}

impl Sphere {
    pub fn new(center: Vec<f64>, radius: f64) -> Self {
        assert!(radius >= 0.0);
        Sphere { center, radius }
    }

    /// Sphere centered at the centroid of the selected rows, with radius
    /// the max distance to any of them (the SR-tree construction).
    pub fn from_points(m: &Matrix, idx: &[usize]) -> Self {
        assert!(!idx.is_empty());
        let d = m.cols();
        let mut c = vec![0.0; d];
        for &i in idx {
            let r = m.row(i);
            for j in 0..d {
                c[j] += r[j];
            }
        }
        for v in &mut c {
            *v /= idx.len() as f64;
        }
        let radius =
            idx.iter().map(|&i| dist(&c, m.row(i))).fold(0.0f64, f64::max);
        Sphere { center: c, radius }
    }

    #[inline]
    pub fn center(&self) -> &[f64] {
        &self.center
    }

    #[inline]
    pub fn radius(&self) -> f64 {
        self.radius
    }

    /// Lower bound on the distance between points in two spheres
    /// (clamped at 0 when they intersect).
    pub fn min_dist(&self, other: &Sphere) -> f64 {
        (dist(&self.center, &other.center) - self.radius - other.radius).max(0.0)
    }

    /// Upper bound on the distance between points in two spheres.
    pub fn max_dist(&self, other: &Sphere) -> f64 {
        dist(&self.center, &other.center) + self.radius + other.radius
    }

    /// Does the sphere contain `p`?
    pub fn contains(&self, p: &[f64]) -> bool {
        dist(&self.center, p) <= self.radius + 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn from_points_contains_all() {
        let m = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]]);
        let s = Sphere::from_points(&m, &[0, 1, 2]);
        for i in 0..3 {
            assert!(s.contains(m.row(i)));
        }
    }

    #[test]
    fn disjoint_sphere_bounds() {
        let a = Sphere::new(vec![0.0, 0.0], 1.0);
        let b = Sphere::new(vec![5.0, 0.0], 1.0);
        assert_eq!(a.min_dist(&b), 3.0);
        assert_eq!(a.max_dist(&b), 7.0);
    }

    #[test]
    fn overlapping_min_is_zero() {
        let a = Sphere::new(vec![0.0], 1.0);
        let b = Sphere::new(vec![1.0], 1.0);
        assert_eq!(a.min_dist(&b), 0.0);
    }

    #[test]
    fn bounds_bracket_random_pairs() {
        let mut rng = Pcg32::new(13);
        for _ in 0..30 {
            let d = 1 + rng.below(4);
            let pts_a: Vec<Vec<f64>> = (0..6)
                .map(|_| (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
                .collect();
            let pts_b: Vec<Vec<f64>> = (0..6)
                .map(|_| (0..d).map(|_| rng.uniform_in(2.0, 4.0)).collect())
                .collect();
            let ma = Matrix::from_rows(&pts_a);
            let mb = Matrix::from_rows(&pts_b);
            let sa = Sphere::from_points(&ma, &[0, 1, 2, 3, 4, 5]);
            let sb = Sphere::from_points(&mb, &[0, 1, 2, 3, 4, 5]);
            for i in 0..6 {
                for j in 0..6 {
                    let dd = dist(ma.row(i), mb.row(j));
                    assert!(sa.min_dist(&sb) <= dd + 1e-9);
                    assert!(dd <= sa.max_dist(&sb) + 1e-9);
                }
            }
        }
    }
}
