//! Geometric primitives: a row-major point matrix, bounding
//! hyper-rectangles with node-node distance bounds, and bounding spheres
//! (for the sphere-rectangle tree variant).

pub mod matrix;
pub mod hrect;
pub mod sphere;

pub use hrect::HRect;
pub use matrix::Matrix;
pub use sphere::Sphere;

/// Squared Euclidean distance between two D-dim points.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    sqdist(a, b).sqrt()
}

/// L∞ (Chebyshev) distance — used by the paper's node radii
/// r_R = max_r ‖x_r − x_R‖_∞ / h.
#[inline]
pub fn linf_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut m = 0.0f64;
    for i in 0..a.len() {
        m = m.max((a[i] - b[i]).abs());
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances() {
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert_eq!(sqdist(&a, &b), 25.0);
        assert_eq!(dist(&a, &b), 5.0);
        assert_eq!(linf_dist(&a, &b), 4.0);
    }

    #[test]
    fn zero_distance_to_self() {
        let a = [1.5, -2.5, 3.0];
        assert_eq!(sqdist(&a, &a), 0.0);
        assert_eq!(linf_dist(&a, &a), 0.0);
    }
}
