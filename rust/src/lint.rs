//! A repo-native invariant linter for the `fastgauss` source tree.
//!
//! The architecture makes promises that `rustc` and clippy cannot
//! check: every `unsafe` is justified, all hot-kernel dispatch flows
//! through the [`crate::compute::simd::Lanes`] table, raw threads
//! exist only inside the work-stealing pool, library code never
//! panics outside a small audited set, and the three user-facing
//! configuration surfaces (config keys, CLI flags,
//! `PrepareOptions` fields) cannot drift apart. This module enforces
//! those promises with a lightweight lexer — no external parser
//! crates — and the `fastgauss_lint` binary (a tier-1 CI step) plus
//! the `lint_rules` integration test keep the tree at zero findings.
//!
//! # Rule families
//!
//! * `safety-comment` — every `unsafe` token carries a `// SAFETY:`
//!   justification within the six preceding lines.
//! * `lanes-bypass` — the hot free functions (`exp_block`, `dot_soa`,
//!   `dot_tile`, `weighted_sum`, `gauss_from_norms`) may be named
//!   directly only by the modules that define them; everyone else
//!   must go through a `Lanes` table (`(lanes.exp_block)(..)`), so a
//!   scalar-vs-vector split can never be introduced by accident.
//! * `raw-thread` — `thread::{spawn, scope, Builder}` only in the
//!   sync shim ([`SYNC_FILES`]); all other fan-out uses the pool.
//! * `no-panic` — no `unwrap`/`expect`/`panic!` family in library
//!   code, except the blessed mutex-poisoning idiom
//!   (`.lock().unwrap()` et al. — poisoning means a panic already
//!   happened elsewhere) and the driver modules listed in
//!   [`DRIVER_FILES`].
//! * `sync-bypass` — raw `std::sync` primitives (`Mutex`, `Condvar`,
//!   atomics, `Once*`, …) and `thread::park` may be named only inside
//!   the sync shim ([`SYNC_FILES`]); everything else uses the
//!   `Sync*` shim types, so the model checker
//!   ([`crate::runtime::modelcheck`]) sees every operation.
//! * `ordering-audit` — every non-`SeqCst` `Ordering::` argument
//!   (`Relaxed`/`Acquire`/`Release`/`AcqRel`) carries a `// ORDER:`
//!   justification within [`ORDER_WINDOW`] preceding lines, mirroring
//!   the `// SAFETY:` rule: a weakened ordering is a proof obligation.
//! * `parity` — config keys, `--flags` and `PrepareOptions` fields
//!   stay in one-to-one correspondence (modulo the explicit alias
//!   and internal-field tables below).
//!
//! A violation that is genuinely intended is waived in place, on the
//! same or the preceding line, with a comment naming the rule and the
//! reason — e.g. `// lint: allow(no-panic): poisoning is re-raised`.
//! The reason is mandatory, so every waiver is an audit record.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// `unsafe` without a `// SAFETY:` comment nearby.
pub const RULE_SAFETY: &str = "safety-comment";
/// Hot kernel named outside the dispatch-table modules.
pub const RULE_LANES: &str = "lanes-bypass";
/// Raw `std::thread` primitive outside the sync shim.
pub const RULE_THREAD: &str = "raw-thread";
/// Panicking construct in library code.
pub const RULE_PANIC: &str = "no-panic";
/// Raw `std::sync` primitive outside the sync shim.
pub const RULE_SYNC: &str = "sync-bypass";
/// Non-SeqCst atomic ordering without an `// ORDER:` justification.
pub const RULE_ORDERING: &str = "ordering-audit";
/// Config-key / CLI-flag / `PrepareOptions`-field drift.
pub const RULE_PARITY: &str = "parity";
/// Meta-rule: a waiver comment that is itself malformed.
pub const RULE_WAIVER: &str = "waiver";

const RULE_NAMES: [&str; 7] = [
    RULE_SAFETY,
    RULE_LANES,
    RULE_THREAD,
    RULE_PANIC,
    RULE_SYNC,
    RULE_ORDERING,
    RULE_PARITY,
];

/// The hot free functions behind the `Lanes` function-pointer table.
const HOT_KERNELS: [&str; 5] =
    ["exp_block", "dot_soa", "dot_tile", "weighted_sum", "gauss_from_norms"];

/// Modules allowed to name the hot kernels directly: the dispatch
/// table itself and the two modules defining the scalar bodies.
const KERNEL_FILES: [&str; 3] = ["compute/simd.rs", "compute/microkernel.rs", "compute/fastexp.rs"];

/// The one home of raw thread and raw `std::sync` primitives: the
/// shim layer itself plus the model checker it routes through (which
/// must use real primitives to implement the virtual ones).
const SYNC_FILES: [&str; 2] = ["runtime/sync.rs", "runtime/modelcheck.rs"];

/// Identifiers that name a raw `std::sync` primitive (the
/// `sync-bypass` needle set; boundaries are identifier-exact, so the
/// `Sync*` shim types do not match).
const SYNC_PRIMITIVES: [&str; 14] = [
    "Mutex",
    "RwLock",
    "Condvar",
    "Once",
    "OnceLock",
    "AtomicBool",
    "AtomicUsize",
    "AtomicU64",
    "AtomicU32",
    "AtomicU8",
    "AtomicI64",
    "AtomicPtr",
    "fence",
    "mpsc",
];

/// The non-`SeqCst` orderings the `ordering-audit` rule gates.
const WEAK_ORDERINGS: [&str; 4] = ["Relaxed", "Acquire", "Release", "AcqRel"];

/// How many lines above a weak `Ordering::` use an `ORDER` comment
/// may sit (same idea as [`SAFETY_WINDOW`], tighter because ordering
/// justifications are per-site).
const ORDER_WINDOW: usize = 4;

/// Driver modules where aborting the process is the designed failure
/// mode, exempt from `no-panic` (binaries under `bin/` and `main.rs`
/// are exempt implicitly).
const DRIVER_FILES: [(&str, &str); 3] = [
    ("cli.rs", "CLI front end: argument errors abort with a usage message"),
    ("benchjson.rs", "bench harness: an internal assert failing the run IS the test"),
    ("prop.rs", "property-test harness: a counterexample aborts the search loudly"),
];

/// Receiver method names whose `.unwrap()` is the blessed poisoning
/// idiom: the lock/channel can only fail if another thread already
/// panicked, and propagating that panic is the correct response.
const BLESSED_UNWRAP_RECEIVERS: [&str; 6] =
    ["lock", "read", "write", "into_inner", "wait", "wait_timeout"];

/// How many lines above an `unsafe` token a `SAFETY` comment may sit
/// (multi-line justifications are common in `simd.rs`).
const SAFETY_WINDOW: usize = 6;

/// Config keys that surface as `PrepareOptions` fields, by their
/// primary `key = value` spelling.
const KEY_TO_FIELD: [(&str, &str); 7] = [
    ("workers", "threads"),
    ("leaf-size", "leaf_size"),
    ("fast-exp", "fast_exp"),
    ("simd", "simd"),
    ("precision", "precision"),
    ("kernel", "kernel"),
    ("slices", "slices"),
];

/// `PrepareOptions` fields that deliberately have no config-file
/// spelling, with the reason on record.
const INTERNAL_FIELDS: [(&str, &str); 4] = [
    ("weights", "per-request data, not a scalar a config file could hold"),
    ("moment_cache_capacity", "sized by the coordinator per sweep, not user-facing"),
    ("truth_cache_capacity", "sized by the coordinator per sweep, not user-facing"),
    ("cost_model", "programmatic tuning surface for embedders only"),
];

/// CLI tokens that look like flags but are not config keys.
const CLI_EXEMPT: [&str; 2] = ["option", "help"];

/// One rule violation (or malformed waiver) at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to `rust/src`, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// One of the `RULE_*` constants.
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rust/src/{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// Lexer: mask a source file into parallel views
// ---------------------------------------------------------------------------

/// Parallel same-length views of one source file: `code` keeps only
/// code bytes (comments, strings and char literals blanked to
/// spaces), `comments` keeps only comment text. Newlines survive in
/// both so line numbers agree everywhere. `strings` records cooked
/// and raw string literal contents with their byte offsets.
struct Masked {
    code: Vec<u8>,
    comments: Vec<u8>,
    strings: Vec<(usize, String)>,
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn find_sub(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

fn mask(src: &[u8]) -> Masked {
    let n = src.len();
    let mut code = vec![b' '; n];
    let mut comments = vec![b' '; n];
    for (i, &b) in src.iter().enumerate() {
        if b == b'\n' {
            code[i] = b'\n';
            comments[i] = b'\n';
        }
    }
    let mut strings = Vec::new();
    let mut i = 0;
    while i < n {
        let c = src[i];
        let c1 = if i + 1 < n { src[i + 1] } else { 0 };
        // line comment (also doc comments — they are comments too)
        if c == b'/' && c1 == b'/' {
            i += 2;
            while i < n && src[i] != b'\n' {
                comments[i] = src[i];
                i += 1;
            }
            continue;
        }
        // block comment, nested per Rust rules
        if c == b'/' && c1 == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if src[i] == b'/' && i + 1 < n && src[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if src[i] == b'*' && i + 1 < n && src[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if src[i] != b'\n' {
                        comments[i] = src[i];
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw / byte string prefixes: r".."  r#".."#  br".."  b".."
        let prev_ident = i > 0 && is_ident(src[i - 1]);
        if (c == b'r' || c == b'b') && !prev_ident {
            if let Some(next) = lex_prefixed_string(src, i, &mut strings) {
                i = next;
                continue;
            }
        }
        if c == b'"' {
            i = lex_cooked_string(src, i, &mut strings);
            continue;
        }
        if c == b'\'' {
            i = lex_quote(src, i);
            continue;
        }
        code[i] = c;
        i += 1;
    }
    Masked { code, comments, strings }
}

/// Lex `r"…"`, `r#"…"#`, `br"…"` or `b"…"` starting at `i` (which
/// points at the prefix). Returns the index just past the literal, or
/// `None` if this is not actually a string prefix (e.g. `b'x'`, or an
/// identifier beginning with `r`).
fn lex_prefixed_string(src: &[u8], i: usize, strings: &mut Vec<(usize, String)>) -> Option<usize> {
    let n = src.len();
    let (raw, mut j) = match src[i] {
        b'r' => (true, i + 1),
        b'b' if i + 1 < n && src[i + 1] == b'r' => (true, i + 2),
        b'b' if i + 1 < n && src[i + 1] == b'"' => (false, i + 1),
        _ => return None,
    };
    if !raw {
        return Some(lex_cooked_string(src, j, strings));
    }
    let mut hashes = 0usize;
    while j < n && src[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || src[j] != b'"' {
        return None;
    }
    j += 1;
    let start = j;
    while j < n {
        if src[j] == b'"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while k < n && h < hashes && src[k] == b'#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                strings.push((start, String::from_utf8_lossy(&src[start..j]).into_owned()));
                return Some(k);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Lex a cooked string starting at the opening quote `src[i] == b'"'`.
/// Escapes are simplified (a backslash shields exactly the next byte),
/// which is sound for delimiter tracking and for the ASCII literals
/// the parity rule reads. Returns the index past the closing quote.
fn lex_cooked_string(src: &[u8], i: usize, strings: &mut Vec<(usize, String)>) -> usize {
    let n = src.len();
    let mut j = i + 1;
    let mut content = Vec::new();
    while j < n {
        match src[j] {
            b'\\' => {
                if j + 1 < n {
                    content.push(src[j + 1]);
                }
                j += 2;
            }
            b'"' => {
                strings.push((i + 1, String::from_utf8_lossy(&content).into_owned()));
                return j + 1;
            }
            b => {
                content.push(b);
                j += 1;
            }
        }
    }
    n
}

/// Lex a `'` at `i`: a char literal (`'x'`, `'\n'`, `'\u{1F}'`) is
/// consumed entirely; a lifetime tick is consumed alone, leaving the
/// lifetime name as ordinary code.
fn lex_quote(src: &[u8], i: usize) -> usize {
    let n = src.len();
    if i + 1 < n && src[i + 1] == b'\\' {
        let mut j = i + 2;
        if j + 1 < n && src[j] == b'u' && src[j + 1] == b'{' {
            j += 2;
            while j < n && src[j] != b'}' {
                j += 1;
            }
        }
        j += 1;
        while j < n && src[j] != b'\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && src[i + 2] == b'\'' && src[i + 1] != b'\'' {
        return i + 3;
    }
    i + 1
}

// ---------------------------------------------------------------------------
// Line bookkeeping, test regions, waivers
// ---------------------------------------------------------------------------

fn line_starts(src: &[u8]) -> Vec<usize> {
    let mut starts = vec![0usize];
    for (i, &b) in src.iter().enumerate() {
        if b == b'\n' {
            starts.push(i + 1);
        }
    }
    starts
}

/// 1-based line number of byte offset `pos`.
fn line_of(starts: &[usize], pos: usize) -> usize {
    starts.partition_point(|&s| s <= pos)
}

fn split_lines(buf: &[u8]) -> Vec<String> {
    buf.split(|&b| b == b'\n').map(|l| String::from_utf8_lossy(l).into_owned()).collect()
}

/// Per-line flags for `#[cfg(test)] mod … { … }` regions, where the
/// library rules do not apply (tests may panic and may compare hot
/// kernels against references directly).
fn test_region_flags(code: &[u8], starts: &[usize]) -> Vec<bool> {
    let mut flags = vec![false; starts.len() + 2];
    // the `all(test` prefix covers feature-gated test modules such as
    // `#[cfg(all(test, feature = "modelcheck"))]`
    for needle in [b"#[cfg(test)]".as_slice(), b"#[cfg(all(test".as_slice()] {
        test_region_flags_for(code, starts, needle, &mut flags);
    }
    flags
}

fn test_region_flags_for(code: &[u8], starts: &[usize], needle: &[u8], flags: &mut [bool]) {
    let mut from = 0usize;
    while let Some(p) = find_sub(code, needle, from) {
        from = p + 1;
        let mut m = p;
        let mod_pos = loop {
            match find_sub(code, b"mod", m) {
                None => break None,
                Some(q) => {
                    m = q + 1;
                    let before_ok = q == 0 || !is_ident(code[q - 1]);
                    let after_ok = q + 3 >= code.len() || !is_ident(code[q + 3]);
                    if before_ok && after_ok {
                        break Some(q);
                    }
                }
            }
        };
        let open = mod_pos.and_then(|q| find_sub(code, b"{", q));
        let Some(open) = open else { continue };
        let mut depth = 0usize;
        let mut close = open;
        for (k, &b) in code.iter().enumerate().skip(open) {
            match b {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = k;
                        break;
                    }
                }
                _ => {}
            }
        }
        let lo = line_of(starts, p);
        let hi = line_of(starts, close);
        for line in lo..=hi.min(flags.len() - 1) {
            flags[line] = true;
        }
    }
}

#[derive(Default)]
struct Waivers {
    by_line: BTreeMap<usize, Vec<&'static str>>,
}

impl Waivers {
    fn allows(&self, line: usize, rule: &str) -> bool {
        [line, line.saturating_sub(1)]
            .iter()
            .any(|l| self.by_line.get(l).is_some_and(|v| v.contains(&rule)))
    }
}

/// Parse waiver comments (the rule-plus-reason form shown in the
/// module docs). Malformed waivers — unknown rule, missing reason —
/// are findings themselves: a waiver is an audit record, not an off
/// switch.
fn parse_waivers(rel: &str, comment_lines: &[String], findings: &mut Vec<Finding>) -> Waivers {
    const MARK: &str = "lint: allow(";
    let mut waivers = Waivers::default();
    for (idx, text) in comment_lines.iter().enumerate() {
        let line = idx + 1;
        let Some(p) = text.find(MARK) else { continue };
        let rest = &text[p + MARK.len()..];
        let Some(close) = rest.find(')') else {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_WAIVER,
                message: "unclosed `lint: allow(` waiver".to_string(),
            });
            continue;
        };
        let name = rest[..close].trim();
        let Some(rule) = RULE_NAMES.iter().copied().find(|r| *r == name) else {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_WAIVER,
                message: format!("waiver names unknown rule `{name}`"),
            });
            continue;
        };
        let after = rest[close + 1..].trim_start();
        let reason_ok = after.strip_prefix(':').is_some_and(|r| !r.trim().is_empty());
        if !reason_ok {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_WAIVER,
                message: format!("waiver for `{rule}` is missing its reason"),
            });
            continue;
        }
        waivers.by_line.entry(line).or_default().push(rule);
    }
    waivers
}

// ---------------------------------------------------------------------------
// Per-file rules
// ---------------------------------------------------------------------------

/// Occurrences of `name` in `code` with identifier boundaries on both
/// sides (so `dot_tile` does not match inside `dot_tile_f32`).
fn ident_occurrences(code: &[u8], name: &[u8]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = find_sub(code, name, from) {
        from = p + 1;
        let before_ok = p == 0 || !is_ident(code[p - 1]);
        let after = p + name.len();
        let after_ok = after >= code.len() || !is_ident(code[after]);
        if before_ok && after_ok {
            out.push(p);
        }
    }
    out
}

/// Previous non-whitespace byte, if any.
fn prev_nonspace(code: &[u8], pos: usize) -> Option<u8> {
    code[..pos].iter().rev().copied().find(|b| !b" \t\n".contains(b))
}

/// True when the `.unwrap(` at `dot_pos` hangs off a call to one of
/// [`BLESSED_UNWRAP_RECEIVERS`]: scan back over one balanced paren
/// group and read the method name in front of it.
fn is_blessed_unwrap(code: &[u8], dot_pos: usize) -> bool {
    let mut q = dot_pos;
    while q > 0 && b" \t\n".contains(&code[q - 1]) {
        q -= 1;
    }
    if q == 0 || code[q - 1] != b')' {
        return false;
    }
    let mut depth = 0usize;
    let mut r = q; // one past the closing paren
    while r > 0 {
        r -= 1;
        match code[r] {
            b')' => depth += 1,
            b'(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    while r > 0 && b" \t\n".contains(&code[r - 1]) {
        r -= 1;
    }
    let end = r;
    while r > 0 && is_ident(code[r - 1]) {
        r -= 1;
    }
    let name = &code[r..end];
    BLESSED_UNWRAP_RECEIVERS.iter().any(|b| b.as_bytes() == name)
}

fn is_driver(rel: &str) -> bool {
    rel == "main.rs" || rel.starts_with("bin/") || DRIVER_FILES.iter().any(|(f, _)| *f == rel)
}

/// Run the six per-file rule families over one source file.
/// `rel` is the path relative to `rust/src` with `/` separators.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let bytes = src.as_bytes();
    let masked = mask(bytes);
    let starts = line_starts(bytes);
    let comment_lines = split_lines(&masked.comments);
    let in_test = test_region_flags(&masked.code, &starts);
    let mut findings = Vec::new();
    let waivers = parse_waivers(rel, &comment_lines, &mut findings);
    let code = &masked.code[..];

    // safety-comment
    for p in ident_occurrences(code, b"unsafe") {
        let line = line_of(&starts, p);
        let lo = line.saturating_sub(SAFETY_WINDOW).max(1);
        let justified =
            (lo..=line).any(|l| comment_lines.get(l - 1).is_some_and(|t| t.contains("SAFETY")));
        if !justified && !waivers.allows(line, RULE_SAFETY) {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_SAFETY,
                message: "`unsafe` without a `// SAFETY:` justification above it".to_string(),
            });
        }
    }

    // lanes-bypass
    if !KERNEL_FILES.contains(&rel) {
        for name in HOT_KERNELS {
            for p in ident_occurrences(code, name.as_bytes()) {
                let line = line_of(&starts, p);
                if in_test.get(line).copied().unwrap_or(false) {
                    continue;
                }
                // `.name` is a Lanes field access — the sanctioned path
                if prev_nonspace(code, p) == Some(b'.') {
                    continue;
                }
                if waivers.allows(line, RULE_LANES) {
                    continue;
                }
                findings.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: RULE_LANES,
                    message: format!(
                        "hot kernel `{name}` named outside the Lanes table; \
                         dispatch through `simd::active()` / `simd::scalar()`"
                    ),
                });
            }
        }
    }

    // raw-thread
    if !SYNC_FILES.contains(&rel) {
        for token in ["thread::spawn", "thread::scope", "thread::Builder"] {
            for p in ident_occurrences(code, token.as_bytes()) {
                let line = line_of(&starts, p);
                if in_test.get(line).copied().unwrap_or(false) {
                    continue;
                }
                if waivers.allows(line, RULE_THREAD) {
                    continue;
                }
                findings.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: RULE_THREAD,
                    message: format!(
                        "`{token}` outside runtime/sync.rs; route work through \
                         WorkStealPool (or sync::spawn_thread inside the runtime)"
                    ),
                });
            }
        }
    }

    // sync-bypass
    if !SYNC_FILES.contains(&rel) {
        let park_tokens = ["thread::park", "thread::park_timeout"];
        let prims = SYNC_PRIMITIVES.iter().copied();
        for name in prims.chain(park_tokens) {
            for p in ident_occurrences(code, name.as_bytes()) {
                let line = line_of(&starts, p);
                if in_test.get(line).copied().unwrap_or(false) {
                    continue;
                }
                if waivers.allows(line, RULE_SYNC) {
                    continue;
                }
                findings.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: RULE_SYNC,
                    message: format!(
                        "raw sync primitive `{name}` outside runtime/sync.rs; use the \
                         Sync* shim types so the model checker sees every operation"
                    ),
                });
            }
        }
    }

    // ordering-audit
    for p in ident_occurrences(code, b"Ordering") {
        let mut q = p + b"Ordering".len();
        while q < code.len() && b" \t\n".contains(&code[q]) {
            q += 1;
        }
        if code.get(q) != Some(&b':') || code.get(q + 1) != Some(&b':') {
            continue;
        }
        q += 2;
        while q < code.len() && b" \t\n".contains(&code[q]) {
            q += 1;
        }
        let start = q;
        while q < code.len() && is_ident(code[q]) {
            q += 1;
        }
        // `Ordering::{...}` imports and `Ordering::SeqCst` fall out
        // here: only a weak variant name creates an obligation
        let name = String::from_utf8_lossy(&code[start..q]).into_owned();
        if !WEAK_ORDERINGS.contains(&name.as_str()) {
            continue;
        }
        let line = line_of(&starts, start);
        if in_test.get(line).copied().unwrap_or(false) {
            continue;
        }
        let lo = line.saturating_sub(ORDER_WINDOW).max(1);
        let justified =
            (lo..=line).any(|l| comment_lines.get(l - 1).is_some_and(|t| t.contains("ORDER:")));
        if justified || waivers.allows(line, RULE_ORDERING) {
            continue;
        }
        findings.push(Finding {
            file: rel.to_string(),
            line,
            rule: RULE_ORDERING,
            message: format!(
                "`Ordering::{name}` without an `// ORDER:` justification within \
                 {ORDER_WINDOW} preceding lines"
            ),
        });
    }

    // no-panic
    if !is_driver(rel) {
        let dotted = [".unwrap(", ".expect("];
        let macros = ["panic!", "unreachable!", "todo!", "unimplemented!"];
        let mut hits: Vec<(usize, &str)> = Vec::new();
        for token in dotted {
            let mut from = 0usize;
            while let Some(p) = find_sub(code, token.as_bytes(), from) {
                from = p + 1;
                hits.push((p, token));
            }
        }
        for token in macros {
            for p in ident_occurrences(code, token.trim_end_matches('!').as_bytes()) {
                if code.get(p + token.len() - 1) == Some(&b'!') {
                    hits.push((p, token));
                }
            }
        }
        for (p, token) in hits {
            let line = line_of(&starts, p);
            if in_test.get(line).copied().unwrap_or(false) {
                continue;
            }
            if token == ".unwrap(" && is_blessed_unwrap(code, p) {
                continue;
            }
            if waivers.allows(line, RULE_PANIC) {
                continue;
            }
            let what = token.trim_start_matches('.').trim_end_matches('(');
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_PANIC,
                message: format!("`{what}` in library code; return an error or waive it"),
            });
        }
    }

    findings
}

// ---------------------------------------------------------------------------
// Parity rule
// ---------------------------------------------------------------------------

/// The three configuration surfaces the parity rule cross-checks.
pub struct ParitySources<'a> {
    /// `rust/src/config.rs` (holds `VALID_KEYS`).
    pub config: &'a str,
    /// `rust/src/cli.rs` (holds the `--flag` spellings).
    pub cli: &'a str,
    /// `rust/src/api/session.rs` (holds `PrepareOptions`).
    pub session: &'a str,
}

/// `VALID_KEYS` entries as alias sets, e.g. `["leaf-size", "leaf_size"]`.
fn config_keys(config: &str) -> Vec<Vec<String>> {
    let bytes = config.as_bytes();
    let masked = mask(bytes);
    let Some(p) = find_sub(&masked.code, b"VALID_KEYS", 0) else { return Vec::new() };
    let end = find_sub(&masked.code, b"];", p).unwrap_or(bytes.len());
    masked
        .strings
        .iter()
        .filter(|(pos, _)| *pos > p && *pos < end)
        .map(|(_, s)| s.split('|').map(|a| a.trim().to_string()).collect())
        .collect()
}

/// Every `--token` spelled in any string literal of `cli.rs` (usage
/// text and match arms both count — that is the point).
fn cli_flags(cli: &str) -> BTreeSet<String> {
    let masked = mask(cli.as_bytes());
    let mut flags = BTreeSet::new();
    for (_, s) in &masked.strings {
        let b = s.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_sub(b, b"--", from) {
            let mut end = p + 2;
            while end < b.len() && (is_ident(b[end]) || b[end] == b'-') {
                end += 1;
            }
            from = end.max(p + 2 + 1);
            if end > p + 2 {
                flags.insert(String::from_utf8_lossy(&b[p + 2..end]).into_owned());
            }
        }
    }
    flags
}

/// Field names of `pub struct PrepareOptions`.
fn prepare_options_fields(session: &str) -> Vec<String> {
    let masked = mask(session.as_bytes());
    let code = &masked.code[..];
    let Some(p) = find_sub(code, b"pub struct PrepareOptions", 0) else { return Vec::new() };
    let Some(open) = find_sub(code, b"{", p) else { return Vec::new() };
    let mut depth = 0usize;
    let mut close = open;
    for (k, &b) in code.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    close = k;
                    break;
                }
            }
            _ => {}
        }
    }
    let mut fields = Vec::new();
    for q in ident_occurrences(&code[open..close], b"pub") {
        let mut r = open + q + 3;
        while r < close && code[r].is_ascii_whitespace() {
            r += 1;
        }
        let start = r;
        while r < close && is_ident(code[r]) {
            r += 1;
        }
        let mut colon = r;
        while colon < close && code[colon].is_ascii_whitespace() {
            colon += 1;
        }
        if r > start && code.get(colon) == Some(&b':') {
            fields.push(String::from_utf8_lossy(&code[start..r]).into_owned());
        }
    }
    fields
}

/// Cross-check the three surfaces; see [`KEY_TO_FIELD`],
/// [`INTERNAL_FIELDS`] and [`CLI_EXEMPT`] for the sanctioned deltas.
pub fn lint_parity(src: &ParitySources<'_>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let keys = config_keys(src.config);
    let flags = cli_flags(src.cli);
    let fields = prepare_options_fields(src.session);
    let push = |findings: &mut Vec<Finding>, file: &str, message: String| {
        findings.push(Finding { file: file.to_string(), line: 1, rule: RULE_PARITY, message });
    };
    if keys.is_empty() {
        push(&mut findings, "config.rs", "VALID_KEYS not found; parity unchecked".to_string());
    }
    if fields.is_empty() {
        push(
            &mut findings,
            "api/session.rs",
            "PrepareOptions fields not found; parity unchecked".to_string(),
        );
    }
    let aliases: BTreeSet<&str> = keys.iter().flatten().map(|a| a.as_str()).collect();
    for (key, field) in KEY_TO_FIELD {
        if !keys.is_empty() && !aliases.contains(key) {
            push(&mut findings, "config.rs", format!("mapped key `{key}` missing from VALID_KEYS"));
        }
        if !fields.is_empty() && !fields.iter().any(|f| f == field) {
            push(
                &mut findings,
                "api/session.rs",
                format!("mapped field `{field}` missing from PrepareOptions"),
            );
        }
    }
    for field in &fields {
        let mapped = KEY_TO_FIELD.iter().any(|(_, f)| f == field);
        let internal = INTERNAL_FIELDS.iter().any(|(f, _)| f == field);
        if !mapped && !internal {
            push(
                &mut findings,
                "api/session.rs",
                format!(
                    "PrepareOptions field `{field}` has neither a config-key mapping \
                     nor an internal-field allowlisting"
                ),
            );
        }
    }
    for alias_set in &keys {
        if !alias_set.iter().any(|a| flags.contains(a)) {
            let key = alias_set.first().map(|s| s.as_str()).unwrap_or("");
            push(&mut findings, "cli.rs", format!("config key `{key}` has no --flag in cli.rs"));
        }
    }
    for flag in &flags {
        let known = aliases.contains(flag.as_str()) || CLI_EXEMPT.iter().any(|e| e == flag);
        if !known {
            push(
                &mut findings,
                "cli.rs",
                format!("cli flag `--{flag}` is neither a config key/alias nor exempt"),
            );
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries = Vec::new();
    for entry in fs::read_dir(dir)? {
        entries.push(entry?.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `<root>/rust/src` (per-file rules plus
/// the cross-file parity rule). `root` is the repository root — the
/// directory holding `Cargo.toml`.
pub fn lint_tree(root: &Path) -> io::Result<(usize, Vec<Finding>)> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    let mut findings = Vec::new();
    let mut config = None;
    let mut cli = None;
    let mut session = None;
    for path in &files {
        let src = String::from_utf8_lossy(&fs::read(path)?).into_owned();
        let rel: String = match path.strip_prefix(&src_root) {
            Ok(r) => r
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/"),
            Err(_) => path.to_string_lossy().into_owned(),
        };
        findings.extend(lint_source(&rel, &src));
        match rel.as_str() {
            "config.rs" => config = Some(src),
            "cli.rs" => cli = Some(src),
            "api/session.rs" => session = Some(src),
            _ => {}
        }
    }
    match (&config, &cli, &session) {
        (Some(c), Some(l), Some(s)) => {
            findings.extend(lint_parity(&ParitySources { config: c, cli: l, session: s }));
        }
        _ => findings.push(Finding {
            file: String::new(),
            line: 1,
            rule: RULE_PARITY,
            message: "config.rs / cli.rs / api/session.rs not all present; parity unchecked"
                .to_string(),
        }),
    }
    Ok((files.len(), findings))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_blanks_comments_strings_and_chars_but_not_code() {
        let src = r##"let s = "unsafe // not code"; // unsafe in comment
let c = 'x'; let lt: &'static str = r#"panic!"#; /* unsafe */ let u = 1;"##;
        let m = mask(src.as_bytes());
        let code = String::from_utf8_lossy(&m.code).into_owned();
        assert!(!code.contains("unsafe"), "masked code leaked literal/comment text: {code}");
        assert!(!code.contains("panic!"), "raw string leaked into code: {code}");
        assert!(code.contains("let s ="));
        assert!(code.contains("static"), "lifetime names must stay code");
        let comments = String::from_utf8_lossy(&m.comments).into_owned();
        assert!(comments.contains("unsafe in comment"));
        assert_eq!(m.strings.len(), 2);
        assert_eq!(m.strings[0].1, "unsafe // not code");
        assert_eq!(m.strings[1].1, "panic!");
    }

    #[test]
    fn nested_block_comments_and_escapes_terminate_where_rust_says() {
        let src = "/* a /* b */ still comment */ let x = \"q\\\"uote\"; let y = 0;";
        let m = mask(src.as_bytes());
        let code = String::from_utf8_lossy(&m.code).into_owned();
        assert!(!code.contains("still comment"));
        assert!(code.contains("let x ="));
        assert!(code.contains("let y = 0;"));
        assert_eq!(m.strings[0].1, "q\"uote");
    }

    #[test]
    fn line_of_is_one_based_and_stable_across_the_file() {
        let src = b"a\nbb\nccc\n";
        let starts = line_starts(src);
        assert_eq!(line_of(&starts, 0), 1);
        assert_eq!(line_of(&starts, 2), 2);
        assert_eq!(line_of(&starts, 5), 3);
    }

    #[test]
    fn blessed_unwrap_spans_newlines_and_nested_parens() {
        let src = "let g = m\n    .lock()\n    .unwrap();\nlet h = v.last().unwrap();";
        let f = lint_source("x.rs", src);
        assert_eq!(f.len(), 1, "only the non-blessed unwrap should flag: {f:?}");
        assert_eq!(f[0].rule, RULE_PANIC);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn waiver_requires_known_rule_and_reason() {
        let ok = "// lint: allow(no-panic): checked two lines up\nlet x = v.last().unwrap();";
        assert!(lint_source("x.rs", ok).is_empty());
        let missing = "// lint: allow(no-panic)\nlet x = v.last().unwrap();";
        let f = lint_source("x.rs", missing);
        assert!(f.iter().any(|f| f.rule == RULE_WAIVER), "{f:?}");
        assert!(f.iter().any(|f| f.rule == RULE_PANIC), "malformed waiver must not waive");
        let unknown = "// lint: allow(no-such-rule): reason\nlet x = 1;";
        let f = lint_source("x.rs", unknown);
        assert!(f.iter().any(|f| f.rule == RULE_WAIVER), "{f:?}");
    }

    #[test]
    fn test_modules_are_exempt_from_library_rules() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { \
                   let v: Vec<u32> = vec![]; v.last().unwrap(); }\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn feature_gated_test_modules_are_exempt_too() {
        let src = "fn lib() {}\n#[cfg(all(test, feature = \"modelcheck\"))]\nmod mc_tests {\n\
                   \x20   use std::sync::atomic::AtomicUsize;\n    fn t() { let v: Vec<u32> = \
                   vec![]; v.last().unwrap(); }\n}\n";
        assert!(lint_source("x.rs", src).is_empty(), "{:?}", lint_source("x.rs", src));
    }

    #[test]
    fn sync_bypass_flags_raw_primitives_outside_the_shim() {
        let src = "use std::sync::Mutex;\nfn f() { std::thread::park(); }\n";
        let f = lint_source("algo/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == RULE_SYNC).count(), 2, "{f:?}");
        assert!(lint_source("runtime/sync.rs", src).is_empty());
        assert!(lint_source("runtime/modelcheck.rs", src).is_empty());
        let waived = "// lint: allow(sync-bypass): below the runtime layer\n\
                      use std::sync::Mutex;\n";
        assert!(lint_source("algo/x.rs", waived).is_empty());
    }

    #[test]
    fn ordering_audit_demands_order_comments_for_weak_orderings() {
        let bad = "fn f(a: &A) { a.x.load(Ordering::Acquire); }\n";
        let f = lint_source("x.rs", bad);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, RULE_ORDERING);
        let good = "// ORDER: Acquire — pairs with the Release store in publish().\n\
                    fn f(a: &A) { a.x.load(Ordering::Acquire); }\n";
        assert!(lint_source("x.rs", good).is_empty());
        // SeqCst needs no justification; imports create no obligation
        let seq = "use std::sync::atomic::Ordering::{self, SeqCst};\n\
                   fn f(a: &A) { a.x.load(Ordering::SeqCst); }\n";
        assert!(lint_source("x.rs", seq).is_empty());
        // the comment must be within the window
        let far = "// ORDER: Acquire — pairs with a Release store.\n\n\n\n\n\
                   fn f(a: &A) { a.x.load(Ordering::Acquire); }\n";
        let f = lint_source("x.rs", far);
        assert_eq!(f.len(), 1, "ORDER comment beyond the window must not count: {f:?}");
    }
}
