//! Multi-index algebra for truncated series expansions.
//!
//! The paper contrasts two truncation families for a D-dimensional
//! series of order p:
//!
//! * **grid / O(pᴰ)** — all α with every component `α_d < p`
//!   (the classical FGT truncation; exactly `pᴰ` terms);
//! * **graded / O(Dᵖ)** — all α with *total degree* `|α| < p` in graded
//!   lexicographic order (Yang et al. 2003; exactly `C(D+p−1, D)` terms).
//!
//! A [`MultiIndexSet`] enumerates one family once, precomputes parent
//! links for incremental monomial evaluation (each index is its parent
//! times one extra coordinate), per-index `1/α!`, degrees, and a
//! position map used by the translation operators.

pub mod factorial;

use std::collections::HashMap;

pub use factorial::{binomial, factorial, ln_factorial};

/// Which truncation family a set enumerates.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Layout {
    /// All α with each component < p — `pᴰ` indices (classical FGT).
    Grid,
    /// All α with total degree |α| < p — `C(D+p−1, D)` indices.
    Graded,
}

/// An enumerated, preprocessed set of multi-indices.
#[derive(Clone, Debug)]
pub struct MultiIndexSet {
    layout: Layout,
    dim: usize,
    order: usize,
    /// The indices, in enumeration order (degree-major for `Graded`,
    /// mixed-radix/lexicographic for `Grid`). Index 0 is always the zero
    /// multi-index.
    indices: Vec<Vec<u32>>,
    /// `parent[i]`: position of α_i − e_{added_dim[i]}; `usize::MAX` for
    /// the zero index.
    parent: Vec<usize>,
    added_dim: Vec<usize>,
    /// 1/α! per index.
    inv_factorial: Vec<f64>,
    /// |α| per index.
    degree: Vec<u32>,
    /// max_d α_d per index (grid-layout truncation predicate).
    max_component: Vec<u32>,
    /// `len_at[p]` = number of indices inside the sub-order-p truncation,
    /// for p = 0..=order (precomputed; `best_method` reads this per pair).
    len_at: Vec<usize>,
    pos: HashMap<Vec<u32>, usize>,
}

impl MultiIndexSet {
    /// Enumerate the family. `order` = p ≥ 1. `dim` = D ≥ 1.
    pub fn new(layout: Layout, dim: usize, order: usize) -> Self {
        assert!(dim >= 1 && order >= 1, "dim/order must be >= 1");
        let indices = match layout {
            Layout::Grid => enumerate_grid(dim, order),
            Layout::Graded => enumerate_graded(dim, order),
        };
        let mut pos = HashMap::with_capacity(indices.len());
        for (i, a) in indices.iter().enumerate() {
            pos.insert(a.clone(), i);
        }
        let mut parent = Vec::with_capacity(indices.len());
        let mut added_dim = Vec::with_capacity(indices.len());
        let mut inv_factorial = Vec::with_capacity(indices.len());
        let mut degree = Vec::with_capacity(indices.len());
        let mut max_component = Vec::with_capacity(indices.len());
        for a in &indices {
            let deg: u32 = a.iter().sum();
            degree.push(deg);
            max_component.push(a.iter().copied().max().unwrap_or(0));
            let mut invf = 1.0;
            for &ad in a {
                invf /= factorial(ad as usize);
            }
            inv_factorial.push(invf);
            if deg == 0 {
                parent.push(usize::MAX);
                added_dim.push(usize::MAX);
            } else {
                // Decrement the last nonzero coordinate; the parent is
                // guaranteed to appear earlier in both enumerations.
                // lint: allow(no-panic): the all-zero index took the branch above, so a nonzero coordinate exists
                let d = a.iter().rposition(|&v| v > 0).unwrap();
                let mut pa = a.clone();
                pa[d] -= 1;
                // lint: allow(no-panic): graded enumeration lists parents before children by construction
                let pi = *pos.get(&pa).expect("parent must be enumerated");
                debug_assert!(pi < pos[a]);
                parent.push(pi);
                added_dim.push(d);
            }
        }
        let mut set = MultiIndexSet {
            layout,
            dim,
            order,
            indices,
            parent,
            added_dim,
            inv_factorial,
            degree,
            max_component,
            len_at: Vec::new(),
            pos,
        };
        set.len_at = (0..=order).map(|p| set.count_at_order(p)).collect();
        set
    }

    #[inline]
    pub fn layout(&self) -> Layout {
        self.layout
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The truncation order p.
    #[inline]
    pub fn order(&self) -> usize {
        self.order
    }

    /// Number of indices in the set.
    #[inline]
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    #[inline]
    pub fn index(&self, i: usize) -> &[u32] {
        &self.indices[i]
    }

    #[inline]
    pub fn degree(&self, i: usize) -> u32 {
        self.degree[i]
    }

    #[inline]
    pub fn inv_factorial(&self, i: usize) -> f64 {
        self.inv_factorial[i]
    }

    /// Position of a multi-index in the enumeration, if present.
    pub fn position(&self, a: &[u32]) -> Option<usize> {
        self.pos.get(a).copied()
    }

    /// Is index `i` inside the *sub*-truncation of order `p ≤ self.order()`?
    /// Graded: |α| < p; Grid: max_d α_d < p. Lets one PLIMIT-sized
    /// coefficient array serve every lower approximation order.
    #[inline]
    pub fn in_order(&self, i: usize, p: usize) -> bool {
        match self.layout {
            Layout::Graded => (self.degree[i] as usize) < p,
            Layout::Grid => (self.max_component[i] as usize) < p,
        }
    }

    /// Number of indices inside the sub-truncation of order `p` (O(1),
    /// precomputed — `best_method` reads this for every node pair).
    #[inline]
    pub fn len_at_order(&self, p: usize) -> usize {
        self.len_at[p.min(self.order)]
    }

    fn count_at_order(&self, p: usize) -> usize {
        (0..self.len()).filter(|&i| self.in_order(i, p)).count()
    }

    /// For layouts where the sub-order-p subset is an enumeration
    /// *prefix* (graded, which is degree-major), the prefix length —
    /// lets truncated hot loops run branch-free. `None` for grid.
    #[inline]
    pub fn order_prefix(&self, p: usize) -> Option<usize> {
        match self.layout {
            Layout::Graded => Some(self.len_at_order(p)),
            Layout::Grid => None,
        }
    }

    /// Iterate (position, index).
    pub fn iter(&self) -> impl Iterator<Item = (usize, &[u32])> {
        self.indices.iter().enumerate().map(|(i, a)| (i, a.as_slice()))
    }

    /// Evaluate all monomials x^α into `out` (len = `self.len()`),
    /// using the parent chain: x^α = x^{parent(α)} · x_{added_dim}.
    pub fn eval_monomials(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert_eq!(out.len(), self.len());
        out[0] = 1.0;
        for i in 1..self.len() {
            out[i] = out[self.parent[i]] * x[self.added_dim[i]];
        }
    }

    /// Convenience allocating variant of [`eval_monomials`].
    pub fn monomials(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.len()];
        self.eval_monomials(x, &mut out);
        out
    }

    /// Expected set size without enumerating: pᴰ or C(D+p−1, D).
    pub fn expected_len(layout: Layout, dim: usize, order: usize) -> f64 {
        match layout {
            Layout::Grid => (order as f64).powi(dim as i32),
            Layout::Graded => binomial(dim + order - 1, dim),
        }
    }
}

/// Componentwise α ≤ β.
#[inline]
pub fn leq(a: &[u32], b: &[u32]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y)
}

/// Componentwise difference β − α (caller guarantees α ≤ β).
#[inline]
pub fn sub(b: &[u32], a: &[u32]) -> Vec<u32> {
    b.iter().zip(a).map(|(x, y)| x - y).collect()
}

/// Componentwise sum α + β.
#[inline]
pub fn add(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// α! as f64.
pub fn multi_factorial(a: &[u32]) -> f64 {
    a.iter().map(|&v| factorial(v as usize)).product()
}

/// Grid (mixed-radix) enumeration: all α with α_d ∈ [0, p), dimension 0
/// slowest — position of α is Σ α_d · p^(D−1−d).
fn enumerate_grid(dim: usize, p: usize) -> Vec<Vec<u32>> {
    // lint: allow(no-panic): explicit capacity guard — a grid overflowing u64 is an upstream caller bug
    let total = (p as u64).checked_pow(dim as u32).expect("grid too large") as usize;
    let mut out = Vec::with_capacity(total);
    let mut cur = vec![0u32; dim];
    loop {
        out.push(cur.clone());
        // increment mixed-radix counter, last dim fastest
        let mut d = dim;
        loop {
            if d == 0 {
                return out;
            }
            d -= 1;
            cur[d] += 1;
            if (cur[d] as usize) < p {
                break;
            }
            cur[d] = 0;
        }
    }
}

/// Graded lexicographic enumeration: degree 0, 1, …, p−1; within each
/// degree, lexicographic (dimension 0 most significant).
fn enumerate_graded(dim: usize, p: usize) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    let mut cur = vec![0u32; dim];
    for deg in 0..p as u32 {
        emit_degree(&mut out, &mut cur, 0, deg);
    }
    out
}

fn emit_degree(out: &mut Vec<Vec<u32>>, cur: &mut Vec<u32>, d: usize, remaining: u32) {
    if d == cur.len() - 1 {
        cur[d] = remaining;
        out.push(cur.clone());
        cur[d] = 0;
        return;
    }
    // lexicographic: highest value in the current dimension first
    for v in (0..=remaining).rev() {
        cur[d] = v;
        emit_degree(out, cur, d + 1, remaining - v);
        cur[d] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_count_is_p_pow_d() {
        for (d, p) in [(1, 4), (2, 3), (3, 2), (4, 2)] {
            let s = MultiIndexSet::new(Layout::Grid, d, p);
            assert_eq!(s.len(), (p as usize).pow(d as u32));
            assert_eq!(s.len() as f64, MultiIndexSet::expected_len(Layout::Grid, d, p));
        }
    }

    #[test]
    fn graded_count_is_binomial() {
        for (d, p) in [(1, 5), (2, 8), (3, 6), (5, 4), (7, 2), (16, 2)] {
            let s = MultiIndexSet::new(Layout::Graded, d, p);
            assert_eq!(s.len() as f64, binomial(d + p - 1, d), "D={d} p={p}");
        }
    }

    #[test]
    fn graded_matches_paper_2d_p2_example() {
        // Section 2's example: order p=2, D=2 → indices (0,0),(1,0),(0,1).
        let s = MultiIndexSet::new(Layout::Graded, 2, 2);
        let idx: Vec<&[u32]> = s.iter().map(|(_, a)| a).collect();
        assert_eq!(idx, vec![&[0, 0][..], &[1, 0][..], &[0, 1][..]]);
    }

    #[test]
    fn grid_matches_paper_2d_p2_example() {
        // O(p^D) with p=2, D=2 → 4 indices incl. the mixed (1,1) term.
        let s = MultiIndexSet::new(Layout::Grid, 2, 2);
        let idx: Vec<&[u32]> = s.iter().map(|(_, a)| a).collect();
        assert_eq!(idx, vec![&[0, 0][..], &[0, 1][..], &[1, 0][..], &[1, 1][..]]);
    }

    #[test]
    fn graded_is_degree_sorted() {
        let s = MultiIndexSet::new(Layout::Graded, 3, 5);
        for i in 1..s.len() {
            assert!(s.degree(i) >= s.degree(i - 1));
        }
    }

    #[test]
    fn zero_index_first_everywhere() {
        for layout in [Layout::Grid, Layout::Graded] {
            let s = MultiIndexSet::new(layout, 3, 3);
            assert_eq!(s.index(0), &[0, 0, 0]);
            assert_eq!(s.degree(0), 0);
            assert_eq!(s.inv_factorial(0), 1.0);
        }
    }

    #[test]
    fn sets_are_downward_closed() {
        // Translation-operator exactness relies on downward closure:
        // α ≤ β ∧ β ∈ S ⇒ α ∈ S.
        for layout in [Layout::Grid, Layout::Graded] {
            let s = MultiIndexSet::new(layout, 3, 4);
            for (_, b) in s.iter() {
                let mut a = b.to_vec();
                for d in 0..3 {
                    if a[d] > 0 {
                        a[d] -= 1;
                        assert!(s.position(&a).is_some(), "{layout:?} {b:?} missing sub");
                        a[d] += 1;
                    }
                }
            }
        }
    }

    #[test]
    fn position_roundtrip() {
        let s = MultiIndexSet::new(Layout::Graded, 4, 3);
        for (i, a) in s.iter() {
            assert_eq!(s.position(a), Some(i));
        }
        assert_eq!(s.position(&[9, 9, 9, 9]), None);
    }

    #[test]
    fn monomials_match_direct_pow() {
        let x = [0.5, -2.0, 3.0];
        for layout in [Layout::Grid, Layout::Graded] {
            let s = MultiIndexSet::new(layout, 3, 4);
            let mono = s.monomials(&x);
            for (i, a) in s.iter() {
                let direct: f64 =
                    a.iter().zip(&x).map(|(&p, &v)| v.powi(p as i32)).product();
                assert!(
                    (mono[i] - direct).abs() <= 1e-12 * direct.abs().max(1.0),
                    "{layout:?} {a:?}: {} vs {direct}",
                    mono[i]
                );
            }
        }
    }

    #[test]
    fn inv_factorial_correct() {
        let s = MultiIndexSet::new(Layout::Grid, 2, 5);
        let i = s.position(&[3, 4]).unwrap();
        assert!((s.inv_factorial(i) - 1.0 / (6.0 * 24.0)).abs() < 1e-15);
    }

    #[test]
    fn componentwise_ops() {
        assert!(leq(&[1, 2], &[1, 3]));
        assert!(!leq(&[2, 0], &[1, 3]));
        assert_eq!(sub(&[3, 4], &[1, 2]), vec![2, 2]);
        assert_eq!(add(&[1, 2], &[3, 0]), vec![4, 2]);
        assert_eq!(multi_factorial(&[3, 2]), 12.0);
    }

    #[test]
    fn in_order_truncation() {
        let g = MultiIndexSet::new(Layout::Graded, 2, 4);
        // graded sub-order p=2 keeps exactly degree-0 and degree-1 terms
        assert_eq!(g.len_at_order(2), 3);
        assert_eq!(g.len_at_order(4), g.len());
        let gr = MultiIndexSet::new(Layout::Grid, 2, 3);
        // grid sub-order p=2 keeps indices with both components < 2 → 4
        assert_eq!(gr.len_at_order(2), 4);
        assert_eq!(gr.len_at_order(3), 9);
        let i = gr.position(&[2, 0]).unwrap();
        assert!(!gr.in_order(i, 2));
        assert!(gr.in_order(i, 3));
    }

    #[test]
    fn graded_suborder_is_prefix() {
        // degree-major enumeration ⇒ the order-p subset is a prefix
        let s = MultiIndexSet::new(Layout::Graded, 3, 5);
        for p in 1..=5 {
            let n = s.len_at_order(p);
            for i in 0..s.len() {
                assert_eq!(s.in_order(i, p), i < n);
            }
        }
    }

    #[test]
    fn large_graded_set_enumerates() {
        // D=16, p=2 (the PLIMIT>6 presumption means p=1, but the set for
        // p=2 should still be cheap): 17 indices.
        let s = MultiIndexSet::new(Layout::Graded, 16, 2);
        assert_eq!(s.len(), 17);
    }
}
