//! Factorial / binomial tables in `f64`. Orders in this codebase are
//! small (p ≤ 8 per dimension, sums α+β ≤ 2p), but bounds formulas take
//! factorials of up to D·p, so we keep a full table to 170 (the largest
//! n with n! finite in f64) and fall back to `ln_factorial` beyond.

// lint: allow(sync-bypass): process-wide one-time factorial table init below the runtime layer — no scheduling to explore
use std::sync::OnceLock;

const TABLE_N: usize = 171;

fn table() -> &'static [f64; TABLE_N] {
    // lint: allow(sync-bypass): process-wide one-time factorial table init below the runtime layer — no scheduling to explore
    static T: OnceLock<[f64; TABLE_N]> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = [1.0f64; TABLE_N];
        for n in 1..TABLE_N {
            t[n] = t[n - 1] * n as f64;
        }
        t
    })
}

/// n! as f64; `inf` for n > 170.
#[inline]
pub fn factorial(n: usize) -> f64 {
    if n < TABLE_N {
        table()[n]
    } else {
        f64::INFINITY
    }
}

/// ln(n!) via Stirling's series (exact table for small n).
pub fn ln_factorial(n: usize) -> f64 {
    if n < TABLE_N {
        return table()[n].ln();
    }
    let x = (n + 1) as f64;
    // Stirling: lnΓ(x) ≈ (x-½)ln x − x + ½ln(2π) + 1/(12x) − 1/(360x³)
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

/// Binomial coefficient C(n, k) as f64 (multiplicative form — exact for
/// the sizes we use, graceful for huge ones).
pub fn binomial(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut acc = 1.0f64;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_factorials_exact() {
        assert_eq!(factorial(0), 1.0);
        assert_eq!(factorial(1), 1.0);
        assert_eq!(factorial(5), 120.0);
        assert_eq!(factorial(10), 3628800.0);
    }

    #[test]
    fn overflow_is_infinite() {
        assert!(factorial(170).is_finite());
        assert!(factorial(171).is_infinite());
    }

    #[test]
    fn ln_factorial_consistent_with_table() {
        for n in [0, 1, 5, 20, 100, 170] {
            assert!((ln_factorial(n) - factorial(n).ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn ln_factorial_stirling_region() {
        // recurrence ln((n+1)!) = ln(n!) + ln(n+1) must hold across the
        // table/Stirling boundary
        for n in 168..400 {
            let lhs = ln_factorial(n + 1);
            let rhs = ln_factorial(n) + ((n + 1) as f64).ln();
            assert!((lhs - rhs).abs() < 1e-6, "n={n}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(5, 0), 1.0);
        assert_eq!(binomial(5, 2), 10.0);
        assert_eq!(binomial(5, 5), 1.0);
        assert_eq!(binomial(4, 7), 0.0);
        assert_eq!(binomial(23, 16), 245157.0);
    }

    #[test]
    fn binomial_symmetry_and_pascal() {
        for n in 1..20usize {
            for k in 0..=n {
                assert_eq!(binomial(n, k), binomial(n, n - k));
                if k >= 1 {
                    let pascal = binomial(n - 1, k - 1) + binomial(n - 1, k);
                    assert!((binomial(n, k) - pascal).abs() < 1e-6);
                }
            }
        }
    }
}
