//! # fastgauss
//!
//! A production-grade reproduction of *“Faster Gaussian Summation: Theory
//! and Experiment”* (Lee & Gray): dual-tree fast Gauss transforms with
//! O(Dᵖ) series expansions, rigorous per-operator error bounds, and the
//! token-based automatic error-control scheme, plus all the baselines the
//! paper compares against (naive, FGT, IFGT, DFD) and a KDE/bandwidth-
//! selection layer on top.
//!
//! Layer map (see DESIGN.md and the README "Architecture" section):
//! * L4 ([`api`]): the [`api::Session`] front door — prepare a dataset
//!   once, answer many [`api::EvalRequest`]s with any [`api::Method`]
//!   (or `Auto`), ε-verified FGT/IFGT tuning included. Every caller
//!   (KDE, LSCV, coordinator, CLI, examples, benches) goes through it.
//!   Sessions are kernel-independent ([`kernel::Kernel`]): Laplace,
//!   Matérn and inverse-multiquadric requests are answered through a
//!   certified sum-of-Gaussians decomposition ([`kernel::sog`]) whose
//!   sup-norm error is charged out of the ε budget
//!   ([`errorcontrol::split_epsilon_kernel`]) before fanning one
//!   Gaussian request per component into the pooled batch path; the
//!   Gaussian default is bit-for-bit unchanged.
//! * L3 (this crate): trees, expansions, translation operators, error
//!   control, the eight algorithms (the paper's seven plus the sliced
//!   Fourier engine [`algo::sliced`] for high dimensions, built on the
//!   certified 1-D fast sum in [`fourier`]), LSCV, sweep coordination,
//!   CLI.
//!   Every fan-out — dual-tree traversal splits, session batches, the
//!   coordinator's sweep cells — schedules onto one shared
//!   work-stealing pool ([`runtime::pool::WorkStealPool`]) with a
//!   fixed task decomposition and indexed reduction, so nested
//!   parallelism composes and results are bit-identical across pool
//!   widths. All exhaustive inner loops route through the shared
//!   [`compute`] drivers — by default the GEMM-shaped tiled base case
//!   ([`compute::tile`]: cached squared norms + dot-product tiles +
//!   the certified [`compute::fastexp`], its error reserved out of the
//!   ε budget by [`errorcontrol::split_epsilon`]), with the bit-exact
//!   SoA microkernel as the reference/fallback; the dual-tree
//!   traversal is generic over [`algo::dualtree::Expansion`] ×
//!   [`errorcontrol::PruneRule`], with the four paper variants
//!   monomorphized from it.
//! * L2/L1 (python, build-time only): a tiled exhaustive Gaussian
//!   summation graph whose hot tile is a Pallas kernel; AOT-lowered to
//!   HLO text in `artifacts/` and executed from [`runtime`] via PJRT
//!   (with a [`compute`]-backed CPU fallback when the `pjrt` feature is
//!   off).
//!
//! Quick start — the [`api::Session`] front door (prepare once,
//! evaluate many, automatic method selection):
//! ```no_run
//! use fastgauss::api::{EvalRequest, Session};
//! let data = fastgauss::data::synthetic::astro2d(1000, 42);
//! let h = fastgauss::kde::bandwidth::silverman(&data);
//! let session = Session::kde(&data);
//! let ans = session.evaluate(&EvalRequest::kde(h, 0.01)).unwrap();
//! println!("G(x_0) = {} via {}", ans.sums[0], ans.method);
//! ```

pub mod util;
pub mod prop;
pub mod geometry;
pub mod multiindex;
pub mod kernel;
pub mod compute;
pub mod hermite;
pub mod bounds;
pub mod tree;
pub mod errorcontrol;
pub mod fourier;
pub mod algo;
pub mod api;
pub mod kde;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod benchjson;
pub mod cli;
pub mod config;
pub mod lint;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::api::{EvalRequest, Evaluation, Method, PrepareOptions, Session};
    pub use crate::geometry::Matrix;
    pub use crate::kernel::{GaussianKernel, Kernel};
    pub use crate::tree::KdTree;
}
