//! # fastgauss
//!
//! A production-grade reproduction of *“Faster Gaussian Summation: Theory
//! and Experiment”* (Lee & Gray): dual-tree fast Gauss transforms with
//! O(Dᵖ) series expansions, rigorous per-operator error bounds, and the
//! token-based automatic error-control scheme, plus all the baselines the
//! paper compares against (naive, FGT, IFGT, DFD) and a KDE/bandwidth-
//! selection layer on top.
//!
//! Layer map (see DESIGN.md and the README "Architecture" section):
//! * L3 (this crate): trees, expansions, translation operators, error
//!   control, the six algorithms, LSCV, sweep coordination, CLI. All
//!   exhaustive inner loops route through the shared [`compute`] SoA
//!   microkernel; the dual-tree traversal is generic over
//!   [`algo::dualtree::Expansion`] × [`errorcontrol::PruneRule`], with
//!   the four paper variants monomorphized from it.
//! * L2/L1 (python, build-time only): a tiled exhaustive Gaussian
//!   summation graph whose hot tile is a Pallas kernel; AOT-lowered to
//!   HLO text in `artifacts/` and executed from [`runtime`] via PJRT
//!   (with a [`compute`]-backed CPU fallback when the `pjrt` feature is
//!   off).
//!
//! Quick start:
//! ```no_run
//! use fastgauss::algo::{dito::Dito, GaussSum, GaussSumProblem};
//! let data = fastgauss::data::synthetic::astro2d(1000, 42);
//! let h = fastgauss::kde::bandwidth::silverman(&data);
//! let out = Dito::default().run(&GaussSumProblem::kde(&data, h, 0.01)).unwrap();
//! println!("G(x_0) = {}", out.sums[0]);
//! ```

pub mod util;
pub mod prop;
pub mod geometry;
pub mod multiindex;
pub mod kernel;
pub mod compute;
pub mod hermite;
pub mod bounds;
pub mod tree;
pub mod errorcontrol;
pub mod algo;
pub mod kde;
pub mod data;
pub mod runtime;
pub mod coordinator;
pub mod cli;
pub mod config;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::geometry::Matrix;
    pub use crate::kernel::GaussianKernel;
    pub use crate::tree::KdTree;
}
