//! GEMM-shaped Q×R tile drivers — the fast base case.
//!
//! The single-query sweep ([`Scratch::gauss_dot`]) re-streams the
//! reference SoA lanes once *per query* and pays one libm `exp` per
//! pair. The tiled drivers here restructure the same leaf-sized
//! workload the way hardware likes it:
//!
//! 1. **Norms outer sum.** Squared distances come from the cached
//!    per-point squared norms (`‖q − r‖² = ‖q‖² + ‖r‖² − 2·q·r`,
//!    clamped at 0) — reference norms are computed once per dataset
//!    (at `KdTree::build`, h-independent) and live alongside the
//!    reordered points.
//! 2. **Dot-product tile.** [`microkernel::dot_tile`] streams each
//!    reference lane once per [`QUERY_TILE`] queries, a blocked
//!    multiply-accumulate the auto-vectorizer turns into FMA chains.
//! 3. **Fused fast exp.** The whole tile's exponents go through one
//!    [`fastexp::exp_block`] pass with a *certified* relative-error
//!    bound ([`fastexp::EXP_MAX_REL_ERR`]) instead of per-pair libm
//!    calls.
//!
//! The drivers never decide on their own whether the certified error is
//! affordable: ε-guaranteed callers run `errorcontrol::split_epsilon`
//! first, which subtracts the certified base-case error from the ε
//! budget (and falls back to the bit-exact [`Scratch::gauss_dot`] path
//! when the bandwidth is too small for the norms trick to be safe).

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

use super::fastexp;
use super::microkernel;
use super::simd;
use super::simd::Lanes;
use super::Scratch;

/// Queries processed per tile row-block: 8 keeps the query lanes and a
/// 2 KiB-per-row value tile L1-resident next to the reference lanes.
pub const QUERY_TILE: usize = 8;

/// Per-row squared norms `‖x_i‖²` of a point set, dims accumulated in
/// ascending order — the h-independent half of the norms-trick squared
/// distance. `KdTree::build` caches this in tree order.
pub fn sq_norms(points: &Matrix) -> Vec<f64> {
    (0..points.rows())
        .map(|i| {
            let row = points.row(i);
            let mut s = 0.0;
            for &v in row {
                s += v * v;
            }
            s
        })
        .collect()
}

/// Turn a dot-product row into Gaussian kernel values in place:
/// `vals[j] = K̃(max(qnorm + rnorm[j] − 2·vals[j], 0))` with the
/// certified fast exp. Shared by the tiled drivers and FGT's
/// sparse-box direct path.
#[inline]
pub fn gauss_from_norms_into(
    kernel: &GaussianKernel,
    qnorm: f64,
    rnorm: &[f64],
    vals: &mut [f64],
    n: usize,
) {
    simd::gauss_from_norms_scalar(kernel.neg_inv_two_h2(), qnorm, rnorm, vals, n);
}

/// [`gauss_from_norms_into`] through an explicit [`Lanes`] table — the
/// scalar table reproduces the plain function bit for bit; the vector
/// tables stay inside the certified budget (see `compute::simd`).
#[inline]
pub fn gauss_from_norms_into_with(
    lanes: &Lanes,
    kernel: &GaussianKernel,
    qnorm: f64,
    rnorm: &[f64],
    vals: &mut [f64],
    n: usize,
) {
    (lanes.gauss_from_norms)(kernel.neg_inv_two_h2(), qnorm, rnorm, vals, n);
}

/// The fast tiled base case: query rows `[qb, qe)` of `queries` (with
/// per-row squared norms `qnorms`, indexed by absolute row) against the
/// lanes currently loaded in `scratch` ([`Scratch::load`] +
/// [`Scratch::load_weights`] + [`Scratch::load_ref_norms`]).
/// Accumulates `out[i] += Σ_j w_j·K̃(‖q_(qb+i) − r_j‖)`.
///
/// Per pair the kernel value carries relative error ≤
/// [`fastexp::EXP_MAX_REL_ERR`] plus the norms-trick cancellation term
/// bounded by `errorcontrol::base_case_rel_err` — callers charge that
/// against their ε budget.
pub fn gauss_sums_fast_on_loaded(
    scratch: &mut Scratch,
    kernel: &GaussianKernel,
    queries: &Matrix,
    qnorms: &[f64],
    qb: usize,
    qe: usize,
    out: &mut [f64],
    lanes: &Lanes,
) {
    debug_assert_eq!(queries.cols(), scratch.dim, "scratch dimension mismatch");
    debug_assert_eq!(out.len(), qe - qb, "output length");
    let n = scratch.len;
    if n == 0 || qe == qb {
        return;
    }
    scratch.ensure_tile();
    let d = queries.cols();
    let stride = scratch.cap;
    let neg = kernel.neg_inv_two_h2();
    let Scratch { soa, w, rnorm, qsoa, qnorm, tile, .. } = scratch;
    debug_assert!(w.len() >= n && rnorm.len() >= n, "lane buffers shorter than loaded length");
    debug_assert!(tile.len() >= QUERY_TILE * stride, "value tile smaller than QUERY_TILE rows");
    debug_assert!(qnorms.len() >= qe, "query norms shorter than the query range");
    let mut q = qb;
    while q < qe {
        let nq = QUERY_TILE.min(qe - q);
        for t in 0..nq {
            let row = queries.row(q + t);
            for k in 0..d {
                qsoa[k * QUERY_TILE + t] = row[k];
            }
            qnorm[t] = qnorms[q + t];
        }
        (lanes.dot_tile)(qsoa, QUERY_TILE, nq, soa, stride, n, d, tile);
        for t in 0..nq {
            let row = &mut tile[t * stride..t * stride + n];
            (lanes.gauss_from_norms)(neg, qnorm[t], rnorm, row, n);
            out[q - qb + t] += (lanes.weighted_sum)(&w[..n], row);
        }
        q += nq;
    }
}

/// The mixed-precision tiled base case: the same shape as
/// [`gauss_sums_fast_on_loaded`] with the reference coordinates,
/// weights, norms and the dot tile in f32 (loaded via
/// [`Scratch::load_f32`] / [`Scratch::load_weights_f32`] /
/// [`Scratch::load_ref_norms_f32`]) — half the lane memory traffic and
/// twice the vector width in the GEMM part — while the exponent is
/// widened back to f64 for the certified exp and the weighted
/// reduction accumulates in f64.
///
/// Per pair the kernel value carries relative error ≤
/// `errorcontrol::base_case_rel_err_f32(dim, h, max‖x‖²)`; callers
/// must have charged that bound against ε via
/// `errorcontrol::split_epsilon_prec` (which refuses the route — the
/// `f32_tile` flag stays false — whenever it does not fit in ε/4).
pub fn gauss_sums_fast_f32_on_loaded(
    scratch: &mut Scratch,
    kernel: &GaussianKernel,
    queries: &Matrix,
    qnorms: &[f64],
    qb: usize,
    qe: usize,
    out: &mut [f64],
    lanes: &Lanes,
) {
    debug_assert_eq!(queries.cols(), scratch.dim, "scratch dimension mismatch");
    debug_assert_eq!(out.len(), qe - qb, "output length");
    let n = scratch.len;
    if n == 0 || qe == qb {
        return;
    }
    scratch.ensure_f32();
    scratch.ensure_tile32();
    let d = queries.cols();
    let stride = scratch.cap;
    let neg = kernel.neg_inv_two_h2();
    let Scratch { soa32, w32, rnorm32, qsoa32, tile32, sq, .. } = scratch;
    debug_assert!(w32.len() >= n && rnorm32.len() >= n, "f32 lanes shorter than loaded length");
    debug_assert!(tile32.len() >= QUERY_TILE * stride && sq.len() >= n, "f32 tile too small");
    debug_assert!(qnorms.len() >= qe, "query norms shorter than the query range");
    let mut q = qb;
    while q < qe {
        let nq = QUERY_TILE.min(qe - q);
        for t in 0..nq {
            let row = queries.row(q + t);
            for k in 0..d {
                qsoa32[k * QUERY_TILE + t] = row[k] as f32;
            }
        }
        (lanes.dot_tile_f32)(qsoa32, QUERY_TILE, nq, soa32, stride, n, d, tile32);
        for t in 0..nq {
            let qn32 = qnorms[q + t] as f32;
            let dots = &tile32[t * stride..t * stride + n];
            let (evals, rn) = (&mut sq[..n], &rnorm32[..n]);
            for j in 0..n {
                evals[j] = f64::from((qn32 + rn[j] - 2.0 * dots[j]).max(0.0)) * neg;
            }
            (lanes.exp_block)(evals);
            let mut acc = 0.0;
            for j in 0..n {
                acc += f64::from(w32[j]) * evals[j];
            }
            out[q - qb + t] += acc;
        }
        q += nq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::reference;
    use crate::util::Pcg32;

    fn random(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        Matrix::from_rows(
            &(0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect::<Vec<_>>(),
        )
    }

    #[test]
    fn sq_norms_matches_manual() {
        let m = Matrix::from_rows(&[vec![3.0, 4.0], vec![0.0, 0.0], vec![-1.0, 2.0]]);
        assert_eq!(sq_norms(&m), vec![25.0, 0.0, 5.0]);
    }

    #[test]
    fn fast_tile_matches_scalar_reference_within_certified_budget() {
        let kernel = GaussianKernel::new(0.35);
        // both the scalar reference table and whatever the process
        // detected must stay inside the certified budget
        for lanes in [simd::scalar(), simd::active()] {
            for (nq, nr, d) in [(1, 1, 1), (3, 7, 2), (8, 13, 3), (13, 40, 5), (30, 64, 2)] {
                let q = random(nq, d, 500 + nq as u64);
                let r = random(nr, d, 600 + nr as u64);
                let w: Vec<f64> = (0..nr).map(|i| 0.5 + 0.01 * i as f64).collect();
                let mut want = vec![0.0; nq];
                reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut want);
                let qnorms = sq_norms(&q);
                let rnorms = sq_norms(&r);
                let mut scratch = Scratch::new(d);
                scratch.load(&r, 0, nr);
                scratch.load_weights(&w, 0, nr);
                scratch.load_ref_norms(&rnorms, 0, nr);
                let mut got = vec![0.0; nq];
                gauss_sums_fast_on_loaded(
                    &mut scratch,
                    &kernel,
                    &q,
                    &qnorms,
                    0,
                    nq,
                    &mut got,
                    lanes,
                );
                for i in 0..nq {
                    // max(1e-300) keeps a zero-sum cell from turning the
                    // assert into NaN (which would pass inverted)
                    let rel = (got[i] - want[i]).abs() / want[i].max(1e-300);
                    assert!(rel <= 1e-12, "nq={nq} nr={nr} d={d} i={i}: rel={rel:.2e}");
                }
            }
        }
    }

    #[test]
    fn f32_tile_stays_within_derived_f32_budget() {
        let h = 0.5;
        let kernel = GaussianKernel::new(h);
        for lanes in [simd::scalar(), simd::active()] {
            let (nq, nr, d) = (13, 40, 3);
            let q = random(nq, d, 91);
            let r = random(nr, d, 92);
            let w: Vec<f64> = (0..nr).map(|i| 0.5 + 0.01 * i as f64).collect();
            let mut want = vec![0.0; nq];
            reference::scalar_gauss_sums(&q, &r, &w, &kernel, &mut want);
            let qnorms = sq_norms(&q);
            let rnorms = sq_norms(&r);
            let rnorms32: Vec<f32> = rnorms.iter().map(|&v| v as f32).collect();
            let mut scratch = Scratch::new(d);
            scratch.load_f32(&r, 0, nr);
            scratch.load_weights_f32(&w, 0, nr);
            scratch.load_ref_norms_f32(&rnorms32, 0, nr);
            let mut got = vec![0.0; nq];
            gauss_sums_fast_f32_on_loaded(
                &mut scratch,
                &kernel,
                &q,
                &qnorms,
                0,
                nq,
                &mut got,
                lanes,
            );
            let max_sq = qnorms.iter().chain(rnorms.iter()).cloned().fold(0.0, f64::max);
            let bound = crate::errorcontrol::base_case_rel_err_f32(d, h, max_sq);
            for i in 0..nq {
                let rel = (got[i] - want[i]).abs() / want[i].max(1e-300);
                assert!(rel <= bound, "i={i}: rel={rel:.2e} bound={bound:.2e}");
            }
        }
    }

    #[test]
    fn gauss_from_norms_matches_eval_sq() {
        let kernel = GaussianKernel::new(0.6);
        let r = random(9, 3, 77);
        let rnorms = sq_norms(&r);
        let q = [0.2, 0.5, 0.9];
        let qn: f64 = q.iter().map(|v| v * v).sum();
        let stride = 16;
        let mut soa = vec![0.0; 3 * stride];
        microkernel::transpose_rows(&r, 0, 9, stride, &mut soa);
        let mut vals = vec![0.0; stride];
        microkernel::dot_soa(&q, &soa, stride, 9, &mut vals);
        gauss_from_norms_into(&kernel, qn, &rnorms, &mut vals, 9);
        for j in 0..9 {
            let want = kernel.eval_sq(crate::geometry::sqdist(&q, r.row(j)));
            let rel = (vals[j] - want).abs() / want.max(1e-300);
            assert!(rel <= 1e-12, "j={j}: rel={rel:.2e}");
        }
    }

    #[test]
    fn tile_accumulates_into_existing_output() {
        let kernel = GaussianKernel::new(0.5);
        let r = random(5, 2, 88);
        let q = random(2, 2, 89);
        let w = vec![1.0; 5];
        let (qnorms, rnorms) = (sq_norms(&q), sq_norms(&r));
        let mut scratch = Scratch::new(2);
        scratch.load(&r, 0, 5);
        scratch.load_weights(&w, 0, 5);
        scratch.load_ref_norms(&rnorms, 0, 5);
        let lanes = simd::scalar();
        let mut once = vec![0.0; 2];
        gauss_sums_fast_on_loaded(&mut scratch, &kernel, &q, &qnorms, 0, 2, &mut once, lanes);
        let mut twice = vec![0.0; 2];
        gauss_sums_fast_on_loaded(&mut scratch, &kernel, &q, &qnorms, 0, 2, &mut twice, lanes);
        gauss_sums_fast_on_loaded(&mut scratch, &kernel, &q, &qnorms, 0, 2, &mut twice, lanes);
        for i in 0..2 {
            assert!((twice[i] - 2.0 * once[i]).abs() < 1e-14);
        }
    }
}
