//! The per-thread scratch arena behind the SoA microkernel.

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

use super::microkernel;
use super::simd;
use super::tile::QUERY_TILE;
use super::BLOCK;

/// Reusable block workspace: SoA coordinate lanes, a weight lane, a
/// squared-distance/kernel-value lane, and (for the tiled fast path,
/// see [`super::tile`]) a reference-norm lane plus a query tile.
///
/// Capacity grows on demand, so sizing is an *optimization*, not a
/// correctness requirement: construct it once with the largest block the
/// workload will see (e.g. the tree's maximum leaf count) and every
/// later call is allocation-free. The dual-tree traversal keeps one
/// `Scratch` inside each task `State`, recycled through a
/// per-evaluate free list on the shared work-stealing pool — so live
/// arenas track the pool's effective concurrency and stay hot across
/// the tasks each one serves.
#[derive(Clone, Debug)]
pub struct Scratch {
    pub(super) dim: usize,
    /// Lane capacity (the SoA stride).
    pub(super) cap: usize,
    /// Lanes currently loaded.
    pub(super) len: usize,
    /// Dim-major coordinates: `soa[k·cap + j]` = coordinate k of lane j.
    pub(super) soa: Vec<f64>,
    /// Per-lane weights.
    pub(super) w: Vec<f64>,
    /// Per-lane squared distances, overwritten with kernel values.
    pub(super) sq: Vec<f64>,
    /// Per-lane cached squared norms ‖r‖² (tiled fast path only).
    pub(super) rnorm: Vec<f64>,
    /// Dim-major query tile, stride [`QUERY_TILE`].
    pub(super) qsoa: Vec<f64>,
    /// Per-tile-row query squared norms.
    pub(super) qnorm: [f64; QUERY_TILE],
    /// QUERY_TILE × cap exponent/kernel-value tile (sized lazily by
    /// [`Scratch::ensure_tile`] — only the tiled drivers pay for it).
    pub(super) tile: Vec<f64>,
    /// f32 mirrors of the SoA/weight/norm lanes plus an f32 dot tile,
    /// for the mixed-precision base case ([`super::tile`]'s f32
    /// driver). Sized lazily by [`Scratch::ensure_f32`] /
    /// [`Scratch::ensure_tile32`] — f64-only sessions never pay for
    /// them.
    pub(super) soa32: Vec<f32>,
    pub(super) w32: Vec<f32>,
    pub(super) rnorm32: Vec<f32>,
    pub(super) qsoa32: Vec<f32>,
    pub(super) tile32: Vec<f32>,
}

impl Scratch {
    /// Workspace for dimension `dim` with the default [`BLOCK`] width.
    pub fn new(dim: usize) -> Self {
        Self::with_block(dim, BLOCK)
    }

    /// Workspace with an explicit initial block capacity.
    pub fn with_block(dim: usize, block: usize) -> Self {
        let cap = block.max(1);
        Scratch {
            dim,
            cap,
            len: 0,
            soa: vec![0.0; dim.max(1) * cap],
            w: vec![0.0; cap],
            sq: vec![0.0; cap],
            rnorm: vec![0.0; cap],
            qsoa: vec![0.0; dim.max(1) * QUERY_TILE],
            qnorm: [0.0; QUERY_TILE],
            tile: Vec::new(),
            soa32: Vec::new(),
            w32: Vec::new(),
            rnorm32: Vec::new(),
            qsoa32: Vec::new(),
            tile32: Vec::new(),
        }
    }

    /// Current lane capacity.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lanes loaded by the last `load*` call.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no lanes are loaded.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn reserve(&mut self, n: usize) {
        if n > self.cap {
            self.cap = n;
            self.soa = vec![0.0; self.dim.max(1) * n];
            self.w = vec![0.0; n];
            self.sq = vec![0.0; n];
            self.rnorm = vec![0.0; n];
            if !self.tile.is_empty() {
                self.tile = vec![0.0; QUERY_TILE * n];
            }
        }
    }

    /// Size the QUERY_TILE × cap value tile (lazy: only the tiled fast
    /// drivers need it, and e.g. the k-center sweep's giant scratch
    /// never should pay QUERY_TILE× its lane memory).
    pub(super) fn ensure_tile(&mut self) {
        if self.tile.len() < QUERY_TILE * self.cap {
            self.tile = vec![0.0; QUERY_TILE * self.cap];
        }
    }

    /// Size the f32 coordinate/weight/norm lanes (lazy, self-healing
    /// after a [`reserve`] growth: the length check re-allocates all
    /// four together whenever the capacity has moved).
    ///
    /// [`reserve`]: Scratch::reserve
    pub(super) fn ensure_f32(&mut self) {
        let lanes = self.dim.max(1) * self.cap;
        if self.soa32.len() < lanes {
            self.soa32 = vec![0.0; lanes];
            self.w32 = vec![0.0; self.cap];
            self.rnorm32 = vec![0.0; self.cap];
            self.qsoa32 = vec![0.0; self.dim.max(1) * QUERY_TILE];
        }
    }

    /// Size the QUERY_TILE × cap f32 dot tile (lazy, like
    /// [`Scratch::ensure_tile`]).
    pub(super) fn ensure_tile32(&mut self) {
        if self.tile32.len() < QUERY_TILE * self.cap {
            self.tile32 = vec![0.0; QUERY_TILE * self.cap];
        }
    }

    /// Load rows `[begin, end)` of `pts` into the SoA lanes. Returns the
    /// lane count.
    pub fn load(&mut self, pts: &Matrix, begin: usize, end: usize) -> usize {
        debug_assert_eq!(pts.cols(), self.dim, "scratch dimension mismatch");
        let n = end - begin;
        self.reserve(n);
        microkernel::transpose_rows(pts, begin, end, self.cap, &mut self.soa);
        self.len = n;
        n
    }

    /// Gather `idx` rows of `pts` into the SoA lanes (in `idx` order).
    pub fn load_indexed(&mut self, pts: &Matrix, idx: &[usize]) -> usize {
        debug_assert_eq!(pts.cols(), self.dim, "scratch dimension mismatch");
        self.reserve(idx.len());
        microkernel::transpose_rows_indexed(pts, idx, self.cap, &mut self.soa);
        self.len = idx.len();
        self.len
    }

    /// Load the weight lane for the same range as the last [`load`].
    ///
    /// [`load`]: Scratch::load
    pub fn load_weights(&mut self, weights: &[f64], begin: usize, end: usize) {
        debug_assert_eq!(end - begin, self.len, "weight range must match loaded lanes");
        self.w[..self.len].copy_from_slice(&weights[begin..end]);
    }

    /// Gather the weight lane for the same `idx` as [`load_indexed`].
    ///
    /// [`load_indexed`]: Scratch::load_indexed
    pub fn load_weights_indexed(&mut self, weights: &[f64], idx: &[usize]) {
        debug_assert_eq!(idx.len(), self.len, "weight index must match loaded lanes");
        for (j, &i) in idx.iter().enumerate() {
            self.w[j] = weights[i];
        }
    }

    /// Load the cached squared-norm lane for the same range as the last
    /// [`load`] (tiled fast path; `norms[i]` = ‖pts.row(i)‖²).
    ///
    /// [`load`]: Scratch::load
    pub fn load_ref_norms(&mut self, norms: &[f64], begin: usize, end: usize) {
        debug_assert_eq!(end - begin, self.len, "norm range must match loaded lanes");
        self.rnorm[..self.len].copy_from_slice(&norms[begin..end]);
    }

    /// [`Scratch::load`] rounded to the f32 coordinate lanes (the
    /// mixed-precision tile; the f64→f32 representation error is
    /// charged by `errorcontrol::base_case_rel_err_f32`).
    pub fn load_f32(&mut self, pts: &Matrix, begin: usize, end: usize) -> usize {
        debug_assert_eq!(pts.cols(), self.dim, "scratch dimension mismatch");
        let n = end - begin;
        self.reserve(n);
        self.ensure_f32();
        for j in 0..n {
            let row = pts.row(begin + j);
            for k in 0..self.dim {
                self.soa32[k * self.cap + j] = row[k] as f32;
            }
        }
        self.len = n;
        n
    }

    /// [`Scratch::load_weights`] rounded to the f32 weight lane.
    pub fn load_weights_f32(&mut self, weights: &[f64], begin: usize, end: usize) {
        debug_assert_eq!(end - begin, self.len, "weight range must match loaded lanes");
        self.ensure_f32();
        for (j, &v) in weights[begin..end].iter().enumerate() {
            self.w32[j] = v as f32;
        }
    }

    /// [`Scratch::load_ref_norms`] from pre-rounded f32 shadow norms
    /// (`KdTree::sq_norms_f32`).
    pub fn load_ref_norms_f32(&mut self, norms: &[f32], begin: usize, end: usize) {
        debug_assert_eq!(end - begin, self.len, "norm range must match loaded lanes");
        self.ensure_f32();
        self.rnorm32[..self.len].copy_from_slice(&norms[begin..end]);
    }

    /// Squared distances from `q` to every loaded lane; returns the
    /// filled slice.
    pub fn sqdist_into(&mut self, q: &[f64]) -> &[f64] {
        microkernel::sqdist_soa(q, &self.soa, self.cap, self.len, &mut self.sq);
        &self.sq[..self.len]
    }

    /// Squared distances via the norms trick
    /// `‖q − r‖² = ‖q‖² + ‖r‖² − 2·q·r` (clamped at 0), using the lane
    /// norms loaded by [`load_ref_norms`]. One multiply-add stream per
    /// dimension instead of sub-square-add — the GEMM-shaped form. The
    /// cancellation error is O(ε_mach·‖q‖·‖r‖) *absolute* (not
    /// relative), which is why ε-guaranteed callers go through
    /// `errorcontrol::split_epsilon` before choosing this path.
    ///
    /// [`load_ref_norms`]: Scratch::load_ref_norms
    pub fn sqdist_into_via_norms(&mut self, q: &[f64], qnorm: f64) -> &[f64] {
        // the scalar table entry IS `microkernel::dot_soa` — this is
        // the pinned bit-exact reference path, reached like every
        // other kernel call: through a Lanes table
        (simd::scalar().dot_soa)(q, &self.soa, self.cap, self.len, &mut self.sq);
        let n = self.len;
        debug_assert!(self.rnorm.len() >= n, "norm lane was not loaded for the loaded lanes");
        let (sq, rnorm) = (&mut self.sq[..n], &self.rnorm[..n]);
        for j in 0..n {
            sq[j] = (qnorm + rnorm[j] - 2.0 * sq[j]).max(0.0);
        }
        &self.sq[..n]
    }

    /// The fused hot path: squared distances from `q`, Gaussian over the
    /// block, then the weighted reduction against the loaded weights —
    /// `Σ_j w_j·K(‖q − lane_j‖)`.
    pub fn gauss_dot(&mut self, kernel: &GaussianKernel, q: &[f64]) -> f64 {
        let n = self.len;
        microkernel::sqdist_soa(q, &self.soa, self.cap, n, &mut self.sq);
        microkernel::gauss_in_place(kernel, &mut self.sq[..n]);
        // scalar-table dispatch: same pointer as the microkernel, so
        // the bit-exact contract of this path is untouched
        (simd::scalar().weighted_sum)(&self.w[..n], &self.sq[..n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::sqdist;

    #[test]
    fn grows_beyond_initial_block() {
        let pts = Matrix::from_rows(&(0..40).map(|i| vec![i as f64, 0.0]).collect::<Vec<_>>());
        let mut s = Scratch::with_block(2, 4);
        assert_eq!(s.capacity(), 4);
        assert_eq!(s.load(&pts, 0, 40), 40);
        assert!(s.capacity() >= 40);
        let sq = s.sqdist_into(&[0.0, 0.0]);
        for (j, &v) in sq.iter().enumerate() {
            assert_eq!(v, (j * j) as f64);
        }
    }

    #[test]
    fn sqdist_via_norms_matches_direct_within_cancellation() {
        let pts = Matrix::from_rows(&[vec![0.1, 0.9], vec![0.4, 0.4], vec![0.85, 0.2]]);
        let norms: Vec<f64> = (0..3)
            .map(|i| pts.row(i).iter().map(|v| v * v).sum())
            .collect();
        let mut s = Scratch::new(2);
        s.load(&pts, 0, 3);
        s.load_ref_norms(&norms, 0, 3);
        let q = [0.3, 0.6];
        let qn: f64 = q.iter().map(|v| v * v).sum();
        let via_norms: Vec<f64> = s.sqdist_into_via_norms(&q, qn).to_vec();
        for (j, &v) in via_norms.iter().enumerate() {
            let direct = sqdist(&q, pts.row(j));
            assert!((v - direct).abs() <= 1e-14, "lane {j}: {v} vs {direct}");
            assert!(v >= 0.0);
        }
    }

    #[test]
    fn gauss_dot_matches_scalar() {
        let pts = Matrix::from_rows(&[vec![0.0, 1.0], vec![2.0, 2.0], vec![-1.0, 0.5]]);
        let w = [1.0, 0.5, 2.0];
        let kernel = GaussianKernel::new(0.8);
        let q = [0.25, 0.75];
        let mut s = Scratch::new(2);
        s.load(&pts, 0, 3);
        s.load_weights(&w, 0, 3);
        let got = s.gauss_dot(&kernel, &q);
        let mut want = 0.0;
        for i in 0..3 {
            want += w[i] * kernel.eval_sq(sqdist(&q, pts.row(i)));
        }
        assert_eq!(got, want);
    }
}
