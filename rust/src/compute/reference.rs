//! The pre-microkernel scalar triple loop, preserved verbatim as the
//! ground truth the SoA microkernel is tested (and benchmarked, see
//! `ablations` §basecase) against. Not used on any hot path.

use crate::geometry::Matrix;
use crate::kernel::GaussianKernel;

/// Unblocked exhaustive summation, one running accumulator per query:
/// `out[qi] += Σ_r weights[r]·K(‖queries_qi − refs_r‖)`. This is the
/// exact loop `algo::naive` and the dual-tree base case ran before the
/// compute layer existed.
pub fn scalar_gauss_sums(
    queries: &Matrix,
    refs: &Matrix,
    weights: &[f64],
    kernel: &GaussianKernel,
    out: &mut [f64],
) {
    assert_eq!(queries.cols(), refs.cols(), "dimension mismatch");
    assert_eq!(weights.len(), refs.rows(), "weights length");
    assert_eq!(out.len(), queries.rows(), "output length");
    let d = queries.cols();
    for (qi, sum) in out.iter_mut().enumerate() {
        let qrow = queries.row(qi);
        let mut acc = 0.0;
        for ri in 0..refs.rows() {
            let rrow = refs.row(ri);
            let mut sq = 0.0;
            for k in 0..d {
                let dd = qrow[k] - rrow[k];
                sq += dd * dd;
            }
            acc += weights[ri] * kernel.eval_sq(sq);
        }
        *sum += acc;
    }
}
